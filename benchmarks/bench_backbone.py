"""Deep-backbone memory/time tradeoff: layer-granular remat (DESIGN.md §13).

Trains-step cost of the DR-CircuitGNN backbone at depth {3, 15}, hidden
128, with layer remat on/off.  Peak training memory is read from the
compiled executable itself — ``jit(value_and_grad(loss)).lower(...)
.compile().memory_analysis().temp_size_in_bytes`` — XLA's own activation
arena size, deterministic and backend-honest (no allocator sampling).
Wall-clock is the usual ``time_jit`` median of the full fwd+bwd step.

The tradeoff being measured: with remat, the backward *recomputes* each
layer's fused forward instead of holding its activations, so peak temp
memory stops scaling with depth while step time pays roughly one extra
forward.  ``--smoke`` (CI leg) asserts the contract:

* remat peak temp bytes STRICTLY below the no-remat baseline at the
  deepest point (depth 15, hidden 128);
* loss and every grad leaf allclose remat-vs-not — remat is a
  rematerialization *schedule*, never a different program.

Rows append to ``BENCH_drspmm.json`` (kind="backbone") so the perf
trajectory records the memory curve across PRs.
"""

from __future__ import annotations

import sys
import time

import jax
import numpy as np

from benchmarks.common import append_json, emit, time_jit
from repro.core.hetero_mp import HeteroMPConfig
from repro.graphs.generator import generate_design
from repro.models.backbone import BackboneSpec
from repro.models.hgnn import init_drcircuitgnn, loss_fn


def _peak_temp_bytes(lowered_jit, *args) -> int:
    """XLA's compiled temp-arena size (activations + scratch) in bytes; 0
    when the backend does not expose a memory analysis."""
    try:
        mem = lowered_jit.lower(*args).compile().memory_analysis()
        return int(getattr(mem, "temp_size_in_bytes", 0) or 0)
    except Exception:
        return 0


def bench_backbone(scale=0.04, size="small", hidden=128, k=16,
                   depths=(3, 15), wiring="plain",
                   out_json="BENCH_drspmm.json", iters=5, smoke=False):
    g = generate_design(1, size, scale=scale)[0]
    fc, fn = g.x_cell.shape[1], g.x_net.shape[1]
    cfg = HeteroMPConfig(hidden=hidden, k_cell=k, k_net=k)
    entries = []
    peaks = {}
    for depth in depths:
        params = init_drcircuitgnn(jax.random.PRNGKey(0), hidden=hidden,
                                   n_layers=depth, f_cell=fc, f_net=fn)
        row = dict(depth=depth, hidden=hidden, wiring=wiring)
        out = {}
        for remat in (False, True):
            spec = BackboneSpec(depth=depth, hidden=hidden, wiring=wiring,
                                remat=remat)
            step = jax.jit(jax.value_and_grad(
                lambda p: loss_fn(p, g, cfg, spec)))
            peak = _peak_temp_bytes(step, params)
            us = time_jit(step, params, iters=iters)
            loss, grads = step(params)
            out[remat] = (peak, us, float(loss), grads)
            tag = "remat" if remat else "noremat"
            row[f"{tag}_peak_bytes"] = peak
            row[f"{tag}_step_us"] = us
        p0, t0, l0, g0 = out[False]
        p1, t1, l1, g1 = out[True]
        row["peak_ratio"] = ratio = p1 / max(p0, 1)
        row["time_ratio"] = t1 / max(t0, 1e-9)
        entries.append(row)
        peaks[depth] = (p0, p1)
        emit(f"backbone_step/d{depth}/h{hidden}/noremat", t0, f"peak={p0}B")
        emit(f"backbone_step/d{depth}/h{hidden}/remat", t1,
             f"peak={p1}B;peak_ratio_vs_noremat={ratio:.3f}x;"
             f"time_ratio={row['time_ratio']:.2f}x")
        # Parity is the contract, smoke or not: same loss, same grads.
        np.testing.assert_allclose(l1, l0, rtol=1e-5, atol=1e-7,
                                   err_msg=f"remat loss drifted, d={depth}")
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g0)):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5,
                err_msg=f"remat grads drifted, d={depth}")
    if smoke:
        p0, p1 = peaks[max(depths)]
        assert 0 < p1 < p0, (
            f"remat must strictly cut peak temp memory at depth "
            f"{max(depths)}: remat={p1}B vs noremat={p0}B")
    append_json(out_json, dict(
        ts=time.time(), kind="backbone", size=size, scale=scale,
        hidden=hidden, wiring=wiring, backend=jax.default_backend(),
        entries=entries))
    return entries


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        # CI-sized: tiny graph, but the REAL depth/width points of the
        # acceptance bar (depth 15, hidden 128) with the memory + parity
        # contracts asserted.
        bench_backbone(scale=0.02, iters=3, smoke=True)
    else:
        bench_backbone()
