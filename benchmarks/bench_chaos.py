"""Chaos-injection serving benchmark: the self-healing ladder under a
fixed-seed fault schedule (DESIGN.md §10, ISSUE-6 acceptance).

One mixed-size stream is served twice over two logical ring slots:

* **clean** — chaos off: the fault-free reference predictions AND the
  throughput baseline for the overhead row;
* **chaos** — a seeded :class:`~repro.fault.inject.FaultInjector` mixing a
  transient dispatch failure, a transient NaN-poisoned output, a
  straggler stall, and a simulated device loss (down long enough to trip
  quarantine, short enough that a probe re-admits the slot).

The run then **asserts** the containment contract rather than just timing
it: every request completes with predictions bit-identical to the clean
pass (``np.array_equal`` — member independence makes this exact), zero
failures, retries > 0, the lost slot quarantined AND re-admitted, and the
NaN poisoning caught by the output guard.  A third phase overloads a
bounded queue under ``admission="shed_oldest"`` and checks the shed
counter exactly.

Reported: healing throughput vs clean throughput (the chaos tax), plus
all ladder counters.  Appended to ``BENCH_serve.json`` (kind
``serve_chaos``) so the robustness trajectory is recorded across PRs.
"""

from __future__ import annotations

import sys
import threading
import time

import jax
import numpy as np

from benchmarks.bench_serve_circuit import make_stream
from benchmarks.common import append_json, emit
from repro.core.hetero_mp import HeteroMPConfig
from repro.fault import FaultInjector, FaultRule
from repro.models.hgnn import init_drcircuitgnn
from repro.serve import CircuitServeEngine


def _serve_stream(eng, stream):
    """Serve ``stream`` through serve_forever(); returns preds by index."""
    server = threading.Thread(target=eng.serve_forever)
    server.start()
    rids = [eng.submit(g) for g in stream]
    preds = [eng.result(r, timeout=600.0).pred for r in rids]
    return server, preds


def _chaos_schedule(seed: int):
    """The fixed schedule: one transient dispatch fault, one poisoned
    output, one straggler stall, one device loss on slot 1."""
    return FaultInjector([
        FaultRule("dispatch", at=(1,)),
        FaultRule("nan_output", at=(0,)),
        FaultRule("straggler", at=(3,), delay_s=0.02),
        FaultRule("device_loss", at=(0,), device=1, down_for=3),
    ], seed=seed)


def bench_chaos(n_per_class: int = 8, max_batch: int = 4, hidden: int = 64,
                classes=((220, 110), (430, 215)), seed: int = 0,
                out_json: str = "BENCH_serve.json"):
    rng = np.random.default_rng(0)
    stream = make_stream(rng, n_per_class, classes=classes)
    f_cell = stream[0].x_cell.shape[1]
    f_net = stream[0].x_net.shape[1]
    cfg = HeteroMPConfig(hidden=hidden, k_cell=16, k_net=16)
    params = init_drcircuitgnn(jax.random.PRNGKey(0), f_cell, f_net, hidden)
    devs = list(jax.local_devices())
    if len(devs) < 2:
        # two logical slots on one device still exercise quarantine routing
        devs = [devs[0], devs[0]]
    devs = devs[:2]
    ladder = dict(max_retries=3, retry_backoff_s=0.01, watchdog_s=120.0,
                  quarantine_after=2, probe_interval_s=0.2)

    # ---- clean pass: fault-free reference + throughput baseline
    eng = CircuitServeEngine(params, cfg, max_batch=max_batch,
                             max_wait_ms=25.0, devices=devs, **ladder)
    server, ref = _serve_stream(eng, stream)
    eng.stop()
    server.join()
    clean = eng.stats()

    # ---- chaos pass: same stream under the seeded schedule
    chaos = _chaos_schedule(seed)
    eng = CircuitServeEngine(params, cfg, max_batch=max_batch,
                             max_wait_ms=25.0, devices=devs, chaos=chaos,
                             **ladder)
    server, preds = _serve_stream(eng, stream)
    # keep a trickle flowing until the lost slot is probed back in
    deadline = time.time() + 300.0
    extra = 0
    while eng.ring.health()["readmissions"] < 1 and time.time() < deadline:
        assert eng.result(eng.submit(stream[0]),
                          timeout=600.0).pred is not None
        extra += 1
        time.sleep(0.02)
    eng.stop()
    server.join()
    st = eng.stats()

    # ---- the containment contract, asserted
    parity = all(np.array_equal(p, r) for p, r in zip(preds, ref))
    assert parity, "healed predictions diverged from the fault-free run"
    assert st["failures"] == 0, st
    assert st["retries"] >= 1, st
    assert st["nonfinite_outputs"] >= 1, st          # poison was caught
    assert st["quarantines"] >= 1 and st["probes"] >= 1, st
    assert st["readmissions"] >= 1, st
    assert st["device_health"] == ["up", "up"], st
    counts = chaos.counts()
    assert counts.get("dispatch") == 1 and counts.get("nan_output") == 1
    assert counts.get("device_loss", 0) >= 1

    # ---- admission overload: bounded queue sheds the FIFO head, exactly
    cap = 4
    burst = stream[:10]
    eng2 = CircuitServeEngine(params, cfg, max_batch=max_batch,
                              max_wait_ms=25.0, devices=devs[:1],
                              max_queue=cap, admission="shed_oldest")
    rids2 = [eng2.submit(g) for g in burst]
    eng2.run()
    shed = eng2.stats()
    assert shed["admission_shed"] == len(burst) - cap, shed
    served = sum(1 for r in rids2
                 if eng2.finished[r].error is None)
    assert served == cap, shed

    chaos_gps = st["requests"] / max(st["wall_s"], 1e-9)
    clean_gps = clean["requests"] / max(clean["wall_s"], 1e-9)
    emit("serve/chaos", 1e6 / max(chaos_gps, 1e-9),
         f"graphs_per_s={chaos_gps:.2f};clean={clean_gps:.2f};"
         f"retries={st['retries']};quarantines={st['quarantines']};"
         f"readmissions={st['readmissions']};parity=ok")
    record = dict(ts=time.time(), kind="serve_chaos", seed=seed,
                  backend=jax.default_backend(),
                  n_graphs=len(stream), extra_probe_requests=extra,
                  max_batch=max_batch, hidden=hidden,
                  classes=list(map(list, classes)),
                  clean_graphs_per_s=clean_gps,
                  chaos_graphs_per_s=chaos_gps,
                  healing_tax=1.0 - chaos_gps / max(clean_gps, 1e-9),
                  healthy_parity=parity,
                  retries=st["retries"], bisects=st["bisects"],
                  failures=st["failures"],
                  nonfinite_outputs=st["nonfinite_outputs"],
                  watchdog_timeouts=st["watchdog_timeouts"],
                  quarantines=st["quarantines"], probes=st["probes"],
                  readmissions=st["readmissions"],
                  admission_shed=shed["admission_shed"],
                  fault_counts=counts)
    append_json(out_json, record)
    return record


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        # CI-sized run: tiny classes, small stream
        r = bench_chaos(n_per_class=4, max_batch=2, hidden=32,
                        classes=((80, 40), (150, 75)))
    else:
        r = bench_chaos()
    print(f"[chaos] healed stream: {r['chaos_graphs_per_s']:.2f} graphs/s "
          f"vs {r['clean_graphs_per_s']:.2f} clean "
          f"({100 * r['healing_tax']:.1f}% healing tax), parity=ok, "
          f"retries={r['retries']}, quarantine->probe->readmit="
          f"{r['quarantines']}/{r['probes']}/{r['readmissions']}, "
          f"shed={r['admission_shed']}")
