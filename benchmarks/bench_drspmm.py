"""Fig. 11 analogue: DR-SpMM forward/backward kernel runtime vs the dense
SpMM baseline (cuSPARSE-analogue) and the row-balanced dense-operand SpMM
(GNNAdvisor-analogue), swept over K and embedding dim on the three
representative design sizes (Table 1, scaled for CPU wall-clock).

Timings use the bucketed XLA execution path (the Pallas kernels are
validated in interpret mode, which is not wall-clock-representative on CPU);
the *derived* column reports the byte-model speedup the CBSR gather traffic
predicts on TPU: dense reads N·D per aggregated row, DR reads N·k values +
indices.
"""

from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import append_json, emit, time_jit
from repro.core.cbsr import cbsr_from_dense
from repro.core.drelu import drelu
from repro.graphs.generator import generate_design
from repro.kernels import ops


def bench(scale=0.08):
    rng = np.random.default_rng(0)
    for size in ("small", "medium", "large"):
        g = generate_design(1, size, scale=scale)[0]
        for etype in ("near", "pin", "pinned"):
            es = g.edges[etype]
            n_src = es.adj.n_src
            for dim in (64, 128):
                x = jnp.asarray(rng.normal(size=(n_src, dim))
                                .astype(np.float32))
                t_dense = time_jit(
                    lambda xv: ops.spmm(es.adj, es.adj_t, xv), x)
                for k in (8, 16, 32):
                    if k >= dim:
                        continue
                    c = cbsr_from_dense(drelu(x, k), k)
                    t_dr = time_jit(
                        lambda v: ops.drspmm(es.adj, es.adj_t, v, c.idx,
                                             dim), c.values)
                    # backward
                    def bwd_dr(v):
                        return jax.grad(lambda q: jnp.sum(ops.drspmm(
                            es.adj, es.adj_t, q, c.idx, dim) ** 2))(v)

                    def bwd_dense(xv):
                        return jax.grad(lambda q: jnp.sum(ops.spmm(
                            es.adj, es.adj_t, q) ** 2))(xv)

                    t_dr_b = time_jit(bwd_dr, c.values)
                    t_dense_b = time_jit(bwd_dense, x)
                    byte_model = dim / (2 * k)      # val+idx per survivor
                    emit(f"drspmm_fwd/{size}/{etype}/d{dim}/k{k}", t_dr,
                         f"speedup_vs_dense={t_dense / t_dr:.2f}x;"
                         f"tpu_byte_model={byte_model:.1f}x")
                    emit(f"drspmm_bwd/{size}/{etype}/d{dim}/k{k}", t_dr_b,
                         f"speedup_vs_dense={t_dense_b / t_dr_b:.2f}x")


def _count_pallas(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for sub in jax.core.jaxprs_in_params(eqn.params):
            n += _count_pallas(sub)
    return n


def dispatch_count(fn, *args) -> int:
    """Number of pallas_call dispatches in the traced computation."""
    return _count_pallas(jax.make_jaxpr(fn)(*args).jaxpr)


def bench_fused(scale=0.08, size="medium", dim=64, k=16,
                out_json="BENCH_drspmm.json", iters=10, smoke=False):
    """Single-dispatch fused executor vs the per-bucket reference path.

    Two measurements per edge-type direction, matching the repo's timing
    convention (Pallas is validated in interpret mode on CPU, which is not
    wall-clock-representative — see ``bench()``):

    * **dispatches** — pallas_call count in the traced computation:
      ``pallas_fused`` must be exactly 1 per direction vs one per degree
      bucket for ``pallas``.
    * **wall-clock** — the fused arena layout vs the per-bucket layout, both
      executed at real XLA speed (``xla_fused`` vs ``xla``).  This isolates
      what the fused packing buys structurally: the adaptive per-row-block
      chunking (~2× fewer padded slots on heavy-tailed degrees) and one
      segment-combine instead of a scatter-add per bucket.

    Results are appended to ``BENCH_drspmm.json`` so the perf trajectory is
    recorded across PRs.
    """
    rng = np.random.default_rng(0)
    g = generate_design(1, size, scale=scale)[0]
    entries = []
    tot = {"xla": 0.0, "xla_fused": 0.0}
    for etype in ("near", "pin", "pinned"):
        es = g.edges[etype]
        n_src = es.adj.n_src
        x = jnp.asarray(rng.normal(size=(n_src, dim)).astype(np.float32))
        c = cbsr_from_dense(drelu(x, k), k)

        def fwd(v, be):
            return ops.drspmm(es.adj, es.adj_t, v, c.idx, dim, backend=be)

        def bwd(v, be):
            return jax.grad(lambda q: jnp.sum(fwd(q, be) ** 2))(v)

        disp = {be: dispatch_count(lambda v: fwd(v, be), c.values)
                for be in ("pallas", "pallas_fused")}
        stats = {}
        for be in ("xla", "xla_fused"):
            stats[be] = dict(
                fwd_us=time_jit(lambda v: fwd(v, be), c.values, iters=iters),
                bwd_us=time_jit(lambda v: bwd(v, be), c.values, iters=iters),
            )
            tot[be] += stats[be]["fwd_us"] + stats[be]["bwd_us"]
        n_buckets = len(es.adj.buckets)
        sp_f = stats["xla"]["fwd_us"] / stats["xla_fused"]["fwd_us"]
        sp_b = stats["xla"]["bwd_us"] / stats["xla_fused"]["bwd_us"]
        emit(f"fused_fwd/{size}/{etype}/d{dim}/k{k}",
             stats["xla_fused"]["fwd_us"],
             f"speedup_vs_bucketed={sp_f:.2f}x;"
             f"dispatches={disp['pallas_fused']}"
             f"(bucketed={disp['pallas']},buckets={n_buckets})")
        emit(f"fused_bwd/{size}/{etype}/d{dim}/k{k}",
             stats["xla_fused"]["bwd_us"],
             f"speedup_vs_bucketed={sp_b:.2f}x")
        entries.append(dict(etype=etype, size=size, dim=dim, k=k,
                            n_buckets=n_buckets, nnz=es.adj.nnz,
                            dispatches_fused=disp["pallas_fused"],
                            dispatches_bucketed=disp["pallas"],
                            **{f"{be}_{m}": v for be, s in stats.items()
                               for m, v in s.items()},
                            fwd_speedup=sp_f, bwd_speedup=sp_b))
    agg = tot["xla"] / max(tot["xla_fused"], 1e-9)
    emit(f"fused_aggregate/{size}", tot["xla_fused"],
         f"aggregate_speedup_vs_bucketed={agg:.2f}x")
    append_json(out_json, dict(
        ts=time.time(), kind="fused_vs_bucketed", size=size, scale=scale,
        backend=jax.default_backend(), aggregate_speedup=agg,
        entries=entries))
    if smoke:
        # §14 acceptance guard: size-adaptive tiering leaves no relation
        # slower than the bucketed baseline in EITHER direction
        bad = [(e["etype"], e["fwd_speedup"], e["bwd_speedup"])
               for e in entries
               if e["fwd_speedup"] < 1.0 or e["bwd_speedup"] < 1.0]
        assert not bad, f"sub-1.0x fused_vs_bucketed rows: {bad}"
    return entries


def bench_learnable(scale=0.08, size="medium", dim=64, k=16,
                    out_json="BENCH_drspmm.json", iters=10):
    """Fused learnable-edge path vs the per-bucket slab loop, fwd + bwd.

    ``drspmm_learnable`` (differentiable per-edge weights) over each
    edge-type direction: the per-bucket reference gathers the canonical
    weight vector into one eid slab per degree bucket and loops
    (backend="xla"); the fused path gathers straight into the single
    arena (backend="xla_fused").  Timing follows the repo convention
    (xla-family wall-clock on CPU; Pallas interpret-mode anti-correlates
    with TPU, see ``bench()``); the pallas-family dispatch counts record
    the single-dispatch property.  The backward leg times BOTH gradients
    (dw + dx) — the sampled-dot dw reduction rides the same arena.
    """
    from repro.graphs.ell import ell_to_coo, pack_eid_slabs

    rng = np.random.default_rng(0)
    g = generate_design(1, size, scale=scale)[0]
    entries = []
    tot = {"xla": 0.0, "xla_fused": 0.0}
    for etype in ("near", "pin", "pinned"):
        es = g.edges[etype]
        dst, src, _w = ell_to_coo(es.adj)
        order = np.argsort(dst, kind="stable")
        fwd, bwd, _o, nnz = pack_eid_slabs(dst[order], src[order],
                                           es.adj.n_dst, es.adj.n_src)
        n_src = es.adj.n_src
        x = jnp.asarray(rng.normal(size=(n_src, dim)).astype(np.float32))
        c = cbsr_from_dense(drelu(x, k), k)
        w = jnp.asarray(rng.normal(size=nnz).astype(np.float32))

        def fwd_call(wv, be):
            return ops.drspmm_learnable(fwd, bwd, nnz, wv, c.values, c.idx,
                                        dim, backend=be)

        def bwd_call(wv, be):
            return jax.grad(
                lambda q, v: jnp.sum(ops.drspmm_learnable(
                    fwd, bwd, nnz, q, v, c.idx, dim, backend=be) ** 2),
                argnums=(0, 1))(wv, c.values)

        disp = {be: dispatch_count(lambda v: fwd_call(v, be), w)
                for be in ("pallas", "pallas_fused")}
        stats = {}
        for be in ("xla", "xla_fused"):
            stats[be] = dict(
                fwd_us=time_jit(lambda v: fwd_call(v, be), w, iters=iters),
                bwd_us=time_jit(lambda v: bwd_call(v, be), w, iters=iters),
            )
            tot[be] += stats[be]["fwd_us"] + stats[be]["bwd_us"]
        n_buckets = len(fwd.buckets)
        sp_f = stats["xla"]["fwd_us"] / stats["xla_fused"]["fwd_us"]
        sp_b = stats["xla"]["bwd_us"] / stats["xla_fused"]["bwd_us"]
        emit(f"learnable_fwd/{size}/{etype}/d{dim}/k{k}",
             stats["xla_fused"]["fwd_us"],
             f"speedup_vs_bucketed={sp_f:.2f}x;"
             f"dispatches={disp['pallas_fused']}"
             f"(bucketed={disp['pallas']},buckets={n_buckets})")
        emit(f"learnable_bwd/{size}/{etype}/d{dim}/k{k}",
             stats["xla_fused"]["bwd_us"],
             f"speedup_vs_bucketed={sp_b:.2f}x")
        entries.append(dict(etype=etype, size=size, dim=dim, k=k, nnz=nnz,
                            n_buckets=n_buckets,
                            dispatches_fused=disp["pallas_fused"],
                            dispatches_bucketed=disp["pallas"],
                            **{f"{be}_{m}": v for be, s in stats.items()
                               for m, v in s.items()},
                            fwd_speedup=sp_f, bwd_speedup=sp_b))
    agg = tot["xla"] / max(tot["xla_fused"], 1e-9)
    emit(f"learnable_aggregate/{size}", tot["xla_fused"],
         f"aggregate_speedup_vs_bucketed={agg:.2f}x")
    append_json(out_json, dict(
        ts=time.time(), kind="learnable_fused_vs_bucketed", size=size,
        scale=scale, backend=jax.default_backend(), aggregate_speedup=agg,
        entries=entries))
    return entries


def bench_hetero(scale=0.08, size="medium", dim=64, k=16,
                 out_json="BENCH_drspmm.json", iters=10, smoke=False):
    """Relation-fused mega-dispatch vs the serial per-direction hetero
    layer (DESIGN.md §9).

    One full HeteroConv layer, forward and forward+backward, with
    ``use_plan`` toggling between the RelationPlan super-arena path (ONE
    dispatch per populated TIER per direction-group, DESIGN.md §14) and the
    serial loop (one per edge-type direction).  Wall-clock follows the repo
    convention — the xla family on CPU (Pallas interpret-mode
    anti-correlates with TPU, see ``bench()``) — while the pallas family
    records the dispatch counts; ``smoke=True`` asserts them (fwd = number
    of populated tiers ≤ 2, grad = 2× that, vs 3 / 6 serial) plus the §14
    no-regression property (plan path at least as fast as serial), the
    acceptance guards CI runs.  The JSON row carries a per-phase forward
    breakdown — host pack (one-time, amortized), type-concat CBSR gather,
    tiered kernel dispatches, output split — so forward-path overhead
    regressions are attributable without a profiler.
    """
    from repro.core.hetero_mp import (HeteroMPConfig, hetero_conv,
                                      init_hetero_layer)
    from repro.graphs.circuit import relation_plan_of

    rng = np.random.default_rng(0)
    g = generate_design(1, size, scale=scale)[0]
    lp = init_hetero_layer(jax.random.PRNGKey(0), dim)
    x_cell = jnp.asarray(rng.normal(size=(g.n_cell, dim)).astype(np.float32))
    x_net = jnp.asarray(rng.normal(size=(g.n_net, dim)).astype(np.float32))

    def cfg_of(backend, use_plan):
        return HeteroMPConfig(hidden=dim, k_cell=k, k_net=k,
                              backend=backend, use_plan=use_plan)

    def fwd(cfg):
        return lambda xc: hetero_conv(lp, g, xc, x_net, cfg)

    def fwd_bwd(cfg):
        # sum over BOTH outputs, differentiate wrt BOTH inputs, so no
        # direction's forward or backward is dead-code-eliminated
        return lambda xc, xn: jax.grad(lambda qc, qn: sum(
            jnp.sum(y ** 2) for y in hetero_conv(lp, g, qc, qn, cfg)),
            argnums=(0, 1))(xc, xn)

    plan = relation_plan_of(g)
    disp = {}
    for name, use_plan in (("plan", True), ("serial", False)):
        c = cfg_of("pallas_fused", use_plan)
        disp[name] = dict(fwd=dispatch_count(fwd(c), x_cell),
                          grad=dispatch_count(fwd_bwd(c), x_cell, x_net))
    if smoke:
        # one dispatch per POPULATED tier per direction (§14): a mixed-tier
        # plan costs 2 fwd / 4 bwd, single-tier plans keep the original 1/2
        n_tiers = int(plan.has_arena) + int(plan.has_dense)
        assert disp["plan"] == dict(fwd=n_tiers, grad=2 * n_tiers), \
            (disp, n_tiers)
        assert disp["serial"] == dict(fwd=3, grad=6), disp

    stats = {}
    for name, use_plan in (("plan", True), ("serial", False)):
        c = cfg_of("xla_fused", use_plan)
        stats[name] = dict(
            fwd_us=time_jit(fwd(c), x_cell, iters=iters),
            grad_us=time_jit(fwd_bwd(c), x_cell, x_net, iters=iters))
    sp_f = stats["serial"]["fwd_us"] / stats["plan"]["fwd_us"]
    sp_g = stats["serial"]["grad_us"] / stats["plan"]["grad_us"]
    if smoke:
        # §14 acceptance guard: the tiered plan path never loses to serial
        assert sp_f >= 1.0 and sp_g >= 1.0, (sp_f, sp_g)

    # Per-phase forward breakdown: pack is host-side wall-clock on a fresh
    # identical graph (the memo makes the resident plan free); the other
    # phases isolate the plan forward's three jitted stages.
    cb = {"cell": cbsr_from_dense(drelu(x_cell, k), k),
          "net": cbsr_from_dense(drelu(x_net, k), k)}
    vals = tuple(cb[t].values for t in plan.src_types)
    idxs = tuple(cb[t].idx for t in plan.src_types)
    g2 = generate_design(1, size, scale=scale)[0]
    t0 = time.perf_counter()
    relation_plan_of(g2)
    pack_us = (time.perf_counter() - t0) * 1e6
    xv, xi, _ = ops._multi_concat(plan, vals, idxs)
    y_cat = ops._hybrid_fwd(plan, xv, xi, dim, "xla_fused")
    phases = dict(
        pack_us=pack_us,
        gather_us=time_jit(lambda *v: ops._multi_concat(plan, v, idxs),
                           *vals, iters=iters),
        kernel_us=time_jit(
            lambda v: ops._hybrid_fwd(plan, v, xi, dim, "xla_fused"),
            xv, iters=iters),
        split_us=time_jit(lambda y: ops._split_out(plan, y), y_cat,
                          iters=iters))
    emit(f"hetero_plan_phases/{size}/d{dim}/k{k}", phases["kernel_us"],
         ";".join(f"{p}={v:.1f}us" for p, v in phases.items()))
    agg = ((stats["serial"]["fwd_us"] + stats["serial"]["grad_us"])
           / (stats["plan"]["fwd_us"] + stats["plan"]["grad_us"]))
    emit(f"hetero_plan_fwd/{size}/d{dim}/k{k}", stats["plan"]["fwd_us"],
         f"speedup_vs_serial={sp_f:.2f}x;"
         f"dispatches={disp['plan']['fwd']}(serial={disp['serial']['fwd']})")
    emit(f"hetero_plan_grad/{size}/d{dim}/k{k}", stats["plan"]["grad_us"],
         f"speedup_vs_serial={sp_g:.2f}x;"
         f"dispatches={disp['plan']['grad']}(serial={disp['serial']['grad']})")
    emit(f"hetero_plan_aggregate/{size}",
         stats["plan"]["fwd_us"] + stats["plan"]["grad_us"],
         f"aggregate_speedup_vs_serial={agg:.2f}x")
    append_json(out_json, dict(
        ts=time.time(), kind="hetero_plan_vs_serial", size=size, scale=scale,
        dim=dim, k=k, backend=jax.default_backend(),
        n_cell=g.n_cell, n_net=g.n_net,
        tiers={s.etype: s.tier for s in plan.segments}, phases=phases,
        dispatches=disp, aggregate_speedup=agg,
        fwd_speedup=sp_f, grad_speedup=sp_g,
        **{f"{n}_{m}": v for n, s in stats.items() for m, v in s.items()}))
    return stats


def bench_sharded(scale=0.08, size="medium", dim=64, k=16,
                  out_json="BENCH_drspmm.json", iters=10, smoke=False,
                  device_counts=(2, 4)):
    """Mesh-sharded mega-dispatch (DESIGN.md §12) vs the single-device plan
    path, per shard count.

    XLA's device count locks at the first jax import, so every shard count
    runs in a child interpreter with
    ``--xla_force_host_platform_device_count=n`` (the tests/_multidev.py
    pattern); the child prints one ``SHARDED_RESULT`` JSON line this parent
    collects.  Wall-clock follows the repo convention — the xla family on
    CPU (Pallas interpret-mode is not wall-clock-representative, see
    ``bench()``); each leg additionally records the per-device arena
    footprint (owned slabs + halo tables) against full-graph replication.
    ``smoke=True`` makes the child assert numeric parity with
    ``drspmm_multi`` AND that every shard's footprint stays strictly below
    replicating the whole super-arena — the reason sharding exists.
    """
    import json
    import os
    import subprocess

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    src = os.path.join(root, "src")
    entries = []
    for n in device_counts:
        env = dict(os.environ)
        env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                                   if env.get("PYTHONPATH") else "")
        flags = [t for t in env.get("XLA_FLAGS", "").split()
                 if not t.startswith("--xla_force_host_platform_device_count")]
        env["XLA_FLAGS"] = " ".join(
            flags + [f"--xla_force_host_platform_device_count={n}"])
        r = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_drspmm",
             "--_sharded-child", str(n), str(scale), size, str(dim),
             str(k), str(iters), str(int(smoke))],
            env=env, cwd=root, capture_output=True, text=True, timeout=1200)
        assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-4000:])
        line = [ln for ln in r.stdout.splitlines()
                if ln.startswith("SHARDED_RESULT ")][-1]
        res = json.loads(line[len("SHARDED_RESULT "):])
        emit(f"sharded_fwd/{size}/n{n}/d{dim}/k{k}", res["sharded_fwd_us"],
             f"vs_single={res['single_fwd_us'] / res['sharded_fwd_us']:.2f}x;"
             f"shard_bytes={res['max_shard_bytes']}"
             f"(full={res['full_arena_bytes']})")
        emit(f"sharded_grad/{size}/n{n}/d{dim}/k{k}", res["sharded_grad_us"],
             f"vs_single={res['single_grad_us'] / res['sharded_grad_us']:.2f}x;"
             f"halo_rows={res['total_halo_rows']}(pad={res['halo_pad']})")
        entries.append(res)
    append_json(out_json, dict(
        ts=time.time(), kind="sharded", size=size, scale=scale, dim=dim,
        k=k, backend=jax.default_backend(), entries=entries))
    return entries


def _bench_sharded_child(n, scale, size, dim, k, iters, smoke):
    """Child half of :func:`bench_sharded` — runs under a forced n-device
    XLA runtime and prints one ``SHARDED_RESULT`` JSON line."""
    import json

    from repro.graphs.circuit import relation_plan_of, sharded_plan_of

    assert jax.device_count() == n, (jax.device_count(), n)
    rng = np.random.default_rng(0)
    g = generate_design(1, size, scale=scale)[0]
    plan = relation_plan_of(g)
    splan = sharded_plan_of(g, n)
    cc = cbsr_from_dense(drelu(jnp.asarray(
        rng.normal(size=(g.n_cell, dim)).astype(np.float32)), k), k)
    cn = cbsr_from_dense(drelu(jnp.asarray(
        rng.normal(size=(g.n_net, dim)).astype(np.float32)), k), k)

    def call(op, p, vc, vn):
        return op(p, {"cell": (vc, cc.idx), "net": (vn, cn.idx)}, dim,
                  backend="xla_fused")

    def grad_call(op, p):
        return lambda vc, vn: jax.grad(
            lambda qc, qn: sum(jnp.sum(jnp.sin(y)) for y in
                               call(op, p, qc, qn).values()),
            argnums=(0, 1))(vc, vn)

    stats = {}
    for name, op, p in (("sharded", ops.drspmm_multi_sharded, splan),
                        ("single", ops.drspmm_multi, plan)):
        stats[f"{name}_fwd_us"] = time_jit(
            lambda vc, vn: call(op, p, vc, vn), cc.values, cn.values,
            iters=iters)
        stats[f"{name}_grad_us"] = time_jit(
            grad_call(op, p), cc.values, cn.values, iters=iters)

    hs = splan.halo_stats()
    if smoke:
        y_sh = call(ops.drspmm_multi_sharded, splan, cc.values, cn.values)
        y_1 = call(ops.drspmm_multi, plan, cc.values, cn.values)
        for et in y_1:
            ref = np.asarray(y_1[et])
            atol = 1e-4 * max(1.0, float(np.abs(ref).max()))
            np.testing.assert_allclose(np.asarray(y_sh[et]), ref,
                                       atol=atol, rtol=1e-5,
                                       err_msg=f"sharded parity {et}")
        assert hs["max_shard_bytes"] < hs["full_arena_bytes"], hs
    print("SHARDED_RESULT " + json.dumps(dict(
        n_shards=n, n_cell=g.n_cell, n_net=g.n_net,
        max_shard_bytes=hs["max_shard_bytes"],
        full_arena_bytes=hs["full_arena_bytes"],
        total_halo_rows=hs["total_halo_rows"], halo_pad=hs["halo_pad"],
        **stats)))


if __name__ == "__main__":
    if "--_sharded-child" in sys.argv:
        a = sys.argv[sys.argv.index("--_sharded-child") + 1:]
        _bench_sharded_child(int(a[0]), float(a[1]), a[2], int(a[3]),
                             int(a[4]), int(a[5]), bool(int(a[6])))
        sys.exit(0)
    if "--smoke" in sys.argv:
        # CI-sized run: tiny graph, fused-vs-bucketed + plan-vs-serial
        # comparisons (fixed-weight, learnable, and hetero-layer legs),
        # with the dispatch-per-tier property and the §14 no-sub-1.0x
        # speedup floors asserted.
        # asserted floors run at 10 iters: the µs-scale dense-tier rows
        # and the ~1.1x plan-vs-serial margin at this scale are real but
        # inside 3-iter median jitter
        bench_fused(scale=0.02, size="small", iters=10, smoke=True)
        bench_learnable(scale=0.02, size="small", iters=3)
        bench_hetero(scale=0.02, size="small", iters=10, smoke=True)
        bench_sharded(scale=0.02, size="small", iters=3, smoke=True)
    else:
        bench_fused()
        bench_learnable()
        bench_hetero()
        bench_sharded()
        bench()
