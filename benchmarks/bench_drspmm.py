"""Fig. 11 analogue: DR-SpMM forward/backward kernel runtime vs the dense
SpMM baseline (cuSPARSE-analogue) and the row-balanced dense-operand SpMM
(GNNAdvisor-analogue), swept over K and embedding dim on the three
representative design sizes (Table 1, scaled for CPU wall-clock).

Timings use the bucketed XLA execution path (the Pallas kernels are
validated in interpret mode, which is not wall-clock-representative on CPU);
the *derived* column reports the byte-model speedup the CBSR gather traffic
predicts on TPU: dense reads N·D per aggregated row, DR reads N·k values +
indices.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_jit
from repro.core.cbsr import cbsr_from_dense
from repro.core.drelu import drelu
from repro.graphs.generator import generate_design
from repro.kernels import ops


def bench(scale=0.08):
    rng = np.random.default_rng(0)
    for size in ("small", "medium", "large"):
        g = generate_design(1, size, scale=scale)[0]
        for etype in ("near", "pin", "pinned"):
            es = g.edges[etype]
            n_src = es.adj.n_src
            for dim in (64, 128):
                x = jnp.asarray(rng.normal(size=(n_src, dim))
                                .astype(np.float32))
                t_dense = time_jit(
                    lambda xv: ops.spmm(es.adj, es.adj_t, xv), x)
                for k in (8, 16, 32):
                    if k >= dim:
                        continue
                    c = cbsr_from_dense(drelu(x, k), k)
                    t_dr = time_jit(
                        lambda v: ops.drspmm(es.adj, es.adj_t, v, c.idx,
                                             dim), c.values)
                    # backward
                    def bwd_dr(v):
                        return jax.grad(lambda q: jnp.sum(ops.drspmm(
                            es.adj, es.adj_t, q, c.idx, dim) ** 2))(v)

                    def bwd_dense(xv):
                        return jax.grad(lambda q: jnp.sum(ops.spmm(
                            es.adj, es.adj_t, q) ** 2))(xv)

                    t_dr_b = time_jit(bwd_dr, c.values)
                    t_dense_b = time_jit(bwd_dense, x)
                    byte_model = dim / (2 * k)      # val+idx per survivor
                    emit(f"drspmm_fwd/{size}/{etype}/d{dim}/k{k}", t_dr,
                         f"speedup_vs_dense={t_dense / t_dr:.2f}x;"
                         f"tpu_byte_model={byte_model:.1f}x")
                    emit(f"drspmm_bwd/{size}/{etype}/d{dim}/k{k}", t_dr_b,
                         f"speedup_vs_dense={t_dense_b / t_dr_b:.2f}x")


if __name__ == "__main__":
    bench()
