"""Fig. 10 analogue: correlation scores + step-time across (k_net, k_cell)
on Mini-CircuitNet (synthetic).  Short training runs; rank correlations are
the metric that matters (Sec. 4.3)."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.graphs.generator import generate_design
from repro.train.circuit_trainer import CircuitTrainConfig, CircuitTrainer


def bench(scale=0.05, epochs=4):
    train = generate_design(0, "small", scale=scale)
    test = generate_design(99, "small", scale=scale)
    base = None
    for k in (2, 4, 8, 16, 32):
        cfg = CircuitTrainConfig(epochs=epochs, hidden=64,
                                 k_cell=k, k_net=k)
        tr = CircuitTrainer(cfg, 16, 16)
        t0 = time.perf_counter()
        out = tr.fit(train, eval_graphs=test)
        dt = (time.perf_counter() - t0) * 1e6 / epochs / len(train)
        if base is None:
            base = dt
        m = out["final"]
        emit(f"kvalue_sweep/k{k}", dt,
             f"pearson={m['pearson']:.3f};spearman={m['spearman']:.3f};"
             f"kendall={m['kendall']:.3f};mae={m['mae']:.3f};"
             f"rmse={m['rmse']:.3f}")


if __name__ == "__main__":
    bench()
