"""LM substrate micro-benchmarks: per-family train-step and decode-step
wall time on reduced configs (CPU proxy; full configs are covered by the
dry-run roofline)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_jit
from repro.configs.base import get_config, reduced
from repro.models.lm import serve
from repro.models.lm.model import build_lm
from repro.train import lm_step

ARCHS = ("qwen3-0.6b", "mamba2-1.3b", "granite-moe-1b-a400m", "zamba2-1.2b")


def bench():
    for arch in ARCHS:
        cfg = reduced(get_config(arch))
        lm = build_lm(cfg)
        params = lm.init(jax.random.PRNGKey(0))
        b, s = 4, 64
        batch = {"tokens": jnp.zeros((b, s), jnp.int32),
                 "targets": jnp.ones((b, s), jnp.int32)}
        state = lm_step.init_train_state(lm, jax.random.PRNGKey(1))
        step = jax.jit(lm_step.make_train_step(lm, total_steps=100))
        t = time_jit(step, state, batch, iters=5)
        tokens_per_s = b * s / (t / 1e6)
        emit(f"lm_train/{arch}", t, f"tokens_per_s={tokens_per_s:.0f}")

        cache, _ = serve.prefill(lm, params, batch["tokens"], None)
        dec = jax.jit(lambda p, c, tok, pos:
                      serve.decode_step(lm, p, c, tok, pos))
        t = time_jit(dec, params, cache, jnp.zeros((b, 1), jnp.int32),
                     jnp.asarray(s - 1, jnp.int32), iters=5)
        emit(f"lm_decode/{arch}", t, f"tokens_per_s={b / (t / 1e6):.0f}")


if __name__ == "__main__":
    bench()
