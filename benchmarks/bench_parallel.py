"""Fig. 12 / Table 3 analogue: end-to-end breakdown of the two optimizations
— DR-SpMM kernel savings vs parallel (fused) subgraph scheduling savings —
against the sequential dense baseline (the DGL/cuSPARSE-analogue)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.hetero_mp import HeteroMPConfig
from repro.graphs.generator import generate_design
from repro.models.hgnn import init_drcircuitgnn, loss_fn


def _step_time(graph, cfg, sequential: bool, iters=5):
    params = init_drcircuitgnn(jax.random.PRNGKey(0), 16, 16, cfg.hidden)
    # graph closed over (not traced): the adjacency is static per design —
    # the paper's per-graph preprocessing contract — letting XLA specialize
    # the gather/scatter patterns.
    grad_fn = jax.jit(lambda p: jax.grad(loss_fn)(p, graph, cfg))
    if sequential:
        # module-by-module with host sync between edge types (DGL-analogue):
        # emulate by splitting the loss into per-edge partial passes.
        from repro.core.hetero_mp import _aggregate
        aggs = {et: jax.jit(lambda et=et, k=cfg.k_cell:
                            _aggregate(graph, et,
                                       graph.x_cell @ params.in_cell
                                       if et != "pinned"
                                       else graph.x_net @ params.in_net,
                                       k, cfg))
                for et in ("near", "pin", "pinned")}

        def run():
            for et, f in aggs.items():
                jax.block_until_ready(f())         # sequential module sync
            jax.block_until_ready(grad_fn(params))
    else:
        def run():
            jax.block_until_ready(grad_fn(params))

    run()                                          # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        run()
    return (time.perf_counter() - t0) / iters * 1e6


def bench(scale=0.08):
    graphs = generate_design(2, "medium", scale=scale)[:2]
    for gi, g in enumerate(graphs):
        # per-bucket ("xla") kept as the pre-fused reference point
        base_cfg = HeteroMPConfig(hidden=64, use_drelu=False, backend="xla")
        dr_cfg = HeteroMPConfig(hidden=64, k_cell=16, k_net=16,
                                use_drelu=True, backend="xla")
        fused_cfg = HeteroMPConfig(hidden=64, k_cell=16, k_net=16,
                                   use_drelu=True)   # default fused backend
        t_base = _step_time(g, base_cfg, sequential=True)
        t_kernel = _step_time(g, dr_cfg, sequential=True)
        t_par = _step_time(g, base_cfg, sequential=False)
        t_both = _step_time(g, dr_cfg, sequential=False)
        t_fused = _step_time(g, fused_cfg, sequential=False)
        emit(f"e2e_baseline/graph{gi}", t_base, "sequential+dense")
        emit(f"e2e_dr_kernel/graph{gi}", t_kernel,
             f"dr_savings={100 * (1 - t_kernel / t_base):.1f}%")
        emit(f"e2e_parallel/graph{gi}", t_par,
             f"parallel_savings={100 * (1 - t_par / t_base):.1f}%")
        emit(f"e2e_both/graph{gi}", t_both,
             f"total_speedup={t_base / t_both:.2f}x")
        emit(f"e2e_fused_exec/graph{gi}", t_fused,
             f"total_speedup={t_base / t_fused:.2f}x;"
             f"vs_bucketed_dr={t_both / t_fused:.2f}x;"
             f"backend={fused_cfg.backend}")


if __name__ == "__main__":
    bench()
