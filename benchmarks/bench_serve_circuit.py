"""Sequential vs batched vs online multi-device circuit serving (the
ISSUE-2/ISSUE-3 acceptance benchmark).

The stream is the adversarial serving case: many small designs whose sizes
jitter within two size classes, interleaved.  Three modes:

* **sequential** — the natural per-graph path: one jitted forward taking
  each graph as a traced argument, so every distinct graph shape compiles
  and every graph is its own dispatch (the HOGA-motivated pathology);
* **batched** — :class:`CircuitServeEngine.run`: block-diagonal collation
  into quantized shape buckets, one fused dispatch per micro-batch, host
  packing of batch i+1 overlapped with device execution of batch i (pinned
  to one device so the row stays comparable across PRs);
* **online** — ``serve_forever()`` fed from a producer thread: continuous
  intake, deadline batching, and round-robin dispatch over every available
  device (2+ under ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).

Reported per mode: aggregate graphs/s over the cold stream (compiles
included — that IS serving cost for a mixed stream), steady-state graphs/s
over a warm second pass, p50/p95 request latency, and compile count; the
online row adds per-device dispatch counts and deadline flushes.  Appended
to ``BENCH_serve.json`` so the serving-perf trajectory is recorded across
PRs.  (Interpret-mode caveat: on CPU the timed backends are the XLA-path
ones — see DESIGN.md §4/§7 — so these numbers track real wall-clock.)
"""

from __future__ import annotations

import sys
import threading
import time

import jax
import numpy as np

from benchmarks.common import append_json, emit
from repro.core.hetero_mp import HeteroMPConfig
from repro.fault.inject import FaultInjector, FaultRule
from repro.graphs.generator import generate_partition, pack_graph_parallel
from repro.models.hgnn import drcircuitgnn_forward, init_drcircuitgnn
from repro.serve import CircuitServeEngine
from repro.train.metrics import percentile


def make_stream(rng, n_per_class: int, classes=((220, 110), (430, 215)),
                jitter: float = 0.06):
    """Interleaved mixed-size stream: sizes jitter within each class."""
    per_class = []
    for ci, (nc, nn) in enumerate(classes):
        gs = []
        for s in range(n_per_class):
            c = int(nc * (1 + rng.uniform(-jitter, jitter)))
            n = int(nn * (1 + rng.uniform(-jitter, jitter)))
            coo, xc, xn, y = generate_partition(
                np.random.default_rng(1000 * ci + s), c, n)
            gs.append(pack_graph_parallel(coo, c, n, xc, xn, y))
        per_class.append(gs)
    return [g for tup in zip(*per_class) for g in tup]


def bench_sequential(params, cfg, stream):
    fwd = jax.jit(lambda p, g: drcircuitgnn_forward(p, g, cfg))
    lat = []
    t0 = time.perf_counter()
    for g in stream:
        t1 = time.perf_counter()
        jax.block_until_ready(fwd(params, g))
        lat.append((time.perf_counter() - t1) * 1e3)
    cold_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    for g in stream:                       # warm pass: shapes already built
        jax.block_until_ready(fwd(params, g))
    warm_wall = time.perf_counter() - t0
    lat.sort()
    p50, p95 = percentile(lat, 0.5), percentile(lat, 0.95)
    compiles = fwd._cache_size() if hasattr(fwd, "_cache_size") else -1
    return dict(graphs_per_s=len(stream) / cold_wall,
                warm_graphs_per_s=len(stream) / warm_wall,
                p50_ms=p50, p95_ms=p95, compiles=compiles)


def bench_online(params, cfg, stream, max_batch: int,
                 max_wait_ms: float = 25.0):
    """serve_forever() fed by this (producer) thread; every local device."""
    eng = CircuitServeEngine(params, cfg, max_batch=max_batch,
                             max_wait_ms=max_wait_ms)
    server = threading.Thread(target=eng.serve_forever)
    server.start()
    for g in stream:
        eng.submit(g)
    eng.stop()
    server.join()
    cold = eng.stats()
    server = threading.Thread(target=eng.serve_forever)
    server.start()
    for g in stream:                       # warm pass: buckets already built
        eng.submit(g)
    eng.stop()
    server.join()
    warm = eng.stats()
    warm_gps = ((warm["requests"] - cold["requests"])
                / max(warm["wall_s"] - cold["wall_s"], 1e-9))
    # cold-pass numbers throughout so the row is internally consistent
    # (sum(dispatches_per_device) == batches)
    return dict(graphs_per_s=cold["requests"] / max(cold["wall_s"], 1e-9),
                warm_graphs_per_s=warm_gps,
                p50_ms=cold["p50_ms"], p95_ms=cold["p95_ms"],
                compiles=cold["compiles"], batches=cold["batches"],
                devices=cold["devices"],
                dispatches_per_device=cold["dispatches_per_device"],
                deadline_flushes=cold["deadline_flushes"])


def bench_degraded(params, cfg, stream, max_batch: int,
                   max_wait_ms: float = 25.0):
    """Online serving with 1-of-N ring slots force-quarantined (the state
    after a device loss, or an ops drain for maintenance): the stream must
    complete on the survivors with zero failures, and the row records the
    throughput cost of losing a slot.  With a single local device the ring
    gets two logical slots on it, so routing-around-quarantine is still
    exercised.  The probe interval is pushed out so no re-admission
    muddies the measurement."""
    devs = list(jax.local_devices())
    if len(devs) < 2:
        devs = [devs[0], devs[0]]
    eng = CircuitServeEngine(params, cfg, max_batch=max_batch,
                             max_wait_ms=max_wait_ms, devices=devs,
                             probe_interval_s=1e9)
    lost = len(devs) - 1
    eng.ring.quarantine(lost)
    server = threading.Thread(target=eng.serve_forever)
    server.start()
    for g in stream:
        eng.submit(g)
    eng.stop()
    server.join()
    st = eng.stats()
    assert st["failures"] == 0, st
    assert st["dispatches_per_device"][lost] == 0, st
    return dict(graphs_per_s=st["requests"] / max(st["wall_s"], 1e-9),
                p50_ms=st["p50_ms"], p95_ms=st["p95_ms"],
                devices=st["devices"], quarantined_slot=lost,
                dispatches_per_device=st["dispatches_per_device"],
                device_health=st["device_health"],
                failures=st["failures"])


def bench_sustained(params, cfg, stream, max_batch: int, *,
                    target_qps: float = 80.0, n_producers: int = 2,
                    max_wait_ms: float = 8.0, chaos=None):
    """Sustained-load serving: ``n_producers`` threads submit at an
    aggregate ``target_qps``, paced so inter-arrival gaps exceed
    ``max_wait_ms`` per bucket — the **deadline-flush** regime (partial
    batches shipped when their oldest request's deadline expires), which
    the burst benchmarks above never enter.  Latency percentiles come from
    the engine's metrics registry (``serve.latency_ms`` histogram), and the
    row records flush/shed counts; the chaos variant overlays a seeded
    fault schedule to price the healing ladder under load."""
    eng = CircuitServeEngine(params, cfg, max_batch=max_batch,
                             max_wait_ms=max_wait_ms, chaos=chaos,
                             max_queue=max(4 * max_batch, 16),
                             admission="shed_oldest")
    # warm pass through the SAME engine (run(), unpaced) so the paced phase
    # measures steady-state serving, not bucket compilation; paced-phase
    # numbers below are deltas over this snapshot
    for g in stream:
        eng.submit(g)
    eng.run()
    cold = eng.stats()
    hist = eng.metrics.histogram("serve.latency_ms")
    n_warm = len(hist.window())

    server = threading.Thread(target=eng.serve_forever)
    server.start()
    gap_s = n_producers / max(target_qps, 1e-9)

    def produce(shard):
        for g in shard:
            t_next = time.perf_counter() + gap_s
            eng.submit(g)
            dt = t_next - time.perf_counter()
            if dt > 0:
                time.sleep(dt)

    t0 = time.perf_counter()
    producers = [threading.Thread(target=produce,
                                  args=(stream[i::n_producers],))
                 for i in range(n_producers)]
    for p in producers:
        p.start()
    for p in producers:
        p.join()
    eng.stop()
    server.join()
    wall = time.perf_counter() - t0
    st = eng.stats()
    lat = sorted(hist.window()[n_warm:])   # paced-phase latencies only
    return dict(target_qps=target_qps,
                achieved_qps=(st["requests"] - cold["requests"]) / wall,
                n_producers=n_producers, max_wait_ms=max_wait_ms,
                p50_ms=percentile(lat, 0.50), p95_ms=percentile(lat, 0.95),
                p99_ms=percentile(lat, 0.99),
                deadline_flushes=(st["deadline_flushes"]
                                  - cold["deadline_flushes"]),
                shed=st["admission_shed"] - cold["admission_shed"],
                failures=st["failures"] - cold["failures"],
                retries=st["retries"] - cold["retries"],
                requests=st["requests"] - cold["requests"],
                batches=st["batches"] - cold["batches"],
                chaos=chaos is not None)


def bench_batched(params, cfg, stream, max_batch: int):
    # pinned to one device so the row stays comparable across PRs (the
    # multi-device path gets its own `online` row)
    eng = CircuitServeEngine(params, cfg, max_batch=max_batch,
                             devices=jax.local_devices()[:1])
    for g in stream:
        eng.submit(g)
    eng.run()
    cold = eng.stats()
    for g in stream:                       # warm pass: buckets already built
        eng.submit(g)
    eng.run()
    warm = eng.stats()
    warm_gps = ((warm["requests"] - cold["requests"])
                / max(warm["wall_s"] - cold["wall_s"], 1e-9))
    return dict(graphs_per_s=cold["requests"] / cold["wall_s"],
                warm_graphs_per_s=warm_gps,
                p50_ms=cold["p50_ms"], p95_ms=cold["p95_ms"],
                compiles=cold["compiles"], batches=cold["batches"],
                cell_padding_ratio=cold["cell_padding_ratio"])


def bench(n_per_class: int = 8, max_batch: int = 4, hidden: int = 64,
          classes=((220, 110), (430, 215)),
          out_json: str = "BENCH_serve.json"):
    rng = np.random.default_rng(0)
    stream = make_stream(rng, n_per_class, classes=classes)
    f_cell = stream[0].x_cell.shape[1]
    f_net = stream[0].x_net.shape[1]
    cfg = HeteroMPConfig(hidden=hidden, k_cell=16, k_net=16)
    params = init_drcircuitgnn(jax.random.PRNGKey(0), f_cell, f_net, hidden)

    seq = bench_sequential(params, cfg, stream)
    bat = bench_batched(params, cfg, stream, max_batch)
    onl = bench_online(params, cfg, stream, max_batch)
    deg = bench_degraded(params, cfg, stream, max_batch)
    sus = bench_sustained(params, cfg, stream, max_batch)
    sus_chaos = bench_sustained(
        params, cfg, stream, max_batch,
        chaos=FaultInjector([FaultRule("dispatch", rate=0.05),
                             FaultRule("straggler", rate=0.05,
                                       delay_s=0.01)], seed=7))

    speedup = bat["graphs_per_s"] / max(seq["graphs_per_s"], 1e-9)
    warm_speedup = (bat["warm_graphs_per_s"]
                    / max(seq["warm_graphs_per_s"], 1e-9))
    online_warm_speedup = (onl["warm_graphs_per_s"]
                           / max(seq["warm_graphs_per_s"], 1e-9))
    emit("serve/sequential", 1e6 / max(seq["graphs_per_s"], 1e-9),
         f"graphs_per_s={seq['graphs_per_s']:.2f};"
         f"compiles={seq['compiles']}")
    emit("serve/batched", 1e6 / max(bat["graphs_per_s"], 1e-9),
         f"graphs_per_s={bat['graphs_per_s']:.2f};"
         f"compiles={bat['compiles']};speedup={speedup:.2f}x;"
         f"warm_speedup={warm_speedup:.2f}x")
    emit("serve/online", 1e6 / max(onl["graphs_per_s"], 1e-9),
         f"graphs_per_s={onl['graphs_per_s']:.2f};"
         f"devices={onl['devices']};compiles={onl['compiles']};"
         f"warm_speedup={online_warm_speedup:.2f}x")
    emit("serve/degraded", 1e6 / max(deg["graphs_per_s"], 1e-9),
         f"graphs_per_s={deg['graphs_per_s']:.2f};"
         f"devices={deg['devices']};"
         f"quarantined_slot={deg['quarantined_slot']}")
    emit("serve/sustained", 1e3 * sus["p99_ms"],
         f"qps={sus['achieved_qps']:.1f}/{sus['target_qps']:.0f};"
         f"p50={sus['p50_ms']:.1f}ms;p99={sus['p99_ms']:.1f}ms;"
         f"deadline_flushes={sus['deadline_flushes']};shed={sus['shed']}")
    emit("serve/sustained_chaos", 1e3 * sus_chaos["p99_ms"],
         f"qps={sus_chaos['achieved_qps']:.1f};"
         f"p99={sus_chaos['p99_ms']:.1f}ms;"
         f"retries={sus_chaos['retries']};"
         f"failures={sus_chaos['failures']}")
    record = dict(ts=time.time(), kind="serve_circuit",
                  backend=jax.default_backend(),
                  n_graphs=len(stream), max_batch=max_batch, hidden=hidden,
                  classes=list(map(list, classes)),
                  sequential=seq, batched=bat, online=onl, degraded=deg,
                  sustained=sus, sustained_chaos=sus_chaos,
                  speedup=speedup, warm_speedup=warm_speedup,
                  online_warm_speedup=online_warm_speedup)
    append_json(out_json, record)
    return record


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        # CI-sized run: tiny classes, small stream
        r = bench(n_per_class=4, max_batch=2, hidden=32,
                  classes=((80, 40), (150, 75)))
        # paced producers must actually enter the deadline-flush regime —
        # the gap ISSUE-6 closed ("deadline_flushes: 0" on burst streams)
        assert r["sustained"]["deadline_flushes"] > 0, r["sustained"]
    else:
        r = bench()
    print(f"[serve] batched vs sequential: {r['speedup']:.2f}x cold, "
          f"{r['warm_speedup']:.2f}x warm "
          f"({r['batched']['compiles']} vs {r['sequential']['compiles']} "
          f"compiles)")
    o = r["online"]
    print(f"[serve] online x{o['devices']} devices: "
          f"{o['graphs_per_s']:.2f} graphs/s cold, "
          f"{r['online_warm_speedup']:.2f}x sequential warm, "
          f"dispatches/device={o['dispatches_per_device']}, "
          f"{o['deadline_flushes']} deadline flushes")
    d = r["degraded"]
    print(f"[serve] degraded (slot {d['quarantined_slot']} of "
          f"{d['devices']} quarantined): {d['graphs_per_s']:.2f} graphs/s, "
          f"dispatches/device={d['dispatches_per_device']}, "
          f"{d['failures']} failures")
    s = r["sustained"]
    print(f"[serve] sustained @{s['target_qps']:.0f} qps "
          f"(achieved {s['achieved_qps']:.1f}): "
          f"p50={s['p50_ms']:.1f}ms p95={s['p95_ms']:.1f}ms "
          f"p99={s['p99_ms']:.1f}ms, "
          f"{s['deadline_flushes']} deadline flushes, {s['shed']} shed")
    sc = r["sustained_chaos"]
    print(f"[serve] sustained+chaos: p99={sc['p99_ms']:.1f}ms, "
          f"{sc['retries']} retries, {sc['failures']} failures")
