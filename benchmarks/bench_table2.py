"""Table 2 analogue: DR-CircuitGNN vs homogeneous GCN/SAGE/GAT on
Mini-CircuitNet (synthetic) — congestion-prediction correlation scores."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.graphs.generator import generate_design
from repro.models.hgnn import homo_forward, homogenize, init_homo
from repro.optim import adamw_init, adamw_update
from repro.train import metrics as M
from repro.train.circuit_trainer import CircuitTrainConfig, CircuitTrainer


def train_homo(kind, graphs, test_graphs, epochs=6, hidden=64, lr=1e-3):
    homo = [homogenize(g) for g in graphs]
    homo_t = [homogenize(g) for g in test_graphs]
    f_in = homo[0][2].shape[1]
    params = init_homo(jax.random.PRNGKey(0), f_in, hidden, kind=kind)
    opt = adamw_init(params)

    def loss_fn(p, adj, adj_t, x, y, n_cell):
        pred = homo_forward(p, adj, adj_t, x, n_cell, kind=kind)
        return jnp.mean((pred - y) ** 2)

    step = jax.jit(jax.value_and_grad(loss_fn), static_argnums=(5,))
    for _ in range(epochs):
        for adj, adj_t, x, y, n_cell in homo:
            l, g = step(params, adj, adj_t, x, y, n_cell)
            params, opt = adamw_update(params, g, opt, jnp.asarray(lr),
                                       weight_decay=2e-4)
    preds, labels = [], []
    for adj, adj_t, x, y, n_cell in homo_t:
        preds.append(np.asarray(homo_forward(params, adj, adj_t, x, n_cell,
                                             kind=kind)))
        labels.append(np.asarray(y))
    return M.all_metrics(np.concatenate(preds), np.concatenate(labels))


def bench(scale=0.05, epochs=6):
    train = generate_design(0, "small", scale=scale)
    test = generate_design(99, "small", scale=scale)
    for kind in ("gcn", "sage", "gat"):
        m = train_homo(kind, train, test, epochs=epochs)
        emit(f"table2/{kind}", 0.0,
             f"pearson={m['pearson']:.3f};spearman={m['spearman']:.3f};"
             f"kendall={m['kendall']:.3f};mae={m['mae']:.3f};"
             f"rmse={m['rmse']:.3f}")
    tr = CircuitTrainer(CircuitTrainConfig(epochs=epochs, hidden=64,
                                           k_cell=16, k_net=16), 16, 16)
    out = tr.fit(train, eval_graphs=test)
    m = out["final"]
    emit("table2/dr-circuitgnn", 0.0,
         f"pearson={m['pearson']:.3f};spearman={m['spearman']:.3f};"
         f"kendall={m['kendall']:.3f};mae={m['mae']:.3f};"
         f"rmse={m['rmse']:.3f}")


if __name__ == "__main__":
    bench()
