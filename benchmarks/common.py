"""Shared benchmark utilities."""

import json
import os
import time

import jax

from repro.train.metrics import percentile


def time_jit(fn, *args, iters: int = 10, warmup: int = 2) -> float:
    """Median wall-time (µs) of a jitted callable (nearest-rank p50 via the
    shared train/metrics helper — no local percentile math)."""
    jfn = jax.jit(fn) if not hasattr(fn, "lower") else fn
    for _ in range(warmup):
        jax.block_until_ready(jfn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(jfn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    return percentile(sorted(times), 0.5)


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")


def append_json(path: str, record: dict):
    """Append one run record to a JSON-list file (perf trajectory log)."""
    runs = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                runs = json.load(f)
        except (json.JSONDecodeError, OSError):
            runs = []
    runs.append(record)
    with open(path, "w") as f:
        json.dump(runs, f, indent=1)
    print(f"[bench] appended record #{len(runs)} to {path}")
