"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Output: ``name,us_per_call,derived`` CSV rows.
  * bench_drspmm   — Fig. 11 (DR-SpMM fwd/bwd vs dense baseline, K × dim)
  * bench_parallel — Fig. 12 / Table 3 (kernel vs parallel-scheduling savings)
  * bench_kvalues  — Fig. 10 (K sweep: correlations + step time)
  * bench_table2   — Table 2 (DR-CircuitGNN vs GCN/SAGE/GAT correlations)
  * bench_lm       — LM substrate step timings (reduced configs)
"""

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller graphs / fewer epochs")
    ap.add_argument("--only", default="",
                    help="comma-separated benchmark names")
    args = ap.parse_args()
    scale = 0.04 if args.fast else 0.08
    epochs = 2 if args.fast else 6

    from benchmarks import (bench_drspmm, bench_kvalues, bench_lm,
                            bench_parallel, bench_table2)
    suites = {
        "drspmm": lambda: bench_drspmm.bench(scale=scale),
        "parallel": lambda: bench_parallel.bench(scale=scale),
        "kvalues": lambda: bench_kvalues.bench(scale=max(scale * 0.6, 0.03),
                                               epochs=max(epochs // 2, 2)),
        "table2": lambda: bench_table2.bench(scale=max(scale * 0.6, 0.03),
                                             epochs=epochs),
        "lm": bench_lm.bench,
    }
    only = set(args.only.split(",")) if args.only else set(suites)
    failed = []
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if name not in only:
            continue
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
