"""Quickstart: D-ReLU + DR-SpMM on a toy heterogeneous circuit graph.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cbsr import cbsr_from_dense
from repro.core.drelu import drelu, profile_optimal_k
from repro.graphs.generator import generate_design
from repro.kernels import ops, ref

# 1. a synthetic CircuitNet-like partition (cell/net nodes; near/pin/pinned)
graph = generate_design(seed=0, size="small", scale=0.05)[0]
print(f"graph: {graph.n_cell} cells, {graph.n_net} nets, "
      f"edge types: {list(graph.edges)}")

# 2. D-ReLU: balanced row sparsification of the cell embeddings
rng = np.random.default_rng(0)
x_cell = jnp.asarray(rng.normal(size=(graph.n_cell, 64)).astype(np.float32))
k = 16
x_sparse = drelu(x_cell, k)
print(f"D-ReLU(k={k}): nnz per row =",
      np.unique(np.asarray((x_sparse != 0).sum(1))))

# 3. CBSR encoding (values + indices — the kernel operand)
c = cbsr_from_dense(x_sparse, k)
print("CBSR:", c.values.shape, c.idx.shape)

# 4. DR-SpMM over the 'near' adjacency (Pallas kernel, interpret on CPU)
es = graph.edges["near"]
y = ops.drspmm(es.adj, es.adj_t, c.values, c.idx, 64, backend="pallas")
y_ref = ref.drspmm_fwd_ref(es.adj, c.values, c.idx, 64)
print("DR-SpMM max|err| vs dense oracle:",
      float(jnp.abs(y - y_ref).max()))

# 5. gradient flows through the sampled backward (SSpMM)
g = jax.grad(lambda v: jnp.sum(ops.drspmm(es.adj, es.adj_t, v, c.idx,
                                          64) ** 2))(c.values)
print("SSpMM grad shape:", g.shape, "finite:", bool(jnp.isfinite(g).all()))

# 6. the profiler picks K per edge type (Sec. 4.3)
from repro.graphs.circuit import graph_degree_stats  # noqa: E402
deg = np.asarray((es.adj.to_dense() != 0).sum(1))
print("profiled optimal K for 'near':", profile_optimal_k(deg, 64))
