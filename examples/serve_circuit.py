"""Quickstart: serve HGNN congestion predictions for a stream of designs.

Generates a mixed stream of small/medium synthetic designs, submits every
partition to the :class:`CircuitServeEngine`, and drains the queue —
block-diagonal micro-batches, one fused dispatch per batch, host packing of
the next batch overlapped with device execution of the current one.

    PYTHONPATH=src python examples/serve_circuit.py \
        [--n-designs 4] [--scale 0.02] [--batch 4] [--hidden 64] [--online]

``--smoke`` runs a CI-sized stream and asserts the compile-once contract:
the mixed-size queue completes with at most one compile per shape bucket
per device (≤ 2 for the two-size-class smoke stream on one device) and
every prediction matches the graph served alone.

``--online`` switches from the one-shot ``run()`` drain to the long-lived
``serve_forever()`` loop: the engine serves on a background thread while
this (producer) thread submits the stream — continuous intake, partial
buckets closing at the ``--max-wait-ms`` deadline, and micro-batches
routed round-robin over every visible device.  Run it with
``XLA_FLAGS=--xla_force_host_platform_device_count=2`` to see two CPU
devices sharing the stream; ``--smoke --online`` additionally asserts the
per-device dispatch counts and the (bucket, device) compile bound.
"""

import argparse
import threading

import numpy as np
import jax

from repro.core.hetero_mp import HeteroMPConfig
from repro.graphs.generator import (generate_design, generate_partition,
                                    pack_graph_parallel)
from repro.models.hgnn import drcircuitgnn_forward, init_drcircuitgnn
from repro.serve import CircuitServeEngine, TraceRecorder


def _smoke_stream(n_per_class=6, classes=((90, 45), (170, 85)),
                  jitter=0.08):
    """Two tightly-jittered size classes, interleaved — lands in exactly two
    engine shape buckets, so the compile-once contract is assertable."""
    rng = np.random.default_rng(0)
    per = []
    for ci, (nc, nn) in enumerate(classes):
        gs = []
        for s in range(n_per_class):
            c = int(nc * (1 + rng.uniform(-jitter, jitter)))
            n = int(nn * (1 + rng.uniform(-jitter, jitter)))
            coo, xc, xn, y = generate_partition(
                np.random.default_rng(1000 * ci + s), c, n)
            gs.append(pack_graph_parallel(coo, c, n, xc, xn, y))
        per.append(gs)
    return [g for tup in zip(*per) for g in tup]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-designs", type=int, default=4)
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run + compile-once/parity assertions")
    ap.add_argument("--online", action="store_true",
                    help="serve_forever() on a background thread "
                         "(continuous intake, deadline batching, "
                         "round-robin over all devices)")
    ap.add_argument("--max-wait-ms", type=float, default=30.0,
                    help="online mode: partial-bucket flush deadline")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="record a request trace and dump Chrome "
                         "trace-event JSON here (open in Perfetto); "
                         "validate with tools/check_trace.py")
    args = ap.parse_args()

    if args.smoke:
        args.hidden = 32
        print("generating two-class smoke stream...")
        stream = _smoke_stream()
    else:
        print("generating mixed small/medium design stream...")
        stream = []
        for seed in range(args.n_designs):
            stream += generate_design(seed, "small", scale=args.scale)
            stream += generate_design(100 + seed, "medium", scale=args.scale)
    f_cell, f_net = stream[0].x_cell.shape[1], stream[0].x_net.shape[1]

    cfg = HeteroMPConfig(hidden=args.hidden, k_cell=args.k, k_net=args.k)
    params = init_drcircuitgnn(jax.random.PRNGKey(0), f_cell, f_net,
                               args.hidden)
    recorder = TraceRecorder() if args.trace else None
    eng = CircuitServeEngine(params, cfg, max_batch=args.batch,
                             max_wait_ms=args.max_wait_ms,
                             recorder=recorder)

    if args.online:
        server = threading.Thread(target=eng.serve_forever)
        server.start()
        rids = [eng.submit(g) for g in stream]     # submit-during-run
        for rid in rids:
            eng.result(rid, timeout=600.0)
        eng.stop()
        server.join()
        out = eng.finished
    else:
        rids = [eng.submit(g) for g in stream]
        out = eng.run()
    st = eng.stats()
    print(f"\nserved {st['requests']} graphs in {st['batches']} batches "
          f"({st['compiles']} compiles, backend={cfg.backend})")
    print(f"throughput {st['graphs_per_s']:.1f} graphs/s | latency "
          f"p50 {st['p50_ms']:.0f} ms, p95 {st['p95_ms']:.0f} ms | "
          f"cell padding x{st['cell_padding_ratio']:.2f}")
    print(f"devices {st['devices']} | dispatches/device "
          f"{st['dispatches_per_device']} | deadline flushes "
          f"{st['deadline_flushes']}")
    r0 = out[rids[0]]
    print(f"request {r0.rid}: {r0.pred.shape[0]} cells, congestion "
          f"mean {r0.pred.mean():.3f} max {r0.pred.max():.3f}")
    if args.trace:
        eng.dump_trace(args.trace)
        print(f"trace: {len(eng.recorder)} events -> {args.trace} "
              f"(load in https://ui.perfetto.dev)")

    if args.smoke:
        n_dev = st["devices"]
        n_buckets = len({eng._group_key(g) for g in stream})
        assert len(out) == len(stream), "requests lost"
        assert n_buckets == 2, f"smoke stream spans {n_buckets} buckets"
        assert eng.compiles <= n_buckets * n_dev, \
            (f"{eng.compiles} compiles for {n_buckets} shape buckets "
             f"on {n_dev} devices")
        if "jit_cache_size" in st:
            assert st["jit_cache_size"] == eng.compiles
        for rid, g in zip(rids, stream):
            ref = np.asarray(drcircuitgnn_forward(params, g, cfg))
            np.testing.assert_allclose(out[rid].pred, ref, atol=1e-5,
                                       rtol=1e-5)
        if args.online:
            counts = st["dispatches_per_device"]
            assert sum(counts) == st["batches"], (counts, st["batches"])
            if n_dev > 1 and st["batches"] >= 2 * n_dev:
                # round-robin routing: every device served its share
                assert all(c > 0 for c in counts), counts
            print(f"[smoke] online x{n_dev} devices: per-device dispatch "
                  f"counts {counts} OK")
        print("[smoke] compile-once + per-request parity OK")


if __name__ == "__main__":
    main()
