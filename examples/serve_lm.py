"""Serve a small LM with batched requests: prefill + token-by-token decode.

    PYTHONPATH=src python examples/serve_lm.py [--arch qwen3-0.6b] \
        [--batch 4] [--prompt-len 32] [--new-tokens 16]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_config, reduced
from repro.models.lm import serve
from repro.models.lm.model import build_lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    lm = build_lm(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b, s = args.batch, args.prompt_len
    total = s + args.new_tokens

    # prompts padded into a cache covering the full generation horizon
    prompts = rng.integers(0, cfg.vocab, (b, total)).astype(np.int32)
    prompts[:, s:] = 0
    tokens = jnp.asarray(prompts)

    extra = None
    if cfg.family == "vlm":
        extra = {"image_emb": jnp.zeros((b, cfg.n_img_tokens, cfg.d_model),
                                        lm.dtype)}
    if cfg.family == "audio":
        extra = {"frames": jnp.zeros((b, cfg.enc_frames, cfg.d_model),
                                     lm.dtype)}

    print(f"[serve] {cfg.name} prefill {b}×{total} ...")
    t0 = time.perf_counter()
    cache, logits = serve.prefill(lm, params, tokens, extra)
    jax.block_until_ready(logits)
    print(f"  prefill {time.perf_counter() - t0:.2f}s")

    decode = jax.jit(lambda p, c, t, q: serve.decode_step(lm, p, c, t, q))
    out_tokens = []
    tok = jnp.argmax(logits[:, -1:, : cfg.vocab], -1).astype(jnp.int32)
    t0 = time.perf_counter()
    for i in range(args.new_tokens):
        pos = jnp.asarray(s + i, jnp.int32)
        cache, logits = decode(params, cache, tok, pos)
        tok = jnp.argmax(logits[:, :, : cfg.vocab], -1).astype(jnp.int32)
        out_tokens.append(np.asarray(tok)[:, 0])
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    gen = np.stack(out_tokens, 1)
    print(f"  decoded {args.new_tokens} tokens × {b} reqs in {dt:.2f}s "
          f"({b * args.new_tokens / dt:.1f} tok/s)")
    print("  sample generations:", gen[:2].tolist())


if __name__ == "__main__":
    main()
