"""End-to-end driver: train DR-CircuitGNN for congestion prediction on
synthetic Mini-CircuitNet (the paper's Table 2 protocol, CPU scale).

    PYTHONPATH=src python examples/train_circuitgnn.py \
        [--epochs 10] [--scale 0.08] [--dense] [--k 16] \
        [--n-layers 15 --remat --wiring residual]

Deep backbones (DESIGN.md §13): ``--n-layers`` sets the stack depth (the
config's single source of truth), ``--wiring residual|dense`` adds skip
reuse from the second layer on, ``--remat`` checkpoints each layer so peak
training memory stops scaling with depth (stats prints the
``peak_memory_bytes`` / ``recompute_ms`` gauges).
"""

import argparse
import time

from repro.graphs.generator import generate_design
from repro.train.circuit_trainer import CircuitTrainConfig, CircuitTrainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--scale", type=float, default=0.06)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--dense", action="store_true",
                    help="disable D-ReLU (dense baseline)")
    ap.add_argument("--n-train", type=int, default=4)
    ap.add_argument("--n-layers", type=int, default=2,
                    help="backbone depth (CircuitTrainConfig.n_layers)")
    ap.add_argument("--wiring", choices=("plain", "residual", "dense"),
                    default="plain", help="inter-layer reuse pattern")
    ap.add_argument("--remat", action="store_true",
                    help="checkpoint each layer (constant-ish activation "
                         "memory in depth; backward recomputes forwards)")
    args = ap.parse_args()

    print("generating Mini-CircuitNet (synthetic)...")
    train = []
    for seed in range(args.n_train):
        train += generate_design(seed, "small", scale=args.scale)
    test = generate_design(999, "small", scale=args.scale)
    f_cell = train[0].x_cell.shape[1]
    f_net = train[0].x_net.shape[1]

    cfg = CircuitTrainConfig(epochs=args.epochs, hidden=args.hidden,
                             k_cell=args.k, k_net=args.k,
                             use_drelu=not args.dense,
                             n_layers=args.n_layers, wiring=args.wiring,
                             remat=args.remat)
    tr = CircuitTrainer(cfg, f_cell, f_net)
    t0 = time.perf_counter()
    out = tr.fit(train, eval_graphs=test)
    dt = time.perf_counter() - t0
    m = out["final"]
    mode = "dense" if args.dense else f"D-ReLU k={args.k}"
    depth = f"L={args.n_layers} {args.wiring}" \
            + (" remat" if args.remat else "")
    st = tr.stats()
    print(f"\n[{mode} {depth}] {dt:.1f}s  "
          f"Pearson={m['pearson']:.3f} Spearman={m['spearman']:.3f} "
          f"Kendall={m['kendall']:.3f} MAE={m['mae']:.3f} "
          f"RMSE={m['rmse']:.3f}  "
          f"peak={st['peak_memory_bytes'] / 1e6:.1f}MB "
          f"recompute={st['recompute_ms']:.1f}ms")


if __name__ == "__main__":
    main()
