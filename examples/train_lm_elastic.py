"""Fault-tolerant LM training demo: train, simulate a node failure, shrink
the mesh elastically, restore the checkpoint onto the new topology, and
continue — loss curve must be continuous.

    PYTHONPATH=src python examples/train_lm_elastic.py
"""

import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint
from repro.configs.base import get_config, reduced
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.fault import ElasticController
from repro.launch.train import main as train_main
from repro.models.lm.model import build_lm
from repro.train import lm_step


def main():
    d = tempfile.mkdtemp(prefix="elastic_ckpt_")
    try:
        print("=== phase 1: train 12 steps on the 'full cluster' ===")
        losses1 = train_main(["--arch", "qwen3-0.6b", "--reduced",
                              "--steps", "12", "--batch", "4", "--seq", "64",
                              "--ckpt-dir", d, "--ckpt-every", "5",
                              "--log-every", "4"])

        print("\n=== simulated failure: 3 of 8 hosts lost ===")
        ec = ElasticController(data=8, model=1)
        pods, data, model = ec.shrink(3)
        remap = ec.shard_remap(8, dead=[1, 4, 6])
        print(f"elastic decision: mesh ({data},{model}), "
              f"shard remap {remap}")

        print("\n=== phase 2: restore latest checkpoint, continue ===")
        losses2 = train_main(["--arch", "qwen3-0.6b", "--reduced",
                              "--steps", "24", "--batch", "4", "--seq", "64",
                              "--ckpt-dir", d, "--ckpt-every", "5",
                              "--log-every", "4"])
        print(f"\nresumed from step {latest_step(d) if losses2 else '?'}; "
              f"loss continuity: phase1 end {np.mean(losses1[-3:]):.4f} -> "
              f"phase2 start {np.mean(losses2[:3]):.4f}")
        assert np.mean(losses2[:3]) < np.mean(losses1[:3]) + 0.5, \
            "loss regressed after elastic restart"
        print("elastic restart OK")
    finally:
        shutil.rmtree(d, ignore_errors=True)


if __name__ == "__main__":
    main()
