"""Async, atomic, elastic checkpointing.

Design (1000+-node posture):
* **atomic**: writes go to ``step_<N>.tmp/`` and are renamed only after the
  manifest + every leaf is fsync'd — a crashed writer never corrupts the
  latest valid checkpoint;
* **async**: the device→host transfer happens at save() call time (cheap),
  serialization runs on a background thread so the train loop keeps stepping
  (checkpoint stalls are the #1 straggler source at scale);
* **elastic restore**: leaves are stored mesh-agnostic (full logical
  arrays).  ``restore_checkpoint(..., shardings=...)`` re-device_puts onto
  ANY mesh — a shrunk or grown cluster resumes from the same file set.  At
  real multi-host scale each host would write its owned shards; the manifest
  format already carries per-leaf shape/dtype so that extension is local.
* **self-describing**: manifest.json carries the pytree structure; restore
  needs no model code.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(ckpt_dir: str, step: int, state, *,
                    blocking: bool = True) -> threading.Thread:
    """Serialize ``state`` (any pytree of arrays) under ``ckpt_dir``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    # device -> host NOW (so the train loop can mutate state afterwards)
    flat = {k: np.asarray(v) for k, v in _flatten(state).items()}
    treedef = jax.tree_util.tree_structure(state)

    def write():
        manifest = {"step": step, "time": time.time(),
                    "treedef": str(treedef),
                    "leaves": {k: {"shape": list(v.shape),
                                   "dtype": str(v.dtype)}
                               for k, v in flat.items()}}
        for k, v in flat.items():
            fn = os.path.join(tmp, k.replace("/", "__") + ".npy")
            # ml_dtypes (bf16 etc.) can't round-trip through np.save;
            # store raw bytes and rebuild from the manifest dtype.
            np.save(fn, v.reshape(-1).view(np.uint8))
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                    # atomic publish

    t = threading.Thread(target=write, daemon=True)
    t.start()
    if blocking:
        t.join()
    return t


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like, *,
                       shardings=None):
    """Restore into the structure of ``like`` (pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: same-structure pytree of
    NamedShardings for elastic re-mesh placement."""
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like = _flatten(like)
    flat_shard = _flatten(shardings) if shardings is not None else None
    loaded = {}
    for k, leaf in flat_like.items():
        fn = os.path.join(d, k.replace("/", "__") + ".npy")
        raw = np.load(fn)
        meta = manifest["leaves"][k]
        import ml_dtypes  # noqa: F401  (registers bf16 etc. with numpy)
        dt = np.dtype(meta["dtype"])
        arr = raw.view(dt).reshape(meta["shape"])
        assert tuple(arr.shape) == tuple(leaf.shape), (k, arr.shape, leaf.shape)
        if flat_shard is not None:
            loaded[k] = jax.device_put(arr, flat_shard[k])
        else:
            loaded[k] = jax.numpy.asarray(arr)
    # rebuild tree in `like`'s structure
    leaves_order = []
    for path, _ in jax.tree_util.tree_flatten_with_path(like)[0]:
        key = "/".join(_path_str(p) for p in path)
        leaves_order.append(loaded[key])
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves_order)


class CheckpointManager:
    """Keeps the last ``keep`` checkpoints; saves async every ``every``."""

    def __init__(self, ckpt_dir: str, every: int = 100, keep: int = 3):
        self.dir = ckpt_dir
        self.every = every
        self.keep = keep
        self._pending: Optional[threading.Thread] = None

    def maybe_save(self, step: int, state) -> bool:
        if step % self.every != 0:
            return False
        if self._pending is not None:
            self._pending.join()                 # one in flight max
        self._pending = save_checkpoint(self.dir, step, state,
                                        blocking=False)
        self._gc()
        return True

    def finalize(self):
        if self._pending is not None:
            self._pending.join()
            self._gc()

    def _gc(self):
        if not os.path.isdir(self.dir):
            return
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)
