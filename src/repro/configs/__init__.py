"""Architecture configs.  ``get_config(name)`` resolves any assigned arch."""

from repro.configs.base import ArchConfig, ShapeSpec, SHAPES, get_config, list_archs  # noqa: F401
