"""Architecture + shape configuration system.

Every assigned architecture is a frozen :class:`ArchConfig`; the four
input-shape cells are :data:`SHAPES`.  ``--arch <id>`` in the launchers
resolves through :func:`get_config`.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | ssm | moe | vlm | audio | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 => d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 1e6
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- SSM ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    # --- hybrid (zamba2): one shared attention block every N ssm blocks ---
    attn_every: int = 0
    # --- vlm: cross-attention layer every N layers ---
    cross_every: int = 0
    n_img_tokens: int = 1600
    # --- audio (whisper): encoder-decoder ---
    enc_layers: int = 0
    enc_frames: int = 1500
    # --- paper technique: D-ReLU top-k on FFN hidden (0 = off) ---
    drelu_k: int = 0
    # --- training ---
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"     # full | dots (dots_saveable)
    grad_accum: int = 1            # microbatches per step (memory lever)
    lr_schedule: str = "cosine"    # minicpm uses "wsd"
    tie_embeddings: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (SSM/hybrid: O(1)-state decode)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS = 6·N·D)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd, nh, nkv = self.hd, self.n_heads, self.n_kv
        emb = v * d * (1 if self.tie_embeddings else 2)
        attn = d * (nh * hd) + 2 * d * (nkv * hd) + (nh * hd) * d

        if self.family == "ssm":
            return emb + L * self._ssm_params()
        if self.family == "moe":
            ffn = 3 * d * f * self.n_experts + d * self.n_experts  # router
        else:
            ffn = 3 * d * f
        per = attn + ffn + 2 * d                      # + norms

        if self.family == "hybrid":
            n_attn_app = L // max(self.attn_every, 1)
            per_ssm = self._ssm_params()
            shared = attn + 3 * d * f + 2 * d
            return emb + L * per_ssm + shared + n_attn_app * 0
        if self.family == "vlm":
            n_cross = L // max(self.cross_every, 1)
            n_self = L - n_cross
            cross = attn + 3 * d * f + 2 * d
            return emb + n_self * per + n_cross * cross
        if self.family == "audio":
            enc = self.enc_layers * per
            return emb + enc + L * per
        return emb + L * per

    def _ssm_params(self) -> int:
        d = self.d_model
        di = self.ssm_expand * d
        n = self.ssm_state
        nh = di // self.ssm_head_dim
        # in_proj -> (x, z, B, C, dt) ; out_proj
        return d * (2 * di + 2 * n + nh) + di * d + nh + di

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.family != "moe":
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.n_layers
        hd, nh, nkv = self.hd, self.n_heads, self.n_kv
        emb = self.vocab * d * 2
        attn = d * (nh * hd) + 2 * d * (nkv * hd) + (nh * hd) * d
        ffn_active = 3 * d * f * self.top_k + d * self.n_experts
        return emb + L * (attn + ffn_active + 2 * d)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = (
    "qwen3-1.7b", "minitron-4b", "minicpm-2b", "qwen3-0.6b", "mamba2-1.3b",
    "llama-3.2-vision-90b", "moonshot-v1-16b-a3b", "granite-moe-1b-a400m",
    "whisper-large-v3", "zamba2-1.2b",
)


def list_archs() -> Tuple[str, ...]:
    return ARCH_IDS


def get_config(name: str, **overrides) -> ArchConfig:
    mod = importlib.import_module(
        "repro.configs." + name.replace("-", "_").replace(".", "_"))
    cfg: ArchConfig = mod.CONFIG
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests (per-arch smoke contract)."""
    return dataclasses.replace(
        cfg,
        n_layers=min(cfg.n_layers, 2),
        d_model=128,
        n_heads=4,
        n_kv=min(max(cfg.n_kv * 4 // max(cfg.n_heads, 1), 1), 4),
        d_ff=256 if cfg.family != "moe" else 64,
        head_dim=32,
        vocab=512,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=16,
        attn_every=min(cfg.attn_every, 2) if cfg.attn_every else 0,
        cross_every=min(cfg.cross_every, 2) if cfg.cross_every else 0,
        n_img_tokens=8 if cfg.family == "vlm" else cfg.n_img_tokens,
        enc_layers=min(cfg.enc_layers, 2) if cfg.enc_layers else 0,
        enc_frames=16 if cfg.family == "audio" else cfg.enc_frames,
        drelu_k=min(cfg.drelu_k, 32) if cfg.drelu_k else 0,
        dtype="float32",
    )
