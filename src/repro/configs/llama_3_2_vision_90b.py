"""llama-3.2-vision-90b [vlm] — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision].  Vision frontend is a stub:
input_specs() provides precomputed patch embeddings (spec contract)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv=8, d_ff=28672,
    vocab=128256, head_dim=128,
    cross_every=5, n_img_tokens=1600,
    drelu_k=7168,
    # 90B × 1M tokens/step: 4 microbatches keep per-device activation
    # residency inside v5e HBM (EXPERIMENTS.md §Dry-run memory notes)
    grad_accum=4,
)
