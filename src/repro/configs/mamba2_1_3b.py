"""mamba2-1.3b [ssm] — SSD (state-space duality) [arXiv:2405.21060].

Attention-free: the paper's DR-SpMM is inapplicable to the SSD core
(DESIGN.md §Arch-applicability); D-ReLU remains available on projections.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv=0, d_ff=0,
    vocab=50280, ssm_state=128, ssm_head_dim=64, ssm_expand=2,
)
