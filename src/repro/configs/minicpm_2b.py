"""minicpm-2b [dense] — WSD schedule, llama-like [arXiv:2404.06395; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv=36, d_ff=5760,
    vocab=122753, head_dim=64,
    lr_schedule="wsd", tie_embeddings=True,
    drelu_k=1440,
)
