"""qwen3-1.7b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv=8, d_ff=6144,
    vocab=151936, head_dim=128, qk_norm=True,
    drelu_k=1536,  # paper technique: D-ReLU top-k on FFN hidden (d_ff/4)
)
