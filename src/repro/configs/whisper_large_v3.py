"""whisper-large-v3 [audio] — enc-dec, conv frontend stubbed
[arXiv:2212.04356].  input_specs() provides precomputed mel-frame embeddings
(B, enc_frames, d_model) per the spec contract."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv=20, d_ff=5120,
    vocab=51866, head_dim=64,
    enc_layers=32, enc_frames=1500,
    drelu_k=1280,
)
