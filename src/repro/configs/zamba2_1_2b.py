"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block
[arXiv:2411.15242].  DR-SpMM inapplicable to the SSM core (DESIGN.md
§Arch-applicability); D-ReLU applies in the shared block's FFN."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv=32, d_ff=8192,
    vocab=32000, head_dim=64,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    attn_every=6,
    drelu_k=2048,
)
