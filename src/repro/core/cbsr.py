"""CBSR — Compressed Balanced Sparse Row format.

The paper's D-ReLU produces *balanced* row sparsity: every row of a node
embedding matrix keeps exactly ``k`` non-zeros.  On GPU the paper stores the
survivors as per-row (values, indices) pairs; on TPU the balanced property is
the entire win — it means the compressed representation is a pair of *dense,
statically-shaped* arrays:

    values : (N, k) float   — surviving magnitudes, ordered by column index
    idx    : (N, k) int32   — column positions of the survivors

Static shapes make CBSR directly tileable into VMEM by a Pallas BlockSpec and
let the scatter back to dense be expressed as a one-hot matmul on the MXU.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CBSR:
    """A row-balanced sparse matrix: exactly ``k`` nnz per row.

    ``dim`` is the dense column count (static); ``values``/``idx`` are
    ``(N, k)``.  Rows are allowed to contain duplicate index ``0`` entries with
    zero value as padding (produced when a row has fewer than ``k`` finite
    survivors); all consumers accumulate, so zero-valued padding is inert.
    """

    values: jax.Array
    idx: jax.Array
    dim: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_rows(self) -> int:
        return self.values.shape[0]

    @property
    def k(self) -> int:
        return self.values.shape[1]

    def to_dense(self) -> jax.Array:
        """Scatter back to a dense (N, dim) matrix."""
        n, _ = self.values.shape
        rows = jnp.arange(n, dtype=jnp.int32)[:, None]
        out = jnp.zeros((n, self.dim), self.values.dtype)
        # ``add`` (not ``set``): tolerates zero-value padding duplicates.
        return out.at[rows, self.idx].add(self.values)


def cbsr_from_dense(x: jax.Array, k: int) -> CBSR:
    """Compress a dense matrix by keeping the top-``k`` entries of each row.

    Survivor columns are re-sorted ascending so gathers walk memory forward —
    the TPU analogue of the paper's CBSR index ordering.
    """
    n, d = x.shape
    k = min(k, d)
    vals, idx = jax.lax.top_k(x, k)  # descending by value
    order = jnp.argsort(idx, axis=1)
    idx = jnp.take_along_axis(idx, order, axis=1).astype(jnp.int32)
    vals = jnp.take_along_axis(vals, order, axis=1)
    return CBSR(values=vals, idx=idx, dim=d)


def cbsr_mask(c: CBSR) -> jax.Array:
    """Dense 0/1 mask of surviving positions (used by the max-merge backward)."""
    n = c.n_rows
    rows = jnp.arange(n, dtype=jnp.int32)[:, None]
    m = jnp.zeros((n, c.dim), jnp.bool_)
    return m.at[rows, c.idx].set(True)


def sample_dense(dense: jax.Array, idx: jax.Array) -> jax.Array:
    """Gather ``dense`` at CBSR positions: out[i, j] = dense[i, idx[i, j]].

    This is the SSpMM sampling step of the backward pass (Alg. 2): gradients
    are only needed at positions D-ReLU let through.
    """
    return jnp.take_along_axis(dense, idx, axis=1)


def scatter_cbsr(values: jax.Array, idx: jax.Array, dim: int) -> jax.Array:
    """Dense (N, dim) from loose (values, idx) pairs."""
    return CBSR(values=values, idx=idx, dim=dim).to_dense()
