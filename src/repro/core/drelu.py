"""Dynamic ReLU (D-ReLU) — row-wise top-k thresholding activation.

Implements Eqs. (2)-(3) of the paper:

    th_i = min(top_k(X_i, k))
    f(X_id) = X_id  if X_id >= th_i  else 0

plus the CBSR encoding of the survivors.  Unlike plain ReLU (irregular
sparsity) or FATReLU (fixed threshold, irregular sparsity), D-ReLU yields
*exactly* k survivors per row, which is what makes the downstream SpMM
workload balanced.

The VJP is straight-through on survivors: dX = dY at kept positions, 0
elsewhere — identical to the subgradient of the piecewise-linear f.  The
threshold's dependence on X is ignored exactly like the kink of ReLU.

Heterogeneous usage: each node type phi_s gets its own k (k_cell, k_net), and
the per-edge-type K-value profile (Sec. 4.3) is handled by
:func:`profile_optimal_k`.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.cbsr import CBSR, cbsr_from_dense


import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _drelu_dense(x: jax.Array, k: int) -> jax.Array:
    """Dense-in dense-out D-ReLU over the last axis (Eq. 3 semantics)."""
    th = _row_threshold(x, k)
    return jnp.where(x >= th[..., None], x, jnp.zeros_like(x))


def _row_threshold(x: jax.Array, k: int) -> jax.Array:
    vals, _ = jax.lax.top_k(x, min(k, x.shape[-1]))
    return vals[..., -1]


def _drelu_fwd(x, k):
    th = _row_threshold(x, k)
    keep = x >= th[..., None]
    return jnp.where(keep, x, jnp.zeros_like(x)), keep


def _drelu_bwd(k, res, g):
    keep = res
    return (jnp.where(keep, g, jnp.zeros_like(g)),)


_drelu_dense.defvjp(_drelu_fwd, _drelu_bwd)


def drelu(x: jax.Array, k: int) -> jax.Array:
    """Dense D-ReLU: keep the top-``k`` entries of each row, zero the rest."""
    if k >= x.shape[-1]:
        return x
    return _drelu_dense(x, k)


def drelu_grouped(x: jax.Array, k: int, groups: int) -> jax.Array:
    """Sharding-local D-ReLU: split the row into ``groups`` contiguous
    blocks and keep the top-(k/groups) of each block.

    Still exactly k survivors per row (the paper's balanced-sparsity
    invariant) but the threshold is per-block, so when the feature dim is
    tensor-sharded the top-k never crosses shard boundaries — a global-top-k
    on a model-sharded FFN hidden would all-gather the full activation
    (measured: 12.9 GB × 2/layer on qwen3-0.6b train_4k).  TPU adaptation
    recorded in DESIGN.md §2; ablation in tests/test_drelu.py.
    """
    f = x.shape[-1]
    if k >= f:
        return x
    if groups <= 1 or f % groups or k % groups:
        return _drelu_dense(x, k)
    lead = x.shape[:-1]
    xg = x.reshape(lead + (groups, f // groups))
    from repro.sharding.specs import constrain
    xg = constrain(xg, (("batch",) + (None,) * (len(lead) - 1)
                        + ("mlp", None)))
    out = _drelu_dense(xg, k // groups)
    return out.reshape(lead + (f,))


def drelu_cbsr(x: jax.Array, k: int) -> CBSR:
    """D-ReLU returning the CBSR encoding (values + indices) directly.

    This is the form consumed by DR-SpMM; indices are preserved for the
    backward pass (Alg. 1 stage 4 / Alg. 2 stage 1).
    """
    return cbsr_from_dense(x, k)


def drelu_cbsr_vjp(x: jax.Array, k: int) -> Tuple[CBSR, jax.Array]:
    """CBSR output plus the dense keep-mask (for building custom VJPs)."""
    c = cbsr_from_dense(x, k)
    th = _row_threshold(x, min(k, x.shape[-1]))
    keep = x >= th[:, None]
    return c, keep


# ---------------------------------------------------------------------------
# K-value profiling (Sec. 4.3): candidate K's are powers of two below the
# embedding dim; the optimal K per subgraph trades kernel speed against
# information kept.  On CPU we cannot wall-clock a TPU kernel, so the profiler
# scores candidates with the kernel's roofline byte model: bytes moved scale
# with k, and tail lag scales with the max-degree bucket's padded width.
# ---------------------------------------------------------------------------

def candidate_ks(dim: int) -> Tuple[int, ...]:
    ks = []
    k = 2
    while k <= dim:
        ks.append(k)
        k *= 2
    return tuple(ks)


def kernel_cost_model(n_rows: int, nnz: int, k: int, dim: int,
                      max_degree: int, mean_degree: float) -> float:
    """Roofline byte-model of one DR-SpMM call (lower is better).

    bytes ≈ gather traffic (nnz rows of (k values + k idx)) + output write
    + a tail-lag penalty proportional to the evil-row imbalance, which the
    degree-bucketed dispatch reduces by the paper's partition factor
    (larger k ⇒ fewer rows co-resident per block ⇒ worse tail absorption).
    """
    gather = float(nnz) * k * (4 + 4)
    out = float(n_rows) * dim * 4
    imbalance = max(max_degree / max(mean_degree, 1.0) - 1.0, 0.0)
    tail = imbalance * k * n_rows * 4.0 / 32.0
    return gather + out + tail


def profile_optimal_k(degrees, dim: int, quality_floor: int = 2) -> int:
    """Pick the cost-minimal candidate K for one subgraph (one edge type).

    ``degrees`` is the integer degree array of destination rows.  Mirrors the
    paper's preprocessing profiler: exhaustive over powers of two, one-time
    cost per dataset.
    """
    import numpy as np

    deg = np.asarray(degrees)
    nnz = int(deg.sum())
    n = int(deg.size)
    maxd = int(deg.max()) if n else 1
    meand = float(deg.mean()) if n else 1.0
    best_k, best_c = quality_floor, float("inf")
    for k in candidate_ks(dim):
        c = kernel_cost_model(n, nnz, k, dim, maxd, meand)
        if c < best_c:
            best_c, best_k = c, k
    return max(best_k, quality_floor)


def hetero_k_values(graph_stats: Dict[str, Dict], dim_by_ntype: Dict[str, int]
                    ) -> Dict[str, int]:
    """Per-edge-type K values from per-subgraph degree stats.

    ``graph_stats[etype] = {"degrees": np.ndarray, "src_type": str}``.
    """
    out = {}
    for etype, st in graph_stats.items():
        dim = dim_by_ntype[st["src_type"]]
        out[etype] = profile_optimal_k(st["degrees"], dim)
    return out
