"""Heterogeneous message passing with D-ReLU + DR-SpMM (the paper's core).

One HeteroConv layer (paper Fig. 1 / Fig. 5) = three edge-type modules:

    near   : SageConv   cell -> cell
    pinned : SageConv   net  -> cell
    pin    : GraphConv  cell -> net

with the cell-side merge Y_cell = max(near_out, pinned_out) (Eq. 8) and
Y_net = pin_out (Eq. 9).  Eqs. 12–14 (the mask-routed backward through the
max merge) fall out of autodiff over ``jnp.maximum``; the SSpMM backward of
each DR-SpMM is the custom VJP in kernels/ops.py.

The three modules are computationally independent until the merge — the
parallel scheduler (core/parallel.py) exploits exactly that.  With the
default ``pallas_fused`` backend (TPU) each edge type's entire bucketed
aggregation is ONE kernel dispatch, so a layer's message passing is exactly
three forward launches (DESIGN.md §1).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.cbsr import cbsr_from_dense
from repro.core.drelu import drelu
from repro.graphs.circuit import CircuitGraph
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class HeteroMPConfig:
    hidden: int = 64
    k_cell: int = 16          # D-ReLU K for cell-sourced embeddings
    k_net: int = 16           # D-ReLU K for net-sourced embeddings
    # "pallas_fused" on TPU (one kernel dispatch per edge-type direction,
    # DESIGN.md §1), "xla_fused" on CPU — the same fused arena in plain XLA.
    backend: ops.Backend = ops.DEFAULT_BACKEND
    use_drelu: bool = True    # False => dense baseline path (plain SpMM)
    drelu_backend: str = "topk"   # topk (lax.top_k) | pallas (binary search)


class HeteroLayerParams(NamedTuple):
    """Per-edge-type weights (Eq. 4's W^ψ) + SAGE self paths."""
    w_near: jax.Array          # (H, H) neighbor transform, near
    w_near_self: jax.Array     # (H, H)
    w_pinned: jax.Array        # (H, H)
    w_pinned_self: jax.Array   # unused by merge (self path shared) — kept for SAGE form
    w_pin: jax.Array           # (H, H) GraphConv weight
    b_cell: jax.Array          # (H,)
    b_net: jax.Array           # (H,)


def init_hetero_layer(key, hidden: int) -> HeteroLayerParams:
    ks = jax.random.split(key, 5)
    s = 1.0 / jnp.sqrt(hidden)
    mk = lambda k: jax.random.uniform(k, (hidden, hidden), jnp.float32, -s, s)
    return HeteroLayerParams(
        w_near=mk(ks[0]), w_near_self=mk(ks[1]), w_pinned=mk(ks[2]),
        w_pinned_self=mk(ks[3]), w_pin=mk(ks[4]),
        b_cell=jnp.zeros((hidden,)), b_net=jnp.zeros((hidden,)))


def _aggregate(graph: CircuitGraph, etype: str, x_src: jax.Array,
               k: int, cfg: HeteroMPConfig) -> jax.Array:
    """A^ψ · D-ReLU(x_src) for one edge type, via DR-SpMM (or dense SpMM)."""
    es = graph.edges[etype]
    if cfg.use_drelu and k < x_src.shape[-1]:
        # D-ReLU -> CBSR -> DR-SpMM.  Gradient routing: the CBSR values carry
        # the autodiff path (top-k gather is differentiable wrt x), and the
        # SSpMM backward samples at the preserved indices (Alg. 2).
        if cfg.drelu_backend == "pallas":
            # the paper's row-wise binary search as a Pallas kernel
            from repro.kernels.drelu_topk import drelu_pallas
            xs = drelu_pallas(jax.lax.stop_gradient(x_src), k)
            xs = xs + (x_src - jax.lax.stop_gradient(x_src)) * (xs != 0)
        else:
            xs = drelu(x_src, k)                   # dense w/ straight-through
        c = cbsr_from_dense(xs, k)
        return ops.drspmm(es.adj, es.adj_t, c.values, c.idx,
                          x_src.shape[-1], backend=cfg.backend)
    return ops.spmm(es.adj, es.adj_t, x_src, backend=cfg.backend)


def hetero_conv(params: HeteroLayerParams, graph: CircuitGraph,
                x_cell: jax.Array, x_net: jax.Array,
                cfg: HeteroMPConfig) -> Tuple[jax.Array, jax.Array]:
    """One HeteroConv layer.  Returns (y_cell, y_net)."""
    # --- three independent edge-type message passings (parallelizable) ---
    agg_near = _aggregate(graph, "near", x_cell, cfg.k_cell, cfg)      # cell->cell
    agg_pinned = _aggregate(graph, "pinned", x_net, cfg.k_net, cfg)    # net->cell
    agg_pin = _aggregate(graph, "pin", x_cell, cfg.k_cell, cfg)        # cell->net

    # --- per-edge W^ψ (Eq. 4) ---
    near_out = agg_near @ params.w_near + x_cell @ params.w_near_self
    pinned_out = agg_pinned @ params.w_pinned + x_cell @ params.w_pinned_self
    pin_out = agg_pin @ params.w_pin

    # --- merge (Eqs. 8-9); Eqs. 12-14 are the autodiff of the max ---
    y_cell = jnp.maximum(near_out, pinned_out) + params.b_cell
    y_net = pin_out + params.b_net
    return y_cell, y_net
