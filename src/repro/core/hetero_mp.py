"""Heterogeneous message passing with D-ReLU + DR-SpMM (the paper's core).

One HeteroConv layer (paper Fig. 1 / Fig. 5) = three edge-type modules:

    near   : SageConv   cell -> cell
    pinned : SageConv   net  -> cell
    pin    : GraphConv  cell -> net

with the cell-side merge Y_cell = max(near_out, pinned_out) (Eq. 8) and
Y_net = pin_out (Eq. 9).  Eqs. 12–14 (the mask-routed backward through the
max merge) fall out of autodiff over ``jnp.maximum``; the SSpMM backward of
each DR-SpMM is the custom VJP in kernels/ops.py.

Two execution strategies share the math:

* **plan path** (default on the fused backends): ALL edge-type directions
  of the layer run as ONE dispatch per direction-group over a
  :class:`~repro.graphs.ell.RelationPlan` super-arena
  (``ops.drspmm_multi`` — one forward ``pallas_call``, one transposed
  backward, DESIGN.md §9).  The plan comes from the graph itself
  (``graph.plan``, attached by the collator / ``with_plan``) or is built
  lazily and memoized when the graph is concrete.  Per-type D-ReLU/CBSR is
  computed once and shared by every relation consuming that type.
* **serial path** (the reference, and the fallback for per-bucket/dense
  backends, dense aggregation, or traced graphs without a plan): the
  per-relation loop of PR 1–4, one ``drspmm``/``spmm`` per edge type —
  but the per-type D-ReLU/CBSR is shared across relations here too
  (``near`` and ``pin`` both consume the cell slab; 2 sparsifications per
  layer, not 3 — tests/test_backbone.py pins the dispatch count).
  ``HeteroMPConfig(use_plan=False)`` pins it for parity tests.

Stack callers (models/backbone.py) additionally hoist the layer-invariant
plan resolution once per stack application and pass it via
``hetero_conv(..., plan=...)``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.cbsr import cbsr_from_dense
from repro.core.drelu import drelu
from repro.graphs.circuit import (CircuitGraph, relation_plan_of,
                                  sharded_plan_of)
from repro.graphs.ell import FusedELL, RelationPlan
from repro.kernels import ops
from repro.sharding.plan_shard import ShardedRelationPlan


@dataclasses.dataclass(frozen=True)
class HeteroMPConfig:
    hidden: int = 64
    k_cell: int = 16          # D-ReLU K for cell-sourced embeddings
    k_net: int = 16           # D-ReLU K for net-sourced embeddings
    # "pallas_fused" on TPU (one kernel dispatch per edge-type direction,
    # DESIGN.md §1), "xla_fused" on CPU — the same fused arena in plain XLA.
    backend: ops.Backend = ops.DEFAULT_BACKEND
    use_drelu: bool = True    # False => dense baseline path (plain SpMM)
    drelu_backend: str = "topk"   # topk (lax.top_k) | pallas (binary search)
    # Relation-fused layer dispatch (DESIGN.md §9): on the fused backends a
    # layer's whole message passing runs as ONE dispatch per direction-group
    # via the graph's RelationPlan.  False pins the serial per-direction
    # reference loop (exact parity: tests/test_relation_plan.py).
    use_plan: bool = True
    # Giant-graph mesh sharding (DESIGN.md §12): > 1 partitions the plan
    # over that many mesh devices and routes the layer through
    # ``ops.drspmm_multi_sharded`` (needs that many visible devices).  A
    # graph arriving with a ShardedRelationPlan already attached uses it
    # regardless of this knob.
    n_shards: int = 0
    # Dense-tier nnz crossover override (DESIGN.md §14): None takes the
    # measured ``DENSE_TIER_NNZ`` constant; <= -1 pins every relation to
    # the arena tier.  Applies only to plans this module builds itself —
    # attached (collated/sharded) plans were tiered at pack time.
    dense_threshold: Optional[int] = None


class HeteroLayerParams(NamedTuple):
    """Per-edge-type weights (Eq. 4's W^ψ) + SAGE self paths."""
    w_near: jax.Array          # (H, H) neighbor transform, near
    w_near_self: jax.Array     # (H, H)
    w_pinned: jax.Array        # (H, H)
    w_pinned_self: jax.Array   # unused by merge (self path shared) — kept for SAGE form
    w_pin: jax.Array           # (H, H) GraphConv weight
    b_cell: jax.Array          # (H,)
    b_net: jax.Array           # (H,)


def init_hetero_layer(key, hidden: int) -> HeteroLayerParams:
    ks = jax.random.split(key, 5)
    s = 1.0 / jnp.sqrt(hidden)
    mk = lambda k: jax.random.uniform(k, (hidden, hidden), jnp.float32, -s, s)
    return HeteroLayerParams(
        w_near=mk(ks[0]), w_near_self=mk(ks[1]), w_pinned=mk(ks[2]),
        w_pinned_self=mk(ks[3]), w_pin=mk(ks[4]),
        b_cell=jnp.zeros((hidden,)), b_net=jnp.zeros((hidden,)))


def _sparsify(x_src: jax.Array, k: int, cfg: HeteroMPConfig):
    """D-ReLU -> CBSR.  Gradient routing: the CBSR values carry the
    autodiff path (top-k gather is differentiable wrt x), and the SSpMM
    backward samples at the preserved indices (Alg. 2)."""
    if cfg.drelu_backend == "pallas":
        # the paper's row-wise binary search as a Pallas kernel
        from repro.kernels.drelu_topk import drelu_pallas
        xs = drelu_pallas(jax.lax.stop_gradient(x_src), k)
        xs = xs + (x_src - jax.lax.stop_gradient(x_src)) * (xs != 0)
    else:
        xs = drelu(x_src, k)                   # dense w/ straight-through
    return cbsr_from_dense(xs, k)


def _aggregate(graph: CircuitGraph, etype: str, x_src: jax.Array,
               c, cfg: HeteroMPConfig) -> jax.Array:
    """A^ψ · D-ReLU(x_src) for one edge type, via DR-SpMM (or dense SpMM) —
    the serial per-direction reference.  ``c`` is the source type's
    pre-computed CBSR (None pins the dense SpMM path): the caller
    sparsifies each node type ONCE per layer and shares it across every
    relation consuming that type, exactly like the plan path — ``near``
    and ``pin`` both read the cell slab, so re-deriving its D-ReLU/CBSR
    per relation was pure recompute (and an extra top_k dispatch)."""
    es = graph.edges[etype]
    if c is not None:
        return ops.drspmm(es.adj, es.adj_t, c.values, c.idx,
                          x_src.shape[-1], backend=cfg.backend)
    return ops.spmm(es.adj, es.adj_t, x_src, backend=cfg.backend)


def _sparsify_types(x_cell: jax.Array, x_net: jax.Array,
                    cfg: HeteroMPConfig):
    """Per-type CBSR, computed once per layer and shared by every relation
    consuming the type (None where the type stays dense — k >= width or
    D-ReLU off).  The single sparsification site for BOTH execution
    strategies, so they cannot drift."""
    c_cell = _sparsify(x_cell, cfg.k_cell, cfg) \
        if cfg.use_drelu and cfg.k_cell < x_cell.shape[-1] else None
    c_net = _sparsify(x_net, cfg.k_net, cfg) \
        if cfg.use_drelu and cfg.k_net < x_net.shape[-1] else None
    return c_cell, c_net


def plan_applicable(cfg: HeteroMPConfig, hidden: int) -> bool:
    """True iff the plan path can serve this config: fused backend (the
    per-bucket/dense names keep their reference semantics) and CBSR
    aggregation on both node types (dense SpMM stays serial).  The single
    gate shared by :func:`_plan_for` and the trainer's plan attachment, so
    the two cannot drift."""
    return (cfg.use_plan and cfg.use_drelu
            and cfg.backend in ("pallas_fused", "xla_fused")
            and cfg.k_cell < hidden and cfg.k_net < hidden)


def _plan_for(graph: CircuitGraph, cfg: HeteroMPConfig,
              hidden: int) -> RelationPlan | ShardedRelationPlan | None:
    """The layer's RelationPlan (possibly mesh-partitioned), or None when
    the serial path must run.

    Beyond :func:`plan_applicable`, a plan must actually be available:
    attached to the graph (collated batches / ``with_sharded_plan`` — works
    traced), or buildable host-side (concrete bucketed adjacencies,
    memoized per graph; partitioned when ``cfg.n_shards > 1``)."""
    if not plan_applicable(cfg, hidden):
        return None
    if graph.plan is not None:
        return graph.plan
    adj = graph.edges["near"].adj
    if isinstance(adj, FusedELL):
        return None    # pre-fused (collated) graph without an attached plan
    if isinstance(adj.buckets[0].nbr, jax.core.Tracer):
        return None    # traced graph argument: host packing impossible
    if cfg.n_shards > 1:
        return sharded_plan_of(graph, cfg.n_shards)
    return relation_plan_of(graph, dense_threshold=cfg.dense_threshold)


def _merge(params: HeteroLayerParams, x_cell: jax.Array,
           agg_near: jax.Array, agg_pinned: jax.Array,
           agg_pin: jax.Array) -> Tuple[jax.Array, jax.Array]:
    # --- per-edge W^ψ (Eq. 4) ---
    near_out = agg_near @ params.w_near + x_cell @ params.w_near_self
    pinned_out = agg_pinned @ params.w_pinned + x_cell @ params.w_pinned_self
    pin_out = agg_pin @ params.w_pin
    # --- merge (Eqs. 8-9); Eqs. 12-14 are the autodiff of the max ---
    y_cell = jnp.maximum(near_out, pinned_out) + params.b_cell
    y_net = pin_out + params.b_net
    return y_cell, y_net


# sentinel: "resolve the plan yourself" (the back-compat default) vs an
# explicit plan=None, which pins the serial path
_RESOLVE_PLAN = object()


def hetero_conv(params: HeteroLayerParams, graph: CircuitGraph,
                x_cell: jax.Array, x_net: jax.Array,
                cfg: HeteroMPConfig, *,
                plan=_RESOLVE_PLAN) -> Tuple[jax.Array, jax.Array]:
    """One HeteroConv layer.  Returns (y_cell, y_net).

    With a :class:`RelationPlan` available (see :func:`_plan_for`) the
    layer's entire message passing is ONE ``drspmm_multi`` dispatch per
    direction-group.  Both strategies sparsify each node type once per
    layer and share the CBSR across the relations consuming it
    (:func:`_sparsify_types` — identical values, so the paths agree
    exactly).

    ``plan`` lets a stack caller (models/backbone.py) hoist the
    layer-invariant plan resolution once per stack application and thread
    it remat-safely through every layer: pass the resolved plan (or
    ``None`` to pin the serial reference); the default sentinel keeps the
    per-call resolution for standalone use."""
    if plan is _RESOLVE_PLAN:
        plan = _plan_for(graph, cfg, x_cell.shape[-1])
    if plan is not None:
        c_cell, c_net = _sparsify_types(x_cell, x_net, cfg)
        op = ops.drspmm_multi_sharded \
            if isinstance(plan, ShardedRelationPlan) else ops.drspmm_multi
        aggs = op(
            plan, {"cell": (c_cell.values, c_cell.idx),
                   "net": (c_net.values, c_net.idx)},
            x_cell.shape[-1], backend=cfg.backend)
        return _merge(params, x_cell, aggs["near"], aggs["pinned"],
                      aggs["pin"])

    # --- serial reference: three edge-type message passings over the two
    # --- shared per-type CBSRs (cell feeds both near and pin) -------------
    c_cell, c_net = _sparsify_types(x_cell, x_net, cfg)
    agg_near = _aggregate(graph, "near", x_cell, c_cell, cfg)    # cell->cell
    agg_pinned = _aggregate(graph, "pinned", x_net, c_net, cfg)  # net->cell
    agg_pin = _aggregate(graph, "pin", x_cell, c_cell, cfg)      # cell->net
    return _merge(params, x_cell, agg_near, agg_pinned, agg_pin)
