"""Parallel subgraph scheduling — the paper's Sec. 3.4, TPU/JAX-native.

The paper overlaps the three edge-type message passings with 3 CPU init
threads + 3 cudaStreams.  The JAX/XLA equivalents:

* **fused mode** (ours): all three SpMMs live in ONE jitted computation.
  XLA sees three dataflow-independent subgraphs and schedules them
  concurrently (on TPU they interleave across the scalar/vector/matrix
  units; across a mesh they can shard onto different devices).  Crucially
  there is no host round-trip between modules.
* **sequential mode** (the DGL-analogue baseline): one jit per module with a
  ``block_until_ready`` barrier after each — this reproduces the
  module-by-module host synchronization the paper measures against.
* **host-side**: graph packing runs on a 3-thread pool
  (graphs/generator.py::pack_graph_parallel), and device transfer uses
  ``jax.device_put`` async dispatch, overlapping H2D with packing — the UVM
  analogue.

``benchmark_modes`` quantifies fused vs sequential for EXPERIMENTS.md
(the Fig. 12 "Parallel savings" analogue).

Public helpers
--------------
``run_fused(fns, args)`` / ``run_sequential(fns, args)`` — the two
execution modes above, with the jitted executables memoized on function
identity (reuse the SAME closures across calls).

``prefetch(items, prepare, depth=d, n_threads=n)`` — the host packing pool:
``prepare`` runs on worker threads up to ``depth`` items ahead of the
consumer, yielding results in input order.  The serve engine sets ``depth``
to its device count so one batch is always being packed *per device* while
the previous batches execute::

    batches = [...]                          # (requests, device_index) units
    for prepared in prefetch(batches, prepare_fn,
                             depth=len(ring), n_threads=4):
        dispatch(prepared)                   # device runs batch i while the
                                             # pool packs batches i+1..i+d
"""

from __future__ import annotations

import functools
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, Iterable, Iterator, Sequence

import jax
import jax.numpy as jnp


# A fresh ``jax.jit`` wrapper owns a fresh trace cache, so wrapping inside
# the runner forced a retrace (and recompile) on EVERY invocation.  The
# executables are memoized on the function tuple instead: a second call with
# the same modules and argument shapes reuses the compiled computation.
#
# Caveat: the memo is keyed on function identity, so callers must reuse the
# SAME closure objects across calls to benefit (rebuilding lambdas per step
# retraces exactly as before, and the cache then also pins whatever the
# stale closures captured until they cycle out — keep module closures
# long-lived and small).

@functools.lru_cache(maxsize=128)
def _fused_executable(fns: tuple):
    return jax.jit(lambda args: tuple(f(*a) for f, a in zip(fns, args)))


@functools.lru_cache(maxsize=512)
def _jit_one(fn: Callable):
    return jax.jit(fn)


def run_fused(fns: Sequence[Callable], args: Sequence[tuple]):
    """Execute independent module closures inside one (cached) jit."""
    return _fused_executable(tuple(fns))(tuple(args))


def run_sequential(fns: Sequence[Callable], args: Sequence[tuple]):
    """DGL-analogue: jit per module, host barrier between modules."""
    outs = []
    for f, a in zip(fns, args):
        o = _jit_one(f)(*a)
        jax.block_until_ready(o)
        outs.append(o)
    return tuple(outs)


def prefetch(items: Iterable, prepare: Callable, *, depth: int = 1,
             n_threads: int = 3) -> Iterator:
    """Host-side prepare/device-execute overlap at batch granularity.

    ``prepare(item)`` (packing, padding, ``jax.device_put``) runs on a
    worker thread up to ``depth`` items ahead of the consumer, so while the
    device executes batch i the pool is already packing and transferring
    batch i+1 — the JAX analogue of the paper's CPU-init-thread +
    multi-stream overlap (Sec. 3.4), moved from subgraph to batch
    granularity.  ``jax.device_put`` dispatches the H2D copy
    asynchronously, so the transfer itself also overlaps.

    Yields ``prepare``'s results in input order.
    """
    it = iter(items)
    with ThreadPoolExecutor(max_workers=n_threads) as pool:
        futs: deque = deque()
        for x in it:
            futs.append(pool.submit(prepare, x))
            if len(futs) > depth:
                yield futs.popleft().result()
        while futs:
            yield futs.popleft().result()


def benchmark_modes(fns, args, iters: int = 20) -> Dict[str, float]:
    """Wall-clock fused vs sequential execution (µs per iteration)."""
    fused = jax.jit(lambda: tuple(f(*a) for f, a in zip(fns, args)))
    jax.block_until_ready(fused())            # compile
    seq_fns = [jax.jit(f) for f in fns]
    for f, a in zip(seq_fns, args):           # compile
        jax.block_until_ready(f(*a))

    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fused())
    t_fused = (time.perf_counter() - t0) / iters * 1e6

    t0 = time.perf_counter()
    for _ in range(iters):
        for f, a in zip(seq_fns, args):
            jax.block_until_ready(f(*a))
    t_seq = (time.perf_counter() - t0) / iters * 1e6

    return {"fused_us": t_fused, "sequential_us": t_seq,
            "speedup": t_seq / max(t_fused, 1e-9)}
