"""Deterministic, shard-indexed synthetic token pipeline.

Fault-tolerance property: batch(step, shard) is a pure function of
(seed, step, shard) — after any host failure the replacement host recomputes
exactly the shards it now owns, with no inter-host shuffle state to rebuild.
This is the data-side half of elastic restart (DESIGN.md §5).

The stream is a mixture of Zipfian unigrams and short Markov motifs so the
loss actually decreases (pure uniform noise would pin CE at log V).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    n_shards: int = 1          # data-parallel shard count (hosts × replicas)
    zipf_a: float = 1.2
    motif_len: int = 8
    n_motifs: int = 512


class TokenPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        root = np.random.default_rng(cfg.seed)
        # global motif table, identical on every host (derived from seed)
        self.motifs = root.integers(
            0, cfg.vocab, (cfg.n_motifs, cfg.motif_len)).astype(np.int32)

    def shard_batch(self, step: int, shard: int) -> Dict[str, np.ndarray]:
        """One shard's slice of the global batch at ``step``."""
        cfg = self.cfg
        assert cfg.global_batch % cfg.n_shards == 0
        b = cfg.global_batch // cfg.n_shards
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + shard)
        # Zipfian base stream
        toks = rng.zipf(cfg.zipf_a, size=(b, cfg.seq_len + 1)).astype(np.int64)
        toks = (toks - 1) % cfg.vocab
        # splice motifs (learnable structure)
        n_splice = max((cfg.seq_len // cfg.motif_len) // 4, 1)
        for i in range(b):
            ids = rng.integers(0, cfg.n_motifs, n_splice)
            pos = rng.integers(0, cfg.seq_len + 1 - cfg.motif_len, n_splice)
            for m, p in zip(ids, pos):
                toks[i, p: p + cfg.motif_len] = self.motifs[m]
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    def global_batch(self, step: int) -> Dict[str, np.ndarray]:
        parts = [self.shard_batch(step, s) for s in range(self.cfg.n_shards)]
        return {k: np.concatenate([p[k] for p in parts], 0) for k in parts[0]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.global_batch(step)
            step += 1


class PrefetchingLoader:
    """Double-buffered host-side prefetch (overlaps batch synthesis /
    disk IO with device compute — the UVM-overlap analogue)."""

    def __init__(self, pipeline: TokenPipeline, start_step: int = 0):
        import threading
        import queue
        self.pipeline = pipeline
        self.q: "queue.Queue" = queue.Queue(maxsize=2)
        self._stop = threading.Event()

        def worker():
            step = start_step
            while not self._stop.is_set():
                batch = pipeline.global_batch(step)
                batch["_step"] = step
                self.q.put(batch)
                step += 1

        self.t = threading.Thread(target=worker, daemon=True)
        self.t.start()

    def next(self) -> Dict[str, np.ndarray]:
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            self.q.get_nowait()
        except Exception:
            pass
