from repro.fault.monitor import StepMonitor, ElasticController  # noqa: F401
