from repro.fault.monitor import (StepMonitor, ElasticController,  # noqa: F401
                                 Heartbeat, StragglerEvent)
from repro.fault.inject import (POINTS, FaultEvent, FaultInjector,  # noqa: F401
                                FaultRule, InjectedFault)
