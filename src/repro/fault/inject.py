"""Deterministic chaos-injection harness for the serve and train paths.

A :class:`FaultInjector` is a seed-scheduled set of :class:`FaultRule`\\ s
bound to named **injection points** — the places in the serving/training
pipeline where production faults actually land:

    ``collate``      host-side collation raises (malformed batch)
    ``device_put``   transfer onto a ring slot raises
    ``dispatch``     the device dispatch raises
    ``nan_output``   the batch output comes back NaN-poisoned
    ``straggler``    the host packing stage stalls for ``delay_s``
    ``device_loss``  a ring slot goes down for ``down_for`` touches

The engine (serve/circuit_engine.py), the trainer
(train/circuit_trainer.py), the chaos bench (benchmarks/bench_chaos.py)
and the tests all consume the SAME harness, so a failure mode reproduced
in a test is the failure mode the containment ladder is benched against.

Scheduling is deterministic: a rule fires on explicit occurrence indices
(``at=(0, 3)`` — the 0th and 3rd time its point is touched) and/or on
Bernoulli draws from a per-rule ``random.Random`` seeded from
``(seed, rule index)`` — the same seed replays the same fault sequence
for the same sequence of touches.  Every firing is recorded in
``injector.events`` for post-hoc assertions.

``device_loss`` is stateful: when its rule triggers on a touch of the
matching slot, that slot enters a *down window* and the next ``down_for``
touches (``device_put``/``dispatch``) raise :class:`InjectedFault` with
``point="device_loss"`` — long enough to trip the engine's K-consecutive-
failures quarantine, short enough that the periodic probe finds the
device healthy again and re-admits it.

Zero-overhead contract: the pipeline guards every hook with
``if chaos is not None`` — a ``chaos=None`` engine (the default) executes
no injection code at all.
"""

from __future__ import annotations

import dataclasses
import random
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

POINTS = ("collate", "device_put", "dispatch", "nan_output", "straggler",
          "device_loss")


class InjectedFault(RuntimeError):
    """Raised by an injection point; carries the point and ring slot so the
    engine's failure classifier can attribute (or not) device blame."""

    def __init__(self, point: str, occurrence: int,
                 device: Optional[int] = None):
        self.point = point
        self.occurrence = occurrence
        self.device = device
        at = f" on ring slot {device}" if device is not None else ""
        super().__init__(f"injected {point} fault{at} "
                         f"(occurrence {occurrence})")


@dataclasses.dataclass(frozen=True)
class FaultRule:
    """One scheduled fault.  ``at`` fires on those occurrence indices of the
    rule's point (0-based, counted per rule, restricted to ``device`` when
    set); ``rate`` additionally fires on seeded Bernoulli draws.  ``n``
    caps total firings.  ``delay_s`` is the straggler stall; ``down_for``
    the device-loss window length in touches."""
    point: str
    at: Tuple[int, ...] = ()
    rate: float = 0.0
    n: Optional[int] = None
    device: Optional[int] = None
    delay_s: float = 0.05
    down_for: int = 3

    def __post_init__(self):
        if self.point not in POINTS:
            raise ValueError(f"unknown injection point {self.point!r}; "
                             f"expected one of {POINTS}")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    point: str
    occurrence: int
    device: Optional[int]
    t: float


class FaultInjector:
    """Seed-scheduled fault source shared by every injection point.

    Thread-safe: the engine touches points from the serve loop, the packing
    pool, and healer threads concurrently.
    """

    def __init__(self, rules: Sequence[FaultRule], seed: int = 0):
        self.rules = tuple(rules)
        self.seed = seed
        # distinct integer stream per rule (tuple seeds are deprecated)
        self._rngs = [random.Random(None if seed is None
                                    else (seed << 20) + i)
                      for i in range(len(self.rules))]
        self._touches = [0] * len(self.rules)   # per-rule occurrence counter
        self._fired = [0] * len(self.rules)
        self._down: Dict[int, int] = {}         # slot -> remaining failures
        self.events: List[FaultEvent] = []
        self._lock = threading.Lock()
        # Optional obs hook (repro.obs.trace.Recorder): when set (the serve
        # engine wires its own recorder in), every injected fault is also an
        # annotated instant on the "chaos" trace track.  The recorder never
        # calls back into the injector, so emitting under self._lock is safe.
        self.recorder = None

    # ------------------------------------------------------------ core

    def _eval(self, point: str, device: Optional[int]) -> Optional[int]:
        """One touch of ``point``; returns the firing occurrence index or
        None.  Caller holds the lock."""
        hit = None
        for i, rule in enumerate(self.rules):
            if rule.point != point:
                continue
            if rule.device is not None and device is not None \
                    and rule.device != device:
                continue
            occ = self._touches[i]
            self._touches[i] += 1
            if rule.n is not None and self._fired[i] >= rule.n:
                continue
            fire = occ in rule.at
            if not fire and rule.rate > 0.0:
                fire = self._rngs[i].random() < rule.rate
            if fire:
                self._fired[i] += 1
                if hit is None:
                    hit = occ
                if point == "device_loss" and device is not None:
                    # open the down window; the triggering touch itself is
                    # the first failure of the window
                    self._down[device] = max(self._down.get(device, 0),
                                             rule.down_for - 1)
        return hit

    def _record(self, point: str, occ: int, device: Optional[int]):
        self.events.append(FaultEvent(point, occ, device, time.time()))
        rec = self.recorder
        if rec is not None and rec.enabled:
            if device is None:
                rec.instant("chaos", f"inject:{point}", occurrence=occ)
            else:
                rec.instant("chaos", f"inject:{point}", occurrence=occ,
                            device=device)

    # --------------------------------------------------- engine-facing

    def raise_if(self, point: str, device: Optional[int] = None) -> None:
        """Touch a raising point (``collate``/``device_put``/``dispatch``);
        device touches also consult the ``device_loss`` state machine."""
        with self._lock:
            if device is not None:
                # an open down window fails every touch of the slot first
                if self._down.get(device, 0) > 0:
                    self._down[device] -= 1
                    occ = sum(self._fired)
                    self._record("device_loss", occ, device)
                    raise InjectedFault("device_loss", occ, device)
                occ = self._eval("device_loss", device)
                if occ is not None:
                    self._record("device_loss", occ, device)
                    raise InjectedFault("device_loss", occ, device)
            occ = self._eval(point, device)
            if occ is not None:
                self._record(point, occ, device)
                raise InjectedFault(point, occ, device)

    def stall(self, point: str = "straggler") -> float:
        """Touch the straggler point; sleeps (and returns) the injected
        delay — 0.0 when the point stays quiet."""
        with self._lock:
            delay = 0.0
            for i, rule in enumerate(self.rules):
                if rule.point != point:
                    continue
                occ = self._touches[i]
                self._touches[i] += 1
                if rule.n is not None and self._fired[i] >= rule.n:
                    continue
                fire = occ in rule.at or (rule.rate > 0.0 and
                                          self._rngs[i].random() < rule.rate)
                if fire:
                    self._fired[i] += 1
                    delay = max(delay, rule.delay_s)
                    self._record(point, occ, None)
        if delay > 0.0:
            time.sleep(delay)
        return delay

    def poison(self, out: np.ndarray,
               point: str = "nan_output") -> np.ndarray:
        """Touch the NaN-poisoning point; when it fires, the returned copy
        of ``out`` is fully NaN (the output guard must catch it)."""
        with self._lock:
            occ = self._eval(point, None)
            if occ is None:
                return out
            self._record(point, occ, None)
        bad = np.array(out, copy=True)
        bad[...] = np.nan
        return bad

    # -------------------------------------------------------- reporting

    def counts(self) -> Dict[str, int]:
        """Firings per point (from the event log)."""
        out: Dict[str, int] = {}
        with self._lock:
            for ev in self.events:
                out[ev.point] = out.get(ev.point, 0) + 1
        return out
