"""Fault tolerance: step-time monitoring, straggler mitigation, elastic
restart policy.

On a real cluster the heartbeat transport is the coordination service
(jax.distributed); the *policy* layer below is transport-agnostic and is
what we exercise in tests:

* :class:`StepMonitor` — robust step-time statistics (median + MAD); flags
  stragglers (> median + k·MAD) and hard failures (missed deadline).
  Mitigations, in escalation order:
    1. ``slack`` — tolerate transient jitter (no action, logged);
    2. ``rebalance`` — reassign the straggler's *data shards* to healthy
       hosts (the pipeline is shard-indexed and stateless, so this is a
       pure index remap — see data/pipeline.py);
    3. ``restart`` — declare the node dead, shrink the mesh, restore the
       latest checkpoint elastically (checkpoint/ckpt.py resharding).
* :class:`ElasticController` — computes the largest valid (data, model)
  mesh for the surviving device count and the data-shard remap.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

from repro.train.metrics import median


@dataclasses.dataclass
class StragglerEvent:
    step: int
    host: int
    duration: float
    threshold: float
    action: str            # "slack" | "rebalance" | "restart"


class StepMonitor:
    def __init__(self, n_hosts: int = 1, *, mad_k: float = 6.0,
                 deadline_factor: float = 10.0, window: int = 50,
                 patience: int = 3):
        self.n_hosts = n_hosts
        self.mad_k = mad_k
        self.deadline_factor = deadline_factor
        self.window = window
        self.patience = patience
        self.history: Dict[int, List[float]] = {h: [] for h in range(n_hosts)}
        self.strikes: Dict[int, int] = {h: 0 for h in range(n_hosts)}
        self.events: List[StragglerEvent] = []

    def record(self, step: int, host: int, duration: float) -> Optional[StragglerEvent]:
        if host not in self.history:
            # elastic mesh growth: hosts joining after construction must not
            # crash the monitor — register them lazily
            self.history[host] = []
            self.strikes[host] = 0
            self.n_hosts = max(self.n_hosts, host + 1)
        hist = self.history[host]
        hist.append(duration)
        if len(hist) > self.window:
            hist.pop(0)
        if len(hist) < 5:
            return None
        med = median(hist)
        mad = median([abs(x - med) for x in hist]) + 1e-9
        threshold = med + self.mad_k * mad
        deadline = med * self.deadline_factor
        if duration > deadline:
            ev = StragglerEvent(step, host, duration, deadline, "restart")
        elif duration > threshold:
            self.strikes[host] += 1
            action = ("rebalance" if self.strikes[host] >= self.patience
                      else "slack")
            ev = StragglerEvent(step, host, duration, threshold, action)
        else:
            self.strikes[host] = max(0, self.strikes[host] - 1)
            return None
        self.events.append(ev)
        return ev


class ElasticController:
    """Mesh shrink / data-shard remap policy for node loss.

    Invariants: the model axis is preserved when possible (param resharding
    is cheap over data but layout-changing over model); the data axis
    shrinks to the largest divisor of the surviving host count.
    """

    def __init__(self, data: int, model: int, pods: int = 1):
        self.data, self.model, self.pods = data, model, pods

    def shrink(self, failed_hosts: int) -> Tuple[int, int, int]:
        """Returns the new (pods, data, model) after losing hosts.

        Whole-pod loss drops the pod axis first; partial loss shrinks data."""
        surviving = self.pods * self.data - failed_hosts
        if surviving <= 0:
            raise RuntimeError("no survivors")
        pods = self.pods
        while pods > 1 and surviving < pods * self.data:
            pods -= 1                       # drop incomplete pods
        per_pod = surviving // pods
        data = _largest_pow2_leq(per_pod) if per_pod >= 1 else 1
        return pods, data, self.model

    def shard_remap(self, n_shards: int, dead: List[int]) -> Dict[int, int]:
        """Reassign dead hosts' data shards round-robin to survivors.
        Stateless pipeline ⇒ remap is a pure function (no data motion)."""
        alive = [h for h in range(n_shards) if h not in dead]
        remap = {}
        for i, d in enumerate(sorted(dead)):
            remap[d] = alive[i % len(alive)]
        return remap


def _largest_pow2_leq(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


class Heartbeat:
    """Host-local heartbeat emitter (file-based transport for tests;
    jax.distributed KV store in production)."""

    def __init__(self, path: str, host: int, interval: float = 5.0):
        self.path, self.host, self.interval = path, host, interval
        self._last = 0.0

    def beat(self, step: int):
        now = time.time()
        if now - self._last < self.interval:
            return
        self._last = now
        import json, os
        os.makedirs(self.path, exist_ok=True)
        # write-then-rename so a concurrent dead_hosts() never reads a
        # partially-written record (rename is atomic on POSIX); the tmp name
        # is per-host, so concurrent beats of different hosts don't collide
        final = f"{self.path}/host_{self.host}.json"
        tmp = f"{final}.tmp"
        with open(tmp, "w") as f:
            json.dump({"host": self.host, "step": step, "time": now}, f)
        os.replace(tmp, final)

    @staticmethod
    def dead_hosts(path: str, timeout: float, now: Optional[float] = None
                   ) -> List[int]:
        import json, os
        now = now or time.time()
        dead = []
        if not os.path.isdir(path):
            return dead
        for fn in os.listdir(path):
            if not (fn.startswith("host_") and fn.endswith(".json")):
                continue                      # skip .tmp files and strays
            try:
                with open(os.path.join(path, fn)) as f:
                    rec = json.load(f)
                host, t = rec["host"], rec["time"]
            except (OSError, ValueError, KeyError, TypeError):
                # unreadable/corrupt record: a monitor must degrade, not
                # crash — treat it as no evidence either way
                continue
            if now - t > timeout:
                dead.append(host)
        return sorted(dead)
