"""Circuit-graph substrate: containers, ELL packing, synthetic CircuitNet."""
