"""Heterogeneous circuit graph container (CircuitNet schema).

Two node types (``cell``, ``net``), three edge types:

    near   : cell -> cell   (geometric)
    pin    : cell -> net    (topological)
    pinned : net  -> cell   (= pinᵀ)

Each edge type carries a forward (row-major over destinations) and transposed
(row-major over sources) degree-bucketed ELL packing — the CSR/CSC pair the
paper preprocesses in Alg. 1 stage 1 / Alg. 2 stage 1.
"""

from __future__ import annotations

import dataclasses
import weakref
from typing import Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs.ell import (BucketedELL, RelationPlan, build_relation_plan,
                              degree_stats, ell_to_coo, pack_ell_pair)
from repro.sharding.plan_shard import (ShardedRelationPlan,
                                       shard_relation_plan)

EDGE_TYPES = ("near", "pin", "pinned")
# (source node type, destination node type) per edge type.
EDGE_SCHEMA = {"near": ("cell", "cell"), "pin": ("cell", "net"),
               "pinned": ("net", "cell")}


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EdgeSet:
    adj: BucketedELL      # A   (n_dst x n_src)
    adj_t: BucketedELL    # Aᵀ  (n_src x n_dst)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CircuitGraph:
    n_cell: int = dataclasses.field(metadata=dict(static=True))
    n_net: int = dataclasses.field(metadata=dict(static=True))
    edges: Dict[str, EdgeSet]
    x_cell: jax.Array            # (n_cell, f_cell) input features
    x_net: jax.Array             # (n_net, f_net)
    y_cell: jax.Array            # (n_cell,) congestion label
    # Optional relation-fused super-arena pair for the whole-layer
    # message-passing dispatch (graphs/ell.py::RelationPlan, DESIGN.md §9),
    # or its mesh-partitioned form (sharding/plan_shard.py::
    # ShardedRelationPlan, DESIGN.md §12) for graphs larger than one
    # device.  Attached by the collator / ``with_plan`` /
    # ``with_sharded_plan`` so plan-driven layers work even when the graph
    # is a TRACED jit argument (host packing is impossible there); ``None``
    # falls back to the serial per-direction path in core/hetero_mp.py.
    plan: Optional[RelationPlan | ShardedRelationPlan] = None

    def n_nodes(self, ntype: str) -> int:
        return self.n_cell if ntype == "cell" else self.n_net


# id-keyed memo with weakref guards (the _FUSE_CACHE pattern): plan packing
# is one-time host-side preprocessing per graph.
_PLAN_CACHE: Dict[int, tuple] = {}


def relation_plan_of(graph: CircuitGraph,
                     dense_threshold: Optional[int] = None) -> RelationPlan:
    """Memoized :class:`RelationPlan` covering every edge type of
    ``graph`` — the one-kernel-per-direction-group packing of its whole
    hetero layer.  Requires concrete (non-traced) bucketed adjacencies; the
    collator attaches pre-quantized plans to collated graphs instead.
    ``dense_threshold`` overrides the measured dense-tier nnz crossover
    (DESIGN.md §14); distinct thresholds memoize separately."""
    if isinstance(graph.plan, RelationPlan) and dense_threshold is None:
        return graph.plan
    key = (id(graph), dense_threshold)
    hit = _PLAN_CACHE.get(key)
    if hit is not None and hit[0]() is graph:
        return hit[1]
    rels = []
    for et in EDGE_TYPES:
        if et not in graph.edges:
            continue
        s_t, d_t = EDGE_SCHEMA[et]
        dst, src, w = ell_to_coo(graph.edges[et].adj)
        rels.append((et, s_t, d_t, dst, src, w))
    plan = build_relation_plan(
        rels, {"cell": graph.n_cell, "net": graph.n_net},
        dense_threshold=dense_threshold)
    _PLAN_CACHE[key] = (
        weakref.ref(graph, lambda _: _PLAN_CACHE.pop(key, None)), plan)
    return plan


def with_plan(graph: CircuitGraph) -> CircuitGraph:
    """``graph`` with its relation plan attached as a pytree child — the
    form to pass into jitted step functions that take the graph as a traced
    argument (the plan's arrays trace along; its segment table is static
    aux data, so equal-shaped graphs still share one compiled executable).
    """
    if graph.plan is not None:
        return graph
    return dataclasses.replace(graph, plan=relation_plan_of(graph))


# (id(graph), n_shards)-keyed memo, weakref-guarded like _PLAN_CACHE: the
# mesh partition is host-side numpy work done once per (graph, mesh size).
_SHARDED_PLAN_CACHE: Dict[tuple, tuple] = {}


def sharded_plan_of(graph: CircuitGraph, n_shards: int,
                    registry=None) -> ShardedRelationPlan:
    """Memoized mesh partition of ``graph``'s relation plan (DESIGN.md
    §12): every device of a ``("shard",)`` mesh owns one destination slab
    of the super-arena plus the halo index tables for its cross-shard
    source rows.  Consumed by ``ops.drspmm_multi_sharded``."""
    key = (id(graph), int(n_shards))
    hit = _SHARDED_PLAN_CACHE.get(key)
    if hit is not None and hit[0]() is graph:
        return hit[1]
    splan = shard_relation_plan(relation_plan_of(graph), n_shards,
                                registry=registry)
    _SHARDED_PLAN_CACHE[key] = (
        weakref.ref(graph, lambda _: _SHARDED_PLAN_CACHE.pop(key, None)),
        splan)
    return splan


def with_sharded_plan(graph: CircuitGraph, n_shards: int) -> CircuitGraph:
    """``graph`` with its mesh-partitioned plan attached as a pytree child
    — the giant-graph analogue of :func:`with_plan` for jitted steps that
    take the graph as a traced argument."""
    if isinstance(graph.plan, ShardedRelationPlan) \
            and graph.plan.n_shards == n_shards:
        return graph
    base = dataclasses.replace(graph, plan=None) \
        if graph.plan is not None else graph
    return dataclasses.replace(base, plan=sharded_plan_of(graph, n_shards))


def build_circuit_graph(coo: Dict[str, Tuple[np.ndarray, np.ndarray]],
                        n_cell: int, n_net: int,
                        x_cell, x_net, y_cell,
                        normalize: str = "mean") -> CircuitGraph:
    """Pack COO edge dicts {etype: (dst, src)} into a CircuitGraph.

    ``normalize="mean"`` row-normalizes edge weights (SAGE mean aggregator /
    GraphConv style); ``"none"`` keeps unit weights.
    """
    sizes = {"cell": n_cell, "net": n_net}
    edges = {}
    for et, (dst, src) in coo.items():
        s_t, d_t = EDGE_SCHEMA[et]
        n_dst, n_src = sizes[d_t], sizes[s_t]
        if normalize == "mean":
            deg = np.bincount(dst, minlength=n_dst).astype(np.float32)
            w = 1.0 / np.maximum(deg[dst], 1.0)
        else:
            w = np.ones(len(dst), np.float32)
        adj, adj_t = pack_ell_pair(dst, src, w, n_dst, n_src)
        edges[et] = EdgeSet(adj=adj, adj_t=adj_t)
    return CircuitGraph(n_cell=n_cell, n_net=n_net, edges=edges,
                        x_cell=jnp.asarray(x_cell), x_net=jnp.asarray(x_net),
                        y_cell=jnp.asarray(y_cell))


def graph_degree_stats(coo: Dict[str, Tuple[np.ndarray, np.ndarray]],
                       n_cell: int, n_net: int) -> Dict[str, dict]:
    sizes = {"cell": n_cell, "net": n_net}
    out = {}
    for et, (dst, src) in coo.items():
        s_t, d_t = EDGE_SCHEMA[et]
        st = degree_stats(np.asarray(dst), sizes[d_t])
        st["src_type"] = s_t
        out[et] = st
    return out
