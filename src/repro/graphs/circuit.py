"""Heterogeneous circuit graph container (CircuitNet schema).

Two node types (``cell``, ``net``), three edge types:

    near   : cell -> cell   (geometric)
    pin    : cell -> net    (topological)
    pinned : net  -> cell   (= pinᵀ)

Each edge type carries a forward (row-major over destinations) and transposed
(row-major over sources) degree-bucketed ELL packing — the CSR/CSC pair the
paper preprocesses in Alg. 1 stage 1 / Alg. 2 stage 1.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs.ell import BucketedELL, pack_ell_pair, degree_stats

EDGE_TYPES = ("near", "pin", "pinned")
# (source node type, destination node type) per edge type.
EDGE_SCHEMA = {"near": ("cell", "cell"), "pin": ("cell", "net"),
               "pinned": ("net", "cell")}


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EdgeSet:
    adj: BucketedELL      # A   (n_dst x n_src)
    adj_t: BucketedELL    # Aᵀ  (n_src x n_dst)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CircuitGraph:
    n_cell: int = dataclasses.field(metadata=dict(static=True))
    n_net: int = dataclasses.field(metadata=dict(static=True))
    edges: Dict[str, EdgeSet]
    x_cell: jax.Array            # (n_cell, f_cell) input features
    x_net: jax.Array             # (n_net, f_net)
    y_cell: jax.Array            # (n_cell,) congestion label

    def n_nodes(self, ntype: str) -> int:
        return self.n_cell if ntype == "cell" else self.n_net


def build_circuit_graph(coo: Dict[str, Tuple[np.ndarray, np.ndarray]],
                        n_cell: int, n_net: int,
                        x_cell, x_net, y_cell,
                        normalize: str = "mean") -> CircuitGraph:
    """Pack COO edge dicts {etype: (dst, src)} into a CircuitGraph.

    ``normalize="mean"`` row-normalizes edge weights (SAGE mean aggregator /
    GraphConv style); ``"none"`` keeps unit weights.
    """
    sizes = {"cell": n_cell, "net": n_net}
    edges = {}
    for et, (dst, src) in coo.items():
        s_t, d_t = EDGE_SCHEMA[et]
        n_dst, n_src = sizes[d_t], sizes[s_t]
        if normalize == "mean":
            deg = np.bincount(dst, minlength=n_dst).astype(np.float32)
            w = 1.0 / np.maximum(deg[dst], 1.0)
        else:
            w = np.ones(len(dst), np.float32)
        adj, adj_t = pack_ell_pair(dst, src, w, n_dst, n_src)
        edges[et] = EdgeSet(adj=adj, adj_t=adj_t)
    return CircuitGraph(n_cell=n_cell, n_net=n_net, edges=edges,
                        x_cell=jnp.asarray(x_cell), x_net=jnp.asarray(x_net),
                        y_cell=jnp.asarray(y_cell))


def graph_degree_stats(coo: Dict[str, Tuple[np.ndarray, np.ndarray]],
                       n_cell: int, n_net: int) -> Dict[str, dict]:
    sizes = {"cell": n_cell, "net": n_net}
    out = {}
    for et, (dst, src) in coo.items():
        s_t, d_t = EDGE_SCHEMA[et]
        st = degree_stats(np.asarray(dst), sizes[d_t])
        st["src_type"] = s_t
        out[et] = st
    return out
