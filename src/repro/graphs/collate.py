"""Block-diagonal collation: N CircuitGraphs → ONE CircuitGraph per batch.

The serve/train hot path dispatches the HGNN once per *batch* instead of
once per graph.  Member graphs are laid out block-diagonally in a shared
node space per node type:

    cell ids of member i live in [cell_off_i, cell_off_i + n_cell_i)
    net  ids of member i live in [net_off_i,  net_off_i  + n_net_i)

Edges never cross members, so every aggregation over the collated graph is
exactly the direct sum of the members' aggregations — batched forward and
gradients match the per-graph loop bit-for-bit up to f32 summation order
(tests/test_collate.py).

Compile-once comes from **shape quantization** (the HOGA/GSR-GNN-motivated
move): member node counts are padded up to a small geometric bucket grid,
and the fused arenas' chunk/row counts are padded the same way, so the
jitted forward — which takes the collated graph as a *traced argument* —
compiles once per shape bucket instead of once per graph.  Padding is inert
by construction: padded node rows carry zero features and no edges, and
padded arena chunks carry zero weights routed into rows the output gather
never reads.

Member edges are recovered host-side from their ELL packings
(``ell_to_coo``), offset, and re-packed in one fused-arena repack per edge
type (``pack_fused_pair``'s two directions).  Member weights (already
row-normalized per member) are carried through unchanged — block-diagonal
row norms are member-local, so no renormalization is needed.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs.circuit import (CircuitGraph, EDGE_SCHEMA, EDGE_TYPES,
                                  EdgeSet)
from repro.graphs.ell import (DEFAULT_BOUNDS, DENSE_TIER_AREA,
                              DENSE_TIER_NNZ, FusedELL, RelationPlan,
                              arena_stats, build_relation_plan, ell_to_coo,
                              fuse_bucketed, pack_ell, pack_ell_pair,
                              pack_fused_eid_pair, pad_fused_arena, _round_up)
from repro.obs.metrics import DEFAULT_REGISTRY as _METRICS

# Default bucket-grid resolutions (mantissa bits of the geometric grid):
# node slabs pay padding linearly (features, gather), so they get a finer
# grid; arena chunk counts only pay inert zero-weight chunks, so a coarser
# grid buys fewer shape buckets (= fewer compiles) cheaply.
NODE_GRID_BITS = 2     # grid {m·2^e : m ∈ [4, 8)} — ≤ ~25% padding
ARENA_GRID_BITS = 1    # grid {m·2^e : m ∈ [2, 4)} — ≤ ~50% padding
# Chunk-count headroom applied when a bucket's layout is FIRST recorded:
# later batches whose chunk count stays within this factor of the first
# batch's reuse its signature (batch-to-batch jitter shrinks ~1/√B, so 15%
# covers typical mixed streams); growth beyond it costs one extra compile
# and raises the bucket's floor.
ARENA_HEADROOM = 1.15


def quantize_up(n: int, mantissa_bits: int = NODE_GRID_BITS,
                minimum: int = 8) -> int:
    """Round ``n`` up to the next point of a geometric grid with
    ``2**mantissa_bits`` points per octave.  Max relative padding is
    ``2**-mantissa_bits``; the grid is what bounds the number of distinct
    compiled shapes to O(log total-size-range)."""
    n = max(int(n), minimum)
    if n <= minimum:
        return minimum
    e = n.bit_length() - 1 - mantissa_bits
    if e <= 0:
        return n
    step = 1 << e
    return _round_up(n, step)


@dataclasses.dataclass
class BucketLayout:
    """Per-shape-bucket fused-arena layout record (owned by the serve
    engine, one per request bucket).  The first batch of a bucket pins the
    chunk width per edge-type direction; chunk counts only grow (and only
    to quantized values), so batch signatures within a bucket converge —
    typically on the very first batch, worst-case after a few early growth
    steps, each of which is one extra compile."""

    chunk: Dict[Tuple[str, str], int] = dataclasses.field(
        default_factory=dict)        # (etype, "fwd"|"bwd") -> Ec
    min_chunks: Dict[Tuple[str, str], int] = dataclasses.field(
        default_factory=dict)        # (etype, "fwd"|"bwd") -> padded C
    # Relation-plan layout (DESIGN.md §9): the super-arena's SHARED chunk
    # width per direction, per-relation chunk-count floors, and the
    # quantized learnable-edge nnz floor — pinned/floored exactly like the
    # per-edge-type arenas so plan signatures converge per shape bucket.
    plan_chunk: Dict[str, int] = dataclasses.field(
        default_factory=dict)        # "fwd"|"bwd" -> Ec
    plan_min_chunks: Dict[Tuple[str, str], int] = dataclasses.field(
        default_factory=dict)        # (etype, "fwd"|"bwd") -> padded C
    min_nnz: Dict[str, int] = dataclasses.field(
        default_factory=dict)        # etype -> quantized eid-arena nnz
    # Relation tier (DESIGN.md §14): dense-vs-arena routing changes the
    # plan's dense-table SHAPES, so a tier flip mid-bucket would change the
    # graph signature.  The first batch of a bucket pins each edge type's
    # tier; later batches reuse it even if their nnz drifts across the
    # crossover — correctness is tier-independent, only speed is at stake.
    plan_tier: Dict[str, str] = dataclasses.field(
        default_factory=dict)        # etype -> "dense"|"arena"


class LayoutTable:
    """LRU table of per-shape-bucket :class:`BucketLayout` records.

    A long-lived serving loop accumulates one layout per request bucket —
    and, in the engine, one compile-cache's worth of executables per bucket.
    Under a long tail of one-off shapes that state grows without bound, so
    the table bounds it: ``get(key)`` creates-or-touches a bucket (LRU
    refresh) and, when the table exceeds ``max_live`` buckets, evicts the
    least-recently-used one, firing ``on_evict(key, layout)`` so the owner
    can release derived state (compiled executables, locks, signature
    counters).  An evicted bucket that returns starts from a fresh layout:
    its first batch re-pins chunk widths and re-floors chunk counts, i.e. it
    costs at most the bucket's original compile again (GSR-GNN's bounded
    layout-reuse property).

    ``max_live=None`` disables eviction (training-style fixed bucket sets).
    Callers serialize access themselves (the engine holds its queue lock).
    """

    def __init__(self, max_live: Optional[int] = None,
                 on_evict: Optional[Callable[[tuple, "BucketLayout"],
                                             None]] = None,
                 metrics=None, recorder=None):
        assert max_live is None or max_live >= 1, max_live
        self.max_live = max_live
        self.on_evict = on_evict
        self.evictions = 0
        # obs hooks (DESIGN.md §11): ``metrics`` (a MetricsRegistry) counts
        # layout.creates / layout.evictions; ``recorder`` annotates each
        # create/evict as an instant on the "layout" trace track.  Both
        # default to off — no observability state is touched when unset.
        self.metrics = metrics
        self.recorder = recorder
        self._table: "OrderedDict[tuple, BucketLayout]" = OrderedDict()

    def get(self, key: tuple) -> BucketLayout:
        """Layout for ``key`` (created on first use), refreshed to
        most-recently-used; may evict the LRU bucket (never ``key``)."""
        layout = self._table.get(key)
        if layout is None:
            layout = self._table[key] = BucketLayout()
            if self.metrics is not None:
                self.metrics.inc("layout.creates")
            if self.recorder is not None and self.recorder.enabled:
                self.recorder.instant("layout", "bucket_create",
                                      bucket=str(key))
        self._table.move_to_end(key)
        while self.max_live is not None and len(self._table) > self.max_live:
            k, v = self._table.popitem(last=False)
            self.evictions += 1
            if self.metrics is not None:
                self.metrics.inc("layout.evictions")
            if self.recorder is not None and self.recorder.enabled:
                self.recorder.instant("layout", "bucket_evict",
                                      bucket=str(k))
            if self.on_evict is not None:
                self.on_evict(k, v)
        return layout

    def __len__(self) -> int:
        return len(self._table)

    def __contains__(self, key: tuple) -> bool:
        return key in self._table

    def keys(self):
        return self._table.keys()


def _arena_row_cap(n_dst: int, bounds: Sequence[int], row_block: int) -> int:
    """Deterministic upper bound on a fused arena's row count: every
    non-empty destination row occupies exactly one arena row, each of the
    ≤ len(bounds)+1 degree buckets rounds its row count up to the row
    block, and the sentinel adds one more block.  It depends only on the
    *padded node count*, so every batch of a shape bucket pads its arenas
    to the same row count — node-quantization alone fixes this dimension.
    """
    return _round_up(max(n_dst, 1), row_block) + (len(bounds) + 2) * row_block


@dataclasses.dataclass(frozen=True)
class MemberSlice:
    """Where one member graph lives inside the collated node spaces."""
    cell_off: int
    n_cell: int
    net_off: int
    n_net: int


@dataclasses.dataclass
class CollatedBatch:
    """One collated dispatch unit.

    ``graph`` is a regular :class:`CircuitGraph` (padded sizes); with
    ``fused=True`` its edge sets hold pre-packed :class:`FusedELL` arenas so
    the fused executors run even when the graph is a traced jit argument.
    ``cell_weight`` holds 1/(n_real·n_cell_i) on member i's rows and 0 on
    padding — ``Σ w·(pred−y)²`` over the batch equals the mean of per-graph
    mean-MSE losses, so batched gradients match the per-graph loop.
    """

    graph: CircuitGraph
    members: Tuple[MemberSlice, ...]
    cell_weight: jax.Array          # (n_cell_pad,)
    n_real: int                     # members that carry real requests
    # with_eids collation: per-edge-type QUANTIZED edge count (the size the
    # traced weight vector is padded to — grid-bucketed so mixed streams
    # stop adding one jit entry per distinct nnz), the exact count, and
    # per-member offsets into the batch-canonical edge order.
    edge_nnz: Dict[str, int] = dataclasses.field(default_factory=dict)
    edge_nnz_exact: Dict[str, int] = dataclasses.field(default_factory=dict)
    edge_eid_offsets: Dict[str, Tuple[int, ...]] = dataclasses.field(
        default_factory=dict)

    @property
    def plan(self) -> Optional[RelationPlan]:
        """The batch graph's relation plan (``with_plan`` collation)."""
        return self.graph.plan

    def concat_edge_weights(self, etype: str, member_ws) -> jax.Array:
        """Member canonical weight vectors → the batch canonical vector.

        Member i's edges occupy ``[edge_eid_offsets[etype][i], +nnz_i)`` of
        the batch order (member node-id blocks are disjoint and increasing,
        so the batch dst-stable sort concatenates the members' canonical
        orders).  Provide one (nnz_i,) vector per member — fillers included,
        typically a reuse of the replicated member's vector.  The result is
        zero-padded up to the quantized ``edge_nnz`` (padded ids are never
        gathered, so the pad slots are inert and receive zero gradient).
        """
        assert len(member_ws) == len(self.members), \
            (len(member_ws), len(self.members))
        w = jnp.concatenate([jnp.asarray(wi) for wi in member_ws])
        exact = self.edge_nnz_exact.get(etype, self.edge_nnz[etype])
        assert w.shape[0] == exact, (w.shape[0], exact)
        pad = self.edge_nnz[etype] - exact
        if pad:
            w = jnp.concatenate([w, jnp.zeros((pad,), w.dtype)])
        return w

    def split_cell(self, y_cell) -> List[jax.Array]:
        """Per-real-member views of a per-cell output of the batched model."""
        return [y_cell[m.cell_off:m.cell_off + m.n_cell]
                for m in self.members[: self.n_real]]

    def split_net(self, y_net) -> List[jax.Array]:
        return [y_net[m.net_off:m.net_off + m.n_net]
                for m in self.members[: self.n_real]]

    @property
    def signature(self) -> tuple:
        return graph_signature(self.graph)


def graph_signature(graph: CircuitGraph) -> tuple:
    """Hashable padded-shape signature: the pytree structure (which carries
    the static fields) plus every leaf's shape/dtype.  Two graphs with equal
    signatures hit the same jit-compiled executable when passed as traced
    arguments — this is exactly jit's cache key restricted to shapes.

    Signatures are a property of the DATA alone: model depth, wiring, and
    remat (the BackboneSpec, DESIGN.md §13) never enter — a 2-layer and a
    15-layer backbone bucket identically, and flipping remat on a trainer
    or serve engine cannot invalidate collated layouts or batches
    (tests/test_backbone.py pins the independence)."""
    leaves, treedef = jax.tree_util.tree_flatten(graph)
    return (treedef,
            tuple((tuple(l.shape), np.dtype(l.dtype).name) for l in leaves))


# Shape-bucket-stable arena padding now lives with the packers
# (graphs/ell.py::pad_fused_arena) so the relation-plan builder shares it;
# kept under the historical private name for this module's call sites.
_pad_fused_arena = pad_fused_arena


def _chunk_for(chunk, etype: str) -> Optional[int]:
    if isinstance(chunk, dict):
        return chunk.get(etype)
    return chunk


def collate_graphs(graphs: Sequence[CircuitGraph], *,
                   fused: bool = True,
                   quantize: bool = True,
                   node_bits: int = NODE_GRID_BITS,
                   arena_bits: int = ARENA_GRID_BITS,
                   chunk: Union[None, int, Dict[str, int]] = None,
                   layout: Optional[BucketLayout] = None,
                   n_real: Optional[int] = None,
                   with_eids: bool = False,
                   with_plan: Optional[bool] = None,
                   bounds: Sequence[int] = DEFAULT_BOUNDS) -> CollatedBatch:
    """Merge member graphs into one block-diagonal :class:`CircuitGraph`.

    Parameters
    ----------
    fused : pre-pack each edge-type direction as a :class:`FusedELL` arena
        (the serve/train hot path — fused executors run with the graph
        traced).  ``False`` packs plain :class:`BucketedELL` pairs: the
        exact block-diagonal graph, usable under every backend (parity
        tests).
    quantize : pad member node slabs and (with ``fused``) arena dims up the
        bucket grid; ``False`` gives the exact-size collation.
    chunk : pin the fused arenas' chunk width (int, or per-edge-type dict);
        ``None`` lets ``fuse_bucketed`` pick per packing from the degree
        histogram.
    layout : mutable per-shape-bucket record (:class:`BucketLayout`): pins
        chunk widths to the bucket's first batch and floors chunk counts at
        the bucket's running max, so same-bucket batches share a signature.
    n_real : members that carry real requests; trailing members are filler
        (their outputs are dropped and their loss weight is zero).
    with_eids : additionally attach a batch-canonical edge-id arena to every
        fused edge direction (member eids offset by the preceding members'
        edge counts), so the collated batch can carry learnable per-edge
        weights through ``ops.drspmm_learnable`` — the batch weight vector
        is the concatenation of the members' canonical vectors
        (:meth:`CollatedBatch.concat_edge_weights`).  Requires ``fused``.
        With ``quantize``, the per-edge-type nnz is rounded up the arena
        grid (and floored at the bucket's running max when a ``layout``
        tracks it): the traced weight vector is zero-padded to that size,
        so mixed learnable-weight streams add one jit entry per GRID POINT
        instead of one per distinct nnz.
    with_plan : attach a :class:`RelationPlan` super-arena pair to the
        collated graph (``batch.graph.plan``) so hetero layers run ONE
        dispatch per direction-group even with the graph traced
        (DESIGN.md §9).  Defaults to ``fused``; the plan's per-relation
        segments are quantized/floored under the same ``layout`` as the
        per-edge-type arenas, so plan signatures are bucket-stable.
    """
    assert graphs, "collate_graphs needs at least one member"
    n_real = len(graphs) if n_real is None else n_real
    f_cell = graphs[0].x_cell.shape[1]
    f_net = graphs[0].x_net.shape[1]
    assert all(g.x_cell.shape[1] == f_cell and g.x_net.shape[1] == f_net
               for g in graphs), "members must share feature widths"

    # --- member slabs (per-member padding keeps offsets deterministic
    # within a shape bucket: the batch signature depends only on the
    # members' quantized sizes, not their exact ones) ---
    members, cell_off, net_off = [], 0, 0
    for g in graphs:
        members.append(MemberSlice(cell_off=cell_off, n_cell=g.n_cell,
                                   net_off=net_off, n_net=g.n_net))
        cell_off += quantize_up(g.n_cell, node_bits) if quantize else g.n_cell
        net_off += quantize_up(g.n_net, node_bits) if quantize else g.n_net
    n_cell_pad, n_net_pad = cell_off, net_off
    sizes_pad = {"cell": n_cell_pad, "net": n_net_pad}

    # --- features / labels / loss weights ---
    x_cell = np.zeros((n_cell_pad, f_cell), np.float32)
    x_net = np.zeros((n_net_pad, f_net), np.float32)
    y_cell = np.zeros(n_cell_pad, np.float32)
    w_cell = np.zeros(n_cell_pad, np.float32)
    for i, (g, m) in enumerate(zip(graphs, members)):
        x_cell[m.cell_off:m.cell_off + m.n_cell] = np.asarray(g.x_cell)
        x_net[m.net_off:m.net_off + m.n_net] = np.asarray(g.x_net)
        y_cell[m.cell_off:m.cell_off + m.n_cell] = np.asarray(g.y_cell)
        if i < n_real:
            w_cell[m.cell_off:m.cell_off + m.n_cell] = \
                1.0 / (n_real * m.n_cell)

    # --- merged COO per edge type, member weights carried through ---
    assert not (with_eids and not fused), "with_eids requires fused collation"
    if with_plan is None:
        with_plan = fused
    assert not (with_plan and not fused), "with_plan requires fused collation"
    off_of = {"cell": [m.cell_off for m in members],
              "net": [m.net_off for m in members]}
    edges = {}
    coo_of: Dict[str, tuple] = {}
    bucketed_of: Dict[str, tuple] = {}
    edge_nnz: Dict[str, int] = {}
    edge_nnz_exact: Dict[str, int] = {}
    edge_eid_offsets: Dict[str, Tuple[int, ...]] = {}
    for et in EDGE_TYPES:
        s_t, d_t = EDGE_SCHEMA[et]
        ds, ss, ws, m_nnz = [], [], [], []
        for i, g in enumerate(graphs):
            dst, src, w = ell_to_coo(g.edges[et].adj)
            ds.append(dst + off_of[d_t][i])
            ss.append(src + off_of[s_t][i])
            ws.append(w)
            m_nnz.append(int(dst.shape[0]))
        dst = np.concatenate(ds)
        src = np.concatenate(ss)
        w = np.concatenate(ws)
        n_dst, n_src = sizes_pad[d_t], sizes_pad[s_t]
        coo_of[et] = (dst, src, w)
        if fused:
            # one degree-bucketed pack per direction, SHARED by the
            # per-edge-type arena and the relation plan (fusing at each
            # consumer's chunk width is memoized per (packing, width))
            bucketed = {"fwd": pack_ell(dst, src, w, n_dst, n_src, bounds),
                        "bwd": pack_ell(src, dst, w, n_src, n_dst, bounds)}
            bucketed_of[et] = (bucketed["fwd"], bucketed["bwd"])
            packed = {}
            for dname in ("fwd", "bwd"):
                ck = layout.chunk.get((et, dname)) if layout else None
                if ck is None:
                    ck = _chunk_for(chunk, et)
                a = fuse_bucketed(bucketed[dname], chunk=ck)
                if layout is not None:
                    layout.chunk.setdefault((et, dname), a.chunk)
                # Pack-time arena efficiency gauges (DESIGN.md §11): cheap
                # — static fields and bucket shapes only, no array scans —
                # and labeled by (etype, dir), a bounded cardinality.
                st = arena_stats(a, bucketed[dname])
                for gname in ("fill_ratio", "padded_slots", "slots",
                              "chunk", "slot_saving"):
                    _METRICS.set(f"arena.{gname}", st[gname],
                                 etype=et, dir=dname)
                if quantize:
                    a = _quantize_arena(a, arena_bits, bounds, layout,
                                        (et, dname))
                packed[dname] = a
            if with_eids:
                # Batch-canonical edge ids: member node-id blocks are
                # disjoint and increasing, so the batch dst-stable sort is
                # the concatenation of the members' canonical orders —
                # member i's ids are its own canonical ids + Σ_{j<i} nnz_j.
                # Member weights are all non-zero (ell_to_coo masks), so the
                # eid packing sorts/chunks identically to the weight packing
                # and the eid table drops straight onto the weight arena.
                efwd, ebwd, _order, et_nnz = pack_fused_eid_pair(
                    dst, src, n_dst, n_src, bounds,
                    chunk=(packed["fwd"].chunk, packed["bwd"].chunk))
                for dname, ea in (("fwd", efwd), ("bwd", ebwd)):
                    a = packed[dname]
                    if quantize:
                        ea = _pad_fused_arena(ea, a.n_chunks,
                                              a.n_arena_rows)
                    assert ea.nbr.shape == a.nbr.shape, (et, dname)
                    packed[dname] = dataclasses.replace(
                        a, eid=np.asarray(ea.eid))
                # Shape-bucketed nnz (ROADMAP): the learnable weight vector
                # is a TRACED operand sized by nnz, so a distinct nnz per
                # batch means one jit entry per batch.  Round it up the
                # arena grid (floored at the bucket's running max) and let
                # concat_edge_weights zero-pad — padded ids are never
                # gathered, so the pad slots are inert.
                nnz_pad = et_nnz
                if quantize:
                    nnz_pad = quantize_up(et_nnz, arena_bits, minimum=8)
                    if layout is not None:
                        floor = layout.min_nnz.get(et)
                        if floor is None:   # first batch: headroom, like
                            floor = quantize_up(   # the chunk-count floors
                                int(np.ceil(et_nnz * ARENA_HEADROOM)),
                                arena_bits, minimum=8)
                        nnz_pad = max(nnz_pad, floor)
                        layout.min_nnz[et] = nnz_pad
                edge_nnz[et] = nnz_pad
                edge_nnz_exact[et] = et_nnz
                edge_eid_offsets[et] = tuple(
                    int(o) for o in np.cumsum([0] + m_nnz[:-1]))
            adj, adj_t = packed["fwd"], packed["bwd"]
        else:
            adj, adj_t = pack_ell_pair(dst, src, w, n_dst, n_src, bounds)
        edges[et] = EdgeSet(adj=adj, adj_t=adj_t)

    plan = None
    if with_plan:
        plan = _build_batch_plan(coo_of, bucketed_of, sizes_pad, quantize,
                                 arena_bits, layout, bounds)
    graph = CircuitGraph(n_cell=n_cell_pad, n_net=n_net_pad, edges=edges,
                         x_cell=jnp.asarray(x_cell), x_net=jnp.asarray(x_net),
                         y_cell=jnp.asarray(y_cell), plan=plan)
    return CollatedBatch(graph=graph, members=tuple(members),
                         cell_weight=jnp.asarray(w_cell), n_real=n_real,
                         edge_nnz=edge_nnz, edge_nnz_exact=edge_nnz_exact,
                         edge_eid_offsets=edge_eid_offsets)


def _build_batch_plan(coo_of: Dict[str, tuple],
                      bucketed_of: Dict[str, tuple],
                      sizes_pad: Dict[str, int],
                      quantize: bool, arena_bits: int,
                      layout: Optional[BucketLayout],
                      bounds: Sequence[int]) -> RelationPlan:
    """RelationPlan over the batch's merged edge sets, quantized for
    signature stability: the super-arena's shared chunk width per direction
    is pinned to the bucket's first batch (``BucketLayout.plan_chunk``) and
    every relation segment's chunk count is padded up the arena grid and
    floored at the bucket's running max (``plan_min_chunks``) — the same
    discipline ``_quantize_arena`` applies to the per-edge-type arenas.
    Row counts take the deterministic cap, so they never vary in-bucket."""
    relations = [(et,) + EDGE_SCHEMA[et] + coo_of[et]
                 for et in EDGE_TYPES if et in coo_of]
    chunk = None
    if layout is not None and layout.plan_chunk:
        chunk = (layout.plan_chunk.get("fwd"), layout.plan_chunk.get("bwd"))

    pad = None
    if quantize:
        def pad(et, dname, arena):
            r_cap = _arena_row_cap(arena.n_dst, bounds, arena.row_block)
            c_pad = quantize_up(arena.n_chunks, arena_bits, minimum=1)
            if layout is not None:
                floor = layout.plan_min_chunks.get((et, dname))
                if floor is None:   # first batch of the bucket: headroom
                    floor = quantize_up(
                        int(np.ceil(arena.n_chunks * ARENA_HEADROOM)),
                        arena_bits, minimum=1)
                c_pad = max(c_pad, floor)
                layout.plan_min_chunks[(et, dname)] = c_pad
            return c_pad, r_cap

    # Tier pinning (DESIGN.md §14): classify each edge type from the
    # batch's EXACT merged-COO nnz (padded plan arenas reset ``nnz``, so
    # build_relation_plan's own count would see the padded slab) against
    # the padded type sizes, then pin the FIRST batch's verdict per bucket
    # — a tier flip changes dense-table shapes, hence the signature.
    tiers = None
    if layout is not None:
        for et, st, dt, dst, _src, _w in relations:
            area = int(sizes_pad[dt]) * int(sizes_pad[st])
            t = ("dense" if (int(dst.shape[0]) <= DENSE_TIER_NNZ
                             and area <= DENSE_TIER_AREA) else "arena")
            layout.plan_tier.setdefault(et, t)
        tiers = dict(layout.plan_tier)

    plan = build_relation_plan(relations, sizes_pad, bounds=bounds,
                               chunk=chunk, pad=pad,
                               packed=bucketed_of or None, tiers=tiers)
    if layout is not None:
        layout.plan_chunk.setdefault("fwd", plan.fwd.chunk)
        layout.plan_chunk.setdefault("bwd", plan.bwd.chunk)
    # Super-arena efficiency gauges: real slots are the summed ARENA-tier
    # relation edge counts (known from the merged COO — padded plan arenas
    # reset ``nnz``, and scanning the arena per batch would not be cheap).
    # Dense-tier relations occupy no arena slots.
    arena_ets = {s.etype for s in plan.arena_segments}
    real = sum(int(r[3].shape[0]) for r in relations if r[0] in arena_ets)
    for dname, arena in (("fwd", plan.fwd), ("bwd", plan.bwd)):
        c, br, ec = (int(s) for s in np.shape(arena.nbr))
        slots = c * br * ec
        _METRICS.set("arena.slots", slots, etype="__plan__", dir=dname)
        _METRICS.set("arena.padded_slots", slots - real,
                     etype="__plan__", dir=dname)
        _METRICS.set("arena.fill_ratio", real / slots if slots else 0.0,
                     etype="__plan__", dir=dname)
        _METRICS.set("arena.chunk", ec, etype="__plan__", dir=dname)
    return plan


def _quantize_arena(f: FusedELL, arena_bits: int, bounds: Sequence[int],
                    layout: Optional[BucketLayout],
                    key: Tuple[str, str]) -> FusedELL:
    """Pad the arena to shape-bucket-stable dims: rows to the deterministic
    cap (a function of the padded node count alone), chunks up the bucket
    grid, floored at the bucket's running max when a layout is tracking."""
    r_cap = _arena_row_cap(f.n_dst, bounds, f.row_block)
    assert f.n_arena_rows <= r_cap, (f.n_arena_rows, r_cap)
    c_pad = quantize_up(f.n_chunks, arena_bits, minimum=1)
    if layout is not None:
        floor = layout.min_chunks.get(key)
        if floor is None:       # first batch of the bucket: add headroom
            floor = quantize_up(int(np.ceil(f.n_chunks * ARENA_HEADROOM)),
                                arena_bits, minimum=1)
        c_pad = max(c_pad, floor)
        layout.min_chunks[key] = c_pad
    return _pad_fused_arena(f, c_pad, r_cap)
