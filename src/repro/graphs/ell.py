"""Degree-bucketed ELL adjacency — the TPU analogue of dynamic warp partitioning.

The paper (Alg. 1, stage 2) classifies neighbor groups (rows) by degree and
partitions warps accordingly so that evil rows do not stall a whole warp.  On
TPU the execution unit is a Pallas grid cell over a *statically shaped* tile,
so the equivalent move is structural: bin rows by degree, pad each bin to its
own max degree (ELL), and dispatch each bin as its own kernel grid with a
block shape tuned to that bin.  Short rows never pay for evil rows' padding,
and evil rows get wide, deep tiles.

Two packings live here:

* :class:`BucketedELL` — one slab per degree bucket, dispatched as one
  ``pallas_call`` each (the reference per-bucket path).
* :class:`FusedELL` — all bucket slabs re-chunked into a single uniform
  chunk arena plus a per-chunk metadata table, so the *entire* bucketed
  aggregation runs as ONE ``pallas_call`` (DESIGN.md §1).  Output rows are
  laid out arena-contiguously; a single inverse-permutation gather replaces
  the per-bucket ``y.at[rows].add`` combine.

All packing is host-side numpy (one-time preprocessing, matching the paper's
CSR/CSC preprocessing stage).
"""

from __future__ import annotations

import dataclasses
import weakref
from typing import Dict, Sequence, Tuple, Union

import numpy as np
import jax
import jax.numpy as jnp

# Row-block granularity of the Pallas grid; bucket row counts are padded to it.
ROW_BLOCK = 8
# Default degree-bucket upper bounds (inclusive); last bucket is open-ended.
DEFAULT_BOUNDS = (4, 16, 64, 256)
# Neighbor-chunk width of the fused arena: each fused grid step contracts
# EDGE_CHUNK neighbors at once (an (BR, Ec·k) × (BR, Ec·k, D) MXU issue).
# 8 × k=16 = 128 = one MXU contraction dim; small enough that narrow rows
# (pin/pinned fan-outs of 2–6) waste at most one chunk of padding.
# This is the *fallback* width: ``fuse_bucketed`` picks the slot-minimizing
# width per packing from its degree histogram (``pick_chunk``) unless the
# caller pins one explicitly.
EDGE_CHUNK = 8
# Candidate chunk widths ``pick_chunk`` chooses between.  Powers of two so
# Ec·k stays MXU-aligned for the usual k ∈ {8, 16, 32}.
CHUNK_CANDIDATES = (4, 8, 16)
# Row-block height of the fused arena.  Kept at the Pallas grid granularity:
# the degree-sort makes a block's chunk count track the max width of just
# these 8 rows, so smaller blocks mean tighter adaptive widths.
FUSED_ROW_BLOCK = 8


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ELLBucket:
    """One degree bin: ``rows[r]`` is the destination row that ``nbr[r]``
    describes.  Padded neighbor slots have weight 0 and index 0; padded row
    slots have ``rows == 0`` and all-zero weights (inert under scatter-add).
    """

    rows: jax.Array   # (R,) int32 destination row ids
    nbr: jax.Array    # (R, E) int32 source ids
    w: jax.Array      # (R, E) float edge weights

    @property
    def n_rows(self) -> int:
        return self.rows.shape[0]

    @property
    def width(self) -> int:
        return self.nbr.shape[1]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BucketedELL:
    """A sparse (n_dst x n_src) matrix as a tuple of degree-bucketed ELL slabs.

    ``nnz`` is counted once at pack time (host-side) and stored as a static
    field — reading it never forces a device→host sync.  ``-1`` means the
    packing predates the count (hand-built instances); consumers treat that
    as unknown.
    """

    buckets: Tuple[ELLBucket, ...]
    n_dst: int = dataclasses.field(metadata=dict(static=True))
    n_src: int = dataclasses.field(metadata=dict(static=True))
    nnz: int = dataclasses.field(metadata=dict(static=True), default=-1)

    def to_dense(self) -> jax.Array:
        a = jnp.zeros((self.n_dst, self.n_src), jnp.float32)
        for b in self.buckets:
            r = jnp.repeat(b.rows[:, None], b.width, axis=1)
            a = a.at[r, b.nbr].add(b.w)
        return a


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pack_ell(dst: np.ndarray, src: np.ndarray, w: np.ndarray | None,
             n_dst: int, n_src: int,
             bounds: Sequence[int] = DEFAULT_BOUNDS,
             row_block: int = ROW_BLOCK) -> BucketedELL:
    """Pack COO edges into degree-bucketed ELL.

    Parameters
    ----------
    dst, src : int arrays (nnz,) — edge endpoints (dst aggregates from src).
    w : float array (nnz,) or None for unit weights.
    bounds : inclusive degree upper bounds for all but the last bucket.
    """
    dst = np.asarray(dst, np.int64)
    src = np.asarray(src, np.int64)
    if w is None:
        w = np.ones(dst.shape[0], np.float32)
    w = np.asarray(w, np.float32)

    # CSR-ify (stage 1 of Alg. 1).
    order = np.argsort(dst, kind="stable")
    dst, src, w = dst[order], src[order], w[order]
    deg = np.bincount(dst, minlength=n_dst)
    rowptr = np.zeros(n_dst + 1, np.int64)
    np.cumsum(deg, out=rowptr[1:])

    # Stage 2: classify rows by degree.  Empty rows are dropped entirely.
    nonempty = np.nonzero(deg > 0)[0]
    edges_of = lambda r: slice(rowptr[r], rowptr[r + 1])

    buckets = []
    nnz = 0
    lo = 1
    bnds = list(bounds) + [int(deg.max()) if deg.size and deg.max() > 0 else 1]
    for hi in bnds:
        if hi < lo:
            continue
        rows = nonempty[(deg[nonempty] >= lo) & (deg[nonempty] <= hi)]
        lo = hi + 1
        if rows.size == 0:
            continue
        width = int(deg[rows].max())
        n_r = _round_up(rows.size, row_block)
        nbr = np.zeros((n_r, width), np.int32)
        wts = np.zeros((n_r, width), np.float32)
        rid = np.zeros(n_r, np.int32)
        rid[: rows.size] = rows
        for i, r in enumerate(rows):
            sl = edges_of(r)
            d = rowptr[r + 1] - rowptr[r]
            nbr[i, :d] = src[sl]
            wts[i, :d] = w[sl]
        nnz += int((wts != 0).sum())
        buckets.append(ELLBucket(rows=jnp.asarray(rid), nbr=jnp.asarray(nbr),
                                 w=jnp.asarray(wts)))
    if not buckets:  # empty matrix — keep one inert bucket for shape sanity
        buckets = [ELLBucket(rows=jnp.zeros((row_block,), jnp.int32),
                             nbr=jnp.zeros((row_block, 1), jnp.int32),
                             w=jnp.zeros((row_block, 1), jnp.float32))]
    return BucketedELL(buckets=tuple(buckets), n_dst=n_dst, n_src=n_src,
                       nnz=nnz)


def pack_eid_slabs(dst: np.ndarray, src: np.ndarray, n_dst: int, n_src: int,
                   bounds: Sequence[int] = DEFAULT_BOUNDS):
    """Edge-id slabs aligned with :func:`pack_ell`'s bucketing.

    Packs edge *indices* (into the canonical dst-stable-sorted edge order)
    instead of weights, with ``nnz`` as the padding sentinel.  Lets a
    learnable weight vector w (nnz,) be gathered into the exact slab layout
    pack_ell produces — the basis of differentiable edge weights
    (kernels/learnable.py).  Returns (fwd_slabs, bwd_slabs, order, nnz):
    slabs are BucketedELL whose ``w`` holds f32-encoded edge ids (exact up
    to 2^24 edges); ``order`` maps the canonical order back to the caller's
    COO order.
    """
    dst = np.asarray(dst, np.int64)
    src = np.asarray(src, np.int64)
    nnz = dst.shape[0]
    assert nnz < (1 << 24), "edge ids exceed f32 exact-integer range"
    order = np.argsort(dst, kind="stable")           # pack_ell's canonical
    eid = np.empty(nnz, np.int64)
    eid[order] = np.arange(nnz)                      # caller-order -> canon
    fwd = pack_ell(dst, src, eid.astype(np.float32) + 1.0, n_dst, n_src,
                   bounds)
    bwd = pack_ell(src, dst, eid.astype(np.float32) + 1.0, n_src, n_dst,
                   bounds)
    # ids stored +1 so padding (0.0) maps to sentinel −1 after decode
    return fwd, bwd, order, nnz


def decode_eids(slab_w) -> "jax.Array":
    """f32-encoded (id+1) slab -> int32 ids with −1 padding sentinel."""
    return (slab_w.astype(jnp.int32)) - 1


def pack_ell_pair(dst: np.ndarray, src: np.ndarray, w: np.ndarray | None,
                  n_dst: int, n_src: int,
                  bounds: Sequence[int] = DEFAULT_BOUNDS
                  ) -> Tuple[BucketedELL, BucketedELL]:
    """Forward (A, row-major over dst) and backward (Aᵀ, row-major over src)
    packings — the CSR/CSC pair of Alg. 1/Alg. 2.  The transposed packing
    makes every *source* row owned by exactly one grid cell, so the backward
    needs no atomics (see DESIGN.md §2)."""
    fwd = pack_ell(dst, src, w, n_dst, n_src, bounds)
    bwd = pack_ell(src, dst, w, n_src, n_dst, bounds)
    return fwd, bwd


def degree_stats(dst: np.ndarray, n_dst: int) -> dict:
    deg = np.bincount(np.asarray(dst, np.int64), minlength=n_dst)
    return dict(degrees=deg, max=int(deg.max()) if deg.size else 0,
                mean=float(deg.mean()) if deg.size else 0.0)


def ell_to_coo(adj: BucketedELL) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host-side inverse of :func:`pack_ell`: (dst, src, w) of the non-zero
    slots.  Zero-weight slots are padding by construction, so the round trip
    preserves exactly the ``nnz`` edges the packing represents.  Used by the
    block-diagonal collator (graphs/collate.py), which re-packs member
    graphs' edges with per-member node-id offsets."""
    ds, ss, ws = [], [], []
    for b in adj.buckets:
        w = np.asarray(b.w, np.float32)
        mask = w != 0
        if not mask.any():
            continue
        rows = np.broadcast_to(np.asarray(b.rows, np.int64)[:, None], w.shape)
        ds.append(rows[mask])
        ss.append(np.asarray(b.nbr, np.int64)[mask])
        ws.append(w[mask])
    if not ds:
        return (np.zeros(0, np.int64), np.zeros(0, np.int64),
                np.zeros(0, np.float32))
    return np.concatenate(ds), np.concatenate(ss), np.concatenate(ws)


# ---------------------------------------------------------------------------
# FusedELL — single-dispatch arena packing (DESIGN.md §1)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FusedELL:
    """All degree buckets re-chunked into one uniform (C, BR, Ec) arena.

    Every chunk holds ``row_block`` rows × ``chunk`` neighbor slots of ONE
    bucket's ELL slab; zero-weight slots are inert padding.  Chunks of the
    same output row-block are stored consecutively, so a Pallas grid over
    chunks revisits each output block in an unbroken run — the grouped-matmul
    accumulation pattern that needs no atomics and no host-side combine.

    ``block_of``/``start`` are the scalar-prefetch metadata table: the output
    row-block each chunk accumulates into, and whether the chunk opens its
    block (→ zero-init).  ``rows`` maps arena rows back to original row ids
    (padding → 0 with zero weights); ``gather`` is the inverse map used to
    read the final (n_dst, D) output out of the arena with ONE gather —
    original rows absent from every bucket point at the trailing sentinel
    block, which is written as all-zeros.
    """

    nbr: jax.Array       # (C, BR, Ec) int32 source ids
    w: jax.Array         # (C, BR, Ec) f32 edge weights (0 = padding)
    block_of: jax.Array  # (C,) int32 output row-block per chunk
    start: jax.Array     # (C,) int32 1 iff chunk opens its row-block
    rows: jax.Array      # (R_arena,) int32 original row per arena row
    gather: jax.Array    # (n_dst,) int32 arena row per original row
    n_dst: int = dataclasses.field(metadata=dict(static=True))
    n_src: int = dataclasses.field(metadata=dict(static=True))
    nnz: int = dataclasses.field(metadata=dict(static=True))
    row_block: int = dataclasses.field(metadata=dict(static=True))
    chunk: int = dataclasses.field(metadata=dict(static=True))
    # Edge-id arena for learnable per-edge weights (kernels/ops.py::
    # drspmm_learnable): (C, BR, Ec) int32 canonical edge ids, padding
    # slots -> −1.  Chunked exactly like ``w``, so a canonical weight
    # vector (nnz,) gathers straight into arena layout.  ``None`` for
    # fixed-weight packings.
    eid: jax.Array | None = None

    @property
    def n_chunks(self) -> int:
        return self.nbr.shape[0]

    @property
    def n_arena_rows(self) -> int:
        return self.rows.shape[0]

    def to_dense(self) -> np.ndarray:
        """Host-side dense reconstruction (round-trip tests)."""
        a = np.zeros((self.n_dst, self.n_src), np.float32)
        nbr = np.asarray(self.nbr)
        w = np.asarray(self.w)
        blk = np.asarray(self.block_of)
        rows = np.asarray(self.rows)
        br = self.row_block
        for c in range(nbr.shape[0]):
            for b in range(br):
                rid = rows[blk[c] * br + b]
                mask = w[c, b] != 0
                np.add.at(a[rid], nbr[c, b][mask], w[c, b][mask])
        return a


# id-keyed memo: fusing is host-side numpy work we only want once per packing.
_FUSE_CACHE: Dict[tuple, tuple] = {}


def _effective_widths(w: np.ndarray) -> np.ndarray:
    """Per-row count of slots up to the last non-zero one (pack_ell fills
    rows left-to-right, so this is the row's effective degree)."""
    nz = w != 0
    e = w.shape[1]
    return np.where(nz.any(axis=1), e - np.argmax(nz[:, ::-1], axis=1), 0)


def _block_widths(adj: BucketedELL, row_block: int) -> list:
    """Max effective width of each fused row-block, after the descending
    degree sort each bucket undergoes inside :func:`fuse_bucketed` — i.e.
    exactly the widths the arena's chunk counts are derived from."""
    bws = []
    for b in adj.buckets:
        width_r = np.sort(_effective_widths(np.asarray(b.w, np.float32)))[::-1]
        rpad = _round_up(max(width_r.size, 1), row_block)
        width_r = np.concatenate(
            [width_r, np.zeros(rpad - width_r.size, np.int64)])
        for t in range(rpad // row_block):
            bws.append(int(width_r[t * row_block:(t + 1) * row_block]
                           .max(initial=0)))
    return bws


def pick_chunk(adj: BucketedELL, row_block: int = None,
               candidates: Sequence[int] = CHUNK_CANDIDATES) -> int:
    """Slot-minimizing arena chunk width for this packing (ROADMAP item).

    ``EDGE_CHUNK = 8`` is tuned for the heavy-tailed ``near`` degrees; the
    narrow ``pin``/``pinned`` fan-outs (2–6) pay up to 2× slot padding at
    width 8.  This picks, from the packing's own degree histogram, the
    candidate minimizing total arena slots Σ_blocks BR·Ec·ceil(bw/Ec); ties
    go to the wider chunk (fewer grid steps, bigger MXU contractions).
    """
    if row_block is None:
        row_block = FUSED_ROW_BLOCK
    bws = _block_widths(adj, row_block)

    def slots(c):
        return sum(row_block * c * max(1, -(-bw // c)) for bw in bws)

    return min(candidates, key=lambda c: (slots(c), -c))


def fuse_bucketed(adj: BucketedELL, row_block: int = None,
                  chunk: int = None, *, eids: bool = False) -> FusedELL:
    """Re-pack a :class:`BucketedELL` into the single-dispatch fused arena.

    ``chunk=None`` picks the slot-minimizing width from the packing's degree
    histogram (:func:`pick_chunk`); pass an int to pin the layout (the
    collator does, so batches of the same shape bucket share a signature).

    ``eids=True`` treats ``adj`` as an edge-id slab packing
    (:func:`pack_eid_slabs` layout: ``w`` holds f32-encoded ``id+1``,
    0 = padding).  The arena then carries a decoded int32 ``eid`` table
    (padding slots → −1) chunked exactly like the weight arena, and ``w``
    becomes the 0/1 real-slot mask — the layout the fused learnable
    executors (kernels/drspmm.py) gather a canonical weight vector into.

    Pure host-side preprocessing; results are memoized per (packing, layout)
    so jit re-traces and repeated layer calls never re-pack.
    """
    if row_block is None:
        row_block = FUSED_ROW_BLOCK
    # chunk=None is memoized under the None key, so a cache hit skips even
    # the pick_chunk histogram scan.
    key = (id(adj), row_block, chunk, eids)
    hit = _FUSE_CACHE.get(key)
    if hit is not None and hit[0]() is adj:
        return hit[1]
    if chunk is None:
        chunk = pick_chunk(adj, row_block)

    nbr_chunks, w_chunks, block_of, start = [], [], [], []
    rows_parts = []
    gather = np.full(adj.n_dst, -1, np.int64)
    blk = 0
    arena_off = 0
    for b in adj.buckets:
        nb = np.asarray(b.nbr)
        wt = np.asarray(b.w, np.float32)
        rid = np.asarray(b.rows, np.int64)
        r, e = nb.shape
        rpad = _round_up(max(r, 1), row_block)
        epad = _round_up(max(e, 1), chunk)
        nb_p = np.zeros((rpad, epad), np.int32)
        wt_p = np.zeros((rpad, epad), np.float32)
        nb_p[:r, :e] = nb
        wt_p[:r, :e] = wt
        rid_p = np.zeros(rpad, np.int32)
        rid_p[:r] = rid
        # Effective row width = last carried weight (pack_ell fills rows
        # left-to-right; zero-weight slots contribute nothing either way).
        nz = wt_p != 0
        width_r = np.where(nz.any(axis=1),
                           epad - np.argmax(nz[:, ::-1], axis=1), 0)
        # Finer-than-bucket adaptivity: order rows by effective width so
        # each row-block's chunk count tracks its OWN max degree, not the
        # bucket's.  A degree-17 row in a width-64 bucket then costs
        # ceil(17/Ec) chunks instead of the whole slab (DESIGN.md §1.2).
        order = np.argsort(-width_r, kind="stable")
        nb_p, wt_p, rid_p, width_r = (nb_p[order], wt_p[order],
                                      rid_p[order], width_r[order])
        # A row is "real" iff it carries any weight; all-zero rows produce
        # all-zero output either way, so routing them to the sentinel is
        # equivalent (DESIGN.md §1.3).
        real = width_r > 0
        gather[rid_p[real]] = arena_off + np.nonzero(real)[0]
        rows_parts.append(rid_p)
        arena_off += rpad
        for t in range(rpad // row_block):
            sl = slice(t * row_block, (t + 1) * row_block)
            bw = int(width_r[sl].max(initial=0))
            nch = max(1, -(-bw // chunk))            # ≥1 so the block inits
            for ci in range(nch):
                cs = slice(ci * chunk, (ci + 1) * chunk)
                nbr_chunks.append(nb_p[sl, cs])
                w_chunks.append(wt_p[sl, cs])
                block_of.append(blk)
                start.append(1 if ci == 0 else 0)
            blk += 1

    # Trailing sentinel block: BR guaranteed-zero arena rows that empty
    # original rows gather from.
    nbr_chunks.append(np.zeros((row_block, chunk), np.int32))
    w_chunks.append(np.zeros((row_block, chunk), np.float32))
    block_of.append(blk)
    start.append(1)
    sentinel_row = arena_off
    rows_parts.append(np.zeros(row_block, np.int32))
    gather[gather < 0] = sentinel_row

    nnz = adj.nnz if adj.nnz >= 0 else int(
        sum(int((np.asarray(b.w) != 0).sum()) for b in adj.buckets))
    w_arena = np.stack(w_chunks)
    eid_arena = None
    if eids:
        # w slots hold f32(id+1) with 0 padding (exact up to 2^24 edges,
        # asserted at pack time): decode to −1-padded int32 ids and leave
        # the 0/1 real-slot mask as the arena weight.
        eid_arena = w_arena.astype(np.int32) - 1
        w_arena = (w_arena != 0).astype(np.float32)
    # NB: leaves stay host numpy — fusing may run lazily inside a jit trace
    # (first call of a jitted layer), where jnp.asarray would capture
    # tracers into the memo and leak them out of the trace.  numpy leaves
    # are trace-safe constants.
    fused = FusedELL(
        nbr=np.stack(nbr_chunks),
        w=w_arena,
        block_of=np.asarray(block_of, np.int32),
        start=np.asarray(start, np.int32),
        rows=np.concatenate(rows_parts).astype(np.int32),
        gather=gather.astype(np.int32),
        n_dst=adj.n_dst, n_src=adj.n_src, nnz=nnz,
        row_block=row_block, chunk=chunk, eid=eid_arena)
    # Evict promptly when the packing dies — a dead entry would otherwise
    # pin its whole fused arena (id reuse is also why the hit path
    # re-checks `ref() is adj`).
    _FUSE_CACHE[key] = (weakref.ref(adj, lambda _: _FUSE_CACHE.pop(key, None)),
                        fused)
    return fused


def pack_fused(dst: np.ndarray, src: np.ndarray, w: np.ndarray | None,
               n_dst: int, n_src: int,
               bounds: Sequence[int] = DEFAULT_BOUNDS,
               row_block: int = None,
               chunk: int = None) -> FusedELL:
    """COO → fused single-dispatch arena (pack_ell then fuse)."""
    return fuse_bucketed(pack_ell(dst, src, w, n_dst, n_src, bounds),
                         row_block=row_block, chunk=chunk)


def pack_fused_pair(dst: np.ndarray, src: np.ndarray, w: np.ndarray | None,
                    n_dst: int, n_src: int,
                    bounds: Sequence[int] = DEFAULT_BOUNDS
                    ) -> Tuple[FusedELL, FusedELL]:
    """Fused forward/transposed pair (the CSR/CSC analogue of Alg. 1/2)."""
    return (pack_fused(dst, src, w, n_dst, n_src, bounds),
            pack_fused(src, dst, w, n_src, n_dst, bounds))


def pack_fused_eid_pair(dst: np.ndarray, src: np.ndarray,
                        n_dst: int, n_src: int,
                        bounds: Sequence[int] = DEFAULT_BOUNDS,
                        row_block: int = None,
                        chunk: Union[int, None, Tuple] = None
                        ) -> Tuple[FusedELL, FusedELL, np.ndarray, int]:
    """Fused edge-id arena pair for learnable per-edge weights.

    The eid analogue of :func:`pack_fused_pair`: packs edge *indices* (into
    the canonical dst-stable-sorted order, :func:`pack_eid_slabs`) and fuses
    both directions into arenas carrying ``eid`` tables (−1 padding), so a
    learnable weight vector w (nnz,) gathers straight into arena layout on
    the single-dispatch path (kernels/ops.py::drspmm_learnable).

    ``chunk`` pins the arena chunk width: an int for both directions, or a
    ``(fwd, bwd)`` tuple (the collator pins per direction).  Returns
    ``(fwd_arena, bwd_arena, order, nnz)`` with ``order`` mapping the
    canonical order back to the caller's COO order.
    """
    fwd, bwd, order, nnz = pack_eid_slabs(dst, src, n_dst, n_src, bounds)
    ck_f, ck_b = chunk if isinstance(chunk, tuple) else (chunk, chunk)
    return (fuse_bucketed(fwd, row_block, ck_f, eids=True),
            fuse_bucketed(bwd, row_block, ck_b, eids=True),
            order, nnz)
