"""Degree-bucketed ELL adjacency — the TPU analogue of dynamic warp partitioning.

The paper (Alg. 1, stage 2) classifies neighbor groups (rows) by degree and
partitions warps accordingly so that evil rows do not stall a whole warp.  On
TPU the execution unit is a Pallas grid cell over a *statically shaped* tile,
so the equivalent move is structural: bin rows by degree, pad each bin to its
own max degree (ELL), and dispatch each bin as its own kernel grid with a
block shape tuned to that bin.  Short rows never pay for evil rows' padding,
and evil rows get wide, deep tiles.

All packing is host-side numpy (one-time preprocessing, matching the paper's
CSR/CSC preprocessing stage).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

# Row-block granularity of the Pallas grid; bucket row counts are padded to it.
ROW_BLOCK = 8
# Default degree-bucket upper bounds (inclusive); last bucket is open-ended.
DEFAULT_BOUNDS = (4, 16, 64, 256)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ELLBucket:
    """One degree bin: ``rows[r]`` is the destination row that ``nbr[r]``
    describes.  Padded neighbor slots have weight 0 and index 0; padded row
    slots have ``rows == 0`` and all-zero weights (inert under scatter-add).
    """

    rows: jax.Array   # (R,) int32 destination row ids
    nbr: jax.Array    # (R, E) int32 source ids
    w: jax.Array      # (R, E) float edge weights

    @property
    def n_rows(self) -> int:
        return self.rows.shape[0]

    @property
    def width(self) -> int:
        return self.nbr.shape[1]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BucketedELL:
    """A sparse (n_dst x n_src) matrix as a tuple of degree-bucketed ELL slabs."""

    buckets: Tuple[ELLBucket, ...]
    n_dst: int = dataclasses.field(metadata=dict(static=True))
    n_src: int = dataclasses.field(metadata=dict(static=True))

    @property
    def nnz(self) -> int:
        return int(sum(int((np.asarray(b.w) != 0).sum()) for b in self.buckets))

    def to_dense(self) -> jax.Array:
        a = jnp.zeros((self.n_dst, self.n_src), jnp.float32)
        for b in self.buckets:
            r = jnp.repeat(b.rows[:, None], b.width, axis=1)
            a = a.at[r, b.nbr].add(b.w)
        return a


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pack_ell(dst: np.ndarray, src: np.ndarray, w: np.ndarray | None,
             n_dst: int, n_src: int,
             bounds: Sequence[int] = DEFAULT_BOUNDS,
             row_block: int = ROW_BLOCK) -> BucketedELL:
    """Pack COO edges into degree-bucketed ELL.

    Parameters
    ----------
    dst, src : int arrays (nnz,) — edge endpoints (dst aggregates from src).
    w : float array (nnz,) or None for unit weights.
    bounds : inclusive degree upper bounds for all but the last bucket.
    """
    dst = np.asarray(dst, np.int64)
    src = np.asarray(src, np.int64)
    if w is None:
        w = np.ones(dst.shape[0], np.float32)
    w = np.asarray(w, np.float32)

    # CSR-ify (stage 1 of Alg. 1).
    order = np.argsort(dst, kind="stable")
    dst, src, w = dst[order], src[order], w[order]
    deg = np.bincount(dst, minlength=n_dst)
    rowptr = np.zeros(n_dst + 1, np.int64)
    np.cumsum(deg, out=rowptr[1:])

    # Stage 2: classify rows by degree.  Empty rows are dropped entirely.
    nonempty = np.nonzero(deg > 0)[0]
    edges_of = lambda r: slice(rowptr[r], rowptr[r + 1])

    buckets = []
    lo = 1
    bnds = list(bounds) + [int(deg.max()) if deg.size and deg.max() > 0 else 1]
    for hi in bnds:
        if hi < lo:
            continue
        rows = nonempty[(deg[nonempty] >= lo) & (deg[nonempty] <= hi)]
        lo = hi + 1
        if rows.size == 0:
            continue
        width = int(deg[rows].max())
        n_r = _round_up(rows.size, row_block)
        nbr = np.zeros((n_r, width), np.int32)
        wts = np.zeros((n_r, width), np.float32)
        rid = np.zeros(n_r, np.int32)
        rid[: rows.size] = rows
        for i, r in enumerate(rows):
            sl = edges_of(r)
            d = rowptr[r + 1] - rowptr[r]
            nbr[i, :d] = src[sl]
            wts[i, :d] = w[sl]
        buckets.append(ELLBucket(rows=jnp.asarray(rid), nbr=jnp.asarray(nbr),
                                 w=jnp.asarray(wts)))
    if not buckets:  # empty matrix — keep one inert bucket for shape sanity
        buckets = [ELLBucket(rows=jnp.zeros((row_block,), jnp.int32),
                             nbr=jnp.zeros((row_block, 1), jnp.int32),
                             w=jnp.zeros((row_block, 1), jnp.float32))]
    return BucketedELL(buckets=tuple(buckets), n_dst=n_dst, n_src=n_src)


def pack_eid_slabs(dst: np.ndarray, src: np.ndarray, n_dst: int, n_src: int,
                   bounds: Sequence[int] = DEFAULT_BOUNDS):
    """Edge-id slabs aligned with :func:`pack_ell`'s bucketing.

    Packs edge *indices* (into the canonical dst-stable-sorted edge order)
    instead of weights, with ``nnz`` as the padding sentinel.  Lets a
    learnable weight vector w (nnz,) be gathered into the exact slab layout
    pack_ell produces — the basis of differentiable edge weights
    (kernels/learnable.py).  Returns (fwd_slabs, bwd_slabs, order, nnz):
    slabs are BucketedELL whose ``w`` holds f32-encoded edge ids (exact up
    to 2^24 edges); ``order`` maps the canonical order back to the caller's
    COO order.
    """
    dst = np.asarray(dst, np.int64)
    src = np.asarray(src, np.int64)
    nnz = dst.shape[0]
    assert nnz < (1 << 24), "edge ids exceed f32 exact-integer range"
    order = np.argsort(dst, kind="stable")           # pack_ell's canonical
    eid = np.empty(nnz, np.int64)
    eid[order] = np.arange(nnz)                      # caller-order -> canon
    fwd = pack_ell(dst, src, eid.astype(np.float32) + 1.0, n_dst, n_src,
                   bounds)
    bwd = pack_ell(src, dst, eid.astype(np.float32) + 1.0, n_src, n_dst,
                   bounds)
    # ids stored +1 so padding (0.0) maps to sentinel −1 after decode
    return fwd, bwd, order, nnz


def decode_eids(slab_w) -> "jax.Array":
    """f32-encoded (id+1) slab -> int32 ids with −1 padding sentinel."""
    return (slab_w.astype(jnp.int32)) - 1


def pack_ell_pair(dst: np.ndarray, src: np.ndarray, w: np.ndarray | None,
                  n_dst: int, n_src: int,
                  bounds: Sequence[int] = DEFAULT_BOUNDS
                  ) -> Tuple[BucketedELL, BucketedELL]:
    """Forward (A, row-major over dst) and backward (Aᵀ, row-major over src)
    packings — the CSR/CSC pair of Alg. 1/Alg. 2.  The transposed packing
    makes every *source* row owned by exactly one grid cell, so the backward
    needs no atomics (see DESIGN.md §2)."""
    fwd = pack_ell(dst, src, w, n_dst, n_src, bounds)
    bwd = pack_ell(src, dst, w, n_src, n_dst, bounds)
    return fwd, bwd


def degree_stats(dst: np.ndarray, n_dst: int) -> dict:
    deg = np.bincount(np.asarray(dst, np.int64), minlength=n_dst)
    return dict(degrees=deg, max=int(deg.max()) if deg.size else 0,
                mean=float(deg.mean()) if deg.size else 0.0)
