"""Degree-bucketed ELL adjacency — the TPU analogue of dynamic warp partitioning.

The paper (Alg. 1, stage 2) classifies neighbor groups (rows) by degree and
partitions warps accordingly so that evil rows do not stall a whole warp.  On
TPU the execution unit is a Pallas grid cell over a *statically shaped* tile,
so the equivalent move is structural: bin rows by degree, pad each bin to its
own max degree (ELL), and dispatch each bin as its own kernel grid with a
block shape tuned to that bin.  Short rows never pay for evil rows' padding,
and evil rows get wide, deep tiles.

Two packings live here:

* :class:`BucketedELL` — one slab per degree bucket, dispatched as one
  ``pallas_call`` each (the reference per-bucket path).
* :class:`FusedELL` — all bucket slabs re-chunked into a single uniform
  chunk arena plus a per-chunk metadata table, so the *entire* bucketed
  aggregation runs as ONE ``pallas_call`` (DESIGN.md §1).  Output rows are
  laid out arena-contiguously; a single inverse-permutation gather replaces
  the per-bucket ``y.at[rows].add`` combine.

All packing is host-side numpy (one-time preprocessing, matching the paper's
CSR/CSC preprocessing stage).
"""

from __future__ import annotations

import dataclasses
import weakref
from typing import Dict, Sequence, Tuple, Union

import numpy as np
import jax
import jax.numpy as jnp

from repro.obs.metrics import DEFAULT_REGISTRY as _METRICS

# Row-block granularity of the Pallas grid; bucket row counts are padded to it.
ROW_BLOCK = 8
# Default degree-bucket upper bounds (inclusive); last bucket is open-ended.
DEFAULT_BOUNDS = (4, 16, 64, 256)
# Neighbor-chunk width of the fused arena: each fused grid step contracts
# EDGE_CHUNK neighbors at once (an (BR, Ec·k) × (BR, Ec·k, D) MXU issue).
# 8 × k=16 = 128 = one MXU contraction dim; small enough that narrow rows
# (pin/pinned fan-outs of 2–6) waste at most one chunk of padding.
# This is the *fallback* width: ``fuse_bucketed`` picks the slot-minimizing
# width per packing from its degree histogram (``pick_chunk``) unless the
# caller pins one explicitly.
EDGE_CHUNK = 8
# Candidate chunk widths ``pick_chunk`` chooses between.  Powers of two so
# Ec·k stays MXU-aligned for the usual k ∈ {8, 16, 32}.
CHUNK_CANDIDATES = (4, 8, 16)
# Row-block height of the fused arena.  Kept at the Pallas grid granularity:
# the degree-sort makes a block's chunk count track the max width of just
# these 8 rows, so smaller blocks mean tighter adaptive widths.
FUSED_ROW_BLOCK = 8
# Dense-tier crossover: relations at or below this nnz run as ONE masked
# dense matmul instead of the chunk-walk arena (DESIGN.md §14).  Measured on
# CPU (xla timing, dim=64, k=16): at nnz≈2k the dense fwd/bwd are 2–4x
# faster than the arena, at nnz≈6–7k the arena is competitive on grad and
# ahead on TPU-shaped work — 4096 splits the measured gap.  Interpret-mode
# timings are meaningless here (ROADMAP: re-tune on real TPU).
DENSE_TIER_NNZ = 4096
# Safety valve on the dense-tier table: never densify a relation whose
# n_dst·n_src exceeds this (a 4M-entry f32 table is 16 MiB per direction —
# past that the arena wins on memory regardless of nnz).
DENSE_TIER_AREA = 1 << 22


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ELLBucket:
    """One degree bin: ``rows[r]`` is the destination row that ``nbr[r]``
    describes.  Padded neighbor slots have weight 0 and index 0; padded row
    slots have ``rows == 0`` and all-zero weights (inert under scatter-add).
    """

    rows: jax.Array   # (R,) int32 destination row ids
    nbr: jax.Array    # (R, E) int32 source ids
    w: jax.Array      # (R, E) float edge weights

    @property
    def n_rows(self) -> int:
        return self.rows.shape[0]

    @property
    def width(self) -> int:
        return self.nbr.shape[1]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BucketedELL:
    """A sparse (n_dst x n_src) matrix as a tuple of degree-bucketed ELL slabs.

    ``nnz`` is counted once at pack time (host-side) and stored as a static
    field — reading it never forces a device→host sync.  ``-1`` means the
    packing predates the count (hand-built instances); consumers treat that
    as unknown.
    """

    buckets: Tuple[ELLBucket, ...]
    n_dst: int = dataclasses.field(metadata=dict(static=True))
    n_src: int = dataclasses.field(metadata=dict(static=True))
    nnz: int = dataclasses.field(metadata=dict(static=True), default=-1)

    def to_dense(self) -> jax.Array:
        a = jnp.zeros((self.n_dst, self.n_src), jnp.float32)
        for b in self.buckets:
            r = jnp.repeat(b.rows[:, None], b.width, axis=1)
            a = a.at[r, b.nbr].add(b.w)
        return a


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pack_ell(dst: np.ndarray, src: np.ndarray, w: np.ndarray | None,
             n_dst: int, n_src: int,
             bounds: Sequence[int] = DEFAULT_BOUNDS,
             row_block: int = ROW_BLOCK) -> BucketedELL:
    """Pack COO edges into degree-bucketed ELL.

    Parameters
    ----------
    dst, src : int arrays (nnz,) — edge endpoints (dst aggregates from src).
    w : float array (nnz,) or None for unit weights.
    bounds : inclusive degree upper bounds for all but the last bucket.
    """
    dst = np.asarray(dst, np.int64)
    src = np.asarray(src, np.int64)
    if w is None:
        w = np.ones(dst.shape[0], np.float32)
    w = np.asarray(w, np.float32)

    # CSR-ify (stage 1 of Alg. 1).
    order = np.argsort(dst, kind="stable")
    dst, src, w = dst[order], src[order], w[order]
    deg = np.bincount(dst, minlength=n_dst)
    rowptr = np.zeros(n_dst + 1, np.int64)
    np.cumsum(deg, out=rowptr[1:])

    # Stage 2: classify rows by degree.  Empty rows are dropped entirely.
    nonempty = np.nonzero(deg > 0)[0]
    edges_of = lambda r: slice(rowptr[r], rowptr[r + 1])

    buckets = []
    nnz = 0
    lo = 1
    bnds = list(bounds) + [int(deg.max()) if deg.size and deg.max() > 0 else 1]
    for hi in bnds:
        if hi < lo:
            continue
        rows = nonempty[(deg[nonempty] >= lo) & (deg[nonempty] <= hi)]
        lo = hi + 1
        if rows.size == 0:
            continue
        width = int(deg[rows].max())
        n_r = _round_up(rows.size, row_block)
        nbr = np.zeros((n_r, width), np.int32)
        wts = np.zeros((n_r, width), np.float32)
        rid = np.zeros(n_r, np.int32)
        rid[: rows.size] = rows
        for i, r in enumerate(rows):
            sl = edges_of(r)
            d = rowptr[r + 1] - rowptr[r]
            nbr[i, :d] = src[sl]
            wts[i, :d] = w[sl]
        nnz += int((wts != 0).sum())
        buckets.append(ELLBucket(rows=jnp.asarray(rid), nbr=jnp.asarray(nbr),
                                 w=jnp.asarray(wts)))
    if not buckets:  # empty matrix — keep one inert bucket for shape sanity
        buckets = [ELLBucket(rows=jnp.zeros((row_block,), jnp.int32),
                             nbr=jnp.zeros((row_block, 1), jnp.int32),
                             w=jnp.zeros((row_block, 1), jnp.float32))]
    return BucketedELL(buckets=tuple(buckets), n_dst=n_dst, n_src=n_src,
                       nnz=nnz)


def pack_eid_slabs(dst: np.ndarray, src: np.ndarray, n_dst: int, n_src: int,
                   bounds: Sequence[int] = DEFAULT_BOUNDS):
    """Edge-id slabs aligned with :func:`pack_ell`'s bucketing.

    Packs edge *indices* (into the canonical dst-stable-sorted edge order)
    instead of weights, with ``nnz`` as the padding sentinel.  Lets a
    learnable weight vector w (nnz,) be gathered into the exact slab layout
    pack_ell produces — the basis of differentiable edge weights
    (kernels/learnable.py).  Returns (fwd_slabs, bwd_slabs, order, nnz):
    slabs are BucketedELL whose ``w`` holds f32-encoded edge ids (exact up
    to 2^24 edges); ``order`` maps the canonical order back to the caller's
    COO order.
    """
    dst = np.asarray(dst, np.int64)
    src = np.asarray(src, np.int64)
    nnz = dst.shape[0]
    assert nnz < (1 << 24), "edge ids exceed f32 exact-integer range"
    order = np.argsort(dst, kind="stable")           # pack_ell's canonical
    eid = np.empty(nnz, np.int64)
    eid[order] = np.arange(nnz)                      # caller-order -> canon
    fwd = pack_ell(dst, src, eid.astype(np.float32) + 1.0, n_dst, n_src,
                   bounds)
    bwd = pack_ell(src, dst, eid.astype(np.float32) + 1.0, n_src, n_dst,
                   bounds)
    # ids stored +1 so padding (0.0) maps to sentinel −1 after decode
    return fwd, bwd, order, nnz


def decode_eids(slab_w) -> "jax.Array":
    """f32-encoded (id+1) slab -> int32 ids with −1 padding sentinel."""
    return (slab_w.astype(jnp.int32)) - 1


def pack_ell_pair(dst: np.ndarray, src: np.ndarray, w: np.ndarray | None,
                  n_dst: int, n_src: int,
                  bounds: Sequence[int] = DEFAULT_BOUNDS
                  ) -> Tuple[BucketedELL, BucketedELL]:
    """Forward (A, row-major over dst) and backward (Aᵀ, row-major over src)
    packings — the CSR/CSC pair of Alg. 1/Alg. 2.  The transposed packing
    makes every *source* row owned by exactly one grid cell, so the backward
    needs no atomics (see DESIGN.md §2)."""
    fwd = pack_ell(dst, src, w, n_dst, n_src, bounds)
    bwd = pack_ell(src, dst, w, n_src, n_dst, bounds)
    return fwd, bwd


def degree_stats(dst: np.ndarray, n_dst: int) -> dict:
    deg = np.bincount(np.asarray(dst, np.int64), minlength=n_dst)
    return dict(degrees=deg, max=int(deg.max()) if deg.size else 0,
                mean=float(deg.mean()) if deg.size else 0.0)


def ell_to_coo(adj: BucketedELL) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host-side inverse of :func:`pack_ell`: (dst, src, w) of the non-zero
    slots.  Zero-weight slots are padding by construction, so the round trip
    preserves exactly the ``nnz`` edges the packing represents.  Used by the
    block-diagonal collator (graphs/collate.py), which re-packs member
    graphs' edges with per-member node-id offsets."""
    ds, ss, ws = [], [], []
    for b in adj.buckets:
        w = np.asarray(b.w, np.float32)
        mask = w != 0
        if not mask.any():
            continue
        rows = np.broadcast_to(np.asarray(b.rows, np.int64)[:, None], w.shape)
        ds.append(rows[mask])
        ss.append(np.asarray(b.nbr, np.int64)[mask])
        ws.append(w[mask])
    if not ds:
        return (np.zeros(0, np.int64), np.zeros(0, np.int64),
                np.zeros(0, np.float32))
    return np.concatenate(ds), np.concatenate(ss), np.concatenate(ws)


# ---------------------------------------------------------------------------
# FusedELL — single-dispatch arena packing (DESIGN.md §1)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FusedELL:
    """All degree buckets re-chunked into one uniform (C, BR, Ec) arena.

    Every chunk holds ``row_block`` rows × ``chunk`` neighbor slots of ONE
    bucket's ELL slab; zero-weight slots are inert padding.  Chunks of the
    same output row-block are stored consecutively, so a Pallas grid over
    chunks revisits each output block in an unbroken run — the grouped-matmul
    accumulation pattern that needs no atomics and no host-side combine.

    ``block_of``/``start`` are the scalar-prefetch metadata table: the output
    row-block each chunk accumulates into, and whether the chunk opens its
    block (→ zero-init).  ``rows`` maps arena rows back to original row ids
    (padding → 0 with zero weights); ``gather`` is the inverse map used to
    read the final (n_dst, D) output out of the arena with ONE gather —
    original rows absent from every bucket point at the trailing sentinel
    block, which is written as all-zeros.
    """

    nbr: jax.Array       # (C, BR, Ec) int32 source ids
    w: jax.Array         # (C, BR, Ec) f32 edge weights (0 = padding)
    block_of: jax.Array  # (C,) int32 output row-block per chunk
    start: jax.Array     # (C,) int32 1 iff chunk opens its row-block
    rows: jax.Array      # (R_arena,) int32 original row per arena row
    gather: jax.Array    # (n_dst,) int32 arena row per original row
    n_dst: int = dataclasses.field(metadata=dict(static=True))
    n_src: int = dataclasses.field(metadata=dict(static=True))
    nnz: int = dataclasses.field(metadata=dict(static=True))
    row_block: int = dataclasses.field(metadata=dict(static=True))
    chunk: int = dataclasses.field(metadata=dict(static=True))
    # Edge-id arena for learnable per-edge weights (kernels/ops.py::
    # drspmm_learnable): (C, BR, Ec) int32 canonical edge ids, padding
    # slots -> −1.  Chunked exactly like ``w``, so a canonical weight
    # vector (nnz,) gathers straight into arena layout.  ``None`` for
    # fixed-weight packings.
    eid: jax.Array | None = None
    # Relation id per chunk for relation-fused super-arenas
    # (:func:`build_relation_plan`): (C,) int32 index into the plan's
    # segment tuple.  The kernels never read it — relation selection is
    # baked into ``nbr``/``block_of``/``rows`` offsets at pack time — but
    # it makes every chunk's provenance auditable (segment round-trip
    # tests, bench dispatch accounting).  ``None`` for single-relation
    # arenas.
    rel: jax.Array | None = None

    @property
    def n_chunks(self) -> int:
        return self.nbr.shape[0]

    @property
    def n_arena_rows(self) -> int:
        return self.rows.shape[0]

    def to_dense(self) -> np.ndarray:
        """Host-side dense reconstruction (round-trip tests)."""
        a = np.zeros((self.n_dst, self.n_src), np.float32)
        nbr = np.asarray(self.nbr)
        w = np.asarray(self.w)
        blk = np.asarray(self.block_of)
        rows = np.asarray(self.rows)
        br = self.row_block
        for c in range(nbr.shape[0]):
            for b in range(br):
                rid = rows[blk[c] * br + b]
                mask = w[c, b] != 0
                np.add.at(a[rid], nbr[c, b][mask], w[c, b][mask])
        return a


# id-keyed memo: fusing is host-side numpy work we only want once per packing.
_FUSE_CACHE: Dict[tuple, tuple] = {}


def _effective_widths(w: np.ndarray) -> np.ndarray:
    """Per-row count of slots up to the last non-zero one (pack_ell fills
    rows left-to-right, so this is the row's effective degree)."""
    nz = w != 0
    e = w.shape[1]
    return np.where(nz.any(axis=1), e - np.argmax(nz[:, ::-1], axis=1), 0)


def _block_widths(adj: BucketedELL, row_block: int) -> list:
    """Max effective width of each fused row-block, after the descending
    degree sort each bucket undergoes inside :func:`fuse_bucketed` — i.e.
    exactly the widths the arena's chunk counts are derived from."""
    bws = []
    for b in adj.buckets:
        width_r = np.sort(_effective_widths(np.asarray(b.w, np.float32)))[::-1]
        rpad = _round_up(max(width_r.size, 1), row_block)
        width_r = np.concatenate(
            [width_r, np.zeros(rpad - width_r.size, np.int64)])
        for t in range(rpad // row_block):
            bws.append(int(width_r[t * row_block:(t + 1) * row_block]
                           .max(initial=0)))
    return bws


def pick_chunk(adj: BucketedELL, row_block: int = None,
               candidates: Sequence[int] = CHUNK_CANDIDATES) -> int:
    """Slot-minimizing arena chunk width for this packing (ROADMAP item).

    ``EDGE_CHUNK = 8`` is tuned for the heavy-tailed ``near`` degrees; the
    narrow ``pin``/``pinned`` fan-outs (2–6) pay up to 2× slot padding at
    width 8.  This picks, from the packing's own degree histogram, the
    candidate minimizing total arena slots Σ_blocks BR·Ec·ceil(bw/Ec); ties
    go to the wider chunk (fewer grid steps, bigger MXU contractions).
    """
    if row_block is None:
        row_block = FUSED_ROW_BLOCK
    bws = _block_widths(adj, row_block)

    def slots(c):
        return sum(row_block * c * max(1, -(-bw // c)) for bw in bws)

    return min(candidates, key=lambda c: (slots(c), -c))


def fuse_bucketed(adj: BucketedELL, row_block: int = None,
                  chunk: int = None, *, eids: bool = False) -> FusedELL:
    """Re-pack a :class:`BucketedELL` into the single-dispatch fused arena.

    ``chunk=None`` picks the slot-minimizing width from the packing's degree
    histogram (:func:`pick_chunk`); pass an int to pin the layout (the
    collator does, so batches of the same shape bucket share a signature).

    ``eids=True`` treats ``adj`` as an edge-id slab packing
    (:func:`pack_eid_slabs` layout: ``w`` holds f32-encoded ``id+1``,
    0 = padding).  The arena then carries a decoded int32 ``eid`` table
    (padding slots → −1) chunked exactly like the weight arena, and ``w``
    becomes the 0/1 real-slot mask — the layout the fused learnable
    executors (kernels/drspmm.py) gather a canonical weight vector into.

    Pure host-side preprocessing; results are memoized per (packing, layout)
    so jit re-traces and repeated layer calls never re-pack.
    """
    if row_block is None:
        row_block = FUSED_ROW_BLOCK
    # chunk=None is memoized under the None key, so a cache hit skips even
    # the pick_chunk histogram scan.
    key = (id(adj), row_block, chunk, eids)
    hit = _FUSE_CACHE.get(key)
    if hit is not None and hit[0]() is adj:
        return hit[1]
    if chunk is None:
        chunk = pick_chunk(adj, row_block)

    nbr_chunks, w_chunks, block_of, start = [], [], [], []
    rows_parts = []
    gather = np.full(adj.n_dst, -1, np.int64)
    blk = 0
    arena_off = 0
    for b in adj.buckets:
        nb = np.asarray(b.nbr)
        wt = np.asarray(b.w, np.float32)
        rid = np.asarray(b.rows, np.int64)
        r, e = nb.shape
        rpad = _round_up(max(r, 1), row_block)
        epad = _round_up(max(e, 1), chunk)
        nb_p = np.zeros((rpad, epad), np.int32)
        wt_p = np.zeros((rpad, epad), np.float32)
        nb_p[:r, :e] = nb
        wt_p[:r, :e] = wt
        rid_p = np.zeros(rpad, np.int32)
        rid_p[:r] = rid
        # Effective row width = last carried weight (pack_ell fills rows
        # left-to-right; zero-weight slots contribute nothing either way).
        nz = wt_p != 0
        width_r = np.where(nz.any(axis=1),
                           epad - np.argmax(nz[:, ::-1], axis=1), 0)
        # Finer-than-bucket adaptivity: order rows by effective width so
        # each row-block's chunk count tracks its OWN max degree, not the
        # bucket's.  A degree-17 row in a width-64 bucket then costs
        # ceil(17/Ec) chunks instead of the whole slab (DESIGN.md §1.2).
        order = np.argsort(-width_r, kind="stable")
        nb_p, wt_p, rid_p, width_r = (nb_p[order], wt_p[order],
                                      rid_p[order], width_r[order])
        # A row is "real" iff it carries any weight; all-zero rows produce
        # all-zero output either way, so routing them to the sentinel is
        # equivalent (DESIGN.md §1.3).
        real = width_r > 0
        gather[rid_p[real]] = arena_off + np.nonzero(real)[0]
        rows_parts.append(rid_p)
        arena_off += rpad
        for t in range(rpad // row_block):
            sl = slice(t * row_block, (t + 1) * row_block)
            bw = int(width_r[sl].max(initial=0))
            nch = max(1, -(-bw // chunk))            # ≥1 so the block inits
            for ci in range(nch):
                cs = slice(ci * chunk, (ci + 1) * chunk)
                nbr_chunks.append(nb_p[sl, cs])
                w_chunks.append(wt_p[sl, cs])
                block_of.append(blk)
                start.append(1 if ci == 0 else 0)
            blk += 1

    # Trailing sentinel block: BR guaranteed-zero arena rows that empty
    # original rows gather from.
    nbr_chunks.append(np.zeros((row_block, chunk), np.int32))
    w_chunks.append(np.zeros((row_block, chunk), np.float32))
    block_of.append(blk)
    start.append(1)
    sentinel_row = arena_off
    rows_parts.append(np.zeros(row_block, np.int32))
    gather[gather < 0] = sentinel_row

    nnz = adj.nnz if adj.nnz >= 0 else int(
        sum(int((np.asarray(b.w) != 0).sum()) for b in adj.buckets))
    w_arena = np.stack(w_chunks)
    eid_arena = None
    if eids:
        # w slots hold f32(id+1) with 0 padding (exact up to 2^24 edges,
        # asserted at pack time): decode to −1-padded int32 ids and leave
        # the 0/1 real-slot mask as the arena weight.
        eid_arena = w_arena.astype(np.int32) - 1
        w_arena = (w_arena != 0).astype(np.float32)
    # NB: leaves stay host numpy — fusing may run lazily inside a jit trace
    # (first call of a jitted layer), where jnp.asarray would capture
    # tracers into the memo and leak them out of the trace.  numpy leaves
    # are trace-safe constants.
    fused = FusedELL(
        nbr=np.stack(nbr_chunks),
        w=w_arena,
        block_of=np.asarray(block_of, np.int32),
        start=np.asarray(start, np.int32),
        rows=np.concatenate(rows_parts).astype(np.int32),
        gather=gather.astype(np.int32),
        n_dst=adj.n_dst, n_src=adj.n_src, nnz=nnz,
        row_block=row_block, chunk=chunk, eid=eid_arena)
    # Evict promptly when the packing dies — a dead entry would otherwise
    # pin its whole fused arena (id reuse is also why the hit path
    # re-checks `ref() is adj`).
    _FUSE_CACHE[key] = (weakref.ref(adj, lambda _: _FUSE_CACHE.pop(key, None)),
                        fused)
    return fused


def arena_stats(f: FusedELL, bucketed: BucketedELL | None = None) -> dict:
    """Pack-time arena efficiency report (DESIGN.md §11).

    The numbers behind the §1 chunking math, made observable instead of
    hand-derivable: total arena slots ``C·BR·Ec``, how many carry real
    edges, the padding overhead, and the chunk-width choice.  With the
    source ``bucketed`` packing, also the bucket-slab baseline (each ELL
    bucket dispatched as its own rows×width slab, the pre-PR-1 layout) and
    ``slot_saving`` — slab slots per arena slot, the adaptive-chunking win
    (~1.9x on heavy-tailed ``near`` degrees; asserted in
    tests/test_obs_arena.py).

    Works on padded arenas too (``pad_fused_arena`` resets ``nnz`` to −1,
    so real slots fall back to a host-side non-zero count of ``w``).
    """
    c, br, ec = (int(s) for s in np.shape(f.nbr))
    slots = c * br * ec
    real = f.nnz if f.nnz >= 0 else int(np.count_nonzero(np.asarray(f.w)))
    out = dict(n_chunks=c, row_block=br, chunk=ec, slots=slots,
               real_slots=real, padded_slots=slots - real,
               fill_ratio=real / slots if slots else 0.0)
    if bucketed is not None:
        slab = sum(int(np.shape(b.nbr)[0]) * int(np.shape(b.nbr)[1])
                   for b in bucketed.buckets)
        out["slab_slots"] = slab
        out["slot_saving"] = slab / slots if slots else 0.0
    return out


def pack_fused(dst: np.ndarray, src: np.ndarray, w: np.ndarray | None,
               n_dst: int, n_src: int,
               bounds: Sequence[int] = DEFAULT_BOUNDS,
               row_block: int = None,
               chunk: int = None) -> FusedELL:
    """COO → fused single-dispatch arena (pack_ell then fuse)."""
    return fuse_bucketed(pack_ell(dst, src, w, n_dst, n_src, bounds),
                         row_block=row_block, chunk=chunk)


def pack_fused_pair(dst: np.ndarray, src: np.ndarray, w: np.ndarray | None,
                    n_dst: int, n_src: int,
                    bounds: Sequence[int] = DEFAULT_BOUNDS
                    ) -> Tuple[FusedELL, FusedELL]:
    """Fused forward/transposed pair (the CSR/CSC analogue of Alg. 1/2)."""
    return (pack_fused(dst, src, w, n_dst, n_src, bounds),
            pack_fused(src, dst, w, n_src, n_dst, bounds))


def pack_fused_eid_pair(dst: np.ndarray, src: np.ndarray,
                        n_dst: int, n_src: int,
                        bounds: Sequence[int] = DEFAULT_BOUNDS,
                        row_block: int = None,
                        chunk: Union[int, None, Tuple] = None
                        ) -> Tuple[FusedELL, FusedELL, np.ndarray, int]:
    """Fused edge-id arena pair for learnable per-edge weights.

    The eid analogue of :func:`pack_fused_pair`: packs edge *indices* (into
    the canonical dst-stable-sorted order, :func:`pack_eid_slabs`) and fuses
    both directions into arenas carrying ``eid`` tables (−1 padding), so a
    learnable weight vector w (nnz,) gathers straight into arena layout on
    the single-dispatch path (kernels/ops.py::drspmm_learnable).

    ``chunk`` pins the arena chunk width: an int for both directions, or a
    ``(fwd, bwd)`` tuple (the collator pins per direction).  Returns
    ``(fwd_arena, bwd_arena, order, nnz)`` with ``order`` mapping the
    canonical order back to the caller's COO order.
    """
    fwd, bwd, order, nnz = pack_eid_slabs(dst, src, n_dst, n_src, bounds)
    ck_f, ck_b = chunk if isinstance(chunk, tuple) else (chunk, chunk)
    return (fuse_bucketed(fwd, row_block, ck_f, eids=True),
            fuse_bucketed(bwd, row_block, ck_b, eids=True),
            order, nnz)


def pad_fused_arena(f: FusedELL, n_chunks: int, n_rows: int) -> FusedELL:
    """Pad a fused arena to (n_chunks, ·, ·) chunks / n_rows arena rows.

    Padding chunks carry zero weights and extend the run of the arena's
    LAST block — the all-zero sentinel ``fuse_bucketed`` always emits last —
    with ``start=0``, so the grouped-matmul revisit invariant (unbroken
    chunk run per block, DESIGN.md §1) holds and the sentinel stays zero.
    Padding rows are simply appended: no chunk references them and the
    output gather never reads them, so they need no initializing chunk.
    ``nnz`` is reset to −1 (unknown): batches of one shape bucket differ in
    nnz, and a static nnz would split the jit cache per batch.

    Used by the block-diagonal collator (graphs/collate.py) for
    shape-bucket-stable batch arenas, and by :func:`build_relation_plan`
    for bucket-stable per-relation segments of a super-arena.
    """
    c, br, ec = f.nbr.shape
    r = f.n_arena_rows
    assert n_rows % br == 0 and n_rows >= r and n_chunks >= c
    pad_chunks = n_chunks - c
    sentinel = r // br - 1
    zpad = lambda a, n, dt: np.concatenate(
        [np.asarray(a), np.zeros((n,) + np.asarray(a).shape[1:], dt)])
    eid = None
    if f.eid is not None:        # learnable-edge arena: padding slots → −1
        eid = np.concatenate(
            [np.asarray(f.eid),
             np.full((pad_chunks, br, ec), -1, np.int32)])
    rel = None
    if f.rel is not None:        # padding chunks stay in the last relation
        rel = np.concatenate(
            [np.asarray(f.rel),
             np.full(pad_chunks, int(np.asarray(f.rel)[-1]), np.int32)])
    return FusedELL(
        nbr=zpad(f.nbr, pad_chunks, np.int32),
        w=zpad(f.w, pad_chunks, np.float32),
        block_of=np.concatenate([np.asarray(f.block_of),
                                 np.full(pad_chunks, sentinel, np.int32)]),
        start=np.concatenate([np.asarray(f.start),
                              np.zeros(pad_chunks, np.int32)]),
        rows=zpad(f.rows, n_rows - r, np.int32),
        gather=np.asarray(f.gather),
        n_dst=f.n_dst, n_src=f.n_src, nnz=-1,
        row_block=f.row_block, chunk=f.chunk, eid=eid, rel=rel)


def fused_to_coo(f: FusedELL) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host-side inverse of :func:`fuse_bucketed`: (dst, src, w) of the
    non-zero slots, in the arena's OWN coordinates.

    For a plain arena that means original row ids; for a super-arena
    (:func:`build_relation_plan`) dst comes out in relation-concat output
    coordinates and src in the type-concat source slab — exactly the global
    coordinate pair the mesh partitioner (sharding/plan_shard.py) shards on.
    Zero-weight slots are padding by construction, so the round trip yields
    exactly the edges the packing represents (vectorized, no chunk loop).
    """
    w = np.asarray(f.w, np.float32)                       # (C, BR, Ec)
    blk = np.asarray(f.block_of, np.int64)
    rows = np.asarray(f.rows, np.int64)
    br = f.row_block
    slot_row = rows[blk[:, None] * br + np.arange(br)]    # (C, BR)
    mask = w != 0
    dst = np.broadcast_to(slot_row[:, :, None], w.shape)[mask]
    src = np.asarray(f.nbr, np.int64)[mask]
    return dst, src, w[mask]


# ---------------------------------------------------------------------------
# RelationPlan — cross-relation super-arena (DESIGN.md §9)
# ---------------------------------------------------------------------------
#
# A hetero layer's message passing is one DR-SpMM per edge-type direction;
# PR 1–4 fused each direction into ONE dispatch but still walked the
# directions serially in Python.  The super-arena collapses that loop: every
# relation's fused arena is concatenated into one (C_total, BR, Ec) arena
# whose metadata bakes the relation routing in —
#
#   * ``nbr``     += the relation's source-type offset in the type-concat
#                    source slab  [x_cell; x_net]
#   * ``rows``    += (fwd) the relation's row offset in the concatenated
#                    output / (bwd) its source-type offset
#   * ``block_of``+= the preceding relations' block counts
#   * ``gather``   = per-relation gathers shifted by the preceding
#                    relations' arena rows
#
# so the §1 kernels run UNCHANGED over the whole direction-group: one
# pallas_call forward, one transposed pallas_call backward, per layer.

@dataclasses.dataclass(frozen=True)
class RelationSegment:
    """Where one relation lives inside a :class:`RelationPlan` (all static:
    part of the plan's pytree aux data, stable within a shape bucket).

    ``out_off`` is ALWAYS the relation's row offset in the full output
    concat (the y/gy slab every tier shares).  Arena-tier segments
    additionally carry ``arena_out_off`` (row offset in the arena-only fwd
    output concat) and ``src_out_off`` (offset in the arena-only dx concat);
    dense-tier segments carry ``dense_off`` (row offset in the plan's
    ``dense_fwd`` table) and leave the arena coordinates at −1 / (0, 0).
    """

    etype: str
    src_type: str
    dst_type: str
    n_dst: int                   # relation destination rows
    n_src: int                   # relation source rows
    out_off: int                 # row offset in the concat output / gy slab
    src_out_off: int             # row offset in the concat per-relation dx
    fwd_chunks: Tuple[int, int]  # [lo, hi) chunk range in the fwd arena
    bwd_chunks: Tuple[int, int]
    fwd_rows: Tuple[int, int]    # [lo, hi) arena-row range in the fwd arena
    bwd_rows: Tuple[int, int]
    tier: str = "arena"          # "arena" (chunk walk) | "dense" (matmul)
    dense_off: int = -1          # row offset in dense_fwd (dense tier only)
    arena_out_off: int = -1      # row offset in the arena-only fwd concat


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RelationPlan:
    """One hetero layer's whole message passing as a fwd/bwd super-arena
    pair plus the relation segment table.

    ``fwd`` aggregates every ARENA-tier relation in ONE dispatch over the
    type-concat source slab (n_src = Σ node-type sizes) into the arena-only
    output concat (n_dst = Σ arena-tier destinations); ``bwd`` is the
    transposed super-arena over the FULL concatenated output cotangents
    (its ``gather`` yields the arena-tier dx concat, summed per source type
    by the op).  DENSE-tier relations (sub-crossover nnz, DESIGN.md §14)
    bypass the chunk walk entirely: ``dense_fwd`` stacks their masked dense
    matrices over the full type-concat source width, and ``dense_bwd`` is
    its exact transpose, so the whole tier is one batched matmul per
    direction.  When no relation lands in a tier, that tier's tables are an
    inert placeholder (empty dense table / sentinel-only arena) the
    executor skips.  Consumed by :func:`repro.kernels.ops.drspmm_multi`.
    """

    fwd: FusedELL
    bwd: FusedELL
    # Type-concat source id per bwd ARENA row: the §2 xi gather reads
    # ``x_idx_concat[bwd_src_rows]``.  Kept separate from ``bwd.rows`` so
    # the bwd arena stays self-consistent over the relation-concat dx space
    # (``rows``/``gather`` are inverse maps there, ``to_dense`` is the
    # block matrix of the transposed relations).
    bwd_src_rows: jax.Array
    # Dense-tier tables: (dense_rows_total, n_src_total) f32 — segment d's
    # matrix occupies rows [dense_off, dense_off + n_dst) and columns
    # [src_off[src_type], + n_src); everything else is structural zero.
    # ``dense_bwd`` is dense_fwd.T, materialized so the backward matmul
    # reads a contiguous operand.  (0, n_src_total)/(n_src_total, 0) when
    # no relation is dense-tier.
    dense_fwd: jax.Array
    dense_bwd: jax.Array
    segments: Tuple[RelationSegment, ...] = dataclasses.field(
        metadata=dict(static=True))
    src_types: Tuple[str, ...] = dataclasses.field(
        metadata=dict(static=True))   # node types, source-concat order
    src_off: Tuple[int, ...] = dataclasses.field(
        metadata=dict(static=True))   # per-type offset in the source concat
    src_sizes: Tuple[int, ...] = dataclasses.field(
        metadata=dict(static=True))   # per-type node count

    @property
    def n_src_total(self) -> int:
        return self.fwd.n_src

    @property
    def n_out_total(self) -> int:
        return self.segments[-1].out_off + self.segments[-1].n_dst \
            if self.segments else self.fwd.n_dst

    @property
    def arena_segments(self) -> Tuple[RelationSegment, ...]:
        return tuple(s for s in self.segments if s.tier == "arena")

    @property
    def dense_segments(self) -> Tuple[RelationSegment, ...]:
        return tuple(s for s in self.segments if s.tier == "dense")

    @property
    def has_arena(self) -> bool:
        return any(s.tier == "arena" for s in self.segments)

    @property
    def has_dense(self) -> bool:
        return any(s.tier == "dense" for s in self.segments)

    def segment(self, etype: str) -> RelationSegment:
        for s in self.segments:
            if s.etype == etype:
                return s
        raise KeyError(etype)

    def to_dense(self) -> np.ndarray:
        """Full (n_out_total, n_src_total) block matrix across BOTH tiers —
        the oracle every executor path must match (round-trip tests, the
        ``dense`` reference backend)."""
        a = np.zeros((self.n_out_total, self.n_src_total), np.float32)
        if self.has_arena:
            fa = np.asarray(self.fwd.to_dense(), np.float32)
            for s in self.arena_segments:
                a[s.out_off:s.out_off + s.n_dst] = \
                    fa[s.arena_out_off:s.arena_out_off + s.n_dst]
        df = np.asarray(self.dense_fwd, np.float32)
        for s in self.dense_segments:
            a[s.out_off:s.out_off + s.n_dst] = \
                df[s.dense_off:s.dense_off + s.n_dst]
        return a


def pick_chunk_multi(packings: Sequence[BucketedELL], row_block: int = None,
                     candidates: Sequence[int] = CHUNK_CANDIDATES) -> int:
    """Slot-minimizing SHARED chunk width for a super-arena.

    A super-arena is one uniform (C, BR, Ec) arena, so all relations must
    agree on Ec; this reuses :func:`pick_chunk`'s per-relation degree
    histogram (``_block_widths``) and minimizes the SUMMED slot count
    Σ_relations Σ_blocks BR·Ec·ceil(bw/Ec).  Ties go to the wider chunk,
    matching ``pick_chunk``."""
    if row_block is None:
        row_block = FUSED_ROW_BLOCK
    bws = [bw for p in packings for bw in _block_widths(p, row_block)]

    def slots(c):
        return sum(row_block * c * max(1, -(-bw // c)) for bw in bws)

    return min(candidates, key=lambda c: (slots(c), -c))


def _empty_super_arena(n_dst: int, n_src: int, row_block: int,
                       chunk: int) -> FusedELL:
    """Inert placeholder arena for a tier nothing landed in: one all-zero
    sentinel chunk/block, every output row gathering from the zero block.
    The executors never dispatch it (``plan.has_arena`` gates the call),
    but keeping the pytree structure uniform means tier composition never
    changes the plan's leaf COUNT — only leaf shapes, which the collator's
    bucket pinning already keeps stable."""
    return FusedELL(
        nbr=np.zeros((1, row_block, chunk), np.int32),
        w=np.zeros((1, row_block, chunk), np.float32),
        block_of=np.zeros(1, np.int32),
        start=np.ones(1, np.int32),
        rows=np.zeros(row_block, np.int32),
        gather=np.zeros(n_dst, np.int32),
        n_dst=n_dst, n_src=n_src, nnz=0,
        row_block=row_block, chunk=chunk,
        rel=np.zeros(1, np.int32))


def plan_to_coo(plan: "RelationPlan"
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host-side (dst, src, w) of EVERY edge a plan represents, across both
    tiers, in full-output-concat / type-concat-source coordinates — the
    global coordinate pair the mesh partitioner (sharding/plan_shard.py)
    shards on.  Arena-tier edges come from :func:`fused_to_coo` with their
    arena-concat rows remapped to full output rows; dense-tier edges come
    straight from the non-zeros of ``dense_fwd``."""
    ds, ss, ws = [], [], []
    if plan.has_arena:
        d, s, w = fused_to_coo(plan.fwd)
        shift = np.zeros(plan.fwd.n_dst, np.int64)
        for seg in plan.arena_segments:
            shift[seg.arena_out_off:seg.arena_out_off + seg.n_dst] = \
                seg.out_off - seg.arena_out_off
        ds.append(d + shift[d])
        ss.append(s)
        ws.append(w)
    if plan.has_dense:
        df = np.asarray(plan.dense_fwd, np.float32)
        for seg in plan.dense_segments:
            blk = df[seg.dense_off:seg.dense_off + seg.n_dst]
            r, c = np.nonzero(blk)
            ds.append(r.astype(np.int64) + seg.out_off)
            ss.append(c.astype(np.int64))
            ws.append(blk[r, c])
    if not ds:
        return (np.zeros(0, np.int64), np.zeros(0, np.int64),
                np.zeros(0, np.float32))
    return np.concatenate(ds), np.concatenate(ss), np.concatenate(ws)


def _concat_arenas(arenas: Sequence[FusedELL], nbr_offs: Sequence[int],
                   rows_offs: Sequence[int], n_dst: int, n_src: int
                   ) -> Tuple[FusedELL, list]:
    """Concatenate per-relation fused arenas into one super-arena.

    ``nbr_offs[i]``/``rows_offs[i]`` are added to arena i's neighbor ids /
    row ids (padding slots get offset too — they carry zero weights, so
    pointing them at row ``off`` instead of 0 is equally inert and keeps
    every id in range).  Each arena keeps its own sentinel block, so the
    per-relation gathers stay valid after shifting.  Returns the super
    arena plus per-relation (chunk_off, row_off) pairs for the segment
    table."""
    br = arenas[0].row_block
    ck = arenas[0].chunk
    assert all(a.row_block == br and a.chunk == ck for a in arenas), \
        "super-arena members must share (row_block, chunk)"
    offs, c_off, r_off = [], 0, 0
    nbr, w, blk, start, rows, gather, rel = [], [], [], [], [], [], []
    for i, (a, no, ro) in enumerate(zip(arenas, nbr_offs, rows_offs)):
        offs.append((c_off, r_off))
        nbr.append(np.asarray(a.nbr) + np.int32(no))
        w.append(np.asarray(a.w))
        blk.append(np.asarray(a.block_of) + np.int32(r_off // br))
        start.append(np.asarray(a.start))
        rows.append(np.asarray(a.rows) + np.int32(ro))
        gather.append(np.asarray(a.gather) + np.int32(r_off))
        rel.append(np.full(a.n_chunks, i, np.int32))
        c_off += a.n_chunks
        r_off += a.n_arena_rows
    nnzs = [a.nnz for a in arenas]
    fused = FusedELL(
        nbr=np.concatenate(nbr), w=np.concatenate(w),
        block_of=np.concatenate(blk), start=np.concatenate(start),
        rows=np.concatenate(rows), gather=np.concatenate(gather),
        n_dst=n_dst, n_src=n_src,
        nnz=-1 if any(n < 0 for n in nnzs) else int(sum(nnzs)),
        row_block=br, chunk=ck, rel=np.concatenate(rel))
    return fused, offs


def build_relation_plan(relations: Sequence[tuple], n_of: Dict[str, int], *,
                        bounds: Sequence[int] = DEFAULT_BOUNDS,
                        row_block: int = None,
                        chunk: Union[int, None, Tuple] = None,
                        pad: Dict[str, Dict[str, Tuple[int, int]]] = None,
                        packed: Dict[str, Tuple[BucketedELL,
                                                BucketedELL]] = None,
                        dense_threshold: int = None,
                        tiers: Dict[str, str] = None
                        ) -> RelationPlan:
    """Pack every relation of a hetero layer into one fwd/bwd super-arena
    plus a dense-tier table for sub-crossover relations (DESIGN.md §14).

    Parameters
    ----------
    relations : sequence of ``(etype, src_type, dst_type, dst, src, w)``
        COO edge lists per relation; the sequence order fixes the segment
        (and output-concat) order.
    n_of : ordered ``{node_type: count}`` — the order fixes the source
        concat layout ``[type0; type1; …]`` the caller's CBSR operands are
        stacked in.
    chunk : shared arena chunk width — an int for both directions, a
        ``(fwd, bwd)`` tuple, or ``None`` to pick the summed-slot-minimizing
        width per direction from the relations' degree histograms
        (:func:`pick_chunk_multi`).  The collator pins it per shape bucket.
    pad : optional ``{etype: {"fwd"|"bwd": (n_chunks, n_rows)}}`` — or a
        callable ``(etype, "fwd"|"bwd", arena) -> (n_chunks, n_rows)`` —
        padding each relation's sub-arena to bucket-stable dims BEFORE
        concatenation (:func:`pad_fused_arena`), so collated plans of one
        shape bucket share a signature (the collator passes a closure over
        its quantization grid + ``BucketLayout`` floors).
    packed : optional ``{etype: (fwd_bucketed, bwd_bucketed)}`` — reuse
        already-built degree-bucketed packings instead of re-running
        ``pack_ell`` (the collator shares the pair it packs for the
        per-edge-type arenas; fusing at the plan's shared chunk width is
        memoized separately per (packing, width)).
    dense_threshold : nnz at or below which a relation is routed to the
        dense tier (default :data:`DENSE_TIER_NNZ`); the
        :data:`DENSE_TIER_AREA` table-size guard always applies on top.
    tiers : optional ``{etype: "arena"|"dense"}`` overriding the nnz
        classification per relation — the collator pins the first-seen
        tiering per shape bucket with this, so padded members of one bucket
        share segment statics (and thus a jit signature) even when filler
        members' nnz straddles the threshold.
    """
    if row_block is None:
        row_block = FUSED_ROW_BLOCK
    src_types = tuple(n_of)
    src_off, off = {}, 0
    for t in src_types:
        src_off[t] = off
        off += int(n_of[t])
    n_src_total = off
    thr = DENSE_TIER_NNZ if dense_threshold is None else int(dense_threshold)

    # Plan packing may run lazily inside a jit trace (first call of a
    # jitted layer over a concrete graph): force the pack_ell slabs to be
    # concrete there — otherwise their jnp leaves become traced constants
    # the host-side fuser cannot np.asarray.  The resulting plan stores
    # host numpy leaves only (trace-safe constants, like _FUSE_CACHE's).
    with jax.ensure_compile_time_eval():
        if packed is not None:
            fwd_b = [packed[r[0]][0] for r in relations]
            bwd_b = [packed[r[0]][1] for r in relations]
        else:
            fwd_b = [pack_ell(dst, src, w, int(n_of[dt]), int(n_of[st]),
                              bounds)
                     for _et, st, dt, dst, src, w in relations]
            bwd_b = [pack_ell(src, dst, w, int(n_of[st]), int(n_of[dt]),
                              bounds)
                     for _et, st, dt, dst, src, w in relations]

        # Tier classification: exact nnz (pack-time count) against the
        # measured crossover, with the table-area guard on top.  An
        # explicit ``tiers`` entry wins — that's how collated buckets stay
        # signature-stable across members.
        tier_of = []
        for i, r in enumerate(relations):
            et, st, dt = r[0], r[1], r[2]
            nnz_i = fwd_b[i].nnz
            if nnz_i < 0:
                nnz_i = int(np.asarray(r[3]).shape[0])
            area = int(n_of[dt]) * int(n_of[st])
            t = "dense" if (nnz_i <= thr and area <= DENSE_TIER_AREA) \
                else "arena"
            if tiers is not None and et in tiers:
                t = tiers[et]
            tier_of.append(t)
            for d in ("fwd", "bwd"):
                _METRICS.set("arena.tier", 1.0 if t == "dense" else 0.0,
                             etype=et, dir=d)
                _METRICS.set("arena.tier_nnz", float(nnz_i), etype=et, dir=d)
                _METRICS.set("arena.tier_threshold", float(thr),
                             etype=et, dir=d)
        arena_idx = [i for i, t in enumerate(tier_of) if t == "arena"]
        dense_idx = [i for i, t in enumerate(tier_of) if t == "dense"]

        ck_f, ck_b = chunk if isinstance(chunk, tuple) else (chunk, chunk)
        if ck_f is None:
            ck_f = pick_chunk_multi([fwd_b[i] for i in arena_idx], row_block)
        if ck_b is None:
            ck_b = pick_chunk_multi([bwd_b[i] for i in arena_idx], row_block)
        fwd_a = [fuse_bucketed(fwd_b[i], row_block, ck_f) for i in arena_idx]
        bwd_a = [fuse_bucketed(bwd_b[i], row_block, ck_b) for i in arena_idx]

        # Dense-tier tables: each relation's exact edge set (straight from
        # its bucketed packing, so zero-weight padding is dropped the same
        # way the arena drops it) scattered into a stacked matrix over the
        # full type-concat source width; bwd is the materialized transpose.
        dense_offs, doff = {}, 0
        for i in dense_idx:
            dense_offs[i] = doff
            doff += int(n_of[relations[i][2]])
        dense_fwd = np.zeros((doff, n_src_total), np.float32)
        for i in dense_idx:
            d, s, wv = ell_to_coo(fwd_b[i])
            np.add.at(dense_fwd,
                      (d + dense_offs[i], s + src_off[relations[i][1]]), wv)
        dense_bwd = np.ascontiguousarray(dense_fwd.T)

    if pad is not None:
        target = pad if callable(pad) else (lambda et, d, _a: pad[et][d])
        fwd_a = [pad_fused_arena(a, *target(relations[i][0], "fwd", a))
                 for a, i in zip(fwd_a, arena_idx)]
        bwd_a = [pad_fused_arena(a, *target(relations[i][0], "bwd", a))
                 for a, i in zip(bwd_a, arena_idx)]

    # Full output concat over ALL relations (y/gy live here, both tiers);
    # the fwd arena's own output space covers arena-tier rows only.
    out_offs = np.cumsum([0] + [int(n_of[r[2]]) for r in relations])
    arena_out_offs = np.cumsum([0] + [a.n_dst for a in fwd_a])
    src_out_offs = np.cumsum([0] + [a.n_dst for a in bwd_a])  # arena dx
    if arena_idx:
        # fwd: sources live in the type-concat slab, outputs in the
        # arena-only concat; bwd: "sources" are the FULL fwd outputs (gy
        # concat — dense-tier rows are simply never referenced), rows are
        # type-concat source ids (the §2 xi gather reads them).
        fwd, f_offs = _concat_arenas(
            fwd_a,
            nbr_offs=[src_off[relations[i][1]] for i in arena_idx],
            rows_offs=[int(o) for o in arena_out_offs[:-1]],
            n_dst=int(arena_out_offs[-1]), n_src=n_src_total)
        bwd, b_offs = _concat_arenas(
            bwd_a,
            nbr_offs=[int(out_offs[i]) for i in arena_idx],
            rows_offs=[int(o) for o in src_out_offs[:-1]],
            n_dst=int(src_out_offs[-1]), n_src=int(out_offs[-1]))
        bwd_src_rows = np.concatenate(
            [np.asarray(a.rows) + np.int32(src_off[relations[i][1]])
             for a, i in zip(bwd_a, arena_idx)])
    else:
        fwd = _empty_super_arena(0, n_src_total, row_block, int(ck_f or 16))
        bwd = _empty_super_arena(0, int(out_offs[-1]), row_block,
                                 int(ck_b or 16))
        bwd_src_rows = np.zeros(row_block, np.int32)
        f_offs = b_offs = []

    segments = []
    a_pos = 0
    for i, (et, st, dt, _d, _s, _w) in enumerate(relations):
        if tier_of[i] == "arena":
            fa, ba = fwd_a[a_pos], bwd_a[a_pos]
            (fc, fr), (bc, brr) = f_offs[a_pos], b_offs[a_pos]
            segments.append(RelationSegment(
                etype=et, src_type=st, dst_type=dt,
                n_dst=fa.n_dst, n_src=fa.n_src,
                out_off=int(out_offs[i]),
                src_out_off=int(src_out_offs[a_pos]),
                fwd_chunks=(fc, fc + fa.n_chunks),
                bwd_chunks=(bc, bc + ba.n_chunks),
                fwd_rows=(fr, fr + fa.n_arena_rows),
                bwd_rows=(brr, brr + ba.n_arena_rows),
                tier="arena", dense_off=-1,
                arena_out_off=int(arena_out_offs[a_pos])))
            a_pos += 1
        else:
            segments.append(RelationSegment(
                etype=et, src_type=st, dst_type=dt,
                n_dst=int(n_of[dt]), n_src=int(n_of[st]),
                out_off=int(out_offs[i]), src_out_off=-1,
                fwd_chunks=(0, 0), bwd_chunks=(0, 0),
                fwd_rows=(0, 0), bwd_rows=(0, 0),
                tier="dense", dense_off=int(dense_offs[i]),
                arena_out_off=-1))
    return RelationPlan(fwd=fwd, bwd=bwd, bwd_src_rows=bwd_src_rows,
                        dense_fwd=dense_fwd, dense_bwd=dense_bwd,
                        segments=tuple(segments),
                        src_types=src_types,
                        src_off=tuple(src_off[t] for t in src_types),
                        src_sizes=tuple(int(n_of[t]) for t in src_types))
