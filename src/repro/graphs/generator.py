"""Synthetic CircuitNet-like design generator.

CircuitNet itself is a multi-terabyte proprietary-derived dataset; this
module generates designs that reproduce the *structural statistics the paper
depends on* (Table 1 + Fig. 4):

* two node types, |cell| ≈ 7.3k–9.8k, |net| ≈ 3.3k–9.1k per partition;
* ``near`` (cell↔cell geometric) degrees are heavy-tailed with a bulk around
  30–60 and evil rows reaching 250+ (the source of GPU tail lag);
* ``pin``/``pinned`` (cell↔net topological) degrees concentrate at 2–5;
* ``pinned`` is exactly ``pin``ᵀ;
* the congestion label correlates with local wiring density, so rank
  correlation metrics (Pearson/Spearman/Kendall) are learnable.

Scale is controlled with ``scale`` so unit tests run in milliseconds while
benchmarks use paper-size partitions.
"""

from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Tuple

import numpy as np

from repro.graphs.circuit import CircuitGraph, build_circuit_graph, graph_degree_stats

# Table 1 anchor statistics (per-partition node counts for the three designs).
TABLE1 = {
    "small": dict(n_net=(3269, 4628), n_cell=(7347, 7767), graphs=2),
    "medium": dict(n_net=(5331, 7271), n_cell=(9493, 9733), graphs=3),
    "large": dict(n_net=(5883, 9100), n_cell=(9341, 9816), graphs=4),
}


def _powerlaw_degrees(rng, n, bulk=40, tail_max=260, alpha=1.8):
    """Heavy-tailed degrees: lognormal bulk + pareto evil-row tail (Fig. 4)."""
    bulk_deg = rng.lognormal(mean=np.log(bulk), sigma=0.6, size=n)
    evil = rng.random(n) < 0.02
    tail = (rng.pareto(alpha, size=n) + 1.0) * bulk * 2.0
    deg = np.where(evil, tail, bulk_deg)
    return np.clip(deg, 1, tail_max).astype(np.int64)


def generate_partition(rng: np.random.Generator, n_cell: int, n_net: int,
                       feat_cell: int = 16, feat_net: int = 16,
                       near_bulk: int = 40) -> Tuple[Dict, np.ndarray,
                                                     np.ndarray, np.ndarray]:
    """One ~10k-node partition: COO edges + features + congestion label."""
    # --- near: geometric. Place cells on a plane; connect k-nearest by a
    # degree budget drawn from the heavy-tailed distribution.
    pos = rng.random((n_cell, 2)).astype(np.float32)
    deg = _powerlaw_degrees(rng, n_cell, bulk=near_bulk)
    # Approximate spatial neighbors with a grid-bucketed candidate pool:
    # sample candidates biased toward spatial proximity (cheap, preserves
    # the degree law which is what the kernels care about).
    dst_l, src_l = [], []
    order = np.argsort(pos[:, 0], kind="stable")
    rank_of = np.empty(n_cell, np.int64)
    rank_of[order] = np.arange(n_cell)
    for i in range(n_cell):
        d = int(deg[i])
        lo = max(rank_of[i] - 4 * d, 0)
        hi = min(rank_of[i] + 4 * d + 1, n_cell)
        cand = order[lo:hi]
        cand = cand[cand != i]
        if cand.size == 0:
            continue
        take = min(d, cand.size)
        nbrs = rng.choice(cand, size=take, replace=False)
        dst_l.append(np.full(take, i)), src_l.append(nbrs)
    near_dst = np.concatenate(dst_l)
    near_src = np.concatenate(src_l)

    # --- pin: each net touches 2–6 cells (Fig. 4 concentrates at 3–4).
    fanout = rng.integers(2, 7, size=n_net)
    pin_net = np.repeat(np.arange(n_net), fanout)
    pin_cell = rng.integers(0, n_cell, size=pin_net.size)
    # dedupe (cell, net) pairs
    key = pin_cell.astype(np.int64) * n_net + pin_net
    _, uniq = np.unique(key, return_index=True)
    pin_cell, pin_net = pin_cell[uniq], pin_net[uniq]

    coo = {
        "near": (near_dst, near_src),               # dst=cell, src=cell
        "pin": (pin_net, pin_cell),                 # dst=net,  src=cell
        "pinned": (pin_cell, pin_net),              # dst=cell, src=net (pinᵀ)
    }

    # --- features & label. Label = wiring density (near-degree + pin count
    # in the neighborhood), standardized + noise: rank-learnable.
    near_deg = np.bincount(near_dst, minlength=n_cell).astype(np.float32)
    pin_deg = np.bincount(pin_cell, minlength=n_cell).astype(np.float32)
    x_cell = np.stack([pos[:, 0], pos[:, 1],
                       near_deg / near_deg.max(),
                       pin_deg / max(pin_deg.max(), 1.0)], 1)
    x_cell = np.concatenate(
        [x_cell, rng.normal(0, 0.1, (n_cell, feat_cell - 4))], 1
    ).astype(np.float32)
    net_fan = np.bincount(pin_net, minlength=n_net).astype(np.float32)
    x_net = np.concatenate(
        [net_fan[:, None] / max(net_fan.max(), 1.0),
         rng.normal(0, 0.1, (n_net, feat_net - 1))], 1).astype(np.float32)

    dens = near_deg + 2.0 * pin_deg
    dens = (dens - dens.mean()) / (dens.std() + 1e-6)
    y = (dens + rng.normal(0, 0.25, n_cell)).astype(np.float32)
    # congestion maps are in [0, 1]; squash
    y = (1.0 / (1.0 + np.exp(-y))).astype(np.float32)
    return coo, x_cell, x_net, y


def generate_design(seed: int, size: str = "small", scale: float = 1.0,
                    feat_cell: int = 16, feat_net: int = 16,
                    n_threads: int = 3) -> List[CircuitGraph]:
    """A design = list of partitions, per Table 1.  Host-side packing of the
    three subgraphs runs on a thread pool (the paper's 3 CPU init threads)."""
    spec = TABLE1[size]
    rng = np.random.default_rng(seed)
    graphs = []
    for g in range(spec["graphs"]):
        lo_c, hi_c = spec["n_cell"]
        lo_n, hi_n = spec["n_net"]
        n_cell = max(int(rng.integers(lo_c, hi_c + 1) * scale), 16)
        n_net = max(int(rng.integers(lo_n, hi_n + 1) * scale), 8)
        coo, xc, xn, y = generate_partition(rng, n_cell, n_net,
                                            feat_cell, feat_net)
        graphs.append(pack_graph_parallel(coo, n_cell, n_net, xc, xn, y,
                                          n_threads=n_threads))
    return graphs


def pack_graph_parallel(coo, n_cell, n_net, xc, xn, y, n_threads: int = 3
                        ) -> CircuitGraph:
    """Pack the three subgraphs concurrently (paper Sec. 3.4: per-subgraph
    CPU init threads).  Falls back to serial when n_threads == 1."""
    if n_threads <= 1:
        return build_circuit_graph(coo, n_cell, n_net, xc, xn, y)
    from repro.graphs.circuit import EDGE_SCHEMA, EdgeSet
    from repro.graphs.ell import pack_ell_pair
    import numpy as _np

    sizes = {"cell": n_cell, "net": n_net}

    def pack_one(et):
        dst, src = coo[et]
        s_t, d_t = EDGE_SCHEMA[et]
        n_dst, n_src = sizes[d_t], sizes[s_t]
        deg = _np.bincount(dst, minlength=n_dst).astype(_np.float32)
        w = 1.0 / _np.maximum(deg[dst], 1.0)
        adj, adj_t = pack_ell_pair(dst, src, w, n_dst, n_src)
        return et, EdgeSet(adj=adj, adj_t=adj_t)

    with ThreadPoolExecutor(max_workers=n_threads) as pool:
        edges = dict(pool.map(pack_one, list(coo)))
    import jax.numpy as jnp
    return CircuitGraph(n_cell=n_cell, n_net=n_net, edges=edges,
                        x_cell=jnp.asarray(xc), x_net=jnp.asarray(xn),
                        y_cell=jnp.asarray(y))


def design_stats(coo, n_cell, n_net):
    return graph_degree_stats(coo, n_cell, n_net)
