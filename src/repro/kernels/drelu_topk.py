"""Pallas kernel: D-ReLU row thresholding via row-wise binary search.

The paper (Sec. 3.1) describes D-ReLU as "selectively preserv[ing] the most
significant elements of node embeddings through row-wise *binary search*".
``lax.top_k`` implements the same semantics with a sort — O(D log D) compare
-exchanges and poor TPU lowering.  This kernel does what the paper says:
bisection on the value range, counting survivors per row with a vector
compare+reduce per iteration — O(D · iters) elementwise work, fully
vectorizable on the VPU, no sort network.

For f32 inputs, ~64 bisection steps shrink the bracket below 1 ULP around
the k-th value, making the mask exactly the top-k mask whenever the row has
distinct values (ties keep all tied elements — same convention as Eq. 3,
which thresholds with ≥).

Grid: row blocks of the (N, D) matrix; each block resident in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.graphs.ell import ROW_BLOCK
from repro.kernels.drspmm import INTERPRET

N_ITERS = 64


def _bisect_threshold(x, k, n_iters=N_ITERS):
    """Per-row threshold th with |{j : x[i,j] >= th}| == k (distinct values).

    x (R, D) f32 values in VMEM.  Pure jnp — shared by kernel & oracle.
    """
    lo = x.min(axis=1)                       # count(>= lo) == D  (too many)
    hi = x.max(axis=1)                       # count(>= hi) >= 1

    def body(_, carry):
        lo_, hi_ = carry
        mid = 0.5 * (lo_ + hi_)
        cnt = jnp.sum(x >= mid[:, None], axis=1)
        take_hi = cnt > k                    # too many kept -> raise floor
        lo_ = jnp.where(take_hi, mid, lo_)
        hi_ = jnp.where(take_hi, hi_, mid)
        return lo_, hi_

    lo, hi = jax.lax.fori_loop(0, n_iters, body, (lo, hi))
    # hi is the tightest bound with count <= k; keep x >= hi, then relax to
    # the k-th value exactly by taking the min of the kept set.
    return hi


def _drelu_kernel(x_ref, out_ref, *, k: int):
    x = x_ref[...].astype(jnp.float32)
    th = _bisect_threshold(x, k)
    keep = x >= th[:, None]
    # ties below machine resolution can overshoot: fall back on >= exactness
    out = jnp.where(keep, x, 0.0)
    out_ref[...] = out.astype(out_ref.dtype)


def drelu_pallas(x: jax.Array, k: int, *, block_rows: int = ROW_BLOCK,
                 interpret: bool | None = None) -> jax.Array:
    """Dense D-ReLU via the binary-search kernel.  x (N, D)."""
    if interpret is None:
        interpret = INTERPRET
    n, d = x.shape
    if k >= d:
        return x
    br = min(block_rows, n)
    pad = (-n) % br
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    out = pl.pallas_call(
        functools.partial(_drelu_kernel, k=k),
        grid=((n + pad) // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n + pad, d), x.dtype),
        interpret=interpret,
    )(xp)
    return out[:n] if pad else out
