"""Pallas TPU kernels for DR-SpMM (forward) and sampled DR-SpMM (backward).

Forward (Alg. 1):   Y[i, :] += w_ij * scatter(X_vals[j], X_idx[j])   over j∈N(i)
Backward (Alg. 2):  dV[j, t]  += w_ij * dY[i, X_idx[j, t]]           over i∈N(j)

Layout / TPU mapping
--------------------
Two execution strategies share the same math:

* **Per-bucket** (reference): one ``pallas_call`` per degree bucket (see
  graphs/ell.py): the grid walks row-blocks of that bucket's ELL slab; the
  slab width E is the bucket's max degree, so short rows never pay evil-row
  padding — the paper's dynamic warp partitioning expressed structurally.
* **Fused** (default hot path, DESIGN.md §1): ALL buckets in ONE
  ``pallas_call``.  The :class:`~repro.graphs.ell.FusedELL` arena stores
  uniform (BR, Ec) neighbor chunks; the grid walks chunks, and a
  scalar-prefetch metadata table routes each chunk's accumulation into its
  output row-block (grouped-matmul revisit pattern — consecutive grid steps
  hit the same output block, so the block stays VMEM-resident and no atomics
  or host-side combines are needed).

Shared kernel-body idioms:

* Neighbors are processed in **E-chunks**: one ``(BR, Ec·k) × (BR, Ec·k, D)``
  MXU contraction per chunk instead of a serial per-neighbor einsum.
* The scatter of k CBSR values into a D-wide accumulator is computed as a
  one-hot contraction ``vals · onehot(idx)`` so it maps onto the MXU instead
  of a serial scatter (TPUs have no fast in-kernel scatter).
* **D-tiling**: when the embedding dim exceeds ``D_TILE`` (128, one MXU
  lane-width) and divides evenly, the grid gains a D-tile dimension and each
  step materializes only a (…, D_TILE) slice of the one-hot — ``hidden >
  128`` no longer forces whole-array VMEM residency of the accumulator.
* Accumulation is fp32 in VMEM regardless of input dtype.

Validated with ``interpret=True`` on CPU against kernels/ref.py; on real TPU
the same code lowers via Mosaic (jnp.take of rows lowers to dynamic gathers
along the sublane dimension).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.graphs.ell import (ELLBucket, FusedELL, ROW_BLOCK, EDGE_CHUNK,
                              _round_up)

# CPU has no Mosaic backend: interpret the kernel bodies.  On TPU this flips
# to False automatically and the kernels compile natively.
INTERPRET = jax.default_backend() != "tpu"

# One MXU lane-width: D-tiling granularity for wide embeddings.
D_TILE = 128


def _d_tiling(dim: int) -> tuple:
    """(tile, n_tiles): tile the D axis at 128 when it divides evenly."""
    if dim > D_TILE and dim % D_TILE == 0:
        return D_TILE, dim // D_TILE
    return dim, 1


def _chunked_reduce(nbr, w, contrib, acc, chunk: int):
    """acc + Σ_chunks contrib(nbr_chunk, w_chunk) with O(1) trace size.

    Full chunks run under a fori_loop with dynamic slices (one traced body
    regardless of slab width — evil-row buckets don't inflate the jaxpr);
    the partial tail chunk, whose width is static, is added unrolled."""
    e_width = nbr.shape[1]
    n_full, rem = divmod(e_width, chunk)
    if n_full:
        def body(ci, a):
            nb = jax.lax.dynamic_slice_in_dim(nbr, ci * chunk, chunk, axis=1)
            wc = jax.lax.dynamic_slice_in_dim(w, ci * chunk, chunk, axis=1)
            return a + contrib(nb, wc)
        acc = jax.lax.fori_loop(0, n_full, body, acc)
    if rem:
        acc = acc + contrib(nbr[:, n_full * chunk:], w[:, n_full * chunk:])
    return acc


# ---------------------------------------------------------------------------
# per-bucket forward (reference path)
# ---------------------------------------------------------------------------

def _fwd_kernel(nbr_ref, w_ref, xv_ref, xi_ref, out_ref, *, d_tile: int,
                chunk: int):
    """One row-block: aggregate E neighbors' CBSR rows into (BR, DT) output.

    The neighbor axis is walked in Ec-chunks; each chunk is one
    (BR, Ec·k) × (BR, Ec·k, DT) one-hot contraction on the MXU.
    """
    nbr = nbr_ref[...]            # (BR, E) int32
    w = w_ref[...]                # (BR, E)
    xv = xv_ref[...]              # (N, k)
    xi = xi_ref[...]              # (N, k) int32
    br, e_width = nbr.shape
    k = xv.shape[1]

    d_base = pl.program_id(1) * d_tile
    iota_d = jax.lax.broadcasted_iota(jnp.int32, (1, 1, d_tile), 2) + d_base

    def contrib(nb, wc):                              # (BR, Ec) chunk
        ec = nb.shape[1]
        flat = nb.reshape(-1)
        v = jnp.take(xv, flat, axis=0).reshape(br, ec, k)
        col = jnp.take(xi, flat, axis=0).reshape(br, ec * k)
        vw = (v.astype(jnp.float32)
              * wc.astype(jnp.float32)[..., None]).reshape(br, ec * k)
        onehot = (col[:, :, None] == iota_d).astype(jnp.float32)
        return jnp.einsum("bm,bmd->bd", vw, onehot)

    acc = _chunked_reduce(nbr, w, contrib,
                          jnp.zeros((br, d_tile), jnp.float32), chunk)
    out_ref[...] = acc.astype(out_ref.dtype)


def drspmm_fwd_bucket(bucket: ELLBucket, x_vals: jax.Array, x_idx: jax.Array,
                      dim: int, *, interpret: bool | None = None) -> jax.Array:
    """Y_bucket (R, dim) for one degree bucket (rows still bucket-local)."""
    if interpret is None:
        interpret = INTERPRET
    r, e = bucket.nbr.shape
    n, k = x_vals.shape
    br = min(ROW_BLOCK, r)
    dt, ndt = _d_tiling(dim)
    grid = (r // br, ndt)
    return pl.pallas_call(
        functools.partial(_fwd_kernel, d_tile=dt, chunk=EDGE_CHUNK),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, e), lambda i, j: (i, 0)),       # nbr row-block
            pl.BlockSpec((br, e), lambda i, j: (i, 0)),       # w   row-block
            pl.BlockSpec((n, k), lambda i, j: (0, 0)),        # x_vals (whole)
            pl.BlockSpec((n, k), lambda i, j: (0, 0)),        # x_idx  (whole)
        ],
        out_specs=pl.BlockSpec((br, dt), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, dim), x_vals.dtype),
        interpret=interpret,
    )(bucket.nbr, bucket.w, x_vals, x_idx)


# ---------------------------------------------------------------------------
# per-bucket backward (SSpMM): gradients sampled at the forward's CBSR indices
# ---------------------------------------------------------------------------

def _bwd_kernel(tnbr_ref, tw_ref, gy_ref, xi_ref, out_ref, *, chunk: int):
    """One source-row-block: dV[j, t] = Σ_i w_ij · dY[i, idx[j, t]].

    ``tnbr``/``tw`` come from the *transposed* ELL packing, so each source row
    j is owned by exactly one grid cell — accumulation is a private VMEM
    reduction, no atomics (DESIGN.md §2).  Targets are gathered Ec at a time.
    """
    tnbr = tnbr_ref[...]          # (BR, E) target ids i ∈ N(j)
    tw = tw_ref[...]              # (BR, E)
    gy = gy_ref[...]              # (M, D)
    xi = xi_ref[...]              # (BR, k) — this block's CBSR indices
    br, e_width = tnbr.shape
    k = xi.shape[1]

    def contrib(ic, wc):                              # (BR, Ec) chunk
        ec = ic.shape[1]
        g = jnp.take(gy, ic.reshape(-1), axis=0).reshape(br, ec, -1)
        idx = jnp.broadcast_to(xi[:, None, :], (br, ec, k))
        sampled = jnp.take_along_axis(g, idx, axis=2)  # (BR, Ec, k) — SSpMM
        return jnp.einsum("be,bek->bk", wc.astype(jnp.float32),
                          sampled.astype(jnp.float32))

    acc = _chunked_reduce(tnbr, tw, contrib,
                          jnp.zeros((br, k), jnp.float32), chunk)
    out_ref[...] = acc.astype(out_ref.dtype)


def drspmm_bwd_bucket(bucket: ELLBucket, gy: jax.Array, xi_rows: jax.Array,
                      *, interpret: bool | None = None) -> jax.Array:
    """dV_bucket (R, k) for one transposed-ELL bucket.

    ``xi_rows`` is x_idx gathered at this bucket's source rows, shape (R, k).
    """
    if interpret is None:
        interpret = INTERPRET
    r, e = bucket.nbr.shape
    m, d = gy.shape
    k = xi_rows.shape[1]
    br = min(ROW_BLOCK, r)
    grid = (r // br,)
    return pl.pallas_call(
        functools.partial(_bwd_kernel, chunk=EDGE_CHUNK),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, e), lambda i: (i, 0)),
            pl.BlockSpec((br, e), lambda i: (i, 0)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),           # dY (whole)
            pl.BlockSpec((br, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, k), gy.dtype),
        interpret=interpret,
    )(bucket.nbr, bucket.w, gy, xi_rows)


# ---------------------------------------------------------------------------
# per-bucket dense-operand SpMM kernel (baseline, cuSPARSE-analogue) — same
# bucketed ELL traversal but the operand is a full (N, D) matrix; lets
# benchmarks compare the CBSR gather traffic (N·k) against the dense gather
# traffic (N·D) under identical scheduling.
# ---------------------------------------------------------------------------

def _dense_kernel(nbr_ref, w_ref, x_ref, out_ref, *, chunk: int):
    nbr = nbr_ref[...]
    w = w_ref[...]
    x = x_ref[...]                # (N, DT) — D-tiled slice
    br, e_width = nbr.shape
    d = x.shape[1]

    def contrib(nb, wc):                              # (BR, Ec) chunk
        ec = nb.shape[1]
        rows = jnp.take(x, nb.reshape(-1), axis=0).reshape(br, ec, d)
        return jnp.einsum("be,bed->bd", wc.astype(jnp.float32),
                          rows.astype(jnp.float32))

    acc = _chunked_reduce(nbr, w, contrib,
                          jnp.zeros((br, d), jnp.float32), chunk)
    out_ref[...] = acc.astype(out_ref.dtype)


def spmm_dense_bucket(bucket: ELLBucket, x: jax.Array,
                      *, interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = INTERPRET
    r, e = bucket.nbr.shape
    n, d = x.shape
    br = min(ROW_BLOCK, r)
    dt, ndt = _d_tiling(d)
    return pl.pallas_call(
        functools.partial(_dense_kernel, chunk=EDGE_CHUNK),
        grid=(r // br, ndt),
        in_specs=[
            pl.BlockSpec((br, e), lambda i, j: (i, 0)),
            pl.BlockSpec((br, e), lambda i, j: (i, 0)),
            pl.BlockSpec((n, dt), lambda i, j: (0, j)),       # D-tiled operand
        ],
        out_specs=pl.BlockSpec((br, dt), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=interpret,
    )(bucket.nbr, bucket.w, x)


# ---------------------------------------------------------------------------
# fused single-dispatch executors — ONE pallas_call for ALL buckets
# ---------------------------------------------------------------------------
#
# Grid = (D-tiles, chunks).  Chunks of the same output row-block are
# consecutive in the arena, so the output BlockSpec's scalar-prefetch index
# map (blk[c]) revisits each block in an unbroken run: the block stays
# VMEM-resident across its chunks and is zero-initialized by the chunk whose
# ``start`` flag is set.  See DESIGN.md §1.

def _fused_fwd_kernel(blk_ref, st_ref, nbr_ref, w_ref, xv_ref, xi_ref,
                      out_ref, *, d_tile: int):
    c = pl.program_id(1)

    @pl.when(st_ref[c] == 1)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    nbr = nbr_ref[0]              # (BR, Ec)
    w = w_ref[0].astype(jnp.float32)
    xv = xv_ref[...]              # (N, k)
    xi = xi_ref[...]
    br, ec = nbr.shape
    k = xv.shape[1]

    d_base = pl.program_id(0) * d_tile
    iota_d = jax.lax.broadcasted_iota(jnp.int32, (1, 1, d_tile), 2) + d_base

    flat = nbr.reshape(-1)
    v = jnp.take(xv, flat, axis=0).reshape(br, ec, k)
    col = jnp.take(xi, flat, axis=0).reshape(br, ec * k)
    vw = (v.astype(jnp.float32) * w[..., None]).reshape(br, ec * k)
    onehot = (col[:, :, None] == iota_d).astype(jnp.float32)
    out_ref[...] += jnp.einsum("bm,bmd->bd", vw, onehot).astype(out_ref.dtype)


def drspmm_fwd_fused(fused: FusedELL, x_vals: jax.Array, x_idx: jax.Array,
                     dim: int, *, interpret: bool | None = None) -> jax.Array:
    """Arena-ordered Y (R_arena, dim) in ONE kernel launch.

    Read the caller-ordered output with ``jnp.take(y, fused.gather, 0)``.
    """
    if interpret is None:
        interpret = INTERPRET
    c, br, ec = fused.nbr.shape
    n, k = x_vals.shape
    dt, ndt = _d_tiling(dim)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(ndt, c),
        in_specs=[
            pl.BlockSpec((1, br, ec), lambda d, i, blk, st: (i, 0, 0)),
            pl.BlockSpec((1, br, ec), lambda d, i, blk, st: (i, 0, 0)),
            pl.BlockSpec((n, k), lambda d, i, blk, st: (0, 0)),
            pl.BlockSpec((n, k), lambda d, i, blk, st: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, dt), lambda d, i, blk, st: (blk[i], d)),
    )
    return pl.pallas_call(
        functools.partial(_fused_fwd_kernel, d_tile=dt),
        grid_spec=grid_spec,
        # fp32 accumulator arena regardless of input dtype (chunk revisits
        # accumulate in the out buffer); the op wrapper casts after gather.
        out_shape=jax.ShapeDtypeStruct((fused.n_arena_rows, dim),
                                       jnp.float32),
        interpret=interpret,
    )(fused.block_of, fused.start, fused.nbr, fused.w, x_vals, x_idx)


def _fused_bwd_kernel(blk_ref, st_ref, tnbr_ref, tw_ref, gy_ref, xi_ref,
                      out_ref):
    c = pl.program_id(0)

    @pl.when(st_ref[c] == 1)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    tnbr = tnbr_ref[0]            # (BR, Ec)
    tw = tw_ref[0].astype(jnp.float32)
    gy = gy_ref[...]              # (M, D)
    xi = xi_ref[...]              # (BR, k) — this arena block's CBSR indices
    br, ec = tnbr.shape
    k = xi.shape[1]

    g = jnp.take(gy, tnbr.reshape(-1), axis=0).reshape(br, ec, -1)
    idx = jnp.broadcast_to(xi[:, None, :], (br, ec, k))
    sampled = jnp.take_along_axis(g, idx, axis=2)      # (BR, Ec, k) — SSpMM
    out_ref[...] += jnp.einsum("be,bek->bk", tw,
                               sampled.astype(jnp.float32)).astype(out_ref.dtype)


def drspmm_bwd_fused(fused_t: FusedELL, gy: jax.Array, xi_arena: jax.Array,
                     *, interpret: bool | None = None) -> jax.Array:
    """Arena-ordered dV (R_arena, k) in ONE kernel launch.

    ``fused_t`` is the fused *transposed* packing; ``xi_arena`` is x_idx
    gathered at ``fused_t.rows`` (arena source order), shape (R_arena, k).
    """
    if interpret is None:
        interpret = INTERPRET
    c, br, ec = fused_t.nbr.shape
    m, d = gy.shape
    k = xi_arena.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((1, br, ec), lambda i, blk, st: (i, 0, 0)),
            pl.BlockSpec((1, br, ec), lambda i, blk, st: (i, 0, 0)),
            pl.BlockSpec((m, d), lambda i, blk, st: (0, 0)),
            pl.BlockSpec((br, k), lambda i, blk, st: (blk[i], 0)),
        ],
        out_specs=pl.BlockSpec((br, k), lambda i, blk, st: (blk[i], 0)),
    )
    return pl.pallas_call(
        _fused_bwd_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((fused_t.n_arena_rows, k),
                                       jnp.float32),
        interpret=interpret,
    )(fused_t.block_of, fused_t.start, fused_t.nbr, fused_t.w, gy, xi_arena)


# ---------------------------------------------------------------------------
# relation-fused super-arena executors — ONE pallas_call for a hetero layer's
# WHOLE direction-group (every edge-type direction at once, DESIGN.md §9).
#
# A RelationPlan (graphs/ell.py) bakes the relation routing into the §1
# metadata: `nbr` is pre-offset into the type-concat source slab, `block_of`
# spans the per-relation chunk segments, and the output rows are the
# relation-concat arena.  The kernel bodies above therefore run UNCHANGED —
# relation selection costs zero in-kernel work; what these wrappers add is
# the super-arena contract (a `rel` chunk table must be present) and, for
# the backward, the arena-ordered xi gather at the plan's type-concat source
# row map.
# ---------------------------------------------------------------------------

def drspmm_fwd_multi(super_fwd: FusedELL, x_vals: jax.Array,
                     x_idx: jax.Array, dim: int,
                     *, interpret: bool | None = None) -> jax.Array:
    """Arena-ordered Y for ALL relations of a direction-group in ONE
    ``pallas_call``.

    ``x_vals``/``x_idx`` are the type-concat CBSR operands (every source
    node type stacked, k padded to the group max); read the relation-concat
    output with ``jnp.take(y, super_fwd.gather, 0)`` and slice per relation
    at the plan's ``out_off`` offsets.
    """
    assert super_fwd.rel is not None, \
        "drspmm_fwd_multi needs a relation-fused super-arena (RelationPlan)"
    return drspmm_fwd_fused(super_fwd, x_vals, x_idx, dim,
                            interpret=interpret)


def drspmm_bwd_multi(super_bwd: FusedELL, bwd_src_rows: jax.Array,
                     gy_cat: jax.Array, x_idx: jax.Array,
                     *, interpret: bool | None = None) -> jax.Array:
    """Arena-ordered dV for ALL relations in ONE transposed ``pallas_call``.

    ``gy_cat`` is the concatenated per-relation output cotangent (the
    forward's relation-concat order); ``bwd_src_rows`` maps bwd arena rows
    to type-concat source ids, so the §2 sampled backward reads each arena
    row's own CBSR indices out of the type-concat ``x_idx``.  Read the
    relation-concat dV with ``jnp.take(dv, super_bwd.gather, 0)`` and sum
    segments per source type (a node type feeding several relations — cell
    feeds both ``near`` and ``pin`` — accumulates across its segments).
    """
    assert super_bwd.rel is not None, \
        "drspmm_bwd_multi needs a relation-fused super-arena (RelationPlan)"
    xi_arena = jnp.take(x_idx, jnp.asarray(bwd_src_rows), axis=0)
    return drspmm_bwd_fused(super_bwd, gy_cat, xi_arena, interpret=interpret)


def _fused_dense_kernel(blk_ref, st_ref, nbr_ref, w_ref, x_ref, out_ref):
    c = pl.program_id(1)

    @pl.when(st_ref[c] == 1)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    nbr = nbr_ref[0]
    w = w_ref[0].astype(jnp.float32)
    x = x_ref[...]                # (N, DT) — D-tiled slice
    br, ec = nbr.shape
    d = x.shape[1]
    rows = jnp.take(x, nbr.reshape(-1), axis=0).reshape(br, ec, d)
    out_ref[...] += jnp.einsum("be,bed->bd", w,
                               rows.astype(jnp.float32)).astype(out_ref.dtype)


def spmm_dense_fused(fused: FusedELL, x: jax.Array,
                     *, interpret: bool | None = None) -> jax.Array:
    """Dense-operand SpMM over the fused arena — ONE kernel launch."""
    if interpret is None:
        interpret = INTERPRET
    c, br, ec = fused.nbr.shape
    n, d = x.shape
    dt, ndt = _d_tiling(d)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(ndt, c),
        in_specs=[
            pl.BlockSpec((1, br, ec), lambda dd, i, blk, st: (i, 0, 0)),
            pl.BlockSpec((1, br, ec), lambda dd, i, blk, st: (i, 0, 0)),
            pl.BlockSpec((n, dt), lambda dd, i, blk, st: (0, dd)),
        ],
        out_specs=pl.BlockSpec((br, dt), lambda dd, i, blk, st: (blk[i], dd)),
    )
    return pl.pallas_call(
        _fused_dense_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((fused.n_arena_rows, d), jnp.float32),
        interpret=interpret,
    )(fused.block_of, fused.start, fused.nbr, fused.w, x)


# ---------------------------------------------------------------------------
# dense-tier executors — tiny relations (nnz ≤ DENSE_TIER_NNZ, graphs/ell.py)
# skip the chunk-walk arena entirely: the plan materializes the relation
# stack as ONE dense matrix and the whole tier runs as a single masked
# matmul (fwd) / single transposed matmul + in-kernel CBSR sampling (bwd).
# Same custom-vjp contract as the arena path: grad flows to x_vals only,
# sampled at x_idx (SSpMM).  DESIGN.md §14.
# ---------------------------------------------------------------------------

DENSE_TIER_ROW_BLOCK = 8      # output rows per grid step (fwd M / bwd N)
DENSE_TIER_SRC_CHUNK = 128    # source rows per scatter-densify step (fwd)


def _dense_tier_fwd_kernel(a_ref, xv_ref, xi_ref, out_ref,
                           *, d_tile: int, n_chunk: int):
    a = a_ref[...].astype(jnp.float32)        # (RB, Np)
    xv = xv_ref[...].astype(jnp.float32)      # (Np, k)
    xi = xi_ref[...]                          # (Np, k)
    rb, npad = a.shape

    d_base = pl.program_id(0) * d_tile
    iota_d = jax.lax.broadcasted_iota(jnp.int32, (1, 1, d_tile), 2) + d_base

    def body(c, acc):
        off = c * n_chunk
        vc = jax.lax.dynamic_slice_in_dim(xv, off, n_chunk, 0)   # (NC, k)
        ic = jax.lax.dynamic_slice_in_dim(xi, off, n_chunk, 0)
        ac = jax.lax.dynamic_slice_in_dim(a, off, n_chunk, 1)    # (RB, NC)
        onehot = (ic[:, :, None] == iota_d).astype(jnp.float32)  # (NC, k, DT)
        xd = jnp.einsum("nk,nkd->nd", vc, onehot)                # (NC, DT)
        return acc + ac @ xd

    acc = jnp.zeros((rb, d_tile), jnp.float32)
    acc = jax.lax.fori_loop(0, npad // n_chunk, body, acc)
    out_ref[...] = acc.astype(out_ref.dtype)


def drspmm_dense_tier_fwd(a_dense: jax.Array, x_vals: jax.Array,
                          x_idx: jax.Array, dim: int,
                          *, interpret: bool | None = None) -> jax.Array:
    """Y = A·dense(CBSR(x)) for a dense-tier relation stack in ONE launch.

    ``a_dense`` is the (M, N) dense relation matrix (the plan's
    ``dense_fwd``); the CBSR operand is scatter-densified in-kernel in
    source chunks, so no (N, dim) intermediate is ever materialized in HBM.
    Returns fp32 (M, dim); the op wrapper casts.
    """
    if interpret is None:
        interpret = INTERPRET
    m, n = a_dense.shape
    k = x_vals.shape[1]
    if m == 0 or n == 0:
        return jnp.zeros((m, dim), jnp.float32)
    rb = DENSE_TIER_ROW_BLOCK
    nc = min(DENSE_TIER_SRC_CHUNK, _round_up(n, 8))
    mp = _round_up(m, rb)
    npad = _round_up(n, nc)
    # constant-folded under jit: shapes are static, pads are zeros (padded
    # x_idx rows point at column 0 but carry zero values — inert).
    a_p = jnp.pad(a_dense, ((0, mp - m), (0, npad - n)))
    xv_p = jnp.pad(x_vals, ((0, npad - n), (0, 0)))
    xi_p = jnp.pad(x_idx, ((0, npad - n), (0, 0)))
    dt, ndt = _d_tiling(dim)
    y = pl.pallas_call(
        functools.partial(_dense_tier_fwd_kernel, d_tile=dt, n_chunk=nc),
        grid=(ndt, mp // rb),
        in_specs=[
            pl.BlockSpec((rb, npad), lambda d, i: (i, 0)),
            pl.BlockSpec((npad, k), lambda d, i: (0, 0)),
            pl.BlockSpec((npad, k), lambda d, i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rb, dt), lambda d, i: (i, d)),
        out_shape=jax.ShapeDtypeStruct((mp, dim), jnp.float32),
        interpret=interpret,
    )(a_p, xv_p, xi_p)
    return y[:m]


def _dense_tier_bwd_kernel(at_ref, gy_ref, xi_ref, out_ref):
    at = at_ref[...].astype(jnp.float32)      # (RB, M)
    gy = gy_ref[...].astype(jnp.float32)      # (M, D)
    xi = xi_ref[...]                          # (RB, k)
    dx = at @ gy                              # (RB, D) — dense row cotangent
    out_ref[...] = jnp.take_along_axis(dx, xi, axis=1).astype(out_ref.dtype)


def drspmm_dense_tier_bwd(a_dense_t: jax.Array, gy: jax.Array,
                          x_idx: jax.Array,
                          *, interpret: bool | None = None) -> jax.Array:
    """dV = sample(Aᵀ·gY, x_idx) for the dense tier in ONE launch.

    ``a_dense_t`` is the transposed relation matrix (the plan's
    ``dense_bwd``, (N, M)); the SSpMM sampling happens in-kernel via
    ``take_along_axis`` at each source row's own CBSR indices, so the
    (N, dim) dense cotangent never leaves VMEM.  Returns fp32 (N, k).
    """
    if interpret is None:
        interpret = INTERPRET
    n, m = a_dense_t.shape
    k = x_idx.shape[1]
    if n == 0 or m == 0:
        return jnp.zeros((n, k), jnp.float32)
    rb = DENSE_TIER_ROW_BLOCK
    npad = _round_up(n, rb)
    at_p = jnp.pad(a_dense_t, ((0, npad - n), (0, 0)))
    xi_p = jnp.pad(x_idx, ((0, npad - n), (0, 0)))
    d = gy.shape[1]
    dv = pl.pallas_call(
        _dense_tier_bwd_kernel,
        grid=(npad // rb,),
        in_specs=[
            pl.BlockSpec((rb, m), lambda i: (i, 0)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),
            pl.BlockSpec((rb, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rb, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((npad, k), jnp.float32),
        interpret=interpret,
    )(at_p, gy, xi_p)
    return dv[:n]


# ---------------------------------------------------------------------------
# fused learnable-edge executors — Y = A(w)·dense(CBSR(x)) with the weight
# vector w (nnz,) gathered IN-KERNEL from the arena's eid table, so the
# differentiable-edge path (kernels/ops.py::drspmm_learnable) is the same
# single dispatch per direction as the fixed-weight path.  DESIGN.md §8.
# ---------------------------------------------------------------------------

def _pad_w_canon(w_canon: jax.Array, nnz: int) -> jax.Array:
    """(nnz,) → (W, 1) with W = nnz+1 rounded up to the row block: slot
    ``nnz`` (and everything after) is guaranteed zero, so −1-padded eids
    remapped to ``nnz`` gather an inert weight.  2-D so the in-kernel gather
    is the same row-take the CBSR operands use."""
    wpad = _round_up(nnz + 1, ROW_BLOCK)
    wp = jnp.zeros((wpad, 1), jnp.float32)
    return wp.at[:nnz, 0].set(w_canon.astype(jnp.float32))


def _gather_chunk_w(wp, eid, nnz: int):
    """(BR, Ec) weight chunk from the padded canonical vector; −1 → 0."""
    safe = jnp.where(eid < 0, nnz, eid)
    br, ec = eid.shape
    return jnp.take(wp, safe.reshape(-1), axis=0).reshape(br, ec)


def _fused_fwd_learnable_kernel(blk_ref, st_ref, nbr_ref, eid_ref, wp_ref,
                                xv_ref, xi_ref, out_ref, *, d_tile: int,
                                nnz: int):
    c = pl.program_id(1)

    @pl.when(st_ref[c] == 1)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    nbr = nbr_ref[0]              # (BR, Ec)
    eid = eid_ref[0]              # (BR, Ec) int32, −1 padding
    wp = wp_ref[...]              # (W, 1) padded canonical weights
    xv = xv_ref[...]              # (N, k)
    xi = xi_ref[...]
    br, ec = nbr.shape
    k = xv.shape[1]
    w = _gather_chunk_w(wp, eid, nnz)                 # in-kernel weight gather

    d_base = pl.program_id(0) * d_tile
    iota_d = jax.lax.broadcasted_iota(jnp.int32, (1, 1, d_tile), 2) + d_base

    flat = nbr.reshape(-1)
    v = jnp.take(xv, flat, axis=0).reshape(br, ec, k)
    col = jnp.take(xi, flat, axis=0).reshape(br, ec * k)
    vw = (v.astype(jnp.float32) * w[..., None]).reshape(br, ec * k)
    onehot = (col[:, :, None] == iota_d).astype(jnp.float32)
    out_ref[...] += jnp.einsum("bm,bmd->bd", vw, onehot).astype(out_ref.dtype)


def drspmm_fwd_learnable_fused(fused: FusedELL, nnz: int,
                               w_canon: jax.Array, x_vals: jax.Array,
                               x_idx: jax.Array, dim: int,
                               *, interpret: bool | None = None) -> jax.Array:
    """Arena-ordered Y = A(w)·dense(CBSR(x)) in ONE kernel launch.

    ``fused`` must carry an eid arena (``fuse_bucketed(..., eids=True)``).
    Read the caller-ordered output with ``jnp.take(y, fused.gather, 0)``.
    """
    if interpret is None:
        interpret = INTERPRET
    assert fused.eid is not None, "learnable executor needs an eid arena"
    c, br, ec = fused.nbr.shape
    n, k = x_vals.shape
    wp = _pad_w_canon(w_canon, nnz)
    wlen = wp.shape[0]
    dt, ndt = _d_tiling(dim)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(ndt, c),
        in_specs=[
            pl.BlockSpec((1, br, ec), lambda d, i, blk, st: (i, 0, 0)),
            pl.BlockSpec((1, br, ec), lambda d, i, blk, st: (i, 0, 0)),
            pl.BlockSpec((wlen, 1), lambda d, i, blk, st: (0, 0)),
            pl.BlockSpec((n, k), lambda d, i, blk, st: (0, 0)),
            pl.BlockSpec((n, k), lambda d, i, blk, st: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, dt), lambda d, i, blk, st: (blk[i], d)),
    )
    return pl.pallas_call(
        functools.partial(_fused_fwd_learnable_kernel, d_tile=dt, nnz=nnz),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((fused.n_arena_rows, dim),
                                       jnp.float32),
        interpret=interpret,
    )(fused.block_of, fused.start, fused.nbr, fused.eid, wp, x_vals, x_idx)


def _fused_bwd_learnable_kernel(blk_ref, st_ref, tnbr_ref, teid_ref, wp_ref,
                                gy_ref, xi_ref, out_ref, *, nnz: int):
    c = pl.program_id(0)

    @pl.when(st_ref[c] == 1)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    tnbr = tnbr_ref[0]            # (BR, Ec) target ids i ∈ N(j)
    teid = teid_ref[0]            # (BR, Ec) canonical edge ids
    wp = wp_ref[...]              # (W, 1)
    gy = gy_ref[...]              # (M, D)
    xi = xi_ref[...]              # (BR, k) — this arena block's CBSR indices
    br, ec = tnbr.shape
    k = xi.shape[1]
    tw = _gather_chunk_w(wp, teid, nnz)

    g = jnp.take(gy, tnbr.reshape(-1), axis=0).reshape(br, ec, -1)
    idx = jnp.broadcast_to(xi[:, None, :], (br, ec, k))
    sampled = jnp.take_along_axis(g, idx, axis=2)      # (BR, Ec, k) — SSpMM
    out_ref[...] += jnp.einsum("be,bek->bk", tw,
                               sampled.astype(jnp.float32)).astype(out_ref.dtype)


def drspmm_bwd_learnable_fused(fused_t: FusedELL, nnz: int,
                               w_canon: jax.Array, gy: jax.Array,
                               xi_arena: jax.Array,
                               *, interpret: bool | None = None) -> jax.Array:
    """Arena-ordered dL/dx_vals (R_arena, k) in ONE kernel launch — the
    transposed sampled backward with the same in-kernel weight gather."""
    if interpret is None:
        interpret = INTERPRET
    assert fused_t.eid is not None, "learnable executor needs an eid arena"
    c, br, ec = fused_t.nbr.shape
    m, d = gy.shape
    k = xi_arena.shape[1]
    wp = _pad_w_canon(w_canon, nnz)
    wlen = wp.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((1, br, ec), lambda i, blk, st: (i, 0, 0)),
            pl.BlockSpec((1, br, ec), lambda i, blk, st: (i, 0, 0)),
            pl.BlockSpec((wlen, 1), lambda i, blk, st: (0, 0)),
            pl.BlockSpec((m, d), lambda i, blk, st: (0, 0)),
            pl.BlockSpec((br, k), lambda i, blk, st: (blk[i], 0)),
        ],
        out_specs=pl.BlockSpec((br, k), lambda i, blk, st: (blk[i], 0)),
    )
    return pl.pallas_call(
        functools.partial(_fused_bwd_learnable_kernel, nnz=nnz),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((fused_t.n_arena_rows, k),
                                       jnp.float32),
        interpret=interpret,
    )(fused_t.block_of, fused_t.start, fused_t.nbr, fused_t.eid, wp, gy,
      xi_arena)


def _fused_dw_learnable_kernel(blk_ref, nbr_ref, gy_ref, xv_ref, xi_ref,
                               out_ref):
    """Per-slot sampled dot: out[0, r, e] = Σ_t dY[row_r, idx[nbr_re, t]] ·
    vals[nbr_re, t].  Same memory-access pattern as the dx gather with the
    roles of weight and value swapped (kernels/learnable.py); the scatter of
    slot contributions into canonical w order happens OUTSIDE the kernel
    (one XLA scatter — TPUs have no fast in-kernel scatter)."""
    nbr = nbr_ref[0]              # (BR, Ec)
    gy = gy_ref[...]              # (BR, D) — this chunk's dY rows
    xv = xv_ref[...]              # (N, k)
    xi = xi_ref[...]
    br, ec = nbr.shape
    k = xv.shape[1]
    d = gy.shape[1]

    flat = nbr.reshape(-1)
    v = jnp.take(xv, flat, axis=0).reshape(br, ec, k)
    col = jnp.take(xi, flat, axis=0).reshape(br, ec, k)
    g = jnp.broadcast_to(gy.astype(jnp.float32)[:, None, :], (br, ec, d))
    sampled = jnp.take_along_axis(g, col, axis=2)      # (BR, Ec, k)
    out_ref[0] = jnp.sum(sampled * v.astype(jnp.float32), axis=-1)


def drspmm_dw_learnable_fused(fused: FusedELL, gy_arena: jax.Array,
                              x_vals: jax.Array, x_idx: jax.Array,
                              *, interpret: bool | None = None) -> jax.Array:
    """Per-arena-slot dL/dw contributions (C, BR, Ec) in ONE kernel launch.

    ``gy_arena`` is dY gathered at ``fused.rows`` (arena destination order).
    The caller reduces to canonical order with one scatter-add over the eid
    table: ``zeros(nnz+1).at[where(eid<0, nnz, eid)].add(contrib)[:nnz]``.
    """
    if interpret is None:
        interpret = INTERPRET
    assert fused.eid is not None, "learnable executor needs an eid arena"
    c, br, ec = fused.nbr.shape
    n, k = x_vals.shape
    d = gy_arena.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((1, br, ec), lambda i, blk: (i, 0, 0)),
            pl.BlockSpec((br, d), lambda i, blk: (blk[i], 0)),
            pl.BlockSpec((n, k), lambda i, blk: (0, 0)),
            pl.BlockSpec((n, k), lambda i, blk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, br, ec), lambda i, blk: (i, 0, 0)),
    )
    return pl.pallas_call(
        _fused_dw_learnable_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((c, br, ec), jnp.float32),
        interpret=interpret,
    )(fused.block_of, fused.nbr, gy_arena, x_vals, x_idx)
