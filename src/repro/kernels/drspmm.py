"""Pallas TPU kernels for DR-SpMM (forward) and sampled DR-SpMM (backward).

Forward (Alg. 1):   Y[i, :] += w_ij * scatter(X_vals[j], X_idx[j])   over j∈N(i)
Backward (Alg. 2):  dV[j, t]  += w_ij * dY[i, X_idx[j, t]]           over i∈N(j)

Layout / TPU mapping
--------------------
* One ``pallas_call`` per degree bucket (see graphs/ell.py): the grid walks
  row-blocks of that bucket's ELL slab; the slab width E is the bucket's max
  degree, so short rows never pay evil-row padding — this is the paper's
  dynamic warp partitioning expressed structurally.
* The CBSR operand (values+indices, each (N, k)) and the gradient operand
  (M, D) are small enough for circuit partitions (N ≲ 10k, k ≤ 64, D ≤ 128)
  to live wholly in VMEM — they get whole-array BlockSpecs.  Row-blocks of
  the ELL slab stream through VMEM tile by tile.
* The scatter of k CBSR values into a D-wide accumulator is computed as a
  one-hot contraction ``vals · onehot(idx)`` so it maps onto the MXU instead
  of a serial scatter (TPUs have no fast in-kernel scatter).
* Accumulation is fp32 in VMEM regardless of input dtype.

Validated with ``interpret=True`` on CPU against kernels/ref.py; on real TPU
the same code lowers via Mosaic (jnp.take of rows lowers to dynamic gathers
along the sublane dimension).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.graphs.ell import ELLBucket, ROW_BLOCK

# CPU has no Mosaic backend: interpret the kernel bodies.  On TPU this flips
# to False automatically and the kernels compile natively.
INTERPRET = jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _fwd_kernel(nbr_ref, w_ref, xv_ref, xi_ref, out_ref, *, dim: int):
    """One row-block: aggregate E neighbors' CBSR rows into (BR, D) output."""
    nbr = nbr_ref[...]            # (BR, E) int32
    w = w_ref[...]                # (BR, E)
    xv = xv_ref[...]              # (N, k)
    xi = xi_ref[...]              # (N, k) int32
    br, e_width = nbr.shape

    iota_d = jax.lax.broadcasted_iota(jnp.int32, (1, 1, dim), 2)

    def body(e, acc):
        j = nbr[:, e]                             # (BR,)
        v = jnp.take(xv, j, axis=0)               # (BR, k) gather from VMEM
        c = jnp.take(xi, j, axis=0)               # (BR, k)
        onehot = (c[:, :, None] == iota_d).astype(acc.dtype)   # (BR, k, D)
        # MXU contraction: scatter-as-matmul over the k axis.
        contrib = jnp.einsum("bk,bkd->bd", v.astype(acc.dtype), onehot)
        return acc + w[:, e].astype(acc.dtype)[:, None] * contrib

    acc = jax.lax.fori_loop(0, e_width, body,
                            jnp.zeros((br, dim), jnp.float32))
    out_ref[...] = acc.astype(out_ref.dtype)


def drspmm_fwd_bucket(bucket: ELLBucket, x_vals: jax.Array, x_idx: jax.Array,
                      dim: int, *, interpret: bool | None = None) -> jax.Array:
    """Y_bucket (R, dim) for one degree bucket (rows still bucket-local)."""
    if interpret is None:
        interpret = INTERPRET
    r, e = bucket.nbr.shape
    n, k = x_vals.shape
    br = min(ROW_BLOCK, r)
    grid = (r // br,)
    return pl.pallas_call(
        functools.partial(_fwd_kernel, dim=dim),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, e), lambda i: (i, 0)),          # nbr row-block
            pl.BlockSpec((br, e), lambda i: (i, 0)),          # w   row-block
            pl.BlockSpec((n, k), lambda i: (0, 0)),           # x_vals (whole)
            pl.BlockSpec((n, k), lambda i: (0, 0)),           # x_idx  (whole)
        ],
        out_specs=pl.BlockSpec((br, dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, dim), x_vals.dtype),
        interpret=interpret,
    )(bucket.nbr, bucket.w, x_vals, x_idx)


# ---------------------------------------------------------------------------
# backward (SSpMM): gradients sampled at the forward's CBSR indices
# ---------------------------------------------------------------------------

def _bwd_kernel(tnbr_ref, tw_ref, gy_ref, xi_ref, out_ref):
    """One source-row-block: dV[j, t] = Σ_i w_ij · dY[i, idx[j, t]].

    ``tnbr``/``tw`` come from the *transposed* ELL packing, so each source row
    j is owned by exactly one grid cell — accumulation is a private VMEM
    reduction, no atomics (DESIGN.md §2).
    """
    tnbr = tnbr_ref[...]          # (BR, E) target ids i ∈ N(j)
    tw = tw_ref[...]              # (BR, E)
    gy = gy_ref[...]              # (M, D)
    xi = xi_ref[...]              # (BR, k) — this block's CBSR indices
    br, e_width = tnbr.shape
    k = xi.shape[1]

    def body(e, acc):
        i = tnbr[:, e]                                  # (BR,)
        g = jnp.take(gy, i, axis=0)                     # (BR, D)
        sampled = jnp.take_along_axis(g, xi, axis=1)    # (BR, k) — SSpMM
        return acc + tw[:, e].astype(acc.dtype)[:, None] * sampled.astype(acc.dtype)

    acc = jax.lax.fori_loop(0, e_width, body,
                            jnp.zeros((br, k), jnp.float32))
    out_ref[...] = acc.astype(out_ref.dtype)


def drspmm_bwd_bucket(bucket: ELLBucket, gy: jax.Array, xi_rows: jax.Array,
                      *, interpret: bool | None = None) -> jax.Array:
    """dV_bucket (R, k) for one transposed-ELL bucket.

    ``xi_rows`` is x_idx gathered at this bucket's source rows, shape (R, k).
    """
    if interpret is None:
        interpret = INTERPRET
    r, e = bucket.nbr.shape
    m, d = gy.shape
    k = xi_rows.shape[1]
    br = min(ROW_BLOCK, r)
    grid = (r // br,)
    return pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, e), lambda i: (i, 0)),
            pl.BlockSpec((br, e), lambda i: (i, 0)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),           # dY (whole)
            pl.BlockSpec((br, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, k), gy.dtype),
        interpret=interpret,
    )(bucket.nbr, bucket.w, gy, xi_rows)


# ---------------------------------------------------------------------------
# dense-operand SpMM kernel (baseline, cuSPARSE-analogue) — same bucketed ELL
# traversal but the operand is a full (N, D) matrix; lets benchmarks compare
# the CBSR gather traffic (N·k) against the dense gather traffic (N·D) under
# identical scheduling.
# ---------------------------------------------------------------------------

def _dense_kernel(nbr_ref, w_ref, x_ref, out_ref):
    nbr = nbr_ref[...]
    w = w_ref[...]
    x = x_ref[...]
    br, e_width = nbr.shape

    def body(e, acc):
        j = nbr[:, e]
        rows = jnp.take(x, j, axis=0).astype(acc.dtype)       # (BR, D)
        return acc + w[:, e].astype(acc.dtype)[:, None] * rows

    acc = jax.lax.fori_loop(0, e_width, body,
                            jnp.zeros((br, x.shape[1]), jnp.float32))
    out_ref[...] = acc.astype(out_ref.dtype)


def spmm_dense_bucket(bucket: ELLBucket, x: jax.Array,
                      *, interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = INTERPRET
    r, e = bucket.nbr.shape
    n, d = x.shape
    br = min(ROW_BLOCK, r)
    return pl.pallas_call(
        _dense_kernel,
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((br, e), lambda i: (i, 0)),
            pl.BlockSpec((br, e), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=interpret,
    )(bucket.nbr, bucket.w, x)
