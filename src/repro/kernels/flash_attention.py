"""Pallas TPU flash-attention kernel (forward) + jit wrapper.

Beyond-paper kernel: the LM substrate's training path uses the pure-jnp
chunked flash (models/lm/attention.py) because its scan composes with
autodiff/remat; this kernel is the TPU-native single-pass version for
serving/prefill, with explicit VMEM tiling:

* grid = (batch·heads, Sq / BLOCK_Q); each grid cell owns one q tile;
* k/v stream through VMEM in BLOCK_K-sized tiles via an in-kernel fori
  over the kv range (the whole per-head k/v lives in one BlockSpec block —
  rows are touched tile-by-tile, matching how Mosaic schedules the loads);
* online softmax in f32 VREGs; causal masking by absolute position;
* MXU-aligned tiles: BLOCK_Q = BLOCK_K = 128, head_dim padded to 128.

Validated in interpret mode against a naive softmax oracle
(tests/test_flash_kernel.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.drspmm import INTERPRET

BLOCK_Q = 128
BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, causal: bool, sk: int,
                  block_k: int, scale: float):
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)                 # (BQ, hd)
    bq, hd = q.shape
    nk = sk // block_k

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, hd), jnp.float32)

    def body(ki, carry):
        m_prev, l_prev, acc = carry
        k = k_ref[pl.dslice(ki * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[pl.dslice(ki * block_k, block_k), :].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (1, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_new = jnp.maximum(m_prev, s.max(-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(-1)
        acc = acc * corr[:, None] + jnp.dot(p, v,
                                            preferred_element_type=jnp.float32)
        return m_new, l_new, acc

    # causal: kv tiles beyond this q tile's diagonal contribute nothing —
    # bound the loop structurally (the in-kernel brick schedule).
    upper = (qi + 1) * bq
    n_vis = (upper + block_k - 1) // block_k if causal else nk
    m, l, acc = jax.lax.fori_loop(0, n_vis, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, interpret: bool | None = None
                    ) -> jax.Array:
    """q (B, Sq, H, hd); k/v (B, Sk, H, hd) — H already tiled/padded.

    Returns (B, Sq, H, hd)."""
    if interpret is None:
        interpret = INTERPRET
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    bq = min(BLOCK_Q, sq)
    bk = min(BLOCK_K, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, sk)
    scale = 1.0 / (hd ** 0.5)

    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, sk, hd)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, causal=causal, sk=sk, block_k=bk,
                          scale=scale),
        grid=(b * h, sq // bq),
        in_specs=[
            pl.BlockSpec((None, bq, hd), lambda g, i: (g, i, 0)),
            pl.BlockSpec((None, sk, hd), lambda g, i: (g, 0, 0)),
            pl.BlockSpec((None, sk, hd), lambda g, i: (g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, hd), lambda g, i: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, hd), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
