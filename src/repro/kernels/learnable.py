"""Learnable edge weights through DR-SpMM (beyond-paper extension).

The paper's adjacency values are fixed normalization constants.  This op
makes them a differentiable parameter vector w (nnz,) — enabling GAT-style
learned heterogeneous attention ON TOP of the CBSR/balanced-sparsity
machinery:

    Y = A(w) · dense(CBSR(x))        with  dY/dw  AND  dY/dx_vals

Gradients:
    dL/dx_vals[j,t] = Σ_{i∈N(j)} w_ij · dY[i, idx[j,t]]      (SSpMM, Alg. 2)
    dL/dw_ij        = Σ_t dY[i, idx[j,t]] · vals[j,t]        (sampled dot)

Both reuse the forward's CBSR indices; the w-gradient is the same sampled
gather as the x-gradient with the roles of weight and value swapped —
no new memory-access pattern is introduced, so the TPU kernel story
(kernels/drspmm.py) carries over unchanged.

Edge-id slabs (graphs/ell.py::pack_eid_slabs) keep the forward and
transposed layouts consistent: both gather from the same canonical w.

This module holds the per-bucket *reference* implementations (the "xla"
backend).  The public :func:`drspmm_learnable` delegates to
``kernels/ops.py``, which runs the same math single-dispatch over the fused
eid arena on the fused backends (DESIGN.md §8) and memoizes the jitted
custom-vjp executor per packing (the seed rebuilt it per call, defeating
jit caching).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.graphs.ell import BucketedELL, decode_eids


def _slab_weights(w_canon: jax.Array, eid_slab) -> jax.Array:
    """Gather canonical weights into a slab; padding (id −1) -> 0."""
    ids = decode_eids(eid_slab)
    wp = jnp.concatenate([w_canon, jnp.zeros((1,), w_canon.dtype)])
    return wp[jnp.where(ids < 0, w_canon.shape[0], ids)]


def _fwd_exact(fwd_slabs: BucketedELL, w_canon, x_vals, x_idx, dim: int):
    y = jnp.zeros((fwd_slabs.n_dst, dim), x_vals.dtype)
    for b in fwd_slabs.buckets:
        w = _slab_weights(w_canon, b.w)                   # (R, E)
        v = jnp.take(x_vals, b.nbr, axis=0)               # (R, E, k)
        c = jnp.take(x_idx, b.nbr, axis=0)
        vw = v * w[..., None]
        yb = jnp.zeros((b.n_rows, dim), x_vals.dtype)
        r, e, k = v.shape
        rloc = jnp.broadcast_to(
            jnp.arange(r, dtype=jnp.int32)[:, None, None], c.shape)
        yb = yb.at[rloc, c].add(vw)
        y = y.at[b.rows].add(yb)
    return y


def _bwd_x(bwd_slabs: BucketedELL, w_canon, gy, x_idx):
    """dL/dx_vals via the transposed slabs (source-row ownership)."""
    n, k = x_idx.shape
    gv = jnp.zeros((n, k), gy.dtype)
    for b in bwd_slabs.buckets:
        w = _slab_weights(w_canon, b.w)                   # (R, E)
        xi_rows = jnp.take(x_idx, b.rows, axis=0)         # (R, k)
        g = jnp.take(gy, b.nbr, axis=0)                   # (R, E, D)
        sampled = jnp.take_along_axis(
            g, jnp.broadcast_to(xi_rows[:, None, :],
                                g.shape[:2] + (k,)), axis=2)
        gv = gv.at[b.rows].add(jnp.sum(sampled * w[..., None], axis=1))
    return gv


def _bwd_w(fwd_slabs: BucketedELL, gy, x_vals, x_idx, nnz: int):
    """dL/dw per canonical edge: sampled dot of dY rows with CBSR values."""
    gw = jnp.zeros((nnz + 1,), gy.dtype)
    for b in fwd_slabs.buckets:
        ids = decode_eids(b.w)                            # (R, E)
        v = jnp.take(x_vals, b.nbr, axis=0)               # (R, E, k)
        c = jnp.take(x_idx, b.nbr, axis=0)
        g_rows = jnp.take(gy, b.rows, axis=0)             # (R, D)
        r, e, k = v.shape
        sampled = jnp.take_along_axis(
            jnp.broadcast_to(g_rows[:, None, :], (r, e, g_rows.shape[-1])),
            c, axis=2)                                    # (R, E, k)
        contrib = jnp.sum(sampled * v, axis=-1)           # (R, E)
        gw = gw.at[jnp.where(ids < 0, nnz, ids)].add(contrib)
    return gw[:nnz]


def drspmm_learnable(fwd_slabs: BucketedELL, bwd_slabs: BucketedELL,
                     nnz: int, w_canon: jax.Array, x_vals: jax.Array,
                     x_idx: jax.Array, dim: int, *,
                     backend=None) -> jax.Array:
    """Differentiable in BOTH w_canon (nnz,) and x_vals (N, k).

    Back-compat entry point: delegates to
    :func:`repro.kernels.ops.drspmm_learnable` (``backend=None`` →
    ``ops.DEFAULT_BACKEND``, i.e. the fused single-dispatch path), so
    existing callers of the slab API get the fast path and the memoized
    executor for free.
    """
    from repro.kernels import ops as _ops   # lazy: ops imports this module
    be = _ops.DEFAULT_BACKEND if backend is None else backend
    return _ops.drspmm_learnable(fwd_slabs, bwd_slabs, nnz, w_canon,
                                 x_vals, x_idx, dim, backend=be)
