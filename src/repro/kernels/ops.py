"""Jit-ready wrappers over the DR-SpMM Pallas kernels.

``drspmm`` is the public op: Y = A · dense(CBSR(x_vals, x_idx)), with a
custom VJP that runs the sampled backward kernel (SSpMM) over the transposed
ELL packing, exactly as Alg. 2 reuses the forward's CBSR indices.

``backend`` selects the execution path:
  * "pallas"   — the Pallas kernels (interpret-mode on CPU, native on TPU);
  * "xla"      — same bucketed math in pure jnp (gather/one-hot), useful when
                 interpret-mode tracing is too slow for large sweeps;
  * "dense"    — fully dense oracle (kernels/ref.py), the cuSPARSE-analogue.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.graphs.ell import BucketedELL
from repro.kernels import drspmm as _k
from repro.kernels import ref as _ref

Backend = Literal["pallas", "xla", "dense"]
DEFAULT_BACKEND: Backend = "xla"


def _fwd_bucket_xla(bucket, x_vals, x_idx, dim):
    """Bucketed CBSR aggregation in plain jnp (same math as the kernel)."""
    v = jnp.take(x_vals, bucket.nbr, axis=0)          # (R, E, k)
    c = jnp.take(x_idx, bucket.nbr, axis=0)           # (R, E, k)
    vw = v * bucket.w[..., None]                      # weight each neighbor
    r, e, k = v.shape
    flat_rows = jnp.repeat(jnp.arange(r, dtype=jnp.int32)[:, None, None],
                           e, axis=1)
    out = jnp.zeros((r, dim), x_vals.dtype)
    return out.at[jnp.broadcast_to(flat_rows, c.shape), c].add(vw)


def _bwd_bucket_xla(bucket, gy, xi_rows):
    g = jnp.take(gy, bucket.nbr, axis=0)              # (R, E, D)
    sampled = jnp.take_along_axis(
        g, jnp.broadcast_to(xi_rows[:, None, :], g.shape[:2] + xi_rows.shape[1:]),
        axis=2)                                       # (R, E, k)
    return jnp.sum(sampled * bucket.w[..., None], axis=1)


def _fwd_impl(adj: BucketedELL, x_vals, x_idx, dim: int, backend: Backend):
    if backend == "dense":
        return _ref.drspmm_fwd_ref(adj, x_vals, x_idx, dim)
    y = jnp.zeros((adj.n_dst, dim), x_vals.dtype)
    for b in adj.buckets:
        if backend == "pallas":
            yb = _k.drspmm_fwd_bucket(b, x_vals, x_idx, dim)
        else:
            yb = _fwd_bucket_xla(b, x_vals, x_idx, dim)
        y = y.at[b.rows].add(yb)  # padded rows carry zero weights — inert
    return y


def _bwd_impl(adj_t: BucketedELL, gy, x_idx, backend: Backend):
    if backend == "dense":
        return _ref.drspmm_bwd_ref(adj_t, gy, x_idx)
    n, k = x_idx.shape
    gv = jnp.zeros((n, k), gy.dtype)
    for b in adj_t.buckets:
        xi_rows = jnp.take(x_idx, b.rows, axis=0)     # (R, k)
        if backend == "pallas":
            gb = _k.drspmm_bwd_bucket(b, gy, xi_rows)
        else:
            gb = _bwd_bucket_xla(b, gy, xi_rows)
        gv = gv.at[b.rows].add(gb)
    return gv


def drspmm(adj: BucketedELL, adj_t: BucketedELL, x_vals: jax.Array,
           x_idx: jax.Array, dim: int, *,
           backend: Backend = DEFAULT_BACKEND) -> jax.Array:
    """Differentiable DR-SpMM.  Gradient flows to ``x_vals`` only; the
    adjacency and the CBSR indices are structural."""

    @jax.custom_vjp
    def f(xv):
        return _fwd_impl(adj, xv, x_idx, dim, backend)

    def f_fwd(xv):
        return _fwd_impl(adj, xv, x_idx, dim, backend), None

    def f_bwd(_, gy):
        return (_bwd_impl(adj_t, gy, x_idx, backend),)

    f.defvjp(f_fwd, f_bwd)
    return f(x_vals)


def spmm(adj: BucketedELL, adj_t: BucketedELL, x: jax.Array, *,
         backend: Backend = DEFAULT_BACKEND) -> jax.Array:
    """Dense-operand SpMM baseline with full (not sampled) backward."""

    @jax.custom_vjp
    def f(xd):
        return _spmm_fwd(adj, xd, backend)

    def f_fwd(xd):
        return _spmm_fwd(adj, xd, backend), None

    def f_bwd(_, gy):
        return (_spmm_fwd(adj_t, gy, backend),)

    f.defvjp(f_fwd, f_bwd)
    return f(x)


def _spmm_fwd(adj: BucketedELL, x, backend: Backend):
    if backend == "dense":
        return _ref.spmm_dense_ref(adj, x)
    y = jnp.zeros((adj.n_dst, x.shape[1]), x.dtype)
    for b in adj.buckets:
        if backend == "pallas":
            yb = _k.spmm_dense_bucket(b, x)
        else:
            rows = jnp.take(x, b.nbr, axis=0)         # (R, E, D)
            yb = jnp.sum(rows * b.w[..., None], axis=1)
        y = y.at[b.rows].add(yb)
    return y
