"""Jit-ready wrappers over the DR-SpMM Pallas kernels.

``drspmm`` is the public op: Y = A · dense(CBSR(x_vals, x_idx)), with a
custom VJP that runs the sampled backward kernel (SSpMM) over the transposed
ELL packing, exactly as Alg. 2 reuses the forward's CBSR indices.

``backend`` selects the execution path:
  * "pallas_fused" — ONE Pallas dispatch per edge-type direction: all degree
                 buckets run in a single kernel over the FusedELL arena and
                 the per-bucket ``y.at[rows].add`` combine collapses to one
                 gather (DESIGN.md §1).  Default on TPU.
  * "xla_fused" — the SAME fused arena layout executed in plain jnp
                 (gather + one scatter / segment-sum, no per-bucket loop).
                 Default on CPU, where Pallas only interprets: it keeps the
                 fused packing's adaptive-chunk slot reduction and its
                 single-combine structure at real XLA wall-clock.
  * "pallas"   — the per-bucket Pallas kernels, one dispatch per degree
                 bucket (interpret-mode on CPU, native on TPU); kept as the
                 reference for the fused path;
  * "xla"      — same bucketed math in pure jnp (gather/one-hot), the
                 per-bucket reference at XLA wall-clock;
  * "dense"    — fully dense oracle (kernels/ref.py), the cuSPARSE-analogue.

Fused packings are derived lazily from the BucketedELL arguments via
``fuse_bucketed`` (host-side, memoized per packing), so every caller of the
bucketed API gets the single-dispatch path by flipping ``backend`` alone.

``drspmm_multi`` lifts the same contract one level: every edge-type
direction of a hetero layer runs over a :class:`RelationPlan` super-arena
as ONE dispatch per direction-group — one forward, one transposed backward
— instead of one per relation (DESIGN.md §9).  Execution is size-adaptive
(DESIGN.md §14): relations the plan classified as dense-tier at pack time
(nnz below the measured crossover) skip the chunk walk and run together as
at most one extra batched dense matmul per direction; ``drspmm`` applies
the same crossover to single tiny relations on the fused-family backends.
"""

from __future__ import annotations

import functools
import weakref
from collections import OrderedDict, deque
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.ell import (DENSE_TIER_AREA, DENSE_TIER_NNZ, BucketedELL,
                              ELLBucket, FusedELL, RelationPlan, decode_eids,
                              ell_to_coo, fuse_bucketed, fused_to_coo)
from repro.kernels import drspmm as _k
from repro.kernels import learnable as _learn
from repro.kernels import ref as _ref
from repro.obs.metrics import DEFAULT_REGISTRY as _METRICS

Backend = Literal["pallas_fused", "xla_fused", "pallas", "xla", "dense"]
# The fused single-dispatch executor is the paper-faithful hot path on real
# hardware; on CPU the Pallas kernels only run in interpret mode (not
# wall-clock-representative), so the same fused arena layout executed in
# plain XLA is the default there.
DEFAULT_BACKEND: Backend = (
    "pallas_fused" if jax.default_backend() == "tpu" else "xla_fused")

# Trace-time dispatch log: every fused-family executor issue appends a
# "family:kind" tag while its op body runs (i.e. while TRACING under jit —
# compiled replays don't re-run Python, so count deltas around an explicit
# trace such as ``jax.make_jaxpr``).  This is how tests and bench smoke
# assert the one-dispatch-per-direction-group property for the xla family,
# where jaxpr ``pallas_call`` counting has nothing to count.  Bounded: a
# long-lived serve loop retraces per (bucket, device) compile and eviction
# return, and nothing outside tests ever drains the log.
FUSED_DISPATCH_LOG: "deque[str]" = deque(maxlen=4096)


def _record_dispatch(tag: str) -> None:
    FUSED_DISPATCH_LOG.append(tag)
    # Generalized per-backend dispatch counters (DESIGN.md §11): the same
    # trace-time semantics as the log, but labeled, unbounded-total, and
    # exportable — ``ops.dispatch{family=...,kind=...}`` in the default
    # metrics registry.  The deque stays the test-facing drainable probe.
    family, _, kind = tag.partition(":")
    _METRICS.inc("ops.dispatch", family=family, kind=kind)


def _fused_of(adj) -> FusedELL:
    if isinstance(adj, FusedELL):
        return adj
    return fuse_bucketed(adj)


def _effective_backend(adj, backend: Backend) -> Backend:
    """Fused packing is host-side preprocessing: it needs concrete arrays.
    When the adjacency arrives as a *traced jit argument* (e.g. a step
    function that takes the graph as a parameter), fall back to the
    per-bucket path of the same executor family — numerically identical
    (see tests/test_fused.py), just bucket-granular dispatch.  Callers who
    want the fused path inside jit should close over the graph (it is
    static per design) or pre-fuse with ``fuse_bucketed``.

    A pre-fused adjacency (:class:`FusedELL`, e.g. a collated serve batch —
    graphs/collate.py) has no bucket slabs to fall back to, so the
    per-bucket/dense backend names are upgraded to the fused executor of the
    matching family (numerically interchangeable, tests/test_fused.py).
    Crucially this works **inside jit with the graph traced**: the arena is
    already packed, so batches sharing a padded shape signature reuse one
    compiled executable."""
    if isinstance(adj, FusedELL):
        if backend in ("pallas", "pallas_fused"):
            return "pallas_fused"
        return "xla_fused"
    if backend in ("pallas_fused", "xla_fused"):
        if any(isinstance(b.nbr, jax.core.Tracer) for b in adj.buckets):
            return "pallas" if backend == "pallas_fused" else "xla"
    return backend


def _fwd_bucket_xla(bucket, x_vals, x_idx, dim):
    """Bucketed CBSR aggregation in plain jnp (same math as the kernel)."""
    v = jnp.take(x_vals, bucket.nbr, axis=0)          # (R, E, k)
    c = jnp.take(x_idx, bucket.nbr, axis=0)           # (R, E, k)
    vw = v * bucket.w[..., None]                      # weight each neighbor
    r, e, k = v.shape
    flat_rows = jnp.repeat(jnp.arange(r, dtype=jnp.int32)[:, None, None],
                           e, axis=1)
    out = jnp.zeros((r, dim), x_vals.dtype)
    return out.at[jnp.broadcast_to(flat_rows, c.shape), c].add(vw)


def _bwd_bucket_xla(bucket, gy, xi_rows):
    g = jnp.take(gy, bucket.nbr, axis=0)              # (R, E, D)
    sampled = jnp.take_along_axis(
        g, jnp.broadcast_to(xi_rows[:, None, :], g.shape[:2] + xi_rows.shape[1:]),
        axis=2)                                       # (R, E, k)
    return jnp.sum(sampled * bucket.w[..., None], axis=1)


# ----- fused arena executed in plain XLA (CPU hot path; same layout the
# ----- Pallas fused kernels consume, so the adaptive chunk packing's
# ----- ~2× slot reduction and the scatter-free combine carry over) -------

def _arena_rows(f: FusedELL):
    """(C, BR) arena row id of each chunk slot row."""
    return (jnp.asarray(f.block_of)[:, None] * f.row_block
            + jnp.arange(f.row_block, dtype=jnp.int32)[None, :])


def _fwd_fused_xla(f: FusedELL, x_vals, x_idx, dim: int):
    nbr = jnp.asarray(f.nbr)                          # (C, BR, Ec)
    w = jnp.asarray(f.w)
    v = jnp.take(x_vals, nbr, axis=0)                 # (C, BR, Ec, k)
    cols = jnp.take(x_idx, nbr, axis=0)
    vw = v * w[..., None]
    rows = _arena_rows(f)                             # (C, BR)
    y = jnp.zeros((f.n_arena_rows, dim), x_vals.dtype)
    y = y.at[jnp.broadcast_to(rows[:, :, None, None], cols.shape),
             cols].add(vw)
    return jnp.take(y, jnp.asarray(f.gather), axis=0)


def _bwd_fused_xla(ft: FusedELL, gy, x_idx, rows=None):
    """``rows`` overrides the arena-row → operand-row map used for the xi
    gather (default ``ft.rows``); the super-arena backward passes the
    plan's type-concat map (``RelationPlan.bwd_src_rows`` — ``ft.rows``
    live in the relation-concat dx space there)."""
    tnbr = jnp.asarray(ft.nbr)                        # (C, BR, Ec) targets
    tw = jnp.asarray(ft.w)
    k = x_idx.shape[1]
    g = jnp.take(gy, tnbr, axis=0)                    # (C, BR, Ec, D)
    xi_arena = jnp.take(
        x_idx, jnp.asarray(ft.rows if rows is None else rows),
        axis=0)                                       # (R_arena, k)
    xi_blocks = jnp.take(xi_arena, _arena_rows(ft), axis=0)   # (C, BR, k)
    sampled = jnp.take_along_axis(
        g, jnp.broadcast_to(xi_blocks[:, :, None, :], g.shape[:3] + (k,)),
        axis=3)                                       # (C, BR, Ec, k) — SSpMM
    contrib = jnp.sum(sampled * tw[..., None], axis=2)         # (C, BR, k)
    n_blocks = ft.n_arena_rows // ft.row_block
    dv = jax.ops.segment_sum(contrib, jnp.asarray(ft.block_of),
                             num_segments=n_blocks)
    dv = dv.reshape(ft.n_arena_rows, k)
    return jnp.take(dv, jnp.asarray(ft.gather), axis=0)


def _spmm_fused_xla(f: FusedELL, x):
    nbr = jnp.asarray(f.nbr)
    w = jnp.asarray(f.w)
    rows_x = jnp.take(x, nbr, axis=0)                 # (C, BR, Ec, D)
    contrib = jnp.sum(rows_x * w[..., None], axis=2)  # (C, BR, D)
    n_blocks = f.n_arena_rows // f.row_block
    y = jax.ops.segment_sum(contrib, jnp.asarray(f.block_of),
                            num_segments=n_blocks)
    y = y.reshape(f.n_arena_rows, x.shape[1])
    return jnp.take(y, jnp.asarray(f.gather), axis=0)


def _fwd_impl(adj: BucketedELL, x_vals, x_idx, dim: int, backend: Backend):
    if backend == "dense":
        return _ref.drspmm_fwd_ref(adj, x_vals, x_idx, dim)
    if backend == "xla_fused":
        _record_dispatch("xla:fwd")
        return _fwd_fused_xla(_fused_of(adj), x_vals, x_idx, dim)
    if backend == "pallas_fused":
        _record_dispatch("pallas:fwd")
        f = _fused_of(adj)
        ya = _k.drspmm_fwd_fused(f, x_vals, x_idx, dim)   # fp32 arena
        return jnp.take(ya, f.gather, axis=0).astype(x_vals.dtype)
    y = jnp.zeros((adj.n_dst, dim), x_vals.dtype)
    for b in adj.buckets:
        if backend == "pallas":
            yb = _k.drspmm_fwd_bucket(b, x_vals, x_idx, dim)
        else:
            yb = _fwd_bucket_xla(b, x_vals, x_idx, dim)
        y = y.at[b.rows].add(yb)  # padded rows carry zero weights — inert
    return y


def _bwd_impl(adj_t: BucketedELL, gy, x_idx, backend: Backend):
    if backend == "dense":
        return _ref.drspmm_bwd_ref(adj_t, gy, x_idx)
    n, k = x_idx.shape
    if backend == "xla_fused":
        _record_dispatch("xla:bwd")
        return _bwd_fused_xla(_fused_of(adj_t), gy, x_idx)
    if backend == "pallas_fused":
        _record_dispatch("pallas:bwd")
        ft = _fused_of(adj_t)
        xi_arena = jnp.take(x_idx, ft.rows, axis=0)   # (R_arena, k)
        ga = _k.drspmm_bwd_fused(ft, gy, xi_arena)    # fp32 arena
        return jnp.take(ga, ft.gather, axis=0).astype(gy.dtype)
    gv = jnp.zeros((n, k), gy.dtype)
    for b in adj_t.buckets:
        xi_rows = jnp.take(x_idx, b.rows, axis=0)     # (R, k)
        if backend == "pallas":
            gb = _k.drspmm_bwd_bucket(b, gy, xi_rows)
        else:
            gb = _bwd_bucket_xla(b, gy, xi_rows)
        gv = gv.at[b.rows].add(gb)
    return gv


# ----- dense fast-path tier for tiny single relations ----------------------
#
# The fused chunk-walk arena LOSES on tiny relations (BENCH_drspmm recorded
# ``pin``/``pinned`` at nnz≈2k running 0.53–0.65x vs the per-bucket path):
# below the measured crossover (graphs/ell.py::DENSE_TIER_NNZ) the whole
# relation is ONE masked dense matmul — still a single dispatch, same
# custom-vjp contract (sampled backward at x_idx).  Fused-family names only:
# "pallas"/"xla" stay bucket-granular as the reference baselines the bench
# compares against.  A collated arena (nnz == −1: padded filler, bucket-
# stable shape signature) never reroutes — tier decisions for collation are
# pinned at pack time by the plan (graphs/collate.py).

_DENSE_MAT_CACHE: "dict[int, tuple]" = {}


def _dense_mat_of(adj) -> np.ndarray:
    """Host-side (n_dst, n_src) dense matrix of a concrete packing,
    memoized per packing identity (same discipline as ``_FUSE_CACHE``)."""
    key = id(adj)
    hit = _DENSE_MAT_CACHE.get(key)
    if hit is not None and hit[0]() is adj:
        return hit[1]
    d, s, w = (fused_to_coo(adj) if isinstance(adj, FusedELL)
               else ell_to_coo(adj))
    a = np.zeros((adj.n_dst, adj.n_src), np.float32)
    np.add.at(a, (d, s), w)
    _DENSE_MAT_CACHE[key] = (
        weakref.ref(adj, lambda _, k=key: _DENSE_MAT_CACHE.pop(k, None)), a)
    return a


def _dense_tier_single(adj, backend: Backend) -> bool:
    """True when a single-relation fused-family call should take the
    dense-tier fast path: concrete packing, known sub-threshold nnz, and a
    dense table small enough to be worth materializing."""
    if backend not in ("pallas_fused", "xla_fused"):
        return False
    leaf = adj.nbr if isinstance(adj, FusedELL) else adj.buckets[0].nbr
    if isinstance(leaf, jax.core.Tracer):
        return False
    return (adj.nnz >= 0 and adj.nnz <= DENSE_TIER_NNZ
            and adj.n_dst * adj.n_src <= DENSE_TIER_AREA)


def _drspmm_dense_single(adj, adj_t, x_vals, x_idx, dim: int,
                         backend: Backend) -> jax.Array:
    family = "pallas" if backend == "pallas_fused" else "xla"
    a = jnp.asarray(_dense_mat_of(adj))
    at = jnp.asarray(_dense_mat_of(adj_t))

    @jax.custom_vjp
    def f(xv):
        _record_dispatch(f"{family}:dense_fwd")
        if backend == "pallas_fused":
            return _k.drspmm_dense_tier_fwd(a, xv, x_idx,
                                            dim).astype(xv.dtype)
        n = xv.shape[0]
        xd = jnp.zeros((n, dim), jnp.float32).at[
            jnp.arange(n)[:, None], x_idx].add(xv.astype(jnp.float32))
        return (a @ xd).astype(xv.dtype)

    def f_fwd(xv):
        return f(xv), None

    def f_bwd(_, gy):
        _record_dispatch(f"{family}:dense_bwd")
        if backend == "pallas_fused":
            dv = _k.drspmm_dense_tier_bwd(at, gy, x_idx)
        else:
            dx = at @ gy.astype(jnp.float32)
            dv = jnp.take_along_axis(dx, x_idx, axis=1)
        return (dv.astype(gy.dtype),)

    f.defvjp(f_fwd, f_bwd)
    return f(x_vals)


def drspmm(adj: BucketedELL, adj_t: BucketedELL, x_vals: jax.Array,
           x_idx: jax.Array, dim: int, *,
           backend: Backend = DEFAULT_BACKEND) -> jax.Array:
    """Differentiable DR-SpMM.  Gradient flows to ``x_vals`` only; the
    adjacency and the CBSR indices are structural.

    Size-adaptive: on the fused-family backends a concrete relation whose
    nnz sits below the measured dense crossover
    (``graphs/ell.py::DENSE_TIER_NNZ``) routes to the dense-tier executor —
    one masked dense matmul forward, one transposed matmul + SSpMM sampling
    backward — instead of walking the arena (DESIGN.md §14)."""

    backend = _effective_backend(adj, backend)
    if _dense_tier_single(adj, backend):
        return _drspmm_dense_single(adj, adj_t, x_vals, x_idx, dim, backend)

    @jax.custom_vjp
    def f(xv):
        return _fwd_impl(adj, xv, x_idx, dim, backend)

    def f_fwd(xv):
        return _fwd_impl(adj, xv, x_idx, dim, backend), None

    def f_bwd(_, gy):
        return (_bwd_impl(adj_t, gy, x_idx, backend),)

    f.defvjp(f_fwd, f_bwd)
    return f(x_vals)


def spmm(adj: BucketedELL, adj_t: BucketedELL, x: jax.Array, *,
         backend: Backend = DEFAULT_BACKEND) -> jax.Array:
    """Dense-operand SpMM baseline with full (not sampled) backward."""

    backend = _effective_backend(adj, backend)

    @jax.custom_vjp
    def f(xd):
        return _spmm_fwd(adj, xd, backend)

    def f_fwd(xd):
        return _spmm_fwd(adj, xd, backend), None

    def f_bwd(_, gy):
        return (_spmm_fwd(adj_t, gy, backend),)

    f.defvjp(f_fwd, f_bwd)
    return f(x)


def _spmm_fwd(adj: BucketedELL, x, backend: Backend):
    if backend == "dense":
        return _ref.spmm_dense_ref(adj, x)
    if backend == "xla_fused":
        _record_dispatch("xla:spmm")
        return _spmm_fused_xla(_fused_of(adj), x)
    if backend == "pallas_fused":
        _record_dispatch("pallas:spmm")
        f = _fused_of(adj)
        ya = _k.spmm_dense_fused(f, x)                # fp32 arena
        return jnp.take(ya, f.gather, axis=0).astype(x.dtype)
    y = jnp.zeros((adj.n_dst, x.shape[1]), x.dtype)
    for b in adj.buckets:
        if backend == "pallas":
            yb = _k.spmm_dense_bucket(b, x)
        else:
            rows = jnp.take(x, b.nbr, axis=0)         # (R, E, D)
            yb = jnp.sum(rows * b.w[..., None], axis=1)
        y = y.at[b.rows].add(yb)
    return y


# ---------------------------------------------------------------------------
# drspmm_learnable — differentiable per-edge weights through the same
# 5-backend family (DESIGN.md §8).  The packing is an edge-ID structure
# (pack_eid_slabs slabs or their fused eid arenas): the canonical weight
# vector w (nnz,) is gathered into slab/arena layout at execution time, so
# Y = A(w)·dense(CBSR(x)) has gradients in BOTH w and x_vals while keeping
# the fixed-weight path's dispatch granularity per backend.
# ---------------------------------------------------------------------------

def _fused_eid_of(pack) -> FusedELL:
    if isinstance(pack, FusedELL):
        assert pack.eid is not None, (
            "learnable fused backends need an eid arena "
            "(fuse_bucketed(..., eids=True) / pack_fused_eid_pair)")
        return pack
    return fuse_bucketed(pack, eids=True)


def _learnable_effective_backend(pack, backend: Backend) -> Backend:
    """Same family-upgrade rules as :func:`_effective_backend`: a pre-fused
    eid arena upgrades per-bucket names to the fused executor of the same
    family (it has no slabs to loop over); traced bucketed slabs downgrade
    fused names to the per-bucket path (fusing is host-side packing)."""
    if isinstance(pack, FusedELL):
        if backend in ("pallas", "pallas_fused"):
            return "pallas_fused"
        if backend == "dense":
            return "dense"
        return "xla_fused"
    if backend in ("pallas_fused", "xla_fused"):
        if any(isinstance(b.nbr, jax.core.Tracer) for b in pack.buckets):
            return "pallas" if backend == "pallas_fused" else "xla"
    return backend


def _wpad(w_canon):
    """Append the inert slot padded eids (→ index nnz) gather from."""
    return jnp.concatenate([w_canon, jnp.zeros((1,), w_canon.dtype)])


def _safe_eids(eid, nnz: int):
    return jnp.where(jnp.asarray(eid) < 0, nnz, jnp.asarray(eid))


# ----- dense oracle (autodiff carries both grads exactly) ------------------

def _learnable_dense(pack, nnz: int, w, xv, xi, dim: int):
    wp = _wpad(w)
    a = jnp.zeros((pack.n_dst, pack.n_src), jnp.float32)
    if isinstance(pack, FusedELL):
        slot_rows = jnp.take(jnp.asarray(pack.rows), _arena_rows(pack),
                             axis=0)                  # (C, BR) original rows
        wa = wp[_safe_eids(pack.eid, nnz)]            # (C, BR, Ec)
        rows3 = jnp.broadcast_to(slot_rows[:, :, None], wa.shape)
        a = a.at[rows3, jnp.asarray(pack.nbr)].add(wa)
    else:
        for b in pack.buckets:
            ids = decode_eids(b.w)
            ws = wp[_safe_eids(ids, nnz)]             # (R, E)
            rows2 = jnp.broadcast_to(b.rows[:, None], ws.shape)
            a = a.at[rows2, b.nbr].add(ws)
    n_src, k = xi.shape[0], xi.shape[1]
    xd = jnp.zeros((n_src, dim), xv.dtype).at[
        jnp.arange(n_src)[:, None], xi].add(xv)
    return a @ xd


# ----- per-bucket Pallas path: slab weights gathered in XLA, then the
# ----- fixed-weight bucket kernels run on the (traced-weight) slabs --------

def _fwd_learnable_pallas(slabs: BucketedELL, nnz, w, xv, xi, dim):
    wp = _wpad(w)
    y = jnp.zeros((slabs.n_dst, dim), xv.dtype)
    for b in slabs.buckets:
        ws = wp[_safe_eids(decode_eids(b.w), nnz)]    # (R, E)
        yb = _k.drspmm_fwd_bucket(
            ELLBucket(rows=b.rows, nbr=b.nbr, w=ws), xv, xi, dim)
        y = y.at[b.rows].add(yb)
    return y


def _bwd_x_learnable_pallas(tslabs: BucketedELL, nnz, w, gy, xi):
    wp = _wpad(w)
    n, k = xi.shape
    gv = jnp.zeros((n, k), gy.dtype)
    for b in tslabs.buckets:
        ws = wp[_safe_eids(decode_eids(b.w), nnz)]
        xi_rows = jnp.take(xi, b.rows, axis=0)        # (R, k)
        gb = _k.drspmm_bwd_bucket(
            ELLBucket(rows=b.rows, nbr=b.nbr, w=ws), gy, xi_rows)
        gv = gv.at[b.rows].add(gb)
    return gv


# ----- fused arena in plain XLA (CPU hot path) -----------------------------

def _fwd_learnable_fused_xla(f: FusedELL, nnz, w, xv, xi, dim):
    wa = _wpad(w)[_safe_eids(f.eid, nnz)]             # (C, BR, Ec)
    nbr = jnp.asarray(f.nbr)
    v = jnp.take(xv, nbr, axis=0)                     # (C, BR, Ec, k)
    cols = jnp.take(xi, nbr, axis=0)
    vw = v * wa[..., None]
    rows = _arena_rows(f)                             # (C, BR)
    y = jnp.zeros((f.n_arena_rows, dim), xv.dtype)
    y = y.at[jnp.broadcast_to(rows[:, :, None, None], cols.shape),
             cols].add(vw)
    return jnp.take(y, jnp.asarray(f.gather), axis=0)


def _bwd_x_learnable_fused_xla(ft: FusedELL, nnz, w, gy, xi):
    twa = _wpad(w)[_safe_eids(ft.eid, nnz)]           # (C, BR, Ec)
    tnbr = jnp.asarray(ft.nbr)
    k = xi.shape[1]
    g = jnp.take(gy, tnbr, axis=0)                    # (C, BR, Ec, D)
    xi_arena = jnp.take(xi, jnp.asarray(ft.rows), axis=0)      # (R_arena, k)
    xi_blocks = jnp.take(xi_arena, _arena_rows(ft), axis=0)    # (C, BR, k)
    sampled = jnp.take_along_axis(
        g, jnp.broadcast_to(xi_blocks[:, :, None, :], g.shape[:3] + (k,)),
        axis=3)                                       # SSpMM sampling
    contrib = jnp.sum(sampled * twa[..., None], axis=2)        # (C, BR, k)
    n_blocks = ft.n_arena_rows // ft.row_block
    dv = jax.ops.segment_sum(contrib, jnp.asarray(ft.block_of),
                             num_segments=n_blocks).reshape(
        ft.n_arena_rows, k)
    return jnp.take(dv, jnp.asarray(ft.gather), axis=0)


def _dw_contrib_to_canon(f: FusedELL, nnz, contrib):
    """Reduce per-arena-slot contributions (C, BR, Ec) to canonical order:
    one scatter-add over the eid table; padding (−1 → slot nnz) dropped."""
    gw = jnp.zeros((nnz + 1,), contrib.dtype)
    gw = gw.at[_safe_eids(f.eid, nnz).reshape(-1)].add(contrib.reshape(-1))
    return gw[:nnz]


def _dw_learnable_fused_xla(f: FusedELL, nnz, gy, xv, xi):
    nbr = jnp.asarray(f.nbr)
    v = jnp.take(xv, nbr, axis=0)                     # (C, BR, Ec, k)
    cols = jnp.take(xi, nbr, axis=0)
    gy_arena = jnp.take(gy, jnp.asarray(f.rows), axis=0)       # (R_arena, D)
    gy_blocks = jnp.take(gy_arena, _arena_rows(f), axis=0)     # (C, BR, D)
    g = jnp.broadcast_to(gy_blocks[:, :, None, :],
                         cols.shape[:3] + (gy.shape[1],))
    sampled = jnp.take_along_axis(g, cols, axis=3)    # (C, BR, Ec, k)
    contrib = jnp.sum(sampled * v, axis=-1)           # (C, BR, Ec)
    return _dw_contrib_to_canon(f, nnz, contrib)


# ----- backend dispatch ----------------------------------------------------

def _learnable_fwd_impl(pack, nnz, w, xv, xi, dim, backend: Backend):
    if backend == "xla_fused":
        return _fwd_learnable_fused_xla(_fused_eid_of(pack), nnz, w, xv, xi,
                                        dim)
    if backend == "pallas_fused":
        f = _fused_eid_of(pack)
        ya = _k.drspmm_fwd_learnable_fused(f, nnz, w, xv, xi, dim)
        return jnp.take(ya, f.gather, axis=0).astype(xv.dtype)
    if backend == "pallas":
        return _fwd_learnable_pallas(pack, nnz, w, xv, xi, dim)
    return _learn._fwd_exact(pack, w, xv, xi, dim)    # "xla" reference


def _learnable_dx_impl(tpack, nnz, w, gy, xi, backend: Backend):
    if backend == "xla_fused":
        return _bwd_x_learnable_fused_xla(_fused_eid_of(tpack), nnz, w, gy,
                                          xi)
    if backend == "pallas_fused":
        ft = _fused_eid_of(tpack)
        xi_arena = jnp.take(xi, jnp.asarray(ft.rows), axis=0)
        ga = _k.drspmm_bwd_learnable_fused(ft, nnz, w, gy, xi_arena)
        return jnp.take(ga, ft.gather, axis=0).astype(gy.dtype)
    if backend == "pallas":
        return _bwd_x_learnable_pallas(tpack, nnz, w, gy, xi)
    return _learn._bwd_x(tpack, w, gy, xi)            # "xla" reference


def _learnable_dw_impl(pack, nnz, gy, xv, xi, backend: Backend):
    if backend == "xla_fused":
        return _dw_learnable_fused_xla(_fused_eid_of(pack), nnz, gy, xv, xi)
    if backend == "pallas_fused":
        f = _fused_eid_of(pack)
        gy_arena = jnp.take(gy, jnp.asarray(f.rows), axis=0)
        contrib = _k.drspmm_dw_learnable_fused(f, gy_arena, xv, xi)
        return _dw_contrib_to_canon(f, nnz, contrib)
    # per-bucket sampled dot — the dw scatter into canonical order is an
    # XLA scatter under every backend (TPUs have no fast in-kernel scatter),
    # so "pallas" shares the bucketed reference reduction.
    return _learn._bwd_w(pack, gy, xv, xi, nnz)


# The executor — custom-vjp wrapper + jit — is built ONCE per
# (packing pair, nnz, dim, backend) and memoized.  The seed defined the
# custom_vjp wrapper inside the op body, so every call built a fresh
# closure and defeated jit/trace caching — the same class of bug
# core/parallel.py's executable memo fixed for the scheduler
# (tests/test_learnable_edges.py has the cache-hit regression).
#
# Entries hold the packings STRONGLY (the jitted closure pins them anyway,
# so a weakref-eviction scheme like ``_FUSE_CACHE``'s could never fire),
# which also makes the id keys collision-free while an entry lives; the
# table is LRU-bounded instead so a long-lived serve loop over many
# collated packings cannot grow it without bound.
_LEARNABLE_EXE: "OrderedDict[tuple, tuple]" = OrderedDict()
_LEARNABLE_EXE_MAX = 64
# Trace probe: appended to each time an executor's forward is TRACED (the
# body runs only while tracing).  Repeated same-shape calls must not grow it.
_LEARNABLE_TRACES: list = []


def _learnable_executable(fwdp, bwdp, nnz: int, dim: int, backend: Backend):
    key = (id(fwdp), id(bwdp), nnz, dim, backend)
    hit = _LEARNABLE_EXE.get(key)
    if hit is not None and hit[0] is fwdp and hit[1] is bwdp:
        _LEARNABLE_EXE.move_to_end(key)
        return hit[2]

    if backend == "dense":
        def f_dense(w, xv, xi):
            _LEARNABLE_TRACES.append(key)
            return _learnable_dense(fwdp, nnz, w, xv, xi, dim)
        exe = jax.jit(f_dense)                        # autodiff = exact oracle
    else:
        @jax.custom_vjp
        def f(w, xv, xi):
            _LEARNABLE_TRACES.append(key)
            return _learnable_fwd_impl(fwdp, nnz, w, xv, xi, dim, backend)

        def f_fwd(w, xv, xi):
            return f(w, xv, xi), (w, xv, xi)

        def f_bwd(res, gy):
            w, xv, xi = res
            gw = _learnable_dw_impl(fwdp, nnz, gy, xv, xi, backend)
            gx = _learnable_dx_impl(bwdp, nnz, w, gy, xi, backend)
            # xi is structural (integer): float0 cotangent
            return gw, gx, np.zeros(xi.shape, jax.dtypes.float0)

        f.defvjp(f_fwd, f_bwd)
        exe = jax.jit(f)

    _LEARNABLE_EXE[key] = (fwdp, bwdp, exe)
    _LEARNABLE_EXE.move_to_end(key)
    while len(_LEARNABLE_EXE) > _LEARNABLE_EXE_MAX:
        _LEARNABLE_EXE.popitem(last=False)
    return exe


def drspmm_learnable(fwd, bwd, nnz: int, w_canon: jax.Array,
                     x_vals: jax.Array, x_idx: jax.Array, dim: int, *,
                     backend: Backend = DEFAULT_BACKEND) -> jax.Array:
    """Y = A(w)·dense(CBSR(x)), differentiable in BOTH ``w_canon`` (nnz,)
    and ``x_vals`` (N, k).

    ``fwd``/``bwd`` are the forward/transposed edge-ID packings: bucketed
    eid slabs (:func:`~repro.graphs.ell.pack_eid_slabs`) or pre-fused eid
    arenas (:func:`~repro.graphs.ell.pack_fused_eid_pair`, collated
    batches).  On the fused backends this is ONE dispatch per direction —
    the weight gather w[eid] happens inside the kernel/arena computation —
    and dw is the sampled dot over the same arena plus one scatter to
    canonical order.  Gradient parity across all five backends:
    tests/test_learnable_edges.py.
    """
    backend = _learnable_effective_backend(fwd, backend)
    if backend in ("pallas_fused", "xla_fused"):
        fwd, bwd = _fused_eid_of(fwd), _fused_eid_of(bwd)
    return _learnable_executable(fwd, bwd, nnz, dim, backend)(
        w_canon, x_vals, x_idx)


# ---------------------------------------------------------------------------
# drspmm_multi — one dispatch per DIRECTION-GROUP: every edge-type direction
# of a hetero layer runs over a RelationPlan super-arena (graphs/ell.py),
# collapsing the per-relation Python loop the serial hetero_conv pays into
# one forward and one transposed-backward executor call per layer
# (DESIGN.md §9).
# ---------------------------------------------------------------------------

def _multi_effective_backend(backend: Backend) -> Backend:
    """Same family rules as :func:`_effective_backend`: a RelationPlan is
    always pre-fused (super-arenas have no bucket slabs to loop over), so
    per-bucket names upgrade to the fused executor of the matching family;
    ``dense`` keeps the oracle.  The traced-downgrade counterpart lives in
    :func:`drspmm_multi` itself: a plan whose leaves are jit tracers skips
    the id-keyed executor cache and traces inline (the outer jit owns the
    caching), since id-keying traced pytrees would be meaningless."""
    if backend in ("pallas", "pallas_fused"):
        return "pallas_fused"
    if backend == "dense":
        return "dense"
    return "xla_fused"


def _multi_concat(plan: RelationPlan, vals, idxs):
    """Stack per-type CBSR operands into the plan's type-concat slab,
    padding k up to the group max (padded value columns are zero, so they
    contribute nothing forward; their sampled gradients are sliced off on
    the way back).

    Values and indices travel together as one (n_t, 2, k) stack per type —
    f32 values bitcast to int32 — so the assembly is ONE pad + ONE
    concatenate instead of a separate pad/concat pair per operand (the
    forward-path overhead BENCH_drspmm attributed to the type-concat
    gather).  The shared container is int32, NOT float32: small column
    indices bitcast to f32 are denormals, and the jit partitioner is free
    to flush those to zero when this concat fuses with a shard_map reshard
    (observed on CPU: every xi reached the sharded kernel as 0).  Integer
    lanes are never flushed, and the int32 0 padding bitcasts back to an
    inert f32 +0.0 — identical padding semantics to the two-array form."""
    kmax = max(int(i.shape[1]) for i in idxs)
    vdt = vals[0].dtype
    parts = []
    for v, i in zip(vals, idxs):
        vi = jnp.stack(
            [jax.lax.bitcast_convert_type(v.astype(jnp.float32), jnp.int32),
             i.astype(jnp.int32)],
            axis=1)                                    # (n_t, 2, k_t)
        k = int(i.shape[1])
        if k < kmax:
            vi = jnp.pad(vi, ((0, 0), (0, 0), (0, kmax - k)))
        parts.append(vi)
    cat = jnp.concatenate(parts)                       # (N, 2, kmax)
    xv = jax.lax.bitcast_convert_type(cat[:, 0, :], jnp.float32).astype(vdt)
    xi = cat[:, 1, :]
    return xv, xi, kmax


def _split_out(plan: RelationPlan, y_cat):
    """Relation-concat output → per-relation views (segment order)."""
    return tuple(y_cat[s.out_off:s.out_off + s.n_dst]
                 for s in plan.segments)


def _dx_cat_to_types(plan: RelationPlan, dx_cat, dv_dense, idxs):
    """Arena relation-concat dV (+ dense-tier type-concat dV) → per-type
    gradients.

    Arena segments of one source type accumulate (cell feeds both ``near``
    and ``pin``); the dense tier's ``dv_dense`` is already type-concat —
    ONE transposed matmul over the stacked ``dense_bwd`` table sums every
    dense relation's contribution per source row — so it adds at most once
    per consuming type.  Padded k columns are sliced off per type.  Either
    input may be ``None`` (single-tier plans)."""
    outs = []
    for ti, t in enumerate(plan.src_types):
        k_t = int(idxs[ti].shape[1])
        acc = None
        for s in plan.arena_segments:
            if s.src_type != t:
                continue
            part = dx_cat[s.src_out_off:s.src_out_off + s.n_src]
            acc = part if acc is None else acc + part
        if dv_dense is not None and any(s.src_type == t
                                        for s in plan.dense_segments):
            o = int(plan.src_off[ti])
            part = dv_dense[o:o + int(plan.src_sizes[ti])]
            acc = part if acc is None else acc + part
        if acc is None:
            ref = dx_cat if dx_cat is not None else dv_dense
            acc = jnp.zeros((int(plan.src_sizes[ti]), k_t), ref.dtype)
        outs.append(acc[:, :k_t])
    return tuple(outs)


def _densify_cbsr(xv, xi, dim: int):
    """Type-concat CBSR → dense (N, dim) operand: the shared densify the
    hybrid forward's tiers both consume (one scatter over N·k values, vs
    the nnz·k-element arena scatter ``_fwd_fused_xla`` pays)."""
    n = xv.shape[0]
    return jnp.zeros((n, dim), xv.dtype).at[
        jnp.arange(n)[:, None], xi].add(xv)


def _multi_fwd_impl(plan: RelationPlan, xv, xi, dim: int, backend: Backend,
                    xd=None):
    if backend == "pallas_fused":
        _record_dispatch("pallas:multi_fwd")
        ya = _k.drspmm_fwd_multi(plan.fwd, xv, xi, dim)       # fp32 arena
        return jnp.take(ya, jnp.asarray(plan.fwd.gather),
                        axis=0).astype(xv.dtype)
    # XLA family: densify once, then the dense-operand arena walk (gather +
    # segment-sum). The in-arena CBSR scatter (`_fwd_fused_xla`) is ~9x
    # slower on CPU at medium nnz — it stays the per-relation reference the
    # serial path runs, and the Pallas kernel keeps consuming CBSR directly
    # (in-register densify; materializing xd would waste TPU bandwidth).
    _record_dispatch("xla:multi_fwd")
    if xd is None:
        xd = _densify_cbsr(xv, xi, dim)
    return _spmm_fused_xla(plan.fwd, xd).astype(xv.dtype)


def _multi_bwd_impl(plan: RelationPlan, gy_cat, xi, backend: Backend):
    """Relation-concat dV (Σ n_src_r, kmax) — ONE transposed dispatch."""
    ft = plan.bwd
    if backend == "pallas_fused":
        _record_dispatch("pallas:multi_bwd")
        ga = _k.drspmm_bwd_multi(ft, plan.bwd_src_rows, gy_cat, xi)
        return jnp.take(ga, jnp.asarray(ft.gather),
                        axis=0).astype(gy_cat.dtype)
    _record_dispatch("xla:multi_bwd")
    return _bwd_fused_xla(ft, gy_cat, xi, rows=plan.bwd_src_rows)


def _multi_dense_fwd(plan: RelationPlan, xv, xi, dim: int, backend: Backend,
                     xd=None):
    """Dense-tier forward: ONE batched masked matmul over the stacked
    ``dense_fwd`` table — every dense-tier relation of the direction-group
    at once (rows are the dense relation-concat, columns the full
    type-concat source slab)."""
    if backend == "pallas_fused":
        _record_dispatch("pallas:multi_dense_fwd")
        return _k.drspmm_dense_tier_fwd(jnp.asarray(plan.dense_fwd), xv, xi,
                                        dim).astype(xv.dtype)
    _record_dispatch("xla:multi_dense_fwd")
    if xd is None:
        xd = _densify_cbsr(xv.astype(jnp.float32), xi, dim)
    return (jnp.asarray(plan.dense_fwd) @ xd.astype(jnp.float32)
            ).astype(xv.dtype)


def _multi_dense_bwd(plan: RelationPlan, gy_dense, xi, backend: Backend):
    """Dense-tier backward: ONE transposed matmul + SSpMM sampling, landing
    directly in type-concat coordinates (``dense_bwd`` is
    (n_src_total, Σ dense n_dst), so source rows outside any dense relation
    come back exactly zero)."""
    if backend == "pallas_fused":
        _record_dispatch("pallas:multi_dense_bwd")
        return _k.drspmm_dense_tier_bwd(jnp.asarray(plan.dense_bwd),
                                        gy_dense, xi).astype(gy_dense.dtype)
    _record_dispatch("xla:multi_dense_bwd")
    dx = jnp.asarray(plan.dense_bwd) @ gy_dense.astype(jnp.float32)
    return jnp.take_along_axis(dx, xi, axis=1).astype(gy_dense.dtype)


def _hybrid_fwd(plan: RelationPlan, xv, xi, dim: int, backend: Backend):
    """Tiered forward: ≤1 fused arena dispatch + ≤1 batched dense dispatch,
    reassembled into the full relation-concat output.  Single-tier plans
    skip the reassembly — their tier-local offsets coincide with the full
    ``out_off`` coordinates.

    On the XLA family the type-concat CBSR is densified ONCE and the
    shared (N, dim) operand feeds both tiers — the dense tier has to
    materialize it anyway, so the arena leg rides along for free and drops
    its nnz-scale scatter.  Pallas tiers keep consuming CBSR directly."""
    xd = None if backend == "pallas_fused" else _densify_cbsr(xv, xi, dim)
    ya = _multi_fwd_impl(plan, xv, xi, dim, backend, xd=xd) \
        if plan.has_arena else None
    yd = _multi_dense_fwd(plan, xv, xi, dim, backend, xd=xd) \
        if plan.has_dense else None
    if yd is None:
        return ya
    if ya is None:
        return yd
    return jnp.concatenate(
        [ya[s.arena_out_off:s.arena_out_off + s.n_dst] if s.tier == "arena"
         else yd[s.dense_off:s.dense_off + s.n_dst]
         for s in plan.segments])


def _hybrid_bwd(plan: RelationPlan, gy_cat, xi, backend: Backend):
    """Tiered backward → (arena relation-concat dV | None, dense type-concat
    dV | None).  The arena transposed super-arena already addresses the FULL
    output concat (its ``nbr`` are pre-offset at pack time), so ``gy_cat``
    feeds it unsliced; the dense tier gets its segments' cotangent slices
    re-stacked into ``dense_fwd`` row order."""
    dx_cat = _multi_bwd_impl(plan, gy_cat, xi, backend) \
        if plan.has_arena else None
    dv_dense = None
    if plan.has_dense:
        gy_dense = gy_cat if not plan.has_arena else jnp.concatenate(
            [gy_cat[s.out_off:s.out_off + s.n_dst]
             for s in plan.dense_segments])
        dv_dense = _multi_dense_bwd(plan, gy_dense, xi, backend)
    return dx_cat, dv_dense


def _super_dense_mat(f: FusedELL):
    """Dense matrix of a (super-)arena built from its own tables — works
    with traced leaves, unlike the host-side ``to_dense``."""
    slot_rows = jnp.take(jnp.asarray(f.rows), _arena_rows(f), axis=0)
    nbr = jnp.asarray(f.nbr)
    a = jnp.zeros((f.n_dst, f.n_src), jnp.float32)
    return a.at[jnp.broadcast_to(slot_rows[:, :, None], nbr.shape),
                nbr].add(jnp.asarray(f.w))


def _plan_dense_mat(plan: RelationPlan):
    """Full (n_out_total, n_src_total) block matrix across BOTH tiers,
    built from the plan's own tables — works with traced leaves, unlike the
    host-side :meth:`RelationPlan.to_dense`."""
    a = jnp.zeros((plan.n_out_total, plan.n_src_total), jnp.float32)
    if plan.has_arena:
        fa = _super_dense_mat(plan.fwd)
        for s in plan.arena_segments:
            a = a.at[s.out_off:s.out_off + s.n_dst].set(
                fa[s.arena_out_off:s.arena_out_off + s.n_dst])
    if plan.has_dense:
        df = jnp.asarray(plan.dense_fwd, jnp.float32)
        for s in plan.dense_segments:
            a = a.at[s.out_off:s.out_off + s.n_dst].set(
                df[s.dense_off:s.dense_off + s.n_dst])
    return a


def _build_multi(plan: RelationPlan, dim: int, backend: Backend,
                 trace_key=None):
    """Custom-vjp callable over (vals_tuple, idxs_tuple): at most one fused
    arena dispatch plus one batched dense-tier dispatch per direction —
    O(1) per layer, not O(relations) — with the type-concat ``xi`` saved as
    a forward residual so the backward never re-runs the concat."""

    def probe():
        if trace_key is not None:
            _MULTI_TRACES.append(trace_key)

    if backend == "dense":
        def impl(vals, idxs):
            probe()
            xv, xi, _ = _multi_concat(plan, vals, idxs)
            n = xv.shape[0]
            xd = jnp.zeros((n, dim), xv.dtype).at[
                jnp.arange(n)[:, None], xi].add(xv)
            return _split_out(plan, _plan_dense_mat(plan) @ xd), xi

        def bwd_impl(xi, idxs, gys):
            # full-coordinate transposed oracle: summing every relation's
            # Aᵀ·gy into the type-concat rows FIRST and sampling once is
            # exact — take_along_axis at a type's shared xi is linear.
            gy_cat = jnp.concatenate(list(gys))
            dx_full = _plan_dense_mat(plan).T @ gy_cat    # (n_src_total, D)
            dv = jnp.take_along_axis(dx_full, xi, axis=1)
            return tuple(
                dv[int(o):int(o) + int(sz)][:, :int(i.shape[1])]
                for o, sz, i in zip(plan.src_off, plan.src_sizes, idxs))
    else:
        def impl(vals, idxs):
            probe()
            xv, xi, _ = _multi_concat(plan, vals, idxs)
            y_cat = _hybrid_fwd(plan, xv, xi, dim, backend)
            return _split_out(plan, y_cat), xi

        def bwd_impl(xi, idxs, gys):
            gy_cat = jnp.concatenate(list(gys))
            dx_cat, dv_dense = _hybrid_bwd(plan, gy_cat, xi, backend)
            return _dx_cat_to_types(plan, dx_cat, dv_dense, idxs)

    @jax.custom_vjp
    def f(vals, idxs):
        return impl(vals, idxs)[0]

    def f_fwd(vals, idxs):
        ys, xi = impl(vals, idxs)
        return ys, (xi, idxs)

    def f_bwd(res, gys):
        xi, idxs = res
        return (bwd_impl(xi, idxs, gys),
                tuple(np.zeros(np.shape(i), jax.dtypes.float0)
                      for i in idxs))

    f.defvjp(f_fwd, f_bwd)
    return f


def _zero_plan_cotangent(plan):
    """Symbolic-zero cotangent pytree for a plan passed as a custom-vjp
    primal: float0 for the integer tables, dense zeros for the float w
    arenas (custom_vjp requires real-dtype cotangents for float leaves)."""
    def z(x):
        if jnp.issubdtype(jnp.result_type(x), jnp.floating):
            return jnp.zeros_like(x)
        return np.zeros(np.shape(x), jax.dtypes.float0)
    return jax.tree.map(z, plan)


def _multi_traced(plan: RelationPlan, vals, idxs, dim: int, backend: Backend):
    """Traced-plan execution (collated serve batches / plan-attached trainer
    graphs, where the graph — plan included — is a jit argument).

    The plan rides through the custom_vjp as an explicit PRIMAL argument
    instead of a closure constant.  This is what makes the executor safe
    under layer-granular remat (``jax.checkpoint`` at the ``hetero_conv``
    boundary, models/backbone.py): the closure form would capture
    checkpoint-scope tracers inside ``f_bwd``, which are stale by the time
    the outer backward invokes it (UnexpectedTracerError).  As a primal the
    plan is a *saved residual* of the checkpointed layer: stored ONCE by
    reference (it is already a jit argument, so every layer's residual
    aliases the same buffers), never rematerialized in the backward, and
    never re-``device_put`` on recompute.  Cotangents for the plan leaves
    are symbolic zeros — the fixed-weight arenas carry no gradient."""

    def body(plan, vals, idxs):
        xv, xi, _ = _multi_concat(plan, vals, idxs)
        return _split_out(plan, _hybrid_fwd(plan, xv, xi, dim, backend)), xi

    @jax.custom_vjp
    def f(plan, vals, idxs):
        return body(plan, vals, idxs)[0]

    def f_fwd(plan, vals, idxs):
        ys, xi = body(plan, vals, idxs)
        # residuals: the plan (aliased jit args, see above) + type-concat xi
        return ys, (plan, xi, idxs)

    def f_bwd(res, gys):
        plan, xi, idxs = res
        gy_cat = jnp.concatenate(list(gys))
        dx_cat, dv_dense = _hybrid_bwd(plan, gy_cat, xi, backend)
        return (_zero_plan_cotangent(plan),
                _dx_cat_to_types(plan, dx_cat, dv_dense, idxs),
                tuple(np.zeros(np.shape(i), jax.dtypes.float0)
                      for i in idxs))

    f.defvjp(f_fwd, f_bwd)
    return f(plan, vals, idxs)


# Same memoization discipline as the learnable executor (§8.3): the
# custom-vjp wrapper + jit is built ONCE per (plan identity, dim, backend)
# in a strong-ref LRU (the jitted closure pins the plan anyway), with a
# trace probe asserting repeat calls never retrace.  Remat interaction:
# ``jax.checkpoint`` traces its body, so a checkpointed layer always sees
# TRACED plan leaves and routes through ``_multi_traced`` — the LRU is only
# ever touched by non-checkpointed concrete-plan calls, so recompute cannot
# thrash it (guarded by tests/test_backbone.py::test_remat_no_retrace).
_MULTI_EXE: "OrderedDict[tuple, tuple]" = OrderedDict()
_MULTI_EXE_MAX = 64
_MULTI_TRACES: list = []


def _multi_executable(plan: RelationPlan, dim: int, backend: Backend):
    key = (id(plan), dim, backend)
    hit = _MULTI_EXE.get(key)
    if hit is not None and hit[0] is plan:
        _MULTI_EXE.move_to_end(key)
        return hit[1]
    exe = jax.jit(_build_multi(plan, dim, backend, trace_key=key))
    _MULTI_EXE[key] = (plan, exe)
    _MULTI_EXE.move_to_end(key)
    while len(_MULTI_EXE) > _MULTI_EXE_MAX:
        _MULTI_EXE.popitem(last=False)
    return exe


def drspmm_multi(plan: RelationPlan, cbsr, dim: int, *,
                 backend: Backend = DEFAULT_BACKEND):
    """Whole-direction-group DR-SpMM, tiered at pack time: the plan's
    arena-tier relations run as ONE fused super-arena dispatch and its
    dense-tier relations (tiny, sub-crossover nnz — graphs/ell.py §tiering)
    as at most ONE batched dense matmul, forward and transposed backward
    alike — dispatch stays O(1) per layer with mixed tiers (≤2 fwd,
    ≤2 bwd).

    ``cbsr`` maps each source node type of the plan to its CBSR pair
    ``{ntype: (vals (n_t, k_t), idx (n_t, k_t))}``; k may differ per type
    (padded to the group max internally, inert).  Returns ``{etype: y
    (n_dst_r, dim)}`` with gradients flowing to every type's ``vals``
    (summed across the relations that consume the type); ``idx`` is
    structural (float0 cotangent).

    Backend rules mirror ``drspmm``/``drspmm_learnable``: plans are always
    pre-fused, so per-bucket names upgrade to the fused family
    (``pallas``→``pallas_fused``, ``xla``→``xla_fused``); ``dense`` is the
    autograd-free oracle with the Alg.-2 sampled backward.  A concrete plan
    routes through the id-keyed LRU executor cache
    (no retrace on repeat calls); a TRACED plan — e.g. a collated serve
    batch whose graph is a jit argument, or any plan seen inside a
    ``jax.checkpoint`` body — is executed inline with the plan threaded as
    a custom-vjp primal (``_multi_traced``: remat-safe, plan saved once as
    an aliased residual) and cached by the outer jit.  Parity across all
    five names: tests/test_relation_plan.py.
    """
    eff = _multi_effective_backend(backend)
    vals = tuple(cbsr[t][0] for t in plan.src_types)
    idxs = tuple(cbsr[t][1] for t in plan.src_types)
    if isinstance(plan.fwd.nbr, jax.core.Tracer):
        if eff == "dense":
            # the oracle closure is traced inline; the outer jit owns the
            # cache (the oracle is not remat-threaded like _multi_traced —
            # checkpointed layers always use the fused families)
            ys = _build_multi(plan, dim, eff)(vals, idxs)
        else:
            ys = _multi_traced(plan, vals, idxs, dim, eff)
    else:
        ys = _multi_executable(plan, dim, eff)(vals, idxs)
    return {s.etype: y for s, y in zip(plan.segments, ys)}


# ---------------------------------------------------------------------------
# drspmm_multi_sharded — the giant-graph path (DESIGN.md §12): the
# super-arena partitioned by destination row-block over a ("shard",) mesh
# (sharding/plan_shard.py), executed under shard_map with ONE all-to-all
# halo exchange per direction.  Each device holds only its local arenas +
# owned operand slabs; the §1/§5 per-shard contraction is unchanged.
# ---------------------------------------------------------------------------

def _sharded_effective_backend(backend: Backend) -> Backend:
    """The sharded path only has the fused per-shard executors (local
    arenas are always pre-fused; the dense oracle lives host-side as
    ``plan_shard.reference_forward``), so every name maps to the fused
    executor of its family."""
    return "pallas_fused" if backend in ("pallas", "pallas_fused") \
        else "xla_fused"


def _local_fused(tabs, n_dst: int, n_src: int, row_block: int,
                 chunk: int) -> FusedELL:
    """This device's arena from shard_map operand slices (leading shard
    axis of size 1) — traced leaves, static geometry."""
    nbr, w, blk, start, rows, gather = (t[0] for t in tabs)
    return FusedELL(nbr=nbr, w=w, block_of=blk, start=start, rows=rows,
                    gather=gather, n_dst=n_dst, n_src=n_src, nnz=-1,
                    row_block=row_block, chunk=chunk)


def _build_multi_sharded(splan, dim: int, backend: Backend, trace_key=None):
    """Custom-vjp callable over (vals_tuple, idxs_tuple), SPMD over the
    ("shard",) mesh.

    Forward: each device gathers the source rows its peers requested
    (``send_idx``), one ``all_to_all`` delivers every halo owner-major, the
    local slab ``[own | halo]`` feeds the unchanged fused contraction, and
    each device writes its contiguous output slab.  Backward reverses the
    exchange: the transposed local arena produces dx over the local slab;
    the halo segment travels back through the same ``all_to_all`` and is
    scatter-added into the owner shards' dx rows (two-coordinate backward,
    DESIGN.md §12).  Padded slots carry zero weights end to end — inert.
    """
    from repro.sharding.specs import shard_map_compat, shard_mesh
    from jax.sharding import PartitionSpec as P

    n, s_slab, t_slab, h = (splan.n_shards, splan.src_slab, splan.out_slab,
                            splan.halo_pad)
    local_src = splan.local_src
    mesh = shard_mesh(n)
    spec = P("shard")

    def probe():
        if trace_key is not None:
            _SHARDED_TRACES.append(trace_key)

    def fwd_inner(xv, xi, nbr, w, blk, start, rows, gather, send):
        # xv/xi: (S, k) owned slab; tables: (1, ...) shard slices
        send2 = send[0]                               # (n, H) rows peers want
        hv = jax.lax.all_to_all(jnp.take(xv, send2, axis=0), "shard", 0, 0)
        hi = jax.lax.all_to_all(jnp.take(xi, send2, axis=0), "shard", 0, 0)
        slab_v = jnp.concatenate([xv, hv.reshape(-1, xv.shape[1])])
        slab_i = jnp.concatenate([xi, hi.reshape(-1, xi.shape[1])])
        f = _local_fused((nbr, w, blk, start, rows, gather), t_slab,
                         local_src, splan.row_block, splan.fwd_chunk)
        if backend == "pallas_fused":
            ya = _k.drspmm_fwd_fused(f, slab_v, slab_i, dim)
            return jnp.take(ya, f.gather, axis=0).astype(xv.dtype)
        # densify-first, like the single-device hybrid: the slab is local
        # after the exchange, so the dense-operand walk is purely per-shard
        return _spmm_fused_xla(
            f, _densify_cbsr(slab_v, slab_i, dim)).astype(xv.dtype)

    def bwd_inner(gy, xi, nbr, w, blk, start, rows, gather, send):
        # gy: (T, D) owned output cotangent; xi: (S, k) owned indices
        send2 = send[0]
        hi = jax.lax.all_to_all(jnp.take(xi, send2, axis=0), "shard", 0, 0)
        slab_i = jnp.concatenate([xi, hi.reshape(-1, xi.shape[1])])
        ft = _local_fused((nbr, w, blk, start, rows, gather), local_src,
                          t_slab, splan.row_block, splan.bwd_chunk)
        if backend == "pallas_fused":
            xi_arena = jnp.take(slab_i, ft.rows, axis=0)
            ga = _k.drspmm_bwd_fused(ft, gy, xi_arena)
            dx_slab = jnp.take(ga, ft.gather, axis=0).astype(gy.dtype)
        else:
            dx_slab = _bwd_fused_xla(ft, gy, slab_i)  # (S + n·H, k)
        # reverse exchange: halo dx goes home, owners scatter-add it.  Both
        # padded send slots (local row 0) and the self segment add exact
        # zeros — unreferenced dx-slab rows gather from the sentinel block.
        back = jax.lax.all_to_all(
            dx_slab[s_slab:].reshape(n, h, -1), "shard", 0, 0)
        return dx_slab[:s_slab].at[send2.reshape(-1)].add(
            back.reshape(n * h, -1))

    sm = dict(mesh=mesh, check_vma=False)
    fwd_sm = shard_map_compat(in_specs=(spec,) * 9, out_specs=spec,
                              **sm)(fwd_inner)
    bwd_sm = shard_map_compat(in_specs=(spec,) * 9, out_specs=spec,
                              **sm)(bwd_inner)
    fwd_tabs = (splan.fwd_nbr, splan.fwd_w, splan.fwd_block_of,
                splan.fwd_start, splan.fwd_rows, splan.fwd_gather)
    bwd_tabs = (splan.bwd_nbr, splan.bwd_w, splan.bwd_block_of,
                splan.bwd_start, splan.bwd_rows, splan.bwd_gather)
    family = "pallas" if backend == "pallas_fused" else "xla"

    def _pad_rows(a, total):
        return jnp.pad(a, ((0, total - a.shape[0]), (0, 0)))

    @jax.custom_vjp
    def f(vals, idxs):
        probe()
        _record_dispatch(f"{family}:shard_fwd")
        xv, xi, _ = _multi_concat(splan, vals, idxs)
        y_full = fwd_sm(_pad_rows(xv, n * s_slab), _pad_rows(xi, n * s_slab),
                        *fwd_tabs, splan.send_idx)
        return _split_out(splan, y_full[:splan.n_out_total])

    def f_fwd(vals, idxs):
        return f(vals, idxs), idxs                # xi is the only residual

    def f_bwd(idxs, gys):
        _record_dispatch(f"{family}:shard_bwd")
        gy_cat = jnp.concatenate(list(gys))
        _, xi, _ = _multi_concat(splan, [jnp.zeros_like(i, jnp.float32)
                                         for i in idxs], idxs)
        dx_full = bwd_sm(_pad_rows(gy_cat, n * t_slab),
                         _pad_rows(xi, n * s_slab), *bwd_tabs,
                         splan.send_idx)
        dx = dx_full[:splan.n_src_total]          # already type-concat
        outs = tuple(dx[o:o + sz][:, :int(i.shape[1])]
                     for o, sz, i in zip(splan.src_off, splan.src_sizes,
                                         idxs))
        return (outs, tuple(np.zeros(np.shape(i), jax.dtypes.float0)
                            for i in idxs))

    f.defvjp(f_fwd, f_bwd)
    return f


_SHARDED_EXE: "OrderedDict[tuple, tuple]" = OrderedDict()
_SHARDED_EXE_MAX = 32
_SHARDED_TRACES: list = []


def _sharded_executable(splan, dim: int, backend: Backend):
    key = (id(splan), dim, backend)
    hit = _SHARDED_EXE.get(key)
    if hit is not None and hit[0] is splan:
        _SHARDED_EXE.move_to_end(key)
        return hit[1]
    exe = jax.jit(_build_multi_sharded(splan, dim, backend, trace_key=key))
    _SHARDED_EXE[key] = (splan, exe)
    _SHARDED_EXE.move_to_end(key)
    while len(_SHARDED_EXE) > _SHARDED_EXE_MAX:
        _SHARDED_EXE.popitem(last=False)
    return exe


def drspmm_multi_sharded(splan, cbsr, dim: int, *,
                         backend: Backend = DEFAULT_BACKEND):
    """Whole-direction-group DR-SpMM over a mesh-partitioned plan
    (:class:`~repro.sharding.plan_shard.ShardedRelationPlan`).

    Same contract as :func:`drspmm_multi` — ``cbsr`` maps source node types
    to CBSR pairs, returns ``{etype: y}``, gradients flow to every type's
    ``vals`` — but the execution is SPMD over the ``("shard",)`` mesh: one
    all-to-all halo exchange + one local fused contraction per direction,
    with each device holding only its arena slices (fwd/grad parity vs the
    single-device plan path: tests/test_sharded_parity.py).  Needs
    ``splan.n_shards`` visible devices (virtual CPU devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).  A concrete
    plan routes through the id-keyed LRU; a traced plan (e.g. a sharded
    trainer step taking the graph as a jit argument) traces inline and the
    outer jit owns the caching.
    """
    eff = _sharded_effective_backend(backend)
    vals = tuple(cbsr[t][0] for t in splan.src_types)
    idxs = tuple(cbsr[t][1] for t in splan.src_types)
    if isinstance(splan.fwd_nbr, jax.core.Tracer):
        ys = _build_multi_sharded(splan, dim, eff)(vals, idxs)
    else:
        ys = _sharded_executable(splan, dim, eff)(vals, idxs)
    return {s.etype: y for s, y in zip(splan.segments, ys)}
