"""Jit-ready wrappers over the DR-SpMM Pallas kernels.

``drspmm`` is the public op: Y = A · dense(CBSR(x_vals, x_idx)), with a
custom VJP that runs the sampled backward kernel (SSpMM) over the transposed
ELL packing, exactly as Alg. 2 reuses the forward's CBSR indices.

``backend`` selects the execution path:
  * "pallas_fused" — ONE Pallas dispatch per edge-type direction: all degree
                 buckets run in a single kernel over the FusedELL arena and
                 the per-bucket ``y.at[rows].add`` combine collapses to one
                 gather (DESIGN.md §1).  Default on TPU.
  * "xla_fused" — the SAME fused arena layout executed in plain jnp
                 (gather + one scatter / segment-sum, no per-bucket loop).
                 Default on CPU, where Pallas only interprets: it keeps the
                 fused packing's adaptive-chunk slot reduction and its
                 single-combine structure at real XLA wall-clock.
  * "pallas"   — the per-bucket Pallas kernels, one dispatch per degree
                 bucket (interpret-mode on CPU, native on TPU); kept as the
                 reference for the fused path;
  * "xla"      — same bucketed math in pure jnp (gather/one-hot), the
                 per-bucket reference at XLA wall-clock;
  * "dense"    — fully dense oracle (kernels/ref.py), the cuSPARSE-analogue.

Fused packings are derived lazily from the BucketedELL arguments via
``fuse_bucketed`` (host-side, memoized per packing), so every caller of the
bucketed API gets the single-dispatch path by flipping ``backend`` alone.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.graphs.ell import BucketedELL, FusedELL, fuse_bucketed
from repro.kernels import drspmm as _k
from repro.kernels import ref as _ref

Backend = Literal["pallas_fused", "xla_fused", "pallas", "xla", "dense"]
# The fused single-dispatch executor is the paper-faithful hot path on real
# hardware; on CPU the Pallas kernels only run in interpret mode (not
# wall-clock-representative), so the same fused arena layout executed in
# plain XLA is the default there.
DEFAULT_BACKEND: Backend = (
    "pallas_fused" if jax.default_backend() == "tpu" else "xla_fused")


def _fused_of(adj) -> FusedELL:
    if isinstance(adj, FusedELL):
        return adj
    return fuse_bucketed(adj)


def _effective_backend(adj, backend: Backend) -> Backend:
    """Fused packing is host-side preprocessing: it needs concrete arrays.
    When the adjacency arrives as a *traced jit argument* (e.g. a step
    function that takes the graph as a parameter), fall back to the
    per-bucket path of the same executor family — numerically identical
    (see tests/test_fused.py), just bucket-granular dispatch.  Callers who
    want the fused path inside jit should close over the graph (it is
    static per design) or pre-fuse with ``fuse_bucketed``.

    A pre-fused adjacency (:class:`FusedELL`, e.g. a collated serve batch —
    graphs/collate.py) has no bucket slabs to fall back to, so the
    per-bucket/dense backend names are upgraded to the fused executor of the
    matching family (numerically interchangeable, tests/test_fused.py).
    Crucially this works **inside jit with the graph traced**: the arena is
    already packed, so batches sharing a padded shape signature reuse one
    compiled executable."""
    if isinstance(adj, FusedELL):
        if backend in ("pallas", "pallas_fused"):
            return "pallas_fused"
        return "xla_fused"
    if backend in ("pallas_fused", "xla_fused"):
        if any(isinstance(b.nbr, jax.core.Tracer) for b in adj.buckets):
            return "pallas" if backend == "pallas_fused" else "xla"
    return backend


def _fwd_bucket_xla(bucket, x_vals, x_idx, dim):
    """Bucketed CBSR aggregation in plain jnp (same math as the kernel)."""
    v = jnp.take(x_vals, bucket.nbr, axis=0)          # (R, E, k)
    c = jnp.take(x_idx, bucket.nbr, axis=0)           # (R, E, k)
    vw = v * bucket.w[..., None]                      # weight each neighbor
    r, e, k = v.shape
    flat_rows = jnp.repeat(jnp.arange(r, dtype=jnp.int32)[:, None, None],
                           e, axis=1)
    out = jnp.zeros((r, dim), x_vals.dtype)
    return out.at[jnp.broadcast_to(flat_rows, c.shape), c].add(vw)


def _bwd_bucket_xla(bucket, gy, xi_rows):
    g = jnp.take(gy, bucket.nbr, axis=0)              # (R, E, D)
    sampled = jnp.take_along_axis(
        g, jnp.broadcast_to(xi_rows[:, None, :], g.shape[:2] + xi_rows.shape[1:]),
        axis=2)                                       # (R, E, k)
    return jnp.sum(sampled * bucket.w[..., None], axis=1)


# ----- fused arena executed in plain XLA (CPU hot path; same layout the
# ----- Pallas fused kernels consume, so the adaptive chunk packing's
# ----- ~2× slot reduction and the scatter-free combine carry over) -------

def _arena_rows(f: FusedELL):
    """(C, BR) arena row id of each chunk slot row."""
    return (jnp.asarray(f.block_of)[:, None] * f.row_block
            + jnp.arange(f.row_block, dtype=jnp.int32)[None, :])


def _fwd_fused_xla(f: FusedELL, x_vals, x_idx, dim: int):
    nbr = jnp.asarray(f.nbr)                          # (C, BR, Ec)
    w = jnp.asarray(f.w)
    v = jnp.take(x_vals, nbr, axis=0)                 # (C, BR, Ec, k)
    cols = jnp.take(x_idx, nbr, axis=0)
    vw = v * w[..., None]
    rows = _arena_rows(f)                             # (C, BR)
    y = jnp.zeros((f.n_arena_rows, dim), x_vals.dtype)
    y = y.at[jnp.broadcast_to(rows[:, :, None, None], cols.shape),
             cols].add(vw)
    return jnp.take(y, jnp.asarray(f.gather), axis=0)


def _bwd_fused_xla(ft: FusedELL, gy, x_idx):
    tnbr = jnp.asarray(ft.nbr)                        # (C, BR, Ec) targets
    tw = jnp.asarray(ft.w)
    k = x_idx.shape[1]
    g = jnp.take(gy, tnbr, axis=0)                    # (C, BR, Ec, D)
    xi_arena = jnp.take(x_idx, jnp.asarray(ft.rows), axis=0)  # (R_arena, k)
    xi_blocks = jnp.take(xi_arena, _arena_rows(ft), axis=0)   # (C, BR, k)
    sampled = jnp.take_along_axis(
        g, jnp.broadcast_to(xi_blocks[:, :, None, :], g.shape[:3] + (k,)),
        axis=3)                                       # (C, BR, Ec, k) — SSpMM
    contrib = jnp.sum(sampled * tw[..., None], axis=2)         # (C, BR, k)
    n_blocks = ft.n_arena_rows // ft.row_block
    dv = jax.ops.segment_sum(contrib, jnp.asarray(ft.block_of),
                             num_segments=n_blocks)
    dv = dv.reshape(ft.n_arena_rows, k)
    return jnp.take(dv, jnp.asarray(ft.gather), axis=0)


def _spmm_fused_xla(f: FusedELL, x):
    nbr = jnp.asarray(f.nbr)
    w = jnp.asarray(f.w)
    rows_x = jnp.take(x, nbr, axis=0)                 # (C, BR, Ec, D)
    contrib = jnp.sum(rows_x * w[..., None], axis=2)  # (C, BR, D)
    n_blocks = f.n_arena_rows // f.row_block
    y = jax.ops.segment_sum(contrib, jnp.asarray(f.block_of),
                            num_segments=n_blocks)
    y = y.reshape(f.n_arena_rows, x.shape[1])
    return jnp.take(y, jnp.asarray(f.gather), axis=0)


def _fwd_impl(adj: BucketedELL, x_vals, x_idx, dim: int, backend: Backend):
    if backend == "dense":
        return _ref.drspmm_fwd_ref(adj, x_vals, x_idx, dim)
    if backend == "xla_fused":
        return _fwd_fused_xla(_fused_of(adj), x_vals, x_idx, dim)
    if backend == "pallas_fused":
        f = _fused_of(adj)
        ya = _k.drspmm_fwd_fused(f, x_vals, x_idx, dim)   # fp32 arena
        return jnp.take(ya, f.gather, axis=0).astype(x_vals.dtype)
    y = jnp.zeros((adj.n_dst, dim), x_vals.dtype)
    for b in adj.buckets:
        if backend == "pallas":
            yb = _k.drspmm_fwd_bucket(b, x_vals, x_idx, dim)
        else:
            yb = _fwd_bucket_xla(b, x_vals, x_idx, dim)
        y = y.at[b.rows].add(yb)  # padded rows carry zero weights — inert
    return y


def _bwd_impl(adj_t: BucketedELL, gy, x_idx, backend: Backend):
    if backend == "dense":
        return _ref.drspmm_bwd_ref(adj_t, gy, x_idx)
    n, k = x_idx.shape
    if backend == "xla_fused":
        return _bwd_fused_xla(_fused_of(adj_t), gy, x_idx)
    if backend == "pallas_fused":
        ft = _fused_of(adj_t)
        xi_arena = jnp.take(x_idx, ft.rows, axis=0)   # (R_arena, k)
        ga = _k.drspmm_bwd_fused(ft, gy, xi_arena)    # fp32 arena
        return jnp.take(ga, ft.gather, axis=0).astype(gy.dtype)
    gv = jnp.zeros((n, k), gy.dtype)
    for b in adj_t.buckets:
        xi_rows = jnp.take(x_idx, b.rows, axis=0)     # (R, k)
        if backend == "pallas":
            gb = _k.drspmm_bwd_bucket(b, gy, xi_rows)
        else:
            gb = _bwd_bucket_xla(b, gy, xi_rows)
        gv = gv.at[b.rows].add(gb)
    return gv


def drspmm(adj: BucketedELL, adj_t: BucketedELL, x_vals: jax.Array,
           x_idx: jax.Array, dim: int, *,
           backend: Backend = DEFAULT_BACKEND) -> jax.Array:
    """Differentiable DR-SpMM.  Gradient flows to ``x_vals`` only; the
    adjacency and the CBSR indices are structural."""

    backend = _effective_backend(adj, backend)

    @jax.custom_vjp
    def f(xv):
        return _fwd_impl(adj, xv, x_idx, dim, backend)

    def f_fwd(xv):
        return _fwd_impl(adj, xv, x_idx, dim, backend), None

    def f_bwd(_, gy):
        return (_bwd_impl(adj_t, gy, x_idx, backend),)

    f.defvjp(f_fwd, f_bwd)
    return f(x_vals)


def spmm(adj: BucketedELL, adj_t: BucketedELL, x: jax.Array, *,
         backend: Backend = DEFAULT_BACKEND) -> jax.Array:
    """Dense-operand SpMM baseline with full (not sampled) backward."""

    backend = _effective_backend(adj, backend)

    @jax.custom_vjp
    def f(xd):
        return _spmm_fwd(adj, xd, backend)

    def f_fwd(xd):
        return _spmm_fwd(adj, xd, backend), None

    def f_bwd(_, gy):
        return (_spmm_fwd(adj_t, gy, backend),)

    f.defvjp(f_fwd, f_bwd)
    return f(x)


def _spmm_fwd(adj: BucketedELL, x, backend: Backend):
    if backend == "dense":
        return _ref.spmm_dense_ref(adj, x)
    if backend == "xla_fused":
        return _spmm_fused_xla(_fused_of(adj), x)
    if backend == "pallas_fused":
        f = _fused_of(adj)
        ya = _k.spmm_dense_fused(f, x)                # fp32 arena
        return jnp.take(ya, f.gather, axis=0).astype(x.dtype)
    y = jnp.zeros((adj.n_dst, x.shape[1]), x.dtype)
    for b in adj.buckets:
        if backend == "pallas":
            yb = _k.spmm_dense_bucket(b, x)
        else:
            rows = jnp.take(x, b.nbr, axis=0)         # (R, E, D)
            yb = jnp.sum(rows * b.w[..., None], axis=1)
        y = y.at[b.rows].add(yb)
    return y
