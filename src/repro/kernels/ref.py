"""Pure-jnp oracles for the DR-SpMM kernels.

Everything here is the mathematically transparent (dense) definition used by
tests to validate the Pallas kernels bit-for-bit (interpret mode) /
allclose (compiled).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.cbsr import CBSR, scatter_cbsr
from repro.graphs.ell import BucketedELL


def drspmm_fwd_ref(adj: BucketedELL, x_vals, x_idx, dim: int):
    """Y = A · dense(X_cbsr) via fully dense math."""
    a = adj.to_dense()
    x = scatter_cbsr(x_vals, x_idx, dim)
    return a @ x


def drspmm_bwd_ref(adj_t: BucketedELL, gy, x_idx):
    """dX_vals = sample(Aᵀ · dY, x_idx)  — the SSpMM of Alg. 2."""
    gx_dense = adj_t.to_dense() @ gy
    return jnp.take_along_axis(gx_dense, x_idx, axis=1)


def spmm_dense_ref(adj: BucketedELL, x):
    """Plain SpMM with a dense operand (the cuSPARSE-analogue baseline)."""
    return adj.to_dense() @ x
