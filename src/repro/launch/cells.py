"""(architecture × input-shape) cell definitions shared by the dry-run,
the roofline analysis, and the benchmarks.

A *cell* = (arch, shape).  ``build_cell`` returns everything needed to
lower it on a mesh: the jit-able step function, abstract inputs
(ShapeDtypeStruct — no allocation), and in/out shardings.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, SHAPES, ShapeSpec, get_config
from repro.models.lm import serve
from repro.models.lm.model import LM, build_lm
from repro.sharding.specs import make_pspec
from repro.train import lm_step


def cell_skip_reason(cfg: ArchConfig, shape: ShapeSpec) -> Optional[str]:
    """Why a cell is skipped (None = runnable).  See DESIGN.md
    §Arch-applicability."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("long_500k needs sub-quadratic attention; "
                f"{cfg.name} is full-attention — skipped per spec")
    return None


def list_cells() -> Tuple[Tuple[str, str], ...]:
    from repro.configs.base import ARCH_IDS
    return tuple((a, s) for a in ARCH_IDS for s in SHAPES)


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeSpec
    lm: LM
    step_fn: Callable           # jit-able
    abstract_inputs: Tuple      # positional args (ShapeDtypeStructs)
    in_shardings: Tuple
    out_shardings: Any
    kind: str                   # train | prefill | decode
    donate: Tuple[int, ...] = ()


def _named(mesh, shape, axes):
    return NamedSharding(mesh, make_pspec(shape, axes, mesh))


def _batch_extras(cfg: ArchConfig, b: int, mesh, dtype):
    """Modality-frontend stubs (spec contract: precomputed embeddings)."""
    extras, shards = {}, {}
    if cfg.family == "vlm":
        sh = (b, cfg.n_img_tokens, cfg.d_model)
        extras["image_emb"] = jax.ShapeDtypeStruct(sh, dtype)
        shards["image_emb"] = _named(mesh, sh, ("batch", None, None))
    if cfg.family == "audio":
        sh = (b, cfg.enc_frames, cfg.d_model)
        extras["frames"] = jax.ShapeDtypeStruct(sh, dtype)
        shards["frames"] = _named(mesh, sh, ("batch", "sp", None))
    return extras, shards


def build_cell(arch: str, shape_name: str, mesh: Mesh, *,
               causal_mode: str = "brick", grad_accum: int = 1,
               overrides: Optional[Dict] = None) -> Cell:
    cfg = get_config(arch, **(overrides or {}))
    shape = SHAPES[shape_name]
    reason = cell_skip_reason(cfg, shape)
    if reason:
        raise ValueError(f"cell skipped: {reason}")
    tp = mesh.shape.get("model", 1)
    lm = build_lm(cfg, tp=tp, causal_mode=causal_mode)
    b, s = shape.global_batch, shape.seq_len
    tok_sh = (b, s)

    if shape.kind == "train":
        # microbatch gradient accumulation: batch gets a leading accum dim
        # (same global tokens/step, ÷ga activation residency)
        ga = grad_accum if grad_accum > 1 else cfg.grad_accum
        state = lm_step.abstract_train_state(lm)
        state_sh = lm_step.train_state_shardings(lm, mesh)
        if ga > 1:
            assert b % ga == 0, (b, ga)
            tok_sh = (ga, b // ga, s)
            tok_axes = (None, "batch", None)
        else:
            tok_axes = ("batch", None)
        batch = {"tokens": jax.ShapeDtypeStruct(tok_sh, jnp.int32),
                 "targets": jax.ShapeDtypeStruct(tok_sh, jnp.int32)}
        batch_sh = {k: _named(mesh, tok_sh, tok_axes) for k in batch}
        extras, ex_sh = _batch_extras(cfg, b // ga if ga > 1 else b,
                                      mesh, lm.dtype)
        if ga > 1 and extras:
            extras = {k: jax.ShapeDtypeStruct((ga,) + v.shape, v.dtype)
                      for k, v in extras.items()}
            ex_sh = {k: _named(mesh, extras[k].shape,
                               (None, "batch") + (None,) * (extras[k].ndim - 2))
                     for k in extras}
        batch.update(extras)
        batch_sh.update(ex_sh)
        step = lm_step.make_train_step(lm, grad_accum=ga)
        scalar = NamedSharding(mesh, P())
        out_sh = (state_sh, {"loss": scalar, "grad_norm": scalar,
                             "lr": scalar})
        return Cell(arch, shape, lm, step, (state, batch),
                    (state_sh, batch_sh), out_sh, "train", donate=(0,))

    params = lm.abstract_params()
    params_sh = lm.param_shardings(mesh)

    if shape.kind == "prefill":
        tokens = jax.ShapeDtypeStruct(tok_sh, jnp.int32)
        tokens_sh = _named(mesh, tok_sh, ("batch", None))
        extras, ex_sh = _batch_extras(cfg, b, mesh, lm.dtype)
        cache_sh = serve.cache_shardings(lm, b, s, mesh)
        logits_sh = _named(mesh, (b, 1, lm.v_pad), ("batch", None, "vocab"))

        if extras:
            def step(p, t, ex):
                return serve.prefill(lm, p, t, ex)
            return Cell(arch, shape, lm, step, (params, tokens, extras),
                        (params_sh, tokens_sh, ex_sh),
                        (cache_sh, logits_sh), "prefill")

        def step(p, t):
            return serve.prefill(lm, p, t, None)
        return Cell(arch, shape, lm, step, (params, tokens),
                    (params_sh, tokens_sh), (cache_sh, logits_sh), "prefill")

    # decode: one new token against a seq_len-sized cache
    cache = serve.cache_structs(lm, b, s)
    cache_sh = serve.cache_shardings(lm, b, s, mesh)
    token = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    token_sh = _named(mesh, (b, 1), ("batch", None))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    pos_sh = NamedSharding(mesh, P())
    logits_sh = _named(mesh, (b, 1, lm.v_pad), ("batch", None, "vocab"))

    def step(p, c, t, q):
        return serve.decode_step(lm, p, c, t, q)

    return Cell(arch, shape, lm, step, (params, cache, token, pos),
                (params_sh, cache_sh, token_sh, pos_sh),
                (cache_sh, logits_sh), "decode", donate=(1,))


def lower_cell(cell: Cell, mesh: Mesh):
    """.lower() the cell's step on the mesh (abstract — no allocation)."""
    from repro.sharding.specs import mesh_context
    with mesh_context(mesh):
        jitted = jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate)
        with mesh:
            lowered = jitted.lower(*cell.abstract_inputs)
    return lowered
