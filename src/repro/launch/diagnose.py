import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Per-op diagnosis for the §Perf hillclimb: lowers one cell and prints the
top collective and top byte-traffic instructions with loop multiplicities.

    PYTHONPATH=src python -m repro.launch.diagnose --arch X --shape Y \
        [--causal-mode brick] [--multi-pod] [--top 15]
"""

import argparse
import re

from repro.configs.base import ARCH_IDS, SHAPES
from repro.launch import hlo_analysis as H
from repro.launch.cells import build_cell, lower_cell
from repro.launch.mesh import make_production_mesh


def diagnose(text: str, top: int = 15):
    comps = H.parse_module(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            entry = H._COMP_START_RE.match(line).group(1)
            break
    mult, fus = {}, {}

    def visit(name, m, f):
        if name not in comps:
            return
        mult[name] = mult.get(name, 0) + m
        fus[name] = fus.get(name, True) and f
        for ins in comps[name].instrs:
            for callee, ctx, trip in H._callees(ins):
                visit(callee, m * trip, f or ctx == "fusion")

    visit(entry, 1.0, False)

    colls, bytes_rows = [], []
    for name, m in mult.items():
        comp = comps[name]
        if fus.get(name):
            continue
        for ins in comp.instrs:
            meta = re.search(r'op_name="([^"]*)"', ins.attrs)
            label = meta.group(1)[-90:] if meta else ins.name
            kind = H._coll_kind(ins.opcode)
            if kind and not ins.opcode.endswith("-done"):
                shapes = ins.out_shapes
                if ins.opcode.endswith("-start") and len(shapes) > 1:
                    shapes = shapes[: len(shapes) // 2]
                b = sum(H._nbytes(dt, d) for dt, d in shapes)
                colls.append((m * b, m, b, kind, shapes[:1], label))
            b = H._instr_bytes(ins, comp)
            if b:
                bytes_rows.append((m * b, m, ins.opcode,
                                   ins.out_shapes[:1], label))

    print(f"== top {top} collectives (bytes × multiplicity) ==")
    for r in sorted(colls, reverse=True)[:top]:
        print(f"{r[0]/1e9:9.2f} GB  ×{r[1]:<5.0f} {r[3]:15s} {r[4]} {r[5]}")
    print(f"\n== top {top} byte-traffic instructions ==")
    for r in sorted(bytes_rows, reverse=True)[:top]:
        print(f"{r[0]/1e9:9.2f} GB  ×{r[1]:<5.0f} {r[2]:20s} {r[3]} {r[4]}")
    ana = H.analyze(text)
    print(f"\nflops={ana['flops']:.3e}  bytes={ana['bytes']:.3e}  "
          f"bytes_aliased={ana['bytes_aliased']:.3e}  "
          f"coll={ana['collective_bytes']:.3e}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--shape", choices=list(SHAPES), required=True)
    ap.add_argument("--causal-mode", default="masked")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--override", action="append", default=[],
                    help="config override key=value (int)")
    args = ap.parse_args()
    overrides = {}
    for kv in args.override:
        k, v = kv.split("=")
        overrides[k] = int(v) if v.lstrip("-").isdigit() else v
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    cell = build_cell(args.arch, args.shape, mesh,
                      causal_mode=args.causal_mode,
                      grad_accum=args.grad_accum, overrides=overrides)
    compiled = lower_cell(cell, mesh).compile()
    diagnose(compiled.as_text(), args.top)


if __name__ == "__main__":
    main()
