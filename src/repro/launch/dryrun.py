import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede any jax import (device count locks at first init).

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
        --shape train_4k [--multi-pod] [--out experiments/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all

Success criterion (deliverable e): ``.lower().compile()`` succeeds for the
(16,16) single-pod mesh AND the (2,16,16) multi-pod mesh for every cell;
``memory_analysis()`` proves fit; ``cost_analysis()`` + the HLO collective
scan feed §Roofline.
"""

import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs.base import ARCH_IDS, SHAPES, get_config
from repro.launch.cells import build_cell, cell_skip_reason, lower_cell
from repro.launch.mesh import describe, make_production_mesh


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             causal_mode: str = "brick") -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "mesh": describe(mesh),
           "multi_pod": multi_pod, "causal_mode": causal_mode}
    cfg = get_config(arch)
    reason = cell_skip_reason(cfg, SHAPES[shape_name])
    if reason:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return _save(rec, out_dir)
    try:
        cell = build_cell(arch, shape_name, mesh, causal_mode=causal_mode)
        lowered = lower_cell(cell, mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        print("=== memory_analysis ===")
        print(mem)
        print("=== cost_analysis (flops/bytes) ===")
        print({k: v for k, v in cost.items()
               if k in ("flops", "bytes accessed")} if isinstance(cost, dict)
              else cost)

        # trip-count-aware analysis (cost_analysis counts scan bodies once;
        # see hlo_analysis module docstring)
        from repro.launch import hlo_analysis
        ana = hlo_analysis.analyze(compiled.as_text())
        rec.update(
            status="ok", kind=cell.kind,
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            xla_flops_naive=float(cost.get("flops", 0.0)),
            xla_bytes_naive=float(cost.get("bytes accessed", 0.0)),
            flops=ana["flops"],                       # per-device, ×trips
            bytes_accessed=ana["bytes"],
            collectives={**ana["collectives"],
                         "total": ana["collective_bytes"],
                         "n_ops": ana["n_collectives"]},
            memory=_mem_dict(mem),
            param_count=cfg.param_count(),
            active_param_count=cfg.active_param_count(),
            tokens=SHAPES[shape_name].global_batch
                   * (1 if cell.kind == "decode" else SHAPES[shape_name].seq_len),
        )
    except Exception as e:
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return _save(rec, out_dir)


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _save(rec: dict, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    tag = "mp" if rec["multi_pod"] else "sp"
    fn = os.path.join(out_dir, f"{rec['arch']}_{rec['shape']}_{tag}.json")
    with open(fn, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[dryrun] {rec['arch']} × {rec['shape']} ({tag}) "
          f"-> {rec['status']}" + (f" ({rec.get('error','')})"
                                   if rec["status"] == "fail" else ""))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--causal-mode", default="brick",
                    choices=("masked", "brick"))
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    if args.all:
        fails = 0
        for arch in ARCH_IDS:
            for shape in SHAPES:
                for mp in (False, True):
                    rec = run_cell(arch, shape, mp, args.out,
                                   args.causal_mode)
                    fails += rec["status"] == "fail"
        sys.exit(1 if fails else 0)

    assert args.arch and args.shape, "--arch/--shape or --all"
    rec = run_cell(args.arch, args.shape, args.multi_pod, args.out,
                   args.causal_mode)
    sys.exit(0 if rec["status"] in ("ok", "skipped") else 1)


if __name__ == "__main__":
    main()
