"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` visits every computation ONCE — a
``lax.scan`` over 28 layers reports 1/28th of the real layer FLOPs.  All
our models scan over layers, so naive cost_analysis undercounts by ~L×.
This module re-derives the roofline terms from ``compiled.as_text()`` with
while-loop multiplicities propagated through the call graph
(``backend_config={"known_trip_count":{"n":...}}``).

Per-device quantities produced:
  * ``flops``        — 2·M·N·K summed over every ``dot`` (MXU work; the
                       elementwise tail is bandwidth-, not compute-bound);
  * ``bytes``        — Σ (operands + outputs) over non-fusion-internal
                       instructions (HloCostAnalysis' definition of
                       bytes-accessed, i.e. an HBM-traffic upper bound);
  * ``collectives``  — per-kind payload bytes (per-participant shard sizes,
                       the operand of the ICI-bandwidth term).

The HLO module of an SPMD-partitioned program is the per-device program, so
everything here is already per-chip.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute", "collective-broadcast")

# free / metadata ops excluded from byte accounting
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id", "iota"}


def _dims(dims_str: str) -> Tuple[int, ...]:
    return tuple(int(d) for d in dims_str.split(",") if d)


def _nbytes(dtype: str, dims: Tuple[int, ...]) -> int:
    n = _DTYPE_BYTES.get(dtype, 4)
    for d in dims:
        n *= d
    return n


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    out_shapes: List[Tuple[str, Tuple[int, ...]]]
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    shape_of: Dict[str, List[Tuple[str, Tuple[int, ...]]]]


def _parse_operands(rest: str, op_idx: int) -> Tuple[List[str], str]:
    """Operand %names inside the balanced parens after the opcode."""
    i = rest.index("(", op_idx)
    depth, j = 0, i
    while j < len(rest):
        if rest[j] == "(":
            depth += 1
        elif rest[j] == ")":
            depth -= 1
            if depth == 0:
                break
        j += 1
    args = rest[i + 1: j]
    attrs = rest[j + 1:]
    return re.findall(r"%([\w.\-]+)", args), attrs


_OPCODE_RE = re.compile(
    r"^\s*(?:\((?:[^()]|\([^()]*\))*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)"
    r"\s+([\w\-]+)\(")


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_START_RE.match(line)
            if m:
                cur = Computation(m.group(1), [], {})
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        om = _OPCODE_RE.match(rest)
        if not om:
            continue
        opcode = om.group(1)
        type_part = rest[: om.start(1)]
        out_shapes = [(dt, _dims(ds)) for dt, ds in _SHAPE_RE.findall(type_part)]
        operands, attrs = _parse_operands(rest, om.start(1))
        ins = Instr(name, opcode, out_shapes, operands, attrs)
        cur.instrs.append(ins)
        cur.shape_of[name] = out_shapes
    return comps


def _dot_flops(ins: Instr, comp: Computation) -> float:
    """2 · |output| · K (K = product of lhs contracting dim sizes)."""
    out_elems = 1
    for _, dims in ins.out_shapes[:1]:
        for d in dims:
            out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
    if not m or not ins.operands:
        return 0.0
    lhs = comp.shape_of.get(ins.operands[0])
    if not lhs:
        return 0.0
    lhs_dims = lhs[0][1]
    k = 1
    for ci in _dims(m.group(1)):
        if ci < len(lhs_dims):
            k *= lhs_dims[ci]
    return 2.0 * out_elems * k


def _instr_bytes(ins: Instr, comp: Computation) -> float:
    if ins.opcode in _FREE_OPS:
        return 0.0
    total = 0
    for dt, dims in ins.out_shapes:
        total += _nbytes(dt, dims)
    for op in ins.operands:
        for dt, dims in comp.shape_of.get(op, []):
            total += _nbytes(dt, dims)
    return float(total)


def _instr_bytes_aliased(ins: Instr, comp: Computation) -> float:
    """Optimistic-aliasing byte model: when an operand has exactly the
    output's shape (scan accumulators, dynamic-update-slice buffers,
    elementwise in-place), XLA's buffer assignment aliases it — the write
    is in-place and the buffer moves once, not twice."""
    if ins.opcode in _FREE_OPS:
        return 0.0
    out_shapes = list(ins.out_shapes)
    total = sum(_nbytes(dt, d) for dt, d in out_shapes)
    remaining = list(out_shapes)
    for op in ins.operands:
        for dt, dims in comp.shape_of.get(op, []):
            if (dt, dims) in remaining:
                remaining.remove((dt, dims))     # aliased with an output
                continue
            total += _nbytes(dt, dims)
    return float(total)


def _callees(ins: Instr) -> List[Tuple[str, str, int]]:
    """(callee, context, trip) — context ∈ {fusion, control}."""
    out = []
    if ins.opcode == "while":
        trip = 1
        m = _TRIP_RE.search(ins.attrs)
        if m:
            trip = int(m.group(1))
        b = re.search(r"body=%?([\w.\-]+)", ins.attrs)
        c = re.search(r"condition=%?([\w.\-]+)", ins.attrs)
        if b:
            out.append((b.group(1), "control", trip))
        if c:
            out.append((c.group(1), "control", trip + 1))
    elif ins.opcode == "fusion":
        m = re.search(r"calls=%?([\w.\-]+)", ins.attrs)
        if m:
            out.append((m.group(1), "fusion", 1))
    elif ins.opcode in ("call", "async-start", "custom-call"):
        m = re.search(r"to_apply=%?([\w.\-]+)", ins.attrs)
        if m:
            out.append((m.group(1), "control", 1))
    elif ins.opcode == "conditional":
        for m in re.finditer(r"(?:true_computation|false_computation|"
                             r"branch_computations=\{)[=%]?%?([\w.\-]+)",
                             ins.attrs):
            out.append((m.group(1), "control", 1))
        m = re.search(r"branch_computations=\{([^}]*)\}", ins.attrs)
        if m:
            for name in re.findall(r"%([\w.\-]+)", m.group(1)):
                out.append((name, "control", 1))
    return out


def analyze(text: str) -> Dict:
    comps = parse_module(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_START_RE.match(line)
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        # fall back: largest computation
        entry = max(comps, key=lambda k: len(comps[k].instrs))

    # multiplicity propagation (DFS; HLO call graphs are acyclic)
    mult: Dict[str, float] = {}
    fusion_ctx: Dict[str, bool] = {}

    def visit(name: str, m: float, in_fusion: bool):
        if name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        fusion_ctx[name] = fusion_ctx.get(name, True) and in_fusion
        for ins in comps[name].instrs:
            for callee, ctx, trip in _callees(ins):
                visit(callee, m * trip, in_fusion or ctx == "fusion")

    visit(entry, 1.0, False)

    flops = 0.0
    bytes_ = 0.0
    bytes_aliased = 0.0
    coll = {k: 0.0 for k in COLLECTIVE_KINDS}
    n_coll = 0.0
    for name, m in mult.items():
        comp = comps[name]
        in_fusion = fusion_ctx.get(name, False)
        for ins in comp.instrs:
            if ins.opcode == "dot":
                flops += m * _dot_flops(ins, comp)
            if in_fusion:
                continue
            kind = _coll_kind(ins.opcode)
            if kind:
                if ins.opcode.endswith("-done"):
                    continue
                b = 0.0
                shapes = ins.out_shapes
                if ins.opcode.endswith("-start") and len(shapes) > 1:
                    shapes = shapes[: len(shapes) // 2]
                for dt, dims in shapes:
                    b += _nbytes(dt, dims)
                coll[kind] += m * b
                n_coll += m
            bytes_ += m * _instr_bytes(ins, comp)
            bytes_aliased += m * _instr_bytes_aliased(ins, comp)

    coll_total = sum(coll.values())
    return {"flops": flops, "bytes": bytes_,
            "bytes_aliased": bytes_aliased, "collectives": coll,
            "collective_bytes": coll_total, "n_collectives": n_coll,
            "n_computations": len(comps)}


def _coll_kind(opcode: str) -> Optional[str]:
    for k in COLLECTIVE_KINDS:
        if opcode == k or opcode == k + "-start" or opcode == k + "-done":
            return k
    return None
