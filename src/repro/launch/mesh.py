"""Production mesh construction.

Mesh shapes (TPU v5e-pod-scale):
    single pod : (16, 16)      axes ("data", "model")    = 256 chips
    multi-pod  : (2, 16, 16)   axes ("pod", "data", "model") = 512 chips

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
device query.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1, data: Optional[int] = None) -> Mesh:
    """Mesh over whatever devices exist (tests / laptop runs)."""
    n = jax.device_count()
    data = data or (n // model)
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_chip_count(mesh: Mesh) -> int:
    return mesh.size


def describe(mesh: Mesh) -> str:
    return "×".join(f"{k}={v}" for k, v in mesh.shape.items())
