"""Assemble EXPERIMENTS.md tables from the dry-run record directory.

    PYTHONPATH=src python -m repro.launch.report \
        [--dryrun-dir experiments/dryrun] [--out experiments/dryrun_table.md]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load(d):
    recs = []
    for fn in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def dryrun_table(recs) -> str:
    rows = ["| arch | shape | mesh | status | kind | compile s "
            "| temp GB/dev | flops/dev | bytes/dev | coll GB/dev |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        mesh = "2×16×16" if r.get("multi_pod") else "16×16"
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | {mesh} | skipped "
                        f"(sub-quadratic rule) | — | — | — | — | — | — |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {mesh} | FAIL | — "
                        f"| — | — | — | — | — |")
            continue
        mem = r.get("memory", {})
        temp = mem.get("temp_size_in_bytes", 0) / 1e9
        rows.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | ok | {r['kind']} "
            f"| {r.get('compile_s', 0):.0f} | {temp:.1f} "
            f"| {r['flops']:.2e} | {r['bytes_accessed']:.2e} "
            f"| {r['collectives']['total']/1e9:.1f} |")
    return "\n".join(rows)


def summary(recs) -> str:
    n_ok = sum(r.get("status") == "ok" for r in recs)
    n_skip = sum(r.get("status") == "skipped" for r in recs)
    n_fail = len(recs) - n_ok - n_skip
    return (f"records: {len(recs)} — ok {n_ok}, skipped {n_skip} "
            f"(long_500k × full-attention archs), fail {n_fail}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/dryrun_table.md")
    args = ap.parse_args()
    recs = load(args.dryrun_dir)
    out = ("# Dry-run records (" + summary(recs) + ")\n\n"
           + dryrun_table(recs) + "\n")
    with open(args.out, "w") as f:
        f.write(out)
    print(summary(recs))


if __name__ == "__main__":
    main()
