"""Roofline analysis over the dry-run records (deliverable g).

    PYTHONPATH=src python -m repro.launch.roofline \
        [--dryrun-dir experiments/dryrun] [--out EXPERIMENTS_roofline.md]

Three-term roofline per (arch × shape), single-pod mesh, from the compiled
artifact (per-device HLO quantities, trip-count corrected — hlo_analysis.py):

    compute    = flops / PEAK_FLOPS
    memory     = bytes / HBM_BW
    collective = collective_bytes / ICI_BW

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
(we charge the busiest-link bandwidth — collectives are modeled as
bandwidth-optimal, so payload bytes/link_bw lower-bounds their time).

Derived:
    bound        = argmax(term)                       (the bottleneck)
    t_lb         = max(term)                          (step-time lower bound)
    MODEL_FLOPS  = 6·N·D (train) / 2·N·D (serve); N = active params (MoE)
    useful ratio = MODEL_FLOPS / (chips · flops)      (remat/waste factor)
    MFU bound    = MODEL_FLOPS / (chips · PEAK · t_lb) (roofline fraction —
                   the §Perf score: achievable MFU given the compiled program)
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link


def roofline_terms(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    chips = 512 if rec.get("multi_pod") else 256
    flops = rec["flops"]
    bytes_ = rec["bytes_accessed"]
    coll = rec["collectives"]["total"]
    t_c = flops / PEAK_FLOPS
    t_m = bytes_ / HBM_BW
    t_x = coll / ICI_BW
    t_lb = max(t_c, t_m, t_x)
    bound = {t_c: "compute", t_m: "memory", t_x: "collective"}[t_lb]
    n = rec.get("active_param_count") or rec["param_count"]
    mult = 6 if rec.get("kind") == "train" else 2
    model_flops = mult * n * rec["tokens"]
    useful = model_flops / max(chips * flops, 1.0)
    mfu_bound = model_flops / (chips * PEAK_FLOPS * t_lb)
    return dict(compute_s=t_c, memory_s=t_m, collective_s=t_x, t_lb=t_lb,
                bound=bound, model_flops=model_flops, useful_ratio=useful,
                mfu_bound=mfu_bound, chips=chips)


def improvement_hint(rec: Dict, terms: Dict) -> str:
    b = terms["bound"]
    if b == "collective":
        c = rec["collectives"]
        top = max((k for k in c if k not in ("total", "n_ops")),
                  key=lambda k: c[k])
        return (f"dominant collective is {top} "
                f"({c[top]/1e9:.1f} GB/dev) — reshard to convert to "
                f"reduce-scatter / overlap with compute")
    if b == "memory":
        return ("HBM-bound: shrink materialized intermediates (fuse masks "
                "into flash inner loop, bf16 scores, larger kv-chunk reuse)")
    return ("compute-bound: cut non-model FLOPs (brick causal schedule, "
            "remat policy on cheap ops only)")


def load_records(d: str, mesh_tag: str = "sp", suffix: str = "") -> List[Dict]:
    recs = []
    for fn in sorted(glob.glob(os.path.join(d, f"*_{mesh_tag}{suffix}.json"))):
        base = os.path.basename(fn)
        if suffix == "" and base.count("_") > 2 and not base.endswith(
                f"_{mesh_tag}.json"):
            continue                      # skip variant records in plain scan
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def table(recs: List[Dict]) -> str:
    rows = ["| arch | shape | kind | compute s | memory s | collective s |"
            " bound | useful | MFU-bound |",
            "|---|---|---|---|---|---|---|---|---|"]
    for rec in recs:
        if rec.get("status") == "skipped":
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | — |"
                        f" skipped | — | — |")
            continue
        if rec.get("status") != "ok":
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | — |"
                        f" FAIL | — | — |")
            continue
        t = roofline_terms(rec)
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['kind']} "
            f"| {t['compute_s']:.3f} | {t['memory_s']:.3f} "
            f"| {t['collective_s']:.3f} | **{t['bound']}** "
            f"| {t['useful_ratio']:.2f} | {t['mfu_bound']*100:.1f}% |")
    return "\n".join(rows)


def detail(rec: Dict) -> str:
    t = roofline_terms(rec)
    if t is None:
        return f"* {rec['arch']} × {rec['shape']}: {rec.get('reason', rec.get('error','fail'))}"
    return (f"* **{rec['arch']} × {rec['shape']}** [{t['bound']}-bound, "
            f"MFU-bound {t['mfu_bound']*100:.1f}%]: {improvement_hint(rec, t)}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/roofline.md")
    ap.add_argument("--suffix", default="",
                    help="record variant, e.g. _brick")
    args = ap.parse_args()
    recs = load_records(args.dryrun_dir, "sp", args.suffix)
    lines = ["# Roofline (single-pod 16×16, per TPU v5e chip)", "",
             table(recs), "", "## What moves the dominant term", ""]
    lines += [detail(r) for r in recs if r.get("status") == "ok"]
    out = "\n".join(lines) + "\n"
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        f.write(out)
    print(out)


if __name__ == "__main__":
    main()
