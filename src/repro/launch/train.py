"""End-to-end LM training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Production posture on a real cluster: same entry point, ``--mesh data,model``
sized to the slice, jax.distributed.initialize() handled by the launcher
environment.  On this CPU container it runs the reduced configs end-to-end
(the full configs are exercised by the dry-run).

Features wired in: WSD/cosine schedules, grad accumulation, async atomic
checkpointing + elastic restore, straggler monitoring, deterministic
shard-indexed data.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, latest_step, restore_checkpoint
from repro.configs.base import ARCH_IDS, get_config, reduced
from repro.data.pipeline import DataConfig, PrefetchingLoader, TokenPipeline
from repro.fault import StepMonitor
from repro.launch.mesh import make_local_mesh
from repro.models.lm.model import build_lm
from repro.sharding.specs import mesh_context
from repro.train import lm_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    mesh = make_local_mesh(model=args.model_parallel)
    lm = build_lm(cfg, tp=mesh.shape["model"])
    print(f"[train] {cfg.name} ({cfg.family}) params={cfg.param_count():,} "
          f"mesh={dict(mesh.shape)}")

    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch, seed=args.seed)
    pipeline = TokenPipeline(data_cfg)

    with mesh_context(mesh), mesh:
        state = lm_step.init_train_state(lm, jax.random.PRNGKey(args.seed))
        step_fn = jax.jit(lm_step.make_train_step(
            lm, lr=args.lr, total_steps=args.steps,
            grad_accum=args.grad_accum), donate_argnums=(0,))

        start = 0
        ckpt = None
        if args.ckpt_dir:
            ckpt = CheckpointManager(args.ckpt_dir, every=args.ckpt_every)
            last = latest_step(args.ckpt_dir)
            if last is not None:
                print(f"[train] restoring step {last}")
                state = restore_checkpoint(args.ckpt_dir, last, state)
                start = last + 1

        monitor = StepMonitor(n_hosts=1)
        loader = PrefetchingLoader(pipeline, start_step=start)
        losses = []
        try:
            for step in range(start, args.steps):
                batch_np = loader.next()
                batch = {k: jnp.asarray(v) for k, v in batch_np.items()
                         if not k.startswith("_")}
                _maybe_add_extras(cfg, batch, lm)
                t0 = time.perf_counter()
                state, metrics = step_fn(state, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                ev = monitor.record(step, 0, dt)
                if ev:
                    print(f"[fault] step {step}: {ev.action} "
                          f"({ev.duration:.2f}s > {ev.threshold:.2f}s)")
                losses.append(loss)
                if step % args.log_every == 0:
                    print(f"step {step:5d} loss {loss:8.4f} "
                          f"gnorm {float(metrics['grad_norm']):8.3f} "
                          f"{dt*1e3:7.1f} ms")
                if ckpt:
                    ckpt.maybe_save(step, state)
        finally:
            loader.close()
            if ckpt:
                ckpt.finalize()
    first = np.mean(losses[: max(len(losses) // 5, 1)])
    last5 = np.mean(losses[-max(len(losses) // 5, 1):])
    print(f"[train] loss {first:.4f} -> {last5:.4f} "
          f"({'improved' if last5 < first else 'NOT improved'})")
    return losses


def _maybe_add_extras(cfg, batch, lm):
    b = batch["tokens"].shape[0]
    if cfg.family == "vlm":
        batch["image_emb"] = jnp.zeros((b, cfg.n_img_tokens, cfg.d_model),
                                       lm.dtype)
    if cfg.family == "audio":
        batch["frames"] = jnp.zeros((b, cfg.enc_frames, cfg.d_model),
                                    lm.dtype)


if __name__ == "__main__":
    main()
