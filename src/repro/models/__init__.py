"""Model zoo: DR-CircuitGNN, homogeneous GNN baselines, LM architectures."""
