"""Deep-backbone stack machinery: declarative specs, wiring, remat.

The exemplar circuit models (GSR-GNN, circuit-fewshot's DeepGEN configs)
are 10–15 layers at hidden 128; training them naively holds every layer's
activations — and, on the plan path, nothing extra, but the activations
alone — live through the backward.  This module turns the ad-hoc
``for lp in layers`` loops of models/hgnn.py into a first-class backbone
(DESIGN.md §13):

* :class:`BackboneSpec` — the declarative stack description (depth,
  hidden, wiring, remat) shared by the trainer, the serve engine, the
  benches, and the examples.  ``CircuitTrainConfig.n_layers`` is its
  single depth source of truth.
* :func:`apply_stack` — the one stack executor.  ``wiring`` draws the
  DeepGEN-style reuse pattern: ``"plain"`` (h_i = f_i(h_{i-1})),
  ``"residual"`` (+ h_{i-1} from the second layer on, so depth-1 is
  exactly the vanilla stack), ``"dense"`` (+ Σ of all previous layer
  states).  ``remat=True`` wraps each layer in :func:`jax.checkpoint`:
  the backward *recomputes* the layer's fused forward instead of storing
  its activations, and peak training memory stops scaling with depth.
* :func:`init_stack` — the shared init-key plumbing
  (``init_drcircuitgnn`` / ``init_homo`` are thin wrappers over it with
  bit-identical RNG streams to the pre-backbone code).

Remat boundary vs the custom-vjp leaf
-------------------------------------
``jax.checkpoint`` is drawn at the layer boundary: the checkpointed body
is one ``hetero_conv`` + its inter-layer activation, taking
``(layer_params, state, const)`` as explicit arguments.  Everything the
layer does NOT own — the graph, and the :class:`RelationPlan` super-arena
riding on it — goes through ``const``, so remat saves those leaves as
plain input residuals: stored once by reference (every layer's residual
aliases the same jit-argument buffers), never rematerialized, never
re-``device_put`` on recompute.  Inside the body, the plan executor
(``kernels/ops.py::drspmm_multi``) is the non-rematerialized *leaf*: its
custom VJP already recomputes nothing (its only data residual is the CBSR
index set), and under a checkpoint trace it threads the plan as a
custom-vjp primal (``ops._multi_traced``) so no closure captures
checkpoint-scope tracers.  The id-keyed executor LRU is untouched by
remat — checkpoint bodies always trace, and traced plans bypass the cache
— so recompute cannot thrash it.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp

WIRINGS = ("plain", "residual", "dense")


@dataclasses.dataclass(frozen=True)
class BackboneSpec:
    """Declarative stack spec.  ``depth`` must match ``len(params.layers)``
    of the params it is applied to (:func:`spec_for` derives it)."""
    depth: int = 2
    hidden: int = 64
    wiring: str = "plain"        # plain | residual | dense
    remat: bool = False

    def __post_init__(self):
        if self.wiring not in WIRINGS:
            raise ValueError(f"unknown wiring {self.wiring!r}; "
                             f"expected one of {WIRINGS}")


def spec_for(layers: Sequence, hidden: int, *, wiring: str = "plain",
             remat: bool = False) -> BackboneSpec:
    """The spec describing an existing layer tuple — the back-compat
    default the thin wrappers use when no spec is passed."""
    return BackboneSpec(depth=len(layers), hidden=hidden, wiring=wiring,
                        remat=remat)


def init_stack(key, n_layers: int, layer_init: Callable, *,
               n_pre: int = 0, n_post: int = 0):
    """Shared init-key plumbing: split ``key`` into ``n_pre`` leading keys,
    one key per layer, and ``n_post`` trailing keys — the exact split
    pattern (and therefore the exact RNG stream) of the pre-backbone
    ``init_drcircuitgnn`` (pre=2, post=1) and ``init_homo`` (pre=0,
    post=2).  ``layer_init(key_i, i)`` builds layer ``i``'s params.

    Returns ``(pre_keys, layers, post_keys)``."""
    ks = jax.random.split(key, n_layers + n_pre + n_post)
    pre = tuple(ks[:n_pre])
    layers = tuple(layer_init(ks[n_pre + i], i) for i in range(n_layers))
    post = tuple(ks[n_pre + n_layers:])
    return pre, layers, post


def _tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def apply_stack(layers: Sequence, state, body: Callable, spec: BackboneSpec,
                const=None):
    """Run ``state`` through ``layers`` with the spec's wiring and remat.

    ``body(layer_params, state, const) -> state`` is one layer's compute
    (conv + activation); ``const`` carries the layer-invariant operands
    (graph + plan) as explicit arguments so remat saves them once as
    aliased input residuals (see module docstring).  Wiring:

    * ``plain``     s_i = body(l_i, s_{i-1})
    * ``residual``  s_i = body(l_i, s_{i-1}) + s_{i-1}   (i ≥ 1)
    * ``dense``     s_i = body(l_i, s_{i-1}) + Σ_{j<i} s_j   (i ≥ 1)

    Skips start at the SECOND layer — the first acts as the stem — so a
    depth-1 residual/dense stack is exactly the vanilla one
    (tests/test_backbone.py::test_residual_depth1_degenerate)."""
    if len(layers) != spec.depth:
        raise ValueError(f"spec.depth={spec.depth} but {len(layers)} "
                         f"layer params given")
    b = jax.checkpoint(body) if spec.remat else body
    acc = None                      # Σ of post-wiring layer states
    for i, lp in enumerate(layers):
        y = b(lp, state, const)
        if i and spec.wiring == "residual":
            y = _tree_add(y, state)
        elif i and spec.wiring == "dense":
            y = _tree_add(y, acc)
        acc = y if acc is None else _tree_add(acc, y)
        state = y
    return state
