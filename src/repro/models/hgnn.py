"""DR-CircuitGNN model (paper Fig. 1) + homogeneous GNN baselines.

DR-CircuitGNN: per-type input Linear → N × HeteroConv → per-cell Linear head
(congestion regression).  Baselines: GCN / GraphSAGE / GAT stacks on the
homogenized graph (all edges merged, single node space), matching the paper's
Table 2 comparison protocol.

Each HeteroConv layer dispatches its whole message passing through the
graph's :class:`~repro.graphs.ell.RelationPlan` when one is available
(``ops.drspmm_multi`` — one kernel per direction-group, DESIGN.md §9); the
per-direction serial loop remains the reference (core/hetero_mp.py).

Both stacks run through the deep-backbone executor (models/backbone.py,
DESIGN.md §13): every forward takes an optional :class:`BackboneSpec`
selecting wiring (plain/residual/dense) and layer-granular remat; the
entry points here stay thin wrappers with exact init/numeric parity to the
pre-backbone hardcoded loops (the default spec IS the old behavior)."""

from __future__ import annotations

import dataclasses
import weakref
from typing import Any, Dict, NamedTuple, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.drelu import drelu
from repro.core.hetero_mp import (HeteroLayerParams, HeteroMPConfig,
                                  _plan_for, hetero_conv, init_hetero_layer)
from repro.graphs.circuit import CircuitGraph
from repro.graphs.ell import BucketedELL, ell_to_coo, pack_fused_eid_pair
from repro.kernels import ops
from repro.models.backbone import (BackboneSpec, apply_stack, init_stack,
                                   spec_for)
from repro.sharding.plan_shard import ShardedRelationPlan


# ---------------------------------------------------------------------------
# DR-CircuitGNN
# ---------------------------------------------------------------------------

class DRCircuitGNNParams(NamedTuple):
    in_cell: jax.Array          # (f_cell, H)
    in_net: jax.Array           # (f_net, H)
    layers: Tuple[HeteroLayerParams, ...]
    head_w: jax.Array           # (H, 1)
    head_b: jax.Array           # (1,)


def init_drcircuitgnn(key, f_cell: int, f_net: int, hidden: int,
                      n_layers: int = 2) -> DRCircuitGNNParams:
    (k_ic, k_in), layers, (k_head,) = init_stack(
        key, n_layers, lambda k, _i: init_hetero_layer(k, hidden),
        n_pre=2, n_post=1)
    s_c, s_n = 1.0 / jnp.sqrt(f_cell), 1.0 / jnp.sqrt(f_net)
    return DRCircuitGNNParams(
        in_cell=jax.random.uniform(k_ic, (f_cell, hidden), jnp.float32, -s_c, s_c),
        in_net=jax.random.uniform(k_in, (f_net, hidden), jnp.float32, -s_n, s_n),
        layers=layers,
        head_w=jax.random.uniform(k_head, (hidden, 1), jnp.float32,
                                  -1.0 / jnp.sqrt(hidden), 1.0 / jnp.sqrt(hidden)),
        head_b=jnp.zeros((1,)))


def _hetero_body(cfg: HeteroMPConfig):
    """One checkpointable backbone layer: hetero_conv + the inter-layer
    activation.  ``const`` threads the layer-invariant (graph, plan) pair
    resolved ONCE per stack application — under remat they are saved input
    residuals, not recomputed (models/backbone.py)."""
    def body(lp, state, const):
        graph, plan = const
        h_cell, h_net = hetero_conv(lp, graph, *state, cfg, plan=plan)
        # inter-layer nonlinearity IS D-ReLU (dense form) — the sparsifier
        # doubles as the activation, per the paper's framing.
        if cfg.use_drelu:
            return drelu(h_cell, cfg.k_cell), drelu(h_net, cfg.k_net)
        return jax.nn.relu(h_cell), jax.nn.relu(h_net)
    return body


def drcircuitgnn_forward(params: DRCircuitGNNParams, graph: CircuitGraph,
                         cfg: HeteroMPConfig,
                         spec: Optional[BackboneSpec] = None) -> jax.Array:
    """Per-cell congestion prediction in [0, 1].

    ``spec`` selects the backbone wiring/remat (DESIGN.md §13); the
    default — plain wiring, no remat, depth from ``params`` — reproduces
    the pre-backbone loop bit-for-bit."""
    if spec is None:
        spec = spec_for(params.layers, params.head_w.shape[0])
    h_cell = graph.x_cell @ params.in_cell
    h_net = graph.x_net @ params.in_net
    # layer-invariant hoist: ONE plan resolution per stack application
    plan = _plan_for(graph, cfg, h_cell.shape[-1])
    if spec.remat and isinstance(plan, ShardedRelationPlan):
        # The mesh-sharded executor (DESIGN.md §12) needs its plan
        # pre-placed with a NamedSharding, which a checkpoint-traced primal
        # cannot express — so the sharded path draws no checkpoint boundary
        # (remat composes with data-parallel replicas, not with §12 yet).
        spec = dataclasses.replace(spec, remat=False)
    h_cell, h_net = apply_stack(params.layers, (h_cell, h_net),
                                _hetero_body(cfg), spec, (graph, plan))
    pred = jax.nn.sigmoid(h_cell @ params.head_w + params.head_b)
    return pred[:, 0]


def loss_fn(params, graph, cfg,
            spec: Optional[BackboneSpec] = None) -> jax.Array:
    pred = drcircuitgnn_forward(params, graph, cfg, spec)
    return jnp.mean((pred - graph.y_cell) ** 2)


def batched_loss_fn(params, graph, cell_weight, cfg,
                    spec: Optional[BackboneSpec] = None) -> jax.Array:
    """Loss over a block-diagonal collated batch (graphs/collate.py).

    ``cell_weight`` is 1/(n_members·n_cell_i) on member i's cells and 0 on
    padding, so this equals the mean of the members' per-graph ``loss_fn``
    values — batched gradients match the per-graph loop exactly."""
    pred = drcircuitgnn_forward(params, graph, cfg, spec)
    return jnp.sum(cell_weight * (pred - graph.y_cell) ** 2)


# ---------------------------------------------------------------------------
# Homogeneous baselines (GCN / SAGE / GAT) on the homogenized graph
# ---------------------------------------------------------------------------

class HomoParams(NamedTuple):
    w_in: jax.Array
    w_layers: Tuple[Any, ...]
    head_w: jax.Array
    head_b: jax.Array


def homogenize(graph: CircuitGraph):
    """Merge node spaces: [cells; nets], all edges unified, mean-normalized.

    Features are zero-padded into a common width.  Returns (adj, adj_t, x, y,
    n_cell) with adj in BucketedELL over the merged id space."""
    import numpy as np
    from repro.graphs.ell import pack_ell_pair

    n_c, n_n = graph.n_cell, graph.n_net
    n = n_c + n_n
    dsts, srcs = [], []
    for et, es in graph.edges.items():
        a = np.asarray(es.adj.to_dense())
        d, s = np.nonzero(a)
        if et == "near":
            pass                      # cell->cell
        elif et == "pin":
            d = d + n_c               # dst nets offset
        elif et == "pinned":
            s = s + n_c               # src nets offset
        dsts.append(d), srcs.append(s)
    # self-loops (Â = A + I — GCN/GAT need the node's own features)
    loop = np.arange(n)
    dsts.append(loop), srcs.append(loop)
    dst = np.concatenate(dsts)
    src = np.concatenate(srcs)
    deg = np.bincount(dst, minlength=n).astype(np.float32)
    w = 1.0 / np.maximum(deg[dst], 1.0)
    adj, adj_t = pack_ell_pair(dst, src, w, n, n)

    f = max(graph.x_cell.shape[1], graph.x_net.shape[1])
    xc = jnp.pad(graph.x_cell, ((0, 0), (0, f - graph.x_cell.shape[1])))
    xn = jnp.pad(graph.x_net, ((0, 0), (0, f - graph.x_net.shape[1])))
    x = jnp.concatenate([xc, xn], 0)
    return adj, adj_t, x, graph.y_cell, n_c


def init_homo(key, f_in: int, hidden: int, n_layers: int = 3,
              kind: str = "gcn", nnz: int = 0) -> HomoParams:
    """``kind="gat_edge"`` layers carry a free per-edge attention logit
    vector (nnz,) — pass ``nnz`` (e.g. ``adj.nnz`` of the homogenized
    graph).  Zero-initialized logits start at uniform attention, which
    coincides with the mean aggregation the other baselines use."""
    s = 1.0 / jnp.sqrt(hidden)

    def layer_init(k, _i):
        if kind == "sage":
            return (jax.random.uniform(k, (hidden, hidden),
                                       jnp.float32, -s, s),
                    jax.random.uniform(jax.random.fold_in(k, 1),
                                       (hidden, hidden), jnp.float32, -s, s))
        if kind == "gat":
            return (jax.random.uniform(k, (hidden, hidden),
                                       jnp.float32, -s, s),
                    jax.random.uniform(jax.random.fold_in(k, 1),
                                       (2 * hidden,), jnp.float32, -s, s))
        if kind == "gat_edge":
            assert nnz > 0, "gat_edge needs the homogenized edge count (nnz)"
            return (jax.random.uniform(k, (hidden, hidden),
                                       jnp.float32, -s, s),
                    jnp.zeros((nnz,), jnp.float32))
        return jax.random.uniform(k, (hidden, hidden),  # gcn
                                  jnp.float32, -s, s)

    _, layers, (k_in, k_head) = init_stack(key, n_layers, layer_init,
                                           n_pre=0, n_post=2)
    si = 1.0 / jnp.sqrt(f_in)
    return HomoParams(
        w_in=jax.random.uniform(k_in, (f_in, hidden), jnp.float32, -si, si),
        w_layers=layers,
        head_w=jax.random.uniform(k_head, (hidden, 1), jnp.float32, -s, s),
        head_b=jnp.zeros((1,)))


# Memoized per-adjacency edge-ID packing for learnable per-edge attention
# (kind="gat_edge"): host-side one-time preprocessing, id-keyed with weakref
# guards like graphs/ell.py::_FUSE_CACHE.
_EDGE_PACK_CACHE: Dict[int, tuple] = {}


def learnable_edge_packing(adj: BucketedELL):
    """(fwd_arena, bwd_arena, dst_canon, src_canon, w_canon, nnz) for
    ``adj``'s edge set.

    The fused eid arenas feed :func:`repro.kernels.ops.drspmm_learnable`;
    ``dst_canon``/``src_canon`` (nnz,) are the canonical
    (dst-stable-sorted) edge endpoints — segment ids for per-destination
    softmax reductions and gather ids for per-source scores — and
    ``w_canon`` carries ``adj``'s fixed weights in the same order (the
    mean-normalization the "gat" branch folds into its attention).  A
    canonical per-edge parameter vector (nnz,) aligns with all of them.
    """
    key = id(adj)
    hit = _EDGE_PACK_CACHE.get(key)
    if hit is not None and hit[0]() is adj:
        return hit[1]
    dst, src, w = ell_to_coo(adj)
    order = np.argsort(dst, kind="stable")
    dst, src, w = dst[order], src[order], w[order]
    fwd, bwd, _order, nnz = pack_fused_eid_pair(dst, src, adj.n_dst,
                                                adj.n_src)
    pack = (fwd, bwd, dst.astype(np.int32), src.astype(np.int32),
            w.astype(np.float32), nnz)
    _EDGE_PACK_CACHE[key] = (
        weakref.ref(adj, lambda _: _EDGE_PACK_CACHE.pop(key, None)), pack)
    return pack


def _homo_body(kind: str, adj, adj_t, backend: ops.Backend):
    """One homogeneous backbone layer (relu included).  ``adj``/``adj_t``
    are closed over — the homo baselines run on concrete (host-packed)
    graphs, and the gat/gat_edge kinds need the host-side
    :func:`learnable_edge_packing` anyway."""
    def body(lw, h, _const):
        if kind == "sage":
            w_nbr, w_self = lw
            agg = ops.spmm(adj, adj_t, h, backend=backend)
            h = jax.nn.relu(agg @ w_nbr + h @ w_self)
        elif kind == "gat":
            w, a = lw
            hw = h @ w
            # single-head GAT, source-score attention plus an explicit
            # self-attention term.  The additive GATv1 logit
            # e_ij = σ(s_dst_i + s_src_j) factorizes in exp space and the
            # destination part cancels in the softmax ratio — but the self
            # pair (i, i) keeps its full joint score, which is what lets
            # attention upweight a node's own features.
            lr_src = jax.nn.leaky_relu(hw @ a[: hw.shape[1]])
            lr_self = jax.nn.leaky_relu(
                hw @ a[: hw.shape[1]] + hw @ a[hw.shape[1]:])
            # Exponentiating unbounded logits overflows for large-magnitude
            # features (exp→inf, num/den→NaN).  num and den are both linear
            # in the exp'd scores, so a per-destination shift cancels in
            # the ratio: subtract each destination's max incoming logit
            # before exp.  (A global max would keep exp finite but
            # underflow every node far below the hottest one to 0/0; the
            # per-destination form keeps the largest term at exp(0) for
            # EVERY node.)  The per-edge gather routes the aggregation
            # through the fused learnable op; adj's mean-normalization
            # weights ride along in the attention, so moderate-scale
            # numerics match the SpMM-decomposed form exactly.
            fwd_e, bwd_e, dst_c, src_c, w_c, nnz = \
                learnable_edge_packing(adj)
            e_log = lr_src[src_c]                     # (nnz,) per-edge score
            m = jnp.maximum(
                jax.ops.segment_max(e_log, dst_c, num_segments=adj.n_dst),
                lr_self)
            m = jax.lax.stop_gradient(jnp.where(jnp.isfinite(m), m, 0.0))
            att = jnp.asarray(w_c) * jnp.exp(e_log - m[dst_c])
            s_self = jnp.exp(lr_self - m)
            xi = jnp.broadcast_to(
                jnp.arange(hw.shape[1], dtype=jnp.int32)[None, :], hw.shape)
            num = ops.drspmm_learnable(fwd_e, bwd_e, nnz, att, hw, xi,
                                       hw.shape[1], backend=backend)
            den = jax.ops.segment_sum(att, dst_c, num_segments=adj.n_dst)
            num = num + s_self[:, None] * hw
            den = den + s_self
            h = jax.nn.relu(num / jnp.maximum(den, 1e-6)[:, None])
        elif kind == "gat_edge":
            # Learnable per-edge attention through the fused learnable op:
            # every edge carries a free logit s_e; softmax over each
            # destination's in-edges (self-loops are already in the
            # homogenized edge set) weights the aggregation, and dL/ds
            # flows through drspmm_learnable's sampled dw reduction.
            w, s = lw
            hw = h @ w
            fwd_e, bwd_e, dst_c, _src_c, _w_c, nnz = \
                learnable_edge_packing(adj)
            logit = jax.nn.leaky_relu(s)
            # per-destination max subtraction (exact softmax stabilization:
            # per-edge logits make the per-node max expressible, unlike the
            # factorized "gat" branch above)
            m = jax.ops.segment_max(logit, dst_c, num_segments=adj.n_dst)
            m = jnp.where(jnp.isfinite(m), m, 0.0)    # edge-less rows: -inf
            att = jnp.exp(logit - jax.lax.stop_gradient(m)[dst_c])
            # dense h as trivially-CBSR operand: k = hidden, idx = iota
            xi = jnp.broadcast_to(
                jnp.arange(hw.shape[1], dtype=jnp.int32)[None, :], hw.shape)
            num = ops.drspmm_learnable(fwd_e, bwd_e, nnz, att, hw, xi,
                                       hw.shape[1], backend=backend)
            den = jax.ops.segment_sum(att, dst_c, num_segments=adj.n_dst)
            h = jax.nn.relu(num / jnp.maximum(den, 1e-6)[:, None])
        else:
            agg = ops.spmm(adj, adj_t, h, backend=backend)
            h = jax.nn.relu(agg @ lw)
        return h
    return body


def homo_forward(params: HomoParams, adj, adj_t, x, n_cell: int,
                 kind: str = "gcn",
                 backend: ops.Backend = ops.DEFAULT_BACKEND,
                 spec: Optional[BackboneSpec] = None) -> jax.Array:
    if spec is None:
        spec = spec_for(params.w_layers, params.head_w.shape[0])
    h = x @ params.w_in
    h = apply_stack(params.w_layers, h, _homo_body(kind, adj, adj_t, backend),
                    spec, None)
    pred = jax.nn.sigmoid(h @ params.head_w + params.head_b)
    return pred[:n_cell, 0]
