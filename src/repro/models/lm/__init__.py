from repro.models.lm.model import LM, build_lm  # noqa: F401
