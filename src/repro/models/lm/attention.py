"""Attention: GQA (flat-head internal layout) with optional qk-norm, a
chunked-flash training path, and a distributed flash-decode path for
sequence-sharded KV caches.

Layout decisions (DESIGN.md §5):

* **Flat padded heads.**  Q projections produce a flat (B, S, H_pad, hd)
  tensor with H_pad = round-up of n_heads to the TP degree; the padded heads
  have zero in/out weights and are numerically inert.  KV heads are kept at
  their true count and *tiled* to H_pad at use (q head h reads kv head
  h % n_kv), so every real kv head keeps an equal share of real q heads.
  This makes the head axis always shardable — archs like minitron (24H),
  minicpm (36H), whisper (20H) would otherwise replicate all attention
  compute across the 16-way model axis.
* **Chunked flash** (online softmax over q-chunk × kv-chunk scans): the
  (S×S) score matrix is never materialized — required for prefill_32k.
  ``causal_mode="brick"`` prunes upper-triangle chunk pairs with *static*
  prefix slices so the pruned FLOPs are absent from the HLO (§Perf lever).
* **Distributed flash-decode**: 32k–500k KV caches are sequence-sharded
  over ``model``; decode attention computes per-shard partial (max, sum,
  acc) inside shard_map and psum-combines — no KV all-gather ever.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.lm.common import head_rms_norm, rope
from repro.sharding.specs import (batch_axes, constrain, get_mesh,
                                  manual_axes)

NEG_INF = -1e30


def _pick_chunk(s: int, target: int) -> int:
    """Largest divisor of ``s`` that is ≤ target (handles e.g. 1500-frame
    whisper memories and 1600-token image grids)."""
    c = min(target, s)
    while s % c:
        c -= 1
    return c


def tile_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, KV, hd) -> (B, S, H, hd); q head h reads kv head h % KV."""
    kv = k.shape[2]
    if kv == n_heads:
        return k
    reps = n_heads // kv
    return jnp.tile(k, (1, 1, reps, 1))


def _flash_inner(qc, ks, vs, qi, q_chunk, kv_chunk, causal, q_offset):
    """Online-softmax scan of one q-chunk over kv chunks.

    qc (B,qc,H,hd); ks/vs (B,nk,kc,H,hd).  Returns (B,qc,H,hd) f32.
    """
    b, qlen, h, hd = qc.shape
    nk = ks.shape[1]
    scale = 1.0 / (hd ** 0.5)
    q_pos = jnp.arange(q_chunk)
    k_pos = jnp.arange(kv_chunk)
    m0 = jnp.full((b, h, q_chunk), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
    a0 = jnp.zeros((b, q_chunk, h, hd), jnp.float32)

    def kv_body(carry, inp):
        ki, kc, vc = inp
        m_prev, l_prev, acc = carry
        s = jnp.einsum("bqhd,bshd->bhqs", qc, kc,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            qp = q_offset + qi * q_chunk + q_pos
            kp = ki * kv_chunk + k_pos
            mask = qp[:, None] >= kp[None, :]
            s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m_prev, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(-1)
        acc = (acc * corr.transpose(0, 2, 1)[..., None]
               + jnp.einsum("bhqs,bshd->bqhd", p, vc.astype(p.dtype)))
        return (m_new, l_new, acc), None

    # checkpoint each kv step: without it, autodiff saves the (nk, B, H,
    # qc, kc) probability tensors across the scan — the exact buffers flash
    # attention exists to avoid (measured 270 GB/step × 448 on qwen3
    # train_4k).  Recomputing scores in the backward costs ~1 extra qk
    # matmul but keeps residuals O(qc·hd) (§Perf iteration 2).
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(kv_body), (m0, l0, a0),
        (jnp.arange(nk), ks.transpose(1, 0, 2, 3, 4),
         vs.transpose(1, 0, 2, 3, 4)))
    return acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      *, causal: bool = True, q_chunk: int = 1024,
                      kv_chunk: int = 1024, causal_mode: str = "masked",
                      q_offset: int = 0) -> jax.Array:
    """Flash attention.  q (B,Sq,H,hd); k/v (B,Sk,H,hd) (already tiled)."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    q_chunk = _pick_chunk(sq, q_chunk)
    kv_chunk = _pick_chunk(sk, kv_chunk)
    nq, nk = sq // q_chunk, sk // kv_chunk
    qs = q.reshape(b, nq, q_chunk, h, hd)
    ks = k.reshape(b, nk, kv_chunk, h, hd)
    vs = v.reshape(b, nk, kv_chunk, h, hd)

    if causal and causal_mode == "brick" and q_offset == 0 and sq == sk:
        # static prefix slices: q chunk i sees kv chunks [0, i] only;
        # upper-triangle work never enters the HLO.
        outs = [
            _flash_inner(qs[:, qi], ks[:, : qi + 1], vs[:, : qi + 1],
                         qi, q_chunk, kv_chunk, True, q_offset)
            for qi in range(nq)
        ]
        out = jnp.stack(outs, 1)
    else:
        def q_body(_, inp):
            qi, qc = inp
            return None, _flash_inner(qc, ks, vs, qi, q_chunk, kv_chunk,
                                      causal, q_offset)

        _, out = jax.lax.scan(q_body, None,
                              (jnp.arange(nq), qs.transpose(1, 0, 2, 3, 4)))
        out = out.transpose(1, 0, 2, 3, 4)
    return out.reshape(b, sq, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# full attention block (training / prefill)
# ---------------------------------------------------------------------------

def attention_block(x, wq, wk, wv, wo, *, n_kv: int,
                    qk_q: Optional[jax.Array] = None,
                    qk_k: Optional[jax.Array] = None,
                    rope_theta: float = 1e6,
                    positions: Optional[jax.Array] = None,
                    causal: bool = True,
                    kv_x: Optional[jax.Array] = None,
                    causal_mode: str = "masked",
                    return_kv: bool = False):
    """Projections + RoPE + chunked flash + out-projection.

    x (B,S,d) residual (sequence-sharded; the einsum boundary is where XLA
    all-gathers — Megatron-SP).  ``kv_x`` switches to cross-attention
    (no RoPE, no causal mask).  wq (d,H,hd); wk/wv (d,KV,hd); wo (H,hd,d).
    """
    b, s, d = x.shape
    src = x if kv_x is None else kv_x
    h = wq.shape[1]

    q = jnp.einsum("bsd,dhe->bshe", x, wq)
    k = jnp.einsum("bsd,dke->bske", src, wk)
    v = jnp.einsum("bsd,dke->bske", src, wv)
    if qk_q is not None:
        q = head_rms_norm(q, qk_q)
        k = head_rms_norm(k, qk_k)
    if kv_x is None:
        if positions is None:
            positions = jnp.arange(s)[None, :]
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)
    q = constrain(q, ("batch", None, "heads", None))
    # named residuals for the "proj" remat policy: the backward reuses the
    # projections instead of recomputing them (and re-all-gathering x)
    from jax.ad_checkpoint import checkpoint_name
    q = checkpoint_name(q, "proj")
    k = checkpoint_name(k, "proj")
    v = checkpoint_name(v, "proj")
    kt = tile_kv(k, h)
    vt = tile_kv(v, h)
    kt = constrain(kt, ("batch", None, "heads", None))
    vt = constrain(vt, ("batch", None, "heads", None))

    ctx = chunked_attention(q, kt, vt, causal=causal and kv_x is None,
                            causal_mode=causal_mode)
    ctx = constrain(ctx, ("batch", None, "heads", None))
    ctx = checkpoint_name(ctx, "proj")
    out = jnp.einsum("bshe,hed->bsd", ctx, wo)
    out = constrain(out, ("batch", "sp", None))
    if return_kv:
        return out, (k, v)
    return out


# ---------------------------------------------------------------------------
# decode: sequence-sharded KV cache, distributed flash-decode
# ---------------------------------------------------------------------------

def decode_attention(q, k_cache, v_cache, pos, new_k, new_v):
    """One-token attention against a (possibly seq-sharded) KV cache.

    q (B,1,H,hd); caches (B,S_max,KV,hd) physically P(batch,'model',·,·);
    new_k/new_v (B,1,KV,hd) written at ``pos`` before attending.
    ``pos`` is a scalar (lockstep batch) or a (B,) vector (continuous
    batching: every slot at its own position — repro/serve/engine.py).
    Returns (ctx (B,1,H,hd), k_cache, v_cache).
    """
    mesh = get_mesh()
    s_max = k_cache.shape[1]
    h = q.shape[2]
    use_shmap = (mesh is not None and "model" in mesh.axis_names
                 and not manual_axes()
                 and mesh.shape["model"] > 1
                 and s_max % mesh.shape["model"] == 0)
    if not use_shmap:
        if getattr(pos, "ndim", 0) == 1:           # per-slot positions
            b_idx = jnp.arange(q.shape[0])
            k_cache = k_cache.at[b_idx, pos].set(new_k[:, 0])
            v_cache = v_cache.at[b_idx, pos].set(new_v[:, 0])
        else:
            k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, new_k,
                                                          pos, 1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, new_v,
                                                          pos, 1)
        ctx = _local_decode(q, k_cache, v_cache, pos, 0)
        return ctx, k_cache, v_cache

    dp = batch_axes(mesh)
    b = q.shape[0]
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    bspec = (dp if len(dp) > 1 else (dp[0] if dp else None))
    if b % max(n_dp, 1) != 0:
        bspec = None                                      # tiny-batch decode
    cache_spec = P(bspec, "model", None, None)
    q_spec = P(bspec, None, None, None)
    new_spec = P(bspec, None, None, None)

    from repro.sharding.specs import shard_map_compat

    @shard_map_compat(
        mesh=mesh,
        in_specs=(q_spec, cache_spec, cache_spec, P(), new_spec, new_spec),
        out_specs=(q_spec, cache_spec, cache_spec),
        check_vma=False)
    def shmap_decode(q_l, kc_l, vc_l, pos_, nk_l, nv_l):
        shard = jax.lax.axis_index("model")
        s_local = kc_l.shape[1]
        offset = shard * s_local
        local_pos = pos_ - offset
        in_range = jnp.logical_and(local_pos >= 0, local_pos < s_local)
        safe_pos = jnp.clip(local_pos, 0, s_local - 1)
        if getattr(pos_, "ndim", 0) == 1:          # per-slot positions
            b_idx = jnp.arange(kc_l.shape[0])
            sel = in_range[:, None, None]
            kc_l = kc_l.at[b_idx, safe_pos].set(
                jnp.where(sel, nk_l[:, 0], kc_l[b_idx, safe_pos]))
            vc_l = vc_l.at[b_idx, safe_pos].set(
                jnp.where(sel, nv_l[:, 0], vc_l[b_idx, safe_pos]))
        else:
            kc_new = jax.lax.dynamic_update_slice_in_dim(kc_l, nk_l,
                                                         safe_pos, 1)
            vc_new = jax.lax.dynamic_update_slice_in_dim(vc_l, nv_l,
                                                         safe_pos, 1)
            kc_l = jnp.where(in_range, kc_new, kc_l)
            vc_l = jnp.where(in_range, vc_new, vc_l)
        m, l, acc = _partial_decode(q_l, kc_l, vc_l, pos_, offset)
        m_g = jax.lax.pmax(m, "model")
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, "model")
        acc_g = jax.lax.psum(acc * corr.transpose(0, 2, 1)[..., None],
                             "model")
        ctx = acc_g / jnp.maximum(l_g, 1e-30).transpose(0, 2, 1)[..., None]
        return ctx.astype(q_l.dtype), kc_l, vc_l

    return shmap_decode(q, k_cache, v_cache, pos, new_k, new_v)


def _partial_decode(q, kc, vc, pos, offset):
    """Masked partial attention stats over one KV shard (f32).

    q (B,1,H,hd); kc/vc (B,S_l,KV,hd); pos scalar or (B,)."""
    b, _, h, hd = q.shape
    s_local = kc.shape[1]
    scale = 1.0 / (hd ** 0.5)
    kt = tile_kv(kc, h)
    vt = tile_kv(vc, h)
    s = jnp.einsum("bqhd,bshd->bhqs", q, kt,
                   preferred_element_type=jnp.float32) * scale
    span = jnp.arange(s_local) + offset
    if getattr(pos, "ndim", 0) == 1:
        valid = span[None, :] <= pos[:, None]          # (B, S_l)
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    else:
        valid = span <= pos
        s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    m = s.max(-1)                                          # (B,H,1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(-1)
    acc = jnp.einsum("bhqs,bshd->bqhd", p, vt.astype(p.dtype))
    return m, l, acc


def _local_decode(q, kc, vc, pos, offset):
    m, l, acc = _partial_decode(q, kc, vc, pos, offset)
    return (acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
            ).astype(q.dtype)
