"""Shared LM building blocks: parameter templates, norms, RoPE, embeddings.

Parameter-template system: each model family declares its weights once as a
nested dict of :class:`PSpec` (shape + logical sharding axes + init).  From
the template we derive real params, abstract params (for the dry-run — no
allocation), and NamedShardings, with zero bookkeeping drift between them.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.specs import constrain, make_pspec, param_sharding


@dataclasses.dataclass(frozen=True)
class PSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]          # logical name per dim
    init: str = "normal"                      # normal | zeros | ones
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


Template = Dict[str, Any]   # nested dicts of PSpec


def _map_template(template: Template, fn):
    out = {}
    for k, v in template.items():
        out[k] = _map_template(v, fn) if isinstance(v, dict) else fn(k, v)
    return out


def init_params(template: Template, key: jax.Array, dtype=jnp.float32):
    leaves = []

    def collect(k, v):
        leaves.append((k, v))
        return None

    _map_template(template, collect)
    keys = jax.random.split(key, max(len(leaves), 1))
    it = iter(range(len(leaves)))

    def mk(_, spec: PSpec):
        i = next(it)
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dtype)
        return (jax.random.normal(keys[i], spec.shape, jnp.float32)
                * spec.scale).astype(dtype)

    return _map_template(template, mk)


def abstract_params(template: Template, dtype=jnp.float32):
    """ShapeDtypeStructs — the dry-run's no-allocation parameter stand-ins."""
    return _map_template(
        template, lambda _, s: jax.ShapeDtypeStruct(s.shape, dtype))


def param_shardings(template: Template, mesh):
    return _map_template(
        template, lambda _, s: param_sharding(s.shape, s.axes, mesh))


def param_pspecs(template: Template, mesh):
    return _map_template(
        template, lambda _, s: make_pspec(s.shape, s.axes, mesh))


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * gamma.astype(dt)


def head_rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6):
    """qk-norm: RMS over the head_dim of (..., H, hd) tensors (qwen3)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * gamma.astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float = 1e6) -> jax.Array:
    """Rotary embedding for (..., S, H, hd); ``positions`` is (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                            # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], -1).astype(x.dtype)


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pad_heads(n_heads: int, n_kv: int, tp: int) -> Tuple[int, int]:
    """Zero-padded head counts so the flat q-head axis shards over ``tp``.

    Padded q heads have zero in/out weights (inert); kv is padded only when
    needed for the tile mapping (h_pad % kv == 0).  Returns (h_pad, kv_pad).
    See DESIGN.md §5 / attention.py module docstring.
    """
    if tp <= 1 or n_heads % tp == 0:
        return n_heads, n_kv
    h_pad = round_up(n_heads, tp)
    if h_pad % n_kv == 0:
        return h_pad, n_kv
    if n_kv == n_heads:                       # MHA: pad kv alongside q
        return h_pad, h_pad
    kv_pad = n_kv
    while h_pad % kv_pad != 0:
        kv_pad += 1
    return h_pad, kv_pad


def pad_vocab(vocab: int, tp: int) -> int:
    """Vocab padded for TP sharding; pad logits are masked in the loss."""
    if tp <= 1:
        return vocab
    m = 256 * tp
    return round_up(vocab, m) if vocab % tp else vocab


def cross_entropy_chunked(x_final: jax.Array, out_w: jax.Array,
                          targets: jax.Array, vocab: int,
                          chunk: int = 512) -> jax.Array:
    """Next-token CE computed in sequence chunks so (B,S,V) logits are never
    resident all at once.  ``out_w`` is (d, V_padded); ids >= vocab never
    occur in targets (pad rows are inert)."""
    b, s, d = x_final.shape
    n_chunks = max(s // chunk, 1)
    chunk = s // n_chunks
    xs = x_final.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    ts = targets.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    v_pad = out_w.shape[-1]
    pad_mask = (jnp.arange(v_pad) >= vocab) if v_pad > vocab else None

    def body(carry, inp):
        xc, tc = inp
        logits = (xc.astype(jnp.float32) @ out_w.astype(jnp.float32))
        logits = constrain(logits, ("batch", None, "vocab"))
        if pad_mask is not None:              # mask padded vocab columns
            logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(lse - gold), None

    # checkpoint: otherwise autodiff saves each chunk's (B, chunk, V) logits
    # across the scan (§Perf iteration 4) — recomputing one matmul in the
    # backward is far cheaper than 300 MB/chunk of residuals.
    total, _ = jax.lax.scan(jax.checkpoint(body),
                            jnp.zeros((), jnp.float32), (xs, ts))
    return total / (b * s)
