"""FFN layers: SwiGLU, D-ReLU-sparsified SwiGLU (the paper's technique
generalized to LM FFNs), and expert-parallel MoE.

D-ReLU on the FFN hidden (``drelu_k``): the hidden activation keeps its
top-k entries per token (balanced row sparsity, Eqs. 2-3 of the paper).
* Training lowers it as a masked dense matmul (the sparsity regularizes and
  the mask is what the SSpMM backward would sample — bitwise the same math).
* Decode exploits it structurally: the down-projection gathers only the k
  surviving rows of W_down per token (``vals · W_down[idx]``), the direct
  analogue of DR-SpMM consuming CBSR operands — FLOPs drop by k/d_ff.

MoE: the router *is* a per-row dynamic top-k (same operator family as
D-ReLU).  Experts are sharded over the ``model`` axis (EP); tokens arrive
sequence-sharded, are all-gathered over ``model``, processed by the local
expert slice with a capacity buffer, and psum-scattered back — the a2a-free
EP scheme (comm = 2× activation volume on the Megatron-SP boundary).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.lm.common import round_up
from repro.sharding.specs import (batch_axes, constrain, get_mesh,
                                  manual_axes)


from repro.sharding.specs import shard_map_compat as _shard_map


def swiglu_ffn(x, w_gate, w_up, w_down, drelu_k: int = 0,
               drelu_groups: int = 1):
    """(B,S,d) -> (B,S,d).  ``drelu_k`` > 0 sparsifies the hidden row-wise
    via grouped D-ReLU (groups = TP degree so the top-k is shard-local)."""
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, w_gate))
    h = h * jnp.einsum("bsd,df->bsf", x, w_up)
    h = constrain(h, ("batch", None, "mlp"))
    if 0 < drelu_k < h.shape[-1]:
        # Balanced top-k (D-ReLU): mask form — the matmul consumes a
        # k-per-row-sparse operand; decode uses the gather form below.
        h = _drelu_sharded(h, drelu_k, drelu_groups)
        h = constrain(h, ("batch", None, "mlp"))
    from jax.ad_checkpoint import checkpoint_name
    h = checkpoint_name(h, "proj")
    out = jnp.einsum("bsf,fd->bsd", h, w_down)
    return constrain(out, ("batch", "sp", None))


def _drelu_sharded(h, k: int, groups: int):
    """Grouped D-ReLU with the top-k forced shard-local.

    A bare ``lax.top_k`` on the model-sharded FFN hidden makes the SPMD
    partitioner replicate the sort operand (measured on qwen3-1.7b
    train_4k: a (256,4096,16,384) f32 all-gather ×2/layer ≈ 1.4 TB/device
    per step).  Running the same top-k inside a partial shard_map over the
    ``model`` axis pins every group's sort to its own shard — zero
    communication.  See EXPERIMENTS.md §Perf iteration 1.
    """
    from repro.core.drelu import drelu_grouped, _drelu_dense
    from repro.sharding.specs import manual_axes
    mesh = get_mesh()
    f = h.shape[-1]
    mp = mesh.shape.get("model", 1) if mesh is not None else 1
    if (mesh is None or mp == 1 or groups % mp or f % groups
            or k % groups or k >= f or manual_axes()):
        # manual_axes(): already inside a shard_map (e.g. the compressed
        # cross-pod gradient region) — nested full-manual maps are invalid;
        # the grouped form is still shard-local-friendly via its constraint.
        return drelu_grouped(h, k, groups)
    b, s, _ = h.shape
    hg = h.reshape(b, s, groups, f // groups)
    # fully manual: with only 'model' manual, the partitioner still chose to
    # replicate the batch over 'data' for the sort (measured 45 GB/layer
    # gathers) — pinning every mesh axis removes all SPMD freedom.
    dp = batch_axes(mesh)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    bspec = (dp if len(dp) > 1 else (dp[0] if dp else None))
    if b % max(n_dp, 1) != 0:
        bspec = None
    spec = P(bspec, None, "model", None)

    @_shard_map(mesh=mesh, in_specs=spec, out_specs=spec, check_vma=False)
    def local_topk(x):
        return _drelu_dense(x, k // groups)

    return local_topk(hg).reshape(b, s, f)


def swiglu_ffn_decode_sparse(x, w_gate, w_up, w_down, drelu_k: int):
    """Decode-path FFN exploiting D-ReLU sparsity structurally.

    x: (B, 1, d).  The down-projection touches only the k surviving rows of
    W_down per token: y = Σ_t vals_t · W_down[idx_t] — CBSR-consuming matmul.
    """
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, w_gate))
    h = h * jnp.einsum("bsd,df->bsf", x, w_up)
    b, s, f = h.shape
    if not (0 < drelu_k < f):
        return jnp.einsum("bsf,fd->bsd", h, w_down)
    from repro.core.cbsr import cbsr_from_dense
    c = cbsr_from_dense(h.reshape(b * s, f), drelu_k)
    rows = jnp.take(w_down, c.idx, axis=0)          # (B*S, k, d) weight gather
    y = jnp.einsum("tk,tkd->td", c.values, rows)
    return y.reshape(b, s, -1)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def moe_capacity(tokens_per_shard: int, n_experts: int, top_k: int,
                 capacity_factor: float) -> int:
    c = int(tokens_per_shard * top_k / n_experts * capacity_factor)
    return max(round_up(c, 8), 8)


def _route(x2d, router_w, top_k: int):
    """Top-k routing (the D-ReLU operator on the expert axis).

    Returns (probs (T,k), ids (T,k) int32, full_probs (T,E))."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    full = jax.nn.softmax(logits, axis=-1)
    probs, ids = jax.lax.top_k(full, top_k)
    probs = probs / jnp.maximum(probs.sum(-1, keepdims=True), 1e-9)
    return probs.astype(x2d.dtype), ids.astype(jnp.int32), full


def _expert_ffn(buf, w_gate, w_up, w_down):
    """buf (E_l, C, d) through per-expert SwiGLU."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate))
    h = h * jnp.einsum("ecd,edf->ecf", buf, w_up)
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def _moe_local(x, router_w, w_gate, w_up, w_down, top_k, capacity_factor,
               e_offset: int, n_experts_global: int):
    """Single-shard MoE over local experts; x (B,S,d) fully local."""
    b, s, d = x.shape
    e_local = w_gate.shape[0]
    x2d = x.reshape(b * s, d)
    t = b * s
    probs, ids, _ = _route(x2d, router_w, top_k)

    cap = moe_capacity(t, n_experts_global, top_k, capacity_factor)
    flat_ids = ids.reshape(-1)                        # (T*k,)
    flat_probs = probs.reshape(-1)
    local = (flat_ids >= e_offset) & (flat_ids < e_offset + e_local)
    el = jnp.where(local, flat_ids - e_offset, e_local)   # sentinel drops
    onehot = jax.nn.one_hot(el, e_local + 1, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot             # position in expert
    p = jnp.take_along_axis(pos, el[:, None], axis=1)[:, 0]
    keep = local & (p < cap)
    el_safe = jnp.where(keep, el, e_local)                # -> dropped row
    p_safe = jnp.where(keep, p, cap)

    tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), top_k)
    buf = jnp.zeros((e_local + 1, cap + 1, d), x.dtype)
    buf = buf.at[el_safe, p_safe].set(x2d[tok], mode="drop")
    y_buf = _expert_ffn(buf[:e_local, :cap], w_gate, w_up, w_down)
    y_buf = jnp.pad(y_buf, ((0, 1), (0, 1), (0, 0)))

    gathered = y_buf[el_safe, p_safe]                     # (T*k, d)
    contrib = gathered * (flat_probs * keep.astype(flat_probs.dtype))[:, None]
    y = jnp.zeros((t, d), x.dtype).at[tok].add(contrib)
    return y.reshape(b, s, d)


def moe_ffn(x, router_w, w_gate, w_up, w_down, *, n_experts: int,
            top_k: int, capacity_factor: float = 1.25) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE.  x (B,S,d) sequence-sharded on entry.

    Returns (y, aux_loss).  aux is the standard load-balance loss computed
    from the (cheap) router replay on the sharded view.
    """
    mesh = get_mesh()
    b, s, d = x.shape

    # load-balance aux (router on the sharded view — tiny matmul)
    _, ids_aux, full_aux = _route(x.reshape(b * s, d), router_w, top_k)
    frac = jnp.mean(jax.nn.one_hot(ids_aux, n_experts, dtype=jnp.float32),
                    axis=(0, 1))
    imp = jnp.mean(full_aux, axis=0)
    aux = n_experts * jnp.sum(frac * imp)

    use_shmap = (mesh is not None and "model" in mesh.axis_names
                 and not manual_axes()
                 and mesh.shape["model"] > 1
                 and n_experts % mesh.shape["model"] == 0
                 and s % mesh.shape["model"] == 0)
    if not use_shmap:
        y = _moe_local(x, router_w, w_gate, w_up, w_down, top_k,
                       capacity_factor, 0, n_experts)
        return y, aux

    mp = mesh.shape["model"]
    e_local = n_experts // mp
    dp = batch_axes(mesh)
    bspec = dp if len(dp) > 1 else (dp[0] if dp else None)
    if b % max(mesh.shape.get("pod", 1) * mesh.shape.get("data", 1), 1) != 0:
        bspec = None
    x_spec = P(bspec, "model", None)
    w_spec = P("model", None, None)

    @_shard_map(mesh=mesh,
                in_specs=(x_spec, P(None, None), w_spec, w_spec, w_spec),
                out_specs=x_spec, check_vma=False)
    def shmap_moe(x_l, rw, wg_l, wu_l, wd_l):
        shard = jax.lax.axis_index("model")
        # recover the full sequence on each model shard (SP boundary gather)
        x_full = jax.lax.all_gather(x_l, "model", axis=1, tiled=True)
        y_full = _moe_local(x_full, rw, wg_l, wu_l, wd_l, top_k,
                            capacity_factor, shard * e_local, n_experts)
        # sum expert contributions across shards AND re-shard the sequence
        return jax.lax.psum_scatter(y_full, "model", scatter_dimension=1,
                                    tiled=True)

    y = shmap_moe(x, router_w, w_gate, w_up, w_down)
    return constrain(y, ("batch", "sp", None)), aux
