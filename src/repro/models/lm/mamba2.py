"""Mamba-2 SSD (state-space duality) core [arXiv:2405.21060].

Chunked SSD for train/prefill (scan over chunks carrying the inter-chunk
state) and the O(1) recurrence for decode — this is what makes the
``long_500k`` cell runnable (DESIGN.md §Arch-applicability: the paper's
DR-SpMM does not apply inside this core; the state recurrence contracts a
dense structured matrix).

Projections are kept *separate* (z/x/B/C/dt) rather than fused so each can
carry its own sharding: the d_inner-sized ones shard over ``model`` ('mlp' /
'ssm_heads'), the small state projections stay replicated.

Shapes (n_groups = 1):
    x   : (B, S, H, P)    — P = ssm_head_dim, H = d_inner / P heads
    B,C : (B, S, N)       — N = ssm_state
    dt  : (B, S, H)       — softplus-positive step sizes
    A   : (H,)            — negative decay rates (−exp(a_log))
state  : (B, H, P, N) f32
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.lm.common import rms_norm
from repro.sharding.specs import constrain

CONV_K = 4          # depthwise causal conv width (mamba2 default)


class SSMCache(NamedTuple):
    state: jax.Array      # (B, H, P, N) f32
    conv_x: jax.Array     # (B, CONV_K-1, d_inner)
    conv_b: jax.Array     # (B, CONV_K-1, N)
    conv_c: jax.Array     # (B, CONV_K-1, N)


def causal_conv1d(x, w, b):
    """Depthwise causal conv.  x (B,S,C); w (CONV_K, C); b (C,)."""
    pad = jnp.pad(x, ((0, 0), (CONV_K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(CONV_K):
        out = out + pad[:, i: i + x.shape[1]] * w[i][None, None, :]
    return out + b[None, None, :]


def causal_conv1d_step(x_t, conv_state, w, b):
    """One-token conv.  x_t (B,1,C); conv_state (B, CONV_K-1, C).
    Returns (out (B,1,C), new_conv_state)."""
    window = jnp.concatenate([conv_state, x_t], axis=1)      # (B, K, C)
    out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                     w.astype(jnp.float32)) + b
    return out[:, None, :].astype(x_t.dtype), window[:, 1:]


def ssd_chunked(x, b_mat, c_mat, dt, a_log, d_skip, *, chunk: int,
                initial_state=None) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.  Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    chunk = min(chunk, s)
    nc = s // chunk
    a = -jnp.exp(a_log.astype(jnp.float32))                  # (H,)

    dt = jax.nn.softplus(dt.astype(jnp.float32))             # (B,S,H)
    xdt = x.astype(jnp.float32) * dt[..., None]              # (B,S,H,P)
    da = dt * a[None, None, :]                               # (B,S,H) ≤ 0

    xdt = xdt.reshape(bsz, nc, chunk, h, p)
    da = da.reshape(bsz, nc, chunk, h)
    bm = b_mat.astype(jnp.float32).reshape(bsz, nc, chunk, n)
    cm = c_mat.astype(jnp.float32).reshape(bsz, nc, chunk, n)

    cum = jnp.cumsum(da, axis=2)                             # (B,nc,C,H)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (B,nc,i,j,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)

    # intra-chunk: Y_i = Σ_{j≤i} (C_i·B_j) decay_ij xdt_j
    g = jnp.einsum("bniv,bnjv->bnij", cm, bm)                # (B,nc,C,C)
    y_intra = jnp.einsum("bnij,bnijh,bnjhp->bnihp", g, decay, xdt)

    # chunk-end states: S_n = Σ_j exp(cum_end − cum_j) B_j ⊗ xdt_j
    end_decay = jnp.exp(cum[:, :, -1:, :] - cum)             # (B,nc,C,H)
    states = jnp.einsum("bnjh,bnjv,bnjhp->bnhpv", end_decay, bm, xdt)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                  # (B,nc,H)

    s0 = (initial_state.astype(jnp.float32) if initial_state is not None
          else jnp.zeros((bsz, h, p, n), jnp.float32))

    def scan_body(st_in, inp):
        states_n, cd_n = inp
        st_out = st_in * cd_n[:, :, None, None] + states_n
        return st_out, st_in                                 # emit incoming

    final_state, prev_states = jax.lax.scan(
        scan_body, s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)       # (B,nc,H,P,N)

    # inter-chunk: Y_i += C_i · prev_state · exp(cum_i)
    in_decay = jnp.exp(cum)                                  # (B,nc,C,H)
    y_inter = jnp.einsum("bniv,bnhpv,bnih->bnihp", cm, prev_states, in_decay)

    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    y = y + x.astype(jnp.float32) * d_skip[None, None, :, None]
    return y.astype(x.dtype), final_state


def ssd_decode_step(x_t, b_t, c_t, dt_t, a_log, d_skip, state):
    """O(1) recurrence: state ← state·exp(dt·a) + dt·(B ⊗ x); y = C·state.

    x_t (B,1,H,P); b_t/c_t (B,1,N); dt_t (B,1,H); state (B,H,P,N) f32."""
    a = -jnp.exp(a_log.astype(jnp.float32))
    dt = jax.nn.softplus(dt_t.astype(jnp.float32))[:, 0]      # (B,H)
    xf = x_t.astype(jnp.float32)[:, 0]                        # (B,H,P)
    bf = b_t.astype(jnp.float32)[:, 0]                        # (B,N)
    cf = c_t.astype(jnp.float32)[:, 0]
    decay = jnp.exp(dt * a[None, :])                          # (B,H)
    upd = jnp.einsum("bhp,bn->bhpn", xf * dt[..., None], bf)
    state = state * decay[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, cf)
    y = y + xf * d_skip[None, :, None]
    return y[:, None].astype(x_t.dtype), state


# ---------------------------------------------------------------------------
# full mamba2 block (split projections + conv + SSD + gate)
# ---------------------------------------------------------------------------

def mamba2_block(x, p, cfg, *, mode: str = "train",
                 cache: Optional[SSMCache] = None
                 ) -> Tuple[jax.Array, Optional[SSMCache]]:
    """One mamba2 block.  x (B,S,d).

    mode: "train" (no cache), "prefill" (returns cache), "decode"
    (consumes + returns cache; S must be 1).
    """
    bsz, s, d = x.shape
    di = cfg.ssm_expand * cfg.d_model
    n = cfg.ssm_state
    p_hd = cfg.ssm_head_dim
    h = di // p_hd
    dt_f32 = jnp.float32

    z = jnp.einsum("bsd,de->bse", x, p["z_proj"].astype(x.dtype))
    xc_raw = jnp.einsum("bsd,de->bse", x, p["x_proj"].astype(x.dtype))
    b_raw = jnp.einsum("bsd,dv->bsv", x, p["b_proj"].astype(x.dtype))
    c_raw = jnp.einsum("bsd,dv->bsv", x, p["c_proj"].astype(x.dtype))
    dt = (jnp.einsum("bsd,dh->bsh", x, p["dt_proj"].astype(x.dtype))
          .astype(dt_f32) + p["dt_bias"][None, None, :])
    xc_raw = constrain(xc_raw, ("batch", None, "mlp"))
    z = constrain(z, ("batch", None, "mlp"))

    cw = {k: p[k].astype(x.dtype) for k in
          ("conv_x_w", "conv_x_b", "conv_b_w", "conv_b_b",
           "conv_c_w", "conv_c_b")}
    if mode == "decode":
        assert cache is not None
        xc, conv_x = causal_conv1d_step(xc_raw, cache.conv_x,
                                        cw["conv_x_w"], cw["conv_x_b"])
        bm, conv_b = causal_conv1d_step(b_raw, cache.conv_b,
                                        cw["conv_b_w"], cw["conv_b_b"])
        cm, conv_c = causal_conv1d_step(c_raw, cache.conv_c,
                                        cw["conv_c_w"], cw["conv_c_b"])
    else:
        xc = causal_conv1d(xc_raw, cw["conv_x_w"], cw["conv_x_b"])
        bm = causal_conv1d(b_raw, cw["conv_b_w"], cw["conv_b_b"])
        cm = causal_conv1d(c_raw, cw["conv_c_w"], cw["conv_c_b"])
        conv_x = xc_raw[:, -(CONV_K - 1):]
        conv_b = b_raw[:, -(CONV_K - 1):]
        conv_c = c_raw[:, -(CONV_K - 1):]

    xc = jax.nn.silu(xc)
    bm = jax.nn.silu(bm)
    cm = jax.nn.silu(cm)

    xh = xc.reshape(bsz, s, h, p_hd)
    xh = constrain(xh, ("batch", None, "ssm_heads", None))

    new_cache = None
    if mode == "decode":
        y, new_state = ssd_decode_step(xh, bm, cm, dt, p["a_log"],
                                       p["d_skip"], cache.state)
        new_cache = SSMCache(state=new_state, conv_x=conv_x,
                             conv_b=conv_b, conv_c=conv_c)
    else:
        y, final_state = ssd_chunked(xh, bm, cm, dt, p["a_log"],
                                     p["d_skip"], chunk=cfg.ssm_chunk)
        if mode == "prefill":
            new_cache = SSMCache(state=final_state, conv_x=conv_x,
                                 conv_b=conv_b, conv_c=conv_c)

    y = y.reshape(bsz, s, di)
    y = y * jax.nn.silu(z)                      # gated
    y = rms_norm(y, p["ssd_norm"])
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    return constrain(out, ("batch", "sp", None)), new_cache


def init_ssm_cache(bsz: int, cfg, dtype=jnp.float32) -> SSMCache:
    di = cfg.ssm_expand * cfg.d_model
    n = cfg.ssm_state
    h = di // cfg.ssm_head_dim
    return SSMCache(
        state=jnp.zeros((bsz, h, cfg.ssm_head_dim, n), jnp.float32),
        conv_x=jnp.zeros((bsz, CONV_K - 1, di), dtype),
        conv_b=jnp.zeros((bsz, CONV_K - 1, n), dtype),
        conv_c=jnp.zeros((bsz, CONV_K - 1, n), dtype))
