"""LM model zoo: one class, six families, three entry points.

Families: dense (qwen3/minitron/minicpm), ssm (mamba2), moe (moonshot,
granite), vlm (llama-3.2-vision), audio (whisper enc-dec), hybrid (zamba2).

Entry points (all pure functions of (params, inputs)):
    loss(params, batch)                      — training objective
    prefill(params, tokens, extra)           — build KV/SSM cache + last logits
    decode_step(params, cache, token, pos)   — one-token serve step

Compile-time discipline: every layer stack is a ``lax.scan`` over stacked
parameters (HLO size independent of depth); heterogeneous stacks (vlm
cross-attention every 5 layers, zamba2 shared block every 6) are scans over
*groups* so no per-layer Python unrolling happens at paper scale.
Remat (``jax.checkpoint``) wraps each layer body when cfg.remat.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.lm import attention as attn
from repro.models.lm import ffn as ffn_mod
from repro.models.lm import mamba2 as m2
from repro.models.lm.common import (PSpec, abstract_params, cross_entropy_chunked,
                                    init_params, pad_heads, pad_vocab,
                                    param_pspecs, param_shardings, rms_norm)
from repro.sharding.specs import constrain

Params = Dict[str, Any]


def _maybe_remat(fn, enable: bool, policy: str = "full"):
    if not enable:
        return fn
    if policy == "dots":
        # save ALL matmul outputs (incl. flash internals — measured 2.6×
        # bytes regression on qwen3; kept for ablation only)
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_saveable)
    if policy == "proj":
        # save only the named projection outputs (q/k/v/ctx/ffn-hidden) —
        # the backward skips their recompute (and its re-all-gathers) while
        # flash internals still recompute from the saved q/k/v locally
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.save_only_these_names("proj"))
    return jax.checkpoint(fn)


class LM:
    """A config-specialized model: template + apply functions."""

    def __init__(self, cfg: ArchConfig, tp: int = 1, *,
                 causal_mode: str = "brick"):
        self.cfg = cfg
        self.tp = tp
        self.causal_mode = causal_mode
        if cfg.family == "ssm":
            self.h_pad, self.kv_pad = 0, 0
        else:
            self.h_pad, self.kv_pad = pad_heads(cfg.n_heads, cfg.n_kv, tp)
        self.v_pad = pad_vocab(cfg.vocab, tp)
        self.dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        self.template = self._build_template()

    # ------------------------------------------------------------------
    # parameter templates
    # ------------------------------------------------------------------

    def _attn_tmpl(self, n: int, cross: bool = False) -> Dict[str, PSpec]:
        c, hd = self.cfg, self.cfg.hd
        t = {
            "wq": PSpec((n, c.d_model, self.h_pad, hd),
                        (None, "embed", "heads", None)),
            "wk": PSpec((n, c.d_model, self.kv_pad, hd),
                        (None, "embed", "kv_heads", None)),
            "wv": PSpec((n, c.d_model, self.kv_pad, hd),
                        (None, "embed", "kv_heads", None)),
            "wo": PSpec((n, self.h_pad, hd, c.d_model),
                        (None, "heads", None, "embed")),
        }
        if c.qk_norm and not cross:
            t["qk_q"] = PSpec((n, hd), (None, None), "ones")
            t["qk_k"] = PSpec((n, hd), (None, None), "ones")
        return t

    def _ffn_tmpl(self, n: int, gelu: bool = False) -> Dict[str, PSpec]:
        c = self.cfg
        if gelu:
            return {"w1": PSpec((n, c.d_model, c.d_ff), (None, "embed", "mlp")),
                    "b1": PSpec((n, c.d_ff), (None, "mlp"), "zeros"),
                    "w2": PSpec((n, c.d_ff, c.d_model), (None, "mlp", "embed")),
                    "b2": PSpec((n, c.d_model), (None, None), "zeros")}
        return {"w_gate": PSpec((n, c.d_model, c.d_ff), (None, "embed", "mlp")),
                "w_up": PSpec((n, c.d_model, c.d_ff), (None, "embed", "mlp")),
                "w_down": PSpec((n, c.d_ff, c.d_model), (None, "mlp", "embed"))}

    def _moe_tmpl(self, n: int) -> Dict[str, PSpec]:
        c = self.cfg
        return {
            "router": PSpec((n, c.d_model, c.n_experts), (None, "embed", None)),
            "w_gate": PSpec((n, c.n_experts, c.d_model, c.d_ff),
                            (None, "experts", "embed", None)),
            "w_up": PSpec((n, c.n_experts, c.d_model, c.d_ff),
                          (None, "experts", "embed", None)),
            "w_down": PSpec((n, c.n_experts, c.d_ff, c.d_model),
                            (None, "experts", None, "embed")),
        }

    def _ssm_tmpl(self, n: int) -> Dict[str, PSpec]:
        c = self.cfg
        d, di = c.d_model, c.ssm_expand * c.d_model
        nst, h = c.ssm_state, (c.ssm_expand * c.d_model) // c.ssm_head_dim
        k = m2.CONV_K
        return {
            "z_proj": PSpec((n, d, di), (None, "embed", "mlp")),
            "x_proj": PSpec((n, d, di), (None, "embed", "mlp")),
            "b_proj": PSpec((n, d, nst), (None, "embed", None)),
            "c_proj": PSpec((n, d, nst), (None, "embed", None)),
            "dt_proj": PSpec((n, d, h), (None, "embed", "ssm_heads")),
            "dt_bias": PSpec((n, h), (None, "ssm_heads"), "zeros"),
            "conv_x_w": PSpec((n, k, di), (None, None, "mlp"), "normal", 0.1),
            "conv_x_b": PSpec((n, di), (None, "mlp"), "zeros"),
            "conv_b_w": PSpec((n, k, nst), (None, None, None), "normal", 0.1),
            "conv_b_b": PSpec((n, nst), (None, None), "zeros"),
            "conv_c_w": PSpec((n, k, nst), (None, None, None), "normal", 0.1),
            "conv_c_b": PSpec((n, nst), (None, None), "zeros"),
            "a_log": PSpec((n, h), (None, "ssm_heads"), "zeros"),
            "d_skip": PSpec((n, h), (None, "ssm_heads"), "ones"),
            "ssd_norm": PSpec((n, di), (None, "mlp"), "ones"),
            "out_proj": PSpec((n, di, d), (None, "mlp", "embed")),
        }

    def _norms(self, n: int, names) -> Dict[str, PSpec]:
        return {k: PSpec((n, self.cfg.d_model), (None, None), "ones")
                for k in names}

    def _build_template(self) -> Params:
        c = self.cfg
        t: Params = {
            "embed": PSpec((self.v_pad, c.d_model), ("vocab", "embed")),
            "final_norm": PSpec((c.d_model,), (None,), "ones"),
        }
        if not c.tie_embeddings:
            t["out_w"] = PSpec((c.d_model, self.v_pad), ("embed", "vocab"))

        if c.family in ("dense",):
            t["layers"] = {**self._attn_tmpl(c.n_layers),
                           **self._ffn_tmpl(c.n_layers),
                           **self._norms(c.n_layers, ("ln1", "ln2"))}
        elif c.family == "moe":
            t["layers"] = {**self._attn_tmpl(c.n_layers),
                           **self._moe_tmpl(c.n_layers),
                           **self._norms(c.n_layers, ("ln1", "ln2"))}
        elif c.family == "ssm":
            t["layers"] = {**self._ssm_tmpl(c.n_layers),
                           **self._norms(c.n_layers, ("ln",))}
        elif c.family == "hybrid":
            t["layers"] = {**self._ssm_tmpl(c.n_layers),
                           **self._norms(c.n_layers, ("ln",))}
            t["shared"] = {**self._attn_tmpl(1), **self._ffn_tmpl(1),
                           **self._norms(1, ("ln1", "ln2"))}
        elif c.family == "vlm":
            n_cross = c.n_layers // c.cross_every
            n_self = c.n_layers - n_cross
            self.n_groups = n_cross
            self.self_per_group = n_self // n_cross
            t["layers"] = {**self._attn_tmpl(n_self),
                           **self._ffn_tmpl(n_self),
                           **self._norms(n_self, ("ln1", "ln2"))}
            cross = {**self._attn_tmpl(n_cross, cross=True),
                     **self._ffn_tmpl(n_cross),
                     **self._norms(n_cross, ("ln1", "ln2"))}
            cross["gate_attn"] = PSpec((n_cross,), (None,), "zeros")
            cross["gate_ffn"] = PSpec((n_cross,), (None,), "zeros")
            t["cross"] = cross
        elif c.family == "audio":
            t["enc_layers"] = {**self._attn_tmpl(c.enc_layers),
                               **self._ffn_tmpl(c.enc_layers, gelu=True),
                               **self._norms(c.enc_layers, ("ln1", "ln2"))}
            t["enc_norm"] = PSpec((c.d_model,), (None,), "ones")
            dec = {**self._attn_tmpl(c.n_layers),
                   **self._ffn_tmpl(c.n_layers, gelu=True),
                   **self._norms(c.n_layers, ("ln1", "ln2", "ln_x"))}
            for k, v in self._attn_tmpl(c.n_layers, cross=True).items():
                dec["x_" + k] = v
            t["layers"] = dec
        else:
            raise ValueError(c.family)
        return t

    # ------------------------------------------------------------------
    # params
    # ------------------------------------------------------------------

    def init(self, key) -> Params:
        return init_params(self.template, key, jnp.float32)

    def abstract_params(self):
        return abstract_params(self.template, jnp.float32)

    def param_shardings(self, mesh):
        return param_shardings(self.template, mesh)

    def param_pspecs(self, mesh):
        return param_pspecs(self.template, mesh)

    def _out_w(self, params):
        return (params["embed"].T if self.cfg.tie_embeddings
                else params["out_w"])

    # ------------------------------------------------------------------
    # layer bodies
    # ------------------------------------------------------------------

    def _attn_args(self, lp, prefix=""):
        g = lambda k: lp[prefix + k].astype(self.dtype)
        qn = lp.get(prefix + "qk_q")
        return dict(wq=g("wq"), wk=g("wk"), wv=g("wv"), wo=g("wo"),
                    qk_q=None if qn is None else lp[prefix + "qk_q"],
                    qk_k=None if qn is None else lp[prefix + "qk_k"],
                    n_kv=self.kv_pad, rope_theta=self.cfg.rope_theta)

    def _dense_body(self, x, lp, *, kv_out: bool = False):
        c = self.cfg
        h = attn.attention_block(rms_norm(x, lp["ln1"]),
                                 causal_mode=self.causal_mode,
                                 return_kv=kv_out, **self._attn_args(lp))
        kv = None
        if kv_out:
            h, kv = h
        x = x + h
        f = ffn_mod.swiglu_ffn(rms_norm(x, lp["ln2"]),
                               lp["w_gate"].astype(self.dtype),
                               lp["w_up"].astype(self.dtype),
                               lp["w_down"].astype(self.dtype),
                               drelu_k=c.drelu_k, drelu_groups=self.tp)
        x = constrain(x + f, ("batch", "sp", None))
        return (x, kv) if kv_out else x

    def _moe_body(self, xa, lp, *, kv_out: bool = False):
        x, aux = xa
        c = self.cfg
        h = attn.attention_block(rms_norm(x, lp["ln1"]),
                                 causal_mode=self.causal_mode,
                                 return_kv=kv_out, **self._attn_args(lp))
        kv = None
        if kv_out:
            h, kv = h
        x = x + h
        f, aux_l = ffn_mod.moe_ffn(rms_norm(x, lp["ln2"]),
                                   lp["router"],
                                   lp["w_gate"].astype(self.dtype),
                                   lp["w_up"].astype(self.dtype),
                                   lp["w_down"].astype(self.dtype),
                                   n_experts=c.n_experts, top_k=c.top_k,
                                   capacity_factor=c.capacity_factor)
        x = constrain(x + f, ("batch", "sp", None))
        return ((x, aux + aux_l), kv) if kv_out else (x, aux + aux_l)

    def _ssm_body(self, x, lp):
        h, _ = m2.mamba2_block(rms_norm(x, lp["ln"]), lp, self.cfg)
        return constrain(x + h, ("batch", "sp", None))

    def _gelu_ffn(self, x, lp, prefix=""):
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x,
                                   lp[prefix + "w1"].astype(self.dtype))
                        + lp[prefix + "b1"].astype(self.dtype))
        h = constrain(h, ("batch", None, "mlp"))
        return (jnp.einsum("bsf,fd->bsd", h,
                           lp[prefix + "w2"].astype(self.dtype))
                + lp[prefix + "b2"].astype(self.dtype))

    # ------------------------------------------------------------------
    # forward (training): tokens -> final hidden
    # ------------------------------------------------------------------

    def _embed(self, params, tokens):
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.dtype)
        return constrain(x, ("batch", "sp", None))

    def forward(self, params, tokens, extra: Optional[Dict] = None):
        """Returns (hidden (B,S,d), aux_loss scalar)."""
        c = self.cfg
        x = self._embed(params, tokens)
        aux = jnp.zeros((), jnp.float32)
        remat = c.remat

        if c.family == "dense":
            body = _maybe_remat(lambda x_, lp: (self._dense_body(x_, lp), None),
                                remat, c.remat_policy)
            x, _ = jax.lax.scan(body, x, params["layers"])
        elif c.family == "moe":
            body = _maybe_remat(lambda xa, lp: (self._moe_body(xa, lp), None),
                                remat, c.remat_policy)
            (x, aux), _ = jax.lax.scan(body, (x, aux), params["layers"])
        elif c.family == "ssm":
            body = _maybe_remat(lambda x_, lp: (self._ssm_body(x_, lp), None),
                                remat, c.remat_policy)
            x, _ = jax.lax.scan(body, x, params["layers"])
        elif c.family == "hybrid":
            x = self._hybrid_forward(params, x)
        elif c.family == "vlm":
            x = self._vlm_forward(params, x, extra["image_emb"])
        elif c.family == "audio":
            x = self._audio_forward(params, x, extra["frames"])
        return rms_norm(x, params["final_norm"]), aux

    # --- hybrid: shared attention block every attn_every ssm layers -----

    def _shared_block(self, params, x):
        sp = {k: v[0] for k, v in params["shared"].items()}
        h = attn.attention_block(rms_norm(x, sp["ln1"]),
                                 causal_mode=self.causal_mode,
                                 **self._attn_args(sp))
        x = x + h
        f = ffn_mod.swiglu_ffn(rms_norm(x, sp["ln2"]),
                               sp["w_gate"].astype(self.dtype),
                               sp["w_up"].astype(self.dtype),
                               sp["w_down"].astype(self.dtype),
                               drelu_k=self.cfg.drelu_k, drelu_groups=self.tp)
        return constrain(x + f, ("batch", "sp", None))

    def _hybrid_split(self, layers):
        c = self.cfg
        n_full = (c.n_layers // c.attn_every) * c.attn_every
        head = jax.tree.map(lambda a: a[:n_full].reshape(
            (n_full // c.attn_every, c.attn_every) + a.shape[1:]), layers)
        tail = jax.tree.map(lambda a: a[n_full:], layers)
        n_tail = c.n_layers - n_full
        return head, tail, n_full // c.attn_every, n_tail

    def _hybrid_forward(self, params, x):
        c = self.cfg
        head, tail, n_groups, n_tail = self._hybrid_split(params["layers"])
        ssm_body = _maybe_remat(
            lambda x_, lp: (self._ssm_body(x_, lp), None), c.remat,
            c.remat_policy)

        def group(x_, glp):
            x_ = self._shared_block(params, x_)
            x_, _ = jax.lax.scan(ssm_body, x_, glp)
            return x_, None

        x, _ = jax.lax.scan(group, x, head)
        if n_tail:
            x = self._shared_block(params, x)        # final application
            x, _ = jax.lax.scan(ssm_body, x, tail)
        return x

    # --- vlm: groups of self layers + one gated cross-attention ---------

    def _cross_body(self, x, lp, img):
        h = attn.attention_block(rms_norm(x, lp["ln1"]), kv_x=img,
                                 causal=False, **self._attn_args(lp))
        x = x + jnp.tanh(lp["gate_attn"]).astype(x.dtype) * h
        f = ffn_mod.swiglu_ffn(rms_norm(x, lp["ln2"]),
                               lp["w_gate"].astype(self.dtype),
                               lp["w_up"].astype(self.dtype),
                               lp["w_down"].astype(self.dtype),
                               drelu_k=self.cfg.drelu_k, drelu_groups=self.tp)
        x = x + jnp.tanh(lp["gate_ffn"]).astype(x.dtype) * f
        return constrain(x, ("batch", "sp", None))

    def _vlm_forward(self, params, x, img):
        c = self.cfg
        img = constrain(img.astype(self.dtype), ("batch", None, None))
        k = self.self_per_group
        grouped = jax.tree.map(
            lambda a: a.reshape((self.n_groups, k) + a.shape[1:]),
            params["layers"])
        self_body = _maybe_remat(
            lambda x_, lp: (self._dense_body(x_, lp), None), c.remat,
            c.remat_policy)
        cross_body = _maybe_remat(
            lambda x_, lp: self._cross_body(x_, lp, img), c.remat,
            c.remat_policy)

        def group(x_, inp):
            slp, clp = inp
            x_, _ = jax.lax.scan(self_body, x_, slp)
            x_ = cross_body(x_, clp)
            return x_, None

        x, _ = jax.lax.scan(group, x, (grouped, params["cross"]))
        return x

    # --- audio: whisper encoder-decoder ---------------------------------

    def _enc_body(self, x, lp):
        h = attn.attention_block(rms_norm(x, lp["ln1"]), causal=False,
                                 **self._attn_args(lp))
        x = x + h
        x = x + self._gelu_ffn(rms_norm(x, lp["ln2"]), lp)
        return constrain(x, ("batch", "sp", None))

    def _dec_body(self, x, lp, enc_out, *, kv_out: bool = False):
        h = attn.attention_block(rms_norm(x, lp["ln1"]),
                                 causal_mode=self.causal_mode,
                                 return_kv=kv_out, **self._attn_args(lp))
        kv = None
        if kv_out:
            h, kv = h
        x = x + h
        hx = attn.attention_block(rms_norm(x, lp["ln_x"]), kv_x=enc_out,
                                  causal=False,
                                  return_kv=kv_out,
                                  **self._attn_args(lp, prefix="x_"))
        xkv = None
        if kv_out:
            hx, xkv = hx
        x = x + hx
        x = x + self._gelu_ffn(rms_norm(x, lp["ln2"]), lp)
        x = constrain(x, ("batch", "sp", None))
        return (x, (kv, xkv)) if kv_out else x

    def encode_audio(self, params, frames):
        """frames (B, F, d) — precomputed mel-frame embeddings (stub)."""
        x = constrain(frames.astype(self.dtype), ("batch", "sp", None))
        body = _maybe_remat(lambda x_, lp: (self._enc_body(x_, lp), None),
                            self.cfg.remat, self.cfg.remat_policy)
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
        return rms_norm(x, params["enc_norm"])

    def _audio_forward(self, params, x, frames):
        enc_out = self.encode_audio(params, frames)
        body = _maybe_remat(
            lambda x_, lp: (self._dec_body(x_, lp, enc_out), None),
            self.cfg.remat, self.cfg.remat_policy)
        x, _ = jax.lax.scan(body, x, params["layers"])
        return x

    # ------------------------------------------------------------------
    # loss
    # ------------------------------------------------------------------

    def loss(self, params, batch: Dict) -> jax.Array:
        extra = {k: v for k, v in batch.items()
                 if k not in ("tokens", "targets")}
        hidden, aux = self.forward(params, batch["tokens"], extra or None)
        ce = cross_entropy_chunked(hidden, self._out_w(params),
                                   batch["targets"], self.cfg.vocab)
        return ce + 0.01 * aux

    # ------------------------------------------------------------------
    # serve: prefill + decode  (see serve.py for cache plumbing)
    # ------------------------------------------------------------------

    def logits_last(self, params, hidden_last):
        """hidden_last (B,1,d) -> (B,1,V_pad)."""
        logits = jnp.einsum("bsd,dv->bsv", hidden_last.astype(jnp.float32),
                            self._out_w(params).astype(jnp.float32))
        return constrain(logits, ("batch", None, "vocab"))


def build_lm(cfg: ArchConfig, tp: int = 1, **kw) -> LM:
    return LM(cfg, tp, **kw)
