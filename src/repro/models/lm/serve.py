"""Serving: cache templates, prefill, and one-token decode for every family.

Cache layout notes (axes are logical sharding names, DESIGN.md §5):
* attention KV caches are **sequence-sharded** over ``model`` ('kv_seq') —
  at decode_32k×B128 or long_500k they cannot live on fewer devices — and
  batch-sharded over data/pod;
* SSM caches shard the head axis ('ssm_heads' → model) and batch;
* cross-attention caches (image tokens / audio frames) are short — batch
  sharding only.

``decode_step`` is the serve_step the decode_* dry-run cells lower.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.lm import attention as attn
from repro.models.lm import ffn as ffn_mod
from repro.models.lm import mamba2 as m2
from repro.models.lm.common import rms_norm, rope, head_rms_norm
from repro.models.lm.model import LM
from repro.sharding.specs import constrain, param_sharding

CacheTmpl = Dict[str, Tuple[Tuple[int, ...], Tuple[Any, ...], Any]]


# ---------------------------------------------------------------------------
# cache templates
# ---------------------------------------------------------------------------

def cache_template(lm: LM, batch: int, s_max: int) -> CacheTmpl:
    """name -> (shape, logical axes, dtype)."""
    c = lm.cfg
    kv, hd = lm.kv_pad, c.hd
    dt = lm.dtype
    kv_axes = (None, "batch", "kv_seq", None, None)

    if c.family in ("dense", "moe"):
        shape = (c.n_layers, batch, s_max, kv, hd)
        return {"k": (shape, kv_axes, dt), "v": (shape, kv_axes, dt)}

    if c.family == "ssm":
        return _ssm_cache_tmpl(c, c.n_layers, batch, dt)

    if c.family == "hybrid":
        t = _ssm_cache_tmpl(c, c.n_layers, batch, dt)
        n_app = -(-c.n_layers // c.attn_every)       # ceil — one per group
        shape = (n_app, batch, s_max, kv, hd)
        t["sk"] = (shape, kv_axes, dt)
        t["sv"] = (shape, kv_axes, dt)
        return t

    if c.family == "vlm":
        g, spg = lm.n_groups, lm.self_per_group
        self_shape = (g, spg, batch, s_max, kv, hd)
        self_axes = (None, None, "batch", "kv_seq", None, None)
        x_shape = (g, batch, c.n_img_tokens, kv, hd)
        x_axes = (None, "batch", None, None, None)
        return {"k": (self_shape, self_axes, dt),
                "v": (self_shape, self_axes, dt),
                "xk": (x_shape, x_axes, dt), "xv": (x_shape, x_axes, dt)}

    if c.family == "audio":
        shape = (c.n_layers, batch, s_max, kv, hd)
        x_shape = (c.n_layers, batch, c.enc_frames, kv, hd)
        x_axes = (None, "batch", None, None, None)
        return {"k": (shape, kv_axes, dt), "v": (shape, kv_axes, dt),
                "xk": (x_shape, x_axes, dt), "xv": (x_shape, x_axes, dt)}
    raise ValueError(c.family)


def _ssm_cache_tmpl(c, n_layers, batch, dt):
    di = c.ssm_expand * c.d_model
    n = c.ssm_state
    h = di // c.ssm_head_dim
    k = m2.CONV_K - 1
    return {
        "state": ((n_layers, batch, h, c.ssm_head_dim, n),
                  (None, "batch", "ssm_heads", None, None), jnp.float32),
        "conv_x": ((n_layers, batch, k, di), (None, "batch", None, "mlp"), dt),
        "conv_b": ((n_layers, batch, k, n), (None, "batch", None, None), dt),
        "conv_c": ((n_layers, batch, k, n), (None, "batch", None, None), dt),
    }


def cache_structs(lm: LM, batch: int, s_max: int):
    return {k: jax.ShapeDtypeStruct(sh, d)
            for k, (sh, ax, d) in cache_template(lm, batch, s_max).items()}


def cache_shardings(lm: LM, batch: int, s_max: int, mesh):
    return {k: param_sharding(sh, ax, mesh)
            for k, (sh, ax, d) in cache_template(lm, batch, s_max).items()}


def cache_zeros(lm: LM, batch: int, s_max: int):
    return {k: jnp.zeros(sh, d)
            for k, (sh, ax, d) in cache_template(lm, batch, s_max).items()}


# ---------------------------------------------------------------------------
# decode-time attention sublayer (projection + distributed flash-decode)
# ---------------------------------------------------------------------------

def _decode_attn(lm: LM, x, lp, kc, vc, pos, prefix=""):
    """x (B,1,d) -> (attn_out (B,1,d), kc, vc)."""
    c = lm.cfg
    dt = lm.dtype
    b = x.shape[0]
    q = jnp.einsum("bsd,dhe->bshe", x, lp[prefix + "wq"].astype(dt))
    nk = jnp.einsum("bsd,dke->bske", x, lp[prefix + "wk"].astype(dt))
    nv = jnp.einsum("bsd,dke->bske", x, lp[prefix + "wv"].astype(dt))
    if (prefix + "qk_q") in lp:
        q = head_rms_norm(q, lp[prefix + "qk_q"])
        nk = head_rms_norm(nk, lp[prefix + "qk_k"])
    if getattr(pos, "ndim", 0) == 1:
        positions = pos[:, None]                   # per-slot positions (B,1)
    else:
        positions = jnp.broadcast_to(pos, (b, 1))
    q = rope(q, positions, c.rope_theta)
    nk = rope(nk, positions, c.rope_theta)
    ctx, kc, vc = attn.decode_attention(q, kc, vc, pos,
                                        nk.astype(kc.dtype),
                                        nv.astype(vc.dtype))
    out = jnp.einsum("bshe,hed->bsd", ctx, lp[prefix + "wo"].astype(dt))
    return out, kc, vc


def _decode_cross(lm: LM, x, lp, xk, xv, prefix="x_"):
    """Cross-attention against a static (short) cached memory."""
    dt = lm.dtype
    q = jnp.einsum("bsd,dhe->bshe", x, lp[prefix + "wq"].astype(dt))
    ctx = attn._local_decode(q, xk, xv, jnp.asarray(xk.shape[1] - 1), 0)
    return jnp.einsum("bshe,hed->bsd", ctx, lp[prefix + "wo"].astype(dt))


def _decode_ffn(lm: LM, x, lp):
    c = lm.cfg
    if c.family == "moe":
        y, _ = ffn_mod.moe_ffn(x, lp["router"],
                               lp["w_gate"].astype(lm.dtype),
                               lp["w_up"].astype(lm.dtype),
                               lp["w_down"].astype(lm.dtype),
                               n_experts=c.n_experts, top_k=c.top_k,
                               capacity_factor=c.capacity_factor)
        return y
    if c.family == "audio":
        return lm._gelu_ffn(x, lp)
    if c.drelu_k:
        # D-ReLU structural sparsity: decode down-proj gathers only the k
        # surviving rows of W_down (paper technique, DR-SpMM analogue).
        return ffn_mod.swiglu_ffn_decode_sparse(
            x, lp["w_gate"].astype(lm.dtype), lp["w_up"].astype(lm.dtype),
            lp["w_down"].astype(lm.dtype), c.drelu_k)
    return ffn_mod.swiglu_ffn(x, lp["w_gate"].astype(lm.dtype),
                              lp["w_up"].astype(lm.dtype),
                              lp["w_down"].astype(lm.dtype))


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def prefill(lm: LM, params, tokens, extra: Optional[Dict] = None,
            s_max: Optional[int] = None):
    """Run the full prompt; returns (cache, last-token logits).

    The cache covers [0, s_max); tokens fill positions [0, S).
    """
    c = lm.cfg
    b, s = tokens.shape
    s_max = s_max or s
    assert s_max == s, "prefill cache sized to prompt (pad prompt to s_max)"
    x = lm._embed(params, tokens)

    if c.family in ("dense", "moe"):
        aux0 = jnp.zeros((), jnp.float32)

        def body(carry, lp):
            if c.family == "dense":
                x_, kv = lm._dense_body(carry, lp, kv_out=True)
                return x_, kv
            (x_, aux), kv = lm._moe_body(carry, lp, kv_out=True)
            return (x_, aux), kv

        carry0 = x if c.family == "dense" else (x, aux0)
        carry, kvs = jax.lax.scan(body, carry0, params["layers"])
        x = carry if c.family == "dense" else carry[0]
        cache = {"k": kvs[0], "v": kvs[1]}

    elif c.family == "ssm":
        def body(x_, lp):
            h, cch = m2.mamba2_block(rms_norm(x_, lp["ln"]), lp, c,
                                     mode="prefill")
            return constrain(x_ + h, ("batch", "sp", None)), cch

        x, caches = jax.lax.scan(body, x, params["layers"])
        cache = {"state": caches.state, "conv_x": caches.conv_x,
                 "conv_b": caches.conv_b, "conv_c": caches.conv_c}

    elif c.family == "hybrid":
        cache = _hybrid_prefill_body(lm, params, x)
        x, cache = cache

    elif c.family == "vlm":
        img = extra["image_emb"].astype(lm.dtype)
        grouped = jax.tree.map(
            lambda a: a.reshape((lm.n_groups, lm.self_per_group) + a.shape[1:]),
            params["layers"])

        def self_body(x_, lp):
            x_, kv = lm._dense_body(x_, lp, kv_out=True)
            return x_, kv

        def group(x_, inp):
            slp, clp = inp
            x_, kvs = jax.lax.scan(self_body, x_, slp)
            xk = jnp.einsum("bsd,dke->bske", img, clp["wk"].astype(lm.dtype))
            xv = jnp.einsum("bsd,dke->bske", img, clp["wv"].astype(lm.dtype))
            x_ = lm._cross_body(x_, clp, img)
            return x_, (kvs, (xk.astype(lm.dtype), xv.astype(lm.dtype)))

        x, (kvs, xkvs) = jax.lax.scan(group, x, (grouped, params["cross"]))
        cache = {"k": kvs[0], "v": kvs[1], "xk": xkvs[0], "xv": xkvs[1]}

    elif c.family == "audio":
        enc_out = lm.encode_audio(params, extra["frames"])

        def body(x_, lp):
            x_, (kv, xkv) = lm._dec_body(x_, lp, enc_out, kv_out=True)
            return x_, (kv, xkv)

        x, (kvs, xkvs) = jax.lax.scan(body, x, params["layers"])
        cache = {"k": kvs[0], "v": kvs[1], "xk": xkvs[0], "xv": xkvs[1]}
    else:
        raise ValueError(c.family)

    hidden = rms_norm(x, params["final_norm"])[:, -1:]
    return cache, lm.logits_last(params, hidden)


def _hybrid_prefill_body(lm: LM, params, x):
    c = lm.cfg
    head, tail, n_groups, n_tail = lm._hybrid_split(params["layers"])

    def ssm_body(x_, lp):
        h, cch = m2.mamba2_block(rms_norm(x_, lp["ln"]), lp, c,
                                 mode="prefill")
        return constrain(x_ + h, ("batch", "sp", None)), cch

    def shared_kv(x_):
        sp = {k: v[0] for k, v in params["shared"].items()}
        h, (sk, sv) = attn.attention_block(
            rms_norm(x_, sp["ln1"]), causal_mode=lm.causal_mode,
            return_kv=True, **lm._attn_args(sp))
        x_ = x_ + h
        f = ffn_mod.swiglu_ffn(rms_norm(x_, sp["ln2"]),
                               sp["w_gate"].astype(lm.dtype),
                               sp["w_up"].astype(lm.dtype),
                               sp["w_down"].astype(lm.dtype),
                               drelu_k=c.drelu_k, drelu_groups=lm.tp)
        return constrain(x_ + f, ("batch", "sp", None)), sk, sv

    def group(x_, glp):
        x_, sk, sv = shared_kv(x_)
        x_, cch = jax.lax.scan(ssm_body, x_, glp)
        return x_, (cch, sk, sv)

    x, (cch_head, sks, svs) = jax.lax.scan(group, x, head)
    caches = cch_head
    if n_tail:
        x, sk_t, sv_t = shared_kv(x)
        x, cch_tail = jax.lax.scan(ssm_body, x, tail)
        caches = jax.tree.map(lambda a, b: jnp.concatenate([a.reshape(
            (n_groups * c.attn_every,) + a.shape[2:]), b], 0),
            cch_head, cch_tail)
        sks = jnp.concatenate([sks, sk_t[None]], 0)
        svs = jnp.concatenate([svs, sv_t[None]], 0)
    else:
        caches = jax.tree.map(lambda a: a.reshape(
            (n_groups * c.attn_every,) + a.shape[2:]), cch_head)
    cache = {"state": caches.state, "conv_x": caches.conv_x,
             "conv_b": caches.conv_b, "conv_c": caches.conv_c,
             "sk": sks, "sv": svs}
    return x, cache


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def decode_step(lm: LM, params, cache: Dict, token, pos):
    """One serve step: token (B,1) int32, pos scalar int32.

    Returns (new_cache, logits (B,1,V_pad))."""
    c = lm.cfg
    x = lm._embed(params, token)

    if c.family in ("dense", "moe"):
        def body(x_, inp):
            lp, kc, vc = inp
            h, kc, vc = _decode_attn(lm, rms_norm(x_, lp["ln1"]), lp,
                                     kc, vc, pos)
            x_ = x_ + h
            x_ = x_ + _decode_ffn(lm, rms_norm(x_, lp["ln2"]), lp)
            return x_, (kc, vc)

        x, (ks, vs) = jax.lax.scan(body, x,
                                   (params["layers"], cache["k"], cache["v"]))
        new_cache = {"k": ks, "v": vs}

    elif c.family == "ssm":
        def body(x_, inp):
            lp, st, cx, cb, cc = inp
            h, cch = m2.mamba2_block(
                rms_norm(x_, lp["ln"]), lp, c, mode="decode",
                cache=m2.SSMCache(state=st, conv_x=cx, conv_b=cb, conv_c=cc))
            return x_ + h, cch

        x, cch = jax.lax.scan(body, x, (params["layers"], cache["state"],
                                        cache["conv_x"], cache["conv_b"],
                                        cache["conv_c"]))
        new_cache = {"state": cch.state, "conv_x": cch.conv_x,
                     "conv_b": cch.conv_b, "conv_c": cch.conv_c}

    elif c.family == "hybrid":
        x, new_cache = _hybrid_decode_body(lm, params, cache, x, pos)

    elif c.family == "vlm":
        def self_body(x_, inp):
            lp, kc, vc = inp
            h, kc, vc = _decode_attn(lm, rms_norm(x_, lp["ln1"]), lp,
                                     kc, vc, pos)
            x_ = x_ + h
            x_ = x_ + _decode_ffn(lm, rms_norm(x_, lp["ln2"]), lp)
            return x_, (kc, vc)

        grouped = jax.tree.map(
            lambda a: a.reshape((lm.n_groups, lm.self_per_group) + a.shape[1:]),
            params["layers"])

        def group(x_, inp):
            slp, kc, vc, clp, xk, xv = inp
            x_, (kc, vc) = jax.lax.scan(self_body, x_, (slp, kc, vc))
            h = _decode_cross(lm, rms_norm(x_, clp["ln1"]), clp, xk, xv,
                              prefix="")
            x_ = x_ + jnp.tanh(clp["gate_attn"]).astype(x_.dtype) * h
            f = _decode_ffn(lm, rms_norm(x_, clp["ln2"]), clp)
            x_ = x_ + jnp.tanh(clp["gate_ffn"]).astype(x_.dtype) * f
            return x_, (kc, vc)

        x, (ks, vs) = jax.lax.scan(
            group, x, (grouped, cache["k"], cache["v"], params["cross"],
                       cache["xk"], cache["xv"]))
        new_cache = {"k": ks, "v": vs, "xk": cache["xk"], "xv": cache["xv"]}

    elif c.family == "audio":
        def body(x_, inp):
            lp, kc, vc, xk, xv = inp
            h, kc, vc = _decode_attn(lm, rms_norm(x_, lp["ln1"]), lp,
                                     kc, vc, pos)
            x_ = x_ + h
            x_ = x_ + _decode_cross(lm, rms_norm(x_, lp["ln_x"]), lp, xk, xv)
            x_ = x_ + _decode_ffn(lm, rms_norm(x_, lp["ln2"]), lp)
            return x_, (kc, vc)

        x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                             cache["v"], cache["xk"],
                                             cache["xv"]))
        new_cache = {"k": ks, "v": vs, "xk": cache["xk"], "xv": cache["xv"]}
    else:
        raise ValueError(c.family)

    hidden = rms_norm(x, params["final_norm"])
    return new_cache, lm.logits_last(params, hidden)


def _hybrid_decode_body(lm: LM, params, cache, x, pos):
    c = lm.cfg
    head, tail, n_groups, n_tail = lm._hybrid_split(params["layers"])
    n_full = n_groups * c.attn_every
    sp = {k: v[0] for k, v in params["shared"].items()}

    def ssm_body(x_, inp):
        lp, st, cx, cb, cc = inp
        h, cch = m2.mamba2_block(
            rms_norm(x_, lp["ln"]), lp, c, mode="decode",
            cache=m2.SSMCache(state=st, conv_x=cx, conv_b=cb, conv_c=cc))
        return x_ + h, cch

    def shared(x_, kc, vc):
        h, kc, vc = _decode_attn(lm, rms_norm(x_, sp["ln1"]), sp, kc, vc, pos)
        x_ = x_ + h
        x_ = x_ + _decode_ffn(lm, rms_norm(x_, sp["ln2"]), sp)
        return x_, kc, vc

    ssm_head = jax.tree.map(lambda a: a[:n_full].reshape(
        (n_groups, c.attn_every) + a.shape[1:]),
        {k: cache[k] for k in ("state", "conv_x", "conv_b", "conv_c")})
    ssm_tail = jax.tree.map(lambda a: a[n_full:],
                            {k: cache[k] for k in ("state", "conv_x",
                                                   "conv_b", "conv_c")})

    def group(x_, inp):
        glp, gc, kc, vc = inp
        x_, kc, vc = shared(x_, kc, vc)
        x_, cch = jax.lax.scan(ssm_body, x_, (glp, gc["state"], gc["conv_x"],
                                              gc["conv_b"], gc["conv_c"]))
        return x_, (cch, kc, vc)

    x, (cch_head, sks, svs) = jax.lax.scan(
        group, x, (head, ssm_head, cache["sk"][:n_groups],
                   cache["sv"][:n_groups]))
    flat_head = jax.tree.map(
        lambda a: a.reshape((n_full,) + a.shape[2:]), cch_head)
    if n_tail:
        x, sk_t, sv_t = shared(x, cache["sk"][n_groups], cache["sv"][n_groups])
        x, cch_tail = jax.lax.scan(ssm_body, x,
                                   (tail, ssm_tail["state"],
                                    ssm_tail["conv_x"], ssm_tail["conv_b"],
                                    ssm_tail["conv_c"]))
        merged = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0),
                              flat_head, cch_tail)
        sks = jnp.concatenate([sks, sk_t[None]], 0)
        svs = jnp.concatenate([svs, sv_t[None]], 0)
    else:
        merged = flat_head
    new_cache = {"state": merged.state, "conv_x": merged.conv_x,
                 "conv_b": merged.conv_b, "conv_c": merged.conv_c,
                 "sk": sks, "sv": svs}
    return x, new_cache
