"""Observability: request tracing, metrics registry, profiling hooks.

Zero-dependency.  See DESIGN.md §11 for the trace model and metric naming
scheme.  Quickstart::

    from repro.obs import TraceRecorder, MetricsRegistry

    eng = CircuitServeEngine(model, params, recorder=TraceRecorder())
    ... serve ...
    eng.dump_trace("trace.json")        # open in https://ui.perfetto.dev
    print(eng.metrics_text())           # Prometheus text exposition
"""

from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    DEFAULT_REGISTRY,
    default_registry,
)
from repro.obs.trace import (
    Recorder,
    TraceRecorder,
    NULL_RECORDER,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "DEFAULT_REGISTRY",
    "default_registry",
    "Recorder",
    "TraceRecorder",
    "NULL_RECORDER",
]
