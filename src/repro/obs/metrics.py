"""Metrics registry: counters, gauges, and bounded-reservoir histograms.

The serving/training pipeline accumulated ad-hoc counter dicts as it grew
(the engine's ``_counters``, the trainer's loose attributes, the module-level
``FUSED_DISPATCH_LOG``).  This registry replaces them with one typed,
thread-safe home — the same bounded-state discipline the memory side applies
(LRU layout tables, bounded latency windows) applied to telemetry:

* :class:`Counter` — monotonically increasing float (``inc``);
* :class:`Gauge` — last-write-wins float (``set``);
* :class:`Histogram` — bounded reservoir (deque of the most recent
  ``reservoir`` observations) plus exact ``count``/``sum``/``min``/``max``;
  percentiles (p50/p95/p99) come from the SAME nearest-rank helper the
  benchmarks use (:func:`repro.train.metrics.percentile`), so there is
  exactly one percentile definition in the repo.

Instruments are keyed by ``(name, labels)``: ``registry.counter("x",
device="0")`` and ``registry.counter("x", device="1")`` are two series of
one metric.  Label cardinality is the caller's responsibility — label with
small enums (device slot, edge type, direction), never with request ids.

Naming scheme (DESIGN.md §11): dotted lowercase paths, ``<subsystem>.<what>``
— ``serve.requests``, ``serve.latency_ms``, ``train.step_ms``,
``ops.dispatch``, ``layout.evictions``, ``arena.fill_ratio``.  The
Prometheus writer maps dots to underscores (``serve_latency_ms``).

Two export formats:

* ``snapshot()`` — one JSON-able dict (counters/gauges as numbers,
  histograms as ``{count, sum, min, max, p50, p95, p99}``);
* ``to_prometheus()`` — Prometheus text exposition (``# TYPE`` lines,
  ``name{label="v"} value``; histograms as gauge-typed quantile series
  plus ``_count``/``_sum``), scrapable or diff-able in tests.

``DEFAULT_REGISTRY`` is the module-level registry that context-free emitters
(the ops dispatch counters, the collator's pack-time arena gauges) write
into; engines and trainers own per-instance registries so concurrent
instances never mix series.
"""

from __future__ import annotations

import json
import re
import threading
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

from repro.train.metrics import percentile

# label key/value and metric names kept printable-simple so the Prometheus
# writer never needs escaping beyond quoting
_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")

DEFAULT_RESERVOIR = 4096


class Counter:
    """Monotonic counter (float increments allowed: wall-clock totals)."""

    __slots__ = ("_lock", "_v")

    def __init__(self):
        self._lock = threading.Lock()
        self._v = 0.0

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._v += n

    @property
    def value(self) -> float:
        return self._v


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("_lock", "_v")

    def __init__(self):
        self._lock = threading.Lock()
        self._v = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._v = float(v)

    def add(self, n: float = 1.0) -> None:
        with self._lock:
            self._v += n

    @property
    def value(self) -> float:
        return self._v


class Histogram:
    """Bounded-reservoir distribution: keeps the most recent ``reservoir``
    observations (deque, O(1) per observe) plus exact running aggregates.
    Percentiles are nearest-rank over the reservoir — for a long-lived loop
    that is a sliding window over recent behavior, which is what latency
    SLOs want; ``count``/``sum`` stay exact over the full lifetime."""

    __slots__ = ("_lock", "_window", "count", "sum", "min", "max")

    def __init__(self, reservoir: int = DEFAULT_RESERVOIR):
        self._lock = threading.Lock()
        self._window: Deque[float] = deque(maxlen=reservoir)
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self._window.append(v)
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile over the reservoir window — the one
        percentile definition (train/metrics.py) everything routes
        through."""
        with self._lock:
            window = sorted(self._window)
        return percentile(window, p)

    def percentiles(self, ps=(0.50, 0.95, 0.99)) -> Tuple[float, ...]:
        with self._lock:
            window = sorted(self._window)
        return tuple(percentile(window, p) for p in ps)

    def window(self) -> List[float]:
        """Snapshot of the reservoir in observation order (oldest first) —
        lets callers slice off a phase of observations (e.g. a benchmark's
        steady-state tail) while ``count`` stays within the reservoir."""
        with self._lock:
            return list(self._window)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def summary(self) -> Dict[str, float]:
        p50, p95, p99 = self.percentiles()
        return dict(count=self.count, sum=self.sum,
                    min=self.min if self.count else 0.0,
                    max=self.max if self.count else 0.0,
                    mean=self.mean, p50=p50, p95=p95, p99=p99)


def _labels_key(labels: Dict[str, object]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class MetricsRegistry:
    """Thread-safe get-or-create home for instruments, keyed by
    ``(name, sorted labels)``.

    The getter methods double as the hot-path API: ``counter(...)`` on an
    existing series is one dict lookup, so pipeline code can call
    ``registry.inc("serve.retries")`` without holding its own references
    (though holding one is cheaper still — the engine caches its per-device
    dispatch counters).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[Tuple[str, tuple], object] = {}
        self._kinds: Dict[str, str] = {}      # metric name -> kind

    # ------------------------------------------------------ get-or-create

    def _get(self, cls, kind: str, name: str, labels: dict, **kw):
        key = (name, _labels_key(labels))
        m = self._metrics.get(key)
        if m is not None:
            prev = self._kinds.get(name)
            if prev != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {prev}, "
                    f"requested {kind}")
            return m
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                prev = self._kinds.setdefault(name, kind)
                if prev != kind:
                    raise ValueError(
                        f"metric {name!r} already registered as {prev}, "
                        f"requested {kind}")
                m = self._metrics[key] = cls(**kw)
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, "counter", name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, "gauge", name, labels)

    def histogram(self, name: str, reservoir: int = DEFAULT_RESERVOIR,
                  **labels) -> Histogram:
        return self._get(Histogram, "histogram", name, labels,
                         reservoir=reservoir)

    # ------------------------------------------------------- conveniences

    def inc(self, name: str, n: float = 1.0, **labels) -> None:
        self.counter(name, **labels).inc(n)

    def set(self, name: str, v: float, **labels) -> None:
        self.gauge(name, **labels).set(v)

    def observe(self, name: str, v: float, **labels) -> None:
        self.histogram(name, **labels).observe(v)

    def value(self, name: str, default: float = 0.0, **labels) -> float:
        """Current value of a counter/gauge series (``default`` when the
        series was never touched — reading must not create series)."""
        m = self._metrics.get((name, _labels_key(labels)))
        return default if m is None else m.value

    def series(self, name: str) -> Dict[tuple, object]:
        """Every (labels → instrument) of one metric name."""
        return {k[1]: m for k, m in self._metrics.items() if k[0] == name}

    # ----------------------------------------------------------- exports

    def snapshot(self) -> Dict[str, object]:
        """JSON-able view: ``{name{label="v"}: number-or-summary}``."""
        with self._lock:
            items = list(self._metrics.items())
        out: Dict[str, object] = {}
        for (name, labels), m in sorted(items, key=lambda kv: kv[0]):
            key = name if not labels else (
                name + "{" + ",".join(f'{k}="{v}"' for k, v in labels) + "}")
            if isinstance(m, Histogram):
                out[key] = m.summary()
            else:
                out[key] = m.value
        return out

    def snapshot_json(self, **json_kw) -> str:
        return json.dumps(self.snapshot(), **json_kw)

    def to_prometheus(self) -> str:
        """Prometheus text exposition (format 0.0.4 subset)."""
        with self._lock:
            items = sorted(self._metrics.items(), key=lambda kv: kv[0])
            kinds = dict(self._kinds)
        lines = []
        seen_type = set()
        for (name, labels), m in items:
            pname = _NAME_RE.sub("_", name.replace(".", "_"))
            if pname not in seen_type:
                seen_type.add(pname)
                kind = kinds.get(name, "gauge")
                ptype = {"counter": "counter",
                         "histogram": "summary"}.get(kind, "gauge")
                lines.append(f"# TYPE {pname} {ptype}")
            lab = "" if not labels else (
                "{" + ",".join(f'{k}="{v}"' for k, v in labels) + "}")
            if isinstance(m, Histogram):
                s = m.summary()
                base_lab = [f'{k}="{v}"' for k, v in labels]
                for q, phi in (("p50", "0.5"), ("p95", "0.95"),
                               ("p99", "0.99")):
                    ql = "{" + ",".join(
                        base_lab + [f'quantile="{phi}"']) + "}"
                    lines.append(f"{pname}{ql} {s[q]:.17g}")
                lines.append(f"{pname}_count{lab} {s['count']}")
                lines.append(f"{pname}_sum{lab} {s['sum']:.17g}")
            else:
                lines.append(f"{pname}{lab} {m.value:.17g}")
        return "\n".join(lines) + ("\n" if lines else "")

    def __len__(self) -> int:
        return len(self._metrics)


# Module-level registry for emitters that have no engine/trainer handle:
# the ops-layer dispatch counters and the collator's pack-time arena gauges.
DEFAULT_REGISTRY = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    return DEFAULT_REGISTRY
