"""Structured request tracing with Chrome trace-event export.

One :class:`Recorder` interface, two implementations:

* :data:`NULL_RECORDER` — the default.  Every method is a no-op and
  ``enabled`` is ``False``; emitters guard with ``if rec.enabled:`` so the
  happy path allocates nothing (the same zero-overhead contract the chaos
  harness keeps with ``if self.chaos is not None``).
* :class:`TraceRecorder` — a bounded in-memory event buffer that exports
  the Chrome trace-event JSON format (``{"traceEvents": [...]}``),
  loadable in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``.

Trace model (DESIGN.md §11)
---------------------------

Tracks are named lanes (``tid`` rows under one ``pid``).  The engine uses:

* ``device/<i>`` — one track per ring slot.  Batch work on a slot is
  emitted as **X (complete) events** carrying ``dur``: healing attempts
  (retry/bisect re-dispatches on daemon threads) can overlap the pipeline's
  next batch on the same slot, and X events nest/overlap cleanly where
  B/E pairs would cross.
* ``worker/<i>`` — one track per host-pool prep worker.  Collate +
  device_put spans are **B/E pairs**: a track maps 1:1 onto a thread, so
  pairs are strictly nested per track (tests assert this).
* ``intake`` — submit/admit/shed/deadline-flush **instant** events.
* ``healing`` — retry/bisect/watchdog/quarantine ladder instants.
* ``chaos`` — fault-injection annotations (one instant per injected
  fault, args carrying point/occurrence/device).
* ``layout`` — compile / eviction / recompile instants from the
  LayoutTable and the engine's jit-cache.

Timestamps are ``time.perf_counter()`` microseconds relative to recorder
creation — monotonic, so exported ``ts`` never goes backwards.  The buffer
is bounded (default 2^16 events); past the cap new events are counted in
``dropped`` rather than grown without bound — same discipline as
``FUSED_DISPATCH_LOG``.
"""

from __future__ import annotations

import json
import threading
import time
from typing import Dict, List, Optional

DEFAULT_MAX_EVENTS = 65536

_PID = 1  # single-process tracing; one pid, tracks are tids


class _NullSpan:
    """Reusable no-op context manager — one shared instance, zero alloc."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class Recorder:
    """No-op base recorder.  All emitters hold one of these; the real
    :class:`TraceRecorder` subclasses it.  Guard emission sites with
    ``if rec.enabled:`` — with the base class that branch is the entire
    cost of tracing-off."""

    enabled: bool = False

    def begin(self, track: str, name: str, **args) -> None: ...

    def end(self, track: str, name: str, **args) -> None: ...

    def instant(self, track: str, name: str, **args) -> None: ...

    def complete(self, track: str, name: str, ts_us: float,
                 dur_us: float, **args) -> None: ...

    def span(self, track: str, name: str, **args):
        return _NULL_SPAN

    def now(self) -> float:
        return 0.0

    def export(self) -> Dict[str, object]:
        return {"traceEvents": []}

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.export(), f)


NULL_RECORDER = Recorder()
# Shared reusable null context for `with (rec.span(...) if rec.enabled
# else NULL_SPAN):` guards — zero allocation on the tracing-off path.
NULL_SPAN = _NULL_SPAN


class _Span:
    __slots__ = ("_rec", "_track", "_name", "_args")

    def __init__(self, rec: "TraceRecorder", track: str, name: str, args):
        self._rec, self._track, self._name, self._args = rec, track, name, args

    def __enter__(self):
        self._rec.begin(self._track, self._name, **self._args)
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is None:
            self._rec.end(self._track, self._name)
        else:
            self._rec.end(self._track, self._name, error=exc_type.__name__)
        return False


class TraceRecorder(Recorder):
    """Bounded in-memory trace-event collector.

    Thread-safe: every emit takes one short lock append.  Emitters never
    re-enter the recorder while holding its lock (the recorder calls
    nothing back), so it is safe to call from under engine/injector locks.
    """

    enabled = True

    def __init__(self, max_events: int = DEFAULT_MAX_EVENTS):
        self._lock = threading.Lock()
        self._events: List[dict] = []
        self._max_events = int(max_events)
        self._tids: Dict[str, int] = {}
        self._t0 = time.perf_counter()
        self.dropped = 0

    # ------------------------------------------------------------- clock

    def now(self) -> float:
        """Microseconds since recorder creation (monotonic)."""
        return (time.perf_counter() - self._t0) * 1e6

    # ------------------------------------------------------------- emits

    def _tid(self, track: str) -> int:
        # caller holds self._lock
        tid = self._tids.get(track)
        if tid is None:
            tid = self._tids[track] = len(self._tids) + 1
        return tid

    def _emit(self, track: str, ev: dict) -> None:
        ts = self.now()
        with self._lock:
            if len(self._events) >= self._max_events:
                self.dropped += 1
                return
            ev["pid"] = _PID
            ev["tid"] = self._tid(track)
            ev.setdefault("ts", ts)
            self._events.append(ev)

    def begin(self, track: str, name: str, **args) -> None:
        ev = {"ph": "B", "name": name, "cat": track}
        if args:
            ev["args"] = args
        self._emit(track, ev)

    def end(self, track: str, name: str, **args) -> None:
        ev = {"ph": "E", "name": name, "cat": track}
        if args:
            ev["args"] = args
        self._emit(track, ev)

    def instant(self, track: str, name: str, **args) -> None:
        ev = {"ph": "i", "s": "t", "name": name, "cat": track}
        if args:
            ev["args"] = args
        self._emit(track, ev)

    def complete(self, track: str, name: str, ts_us: float,
                 dur_us: float, **args) -> None:
        """X event with explicit start/duration — for slot-track work whose
        start time the caller measured (dispatch attempts may overlap on
        one track, which B/E pairs cannot express)."""
        ev = {"ph": "X", "name": name, "cat": track,
              "ts": float(ts_us), "dur": max(0.0, float(dur_us))}
        if args:
            ev["args"] = args
        self._emit(track, ev)

    def span(self, track: str, name: str, **args):
        """``with rec.span("worker/0", "collate", bucket=sig): ...`` —
        emits a B at entry and an E at exit (annotated on exception)."""
        return _Span(self, track, name, args)

    # ------------------------------------------------------------ export

    def export(self) -> Dict[str, object]:
        """Chrome trace-event JSON: metadata (process/thread names) first,
        then all events sorted by ``ts``."""
        with self._lock:
            events = [dict(e) for e in self._events]
            tids = dict(self._tids)
            dropped = self.dropped
        meta: List[dict] = [{
            "ph": "M", "name": "process_name", "pid": _PID, "tid": 0,
            "args": {"name": "repro-circuit-serve"},
        }]
        for track, tid in sorted(tids.items(), key=lambda kv: kv[1]):
            meta.append({"ph": "M", "name": "thread_name",
                         "pid": _PID, "tid": tid, "args": {"name": track}})
        events.sort(key=lambda e: (e["ts"], 0 if e["ph"] == "B" else 1))
        out: Dict[str, object] = {"traceEvents": meta + events,
                                  "displayTimeUnit": "ms"}
        if dropped:
            out["otherData"] = {"dropped_events": dropped}
        return out

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.export(), f)

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)
