from repro.optim.adamw import AdamWState, adamw_init, adamw_update  # noqa: F401
from repro.optim.schedules import constant, cosine, wsd  # noqa: F401
