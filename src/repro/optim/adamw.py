"""Minimal, shardable AdamW (pure pytree — no optax dependency).

Optimizer state mirrors the parameter pytree leaf-for-leaf, so any sharding
applied to params transfers to m/v verbatim — this is what lets the dry-run
lower the optimizer over the production mesh without extra spec plumbing.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # scalar int32
    m: Any                   # first moment, like params
    v: Any                   # second moment, like params


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def adamw_update(params, grads, state: AdamWState, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.0, grad_clip: float = 0.0):
    """One AdamW step.  ``lr`` may be a scalar array (schedule output)."""
    step = state.step + 1
    if grad_clip > 0.0:
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                     state.m, grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2)
                     * jnp.square(g.astype(jnp.float32)), state.v, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m_, v_):
        mh = m_ / bc1
        vh = v_ / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, AdamWState(step=step, m=m, v=v)
