"""Gradient compression for slow links (inter-pod DP sync).

``int8_allreduce_sum`` quantizes a tensor to int8 with a shared per-tensor
scale, sums across an axis in int32 (exact), and dequantizes — cutting the
bytes on the wire ~4× (f32) / ~2× (bf16) at ~0.4% relative error.

``compressed_pod_psum`` applies it to a gradient pytree across the ``pod``
mesh axis inside shard_map: intra-pod reduction stays full-precision (fast
ICI), only the pod-crossing traffic is compressed — the standard hierarchy
used by large-cluster DP.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_int8(x: jax.Array, axis_name: str | None = None):
    """Symmetric per-tensor int8 quantization; scale is pmax'd across the
    reduction axis so every participant uses the same grid."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    if axis_name is not None:
        amax = jax.lax.pmax(amax, axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def int8_allreduce_sum(x: jax.Array, axis_name: str) -> jax.Array:
    """Compressed psum: int8 on the wire, int32 accumulation (exact sum of
    quantized values — no overflow for ≤ 2^23 participants)."""
    q, scale = quantize_int8(x, axis_name)
    s = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return (s.astype(jnp.float32) * scale).astype(x.dtype)


def compressed_pod_psum(grads: Any, mesh) -> Any:
    """Mean-reduce a gradient pytree across the ``pod`` axis with int8
    compression.  Gradients enter already reduced over data/model (XLA's
    automatic partial sums within a pod when the batch also shards over
    'pod' would normally fold this in — using this path, the batch shards
    over 'pod' too, and we take over the pod-level reduction explicitly)."""
    if "pod" not in mesh.axis_names:
        return grads
    n_pod = mesh.shape["pod"]

    def one(g):
        spec = P(*([None] * g.ndim))

        from repro.sharding.specs import shard_map_compat

        @shard_map_compat(mesh=mesh,
                          in_specs=spec, out_specs=spec, check_vma=False)
        def ar(g_l):
            return int8_allreduce_sum(g_l, "pod") / n_pod

        return ar(g)

    return jax.tree.map(one, grads)
