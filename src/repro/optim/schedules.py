"""LR schedules.  WSD (warmup-stable-decay) is required by the minicpm-2b
assigned architecture [arXiv:2404.06395]."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine(lr: float, total_steps: int, warmup: int = 0, min_ratio: float = 0.1):
    def f(step):
        step = step.astype(jnp.float32)
        # warmup reaches lr at `warmup`, starting ABOVE zero at step 0
        # (an lr of exactly 0 makes the first optimizer step a no-op)
        warm = jnp.minimum((step + 1.0) / jnp.maximum(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / jnp.maximum(total_steps - warmup, 1), 0, 1)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * warm * cos
    return f


def wsd(lr: float, total_steps: int, warmup_frac: float = 0.01,
        decay_frac: float = 0.1, min_ratio: float = 0.1):
    """Warmup-Stable-Decay: linear warmup, flat plateau, sharp final decay."""
    warmup = max(int(total_steps * warmup_frac), 1)
    decay_start = int(total_steps * (1.0 - decay_frac))

    def f(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum((step + 1.0) / warmup, 1.0)
        t = jnp.clip((step - decay_start) / jnp.maximum(total_steps - decay_start, 1),
                     0.0, 1.0)
        decay = 1.0 - (1.0 - min_ratio) * t
        return lr * warm * decay
    return f
