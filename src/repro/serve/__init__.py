from repro.serve.engine import Request, ServeEngine  # noqa: F401
from repro.serve.circuit_engine import (CircuitRequest,  # noqa: F401
                                        CircuitServeEngine, percentile,
                                        QueueFullError, LoadShedError,
                                        WatchdogTimeoutError,
                                        NonFiniteInputError,
                                        NonFiniteOutputError)
from repro.obs import (MetricsRegistry, Recorder,  # noqa: F401
                       TraceRecorder)
