"""Circuit serve engine: compile-once batched HGNN congestion inference.

The LM engine (serve/engine.py) batches *tokens* into fixed slots; circuit
graphs have no such fixed shape, so this engine batches *graphs* via
block-diagonal collation (graphs/collate.py) instead:

* **request queue** — each request is one packed :class:`CircuitGraph`;
* **micro-batcher** — the FIFO head defines a shape bucket (quantized node
  counts + feature widths); the queue is scanned for up to ``max_batch``
  bucket-compatible requests, which collate into ONE padded graph and ONE
  fused-executor dispatch.  Partial batches are filled with replicas of the
  last member (inert: filler outputs are dropped) so member count never
  splits the compile cache;
* **executor cache** — the jitted forward takes the collated graph as a
  *traced argument*; its compile cache is keyed by the padded shape
  signature, so a mixed-size stream compiles once per bucket, not once per
  graph (the HOGA-motivated property).  The engine counts distinct
  signatures as ``compiles`` and asserts them against jit's own cache when
  available;
* **packing pool** — ``core.parallel.prefetch`` packs/pads/``device_put``s
  batch i+1 on host threads while batch i runs on device — the paper's
  CPU-thread + stream overlap (Sec. 3.4) at batch granularity.

Throughput/latency stats (graphs/s, p50/p95 ms, compiles) are kept per run
for benchmarks/bench_serve_circuit.py.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Union

import numpy as np
import jax

from repro.core.hetero_mp import HeteroMPConfig
from repro.core.parallel import prefetch
from repro.graphs.circuit import CircuitGraph
from repro.graphs.collate import (ARENA_GRID_BITS, BucketLayout,
                                  collate_graphs, quantize_up)
from repro.models.hgnn import drcircuitgnn_forward


def percentile(sorted_values, p: float) -> float:
    """Nearest-rank percentile of an ascending list (0 for empty input).
    Shared by the engine's stats and benchmarks/bench_serve_circuit.py."""
    if not sorted_values:
        return 0.0
    i = min(int(p * (len(sorted_values) - 1)), len(sorted_values) - 1)
    return sorted_values[i]


@dataclasses.dataclass
class CircuitRequest:
    rid: int
    graph: CircuitGraph
    t_submit: float
    t_done: float = 0.0
    pred: Optional[np.ndarray] = None     # (n_cell,) congestion in [0, 1]

    @property
    def latency_ms(self) -> float:
        return (self.t_done - self.t_submit) * 1e3


class CircuitServeEngine:
    """Micro-batching congestion-prediction server over a fixed model."""

    # Serving wants FEW shape buckets more than tight padding: one mantissa
    # bit (grid {2^e, 3·2^(e-1)}) collapses a size class with ±10% jitter
    # into one bucket at ≤50% worst-case node padding.  Training keeps the
    # finer NODE_GRID_BITS default — its batch membership is fixed, so
    # signatures are stable regardless.
    SERVE_NODE_BITS = 1

    def __init__(self, params, mp_cfg: HeteroMPConfig, *,
                 max_batch: int = 8,
                 n_pack_threads: int = 3,
                 node_bits: int = SERVE_NODE_BITS,
                 arena_bits: int = ARENA_GRID_BITS,
                 chunk: Union[None, int, Dict[str, int]] = None,
                 pad_to_full: bool = True):
        self.params = params
        self.mp_cfg = mp_cfg
        self.b = max_batch
        self.n_pack_threads = n_pack_threads
        self.node_bits = node_bits
        self.arena_bits = arena_bits
        self.chunk = chunk
        self.pad_to_full = pad_to_full
        self.queue: Deque[CircuitRequest] = deque()
        self.finished: Dict[int, CircuitRequest] = {}
        self._rid = itertools.count()
        self._seen_sigs = set()
        self._layouts: Dict[tuple, BucketLayout] = {}
        self._bucket_locks: Dict[tuple, threading.Lock] = {}
        self._layout_lock = threading.Lock()     # guards the two dicts
        self._counters = dict(batches=0, requests=0, real_cells=0,
                              padded_cells=0, wall_s=0.0)
        self._fwd = jax.jit(
            lambda p, g: drcircuitgnn_forward(p, g, mp_cfg))

    # ------------------------------------------------------------- intake

    def submit(self, graph: CircuitGraph) -> int:
        rid = next(self._rid)
        self.queue.append(CircuitRequest(rid=rid, graph=graph,
                                         t_submit=time.perf_counter()))
        return rid

    def _group_key(self, g: CircuitGraph) -> tuple:
        """Per-request shape bucket: requests sharing it collate into one
        signature-stable batch."""
        return (quantize_up(g.n_cell, self.node_bits),
                quantize_up(g.n_net, self.node_bits),
                g.x_cell.shape[1], g.x_net.shape[1])

    def _take_batch(self) -> Optional[List[CircuitRequest]]:
        """Micro-batcher: FIFO head defines the bucket; scan the queue for
        up to ``max_batch`` bucket-compatible requests (others keep their
        positions)."""
        if not self.queue:
            return None
        key = self._group_key(self.queue[0].graph)
        batch: List[CircuitRequest] = []
        # Rotate the deque in place (never rebind self.queue): a submit()
        # from another thread during the scan appends to the live deque and
        # cannot be lost.  Non-matching requests keep their relative order.
        for _ in range(len(self.queue)):
            r = self.queue.popleft()
            if len(batch) < self.b and self._group_key(r.graph) == key:
                batch.append(r)
            else:
                self.queue.append(r)
        return batch

    # ---------------------------------------------------------- pipeline

    def _prepare(self, reqs: List[CircuitRequest]):
        """Host side (runs on the packing pool): collate, pad, transfer."""
        graphs = [r.graph for r in reqs]
        n_real = len(graphs)
        if self.pad_to_full and n_real < self.b:
            # replicate the last member as filler so partial batches keep
            # the full-batch signature (outputs dropped, loss weight zero)
            graphs = graphs + [graphs[-1]] * (self.b - n_real)
        # The bucket layout pins chunk widths and floors chunk counts so
        # same-bucket batches share a signature.  Locking is per bucket:
        # prepares of different buckets (the common in-flight pair for an
        # interleaved stream) pack concurrently; only the rare same-bucket
        # pair serializes on its layout.
        key = self._group_key(reqs[0].graph)
        with self._layout_lock:
            layout = self._layouts.setdefault(key, BucketLayout())
            lock = self._bucket_locks.setdefault(key, threading.Lock())
        with lock:
            batch = collate_graphs(graphs, fused=True, quantize=True,
                                   node_bits=self.node_bits,
                                   arena_bits=self.arena_bits,
                                   chunk=self.chunk, layout=layout,
                                   n_real=n_real)
        graph = jax.device_put(batch.graph)
        return reqs, batch, graph

    def _dispatch(self, prepared):
        reqs, batch, graph = prepared
        sig = batch.signature
        if sig not in self._seen_sigs:
            self._seen_sigs.add(sig)
        out = self._fwd(self.params, graph)         # async dispatch
        return reqs, batch, out

    def _complete(self, inflight):
        reqs, batch, out = inflight
        preds = np.asarray(out)                     # device barrier
        now = time.perf_counter()
        for r, m in zip(reqs, batch.members):
            r.pred = preds[m.cell_off:m.cell_off + m.n_cell]
            r.t_done = now
            self.finished[r.rid] = r
        c = self._counters
        c["batches"] += 1
        c["requests"] += len(reqs)
        c["real_cells"] += sum(m.n_cell for m in batch.members[:batch.n_real])
        c["padded_cells"] += batch.graph.n_cell

    def run(self) -> Dict[int, CircuitRequest]:
        """Drain the queue: collate-compatible micro-batches flow through a
        prefetch pipeline — the pool packs batch i+1 while the device runs
        batch i, and batch i+1 is dispatched before batch i's results are
        fetched (two batches in flight)."""
        batches = []
        while self.queue:
            batches.append(self._take_batch())
        t0 = time.perf_counter()
        inflight = None
        for prepared in prefetch(batches, self._prepare,
                                 n_threads=self.n_pack_threads):
            nxt = self._dispatch(prepared)
            if inflight is not None:
                self._complete(inflight)
            inflight = nxt
        if inflight is not None:
            self._complete(inflight)
        self._counters["wall_s"] += time.perf_counter() - t0
        return self.finished

    # ------------------------------------------------------------- stats

    @property
    def compiles(self) -> int:
        """Distinct padded-shape signatures dispatched (== jit compiles of
        the forward; cross-checked in stats() when jit exposes its cache)."""
        return len(self._seen_sigs)

    def stats(self) -> Dict[str, float]:
        lat = sorted(r.latency_ms for r in self.finished.values())
        c = self._counters
        out = dict(requests=c["requests"], batches=c["batches"],
                   compiles=self.compiles,
                   graphs_per_s=c["requests"] / max(c["wall_s"], 1e-9),
                   p50_ms=percentile(lat, 0.50), p95_ms=percentile(lat, 0.95),
                   wall_s=c["wall_s"],
                   cell_padding_ratio=(c["padded_cells"]
                                       / max(c["real_cells"], 1)))
        cache_size = getattr(self._fwd, "_cache_size", None)
        if callable(cache_size):
            out["jit_cache_size"] = cache_size()
        return out
