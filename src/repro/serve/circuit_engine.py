"""Circuit serve engine: online multi-device batched HGNN congestion
inference.

The LM engine (serve/engine.py) batches *tokens* into fixed slots; circuit
graphs have no such fixed shape, so this engine batches *graphs* via
block-diagonal collation (graphs/collate.py) instead:

* **continuous intake** — ``submit()`` is thread-safe and legal while
  ``serve_forever()`` is running: producers append to the live queue under
  the engine lock and wake the serving loop;
* **deadline-aware micro-batcher** — requests group into shape buckets
  (quantized node counts + feature widths), FIFO within a bucket.  The
  first bucket to reach ``max_batch`` compatible requests dispatches as a
  full batch; a partial bucket closes when its oldest request has waited
  ``max_wait_ms`` (filler-padding — inert replicas of the last member —
  happens only at that deadline, so full batches never pay padding and
  partial batches never starve);
* **bucket eviction** — per-bucket compiled-layout state (the
  :class:`~repro.graphs.collate.BucketLayout` that pins arena chunk widths
  and floors chunk counts, plus the bucket's own jitted forward and its
  compiled executables) lives in an LRU :class:`LayoutTable` bounded by
  ``max_live_buckets``: a long tail of one-off shapes evicts cold buckets
  instead of growing host+device memory without bound.  An evicted bucket
  that returns recompiles at most once;
* **multi-device routing** — bucket-compatible micro-batches are routed
  round-robin onto the replica devices of the active mesh (or every local
  device) via :class:`~repro.sharding.specs.DeviceRing`: independent
  collated batches are embarrassingly parallel, so N devices give N
  concurrent dispatches, each compiled once per (bucket, device);
* **executor cache** — each bucket owns a jitted forward taking the
  collated graph as a *traced argument*; a mixed-size stream compiles once
  per (bucket, device), not once per graph (the HOGA-motivated property).
  ``compiles`` counts first-dispatches of (signature, device) pairs,
  cumulative across evictions, and ``stats()`` cross-checks the live count
  against jit's own caches when available;
* **packing pool** — host threads collate/pad/``device_put`` upcoming
  batches while devices execute the current ones, one batch in flight per
  device (``core.parallel.prefetch`` in drain mode; an equivalent explicit
  pipeline in the online loop) — the paper's CPU-thread + stream overlap
  (Sec. 3.4) at batch granularity;
* **params hot-swap** — ``update_params()`` commits fresh per-device
  replicas between batches (in-flight batches finish on the old weights);
  every request records the ``params_version`` that served it — the
  train-then-serve loop without a restart or a recompile;
* **multi-tenant head registry** — ``register_head(name, head_w, head_b)``
  installs named per-task output heads sharing the ONE backbone:
  ``submit(graph, head="congestion_v2")`` selects a head per request.  The
  bucket's jitted forward takes the head weights as *traced arguments*
  (same shapes for every head), so N heads share each bucket's single
  compiled executable — head registration and selection cost ZERO extra
  compiles (tests/test_backbone.py pins it).  Batches group by
  (shape bucket, head) — a batch is head-homogeneous — while layouts,
  compile caches, and the ``compiles`` counter stay keyed by shape bucket
  alone.  ``head=None`` (default) serves the committed params' own head,
  so ``update_params()`` interacts unchanged;
* **self-healing containment ladder** (DESIGN.md §10) — a failed batch is
  retried with exponential backoff on a freshly-routed device
  (``max_retries``); a batch that keeps failing is *bisected* so only the
  poison member errors while every healthy member is re-served with
  results bit-identical to a fault-free run; device-attributable failures
  feed the :class:`~repro.sharding.specs.DeviceRing` health state
  (K-consecutive-failure quarantine, periodic probe re-admission); an
  optional ``watchdog_s`` bounds per-attempt wall-clock so a wedged
  dispatch becomes a timed-out request instead of a hung ``result()``;
* **admission control** — ``max_queue`` bounds the intake queue with a
  pluggable ``admission`` policy: ``"block"`` (backpressure the producer),
  ``"reject"`` (raise :class:`QueueFullError` promptly), or
  ``"shed_oldest"`` (evict the FIFO head with :class:`LoadShedError`);
  ``validate_inputs`` rejects NaN/Inf-feature graphs at ``submit()`` and a
  non-finite output guard fails poisoned predictions with diagnostics;
* **chaos hook** — pass ``chaos=FaultInjector(...)``
  (fault/inject.py) to exercise every injection point under a
  deterministic seed; ``chaos=None`` (default) executes no injection code;
* **observability** (DESIGN.md §11) — every counter/latency stat lives in a
  per-engine :class:`~repro.obs.metrics.MetricsRegistry` (``stats()`` is a
  back-compat view; ``metrics_text()`` is Prometheus exposition), and
  passing ``recorder=TraceRecorder()`` traces every request through
  submit → admit/shed → bucket → collate → device_put → dispatch → commit
  — healing-ladder steps and chaos injections included — exportable as
  Chrome trace-event JSON (``dump_trace(path)``, perfetto-loadable).  The
  default no-op recorder keeps the happy path allocation-free.

Collated batches also carry a :class:`~repro.graphs.ell.RelationPlan`
(``collate_graphs(with_plan=True)``, the default), so each hetero layer of
the batched forward runs as ONE dispatch per direction-group instead of one
per edge type (DESIGN.md §9); plan layouts are pinned per bucket in the
same ``BucketLayout`` as the per-edge-type arenas.

Two serving modes share the pipeline:

* ``run()`` — drain a snapshot of the queue (partial batches flush
  immediately), the PR-2 batch interface;
* ``serve_forever()`` — block the calling thread serving submits as they
  arrive until ``stop()`` (which drains) or, with ``stop_when_idle=True``,
  until the queue and pipeline are empty.  Typical online use::

      eng = CircuitServeEngine(params, cfg, max_wait_ms=20.0,
                               max_live_buckets=32)
      t = threading.Thread(target=eng.serve_forever)
      t.start()
      rid = eng.submit(graph)               # any thread, any time
      pred = eng.result(rid, timeout=5.0).pred
      eng.stop(); t.join()

Throughput/latency stats (graphs/s, p50/p95 ms, compiles, evictions,
per-device dispatch counts) are kept per run for
benchmarks/bench_serve_circuit.py.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Deque, Dict, List, Optional, Sequence, Union

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.hetero_mp import HeteroMPConfig
from repro.core.parallel import prefetch
from repro.fault.inject import FaultInjector, InjectedFault
from repro.graphs.circuit import CircuitGraph
from repro.graphs.collate import (ARENA_GRID_BITS, LayoutTable,
                                  collate_graphs, quantize_up)
from repro.models.backbone import BackboneSpec
from repro.models.hgnn import drcircuitgnn_forward
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_RECORDER, NULL_SPAN, Recorder
from repro.sharding.specs import DeviceRing
# Back-compat re-export: percentile lived here through PR 2; it is now a
# train.metrics helper so benchmarks don't import the engine for stats.
from repro.train.metrics import percentile  # noqa: F401


class QueueFullError(RuntimeError):
    """submit() under ``admission="reject"`` with the queue at capacity."""


class LoadShedError(RuntimeError):
    """Request evicted by ``admission="shed_oldest"`` to admit a newer one;
    its ``result()`` re-raises with this cause."""


class WatchdogTimeoutError(RuntimeError):
    """Batch attempt exceeded ``watchdog_s``; its requests are failed so
    ``result()`` returns instead of hanging on a wedged dispatch."""


class NonFiniteInputError(ValueError):
    """submit() rejected a graph whose features contain NaN/Inf."""


class NonFiniteOutputError(RuntimeError):
    """The output guard found NaN/Inf in a member's prediction (poisoned
    input that slipped validation, or an unhealthy kernel/device)."""


@dataclasses.dataclass
class CircuitRequest:
    rid: int
    graph: CircuitGraph
    t_submit: float
    t_done: float = 0.0
    pred: Optional[np.ndarray] = None     # (n_cell,) congestion in [0, 1]
    key: Optional[tuple] = None           # shape bucket, stamped by submit()
    # which registered head serves this request; None = the committed
    # params' own head.  Batching is head-homogeneous (the grouping key is
    # (key, head)) but compilation is not: every head shares the bucket's
    # one executable.
    head: Optional[str] = None
    error: Optional[BaseException] = None  # set when the batch failed
    # which params generation served this request (update_params bumps it);
    # stamped at dispatch, so callers can tell pre- from post-swap results
    params_version: int = 0
    # finalized: result committed (pred or error).  The containment ladder
    # may abandon a wedged attempt whose orphaned thread finishes later —
    # the flag makes the first commit win and every later one a no-op.
    final: bool = False

    @property
    def latency_ms(self) -> float:
        return (self.t_done - self.t_submit) * 1e3


@dataclasses.dataclass
class _BucketState:
    """Engine-side per-bucket derived state, dropped as ONE unit by the
    eviction hook (new per-bucket fields belong here, not in a sibling
    dict, so they cannot leak past max_live_buckets)."""
    lock: threading.Lock = dataclasses.field(default_factory=threading.Lock)
    fwd: Optional[object] = None          # the bucket's jitted forward
    sigs: set = dataclasses.field(default_factory=set)  # live (sig, dev)


# sentinel boxed through run()'s prefetch pipeline so a failed prepare
# reaches the containment ladder instead of killing the iterator
_PREP_FAILED = object()

# Per-thread trace track names for the host-side packing spans: a track
# maps 1:1 onto a thread, so B/E prepare spans never interleave within a
# track (the trace validator asserts matched pairs per track).  Pool
# workers and healer threads alike get "worker/<k>" on first emission.
_track_local = threading.local()
_track_counter = itertools.count()


def _worker_track() -> str:
    name = getattr(_track_local, "name", None)
    if name is None:
        name = _track_local.name = f"worker/{next(_track_counter)}"
    return name


class CircuitServeEngine:
    """Micro-batching congestion-prediction server over a fixed model."""

    # Serving wants FEW shape buckets more than tight padding: one mantissa
    # bit (grid {2^e, 3·2^(e-1)}) collapses a size class with ±10% jitter
    # into one bucket at ≤50% worst-case node padding.  Training keeps the
    # finer NODE_GRID_BITS default — its batch membership is fixed, so
    # signatures are stable regardless.
    SERVE_NODE_BITS = 1

    def __init__(self, params, mp_cfg: HeteroMPConfig, *,
                 spec: Optional[BackboneSpec] = None,
                 max_batch: int = 8,
                 n_pack_threads: int = 3,
                 node_bits: int = SERVE_NODE_BITS,
                 arena_bits: int = ARENA_GRID_BITS,
                 chunk: Union[None, int, Dict[str, int]] = None,
                 pad_to_full: bool = True,
                 max_wait_ms: float = 50.0,
                 max_live_buckets: Optional[int] = None,
                 max_finished: Optional[int] = None,
                 devices: Optional[Sequence] = None,
                 # --- self-healing / admission (DESIGN.md §10) ---
                 max_retries: int = 2,
                 retry_backoff_s: float = 0.02,
                 watchdog_s: Optional[float] = None,
                 max_queue: Optional[int] = None,
                 admission: str = "block",
                 validate_inputs: bool = True,
                 quarantine_after: int = 3,
                 probe_interval_s: float = 1.0,
                 chaos: Optional[FaultInjector] = None,
                 # --- observability (DESIGN.md §11) ---
                 recorder: Optional[Recorder] = None,
                 registry: Optional[MetricsRegistry] = None):
        if admission not in ("block", "reject", "shed_oldest"):
            raise ValueError(f"unknown admission policy {admission!r}")
        self.mp_cfg = mp_cfg
        # backbone spec (wiring/remat/depth — DESIGN.md §13); None keeps
        # the vanilla plain stack derived from the params themselves
        self.spec = spec
        self.b = max_batch
        self.n_pack_threads = n_pack_threads
        self.node_bits = node_bits
        self.arena_bits = arena_bits
        self.chunk = chunk
        self.pad_to_full = pad_to_full
        self.max_wait_ms = max_wait_ms
        # Bound on retained results: a long-lived loop whose clients never
        # collect would otherwise pin every request's graph + prediction
        # forever.  None keeps everything (the run()-and-read-back pattern);
        # online clients should either set it or result(..., pop=True).
        self.max_finished = max_finished
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.watchdog_s = watchdog_s
        self.max_queue = max_queue
        self.admission = admission
        self.validate_inputs = validate_inputs
        self.chaos = chaos
        self.ring = DeviceRing(devices, quarantine_after=quarantine_after,
                               probe_interval_s=probe_interval_s)
        self.params = params
        # one committed replica per ring device: a dispatch's placement
        # follows its (committed) arguments, so batch routing is just
        # "device_put the batch to slot i, call with replica i"
        self._params_of = tuple(jax.device_put(params, d)
                                for d in self.ring.devices)
        self._params_version = 0
        # head registry: name -> per-ring-slot (head_w, head_b) replicas,
        # committed like _params_of so a dispatch just indexes its slot
        self._heads: Dict[str, tuple] = {}
        self.queue: Deque[CircuitRequest] = deque()
        self.finished: Dict[int, CircuitRequest] = {}
        self._rid = itertools.count()
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)   # submit/prep/stop
        self._done = threading.Condition(self._lock)   # result() waiters
        self._stop = False
        self._serving = False
        # --- observability: per-engine metrics registry + trace recorder.
        # The recorder defaults to the shared no-op (enabled=False), so the
        # happy path's entire tracing cost is dead `if rec.enabled` checks;
        # pass obs.TraceRecorder() to capture a Chrome trace (dump_trace).
        self.metrics = registry if registry is not None else MetricsRegistry()
        self._rec = recorder if recorder is not None else NULL_RECORDER
        if self.chaos is not None and self._rec.enabled:
            # injected faults become annotated instants on the chaos track
            self.chaos.recorder = self._rec
        # Counter handles cached once (get-or-create is lock-free only on
        # the hit path; the hot path should be a plain .inc()).  These
        # replace the PR-2..6 ad-hoc `_counters` dict; stats() rebuilds the
        # same keys from the registry.
        m = self.metrics
        self._c = {name: m.counter("serve." + name) for name in (
            "batches", "requests", "real_cells", "padded_cells", "wall_s",
            "deadline_flushes", "failures", "retries", "bisects",
            "watchdog_timeouts", "nonfinite_outputs", "rejected_inputs",
            "admission_blocked", "admission_rejected", "admission_shed")}
        self._disp = [m.counter("serve.dispatches", device=i)
                      for i in range(len(self.ring))]
        # latency stats live in their own bounded reservoir so trimming
        # `finished` (max_finished / result(pop=True)) can't skew them
        self._lat = m.histogram("serve.latency_ms")
        # Per-bucket state, all evicted together by the LayoutTable LRU:
        # the arena layout (the table's value) plus the engine-side
        # _BucketState — pack lock, the bucket's jitted forward (owning its
        # compile cache; dropping it is what releases the executables), and
        # its live (signature, device) set.
        self._layouts = LayoutTable(max_live=max_live_buckets,
                                    on_evict=self._evict_bucket,
                                    metrics=m, recorder=self._rec)
        self._buckets: Dict[tuple, _BucketState] = {}
        self._n_compiles = 0        # cumulative, incl. eviction recompiles
        self._healing = 0           # containment-ladder batches in flight

    def _make_fwd(self):
        """The bucket's jitted forward.  The head weights ride as TRACED
        arguments (not baked into the closure): every registered head has
        the shapes of ``params.head_w``/``head_b``, so selecting a head
        changes only argument *values* — the (signature, device) executable
        is shared by all heads and by the default, and head selection can
        never trigger a compile."""
        cfg, spec = self.mp_cfg, self.spec
        return jax.jit(lambda p, hw, hb, g: drcircuitgnn_forward(
            p._replace(head_w=hw, head_b=hb), g, cfg, spec))

    # ------------------------------------------------------------- intake

    def submit(self, graph: CircuitGraph,
               timeout: Optional[float] = None, *,
               head: Optional[str] = None) -> int:
        """Enqueue one request; thread-safe, legal while serve_forever()
        runs (the serving loop is woken immediately).

        ``head`` selects a registered per-task output head by name
        (:meth:`register_head`); ``None`` serves the committed params' own
        head.  An unregistered name raises ``KeyError`` here, at the door.

        With ``max_queue`` set, admission is policy-dependent when the
        queue is full: ``"block"`` waits for capacity (up to ``timeout``,
        raising :class:`TimeoutError`) — backpressure on the producer;
        ``"reject"`` raises :class:`QueueFullError` promptly;
        ``"shed_oldest"`` evicts the FIFO head (its ``result()`` re-raises
        :class:`LoadShedError`) and admits the newcomer.  With
        ``validate_inputs`` (default), NaN/Inf-feature graphs raise
        :class:`NonFiniteInputError` here instead of poisoning a batch."""
        if head is not None and head not in self._heads:
            raise KeyError(f"unknown head {head!r}; registered heads: "
                           f"{sorted(self._heads)}")
        if self.validate_inputs:
            self._validate(graph)
        rid = next(self._rid)
        # bucket key stamped once here, so the batcher's queue scans don't
        # recompute it under the engine lock on every wake
        req = CircuitRequest(rid=rid, graph=graph,
                             t_submit=time.perf_counter(),
                             key=self._group_key(graph), head=head)
        deadline = None if timeout is None else time.perf_counter() + timeout
        with self._work:
            if self.max_queue is not None and \
                    len(self.queue) >= self.max_queue:
                if self.admission == "reject":
                    self._c["admission_rejected"].inc()
                    if self._rec.enabled:
                        self._rec.instant("intake", "admission_reject",
                                          rid=rid)
                    raise QueueFullError(
                        f"queue at capacity ({self.max_queue}); request "
                        f"rejected (admission='reject')")
                if self.admission == "shed_oldest":
                    while len(self.queue) >= self.max_queue:
                        head = self.queue.popleft()
                        self._c["admission_shed"].inc()
                        if self._rec.enabled:
                            self._rec.instant("intake", "admission_shed",
                                              rid=head.rid, admitted=rid)
                        self._finalize_failed_locked(
                            [head], LoadShedError(
                                f"request {head.rid} shed (FIFO head) to "
                                f"admit request {rid} under "
                                f"admission='shed_oldest'"))
                else:                       # "block": producer backpressure
                    waited = False
                    while len(self.queue) >= self.max_queue:
                        if not waited:
                            self._c["admission_blocked"].inc()
                            if self._rec.enabled:
                                self._rec.instant(
                                    "intake", "admission_block", rid=rid)
                            waited = True
                        rem = None if deadline is None \
                            else deadline - time.perf_counter()
                        if rem is not None and rem <= 0:
                            raise TimeoutError(
                                f"submit blocked on full queue "
                                f"({self.max_queue}) for {timeout}s")
                        self._work.wait(rem)
            self.queue.append(req)
            self._work.notify_all()
        if self._rec.enabled:
            self._rec.instant("intake", "submit", rid=rid,
                              bucket=str(req.key))
        return rid

    def _validate(self, g: CircuitGraph) -> None:
        """Per-request input guard: NaN/Inf features are rejected at the
        door — a poisoned member would otherwise fail (or silently corrupt)
        the whole collated batch it lands in."""
        for name in ("x_cell", "x_net"):
            x = np.asarray(getattr(g, name))
            if not np.isfinite(x).all():
                bad = int(np.size(x) - np.count_nonzero(np.isfinite(x)))
                self._c["rejected_inputs"].inc()
                if self._rec.enabled:
                    self._rec.instant("intake", "input_rejected", field=name)
                raise NonFiniteInputError(
                    f"graph.{name} contains {bad} non-finite value(s) "
                    f"of {x.size}; rejected at submit")

    def result(self, rid: int, timeout: Optional[float] = None,
               pop: bool = False) -> CircuitRequest:
        """Block until request ``rid`` finishes (serve_forever must be
        running on another thread, or run() called later).  ``pop=True``
        releases the engine's reference to the finished request — the
        collect-your-results pattern that keeps a long-lived loop's memory
        flat even without ``max_finished``."""
        deadline = None if timeout is None else time.perf_counter() + timeout
        with self._done:
            while rid not in self.finished:
                rem = None if deadline is None \
                    else deadline - time.perf_counter()
                if rem is not None and rem <= 0:
                    raise TimeoutError(f"request {rid} not finished "
                                       f"within {timeout}s")
                self._done.wait(rem)
            req = self.finished.pop(rid) if pop else self.finished[rid]
        if req.error is not None:
            raise RuntimeError(f"request {rid} failed in serving"
                               ) from req.error
        return req

    def _group_key(self, g: CircuitGraph) -> tuple:
        """Per-request shape bucket: requests sharing it collate into one
        signature-stable batch."""
        return (quantize_up(g.n_cell, self.node_bits),
                quantize_up(g.n_net, self.node_bits),
                g.x_cell.shape[1], g.x_net.shape[1])

    # ----------------------------------------------------------- batcher

    def _take_due_batch(self, max_wait_s: float
                        ) -> Optional[List[CircuitRequest]]:
        """Deadline-aware micro-batcher (caller holds the lock).

        Requests group by (shape bucket, head) — a batch is always
        head-homogeneous, so its one dispatch reads one head's weights —
        while layouts and compile caches stay keyed by shape bucket alone.
        Buckets form in FIFO order of first appearance; the first bucket
        with ``max_batch`` compatible requests dispatches full.  With none
        full, the head bucket dispatches partial once its oldest request
        (the queue head — the globally oldest) has waited ``max_wait_s``;
        ``max_wait_s <= 0`` flushes partials immediately (drain mode).
        Returns None when nothing is due.  Taken requests leave the queue;
        the rest keep their relative order."""
        if not self.queue:
            return None
        groups: Dict[tuple, List[CircuitRequest]] = {}
        order: List[tuple] = []
        for r in self.queue:
            k = (r.key, r.head)
            g = groups.get(k)
            if g is None:
                groups[k] = g = []
                order.append(k)
            if len(g) < self.b:
                g.append(r)
        pick = next((k for k in order if len(groups[k]) >= self.b), None)
        if pick is None:
            head = order[0]
            age = time.perf_counter() - groups[head][0].t_submit
            if max_wait_s <= 0 or age >= max_wait_s:
                pick = head
                if max_wait_s > 0 and len(groups[head]) < self.b:
                    self._c["deadline_flushes"].inc()
                    if self._rec.enabled:
                        self._rec.instant("intake", "deadline_flush",
                                          bucket=str(head),
                                          size=len(groups[head]),
                                          waited_ms=age * 1e3)
        if pick is None:
            return None
        chosen = {id(r) for r in groups[pick]}
        # Rotate the deque in place (never rebind self.queue): non-matching
        # requests keep their relative order.
        for _ in range(len(self.queue)):
            r = self.queue.popleft()
            if id(r) not in chosen:
                self.queue.append(r)
        # the queue shrank: wake producers blocked on admission backpressure
        self._work.notify_all()
        if self._rec.enabled:
            self._rec.instant("intake", "batch_formed", bucket=str(pick),
                              size=len(groups[pick]),
                              rids=[r.rid for r in groups[pick]])
        return groups[pick]

    def _next_deadline_s(self, max_wait_s: float) -> Optional[float]:
        """Seconds until the queue head's deadline (lock held); None when
        the queue is empty (wait for a submit)."""
        if not self.queue or max_wait_s <= 0:
            return None if not self.queue else 0.0
        rem = self.queue[0].t_submit + max_wait_s - time.perf_counter()
        return max(rem, 0.0)

    # ---------------------------------------------------------- pipeline

    def _prepare(self, reqs: List[CircuitRequest], dev_idx: int):
        """Host side (runs on the packing pool): collate, pad, transfer to
        ring slot ``dev_idx``.  Collation errors are the batch's fault;
        transfer errors are the device's (ring health records them).

        Traced as B/E spans on the calling thread's ``worker/<k>`` track
        (collate and device_put separately) — a track maps 1:1 onto a
        thread, so the pairs per track are strictly nested."""
        rec = self._rec
        track = _worker_track() if rec.enabled else None
        try:
            with (rec.span(track, "collate", batch=len(reqs),
                           bucket=str(reqs[0].key), device=dev_idx)
                  if rec.enabled else NULL_SPAN):
                if self.chaos is not None:
                    self.chaos.stall("straggler")
                    self.chaos.raise_if("collate")
                graphs = [r.graph for r in reqs]
                n_real = len(graphs)
                if self.pad_to_full and n_real < self.b:
                    # replicate the last member as filler so partial batches
                    # keep the full-batch signature (outputs dropped, loss
                    # weight zero)
                    graphs = graphs + [graphs[-1]] * (self.b - n_real)
                key = reqs[0].key
                # The bucket layout pins chunk widths and floors chunk
                # counts so same-bucket batches share a signature.  Locking
                # is per bucket: prepares of different buckets (the common
                # in-flight set for an interleaved stream) pack
                # concurrently; only the rare same-bucket pair serializes
                # on its layout.
                with self._lock:
                    layout = self._layouts.get(key)  # LRU touch; may evict
                    lock = self._buckets.setdefault(key, _BucketState()).lock
                with lock:
                    batch = collate_graphs(graphs, fused=True, quantize=True,
                                           node_bits=self.node_bits,
                                           arena_bits=self.arena_bits,
                                           chunk=self.chunk, layout=layout,
                                           n_real=n_real)
        except Exception:
            # host-side failure before the device was touched: the routed
            # slot must not be blamed — but a probe handout must not stay
            # in probing limbo either (it would never be re-probed)
            self.ring.release(dev_idx)
            raise
        try:
            with (rec.span(track, "device_put", device=dev_idx)
                  if rec.enabled else NULL_SPAN):
                if self.chaos is not None:
                    self.chaos.raise_if("device_put", device=dev_idx)
                graph = self.ring.put(batch.graph, dev_idx)
        except Exception:
            self.ring.record_failure(dev_idx)
            raise
        return reqs, batch, graph, key, dev_idx

    def _dispatch(self, prepared):
        reqs, batch, graph, key, dev_idx = prepared
        sig = batch.signature
        rec = self._rec
        t_disp = rec.now() if rec.enabled else 0.0
        compile_new = False
        with self._lock:
            st = self._buckets.setdefault(key, _BucketState())
            if st.fwd is None:
                # first dispatch of the bucket, or its return after an
                # eviction dropped the old jit — either way a fresh compile
                # cache (so "recompiles at most once on return" is exact)
                st.fwd = self._make_fwd()
            fwd = st.fwd
            if (sig, dev_idx) not in st.sigs:
                st.sigs.add((sig, dev_idx))
                self._n_compiles += 1
                compile_new = True
            self._disp[dev_idx].inc()
            # snapshot replicas + version under the lock so a concurrent
            # update_params() can't hand this batch replica A and stamp it
            # version B.  The head replica snapshots under the SAME lock:
            # batches are head-homogeneous (the batcher groups by
            # (key, head)), so reqs[0] speaks for the batch.
            params_d = self._params_of[dev_idx]
            version = self._params_version
            head = reqs[0].head
            hw, hb = (params_d.head_w, params_d.head_b) if head is None \
                else self._heads[head][dev_idx]
        if compile_new:
            self.metrics.inc("serve.compiles")
            if rec.enabled:
                rec.instant(f"device/{dev_idx}", "compile", bucket=str(key))
        try:
            if self.chaos is not None:
                self.chaos.raise_if("dispatch", device=dev_idx)
            out = fwd(params_d, hw, hb, graph)        # async dispatch
        except Exception:
            self.ring.record_failure(dev_idx)
            raise
        # t_disp rides at the END of the tuple: downstream consumers
        # (serve_forever) index dev_idx as entry[4], so never insert before
        return reqs, batch, out, version, dev_idx, t_disp

    def _complete(self, inflight):
        reqs, batch, out, version, dev_idx, t_disp = inflight
        try:
            preds = np.asarray(out)                   # device barrier
        except Exception:
            self.ring.record_failure(dev_idx)
            raise
        self.ring.record_success(dev_idx)
        if self.chaos is not None:
            preds = self.chaos.poison(preds)
        # Output guard: a non-finite member prediction must surface as a
        # diagnosed failure, never as a served result.  Raising for the
        # whole batch hands it to the containment ladder — a transient
        # (poisoned output) heals on retry, a poisoned member bisects down
        # to a single diagnosed request.
        bad = [(r, m) for r, m in zip(reqs, batch.members)
               if not np.isfinite(preds[m.cell_off:m.cell_off + m.n_cell]
                                  ).all()]
        if bad:
            self._c["nonfinite_outputs"].inc()
            if self._rec.enabled:
                self._rec.instant("healing", "nonfinite_output",
                                  device=dev_idx,
                                  rids=[r.rid for r, _ in bad])
            rids = [r.rid for r, _ in bad]
            counts = [int((~np.isfinite(
                preds[m.cell_off:m.cell_off + m.n_cell])).sum())
                for _, m in bad]
            raise NonFiniteOutputError(
                f"non-finite predictions for request(s) {rids} "
                f"({counts} bad cells of "
                f"{[m.n_cell for _, m in bad]}) on ring slot {dev_idx}")
        now = time.perf_counter()
        with self._done:
            committed = []
            for r, m in zip(reqs, batch.members):
                if r.final:
                    continue          # an abandoned attempt raced us; the
                    #                   first committed result stands
                r.final = True
                # copy: a view would pin the whole padded batch array, so
                # max_finished / result(pop=True) would bound nothing
                r.pred = preds[m.cell_off:m.cell_off + m.n_cell].copy()
                r.t_done = now
                r.params_version = version
                self.finished[r.rid] = r
                self._lat.observe(r.latency_ms)
                committed.append(m)
            if self.max_finished is not None:
                while len(self.finished) > self.max_finished:
                    # dict preserves insertion order: drop the oldest
                    self.finished.pop(next(iter(self.finished)))
            if committed:
                self._c["batches"].inc()
                self._c["requests"].inc(len(committed))
                self._c["real_cells"].inc(sum(m.n_cell for m in committed))
                self._c["padded_cells"].inc(batch.graph.n_cell)
            self._done.notify_all()
        if self._rec.enabled:
            # one X (complete) event per committed batch attempt on the
            # slot's track: attempts may overlap on a slot (pipeline batch
            # vs healing re-dispatch), which B/E pairs cannot express
            self._rec.complete(
                f"device/{dev_idx}", "batch", t_disp,
                self._rec.now() - t_disp, requests=len(committed),
                batch=len(reqs), params_version=version)

    def _evict_bucket(self, key: tuple, layout) -> None:
        """LayoutTable eviction hook (fires under self._lock, from the
        pool thread inside _prepare).  Dropping the bucket's _BucketState
        releases its jit's compiled executables; its signatures stop being
        live, so a future return of the bucket counts as a fresh compile."""
        self._buckets.pop(key, None)

    def _fail(self, reqs: List[CircuitRequest], exc: BaseException) -> None:
        """Contain a batch failure: mark its requests failed (result()
        re-raises for them) and keep serving — one malformed request must
        not strand the rest of the stream."""
        with self._done:
            self._finalize_failed_locked(reqs, exc)

    def _finalize_failed_locked(self, reqs: List[CircuitRequest],
                                exc: BaseException) -> None:
        """Commit failures (engine lock held).  Already-finalized requests
        are skipped — an abandoned watchdog attempt may have raced us."""
        now = time.perf_counter()
        failed = 0
        for r in reqs:
            if r.final:
                continue
            r.final = True
            r.error = exc
            r.t_done = now
            self.finished[r.rid] = r
            failed += 1
        if self.max_finished is not None:
            while len(self.finished) > self.max_finished:
                self.finished.pop(next(iter(self.finished)))
        if failed:
            self._c["failures"].inc(failed)
            if self._rec.enabled:
                self._rec.instant("healing", "fail", count=failed,
                                  error=type(exc).__name__,
                                  rids=[r.rid for r in reqs])
        self._done.notify_all()

    # ------------------------------------------- containment ladder (§10)

    def _attempt(self, reqs: List[CircuitRequest]) -> None:
        """One full serve attempt of ``reqs`` on a freshly-routed device
        (quarantined slots are skipped by the ring; a due probe may be
        handed out here — a healing retry doubling as the health probe)."""
        dev_idx = self.ring.next_index()
        self._complete(self._dispatch(self._prepare(reqs, dev_idx)))

    def _timed_attempt(self, reqs: List[CircuitRequest]) -> None:
        """``_attempt`` bounded by ``watchdog_s``: the attempt runs on a
        disposable daemon thread; on expiry the thread is abandoned (its
        eventual late commit is voided by the requests' ``final`` flags)
        and :class:`WatchdogTimeoutError` raises instead of hanging."""
        if self.watchdog_s is None:
            return self._attempt(reqs)
        box: Dict[str, BaseException] = {}

        def attempt():
            try:
                self._attempt(reqs)
            except BaseException as e:
                box["exc"] = e

        th = threading.Thread(target=attempt, daemon=True)
        th.start()
        th.join(self.watchdog_s)
        if th.is_alive():
            self._c["watchdog_timeouts"].inc()
            if self._rec.enabled:
                self._rec.instant("healing", "watchdog_timeout",
                                  batch=len(reqs), where="healing_attempt")
            raise WatchdogTimeoutError(
                f"healing attempt for batch of {len(reqs)} exceeded "
                f"watchdog {self.watchdog_s}s")
        if "exc" in box:
            raise box["exc"]

    def _heal(self, reqs: List[CircuitRequest], exc: BaseException,
              depth: int = 0) -> None:
        """The containment ladder, run off the serve loop after a batch's
        pipeline attempt failed with ``exc``:

        1. **retry** — up to ``max_retries`` full re-serves with
           exponential backoff, each on a freshly-routed (healthy) device;
        2. **bisect** — a batch that keeps failing splits in half and each
           half re-enters the ladder, so a single poison member is isolated
           in O(log B) rounds and ONLY it ultimately fails;
        3. **fail** — a singleton that keeps failing is marked failed with
           the last error (``result()`` re-raises it).

        Healthy members re-served here are bit-identical to a fault-free
        run: collation is block-diagonal and the bucket layout pins the
        padded shapes, so a member's output rows do not depend on which
        companions shared its batch."""
        for attempt in range(self.max_retries):
            time.sleep(self.retry_backoff_s * (2 ** attempt))
            self._c["retries"].inc()
            if self._rec.enabled:
                self._rec.instant("healing", "retry", attempt=attempt,
                                  depth=depth, batch=len(reqs),
                                  error=type(exc).__name__)
            try:
                self._timed_attempt(reqs)
                return
            except Exception as e:
                exc = e
        if len(reqs) > 1:
            self._c["bisects"].inc()
            if self._rec.enabled:
                self._rec.instant("healing", "bisect", depth=depth,
                                  batch=len(reqs),
                                  error=type(exc).__name__)
            mid = len(reqs) // 2
            self._heal(reqs[:mid], exc, depth + 1)
            self._heal(reqs[mid:], exc, depth + 1)
        else:
            self._fail(reqs, exc)

    def _on_watchdog(self, reqs: List[CircuitRequest],
                     dev_idx: Optional[int] = None) -> None:
        """An in-flight pipeline batch outlived ``watchdog_s``: fail its
        requests now (result() returns a timed-out error instead of
        hanging) and blame the device — a wedge IS a device fault."""
        self._c["watchdog_timeouts"].inc()
        if self._rec.enabled:
            self._rec.instant("healing", "watchdog_timeout",
                              batch=len(reqs), device=dev_idx,
                              where="pipeline")
        if dev_idx is not None:
            self.ring.record_failure(dev_idx)
        self._fail(reqs, WatchdogTimeoutError(
            f"batch of {len(reqs)} in flight past the "
            f"{self.watchdog_s}s watchdog"))

    # ------------------------------------------------------------- modes

    def run(self) -> Dict[int, CircuitRequest]:
        """Drain a snapshot of the queue: partial batches flush immediately
        (no deadline wait), batches round-robin over the device ring, and
        the packing pool keeps one batch in flight per device — the pool
        packs batches i+1..i+D while the D devices run batches i-D+1..i.
        Failed batches enter the containment ladder synchronously (drain
        mode has no serve loop to hand off to)."""
        batches = []
        with self._lock:
            if self._serving:
                raise RuntimeError("run() while serve_forever() is active; "
                                   "use submit()/result() instead")
            while self.queue:
                reqs = self._take_due_batch(0.0)
                batches.append((reqs, self.ring.next_index()))
        t0 = time.perf_counter()
        inflight: Deque = deque()
        n_dev = len(self.ring)

        def prep_safe(reqs, dev_idx):
            # prefetch's iterator re-raises worker exceptions, which would
            # strand every later batch — box the failure instead
            try:
                return self._prepare(reqs, dev_idx)
            except Exception as e:
                return _PREP_FAILED, reqs, e

        def retire(entry):
            try:
                self._complete(entry)
            except Exception as e:
                self._heal(entry[0], e)

        for prepared in prefetch(batches, lambda ba: prep_safe(*ba),
                                 depth=n_dev,
                                 n_threads=max(self.n_pack_threads, n_dev)):
            if prepared[0] is _PREP_FAILED:
                self._heal(prepared[1], prepared[2])
                continue
            try:
                inflight.append(self._dispatch(prepared))
            except Exception as e:
                self._heal(prepared[0], e)
                continue
            if len(inflight) > n_dev:
                retire(inflight.popleft())
        while inflight:
            retire(inflight.popleft())
        self._c["wall_s"].inc(time.perf_counter() - t0)
        return self.finished

    def serve_forever(self, *, stop_when_idle: bool = False
                      ) -> Dict[int, CircuitRequest]:
        """Long-lived online loop: serve submits as they arrive until
        ``stop()`` (which drains the queue and pipeline first) or, with
        ``stop_when_idle``, until queue and pipeline are both empty.

        Blocks the calling thread — run it on a dedicated thread and feed
        it with ``submit()`` from any other.  The pipeline is the drain-mode
        one made incremental: pool threads prepare due batches (one in
        flight per device, plus the pool's own lookahead), the loop
        dispatches them in order, and completed batches are retired eagerly
        whenever no batch is due — so results surface during lulls instead
        of waiting for the next submit.

        Batch failures are contained by the self-healing ladder: a
        prepare/dispatch/complete exception hands the batch to a healer
        thread (retry with backoff → bisect → fail only the poison member;
        ``stats()`` counts ``retries``/``bisects``/``failures``) and the
        loop keeps serving the rest of the stream.  With ``watchdog_s``
        set, a batch wedged in flight past the bound is failed with
        :class:`WatchdogTimeoutError` — ``result()`` never hangs on it."""
        max_wait_s = self.max_wait_ms * 1e-3
        n_dev = len(self.ring)
        prep: Deque = deque()       # (Future of _prepare, reqs, t0, dev)
        inflight: Deque = deque()   # (Future of _complete, reqs, t0, dev)

        def overdue(t_start: float) -> bool:
            return (self.watchdog_s is not None
                    and time.perf_counter() - t_start > self.watchdog_s)

        def heal_async(reqs_h, exc):
            # containment off the serve thread: backoff sleeps and bisect
            # rounds must not stall the happy path.  _healing keeps the
            # drain honest (stop() waits for outstanding heals).
            with self._lock:
                self._healing += 1

            def heal():
                try:
                    self._heal(reqs_h, exc)
                finally:
                    with self._work:
                        self._healing -= 1
                        self._work.notify_all()

            threading.Thread(target=heal, daemon=True).start()

        def dispatch_head():
            fut, reqs_p, t_start, _dev = prep.popleft()
            try:
                entry = self._dispatch(fut.result())
            except Exception as e:
                heal_async(reqs_p, e)
                return
            cfut = pool.submit(self._complete, entry)
            cfut.add_done_callback(self._notify_work)
            inflight.append((cfut, reqs_p, t_start, entry[4]))

        def reap_head():
            cfut, reqs_c, t_start, dev_idx = inflight.popleft()
            if cfut.done():
                exc = cfut.exception()
                if exc is not None:
                    heal_async(reqs_c, exc)
            else:
                # overdue and still running: abandon the attempt (the
                # `final` flags void its late commit) and time it out
                self._on_watchdog(reqs_c, dev_idx)

        with self._lock:
            if self._serving:
                raise RuntimeError("serve_forever() is already running")
            self._serving = True
            # do NOT clear _stop here: a stop() that raced ahead of this
            # thread's start must still win (the loop then just drains the
            # already-queued requests and returns).  _stop resets on exit,
            # so a later serve_forever() starts fresh.
        t0 = time.perf_counter()
        # +2 workers: a wedged _complete occupying a worker past its
        # watchdog must not starve the packing lookahead
        pool = ThreadPoolExecutor(
            max_workers=max(self.n_pack_threads, n_dev) + 2)
        try:
            while True:
                while prep and prep[0][0].done():
                    dispatch_head()
                while inflight and (inflight[0][0].done()
                                    or overdue(inflight[0][2])):
                    reap_head()
                if prep and overdue(prep[0][2]):
                    # wedged prepare (e.g. a stalled host thread): the
                    # whole batch times out, the orphaned future's result
                    # is never dispatched; the routed slot takes the blame
                    # (which also resolves a probe handout)
                    fut, reqs_p, _, dev_p = prep.popleft()
                    fut.cancel()
                    self._on_watchdog(reqs_p, dev_p)
                reqs = dev_idx = None
                with self._work:
                    # stopping flushes partials immediately — no deadline;
                    # one batch in flight per device bounds device queueing
                    if len(inflight) <= n_dev:
                        reqs = self._take_due_batch(
                            0.0 if self._stop else max_wait_s)
                    if reqs is not None:
                        dev_idx = self.ring.next_index()
                    elif prep or inflight or self._healing:
                        # pipeline busy: sleep until a future lands, a
                        # submit arrives, or the next watchdog/queue
                        # deadline — unless a head is already actionable
                        if not ((prep and prep[0][0].done()) or
                                (inflight and inflight[0][0].done())):
                            self._work.wait(
                                self._tick_s(prep, inflight, max_wait_s))
                        continue
                    elif self._stop or (stop_when_idle and not self.queue):
                        break       # queue empty, pipeline dry, heals done
                    else:
                        # nothing due and nothing in flight: sleep until
                        # the head's deadline / a submit / stop()
                        self._work.wait(self._next_deadline_s(max_wait_s))
                        continue
                fut = pool.submit(self._prepare, reqs, dev_idx)
                fut.add_done_callback(self._notify_work)
                prep.append((fut, reqs, time.perf_counter(), dev_idx))
        finally:
            pool.shutdown(wait=False)
            with self._lock:
                self._serving = False
                self._stop = False
            self._c["wall_s"].inc(time.perf_counter() - t0)
        return self.finished

    def _tick_s(self, prep, inflight, max_wait_s: float) -> Optional[float]:
        """Bounded sleep for the serve loop while the pipeline is busy:
        the soonest of the queue-head deadline and the heads' watchdog
        deadlines (None blocks until a notify)."""
        cands = []
        q = self._next_deadline_s(max_wait_s)
        if q is not None:
            cands.append(q)
        if self.watchdog_s is not None:
            now = time.perf_counter()
            if prep:
                cands.append(max(prep[0][2] + self.watchdog_s - now, 0.0))
            if inflight:
                cands.append(max(inflight[0][2] + self.watchdog_s - now,
                                 0.0))
        return min(cands) if cands else None

    def stop(self) -> None:
        """Ask serve_forever() to drain (queue + in-flight batches) and
        return; thread-safe, and it wins even when it races ahead of the
        serving thread's start (the flag is sticky until a serve loop
        consumes it on exit).  Requests submitted after stop() may still be
        served by the drain or by a later run()/serve_forever()."""
        with self._work:
            self._stop = True
            self._work.notify_all()

    def _notify_work(self, _fut) -> None:
        with self._work:
            self._work.notify_all()

    # --------------------------------------------------------- hot swap

    def update_params(self, params) -> int:
        """Swap the served model without stopping the loop (the
        train-then-serve pattern, ROADMAP): new per-device replicas are
        committed via the same ``_params_of`` isolation every dispatch
        reads, so batches dispatched after the swap use the new weights
        while in-flight batches finish on the old ones — no torn batch ever
        mixes generations (replica + version are snapshotted together under
        the engine lock at dispatch).  Returns the new version; every
        request records the version that served it
        (``result(rid).params_version``).  Params must keep their pytree
        shapes — the per-bucket jits re-run the existing executables, so a
        swap costs zero recompiles.  Registered heads (:meth:`register_head`)
        are independent replicas and survive the swap unchanged; only the
        default ``head=None`` path follows the new params' own head."""
        replicas = tuple(jax.device_put(params, d)
                         for d in self.ring.devices)
        with self._lock:
            self.params = params
            self._params_of = replicas
            self._params_version += 1
            return self._params_version

    @property
    def params_version(self) -> int:
        return self._params_version

    # ------------------------------------------------- multi-tenant heads

    def register_head(self, name: str, head_w, head_b=None) -> None:
        """Install (or replace) a named per-task output head sharing the
        engine's one backbone.  ``head_w``/``head_b`` must match the
        committed params' head shapes — that is what guarantees selection
        is argument-only and costs zero compiles (a different shape would
        be a different model, not a head).  ``head_b=None`` uses a zero
        bias.  Replicas are committed per ring slot exactly like
        ``update_params`` replicas; re-registering a name hot-swaps that
        head between batches.  Requests then opt in per call:
        ``submit(graph, head=name)``."""
        ref_w, ref_b = self.params.head_w, self.params.head_b
        head_w = jnp.asarray(head_w, ref_w.dtype)
        head_b = jnp.zeros_like(ref_b) if head_b is None \
            else jnp.asarray(head_b, ref_b.dtype)
        if head_w.shape != ref_w.shape or head_b.shape != ref_b.shape:
            raise ValueError(
                f"head {name!r} shapes {head_w.shape}/{head_b.shape} do "
                f"not match the backbone's head {ref_w.shape}/{ref_b.shape}"
                f"; a registered head swaps argument values only")
        replicas = tuple(
            (jax.device_put(head_w, d), jax.device_put(head_b, d))
            for d in self.ring.devices)
        with self._lock:
            self._heads[name] = replicas

    @property
    def heads(self) -> tuple:
        """Registered head names, sorted."""
        return tuple(sorted(self._heads))

    # ------------------------------------------------------------- stats

    @property
    def compiles(self) -> int:
        """Cumulative first-dispatches of (padded-shape signature, device)
        pairs — each is one jit compile.  Evicting a bucket drops its live
        signatures, so a bucket that returns after eviction counts its
        recompile here too (cross-checked in stats() against the live
        buckets' own jit caches)."""
        return self._n_compiles

    @property
    def live_buckets(self) -> int:
        return len(self._layouts)

    @property
    def evictions(self) -> int:
        return self._layouts.evictions

    def stats(self) -> Dict[str, float]:
        """Back-compat stats dict, now a VIEW over the metrics registry:
        every pre-PR-7 key is preserved (tests pin the key set), counters
        are integer-valued where they were, and p99_ms rides along from the
        latency histogram.  ``metrics_snapshot()``/``metrics_text()`` expose
        the full registry."""
        with self._lock:
            fwds = [s.fwd for s in self._buckets.values()
                    if s.fwd is not None]
            live = sum(len(s.sigs) for s in self._buckets.values())
        health = self.ring.health()
        ci = {name: int(cnt.value) for name, cnt in self._c.items()}
        wall_s = self._c["wall_s"].value
        p50, p95, p99 = self._lat.percentiles((0.50, 0.95, 0.99))
        out = dict(requests=ci["requests"], batches=ci["batches"],
                   compiles=self.compiles,
                   graphs_per_s=ci["requests"] / max(wall_s, 1e-9),
                   p50_ms=p50, p95_ms=p95, p99_ms=p99,
                   wall_s=wall_s,
                   cell_padding_ratio=(ci["padded_cells"]
                                       / max(ci["real_cells"], 1)),
                   deadline_flushes=ci["deadline_flushes"],
                   failures=ci["failures"],
                   retries=ci["retries"],
                   bisects=ci["bisects"],
                   watchdog_timeouts=ci["watchdog_timeouts"],
                   nonfinite_outputs=ci["nonfinite_outputs"],
                   rejected_inputs=ci["rejected_inputs"],
                   admission_blocked=ci["admission_blocked"],
                   admission_rejected=ci["admission_rejected"],
                   admission_shed=ci["admission_shed"],
                   queued=len(self.queue),
                   device_health=health["states"],
                   quarantines=health["quarantines"],
                   probes=health["probes"],
                   readmissions=health["readmissions"],
                   devices=len(self.ring),
                   dispatches_per_device=[int(c.value) for c in self._disp],
                   live_buckets=self.live_buckets,
                   evictions=self.evictions,
                   live_compiles=live,
                   params_version=self._params_version)
        sizes = [f._cache_size() for f in fwds
                 if callable(getattr(f, "_cache_size", None))]
        if len(sizes) == len(fwds):
            # sum over live per-bucket jits == live (sig, device) pairs;
            # with no evictions this equals the cumulative `compiles`
            out["jit_cache_size"] = sum(sizes)
        return out

    # ----------------------------------------------------- obs exports

    @property
    def recorder(self) -> Recorder:
        return self._rec

    def dump_trace(self, path: str) -> None:
        """Write the engine's Chrome trace-event JSON to ``path`` (open in
        https://ui.perfetto.dev or chrome://tracing).  With the default
        no-op recorder this writes an empty-but-valid trace."""
        self._rec.dump(path)

    def metrics_snapshot(self) -> Dict[str, object]:
        """JSON-able registry snapshot (counters/gauges as numbers,
        histograms as count/sum/min/max/percentile summaries)."""
        return self.metrics.snapshot()

    def metrics_text(self) -> str:
        """Prometheus text exposition of the engine's registry."""
        return self.metrics.to_prometheus()
