"""Continuous-batching serve engine (vLLM-lite).

Slot-based scheduler over the LM's one-token decode step:

* a fixed pool of B cache slots (static shapes — TPU-compile-once);
* every engine step decodes ONE token for every active slot, each at its
  own position (the vector-``pos`` decode path in models/lm/attention.py);
* prompt consumption and generation use the same step: while a slot still
  has prompt tokens left, the model's prediction is discarded and the next
  prompt token is fed (ragged prefill-by-decode, so requests of different
  lengths join/leave the batch at any step with zero recompilation);
* finished slots are freed and immediately refilled from the queue.

One jitted function serves the whole lifecycle.  For the 32k-cache shapes
the caches are sequence-sharded over ``model`` exactly as in the dry-run
cells; the engine is mesh-agnostic.
"""

from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import serve
from repro.models.lm.model import LM


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    generated: List[int] = dataclasses.field(default_factory=list)
    _consumed: int = 0         # prompt tokens already fed

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


class ServeEngine:
    def __init__(self, lm: LM, params, *, max_batch: int, s_max: int,
                 sample: Optional[Callable] = None):
        self.lm = lm
        self.params = params
        self.b = max_batch
        self.s_max = s_max
        self.sample = sample or (lambda logits: int(np.argmax(logits)))
        self.cache = serve.cache_zeros(lm, max_batch, s_max)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.pos = np.zeros(max_batch, np.int32)     # next write position
        self.queue: Deque[Request] = deque()
        self.finished: Dict[int, Request] = {}
        self._rid = itertools.count()
        self._decode = jax.jit(
            lambda p, c, t, q: serve.decode_step(lm, p, c, t, q))

    # ------------------------------------------------------------------

    def submit(self, prompt: List[int], max_new_tokens: int = 16) -> int:
        rid = next(self._rid)
        self.queue.append(Request(rid, list(prompt), max_new_tokens))
        return rid

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    def _admit(self):
        for i in range(self.b):
            if self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                self.slots[i] = req
                self.pos[i] = 0

    def step(self) -> int:
        """One engine step: decode one token for every active slot.
        Returns the number of active slots processed."""
        self._admit()
        if self.n_active == 0:
            return 0
        token = np.zeros((self.b, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if req._consumed < len(req.prompt):
                token[i, 0] = req.prompt[req._consumed]
            else:
                token[i, 0] = req.generated[-1]
        pos_vec = jnp.asarray(np.where(
            [s is not None for s in self.slots], self.pos, 0))
        self.cache, logits = self._decode(self.params, self.cache,
                                          jnp.asarray(token), pos_vec)
        logits_np = np.asarray(logits[:, 0, : self.lm.cfg.vocab], np.float32)

        n = 0
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            n += 1
            self.pos[i] += 1
            if req._consumed < len(req.prompt):
                req._consumed += 1
                if req._consumed == len(req.prompt):
                    req.generated.append(self.sample(logits_np[i]))
            else:
                req.generated.append(self.sample(logits_np[i]))
            if req.done or self.pos[i] >= self.s_max:
                self.finished[req.rid] = req
                self.slots[i] = None
        return n

    def run(self, max_steps: int = 10_000) -> Dict[int, Request]:
        for _ in range(max_steps):
            if self.n_active == 0 and not self.queue:
                break
            self.step()
        return self.finished
