from repro.sharding.specs import (RULES, constrain, make_pspec, set_mesh,  # noqa: F401
                                  get_mesh, mesh_context, param_sharding)
from repro.sharding.specs import (DeviceRing, batch_devices,  # noqa: F401
                                  shard_map_compat, shard_mesh)
from repro.sharding.plan_shard import (ShardedRelationPlan,  # noqa: F401
                                       shard_relation_plan)
