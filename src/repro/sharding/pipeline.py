"""Pipeline parallelism: GPipe-style microbatch pipelining over a ``stage``
mesh axis, as a composable shard_map transform.

``pipeline_apply(mesh, stage_fn, stage_params, microbatches)`` runs

    y_m = stage_fn(p_{S-1}, ... stage_fn(p_1, stage_fn(p_0, x_m)))

for every microbatch m, with stage s resident on mesh slice ``stage=s`` and
activations moving stage→stage via ``collective_permute`` (the ICI-neighbor
transfer on a real TPU torus).  The schedule is the classic GPipe ramp:
T = n_micro + n_stages − 1 ticks; at tick t, stage s works on microbatch
t − s (bubble fraction (S−1)/T).  The backward pass falls out of autodiff —
``collective_permute`` transposes to the reverse permute, giving the
standard reverse-schedule pipeline backward.

Composition caveat: on this JAX version, partial-manual shard_map
(``axis_names={'stage'}`` with auto data/model axes) rejects replicated
out_specs, so ``pipeline_apply`` currently targets a stage-only mesh (or a
mesh where the other axes are handled by an outer pjit).  Intra-stage
TP composes by nesting the model axis inside ``stage_fn`` via the usual
``constrain`` hints once that JAX limitation lifts.

Integration note (DESIGN.md §5): the LM cells use DP×TP×SP×EP meshes where
depth fits memory after remat; PP is provided for the deeper-than-memory
regime and validated on a 4-stage pipeline in tests/test_pipeline.py.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(mesh: Mesh, stage_fn: Callable, stage_params,
                   microbatches: jax.Array, *, axis: str = "stage"
                   ) -> jax.Array:
    """Run ``microbatches`` (M, mb, ...) through S pipelined stages.

    ``stage_params``: pytree with leading stage axis S on every leaf.
    Returns (M, mb, ...) outputs (shapes preserved by stage_fn).
    """
    n_stage = mesh.shape[axis]
    m = microbatches.shape[0]
    ticks = m + n_stage - 1
    perm_fwd = [(i, i + 1) for i in range(n_stage - 1)]

    p_spec = jax.tree.map(lambda _: P(axis), stage_params)

    from repro.sharding.specs import shard_map_compat

    @shard_map_compat(
        mesh=mesh, axis_names={axis},
        in_specs=(p_spec, P()), out_specs=P(), check_vma=False)
    def run(params_l, mbs):
        sid = jax.lax.axis_index(axis)
        p_local = jax.tree.map(lambda a: a[0], params_l)   # squeeze stage dim
        buf0 = jnp.zeros_like(mbs[0])
        outs0 = jnp.zeros_like(mbs)

        def tick(t, carry):
            incoming, outs = carry
            # stage 0 injects microbatch t (clamped; inactive ticks are
            # masked out by the collection step below)
            inject = mbs[jnp.clip(t, 0, m - 1)]
            cur = jnp.where(sid == 0, inject, incoming)
            y = stage_fn(p_local, cur)
            # last stage emits microbatch t-(S-1)
            out_idx = jnp.clip(t - (n_stage - 1), 0, m - 1)
            emit = jnp.logical_and(sid == n_stage - 1,
                                   t - (n_stage - 1) >= 0)
            outs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y.astype(o.dtype), out_idx, 0),
                lambda o: o, outs)
            nxt = jax.lax.ppermute(y, axis, perm_fwd)
            return nxt, outs

        _, outs = jax.lax.fori_loop(0, ticks, tick, (buf0, outs0))
        # replicate the last stage's collected outputs to every stage
        keep = (sid == n_stage - 1).astype(outs.dtype)
        return jax.lax.psum(outs * keep, axis)

    return run(stage_params, microbatches)


def sequential_reference(stage_fn, stage_params, microbatches):
    """Oracle: apply the S stages in sequence, no pipelining."""
    def one(x):
        def body(x_, p):
            return stage_fn(p, x_), None
        y, _ = jax.lax.scan(body, x, stage_params)
        return y
    return jax.vmap(one)(microbatches)
