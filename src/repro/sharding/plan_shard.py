"""Mesh-partitioned RelationPlan: giant-graph sharded execution (DESIGN §12).

Everything up to PR 7 assumes one device holds the whole circuit; the
paper's headline workload (full-size CircuitNet) does not fit.  This module
splits a :class:`~repro.graphs.ell.RelationPlan` super-arena by destination
row-block across a 1-D ``("shard",)`` mesh at PACK time:

* Device ``d`` owns the contiguous OUTPUT slab ``[d·T, (d+1)·T)`` of the
  relation-concat output space and the contiguous SOURCE slab
  ``[d·S, (d+1)·S)`` of the type-concat source space (``T``/``S`` are the
  ceil-divided slab sizes; the ragged tail is inert padding).
* Every edge lands on the shard owning its destination row.  Source rows a
  shard needs but does not own form its HALO: a per-owner sorted-unique
  request list, baked into two index tables —

    - ``send_idx[s, p]``  — local coords (at owner ``s``) of the rows peer
      ``p`` requested: the all-to-all SEND gather.
    - ``halo_rows[d, s]`` — global source row ids behind shard ``d``'s halo
      slots from owner ``s`` (−1 = padding): the audit table the property
      suite checks bijectivity on (tests/test_plan_shard.py).

* Each shard's edges are re-packed (``pack_ell`` → ``fuse_bucketed`` at the
  plan's pinned chunk widths) into LOCAL fwd/bwd arenas over the local
  coordinate space ``[own slab | halo slab]`` (halo slot ``(s, j)`` lives at
  ``S + s·H + j``).  The §1/§5 kernels run UNCHANGED per shard; all shards'
  arenas are padded to one stacked shape so ``shard_map`` sees uniform
  operands and each device holds exactly its slice.

The executor (kernels/ops.py::drspmm_multi_sharded) runs the halo exchange
as ONE ``jax.lax.all_to_all`` per direction: forward gathers requested
source rows to the shards that read them; backward reverses the exchange —
the halo segment of the local dx slab travels back to the owner shards,
which scatter-add it into their owned dx rows.  Padded slots carry zero
weights end to end, so every padding path is inert (property-tested).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np
import jax

from repro.graphs.ell import (FusedELL, RelationPlan, RelationSegment,
                              fuse_bucketed, pack_ell, pad_fused_arena,
                              plan_to_coo)
from repro.obs.metrics import DEFAULT_REGISTRY as _METRICS


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _arena_nbytes(f: FusedELL) -> int:
    """Device footprint of one arena's tables (slot tables dominate)."""
    return sum(np.asarray(a).nbytes
               for a in (f.nbr, f.w, f.block_of, f.start, f.rows, f.gather))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ShardedRelationPlan:
    """A RelationPlan partitioned over ``n_shards`` mesh devices.

    Array fields are STACKED per-shard tables with a leading ``n_shards``
    axis; under ``shard_map`` with ``P("shard")`` each device holds exactly
    its slice — the whole point: no shard ever materializes another shard's
    arena.  Static fields mirror :class:`RelationPlan`'s aux data plus the
    slab geometry, so the executor's jit cache keys stay shape-stable.
    """

    # fwd local arenas (stacked): (n, Cf, BR, Ec) slots + per-chunk metadata
    fwd_nbr: jax.Array
    fwd_w: jax.Array
    fwd_block_of: jax.Array      # (n, Cf)
    fwd_start: jax.Array         # (n, Cf)
    fwd_rows: jax.Array          # (n, Rf) local output row per arena row
    fwd_gather: jax.Array        # (n, T)  arena row per local output row
    # bwd (transposed) local arenas: dx over the local [own | halo] slab
    bwd_nbr: jax.Array
    bwd_w: jax.Array
    bwd_block_of: jax.Array
    bwd_start: jax.Array
    bwd_rows: jax.Array          # (n, Rb) local source-slab row per arena row
    bwd_gather: jax.Array        # (n, S + n·H)
    # halo exchange tables
    send_idx: jax.Array          # (n, n, H) local rows owner s sends peer p
    halo_rows: jax.Array         # (n, n, H) global src row per halo slot; −1 pad

    n_shards: int = dataclasses.field(metadata=dict(static=True))
    src_slab: int = dataclasses.field(metadata=dict(static=True))    # S
    out_slab: int = dataclasses.field(metadata=dict(static=True))    # T
    halo_pad: int = dataclasses.field(metadata=dict(static=True))    # H
    n_src_total: int = dataclasses.field(metadata=dict(static=True))
    n_out_total: int = dataclasses.field(metadata=dict(static=True))
    row_block: int = dataclasses.field(metadata=dict(static=True))
    fwd_chunk: int = dataclasses.field(metadata=dict(static=True))
    bwd_chunk: int = dataclasses.field(metadata=dict(static=True))
    # full unsharded super-arena footprint — the replication baseline the
    # bench smoke asserts every per-shard footprint strictly beats
    full_arena_bytes: int = dataclasses.field(metadata=dict(static=True))
    segments: Tuple[RelationSegment, ...] = dataclasses.field(
        metadata=dict(static=True))
    src_types: Tuple[str, ...] = dataclasses.field(
        metadata=dict(static=True))
    src_off: Tuple[int, ...] = dataclasses.field(
        metadata=dict(static=True))
    src_sizes: Tuple[int, ...] = dataclasses.field(
        metadata=dict(static=True))

    @property
    def local_src(self) -> int:
        """Local source-slab width: owned rows + owner-major halo slots."""
        return self.src_slab + self.n_shards * self.halo_pad

    def local_fwd(self, d: int) -> FusedELL:
        """Shard ``d``'s forward arena as a host-side :class:`FusedELL`
        (round-trip tests, reference simulators)."""
        return FusedELL(
            nbr=np.asarray(self.fwd_nbr)[d], w=np.asarray(self.fwd_w)[d],
            block_of=np.asarray(self.fwd_block_of)[d],
            start=np.asarray(self.fwd_start)[d],
            rows=np.asarray(self.fwd_rows)[d],
            gather=np.asarray(self.fwd_gather)[d],
            n_dst=self.out_slab, n_src=self.local_src, nnz=-1,
            row_block=self.row_block, chunk=self.fwd_chunk)

    def local_bwd(self, d: int) -> FusedELL:
        return FusedELL(
            nbr=np.asarray(self.bwd_nbr)[d], w=np.asarray(self.bwd_w)[d],
            block_of=np.asarray(self.bwd_block_of)[d],
            start=np.asarray(self.bwd_start)[d],
            rows=np.asarray(self.bwd_rows)[d],
            gather=np.asarray(self.bwd_gather)[d],
            n_dst=self.local_src, n_src=self.out_slab, nnz=-1,
            row_block=self.row_block, chunk=self.bwd_chunk)

    def owned_src_rows(self, d: int) -> int:
        """Count of REAL (non-padding) source rows shard ``d`` owns."""
        return max(0, min(self.src_slab, self.n_src_total - d * self.src_slab))

    def shard_bytes(self, d: int) -> int:
        """Per-device table footprint: owned arena slices + the send table.
        Identical across shards by construction (stacked uniform shapes)."""
        return _arena_nbytes(self.local_fwd(d)) \
            + _arena_nbytes(self.local_bwd(d)) \
            + np.asarray(self.send_idx)[d].nbytes

    def halo_stats(self) -> dict:
        hr = np.asarray(self.halo_rows)
        shards = []
        for d in range(self.n_shards):
            owned = self.owned_src_rows(d)
            halo = int((hr[d] >= 0).sum())
            shards.append(dict(
                shard=d, owned_rows=owned, halo_rows=halo,
                halo_owned_ratio=halo / max(1, owned),
                arena_bytes=self.shard_bytes(d)))
        return dict(shards=shards, halo_pad=self.halo_pad,
                    max_shard_bytes=max(s["arena_bytes"] for s in shards),
                    total_halo_rows=sum(s["halo_rows"] for s in shards),
                    full_arena_bytes=self.full_arena_bytes)


def _relation_halo_counts(plan: RelationPlan, dst: np.ndarray,
                          src: np.ndarray, shard_of: np.ndarray,
                          owner_of: np.ndarray) -> Dict[str, dict]:
    """Per-relation halo accounting for the ``arena.halo_*`` gauges: a halo
    "row" is one distinct (reader shard, source row) pair some cross-shard
    edge of the relation forces into a halo slab; "owned" is the relation's
    distinct source-row working set (same bytes per row, so the row ratio IS
    the byte ratio for the feature slabs the exchange moves)."""
    out = {}
    for seg in plan.segments:
        m = (dst >= seg.out_off) & (dst < seg.out_off + seg.n_dst)
        used = np.unique(src[m])
        cross = shard_of[m] != owner_of[m]
        pairs = np.unique(np.stack([shard_of[m][cross], src[m][cross]],
                                   axis=1), axis=0) if cross.any() else \
            np.zeros((0, 2), np.int64)
        out[seg.etype] = dict(halo_rows=int(pairs.shape[0]),
                              owned_rows=int(used.size))
    return out


def shard_relation_plan(plan: RelationPlan, n_shards: int, *,
                        registry=None) -> ShardedRelationPlan:
    """Partition a super-arena plan into per-shard local arenas + halo
    tables (pure host-side numpy; see module docstring for the layout).

    The partition is by global coordinates, not arena blocks — the fused
    arenas degree-sort rows, so shard slabs are recovered from the exact
    edge set via :func:`plan_to_coo` and re-packed locally at the plan's
    pinned chunk widths.  Sharded plans have NO dense tier (DESIGN.md §14):
    every relation — including ones the single-device plan would route
    dense — shards by destination slab into the per-shard local arenas, so
    the executor stays one exchange + one walk per direction and no dense
    table needs replicating across the mesh.  Emits ``arena.halo_*`` gauges
    into ``registry`` (default: the process registry, DESIGN.md §11).
    """
    n = int(n_shards)
    assert n >= 1, n_shards
    reg = _METRICS if registry is None else registry
    fwd = plan.fwd
    br = fwd.row_block
    n_out, n_src = plan.n_out_total, plan.n_src_total
    t_slab = _ceil_div(n_out, n)
    s_slab = _ceil_div(n_src, n)

    dst, src, w = plan_to_coo(plan)
    shard_of = dst // t_slab
    owner_of = src // s_slab

    # per-shard edge sets + per-owner halo request lists (sorted unique)
    parts, req = [], []
    for d in range(n):
        m = shard_of == d
        sd, ss, sw, own = dst[m] - d * t_slab, src[m], w[m], owner_of[m]
        req.append([np.unique(ss[(own == s) & (own != d)])
                    for s in range(n)])
        parts.append((sd, ss, sw, own))
    h_pad = max(1, max((r.size for row in req for r in row), default=1))
    local_src = s_slab + n * h_pad

    # local re-pack: own rows keep [0, S); halo row j of owner s → S + s·H + j
    fwd_arenas, bwd_arenas = [], []
    for d in range(n):
        sd, ss, sw, own = parts[d]
        loc = ss - d * s_slab
        for s in range(n):
            if s == d or req[d][s].size == 0:
                continue
            m_s = own == s
            loc = np.where(m_s, s_slab + s * h_pad
                           + np.searchsorted(req[d][s], ss), loc)
        fwd_arenas.append(fuse_bucketed(
            pack_ell(sd, loc, sw, t_slab, local_src),
            row_block=br, chunk=fwd.chunk))
        bwd_arenas.append(fuse_bucketed(
            pack_ell(loc, sd, sw, local_src, t_slab),
            row_block=br, chunk=plan.bwd.chunk))

    # pad every shard's arenas to one stacked shape (shard_map uniformity)
    cf = max(f.n_chunks for f in fwd_arenas)
    rf = max(f.n_arena_rows for f in fwd_arenas)
    cb = max(f.n_chunks for f in bwd_arenas)
    rb = max(f.n_arena_rows for f in bwd_arenas)
    fwd_arenas = [pad_fused_arena(f, cf, rf) for f in fwd_arenas]
    bwd_arenas = [pad_fused_arena(f, cb, rb) for f in bwd_arenas]

    send_idx = np.zeros((n, n, h_pad), np.int32)
    halo_rows = np.full((n, n, h_pad), -1, np.int32)
    for d in range(n):
        for s in range(n):
            r = req[d][s]
            if r.size:
                halo_rows[d, s, :r.size] = r
                send_idx[s, d, :r.size] = r - s * s_slab

    stack = lambda key, fs: np.stack([np.asarray(getattr(f, key))
                                      for f in fs])
    splan = ShardedRelationPlan(
        fwd_nbr=stack("nbr", fwd_arenas), fwd_w=stack("w", fwd_arenas),
        fwd_block_of=stack("block_of", fwd_arenas),
        fwd_start=stack("start", fwd_arenas),
        fwd_rows=stack("rows", fwd_arenas),
        fwd_gather=stack("gather", fwd_arenas),
        bwd_nbr=stack("nbr", bwd_arenas), bwd_w=stack("w", bwd_arenas),
        bwd_block_of=stack("block_of", bwd_arenas),
        bwd_start=stack("start", bwd_arenas),
        bwd_rows=stack("rows", bwd_arenas),
        bwd_gather=stack("gather", bwd_arenas),
        send_idx=send_idx, halo_rows=halo_rows,
        n_shards=n, src_slab=s_slab, out_slab=t_slab, halo_pad=h_pad,
        n_src_total=n_src, n_out_total=n_out, row_block=br,
        fwd_chunk=fwd.chunk, bwd_chunk=plan.bwd.chunk,
        full_arena_bytes=_arena_nbytes(fwd) + _arena_nbytes(plan.bwd)
        + np.asarray(plan.bwd_src_rows).nbytes
        + np.asarray(plan.dense_fwd).nbytes
        + np.asarray(plan.dense_bwd).nbytes,
        segments=plan.segments, src_types=plan.src_types,
        src_off=plan.src_off, src_sizes=plan.src_sizes)

    # pack-time observability (DESIGN.md §11): halo pressure per shard and
    # per relation, so layout regressions show up without running a step
    for st in splan.halo_stats()["shards"]:
        d = str(st["shard"])
        reg.set("arena.halo_rows", float(st["halo_rows"]), shard=d)
        reg.set("arena.halo_owned_byte_ratio",
                float(st["halo_owned_ratio"]), shard=d)
        reg.set("arena.shard_bytes", float(st["arena_bytes"]), shard=d)
    for et, st in _relation_halo_counts(plan, dst, src, shard_of,
                                        owner_of).items():
        reg.set("arena.halo_rows", float(st["halo_rows"]), etype=et)
        reg.set("arena.halo_owned_byte_ratio",
                float(st["halo_rows"] / max(1, st["owned_rows"])), etype=et)
    reg.set("arena.halo_pad", float(h_pad), shards=str(n))
    return splan


# ---------------------------------------------------------------------------
# Host-side reference simulators — numpy re-enactments of the exchange the
# executor performs with jax.lax.all_to_all, used by the property suite to
# prove layout correctness without needing a multi-device runtime.
# ---------------------------------------------------------------------------

def _exchange(splan: ShardedRelationPlan, x_pad: np.ndarray,
              d: int) -> np.ndarray:
    """Shard ``d``'s local source slab ``[own | halo]`` under a simulated
    all-to-all: halo slot (s, j) receives owner s's row ``send_idx[s, d, j]``
    — exactly the wire order of the executor's collective."""
    n, s_slab, h = splan.n_shards, splan.src_slab, splan.halo_pad
    send = np.asarray(splan.send_idx)
    own = x_pad[d * s_slab:(d + 1) * s_slab]
    halo = np.concatenate([x_pad[s * s_slab:(s + 1) * s_slab][send[s, d]]
                           for s in range(n)])
    return np.concatenate([own, halo])


def reference_forward(splan: ShardedRelationPlan, x: np.ndarray) -> np.ndarray:
    """Dense-operand sharded forward: y = A @ x re-enacted shard by shard
    (local ``to_dense`` contraction over the exchanged slab).  Matches
    ``plan.fwd.to_dense() @ x`` exactly when the layout is correct."""
    n, s_slab, t_slab = splan.n_shards, splan.src_slab, splan.out_slab
    x = np.asarray(x, np.float32)
    x_pad = np.concatenate(
        [x, np.zeros((n * s_slab - x.shape[0],) + x.shape[1:], np.float32)])
    ys = [np.asarray(splan.local_fwd(d).to_dense(), np.float32)
          @ _exchange(splan, x_pad, d) for d in range(n)]
    return np.concatenate(ys)[:splan.n_out_total]


def reference_backward(splan: ShardedRelationPlan,
                       gy: np.ndarray) -> np.ndarray:
    """Dense-operand sharded backward: dx = Aᵀ @ gy with the reversed halo
    exchange — each shard's halo dx segment is scattered-ADDED back into the
    owner shard's rows, the two-coordinate step DESIGN.md §12 describes."""
    n, s_slab, t_slab, h = (splan.n_shards, splan.src_slab, splan.out_slab,
                            splan.halo_pad)
    gy = np.asarray(gy, np.float32)
    gy_pad = np.concatenate(
        [gy, np.zeros((n * t_slab - gy.shape[0],) + gy.shape[1:],
                      np.float32)])
    send = np.asarray(splan.send_idx)
    dx = np.zeros((n * s_slab,) + gy.shape[1:], np.float32)
    for d in range(n):
        slab = np.asarray(splan.local_bwd(d).to_dense(), np.float32) \
            @ gy_pad[d * t_slab:(d + 1) * t_slab]
        dx[d * s_slab:(d + 1) * s_slab] += slab[:s_slab]
        for s in range(n):            # halo segment travels back to owner s
            seg = slab[s_slab + s * h: s_slab + (s + 1) * h]
            np.add.at(dx[s * s_slab:(s + 1) * s_slab], send[s, d], seg)
    return dx[:splan.n_src_total]
