"""Logical-axis sharding rules (MaxText-style) + device routing helpers.

Models annotate tensors with *logical* axis names; this module maps them to
mesh axes per :data:`RULES`, dropping any mapping whose divisibility fails
(a replicated axis is always correct, never wrong — the roofline analysis
then shows the cost and the perf loop fixes the layout, e.g. by head
padding).

Mesh axes:
    pod    — inter-pod data parallelism (multi-pod mesh only)
    data   — intra-pod data parallelism + FSDP (ZeRO-3) param sharding
    model  — tensor parallelism (heads / mlp / vocab / experts / kv-seq)

Public helpers
--------------
``mesh_context(mesh)`` — scope the active mesh (thread-local); every
``constrain``/``make_pspec`` call inside resolves logical names against it::

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    with mesh_context(mesh), mesh:
        state = init_train_state(...)        # params land FSDP-sharded
        out = train_step(state, batch)       # constrain() sees the mesh

``shard_map_compat(mesh=..., in_specs=..., out_specs=...)`` — decorator
factory over ``jax.shard_map`` that also runs on older jax releases (maps
``check_vma``/``axis_names`` onto ``check_rep``/``auto``)::

    @shard_map_compat(mesh=mesh, in_specs=P("data"), out_specs=P("data"))
    def per_shard(x):                        # x is this device's slice
        return x * 2

``DeviceRing`` / ``batch_devices`` — round-robin routing of *independent*
dispatches (collated serve batches, data-parallel gradient shards) onto the
replica devices of the active mesh, or every local device when no mesh is
set.  Used by serve/circuit_engine.py and train/circuit_trainer.py::

    ring = DeviceRing()                      # one slot per replica device
    i = ring.next_index()                    # thread-safe round-robin
    batch = ring.put(batch, i)               # device_put onto slot i
"""

from __future__ import annotations

import contextlib
import itertools
import threading
import time
from typing import Optional, Sequence, Tuple

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axes (in priority order; multiple axes multiply)
RULES = {
    "batch": ("pod", "data"),
    "sp": ("model",),          # sequence-parallel residual storage
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "embed": ("data",),        # FSDP axis for parameter d_model dims
    "kv_seq": ("model",),      # decode-time KV/state cache sequence sharding
    "ssm_heads": ("model",),
    None: (),
}

_state = threading.local()


def set_mesh(mesh: Optional[Mesh]) -> None:
    _state.mesh = mesh


def get_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def mesh_context(mesh: Optional[Mesh]):
    prev = get_mesh()
    set_mesh(mesh)
    try:
        yield mesh
    finally:
        set_mesh(prev)


def _axes_for(logical: Optional[str], mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in RULES.get(logical, ()) if a in mesh.axis_names)


def make_pspec(shape: Sequence[int], axes: Sequence[Optional[str]],
               mesh: Mesh) -> P:
    """PartitionSpec for ``shape`` under logical ``axes`` — replicating any
    dim whose size is not divisible by its mesh-axis product."""
    assert len(shape) == len(axes), (shape, axes)
    spec = []
    for dim, name in zip(shape, axes):
        mesh_axes = _axes_for(name, mesh)
        size = 1
        for a in mesh_axes:
            size *= mesh.shape[a]
        if mesh_axes and dim % size == 0 and dim > 0:
            spec.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
        else:
            spec.append(None)
    return P(*spec)


def constrain(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without a mesh or
    inside a manual (shard_map) region, where shard_map's specs govern."""
    mesh = get_mesh()
    if mesh is None or mesh.size == 1 or manual_axes():
        return x
    spec = make_pspec(x.shape, axes, mesh)
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    except ValueError as e:
        # Inside a partial-manual shard_map on jax versions where
        # manual-mode detection (manual_axes) is unavailable, constraining
        # a manual axis raises; the shard_map specs govern there — no-op.
        # Any other invalid spec must still fail loudly.
        if "manual" in str(e).lower():
            return x
        raise


def param_sharding(shape: Sequence[int], axes: Sequence[Optional[str]],
                   mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, make_pspec(shape, axes, mesh))


def batch_axes(mesh: Optional[Mesh] = None) -> Tuple[str, ...]:
    mesh = mesh or get_mesh()
    return tuple(a for a in ("pod", "data") if mesh and a in mesh.axis_names)


def batch_devices(mesh: Optional[Mesh] = None) -> Tuple:
    """Devices that can each run an *independent* batch: one per batch-axis
    ("pod" × "data") coordinate of the active mesh — model-axis peers hold
    shards of ONE replica, so only the first device of each model group is a
    routing target — or every local device when no mesh is set."""
    mesh = mesh if mesh is not None else get_mesh()
    if mesh is None:
        return tuple(jax.local_devices())
    dv = np.asarray(mesh.devices)
    ax = batch_axes(mesh)
    if not ax:
        return (dv.flat[0],)
    names = list(mesh.axis_names)
    perm = [names.index(a) for a in ax] + \
           [i for i, n in enumerate(names) if n not in ax]
    n = 1
    for a in ax:
        n *= mesh.shape[a]
    return tuple(np.transpose(dv, perm).reshape(n, -1)[:, 0])


class DeviceRing:
    """Round-robin router for embarrassingly parallel dispatches, with
    per-slot health state.

    Independent collated batches (serving) and gradient shards (data-
    parallel training) have no cross-device dataflow, so routing them onto
    distinct devices is pure throughput.  ``devices=None`` resolves via
    :func:`batch_devices` at construction time; ``next_index`` is a
    thread-safe round-robin counter (callers on packing-pool threads share
    one ring).

    Health (opt-in — nothing changes until a caller reports failures):
    ``record_failure(i)`` / ``record_success(i)`` track consecutive
    failures per slot.  ``quarantine_after`` consecutive failures move a
    slot to ``"quarantined"`` and ``next_index`` routes around it; after
    ``probe_interval_s`` the slot is handed out ONCE as a probe
    (``"probing"``) — a success re-admits it, a failure re-quarantines and
    restarts the probe clock.  With every slot down the ring degrades to
    plain round-robin over all slots (refusing service is strictly worse
    than trying a sick device).  The serve engine is the caller
    (serve/circuit_engine.py containment ladder); DESIGN.md §10."""

    UP, QUARANTINED, PROBING = "up", "quarantined", "probing"

    def __init__(self, devices: Optional[Sequence] = None, *,
                 quarantine_after: int = 3,
                 probe_interval_s: float = 1.0,
                 clock=time.monotonic):
        self.devices = tuple(devices) if devices is not None \
            else batch_devices()
        assert self.devices, "DeviceRing needs at least one device"
        self.quarantine_after = quarantine_after
        self.probe_interval_s = probe_interval_s
        self._clock = clock
        self._count = itertools.count()
        n = len(self.devices)
        self._hlock = threading.Lock()
        self._state = [self.UP] * n
        self._fails = [0] * n               # consecutive failures per slot
        self._since = [0.0] * n             # quarantine timestamp per slot
        self.quarantines = 0
        self.probes = 0
        self.readmissions = 0

    def __len__(self) -> int:
        return len(self.devices)

    def next_index(self) -> int:
        with self._hlock:
            now = self._clock()
            for i, st in enumerate(self._state):
                if st == self.QUARANTINED and \
                        now - self._since[i] >= self.probe_interval_s:
                    # one probe dispatch; PROBING keeps the slot out of the
                    # healthy rotation until the probe resolves
                    self._state[i] = self.PROBING
                    self.probes += 1
                    return i
            healthy = [i for i, st in enumerate(self._state)
                       if st == self.UP]
            if not healthy:                 # no survivors: degrade, serve
                return next(self._count) % len(self.devices)
            return healthy[next(self._count) % len(healthy)]

    def record_failure(self, index: int) -> None:
        """A device-attributable failure on slot ``index`` (dispatch /
        transfer / watchdog timeout — NOT data faults)."""
        with self._hlock:
            i = index % len(self.devices)
            self._fails[i] += 1
            if self._state[i] == self.PROBING:
                self._state[i] = self.QUARANTINED     # probe failed
                self._since[i] = self._clock()
            elif self._state[i] == self.UP and \
                    self._fails[i] >= self.quarantine_after:
                self._state[i] = self.QUARANTINED
                self._since[i] = self._clock()
                self.quarantines += 1

    def release(self, index: int) -> None:
        """The caller obtained ``index`` but never exercised the device
        (e.g. host-side collation failed first).  A probe handout must not
        stay in ``"probing"`` limbo — put it back to ``"quarantined"``
        WITHOUT resetting the probe clock, so the very next ``next_index``
        re-probes; no failure is attributed (the device was untouched)."""
        with self._hlock:
            i = index % len(self.devices)
            if self._state[i] == self.PROBING:
                self._state[i] = self.QUARANTINED

    def record_success(self, index: int) -> None:
        with self._hlock:
            i = index % len(self.devices)
            self._fails[i] = 0
            if self._state[i] != self.UP:
                self._state[i] = self.UP              # probe succeeded
                self.readmissions += 1

    def quarantine(self, index: int) -> None:
        """Force a slot down (ops/bench hook: degraded-mode measurement,
        draining a device for maintenance)."""
        with self._hlock:
            i = index % len(self.devices)
            if self._state[i] == self.UP:
                self.quarantines += 1
            self._state[i] = self.QUARANTINED
            self._since[i] = self._clock()

    @property
    def quarantined(self) -> Tuple[int, ...]:
        with self._hlock:
            return tuple(i for i, st in enumerate(self._state)
                         if st != self.UP)

    def health(self) -> dict:
        """Snapshot for ``stats()``: per-slot state plus lifetime
        quarantine/probe/readmission counters."""
        with self._hlock:
            return dict(states=list(self._state),
                        consecutive_failures=list(self._fails),
                        quarantines=self.quarantines,
                        probes=self.probes,
                        readmissions=self.readmissions)

    def put(self, tree, index: int):
        """``jax.device_put`` a pytree onto ring slot ``index``."""
        return jax.device_put(tree, self.devices[index % len(self.devices)])


_SHARD_MESHES: dict = {}


def shard_mesh(n_shards: int) -> Mesh:
    """1-D ``("shard",)`` mesh over the first ``n_shards`` local devices,
    memoized.  The giant-graph executor (kernels/ops.py::
    drspmm_multi_sharded) keys its jit cache on plan identity; an
    identity-stable mesh keeps those cache entries from splitting."""
    m = _SHARD_MESHES.get(n_shards)
    if m is None:
        devs = jax.local_devices()
        if n_shards > len(devs):
            raise ValueError(
                f"shard_mesh({n_shards}) needs {n_shards} devices, "
                f"{len(devs)} visible — set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={n_shards} "
                f"before the first jax import for virtual CPU devices")
        m = Mesh(np.asarray(devs[:n_shards]), ("shard",))
        _SHARD_MESHES[n_shards] = m
    return m


def shard_map_compat(**kw):
    """Decorator factory over jax.shard_map that also runs on older jax
    releases, where shard_map lives in jax.experimental.shard_map and takes
    ``check_rep`` / ``auto`` instead of ``check_vma`` / ``axis_names``."""
    import functools
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
        if "check_vma" in kw:
            kw["check_rep"] = kw.pop("check_vma")
        if "axis_names" in kw:
            manual = set(kw.pop("axis_names"))
            kw["auto"] = frozenset(kw["mesh"].axis_names) - manual
    return functools.partial(sm, **kw)


def manual_axes() -> Tuple[str, ...]:
    """Mesh axes already in Manual mode (i.e. we are inside a shard_map).
    Nested full-manual shard_maps over a mismatched mesh are rejected by
    JAX, so callers fall back to plain jnp in that case."""
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and m.axis_names:
            return tuple(n for n, t in zip(m.axis_names, m.axis_types)
                         if "Manual" in str(t))
    except Exception:
        pass
    try:
        # jax 0.4.x: shard_map binds its manual axes in the core axis env.
        from jax._src import core as _core
        return tuple(_core.get_axis_env().axis_sizes)
    except Exception:
        return ()
