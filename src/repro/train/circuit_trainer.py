"""End-to-end DR-CircuitGNN trainer for congestion prediction.

Mirrors the paper's experimental protocol (Sec. 4.1): MSE regression on
per-cell congestion, rank-correlation metrics, per-design graph lists, and
the parallel (fused) vs sequential (DGL-analogue) execution toggle.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hetero_mp import HeteroMPConfig
from repro.graphs.circuit import CircuitGraph
from repro.models.hgnn import (DRCircuitGNNParams, drcircuitgnn_forward,
                               init_drcircuitgnn, loss_fn)
from repro.optim import adamw_init, adamw_update, constant
from repro.train import metrics as M


@dataclasses.dataclass
class CircuitTrainConfig:
    hidden: int = 64
    n_layers: int = 2
    k_cell: int = 16
    k_net: int = 16
    auto_k: bool = False              # profile per-graph optimal K (Sec. 4.3)
    lr: float = 2e-4                  # paper's optimal DR-CircuitGNN setup
    weight_decay: float = 1e-5
    epochs: int = 10
    backend: str = "xla"
    use_drelu: bool = True
    seed: int = 0


class CircuitTrainer:
    def __init__(self, cfg: CircuitTrainConfig, f_cell: int, f_net: int):
        self.cfg = cfg
        self.mp_cfg = HeteroMPConfig(hidden=cfg.hidden, k_cell=cfg.k_cell,
                                     k_net=cfg.k_net, backend=cfg.backend,
                                     use_drelu=cfg.use_drelu)
        key = jax.random.PRNGKey(cfg.seed)
        self.params = init_drcircuitgnn(key, f_cell, f_net, cfg.hidden,
                                        cfg.n_layers)
        self.opt_state = adamw_init(self.params)
        self.lr = constant(cfg.lr)
        self._step_fn = self._build_step()

    def _build_step(self):
        mp_cfg, lr, wd = self.mp_cfg, self.lr, self.cfg.weight_decay

        @jax.jit
        def step(params, opt_state, graph: CircuitGraph):
            loss, grads = jax.value_and_grad(loss_fn)(params, graph, mp_cfg)
            params, opt_state = adamw_update(params, grads, opt_state,
                                             lr(opt_state.step),
                                             weight_decay=wd)
            return params, opt_state, loss

        return step

    def train_epoch(self, graphs: List[CircuitGraph]) -> float:
        losses = []
        for g in graphs:
            self.params, self.opt_state, loss = self._step_fn(
                self.params, self.opt_state, g)
            losses.append(float(loss))
        return float(np.mean(losses))

    def profile_k(self, graphs: List[CircuitGraph]) -> Dict[str, int]:
        """The paper's preprocessing profiler (Sec. 4.3): pick the
        cost-model-optimal K per node type from the graphs' degree
        statistics, then rebuild the step function with those K's."""
        import numpy as np
        from repro.core.drelu import profile_optimal_k

        deg_by_src = {"cell": [], "net": []}
        for g in graphs:
            for et, es in g.edges.items():
                src_t = {"near": "cell", "pin": "cell", "pinned": "net"}[et]
                w = np.asarray(es.adj.to_dense())
                deg_by_src[src_t].append((w != 0).sum(1))
        ks = {}
        for t, degs in deg_by_src.items():
            deg = np.concatenate([d[d > 0] for d in degs])
            ks[t] = min(profile_optimal_k(deg, self.cfg.hidden),
                        self.cfg.hidden)
        self.mp_cfg = dataclasses.replace(self.mp_cfg, k_cell=ks["cell"],
                                          k_net=ks["net"])
        self._step_fn = self._build_step()
        return ks

    def fit(self, train_graphs: List[CircuitGraph],
            eval_graphs: Optional[List[CircuitGraph]] = None,
            log_every: int = 1) -> Dict:
        if self.cfg.auto_k:
            ks = self.profile_k(train_graphs)
            print(f"[profile] optimal K per node type: {ks}")
        history = []
        t0 = time.perf_counter()
        for ep in range(self.cfg.epochs):
            loss = self.train_epoch(train_graphs)
            rec = {"epoch": ep, "loss": loss,
                   "wall_s": time.perf_counter() - t0}
            if eval_graphs is not None and (ep + 1) % log_every == 0:
                rec.update(self.evaluate(eval_graphs))
            history.append(rec)
        return {"history": history, "final": history[-1]}

    def evaluate(self, graphs: List[CircuitGraph]) -> Dict[str, float]:
        preds, labels = [], []
        for g in graphs:
            p = drcircuitgnn_forward(self.params, g, self.mp_cfg)
            preds.append(np.asarray(p))
            labels.append(np.asarray(g.y_cell))
        return M.all_metrics(np.concatenate(preds), np.concatenate(labels))
