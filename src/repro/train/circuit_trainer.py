"""End-to-end DR-CircuitGNN trainer for congestion prediction.

Mirrors the paper's experimental protocol (Sec. 4.1): MSE regression on
per-cell congestion, rank-correlation metrics, per-design graph lists, and
the parallel (fused) vs sequential (DGL-analogue) execution toggle.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hetero_mp import HeteroMPConfig, plan_applicable
from repro.fault.inject import FaultInjector
from repro.fault.monitor import StepMonitor
from repro.graphs.circuit import CircuitGraph, relation_plan_of
from repro.graphs.collate import collate_graphs
from repro.kernels import ops
from repro.models.backbone import BackboneSpec
from repro.models.hgnn import (DRCircuitGNNParams, batched_loss_fn,
                               drcircuitgnn_forward, init_drcircuitgnn,
                               loss_fn)
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_RECORDER, Recorder
from repro.optim import adamw_init, adamw_update, constant
from repro.sharding.specs import DeviceRing
from repro.train import metrics as M


@dataclasses.dataclass
class CircuitTrainConfig:
    hidden: int = 64
    n_layers: int = 2
    k_cell: int = 16
    k_net: int = 16
    auto_k: bool = False              # profile per-graph optimal K (Sec. 4.3)
    lr: float = 2e-4                  # paper's optimal DR-CircuitGNN setup
    weight_decay: float = 1e-5
    epochs: int = 10
    backend: str = ops.DEFAULT_BACKEND   # fused path everywhere by default
    use_drelu: bool = True
    # Relation-fused layer dispatch (DESIGN.md §9): single-graph steps
    # attach each graph's RelationPlan (cached per graph, device-resident)
    # so the jitted step runs ONE dispatch per direction-group; collated
    # batches carry plans from the collator.  False pins the serial loop.
    use_plan: bool = True
    # Giant-graph sharded steps (DESIGN.md §12): > 1 partitions each
    # graph's plan over that many mesh devices and the jitted step runs the
    # message passing SPMD with one all-to-all halo exchange per direction
    # — each device holds only its arena slices.  Needs that many visible
    # devices; parity with the single-device plan path:
    # tests/test_sharded_parity.py.
    n_shards: int = 0
    # Dense-tier crossover override threaded to HeteroMPConfig (DESIGN.md
    # §14): None keeps the measured constant; <= -1 forces all-arena.
    dense_threshold: Optional[int] = None
    seed: int = 0
    # graphs per optimizer step: an epoch over a design list is
    # ceil(n/batch_size) collated dispatches instead of n (graphs/collate.py)
    batch_size: int = 1
    # Deep-backbone knobs (models/backbone.py, DESIGN.md §13).  ``n_layers``
    # above is the single depth source of truth end-to-end (it sizes the
    # params AND the spec).  ``remat=True`` checkpoints each hetero layer:
    # the backward recomputes the layer's fused forward instead of storing
    # its activations, so depth-15 trains at roughly depth-3 peak memory
    # (bench_backbone asserts it).  ``wiring`` selects the DeepGEN-style
    # reuse pattern: "plain" | "residual" | "dense".
    remat: bool = False
    wiring: str = "plain"


def _grads_finite(grads) -> jax.Array:
    """Scalar bool: every gradient leaf is NaN/Inf-free (traceable)."""
    return jnp.all(jnp.stack([jnp.all(jnp.isfinite(g))
                              for g in jax.tree.leaves(grads)]))


def _where_tree(ok, new, old):
    """``new`` where ``ok`` else ``old``, leafwise — a skipped step is a
    true no-op (params, moments, AND the opt step counter stay put)."""
    return jax.tree.map(lambda n, o: jnp.where(ok, n, o), new, old)


class CircuitTrainer:
    def __init__(self, cfg: CircuitTrainConfig, f_cell: int, f_net: int, *,
                 chaos: Optional[FaultInjector] = None,
                 monitor: Optional[StepMonitor] = None,
                 registry: Optional[MetricsRegistry] = None,
                 recorder: Optional[Recorder] = None):
        self.cfg = cfg
        self.mp_cfg = HeteroMPConfig(hidden=cfg.hidden, k_cell=cfg.k_cell,
                                     k_net=cfg.k_net, backend=cfg.backend,
                                     use_drelu=cfg.use_drelu,
                                     use_plan=cfg.use_plan,
                                     n_shards=cfg.n_shards,
                                     dense_threshold=cfg.dense_threshold)
        # the backbone spec shares cfg.n_layers with init_drcircuitgnn —
        # one depth knob end-to-end (trainer, examples, benches)
        self.spec = BackboneSpec(depth=cfg.n_layers, hidden=cfg.hidden,
                                 wiring=cfg.wiring, remat=cfg.remat)
        key = jax.random.PRNGKey(cfg.seed)
        self.params = init_drcircuitgnn(key, f_cell, f_net, cfg.hidden,
                                        cfg.n_layers)
        self.opt_state = adamw_init(self.params)
        self.lr = constant(cfg.lr)
        self._step_fn = self._build_step()
        self._batched_step_fn = self._build_batched_step()
        self._grad_fn = self._build_grad()
        self._apply_fn = self._build_apply()
        self._fwd_fn, self._batched_fwd_fn = self._build_fwd_losses()
        self._batch_cache = {}        # id-tuple of member graphs -> device batch
        self._plan_cache = {}         # id(graph) -> plan-attached graph
        # Robustness (DESIGN.md §10): the chaos harness (fault/inject.py)
        # can stall steps; the StepMonitor flags the resulting stragglers
        # (slack -> rebalance -> restart escalation); non-finite-grad steps
        # are skipped in-jit (update frozen leafwise) and counted here.
        self.chaos = chaos
        self.monitor = monitor if monitor is not None \
            else StepMonitor(n_hosts=1)
        # Observability (DESIGN.md §11): per-trainer registry; counters
        # replace the ad-hoc ints but keep attribute-read back-compat via
        # the ``nonfinite_grad_steps`` property below.
        self.metrics = registry if registry is not None else MetricsRegistry()
        self._rec = recorder if recorder is not None else NULL_RECORDER
        if self.chaos is not None and self._rec.enabled:
            self.chaos.recorder = self._rec
        self._c_steps = self.metrics.counter("train.steps")
        self._c_nonfinite = self.metrics.counter("train.nonfinite_grad_steps")
        self._h_step_ms = self.metrics.histogram("train.step_ms")
        # Deep-backbone memory accounting (§11 gauges, backend-guarded —
        # see _peak_memory_bytes / _recompute_ms): peak device bytes after
        # each step, and the per-step recompute-cost estimate remat pays.
        self._g_peak = self.metrics.gauge("train.peak_memory_bytes")
        self._g_recompute = self.metrics.gauge("train.recompute_ms")
        self._fwd_time_cache = {}     # id(step input) -> (pin, est_ms)
        self._global_step = 0

    @property
    def nonfinite_grad_steps(self) -> int:
        """Skipped-step count (back-compat attribute over the registry)."""
        return int(self._c_nonfinite.value)

    def stats(self) -> Dict[str, float]:
        """Registry-backed trainer counters + step-time percentiles."""
        p50, p95, p99 = self._h_step_ms.percentiles((0.50, 0.95, 0.99))
        return {
            "steps": int(self._c_steps.value),
            "nonfinite_grad_steps": int(self._c_nonfinite.value),
            "step_p50_ms": p50, "step_p95_ms": p95, "step_p99_ms": p99,
            "peak_memory_bytes": int(self._g_peak.value),
            "recompute_ms": float(self._g_recompute.value),
        }

    def _peak_memory_bytes(self) -> int:
        """Peak device memory, backend-guarded: real accelerators report
        ``peak_bytes_in_use`` via ``device.memory_stats()``; CPU/interpret
        backends return None there, so the gauge degrades to a live-buffer
        estimate (Σ nbytes over ``jax.live_arrays()``) instead of crashing.
        The deterministic compiled-peak measure (``memory_analysis()``)
        lives in benchmarks/bench_backbone.py."""
        try:
            ms = jax.devices()[0].memory_stats()
            if ms and "peak_bytes_in_use" in ms:
                return int(ms["peak_bytes_in_use"])
        except Exception:
            pass
        try:
            return int(sum(x.nbytes for x in jax.live_arrays()))
        except Exception:
            return 0

    def _recompute_ms(self, fwd_fn, args) -> float:
        """Per-step recompute-cost estimate under remat: the backward
        re-runs each checkpointed layer's forward exactly once, so the
        extra work per step ≈ one forward pass — measured on the jitted
        forward loss once per step input (id-cached, pinned like
        _plan_cache) and emitted as the ``train.recompute_ms`` gauge.
        0.0 with remat off."""
        if not self.cfg.remat:
            return 0.0
        key = id(args[0])
        hit = self._fwd_time_cache.get(key)
        if hit is not None and hit[0] is args[0]:
            return hit[1]
        fwd_fn(self.params, *args).block_until_ready()   # compile warm-up
        t0 = time.perf_counter()
        fwd_fn(self.params, *args).block_until_ready()
        est = (time.perf_counter() - t0) * 1e3
        self._fwd_time_cache[key] = (args[0], est)
        return est

    def _tick(self, duration_s: float, recompute_ms: float = 0.0) -> None:
        """Feed one step's wall-clock to the StepMonitor (host 0 — the
        single-process trainer; multi-host callers own their monitor) and
        refresh the §11 memory/recompute gauges."""
        self.monitor.record(self._global_step, 0, duration_s)
        self._global_step += 1
        self._c_steps.inc()
        self._h_step_ms.observe(duration_s * 1e3)
        self._g_peak.set(self._peak_memory_bytes())
        self._g_recompute.set(recompute_ms)

    def _build_step(self):
        mp_cfg, lr, wd = self.mp_cfg, self.lr, self.cfg.weight_decay
        spec = self.spec

        @jax.jit
        def step(params, opt_state, graph: CircuitGraph):
            loss, grads = jax.value_and_grad(loss_fn)(params, graph, mp_cfg,
                                                      spec)
            ok = _grads_finite(grads)
            new_p, new_o = adamw_update(params, grads, opt_state,
                                        lr(opt_state.step),
                                        weight_decay=wd)
            return (_where_tree(ok, new_p, params),
                    _where_tree(ok, new_o, opt_state), loss, ok)

        return step

    def _build_batched_step(self):
        mp_cfg, lr, wd = self.mp_cfg, self.lr, self.cfg.weight_decay
        spec = self.spec

        @jax.jit
        def step(params, opt_state, graph: CircuitGraph, cell_w):
            loss, grads = jax.value_and_grad(batched_loss_fn)(
                params, graph, cell_w, mp_cfg, spec)
            ok = _grads_finite(grads)
            new_p, new_o = adamw_update(params, grads, opt_state,
                                        lr(opt_state.step),
                                        weight_decay=wd)
            return (_where_tree(ok, new_p, params),
                    _where_tree(ok, new_o, opt_state), loss, ok)

        return step

    def _build_grad(self):
        """Loss+grad over one collated shard — the per-device half of a
        data-parallel step.  Placement follows the committed arguments, so
        dispatching shard d with replica-d params runs on device d."""
        mp_cfg, spec = self.mp_cfg, self.spec

        @jax.jit
        def gfn(params, graph: CircuitGraph, cell_w):
            return jax.value_and_grad(batched_loss_fn)(params, graph,
                                                       cell_w, mp_cfg, spec)

        return gfn

    def _build_fwd_losses(self):
        """Jitted forward-only losses — the measurement probes behind the
        ``train.recompute_ms`` gauge (one forward ≈ the extra work a remat
        backward pays per step)."""
        mp_cfg, spec = self.mp_cfg, self.spec
        f = jax.jit(lambda p, g: loss_fn(p, g, mp_cfg, spec))
        fb = jax.jit(lambda p, g, w: batched_loss_fn(p, g, w, mp_cfg, spec))
        return f, fb

    def _build_apply(self):
        lr, wd = self.lr, self.cfg.weight_decay

        @jax.jit
        def apply(params, opt_state, grads):
            return adamw_update(params, grads, opt_state,
                                lr(opt_state.step), weight_decay=wd)

        return apply

    def _dp_step(self, graphs: List[CircuitGraph], ring: DeviceRing):
        """One data-parallel optimizer step over ``graphs``: members are
        sharded round-robin onto the ring devices, per-shard grads (each a
        mean over its members) dispatch concurrently — independent collated
        batches are embarrassingly parallel, the same property the serve
        engine routes on — then combine as a member-count-weighted mean into
        ONE adamw update.  The gradient equals the single-device batched
        step over the same members (weights 1/(n_shard·n_cell_i) scaled by
        n_shard/n_total compose to 1/(n_total·n_cell_i))."""
        n_dev = min(len(ring), len(graphs))
        shards = [graphs[d::n_dev] for d in range(n_dev)]
        outs, weights = [], []
        for d, shard in enumerate(shards):
            graph, cell_w, n_real = self._collate(shard,
                                                  device=ring.devices[d])
            p_d = jax.device_put(self.params, ring.devices[d])
            outs.append(self._grad_fn(p_d, graph, cell_w))   # async, dev d
            weights.append(n_real)
        total = sum(weights)
        dev0 = ring.devices[0]
        losses = [jax.device_get(loss) for loss, _ in outs]
        grads = jax.tree.map(
            lambda *gs: sum((w / total) * jax.device_put(g, dev0)
                            for w, g in zip(weights, gs)),
            *[g for _, g in outs])
        if not all(np.isfinite(np.asarray(g)).all()
                   for g in jax.tree.leaves(grads)):
            # poisoned shard: skip the whole combined update (the same
            # no-op the jitted steps apply in-trace)
            return float(np.average(losses, weights=weights)), total, False
        self.params, self.opt_state = self._apply_fn(
            jax.device_put(self.params, dev0), self.opt_state, grads)
        return float(np.average(losses, weights=weights)), total, True

    def _planned(self, g: CircuitGraph) -> CircuitGraph:
        """``g`` with its RelationPlan attached and device-resident, cached
        per graph — the jitted step takes the graph as a traced argument,
        so the plan must ride along as pytree leaves (host packing is
        impossible inside the trace); caching the ``device_put`` avoids
        re-uploading the plan's host arrays every step.  The jit cache is
        keyed by shapes, so equal-shaped graphs still share one executable.
        """
        if not plan_applicable(self.mp_cfg, self.cfg.hidden):
            return g
        key = id(g)
        hit = self._plan_cache.get(key)
        if hit is not None and hit[0] is g:
            return hit[1]
        if self.cfg.n_shards > 1:
            # giant-graph step: the partitioned plan's stacked tables are
            # device_put PRE-SHARDED over the ("shard",) mesh, so each
            # device ever holds only its arena slices and the jitted step's
            # shard_map consumes them without resharding
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.graphs.circuit import sharded_plan_of
            from repro.sharding.specs import shard_mesh
            sp = sharded_plan_of(g, self.cfg.n_shards)
            mesh = shard_mesh(self.cfg.n_shards)
            pg = dataclasses.replace(g, plan=jax.device_put(
                sp, NamedSharding(mesh, P("shard"))))
        else:
            pg = dataclasses.replace(
                g, plan=jax.device_put(relation_plan_of(g)))
        self._plan_cache[key] = (g, pg)
        return pg

    def _collate(self, graphs: List[CircuitGraph], device=None):
        """Collate (and device-put) a batch once; reuse across epochs.  The
        quantized fused arenas mean batches of one shape bucket also share
        the jitted step's compiled executable.

        The cache key is the member id-tuple; the entry pins the member
        graphs (so their ids cannot be reused while it lives) and the hit
        path re-checks identity — the same guard _FUSE_CACHE uses."""
        key = (tuple(id(g) for g in graphs), getattr(device, "id", None))
        hit = self._batch_cache.get(key)
        if hit is not None and all(a is b for a, b in zip(hit[0], graphs)):
            return hit[1]
        batch = collate_graphs(graphs)
        entry = (jax.device_put(batch.graph, device),
                 jax.device_put(batch.cell_weight, device), batch.n_real)
        self._batch_cache[key] = (tuple(graphs), entry)
        return entry

    def train_epoch(self, graphs: List[CircuitGraph],
                    batch_size: int = None, devices=None) -> float:
        """One epoch.  ``batch_size > 1`` collates consecutive graphs
        block-diagonally so the epoch is ceil(n/B) dispatches instead of n
        (one optimizer step per *batch*, gradient = mean of member
        losses).

        ``devices`` opts into data-parallel steps: each batch's members are
        sharded over a :class:`DeviceRing` (a device sequence, or ``True``
        for the mesh/local default) and the per-shard grads averaged into
        one update — the serve engine's multi-device dispatch reused for
        training (same math as the single-device batched step)."""
        b = self.cfg.batch_size if batch_size is None else batch_size
        if b <= 1:
            losses = []
            for g in graphs:
                if self.chaos is not None:
                    self.chaos.stall("straggler")
                pg = self._planned(g)
                t_step = time.perf_counter()
                self.params, self.opt_state, loss, ok = self._step_fn(
                    self.params, self.opt_state, pg)
                ok = bool(ok)                  # device barrier ends the step
                self._tick(time.perf_counter() - t_step,
                           self._recompute_ms(self._fwd_fn, (pg,)))
                if not ok:
                    self._c_nonfinite.inc()
                    if self._rec.enabled:
                        self._rec.instant("train", "nonfinite_grads_skip",
                                          step=self._global_step)
                    continue                   # skipped: a true no-op step
                losses.append(float(loss))
            return float(np.mean(losses)) if losses else float("nan")
        ring = None
        if devices is not None:
            ring = DeviceRing(None if devices is True else devices)
        losses, weights = [], []
        for i in range(0, len(graphs), b):
            chunk = graphs[i:i + b]
            if self.chaos is not None:
                self.chaos.stall("straggler")
            t_step = time.perf_counter()
            recompute = 0.0
            if ring is not None and len(chunk) > 1:
                loss, n_real, ok = self._dp_step(chunk, ring)
            else:
                graph, cell_w, n_real = self._collate(chunk)
                self.params, self.opt_state, loss, ok = \
                    self._batched_step_fn(self.params, self.opt_state,
                                          graph, cell_w)
                ok = bool(ok)
                recompute = self._recompute_ms(self._batched_fwd_fn,
                                               (graph, cell_w))
            self._tick(time.perf_counter() - t_step, recompute)
            if not ok:
                self._c_nonfinite.inc()
                if self._rec.enabled:
                    self._rec.instant("train", "nonfinite_grads_skip",
                                      step=self._global_step)
                continue
            losses.append(float(loss))
            weights.append(n_real)
        return float(np.average(losses, weights=weights)) if losses \
            else float("nan")

    def profile_k(self, graphs: List[CircuitGraph]) -> Dict[str, int]:
        """The paper's preprocessing profiler (Sec. 4.3): pick the
        cost-model-optimal K per node type from the graphs' degree
        statistics, then rebuild the step function with those K's."""
        import numpy as np
        from repro.core.drelu import profile_optimal_k

        deg_by_src = {"cell": [], "net": []}
        for g in graphs:
            for et, es in g.edges.items():
                src_t = {"near": "cell", "pin": "cell", "pinned": "net"}[et]
                w = np.asarray(es.adj.to_dense())
                deg_by_src[src_t].append((w != 0).sum(1))
        ks = {}
        for t, degs in deg_by_src.items():
            deg = np.concatenate([d[d > 0] for d in degs])
            ks[t] = min(profile_optimal_k(deg, self.cfg.hidden),
                        self.cfg.hidden)
        self.mp_cfg = dataclasses.replace(self.mp_cfg, k_cell=ks["cell"],
                                          k_net=ks["net"])
        self._step_fn = self._build_step()
        self._batched_step_fn = self._build_batched_step()
        self._grad_fn = self._build_grad()
        self._fwd_fn, self._batched_fwd_fn = self._build_fwd_losses()
        self._fwd_time_cache.clear()
        return ks

    def fit(self, train_graphs: List[CircuitGraph],
            eval_graphs: Optional[List[CircuitGraph]] = None,
            log_every: int = 1) -> Dict:
        if self.cfg.auto_k:
            ks = self.profile_k(train_graphs)
            print(f"[profile] optimal K per node type: {ks}")
        history = []
        t0 = time.perf_counter()
        for ep in range(self.cfg.epochs):
            loss = self.train_epoch(train_graphs)
            rec = {"epoch": ep, "loss": loss,
                   "wall_s": time.perf_counter() - t0}
            if eval_graphs is not None and (ep + 1) % log_every == 0:
                rec.update(self.evaluate(eval_graphs))
            history.append(rec)
        return {"history": history, "final": history[-1]}

    def evaluate(self, graphs: List[CircuitGraph]) -> Dict[str, float]:
        preds, labels = [], []
        for g in graphs:
            p = drcircuitgnn_forward(self.params, g, self.mp_cfg,
                                     self.spec)
            preds.append(np.asarray(p))
            labels.append(np.asarray(g.y_cell))
        return M.all_metrics(np.concatenate(preds), np.concatenate(labels))
