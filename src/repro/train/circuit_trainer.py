"""End-to-end DR-CircuitGNN trainer for congestion prediction.

Mirrors the paper's experimental protocol (Sec. 4.1): MSE regression on
per-cell congestion, rank-correlation metrics, per-design graph lists, and
the parallel (fused) vs sequential (DGL-analogue) execution toggle.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hetero_mp import HeteroMPConfig
from repro.graphs.circuit import CircuitGraph
from repro.graphs.collate import collate_graphs
from repro.kernels import ops
from repro.models.hgnn import (DRCircuitGNNParams, batched_loss_fn,
                               drcircuitgnn_forward, init_drcircuitgnn,
                               loss_fn)
from repro.optim import adamw_init, adamw_update, constant
from repro.train import metrics as M


@dataclasses.dataclass
class CircuitTrainConfig:
    hidden: int = 64
    n_layers: int = 2
    k_cell: int = 16
    k_net: int = 16
    auto_k: bool = False              # profile per-graph optimal K (Sec. 4.3)
    lr: float = 2e-4                  # paper's optimal DR-CircuitGNN setup
    weight_decay: float = 1e-5
    epochs: int = 10
    backend: str = ops.DEFAULT_BACKEND   # fused path everywhere by default
    use_drelu: bool = True
    seed: int = 0
    # graphs per optimizer step: an epoch over a design list is
    # ceil(n/batch_size) collated dispatches instead of n (graphs/collate.py)
    batch_size: int = 1


class CircuitTrainer:
    def __init__(self, cfg: CircuitTrainConfig, f_cell: int, f_net: int):
        self.cfg = cfg
        self.mp_cfg = HeteroMPConfig(hidden=cfg.hidden, k_cell=cfg.k_cell,
                                     k_net=cfg.k_net, backend=cfg.backend,
                                     use_drelu=cfg.use_drelu)
        key = jax.random.PRNGKey(cfg.seed)
        self.params = init_drcircuitgnn(key, f_cell, f_net, cfg.hidden,
                                        cfg.n_layers)
        self.opt_state = adamw_init(self.params)
        self.lr = constant(cfg.lr)
        self._step_fn = self._build_step()
        self._batched_step_fn = self._build_batched_step()
        self._batch_cache = {}        # id-tuple of member graphs -> device batch

    def _build_step(self):
        mp_cfg, lr, wd = self.mp_cfg, self.lr, self.cfg.weight_decay

        @jax.jit
        def step(params, opt_state, graph: CircuitGraph):
            loss, grads = jax.value_and_grad(loss_fn)(params, graph, mp_cfg)
            params, opt_state = adamw_update(params, grads, opt_state,
                                             lr(opt_state.step),
                                             weight_decay=wd)
            return params, opt_state, loss

        return step

    def _build_batched_step(self):
        mp_cfg, lr, wd = self.mp_cfg, self.lr, self.cfg.weight_decay

        @jax.jit
        def step(params, opt_state, graph: CircuitGraph, cell_w):
            loss, grads = jax.value_and_grad(batched_loss_fn)(
                params, graph, cell_w, mp_cfg)
            params, opt_state = adamw_update(params, grads, opt_state,
                                             lr(opt_state.step),
                                             weight_decay=wd)
            return params, opt_state, loss

        return step

    def _collate(self, graphs: List[CircuitGraph]):
        """Collate (and device-put) a batch once; reuse across epochs.  The
        quantized fused arenas mean batches of one shape bucket also share
        the jitted step's compiled executable.

        The cache key is the member id-tuple; the entry pins the member
        graphs (so their ids cannot be reused while it lives) and the hit
        path re-checks identity — the same guard _FUSE_CACHE uses."""
        key = tuple(id(g) for g in graphs)
        hit = self._batch_cache.get(key)
        if hit is not None and all(a is b for a, b in zip(hit[0], graphs)):
            return hit[1]
        batch = collate_graphs(graphs)
        entry = (jax.device_put(batch.graph),
                 jax.device_put(batch.cell_weight), batch.n_real)
        self._batch_cache[key] = (tuple(graphs), entry)
        return entry

    def train_epoch(self, graphs: List[CircuitGraph],
                    batch_size: int = None) -> float:
        """One epoch.  ``batch_size > 1`` collates consecutive graphs
        block-diagonally so the epoch is ceil(n/B) dispatches instead of n
        (one optimizer step per *batch*, gradient = mean of member
        losses)."""
        b = self.cfg.batch_size if batch_size is None else batch_size
        if b <= 1:
            losses = []
            for g in graphs:
                self.params, self.opt_state, loss = self._step_fn(
                    self.params, self.opt_state, g)
                losses.append(float(loss))
            return float(np.mean(losses))
        losses, weights = [], []
        for i in range(0, len(graphs), b):
            graph, cell_w, n_real = self._collate(graphs[i:i + b])
            self.params, self.opt_state, loss = self._batched_step_fn(
                self.params, self.opt_state, graph, cell_w)
            losses.append(float(loss))
            weights.append(n_real)
        return float(np.average(losses, weights=weights))

    def profile_k(self, graphs: List[CircuitGraph]) -> Dict[str, int]:
        """The paper's preprocessing profiler (Sec. 4.3): pick the
        cost-model-optimal K per node type from the graphs' degree
        statistics, then rebuild the step function with those K's."""
        import numpy as np
        from repro.core.drelu import profile_optimal_k

        deg_by_src = {"cell": [], "net": []}
        for g in graphs:
            for et, es in g.edges.items():
                src_t = {"near": "cell", "pin": "cell", "pinned": "net"}[et]
                w = np.asarray(es.adj.to_dense())
                deg_by_src[src_t].append((w != 0).sum(1))
        ks = {}
        for t, degs in deg_by_src.items():
            deg = np.concatenate([d[d > 0] for d in degs])
            ks[t] = min(profile_optimal_k(deg, self.cfg.hidden),
                        self.cfg.hidden)
        self.mp_cfg = dataclasses.replace(self.mp_cfg, k_cell=ks["cell"],
                                          k_net=ks["net"])
        self._step_fn = self._build_step()
        self._batched_step_fn = self._build_batched_step()
        return ks

    def fit(self, train_graphs: List[CircuitGraph],
            eval_graphs: Optional[List[CircuitGraph]] = None,
            log_every: int = 1) -> Dict:
        if self.cfg.auto_k:
            ks = self.profile_k(train_graphs)
            print(f"[profile] optimal K per node type: {ks}")
        history = []
        t0 = time.perf_counter()
        for ep in range(self.cfg.epochs):
            loss = self.train_epoch(train_graphs)
            rec = {"epoch": ep, "loss": loss,
                   "wall_s": time.perf_counter() - t0}
            if eval_graphs is not None and (ep + 1) % log_every == 0:
                rec.update(self.evaluate(eval_graphs))
            history.append(rec)
        return {"history": history, "final": history[-1]}

    def evaluate(self, graphs: List[CircuitGraph]) -> Dict[str, float]:
        preds, labels = [], []
        for g in graphs:
            p = drcircuitgnn_forward(self.params, g, self.mp_cfg)
            preds.append(np.asarray(p))
            labels.append(np.asarray(g.y_cell))
        return M.all_metrics(np.concatenate(preds), np.concatenate(labels))
