"""Distributed train / serve steps for the LM substrate.

``make_train_step`` returns a jit-able (state, batch) -> (state, metrics)
closure with:
  * microbatch gradient accumulation (``grad_accum``) via lax.scan — the
    grads of microbatch i+1 overlap XLA's reduce-scatter of i (latency
    hiding), and the optimizer's cross-replica sync happens once per step;
  * optional int8-compressed cross-pod gradient all-reduce
    (optim/compression.py) for the slow inter-pod links;
  * AdamW + schedule (WSD for minicpm).

``make_serve_steps`` returns (prefill_fn, decode_fn).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.lm import serve
from repro.models.lm.model import LM
from repro.optim import AdamWState, adamw_init, adamw_update, cosine, wsd
from repro.sharding.specs import get_mesh


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def make_schedule(cfg: ArchConfig, lr: float, total_steps: int):
    if cfg.lr_schedule == "wsd":
        return wsd(lr, total_steps)
    return cosine(lr, total_steps, warmup=max(total_steps // 100, 1))


def make_train_step(lm: LM, *, lr: float = 3e-4, total_steps: int = 10_000,
                    weight_decay: float = 0.1, grad_clip: float = 1.0,
                    grad_accum: int = 1,
                    compress_pod_grads: bool = False) -> Callable:
    sched = make_schedule(lm.cfg, lr, total_steps)

    def loss_fn(params, batch):
        return lm.loss(params, batch)

    def value_and_grads(params, batch):
        if grad_accum > 1:
            # batch leading dim = grad_accum microbatches
            def micro(acc, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return jax.tree.map(jnp.add, acc, (l, g)), None

            zeros = (jnp.zeros(()),
                     jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  params))
            (loss, grads), _ = jax.lax.scan(micro, zeros, batch)
            return loss / grad_accum, jax.tree.map(
                lambda g: g / grad_accum, grads)
        return jax.value_and_grad(loss_fn)(params, batch)

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        mesh = get_mesh()
        if compress_pod_grads and not hasattr(jax, "shard_map"):
            # Older jax: the partial-manual (axis_names) shard_map this path
            # needs is emulated via experimental shard_map's `auto`, whose
            # XLA lowering hits a hard CHECK (hlo_sharding_util manual
            # subgroup) — fall back to exact gradients.
            import warnings
            warnings.warn("compress_pod_grads requires jax.shard_map "
                          "(partial-manual); falling back to exact "
                          "gradient all-reduce")
            loss, grads = value_and_grads(state.params, batch)
        elif (compress_pod_grads and mesh is not None
                and "pod" in mesh.axis_names and mesh.shape["pod"] > 1):
            # pod-local grads; explicit int8-compressed all-reduce on the
            # slow inter-pod links.  data/model axes stay auto-sharded.
            from jax.sharding import PartitionSpec as P
            from repro.optim.compression import int8_allreduce_sum
            n_pod = mesh.shape["pod"]

            from repro.sharding.specs import shard_map_compat

            @shard_map_compat(
                mesh=mesh, axis_names={"pod"},
                in_specs=(P(), P("pod")), out_specs=(P(), P()),
                check_vma=False)
            def pod_grads(params, b):
                l, g = value_and_grads(params, b)
                l = jax.lax.pmean(l, "pod")
                g = jax.tree.map(
                    lambda x: int8_allreduce_sum(x, "pod") / n_pod, g)
                return l, g

            loss, grads = pod_grads(state.params, batch)
        else:
            loss, grads = value_and_grads(state.params, batch)

        params, opt = adamw_update(state.params, grads, state.opt,
                                   sched(state.opt.step),
                                   weight_decay=weight_decay,
                                   grad_clip=grad_clip)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        return TrainState(params, opt), {"loss": loss, "grad_norm": gnorm,
                                         "lr": sched(state.opt.step)}

    return train_step


def init_train_state(lm: LM, key) -> TrainState:
    params = lm.init(key)
    return TrainState(params=params, opt=adamw_init(params))


def abstract_train_state(lm: LM) -> TrainState:
    """ShapeDtypeStruct state for dry-run lowering (no allocation)."""
    params = lm.abstract_params()
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return TrainState(
        params=params,
        opt=AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                       m=jax.tree.map(f32, params),
                       v=jax.tree.map(f32, params)))


def train_state_shardings(lm: LM, mesh) -> TrainState:
    ps = lm.param_shardings(mesh)
    from jax.sharding import NamedSharding, PartitionSpec as P
    scalar = NamedSharding(mesh, P())
    return TrainState(params=ps,
                      opt=AdamWState(step=scalar, m=ps, v=ps))


# ---------------------------------------------------------------------------
# serve steps
# ---------------------------------------------------------------------------

def make_serve_steps(lm: LM):
    def prefill_fn(params, tokens, extra=None):
        return serve.prefill(lm, params, tokens, extra)

    def decode_fn(params, cache, token, pos):
        return serve.decode_step(lm, params, cache, token, pos)

    return prefill_fn, decode_fn
