"""Rank-correlation metrics for congestion prediction (paper Sec. 4.1):
Pearson, Spearman, Kendall, plus MAE/RMSE.  Numpy implementations (small N).

Also home of ``percentile``, the nearest-rank latency-stats helper shared by
the serve engine and the benchmarks (keeping it here avoids a
benchmarks→engine import knot)."""

from __future__ import annotations

import numpy as np


def percentile(sorted_values, p: float) -> float:
    """Nearest-rank percentile of an ascending list (0 for empty input)."""
    if not sorted_values:
        return 0.0
    i = min(int(p * (len(sorted_values) - 1)), len(sorted_values) - 1)
    return sorted_values[i]


def median(values) -> float:
    """Midpoint-averaging median (exact for even n; 0 for empty input) —
    the convention the straggler monitor's MAD thresholds were built on
    (fault/monitor.py).  Nearest-rank consumers use
    ``percentile(sorted_values, 0.5)`` instead; these are the repo's only
    two central-tendency definitions."""
    s = sorted(values)
    n = len(s)
    if not n:
        return 0.0
    if n % 2:
        return float(s[n // 2])
    return 0.5 * (float(s[n // 2 - 1]) + float(s[n // 2]))


def pearson(pred, label) -> float:
    p, l = np.asarray(pred, np.float64), np.asarray(label, np.float64)
    p, l = p - p.mean(), l - l.mean()
    den = np.sqrt((p * p).sum() * (l * l).sum())
    return float((p * l).sum() / den) if den > 0 else 0.0


def _ranks(x):
    order = np.argsort(x, kind="stable")
    r = np.empty_like(order, dtype=np.float64)
    r[order] = np.arange(len(x))
    # midranks for ties
    x_sorted = np.asarray(x)[order]
    i = 0
    while i < len(x_sorted):
        j = i
        while j + 1 < len(x_sorted) and x_sorted[j + 1] == x_sorted[i]:
            j += 1
        if j > i:
            r[order[i:j + 1]] = (i + j) / 2.0
        i = j + 1
    return r


def spearman(pred, label) -> float:
    return pearson(_ranks(np.asarray(pred)), _ranks(np.asarray(label)))


def kendall(pred, label, max_n: int = 2000, seed: int = 0) -> float:
    """Kendall tau-b; subsampled above ``max_n`` (O(n²) pairs)."""
    p, l = np.asarray(pred, np.float64), np.asarray(label, np.float64)
    if len(p) > max_n:
        idx = np.random.default_rng(seed).choice(len(p), max_n, replace=False)
        p, l = p[idx], l[idx]
    dp = np.sign(p[:, None] - p[None, :])
    dl = np.sign(l[:, None] - l[None, :])
    iu = np.triu_indices(len(p), 1)
    conc = (dp[iu] * dl[iu])
    n0 = len(conc)
    tp = (dp[iu] == 0).sum()
    tl = (dl[iu] == 0).sum()
    den = np.sqrt((n0 - tp) * (n0 - tl))
    return float(conc.sum() / den) if den > 0 else 0.0


def mae(pred, label) -> float:
    return float(np.abs(np.asarray(pred) - np.asarray(label)).mean())


def rmse(pred, label) -> float:
    return float(np.sqrt(((np.asarray(pred) - np.asarray(label)) ** 2).mean()))


def all_metrics(pred, label) -> dict:
    return dict(pearson=pearson(pred, label), spearman=spearman(pred, label),
                kendall=kendall(pred, label), mae=mae(pred, label),
                rmse=rmse(pred, label))
