"""Minimal stand-in for ``hypothesis`` when it is not installed.

The tier-1 suite must run green on a bare interpreter (the container only
guarantees numpy/jax/pytest).  Test modules import through here::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hyp_fallback import given, settings, strategies as st

With real hypothesis absent, ``@given`` degrades to a deterministic sweep of
``max_examples`` seeded-random draws — no shrinking, no database, but the
same property bodies execute over the same kind of input distribution.

Only the strategy surface the repo's tests use is implemented: ``integers``,
``just``, ``tuples``, ``sampled_from``, ``booleans``, ``flatmap``/``map``.
"""

from __future__ import annotations

import types

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def flatmap(self, f):
        return _Strategy(lambda rng: f(self._draw(rng))._draw(rng))

    def map(self, f):
        return _Strategy(lambda rng: f(self._draw(rng)))


def _integers(lo, hi):
    return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))


def _just(value):
    return _Strategy(lambda rng: value)


def _tuples(*strats):
    return _Strategy(lambda rng: tuple(s._draw(rng) for s in strats))


def _sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])


def _booleans():
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


strategies = types.SimpleNamespace(
    integers=_integers, just=_just, tuples=_tuples,
    sampled_from=_sampled_from, booleans=_booleans)


class settings:  # noqa: N801 — mirrors hypothesis' API
    _profiles = {"default": 25}
    _max_examples = 25

    def __init__(self, *_, **__):
        pass

    @classmethod
    def register_profile(cls, name, max_examples=25, **_):
        cls._profiles[name] = max_examples

    @classmethod
    def load_profile(cls, name):
        cls._max_examples = cls._profiles.get(name, 25)


def given(*strats):
    def deco(test_fn):
        # NB: the wrapper must expose a ZERO-arg signature — pytest resolves
        # named parameters as fixtures, and the drawn arguments are supplied
        # here, not by pytest.  (functools.wraps would leak the original
        # signature via __wrapped__.)
        def wrapper():
            rng = np.random.default_rng(0)
            for _ in range(settings._max_examples):
                drawn = tuple(s._draw(rng) for s in strats)
                test_fn(*drawn)
        wrapper.__name__ = test_fn.__name__
        wrapper.__doc__ = test_fn.__doc__
        return wrapper
    return deco
