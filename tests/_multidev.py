"""Shared multi-device subprocess runner for the tier-1 suite.

XLA's device count locks at the FIRST jax import, so any test needing N > 1
virtual CPU devices must run in a child interpreter with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` set before jax loads.
That boilerplate used to be copy-pasted across test_multidevice.py,
test_online_serve.py and test_obs_integration.py; every multi-device suite
now routes through :func:`run_multidev` (the new sharded-parity harness,
tests/test_sharded_parity.py, included).

The runner returns the completed process so callers can make additional
assertions on stdout (e.g. parse counters the script prints).
"""

import os
import subprocess
import sys

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
_COUNT_FLAG = "--xla_force_host_platform_device_count"


def run_multidev(script: str, n_devices: int = 2, argv=(), expect=(),
                 timeout: float = 1200.0) -> "subprocess.CompletedProcess":
    """Run ``script`` in a child python with ``n_devices`` virtual devices.

    ``argv`` is forwarded as ``sys.argv[1:]`` (stringified); every marker in
    ``expect`` must appear in the child's stdout.  Failures surface both
    stream tails — subprocess assertions are useless without them.
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    # replace (not duplicate) any inherited device-count flag; keep the rest
    flags = [t for t in env.get("XLA_FLAGS", "").split()
             if not t.startswith(_COUNT_FLAG)]
    env["XLA_FLAGS"] = " ".join(flags + [f"{_COUNT_FLAG}={n_devices}"])
    r = subprocess.run([sys.executable, "-c", script,
                        *[str(a) for a in argv]],
                       env=env, capture_output=True, text=True,
                       timeout=timeout)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-4000:])
    for marker in expect:
        assert marker in r.stdout, (marker, r.stdout[-2000:])
    return r
