import os
import sys

# tests must see exactly ONE device (the dry-run sets its own flags in a
# subprocess); make `import repro` work regardless of invocation dir.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
