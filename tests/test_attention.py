"""Attention: chunked-flash vs naive softmax oracle; decode path; padding."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.lm.attention import (chunked_attention, decode_attention,
                                       tile_kv, _local_decode, _pick_chunk)
from repro.models.lm.common import pad_heads, pad_vocab, rope


def naive_attention(q, k, v, causal):
    """O(S²) oracle, f32."""
    qf, kf, vf = (np.asarray(t, np.float64) for t in (q, k, v))
    b, sq, h, hd = qf.shape
    sk = kf.shape[1]
    s = np.einsum("bqhd,bshd->bhqs", qf, kf) / np.sqrt(hd)
    if causal:
        mask = np.tril(np.ones((sq, sk), bool), k=sk - sq)
        s = np.where(mask[None, None], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    return np.einsum("bhqs,bshd->bqhd", p, vf)


@pytest.mark.parametrize("sq,sk,causal,mode", [
    (32, 32, True, "masked"), (32, 32, True, "brick"),
    (32, 32, False, "masked"), (16, 48, False, "masked"),
    (64, 64, True, "brick"), (30, 30, True, "masked"),  # non-pow2
])
def test_chunked_vs_naive(sq, sk, causal, mode):
    rng = np.random.default_rng(sq + sk)
    b, h, hd = 2, 4, 16
    q = rng.normal(size=(b, sq, h, hd)).astype(np.float32)
    k = rng.normal(size=(b, sk, h, hd)).astype(np.float32)
    v = rng.normal(size=(b, sk, h, hd)).astype(np.float32)
    out = chunked_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                            causal=causal, q_chunk=8, kv_chunk=8,
                            causal_mode=mode)
    ref = naive_attention(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-4)


def test_brick_equals_masked():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 64, 4, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 64, 4, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 64, 4, 16)).astype(np.float32))
    a = chunked_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16,
                          causal_mode="masked")
    b = chunked_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16,
                          causal_mode="brick")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_decode_matches_full_attention():
    """decode at pos p == row p of full causal attention."""
    rng = np.random.default_rng(1)
    b, s, h, hd, kv = 2, 24, 4, 16, 2
    k = rng.normal(size=(b, s, kv, hd)).astype(np.float32)
    v = rng.normal(size=(b, s, kv, hd)).astype(np.float32)
    q_all = rng.normal(size=(b, s, h, hd)).astype(np.float32)
    kt = np.asarray(tile_kv(jnp.asarray(k), h))
    vt = np.asarray(tile_kv(jnp.asarray(v), h))
    full = naive_attention(q_all, kt, vt, causal=True)
    pos = 10
    kc = jnp.asarray(np.where(np.arange(s)[None, :, None, None] <= pos, k, 0.0)
                     .astype(np.float32))
    vc = jnp.asarray(np.where(np.arange(s)[None, :, None, None] <= pos, v, 0.0)
                     .astype(np.float32))
    ctx, kc2, vc2 = decode_attention(
        jnp.asarray(q_all[:, pos: pos + 1]), kc, vc,
        jnp.asarray(pos), jnp.asarray(k[:, pos: pos + 1]),
        jnp.asarray(v[:, pos: pos + 1]))
    np.testing.assert_allclose(np.asarray(ctx)[:, 0], full[:, pos],
                               rtol=1e-4, atol=1e-4)


def test_tile_kv_mapping():
    k = jnp.arange(2 * 3 * 2 * 4).reshape(2, 3, 2, 4)
    t = tile_kv(k, 6)
    assert t.shape == (2, 3, 6, 4)
    # q head h reads kv head h % 2
    for h in range(6):
        np.testing.assert_array_equal(np.asarray(t[:, :, h]),
                                      np.asarray(k[:, :, h % 2]))


def test_pad_heads_properties():
    assert pad_heads(16, 8, 16) == (16, 8)       # divisible: unchanged
    h, kv = pad_heads(24, 8, 16)                 # minitron
    assert h % 16 == 0 and h % kv == 0 and h >= 24 and kv == 8
    h, kv = pad_heads(36, 36, 16)                # minicpm MHA
    assert h % 16 == 0 and h == kv
    h, kv = pad_heads(20, 20, 16)                # whisper MHA
    assert h % 16 == 0 and h == kv
    assert pad_heads(64, 8, 16) == (64, 8)       # llama-90b


def test_pad_vocab():
    assert pad_vocab(151936, 16) == 151936       # already divisible
    v = pad_vocab(122753, 16)
    assert v % 16 == 0 and v >= 122753
    assert pad_vocab(122753, 1) == 122753


def test_padded_heads_are_inert():
    """Zero-weight padded q heads must not change the block output."""
    from repro.models.lm.attention import attention_block
    rng = np.random.default_rng(5)
    b, s, d, h, kv, hd = 2, 16, 32, 6, 2, 8
    x = jnp.asarray(rng.normal(size=(b, s, d)).astype(np.float32))
    wq = rng.normal(size=(d, h, hd)).astype(np.float32) * 0.1
    wk = rng.normal(size=(d, kv, hd)).astype(np.float32) * 0.1
    wv = rng.normal(size=(d, kv, hd)).astype(np.float32) * 0.1
    wo = rng.normal(size=(h, hd, d)).astype(np.float32) * 0.1
    out = attention_block(x, jnp.asarray(wq), jnp.asarray(wk),
                          jnp.asarray(wv), jnp.asarray(wo), n_kv=kv)
    # pad q heads 6 -> 8 with zeros (kv unchanged; 8 % 2 == 0)
    wq_p = np.zeros((d, 8, hd), np.float32)
    wq_p[:, :h] = wq
    wo_p = np.zeros((8, hd, d), np.float32)
    wo_p[:h] = wo
    out_p = attention_block(x, jnp.asarray(wq_p), jnp.asarray(wk),
                            jnp.asarray(wv), jnp.asarray(wo_p), n_kv=kv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_p),
                               rtol=1e-5, atol=1e-5)


def test_pick_chunk():
    assert _pick_chunk(1500, 1024) == 750
    assert _pick_chunk(1600, 1024) == 800
    assert _pick_chunk(4096, 1024) == 1024
    assert _pick_chunk(7, 4) == 1


def test_rope_rotation_invariant():
    """RoPE preserves norms and relative-position inner products."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(1, 8, 2, 16)).astype(np.float32))
    pos = jnp.arange(8)[None, :]
    r = rope(x, pos, theta=1e4)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(r), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # relative property: <R(p)q, R(p+k)v> depends only on k
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 16)).astype(np.float32))
    qb = jnp.broadcast_to(q, (1, 8, 1, 16))
    rq = np.asarray(rope(qb, pos, theta=1e4))
    d01 = float((rq[0, 0] * rq[0, 1]).sum())
    d34 = float((rq[0, 3] * rq[0, 4]).sum())
    assert abs(d01 - d34) < 1e-3
