"""The paper's K-profiling preprocessing (Sec. 4.3) wired into the trainer."""

import numpy as np

from repro.graphs.generator import generate_design
from repro.train.circuit_trainer import CircuitTrainConfig, CircuitTrainer


def test_auto_k_profiles_and_trains():
    graphs = generate_design(5, "small", scale=0.03)
    tr = CircuitTrainer(CircuitTrainConfig(epochs=2, hidden=32, auto_k=True),
                        16, 16)
    out = tr.fit(graphs, eval_graphs=graphs)
    # profiled K's must be applied and be valid powers of two <= hidden
    assert tr.mp_cfg.k_cell in (2, 4, 8, 16, 32)
    assert tr.mp_cfg.k_net in (2, 4, 8, 16, 32)
    assert np.isfinite(out["final"]["loss"])


def test_profile_k_prefers_smaller_for_denser_source():
    graphs = generate_design(5, "small", scale=0.04)
    tr = CircuitTrainer(CircuitTrainConfig(hidden=64), 16, 16)
    ks = tr.profile_k(graphs)
    # 'cell'-sourced edges include the heavy-tailed `near` adjacency; the
    # cost model must not pick a larger K for it than for net-sourced edges
    assert ks["cell"] <= ks["net"] * 2
