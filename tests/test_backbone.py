"""Deep-backbone machinery (DESIGN.md §13): spec/wiring/remat semantics,
remat-vs-not numeric parity, executor-cache hygiene under recompute, the
per-layer CBSR hoist, init RNG parity with the pre-backbone code, and the
serve engine's multi-tenant head registry."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hetero_mp import (HeteroMPConfig, _sparsify_types,
                                  init_hetero_layer)
from repro.graphs.collate import collate_graphs, graph_signature
from repro.graphs.generator import generate_design
from repro.kernels import ops
from repro.models.backbone import (BackboneSpec, apply_stack, init_stack,
                                   spec_for)
from repro.models.hgnn import (drcircuitgnn_forward, init_drcircuitgnn,
                               loss_fn)
from repro.serve.circuit_engine import CircuitServeEngine
from repro.train.circuit_trainer import CircuitTrainConfig, CircuitTrainer

CFG = HeteroMPConfig(hidden=32, k_cell=8, k_net=8)


@pytest.fixture(scope="module")
def graph():
    return generate_design(3, "small", scale=0.03)[0]


def _params(graph, depth, hidden=32, seed=0):
    return init_drcircuitgnn(jax.random.PRNGKey(seed),
                             graph.x_cell.shape[1], graph.x_net.shape[1],
                             hidden, n_layers=depth)


# ---------------------------------------------------------------- spec


def test_spec_validates_wiring():
    with pytest.raises(ValueError, match="wiring"):
        BackboneSpec(wiring="helix")


def test_apply_stack_depth_mismatch():
    spec = BackboneSpec(depth=3, hidden=4)
    with pytest.raises(ValueError, match="depth"):
        apply_stack((None,), 0.0, lambda lp, s, c: s, spec)


# ------------------------------------------------- remat numeric parity


def test_remat_parity_deep(graph):
    """Remat is a rematerialization schedule, not a different program:
    loss AND every grad leaf agree with the plain stack at depth 8."""
    depth = 8
    params = _params(graph, depth)
    outs = {}
    for remat in (False, True):
        spec = BackboneSpec(depth=depth, hidden=32, remat=remat)
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, graph, CFG, spec))(params)
        outs[remat] = (float(loss), grads)
    assert np.isclose(outs[True][0], outs[False][0], rtol=1e-6)
    for a, b in zip(jax.tree.leaves(outs[True][1]),
                    jax.tree.leaves(outs[False][1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_remat_trained_params_parity(graph):
    """Two trainers differing ONLY in remat converge to allclose params
    (and the gauges report: remat measures recompute, plain reads 0)."""
    trained, stats = {}, {}
    for remat in (False, True):
        cfg = CircuitTrainConfig(epochs=2, hidden=32, k_cell=8, k_net=8,
                                 n_layers=8, remat=remat)
        tr = CircuitTrainer(cfg, graph.x_cell.shape[1],
                            graph.x_net.shape[1])
        for _ in range(2):
            tr.train_epoch([graph])
        trained[remat] = tr.params
        stats[remat] = tr.stats()
    for a, b in zip(jax.tree.leaves(trained[True]),
                    jax.tree.leaves(trained[False])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
    assert stats[True]["recompute_ms"] > 0.0
    assert stats[False]["recompute_ms"] == 0.0
    assert stats[True]["peak_memory_bytes"] > 0


# ----------------------------------------------------------- wiring


def test_residual_depth1_degenerate(graph):
    """Skips start at the SECOND layer, so every wiring is bit-identical
    to plain at depth 1."""
    params = _params(graph, 1)
    ref = np.asarray(drcircuitgnn_forward(
        params, graph, CFG, BackboneSpec(depth=1, hidden=32)))
    for wiring in ("residual", "dense"):
        got = np.asarray(drcircuitgnn_forward(
            params, graph, CFG,
            BackboneSpec(depth=1, hidden=32, wiring=wiring)))
        np.testing.assert_array_equal(got, ref, err_msg=wiring)


def test_wiring_changes_deep_forward(graph):
    """At depth 3 the skip wirings are real different functions."""
    params = _params(graph, 3)
    preds = {w: np.asarray(drcircuitgnn_forward(
        params, graph, CFG, BackboneSpec(depth=3, hidden=32, wiring=w)))
        for w in ("plain", "residual", "dense")}
    assert np.abs(preds["residual"] - preds["plain"]).max() > 1e-6
    assert np.abs(preds["dense"] - preds["residual"]).max() > 1e-6


def test_residual_wiring_grads_flow(graph):
    """Residual stacks train: grads reach the FIRST layer and are not
    degenerate at depth 8 (the wiring's reason to exist)."""
    params = _params(graph, 8)
    spec = BackboneSpec(depth=8, hidden=32, wiring="residual", remat=True)
    grads = jax.grad(lambda p: loss_fn(p, graph, CFG, spec))(params)
    g0 = np.concatenate([np.asarray(x).ravel()
                         for x in jax.tree.leaves(grads.layers[0])])
    assert np.abs(g0).max() > 0


# ------------------------------------------------- executor-cache hygiene


def test_remat_no_retrace(graph):
    """Checkpoint bodies always trace, so remat must route around the
    id-keyed executor LRU (ops._MULTI_EXE) — recompute cannot thrash or
    grow it — and the jitted step compiles exactly once."""
    params = _params(graph, 4)
    drcircuitgnn_forward(params, graph, CFG)      # concrete warm-up entry
    n0 = len(ops._MULTI_EXE)
    assert n0 > 0
    spec = BackboneSpec(depth=4, hidden=32, remat=True)
    step = jax.jit(jax.grad(lambda p: loss_fn(p, graph, CFG, spec)))
    step(params)
    jax.block_until_ready(step(params))
    assert len(ops._MULTI_EXE) == n0
    if callable(getattr(step, "_cache_size", None)):
        assert step._cache_size() == 1


# ------------------------------------------------------ CBSR hoist


def _count_topk(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "top_k":
            n += 1
        for sub in jax.core.jaxprs_in_params(eqn.params):
            n += _count_topk(sub)
    return n


def test_cbsr_shared_per_type_dispatch_count(graph):
    """The serial path sparsifies each node type ONCE per layer (near and
    pin both read the cell slab): total top_k work is depth × (one
    two-type sparsification) + one per inter-layer D-ReLU pair — not the
    3-per-layer of re-deriving CBSR per relation."""
    cfg = HeteroMPConfig(hidden=32, k_cell=8, k_net=8, use_plan=False)
    depth = 3
    params = _params(graph, depth)
    x_cell = jnp.zeros((graph.n_cell, 32))
    x_net = jnp.zeros((graph.n_net, 32))
    per_layer = _count_topk(jax.make_jaxpr(
        lambda a, b: _sparsify_types(a, b, cfg))(x_cell, x_net).jaxpr)
    act = _count_topk(jax.make_jaxpr(
        lambda a, b: (jax.tree.map(lambda v: v, a), b))(x_cell, x_net).jaxpr)
    assert act == 0 and per_layer > 0
    # the inter-layer activation is D-ReLU too: one more two-type pass
    from repro.core.drelu import drelu
    act_pair = _count_topk(jax.make_jaxpr(
        lambda a, b: (drelu(a, 8), drelu(b, 8)))(x_cell, x_net).jaxpr)
    spec = BackboneSpec(depth=depth, hidden=32)
    total = _count_topk(jax.make_jaxpr(
        lambda p: drcircuitgnn_forward(p, graph, cfg, spec))(params).jaxpr)
    assert total == depth * (per_layer + act_pair), \
        (total, depth, per_layer, act_pair)


# ------------------------------------------------------ init parity


def test_init_stack_rng_parity():
    """init_drcircuitgnn's RNG stream is pinned to the pre-backbone split
    pattern: split(key, L+3) with inputs at ks[0:2], layer i at ks[2+i],
    head at ks[-1]."""
    key, hidden, fc, fn, L = jax.random.PRNGKey(42), 16, 8, 12, 4
    p = init_drcircuitgnn(key, fc, fn, hidden, n_layers=L)
    ks = jax.random.split(key, L + 3)
    s_c = 1.0 / jnp.sqrt(fc)
    np.testing.assert_array_equal(
        np.asarray(p.in_cell),
        np.asarray(jax.random.uniform(ks[0], (fc, hidden), jnp.float32,
                                      -s_c, s_c)))
    for i in range(L):
        ref = init_hetero_layer(ks[2 + i], hidden)
        for a, b in zip(p.layers[i], ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    s_h = 1.0 / jnp.sqrt(hidden)
    np.testing.assert_array_equal(
        np.asarray(p.head_w),
        np.asarray(jax.random.uniform(ks[-1], (hidden, 1), jnp.float32,
                                      -s_h, s_h)))


def test_init_stack_key_layout():
    pre, layers, post = init_stack(jax.random.PRNGKey(1), 3,
                                   lambda k, i: (i, k), n_pre=2, n_post=1)
    assert len(pre) == 2 and len(layers) == 3 and len(post) == 1
    assert [i for i, _ in layers] == [0, 1, 2]
    ks = jax.random.split(jax.random.PRNGKey(1), 6)
    np.testing.assert_array_equal(np.asarray(layers[0][1]),
                                  np.asarray(ks[2]))


# ------------------------------------------- signatures are data-only


def test_signature_depth_independent(graph):
    """Batch/bucket signatures depend on the DATA alone — depth, wiring,
    and remat never enter, so flipping the backbone can't invalidate
    collated layouts."""
    sigs = []
    for n_layers, remat, wiring in ((2, False, "plain"),
                                    (15, True, "dense")):
        cfg = CircuitTrainConfig(hidden=32, k_cell=8, k_net=8,
                                 n_layers=n_layers, remat=remat,
                                 wiring=wiring)
        tr = CircuitTrainer(cfg, graph.x_cell.shape[1],
                            graph.x_net.shape[1])
        sigs.append(graph_signature(tr._planned(graph)))
    assert sigs[0] == sigs[1]
    assert (collate_graphs([graph, graph]).signature
            == collate_graphs([graph, graph]).signature)


# -------------------------------------------------- head registry


def test_head_registry_shares_backbone_zero_compiles(graph):
    """Two named heads + the default share ONE backbone and ONE compiled
    executable per (signature, device): serving all three costs exactly
    one compile, selection is per request, and results match calling the
    forward with that head's weights directly."""
    params = _params(graph, 3)
    spec = BackboneSpec(depth=3, hidden=32, wiring="residual")
    eng = CircuitServeEngine(params, CFG, spec=spec, max_batch=2)
    hw_a = jax.random.uniform(jax.random.PRNGKey(7), params.head_w.shape,
                              jnp.float32, -0.2, 0.2)
    eng.register_head("taskA", hw_a)
    eng.register_head("taskB", -hw_a, params.head_b + 0.5)
    assert eng.heads == ("taskA", "taskB")

    rids = {h: eng.submit(graph, head=h) for h in (None, "taskA", "taskB")}
    eng.run()
    preds = {h: eng.result(r).pred for h, r in rids.items()}
    st = eng.stats()
    assert st["requests"] == 3
    assert st["compiles"] == 1, st["compiles"]   # heads share the compile

    # per-request selection really happened
    assert np.abs(preds["taskA"] - preds["taskB"]).max() > 1e-3
    assert np.abs(preds["taskA"] - preds[None]).max() > 1e-3
    ref = np.asarray(drcircuitgnn_forward(
        params._replace(head_w=hw_a), graph, CFG, spec))
    np.testing.assert_allclose(preds["taskA"], ref, atol=1e-5)

    # unknown heads bounce at the door; bad shapes bounce at registration
    with pytest.raises(KeyError, match="unknown head"):
        eng.submit(graph, head="nope")
    with pytest.raises(ValueError, match="shapes"):
        eng.register_head("bad", jnp.zeros((7, 1)))


def test_head_registry_survives_update_params(graph):
    """update_params swaps the backbone+default head but leaves registered
    heads (independent replicas) serving — still zero new compiles for a
    same-bucket stream."""
    params = _params(graph, 2, seed=0)
    eng = CircuitServeEngine(params, CFG, max_batch=1)
    hw = jax.random.uniform(jax.random.PRNGKey(9), params.head_w.shape,
                            jnp.float32, -0.3, 0.3)
    eng.register_head("fixed", hw)
    r0 = eng.submit(graph, head="fixed")
    eng.run()
    before = eng.result(r0).pred
    c0 = eng.stats()["compiles"]

    eng.update_params(_params(graph, 2, seed=1))
    assert eng.heads == ("fixed",)
    r1 = eng.submit(graph, head="fixed")
    r2 = eng.submit(graph)
    eng.run()
    after = eng.result(r1).pred
    default_after = eng.result(r2).pred
    assert eng.stats()["compiles"] == c0        # swap + heads: no compiles
    # new backbone under the same registered head -> different features
    assert np.abs(after - before).max() > 1e-6
    assert np.abs(after - default_after).max() > 1e-6
