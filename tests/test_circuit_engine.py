"""CircuitServeEngine: compile-once batched serving + batched training.

The acceptance property: a mixed-size request stream is processed to
completion with at most one compile per shape bucket, and every request's
prediction equals what its graph produces alone.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hetero_mp import HeteroMPConfig
from repro.graphs.generator import generate_design, generate_partition, \
    pack_graph_parallel
from repro.models.hgnn import (drcircuitgnn_forward, init_drcircuitgnn,
                               loss_fn)
from repro.serve import CircuitServeEngine
from repro.train.circuit_trainer import CircuitTrainConfig, CircuitTrainer


def _graph(n_cell, n_net, seed):
    coo, xc, xn, y = generate_partition(np.random.default_rng(seed),
                                        n_cell, n_net)
    return pack_graph_parallel(coo, n_cell, n_net, xc, xn, y)


@pytest.fixture(scope="module")
def model():
    cfg = HeteroMPConfig(hidden=32, k_cell=8, k_net=8, backend="xla_fused")
    params = init_drcircuitgnn(jax.random.PRNGKey(0), 16, 16, 32)
    return params, cfg


@pytest.fixture(scope="module")
def mixed_stream():
    """Two size classes, sizes jittered within each, interleaved — the
    adversarial case for per-graph compilation."""
    rng = np.random.default_rng(7)
    small = [_graph(int(rng.integers(55, 64)), int(rng.integers(28, 32)), s)
             for s in range(6)]
    med = [_graph(int(rng.integers(110, 120)), int(rng.integers(56, 62)),
                  100 + s) for s in range(6)]
    return [g for pair in zip(small, med) for g in pair]


def test_mixed_queue_one_compile_per_bucket(model, mixed_stream):
    params, cfg = model
    eng = CircuitServeEngine(params, cfg, max_batch=3)
    rids = [eng.submit(g) for g in mixed_stream]
    out = eng.run()

    # everything finished
    assert set(out) == set(rids)
    # two size classes -> at most one compile each
    assert eng.compiles <= 2, eng.stats()
    st = eng.stats()
    assert st["batches"] == 4 and st["requests"] == len(mixed_stream)
    # the engine's signature counter is honest: it equals the number of
    # executables jit actually built
    if "jit_cache_size" in st:
        assert st["jit_cache_size"] == eng.compiles

    # per-request isolation: batched prediction == the graph served alone
    for rid, g in zip(rids, mixed_stream):
        ref = np.asarray(drcircuitgnn_forward(params, g, cfg))
        np.testing.assert_allclose(out[rid].pred, ref, atol=1e-5, rtol=1e-5,
                                   err_msg=f"request {rid}")


def test_partial_batch_filler_is_inert(model):
    """A batch with fewer requests than max_batch is padded with filler
    members; fillers keep the full-batch signature and never surface."""
    params, cfg = model
    eng = CircuitServeEngine(params, cfg, max_batch=4)
    graphs = [_graph(40, 20, i) for i in range(3)]
    rids = [eng.submit(g) for g in graphs]
    out = eng.run()
    assert len(out) == 3 and eng.stats()["batches"] == 1
    for rid, g in zip(rids, graphs):
        ref = np.asarray(drcircuitgnn_forward(params, g, cfg))
        np.testing.assert_allclose(out[rid].pred, ref, atol=1e-5, rtol=1e-5)

    # a later FULL batch of the same bucket reuses the executable
    eng2_rids = [eng.submit(_graph(41, 21, 10 + i)) for i in range(4)]
    eng.run()
    assert eng.compiles == 1, eng.stats()


def test_batcher_keeps_skipped_requests(model):
    """Requests that don't match the FIFO head's bucket keep their order
    and are served by a later batch — nothing is dropped or starved."""
    params, cfg = model
    eng = CircuitServeEngine(params, cfg, max_batch=2)
    gs = [_graph(40, 20, 0), _graph(120, 60, 1), _graph(41, 21, 2),
          _graph(118, 59, 3), _graph(39, 19, 4)]
    rids = [eng.submit(g) for g in gs]
    out = eng.run()
    assert set(out) == set(rids)
    assert eng.stats()["batches"] == 3          # {0,2}, {1,3}, {4}


def test_latency_and_throughput_stats(model, mixed_stream):
    params, cfg = model
    eng = CircuitServeEngine(params, cfg, max_batch=3)
    for g in mixed_stream[:6]:
        eng.submit(g)
    eng.run()
    st = eng.stats()
    assert st["graphs_per_s"] > 0
    assert 0 < st["p50_ms"] <= st["p95_ms"]
    assert st["cell_padding_ratio"] >= 1.0


# --------------------------- batched training ---------------------------

def test_train_epoch_batched_matches_quality():
    """batch_size=B trains the same task to a comparable loss with
    ceil(n/B) dispatches, and the collation cache makes later epochs reuse
    the device-resident batches."""
    graphs = generate_design(0, "small", scale=0.03) \
        + generate_design(1, "small", scale=0.03)
    f_cell, f_net = graphs[0].x_cell.shape[1], graphs[0].x_net.shape[1]

    tr = CircuitTrainer(CircuitTrainConfig(hidden=32, batch_size=2,
                                           epochs=4), f_cell, f_net)
    first = tr.train_epoch(graphs)
    assert np.isfinite(first)
    assert len(tr._batch_cache) == 2            # ceil(4/2) batches collated
    for _ in range(3):
        last = tr.train_epoch(graphs)
    assert len(tr._batch_cache) == 2            # reused, not re-collated
    assert last < first                          # it actually learns

    # explicit batch_size overrides the config default
    seq_loss = tr.train_epoch(graphs, batch_size=1)
    assert np.isfinite(seq_loss)


def test_batched_and_sequential_start_from_same_loss():
    """First-step losses agree: the batched loss is the mean of member
    losses (gradient-level parity is test_collate.py's job)."""
    graphs = generate_design(3, "small", scale=0.03)[:2]
    f_cell, f_net = graphs[0].x_cell.shape[1], graphs[0].x_net.shape[1]
    cfg = CircuitTrainConfig(hidden=32, seed=5)
    a = CircuitTrainer(cfg, f_cell, f_net)
    b = CircuitTrainer(cfg, f_cell, f_net)
    la = a.train_epoch(graphs, batch_size=2)    # one batched step
    lb = np.mean([float(loss_fn(b.params, g, b.mp_cfg)) for g in graphs])
    assert abs(la - lb) < 1e-5
