"""Block-diagonal collation (graphs/collate.py).

A collated batch must be numerically indistinguishable from its members run
one at a time: forward and gradients match per-graph results under every
backend, quantization padding contributes exactly zero to member outputs,
and the member offsets tile the merged node spaces without overlap.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # bare container: seeded fallback
    from _hyp_fallback import given, settings, strategies as st

from repro.core.hetero_mp import HeteroMPConfig
from repro.graphs.collate import (BucketLayout, collate_graphs,
                                  quantize_up)
from repro.graphs.ell import ell_to_coo, pack_ell, pick_chunk
from repro.graphs.generator import generate_partition, pack_graph_parallel
from repro.models.hgnn import (batched_loss_fn, drcircuitgnn_forward,
                               init_drcircuitgnn, loss_fn)

settings.register_profile("fast", max_examples=20, deadline=None)
settings.load_profile("fast")

BACKENDS = ("pallas_fused", "xla_fused", "pallas", "xla", "dense")


def _graph(n_cell, n_net, seed):
    coo, xc, xn, y = generate_partition(np.random.default_rng(seed),
                                        n_cell, n_net)
    return pack_graph_parallel(coo, n_cell, n_net, xc, xn, y)


@pytest.fixture(scope="module")
def members():
    return [_graph(60, 30, 0), _graph(101, 55, 1), _graph(37, 20, 2)]


@pytest.fixture(scope="module")
def params():
    return init_drcircuitgnn(jax.random.PRNGKey(0), 16, 16, 32)


def _cfg(backend):
    return HeteroMPConfig(hidden=32, k_cell=8, k_net=8, backend=backend)


def _assert_close(actual, ref, msg):
    atol = 1e-5 * max(1.0, float(np.abs(ref).max()) if ref.size else 1.0)
    np.testing.assert_allclose(actual, ref, atol=atol, rtol=1e-5,
                               err_msg=msg)


@pytest.mark.parametrize("backend", BACKENDS)
def test_batched_forward_matches_member_loop(members, params, backend):
    """Exact (unquantized, bucketed) collation: every backend sees the same
    block-diagonal graph and must reproduce the per-member forwards."""
    cfg = _cfg(backend)
    batch = collate_graphs(members, fused=False, quantize=False)
    parts = batch.split_cell(drcircuitgnn_forward(params, batch.graph, cfg))
    for i, (g, p) in enumerate(zip(members, parts)):
        ref = np.asarray(drcircuitgnn_forward(params, g, cfg))
        _assert_close(np.asarray(p), ref, f"member {i} fwd {backend}")


@pytest.mark.parametrize("backend", BACKENDS)
def test_batched_gradients_match_member_loop(members, params, backend):
    """∇ of the weighted batched loss == ∇ of the mean of per-graph mean-MSE
    losses — the property that makes train_epoch(batch_size=B) a drop-in."""
    cfg = _cfg(backend)
    batch = collate_graphs(members, fused=False, quantize=False)
    g_b = jax.grad(batched_loss_fn)(params, batch.graph, batch.cell_weight,
                                    cfg)
    g_ref = None
    for g in members:
        gi = jax.grad(loss_fn)(params, g, cfg)
        g_ref = gi if g_ref is None else jax.tree_util.tree_map(
            jnp.add, g_ref, gi)
    g_ref = jax.tree_util.tree_map(lambda x: x / len(members), g_ref)
    for (pa, a), (_, r) in zip(
            jax.tree_util.tree_leaves_with_path(g_b),
            jax.tree_util.tree_leaves_with_path(g_ref)):
        _assert_close(np.asarray(a), np.asarray(r),
                      f"grad {jax.tree_util.keystr(pa)} {backend}")


@pytest.mark.parametrize("backend", ["xla_fused", "pallas_fused"])
def test_quantization_padding_is_invariant(members, params, backend):
    """Padded node rows / arena chunks contribute zero: the quantized fused
    collation reproduces the exact collation on every member slice.  Runs
    both fused executors — the Pallas kernel must tolerate the padding
    chunks extending the sentinel block's run."""
    cfg = _cfg(backend)
    exact = collate_graphs(members, fused=False, quantize=False)
    quant = collate_graphs(members, fused=True, quantize=True)
    assert quant.graph.n_cell >= exact.graph.n_cell
    p_exact = exact.split_cell(drcircuitgnn_forward(params, exact.graph, cfg))
    p_quant = quant.split_cell(drcircuitgnn_forward(params, quant.graph, cfg))
    for i, (a, b) in enumerate(zip(p_exact, p_quant)):
        _assert_close(np.asarray(b), np.asarray(a), f"member {i} padding")
    # gradients flow identically through the padded arenas
    g_e = jax.grad(batched_loss_fn)(params, exact.graph, exact.cell_weight,
                                    cfg)
    g_q = jax.grad(batched_loss_fn)(params, quant.graph, quant.cell_weight,
                                    cfg)
    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(g_e),
            jax.tree_util.tree_leaves_with_path(g_q)):
        _assert_close(np.asarray(b), np.asarray(a),
                      f"grad {jax.tree_util.keystr(pa)} padding")


def test_fused_collation_runs_fused_inside_jit(members, params):
    """The whole point of pre-fused arenas: the batched forward keeps the
    fused executor even when the graph is a traced jit argument (no
    per-bucket fallback, no recompile for an equal-signature batch)."""
    cfg = _cfg("xla_fused")
    batch = collate_graphs(members, fused=True, quantize=True)

    fwd = jax.jit(lambda p, g: drcircuitgnn_forward(p, g, cfg))
    y1 = fwd(params, batch.graph)
    ref = np.asarray(drcircuitgnn_forward(params, batch.graph, cfg))
    _assert_close(np.asarray(y1), ref, "jitted batched fwd")
    if hasattr(fwd, "_cache_size"):
        # same-signature batch (different member sizes, same buckets) must
        # hit the compiled executable
        other = collate_graphs([_graph(62, 31, 7), _graph(99, 56, 8),
                                _graph(36, 20, 9)],
                               fused=True, quantize=True)
        if other.signature == batch.signature:
            fwd(params, other.graph)
            assert fwd._cache_size() == 1


def test_cell_weight_normalization(members):
    batch = collate_graphs(members)
    w = np.asarray(batch.cell_weight)
    assert abs(w.sum() - 1.0) < 1e-5
    # weight is zero exactly off the member slices
    mask = np.zeros(batch.graph.n_cell, bool)
    for m in batch.members:
        mask[m.cell_off:m.cell_off + m.n_cell] = True
    assert (w[~mask] == 0).all() and (w[mask] > 0).all()


def test_filler_members_have_zero_weight(members):
    batch = collate_graphs(members + [members[-1]], n_real=len(members))
    assert batch.n_real == len(members)
    assert len(batch.split_cell(jnp.zeros(batch.graph.n_cell))) == len(members)
    w = np.asarray(batch.cell_weight)
    filler = batch.members[-1]
    assert (w[filler.cell_off:filler.cell_off + filler.n_cell] == 0).all()
    assert abs(w.sum() - 1.0) < 1e-5


def test_quantize_up_grid():
    assert quantize_up(17, 2) == 20
    assert quantize_up(16, 2) == 16
    assert quantize_up(1000, 2) == 1024
    assert quantize_up(5, 2, minimum=8) == 8
    # monotone, idempotent on grid points, bounded padding
    for bits in (1, 2, 3):
        for n in range(8, 4000, 37):
            q = quantize_up(n, bits)
            assert q >= n
            assert quantize_up(q, bits) == q
            assert q <= n * (1 + 2.0 ** -bits) + 1


def test_pick_chunk_follows_degree_histogram():
    """ROADMAP item: narrow pin/pinned fan-outs should get a narrow chunk,
    heavy-tailed rows a wide one — slot-minimizing per packing."""
    rng = np.random.default_rng(0)
    # fan-outs 2–4 (pin-like)
    dst = np.repeat(np.arange(64), 3)
    adj_narrow = pack_ell(dst, rng.integers(0, 64, dst.size), None, 64, 64)
    assert pick_chunk(adj_narrow) == 4
    # uniformly heavy rows (near-like evil bulk)
    dst = np.repeat(np.arange(32), 64)
    adj_wide = pack_ell(dst, rng.integers(0, 256, dst.size), None, 32, 256)
    assert pick_chunk(adj_wide) == 16


# --------------------- offset round-trip property ----------------------

member_lists = st.integers(0, 2 ** 31 - 1).flatmap(lambda seed: st.tuples(
    st.just(seed), st.integers(1, 4), st.booleans()))


@given(member_lists)
def test_collate_offsets_roundtrip(args):
    """The collated adjacency is exactly the block-diagonal direct sum: each
    member's dense matrix reappears at its offsets, and nothing appears
    outside the member blocks."""
    seed, n_members, quantize = args
    rng = np.random.default_rng(seed)
    members = [_graph(int(rng.integers(12, 48)), int(rng.integers(6, 24)),
                      int(rng.integers(0, 2 ** 31))) for _ in range(n_members)]
    batch = collate_graphs(members, fused=False, quantize=quantize)
    g = batch.graph
    off = {"cell": [m.cell_off for m in batch.members],
           "net": [m.net_off for m in batch.members]}
    n_of = {"cell": [m.n_cell for m in batch.members],
            "net": [m.n_net for m in batch.members]}
    from repro.graphs.circuit import EDGE_SCHEMA
    for et, es in g.edges.items():
        s_t, d_t = EDGE_SCHEMA[et]
        dense = np.asarray(es.adj.to_dense())
        covered = np.zeros_like(dense, bool)
        for i, m in enumerate(members):
            ds, de = off[d_t][i], off[d_t][i] + n_of[d_t][i]
            ss, se = off[s_t][i], off[s_t][i] + n_of[s_t][i]
            block = dense[ds:de, ss:se]
            np.testing.assert_allclose(
                block, np.asarray(m.edges[et].adj.to_dense()), atol=1e-6,
                err_msg=f"{et} member {i}")
            covered[ds:de, ss:se] = True
        assert dense[~covered].sum() == 0, f"{et}: mass outside blocks"
        # transposed packing is consistent
        np.testing.assert_allclose(np.asarray(es.adj_t.to_dense()).T, dense,
                                   atol=1e-6, err_msg=f"{et} adj_t")


def test_ell_to_coo_roundtrip():
    rng = np.random.default_rng(4)
    dst = rng.integers(0, 40, 200)
    src = rng.integers(0, 30, 200)
    pairs = np.unique(np.stack([dst, src], 1), axis=0)
    w = rng.normal(size=pairs.shape[0]).astype(np.float32)
    w[w == 0] = 1.0
    adj = pack_ell(pairs[:, 0], pairs[:, 1], w, 40, 30)
    d2, s2, w2 = ell_to_coo(adj)
    a = np.zeros((40, 30), np.float32)
    np.add.at(a, (d2, s2), w2)
    np.testing.assert_allclose(a, np.asarray(adj.to_dense()), atol=1e-6)


def test_signature_stability_within_bucket():
    """Graphs jittered within one size class collate to the same padded
    shape signature when a shared BucketLayout pins the arena layout — the
    property the serve engine's compile cache rests on (engine-level
    assertion lives in test_circuit_engine.py)."""
    layout = BucketLayout()
    b1 = collate_graphs([_graph(60, 30, 0), _graph(58, 29, 1)],
                        node_bits=1, layout=layout)
    b2 = collate_graphs([_graph(63, 31, 2), _graph(59, 28, 3)],
                        node_bits=1, layout=layout)
    assert b1.signature == b2.signature
