"""D-ReLU property tests (hypothesis): the paper's Eqs. 2-3 invariants."""

import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # bare container: seeded fallback
    from _hyp_fallback import given, settings, strategies as st

from repro.core.cbsr import CBSR, cbsr_from_dense, cbsr_mask, sample_dense
from repro.core.drelu import (candidate_ks, drelu, drelu_grouped,
                              hetero_k_values, profile_optimal_k)

settings.register_profile("fast", max_examples=25, deadline=None)
settings.load_profile("fast")


mat = st.integers(2, 40).flatmap(
    lambda n: st.integers(2, 64).flatmap(
        lambda d: st.tuples(st.just(n), st.just(d),
                            st.integers(1, d),
                            st.integers(0, 2 ** 31 - 1))))


@given(mat)
def test_exactly_k_survivors(args):
    n, d, k, seed = args
    x = np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)
    # ties break the exact count; perturb to distinct values
    x += np.arange(n * d).reshape(n, d) * 1e-6
    y = np.asarray(drelu(jnp.asarray(x), k))
    nnz = (y != 0).sum(1)
    kept = np.minimum(k, d)
    # rows may keep fewer if a kept element is exactly 0.0 (prob ~0)
    assert np.all(nnz == kept), (nnz, kept)


@given(mat)
def test_threshold_semantics(args):
    """f(x)=x iff x >= min(top_k(row)) — Eq. 3 verbatim."""
    n, d, k, seed = args
    x = np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)
    x += np.arange(n * d).reshape(n, d) * 1e-6
    y = np.asarray(drelu(jnp.asarray(x), k))
    th = np.sort(x, axis=1)[:, -min(k, d)]
    expected = np.where(x >= th[:, None], x, 0.0)
    np.testing.assert_allclose(y, expected)


@given(mat)
def test_grad_straight_through(args):
    n, d, k, seed = args
    x = np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)
    x += np.arange(n * d).reshape(n, d) * 1e-6
    xj = jnp.asarray(x)
    g = jax.grad(lambda z: jnp.sum(drelu(z, k) * 3.0))(xj)
    keep = np.asarray(drelu(xj, k)) != 0
    assert np.allclose(np.asarray(g)[keep], 3.0)
    assert np.allclose(np.asarray(g)[~keep], 0.0)


@given(st.integers(0, 10_000), st.sampled_from([4, 8, 16]),
       st.sampled_from([2, 4]))
def test_grouped_keeps_exactly_k(seed, fg, groups):
    f = fg * groups
    k = groups * max(fg // 2, 1)
    x = np.random.default_rng(seed).normal(size=(6, f)).astype(np.float32)
    x += np.arange(6 * f).reshape(6, f) * 1e-6
    y = np.asarray(drelu_grouped(jnp.asarray(x), k, groups))
    assert np.all((y != 0).sum(1) == k)
    # each group keeps exactly k/groups
    yg = y.reshape(6, groups, fg)
    assert np.all((yg != 0).sum(-1) == k // groups)


def test_cbsr_roundtrip_equals_drelu():
    x = np.random.default_rng(0).normal(size=(20, 32)).astype(np.float32)
    k = 8
    dense = np.asarray(drelu(jnp.asarray(x), k))
    c = cbsr_from_dense(jnp.asarray(x), k)
    np.testing.assert_allclose(np.asarray(c.to_dense()), dense, atol=1e-6)
    # indices sorted ascending per row
    idx = np.asarray(c.idx)
    assert np.all(np.diff(idx, axis=1) >= 0)


@given(st.integers(0, 1000))
def test_sample_dense_inverts_scatter(seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(10, 24)).astype(np.float32)
    c = cbsr_from_dense(jnp.asarray(x), 6)
    sampled = sample_dense(c.to_dense(), c.idx)
    np.testing.assert_allclose(np.asarray(sampled), np.asarray(c.values),
                               atol=1e-6)


def test_k_profiler_prefers_small_k_for_evil_rows():
    """The cost model must choose smaller K for heavier-tailed graphs
    (the paper's NG-size-aware K adaptation)."""
    uniform = np.full(1000, 8)
    evil = np.copy(uniform)
    evil[:20] = 500
    k_u = profile_optimal_k(uniform, 128)
    k_e = profile_optimal_k(evil, 128)
    assert k_e <= k_u


def test_candidate_ks_are_pow2():
    assert candidate_ks(64) == (2, 4, 8, 16, 32, 64)


def test_hetero_k_values():
    stats = {"near": {"degrees": np.full(100, 50), "src_type": "cell"},
             "pin": {"degrees": np.full(100, 3), "src_type": "cell"},
             "pinned": {"degrees": np.full(100, 4), "src_type": "net"}}
    ks = hetero_k_values(stats, {"cell": 64, "net": 64})
    assert set(ks) == {"near", "pin", "pinned"}
    assert all(2 <= v <= 64 for v in ks.values())
