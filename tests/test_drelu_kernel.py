"""Binary-search D-ReLU Pallas kernel vs the lax.top_k oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.drelu import drelu
from repro.kernels.drelu_topk import drelu_pallas, _bisect_threshold


@pytest.mark.parametrize("n,d,k", [(8, 32, 8), (17, 64, 16), (40, 128, 32),
                                   (5, 16, 1), (8, 16, 15)])
def test_kernel_matches_topk_oracle(n, d, k):
    rng = np.random.default_rng(n * d + k)
    x = rng.normal(size=(n, d)).astype(np.float32)
    x += np.arange(n * d).reshape(n, d) * 1e-6      # break ties
    got = np.asarray(drelu_pallas(jnp.asarray(x), k))
    want = np.asarray(drelu(jnp.asarray(x), k))
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_bisect_threshold_counts():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(12, 64)).astype(np.float32)
    x += np.arange(12 * 64).reshape(12, 64) * 1e-6
    for k in (1, 4, 16, 63):
        th = np.asarray(_bisect_threshold(jnp.asarray(x), k))
        cnt = (x >= th[:, None]).sum(1)
        assert np.all(cnt == k), (k, cnt)


def test_kernel_bf16():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(16, 32)).astype(np.float32))
    got = drelu_pallas(x.astype(jnp.bfloat16), 8)
    want = drelu(x.astype(jnp.bfloat16).astype(jnp.float32), 8)
    nnz = np.asarray((np.asarray(got, np.float32) != 0).sum(1))
    # bf16 ties possible; allow k ± tie width
    assert np.all(nnz >= 7) and np.all(nnz <= 10)
