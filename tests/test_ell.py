"""Degree-bucketed ELL packing properties (hypothesis)."""

import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # bare container: seeded fallback
    from _hyp_fallback import given, settings, strategies as st

from repro.graphs.ell import BucketedELL, pack_ell, pack_ell_pair, ROW_BLOCK

settings.register_profile("fast", max_examples=25, deadline=None)
settings.load_profile("fast")


graphs = st.integers(0, 2 ** 31 - 1).flatmap(lambda seed: st.tuples(
    st.just(seed), st.integers(1, 60), st.integers(1, 60),
    st.integers(0, 300)))


def make_coo(seed, n_dst, n_src, nnz):
    rng = np.random.default_rng(seed)
    if nnz == 0:
        return (np.zeros(0, np.int64), np.zeros(0, np.int64),
                np.zeros(0, np.float32))
    dst = rng.integers(0, n_dst, nnz)
    src = rng.integers(0, n_src, nnz)
    pairs = np.unique(np.stack([dst, src], 1), axis=0)
    w = rng.normal(size=pairs.shape[0]).astype(np.float32)
    return pairs[:, 0], pairs[:, 1], w


@given(graphs)
def test_dense_reconstruction(args):
    seed, n_dst, n_src, nnz = args
    dst, src, w = make_coo(seed, n_dst, n_src, nnz)
    adj = pack_ell(dst, src, w, n_dst, n_src)
    dense = np.zeros((n_dst, n_src), np.float32)
    dense[dst, src] = w
    np.testing.assert_allclose(np.asarray(adj.to_dense()), dense, atol=1e-6)


@given(graphs)
def test_transpose_pair(args):
    seed, n_dst, n_src, nnz = args
    dst, src, w = make_coo(seed, n_dst, n_src, nnz)
    a, at = pack_ell_pair(dst, src, w, n_dst, n_src)
    np.testing.assert_allclose(np.asarray(a.to_dense()).T,
                               np.asarray(at.to_dense()), atol=1e-6)


@given(graphs)
def test_bucket_invariants(args):
    seed, n_dst, n_src, nnz = args
    dst, src, w = make_coo(seed, n_dst, n_src, nnz)
    adj = pack_ell(dst, src, w, n_dst, n_src)
    seen = set()
    for b in adj.buckets:
        assert b.n_rows % ROW_BLOCK == 0          # grid-aligned
        rows = np.asarray(b.rows)
        wts = np.asarray(b.w)
        real = wts.any(axis=1)
        for r in rows[real]:
            assert r not in seen                   # each row in ONE bucket
            seen.add(int(r))
        # padded rows are inert (zero weights)
        assert not wts[~real].any()


@given(graphs)
def test_no_bucket_wider_than_its_max_degree(args):
    """The point of bucketing: short rows never pay evil-row padding."""
    seed, n_dst, n_src, nnz = args
    dst, src, w = make_coo(seed, n_dst, n_src, nnz)
    if len(dst) == 0:
        return
    adj = pack_ell(dst, src, w, n_dst, n_src)
    deg = np.bincount(dst, minlength=n_dst)
    for b in adj.buckets:
        rows = np.asarray(b.rows)
        real = np.asarray(b.w).any(axis=1)
        if real.any():
            assert b.width == deg[rows[real]].max()
