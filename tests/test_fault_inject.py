"""fault/inject.py unit coverage: rule validation, at-index scheduling,
seeded Bernoulli determinism, firing caps, device filters, the straggler
stall, NaN poisoning, and the stateful device-loss down window."""

import numpy as np
import pytest

from repro.fault import FaultInjector, FaultRule, InjectedFault, POINTS


def _fires(inj, point, n, device=None):
    """Touch ``point`` n times; return the boolean firing pattern."""
    pat = []
    for _ in range(n):
        try:
            inj.raise_if(point, device=device)
            pat.append(False)
        except InjectedFault:
            pat.append(True)
    return pat


def test_unknown_point_rejected():
    with pytest.raises(ValueError, match="unknown injection point"):
        FaultRule("warp_drive")
    for p in POINTS:
        FaultRule(p)                        # every documented point is legal


def test_at_fires_on_exact_occurrences():
    inj = FaultInjector([FaultRule("collate", at=(1, 3))])
    assert _fires(inj, "collate", 6) == [False, True, False, True,
                                         False, False]
    assert inj.counts() == {"collate": 2}


def test_rate_schedule_is_seed_deterministic():
    mk = lambda seed: FaultInjector([FaultRule("dispatch", rate=0.5)],
                                    seed=seed)
    a = _fires(mk(7), "dispatch", 100)
    b = _fires(mk(7), "dispatch", 100)
    c = _fires(mk(8), "dispatch", 100)
    assert a == b                           # same seed -> same schedule
    assert a != c                           # different seed -> different
    assert 10 < sum(a) < 90                 # and it is actually Bernoulli


def test_n_caps_total_firings():
    inj = FaultInjector([FaultRule("collate", rate=1.0, n=2)])
    assert _fires(inj, "collate", 5) == [True, True, False, False, False]


def test_device_filter_scopes_rule():
    inj = FaultInjector([FaultRule("device_put", at=(0,), device=1)])
    assert _fires(inj, "device_put", 3, device=0) == [False] * 3
    # occurrences count per matching device, so slot 1 still sees occ 0
    assert _fires(inj, "device_put", 2, device=1) == [True, False]
    ev = inj.events[0]
    assert ev.point == "device_put" and ev.device == 1


def test_fault_carries_point_and_device():
    inj = FaultInjector([FaultRule("dispatch", at=(0,))])
    with pytest.raises(InjectedFault) as ei:
        inj.raise_if("dispatch", device=2)
    assert ei.value.point == "dispatch" and ei.value.device == 2
    assert "dispatch" in str(ei.value) and "slot 2" in str(ei.value)


def test_stall_sleeps_scheduled_delay():
    inj = FaultInjector([FaultRule("straggler", at=(1,), delay_s=0.01)])
    assert inj.stall() == 0.0               # occurrence 0: quiet
    assert inj.stall() == 0.01              # occurrence 1: fires
    assert inj.stall() == 0.0
    assert inj.counts() == {"straggler": 1}


def test_poison_nans_full_output_once():
    inj = FaultInjector([FaultRule("nan_output", at=(1,))])
    out = np.ones((4, 2), np.float32)
    same = inj.poison(out)
    assert same is out                      # quiet touch: passthrough
    bad = inj.poison(out)
    assert np.isnan(bad).all() and bad.shape == out.shape
    assert np.isfinite(out).all()           # the original is never mutated
    assert inj.poison(out) is out


def test_device_loss_opens_down_window():
    """The triggering touch plus ``down_for - 1`` follow-ups fail on the
    lost slot; other slots are untouched; the window then closes."""
    inj = FaultInjector([FaultRule("device_loss", at=(0,), device=1,
                                   down_for=3)])
    # slot 0 is never down
    assert _fires(inj, "device_put", 2, device=0) == [False, False]
    pat = []
    for _ in range(5):
        try:
            inj.raise_if("dispatch", device=1)
            pat.append(None)
        except InjectedFault as e:
            pat.append(e.point)
    assert pat == ["device_loss"] * 3 + [None, None]
    assert inj.counts() == {"device_loss": 3}
    # slot 0 stayed healthy throughout the window
    assert _fires(inj, "device_put", 2, device=0) == [False, False]


def test_down_window_blocks_every_point_touch_of_slot():
    """Once a slot is down, device_put AND dispatch touches both fail —
    the engine sees the loss wherever it next touches the device."""
    inj = FaultInjector([FaultRule("device_loss", at=(0,), device=0,
                                   down_for=2)])
    with pytest.raises(InjectedFault):
        inj.raise_if("device_put", device=0)
    with pytest.raises(InjectedFault):
        inj.raise_if("dispatch", device=0)
    inj.raise_if("dispatch", device=0)      # window exhausted


def test_thread_safety_under_concurrent_touches():
    import threading
    inj = FaultInjector([FaultRule("dispatch", rate=0.3, n=50)])
    hits = []

    def worker():
        for _ in range(200):
            try:
                inj.raise_if("dispatch")
            except InjectedFault:
                hits.append(1)

    ts = [threading.Thread(target=worker) for _ in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert len(hits) == 50                  # the n cap holds under races
    assert inj.counts()["dispatch"] == 50
