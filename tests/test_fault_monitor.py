"""fault/monitor.py coverage: ElasticController shrink edge cases,
shard_remap determinism, StepMonitor escalation + lazy host registration,
Heartbeat atomic-write liveness and corruption tolerance."""

import json
import os
import threading
import time

import pytest

from repro.fault import ElasticController, Heartbeat, StepMonitor


# ------------------------------------------------------ ElasticController

def test_shrink_partial_loss_shrinks_data_axis():
    ctl = ElasticController(data=4, model=2, pods=1)
    assert ctl.shrink(1) == (1, 2, 2)       # 3 survivors -> pow2 data = 2
    assert ctl.shrink(2) == (1, 2, 2)
    assert ctl.shrink(3) == (1, 1, 2)


def test_shrink_whole_pod_loss_drops_pod_axis_first():
    ctl = ElasticController(data=4, model=1, pods=2)
    # losing a full pod's worth: the pod axis absorbs it, data survives
    assert ctl.shrink(4) == (1, 4, 1)
    # losing more than a pod: pod drops AND data shrinks
    assert ctl.shrink(5) == (1, 2, 1)


def test_shrink_single_survivor():
    ctl = ElasticController(data=2, model=1, pods=2)
    pods, data, model = ctl.shrink(3)       # one survivor of four
    assert (pods, data, model) == (1, 1, 1)


def test_shrink_no_survivors_raises():
    ctl = ElasticController(data=2, model=1, pods=1)
    with pytest.raises(RuntimeError, match="no survivors"):
        ctl.shrink(2)
    with pytest.raises(RuntimeError, match="no survivors"):
        ctl.shrink(3)                       # over-reported loss still raises


def test_shrink_model_axis_preserved():
    ctl = ElasticController(data=8, model=4, pods=1)
    for failed in (1, 3, 5, 7):
        _, _, model = ctl.shrink(failed)
        assert model == 4                   # layout-changing axis untouched


def test_shard_remap_round_robin_and_deterministic():
    ctl = ElasticController(data=8, model=1)
    dead = [6, 1, 3]
    remap = ctl.shard_remap(8, dead)
    # dead shards only, each mapped to a survivor, round-robin over the
    # sorted dead list
    alive = [h for h in range(8) if h not in dead]
    assert sorted(remap) == sorted(dead)
    assert remap == {1: alive[0], 3: alive[1], 6: alive[2]}
    assert all(t not in dead for t in remap.values())
    # pure function of (n_shards, dead): same inputs, same remap
    assert remap == ctl.shard_remap(8, [3, 6, 1])


def test_shard_remap_wraps_over_few_survivors():
    ctl = ElasticController(data=4, model=1)
    remap = ctl.shard_remap(4, [0, 1, 2])   # one survivor takes all three
    assert remap == {0: 3, 1: 3, 2: 3}


# ----------------------------------------------------------- StepMonitor

def _feed_steady(mon, host=0, n=10, dt=0.1):
    for s in range(n):
        assert mon.record(s, host, dt) is None


def test_stepmonitor_slack_then_rebalance_escalation():
    mon = StepMonitor(n_hosts=1, patience=3)
    _feed_steady(mon, n=10)
    actions = []
    for s in range(10, 14):
        ev = mon.record(s, 0, 0.5)          # straggling but under deadline
        if ev is not None:
            actions.append(ev.action)
    # strikes accumulate: slack first, rebalance at patience
    assert actions[:2] == ["slack", "slack"]
    assert "rebalance" in actions[2:]


def test_stepmonitor_deadline_restarts_immediately():
    mon = StepMonitor(n_hosts=1)
    _feed_steady(mon, n=10)
    ev = mon.record(10, 0, 10.0 * 0.1 * 1.5)   # past median*deadline_factor
    assert ev is not None and ev.action == "restart"


def test_stepmonitor_recovery_decays_strikes():
    mon = StepMonitor(n_hosts=1, patience=2)
    _feed_steady(mon, n=10)
    assert mon.record(10, 0, 0.5).action == "slack"
    for s in range(11, 14):
        mon.record(s, 0, 0.1)               # healthy steps decay the strike
    ev = mon.record(14, 0, 0.5)
    assert ev is not None and ev.action == "slack"   # not escalated


def test_stepmonitor_lazy_host_registration():
    """Hosts joining after construction (elastic mesh growth) register
    lazily instead of raising KeyError."""
    mon = StepMonitor(n_hosts=1)
    assert mon.record(0, 5, 0.1) is None    # unseen host id
    assert 5 in mon.history and mon.strikes[5] == 0
    assert mon.n_hosts == 6
    # the lazy host gets the same statistics treatment
    for s in range(1, 10):
        mon.record(s, 5, 0.1)
    ev = mon.record(10, 5, 5.0)
    assert ev is not None and ev.host == 5


# ------------------------------------------------------------- Heartbeat

def test_heartbeat_liveness_roundtrip(tmp_path):
    path = str(tmp_path)
    hb = Heartbeat(path, host=0, interval=0.0)
    hb.beat(step=7)
    t_beat = time.time()
    assert Heartbeat.dead_hosts(path, timeout=60.0) == []
    assert Heartbeat.dead_hosts(path, timeout=0.5, now=t_beat + 10) == [0]
    rec = json.load(open(os.path.join(path, "host_0.json")))
    assert rec["step"] == 7 and rec["host"] == 0


def test_heartbeat_interval_rate_limits(tmp_path):
    path = str(tmp_path)
    hb = Heartbeat(path, host=1, interval=1000.0)
    hb.beat(step=1)
    hb.beat(step=2)                         # suppressed by the interval
    rec = json.load(open(os.path.join(path, "host_1.json")))
    assert rec["step"] == 1


def test_heartbeat_write_is_atomic(tmp_path):
    """beat() writes via temp-file + rename: no partially-written final
    record ever exists, and leftover .tmp files are ignored by readers."""
    path = str(tmp_path)
    hb = Heartbeat(path, host=0, interval=0.0)
    hb.beat(step=1)
    assert not [f for f in os.listdir(path) if f.endswith(".tmp")]
    # a stray tmp from a crashed writer must not confuse dead_hosts
    with open(os.path.join(path, "host_3.json.tmp"), "w") as f:
        f.write('{"host": 3, "time"')
    assert Heartbeat.dead_hosts(path, timeout=60.0) == []


def test_dead_hosts_skips_corrupt_records(tmp_path):
    path = str(tmp_path)
    hb = Heartbeat(path, host=0, interval=0.0)
    hb.beat(step=1)
    # truncated JSON (the failure mode non-atomic writes used to produce)
    with open(os.path.join(path, "host_1.json"), "w") as f:
        f.write('{"host": 1, "ti')
    # wrong schema
    with open(os.path.join(path, "host_2.json"), "w") as f:
        json.dump({"hello": "world"}, f)
    # stale but valid record on another host
    with open(os.path.join(path, "host_4.json"), "w") as f:
        json.dump({"host": 4, "step": 0, "time": time.time() - 1e6}, f)
    dead = Heartbeat.dead_hosts(path, timeout=60.0)
    assert dead == [4]                      # corrupt skipped, stale flagged


def test_heartbeat_concurrent_beat_and_read(tmp_path):
    """Hammer beat() while polling dead_hosts(): readers never crash on a
    mid-write record (the regression the atomic rename fixes)."""
    path = str(tmp_path)
    hb = Heartbeat(path, host=0, interval=0.0)
    stop = threading.Event()
    errors = []

    def writer():
        step = 0
        while not stop.is_set():
            hb.beat(step)
            step += 1

    def reader():
        while not stop.is_set():
            try:
                Heartbeat.dead_hosts(path, timeout=60.0)
            except Exception as e:          # pragma: no cover
                errors.append(e)
                return

    threads = [threading.Thread(target=writer),
               threading.Thread(target=reader)]
    for t in threads:
        t.start()
    time.sleep(0.5)
    stop.set()
    for t in threads:
        t.join(timeout=10.0)
    assert not errors
