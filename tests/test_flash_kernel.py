"""Pallas flash-attention kernel vs naive softmax oracle + the jnp chunked
flash used in the model path."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models.lm.attention import chunked_attention


def naive(q, k, v, causal):
    qf, kf, vf = (np.asarray(t, np.float64) for t in (q, k, v))
    b, sq, h, hd = qf.shape
    sk = kf.shape[1]
    s = np.einsum("bqhd,bshd->bhqs", qf, kf) / np.sqrt(hd)
    if causal:
        mask = np.tril(np.ones((sq, sk), bool), k=sk - sq)
        s = np.where(mask[None, None], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    return np.einsum("bhqs,bshd->bqhd", p, vf)


@pytest.mark.parametrize("sq,sk,causal", [
    (128, 128, True), (128, 128, False), (256, 256, True),
    (128, 256, False),
])
def test_flash_vs_naive(sq, sk, causal):
    rng = np.random.default_rng(sq + sk)
    b, h, hd = 2, 2, 64
    q = rng.normal(size=(b, sq, h, hd)).astype(np.float32)
    k = rng.normal(size=(b, sk, h, hd)).astype(np.float32)
    v = rng.normal(size=(b, sk, h, hd)).astype(np.float32)
    out = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                          causal=causal)
    np.testing.assert_allclose(np.asarray(out), naive(q, k, v, causal),
                               rtol=2e-4, atol=2e-4)


def test_flash_matches_model_path():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 256, 2, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 256, 2, 64)).astype(np.float32))
    a = flash_attention(q, k, v, causal=True)
    b = chunked_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64,
                          causal_mode="brick")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)
