"""Parity + packing tests for the fused single-dispatch DR-SpMM executor.

The fused arena path ("pallas_fused") must be numerically interchangeable
with the per-bucket Pallas path, the bucketed XLA path, and the dense oracle
— forward and gradient — across the degree distributions that stress the
packing: empty buckets, single evil rows, all-rows-one-bucket, non-divisible
row counts, and the empty matrix.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # bare container: seeded fallback
    from _hyp_fallback import given, settings, strategies as st

from repro.core.cbsr import cbsr_from_dense
from repro.core.drelu import drelu
from repro.graphs.ell import (fuse_bucketed, pack_ell, pack_ell_pair,
                              pack_fused, ROW_BLOCK)
from repro.kernels import ops

settings.register_profile("fast", max_examples=25, deadline=None)
settings.load_profile("fast")

BACKENDS = ("pallas_fused", "xla_fused", "pallas", "xla")


def _assert_close(actual, ref, msg):
    """≤1e-5 agreement at the reference's scale (f32 accumulation-order
    noise grows with |ref| — a raw atol would fail even xla-vs-dense)."""
    atol = 1e-5 * max(1.0, float(np.abs(ref).max()) if ref.size else 1.0)
    np.testing.assert_allclose(actual, ref, atol=atol, rtol=1e-5,
                               err_msg=msg)


def _coo(name, rng):
    """Named degree distributions that stress the bucketing."""
    if name == "empty":
        return (np.zeros(0, np.int64), np.zeros(0, np.int64),
                np.zeros(0, np.float32), 11, 9)
    if name == "evil_row":
        # one row holding most of the nnz (lands alone in a wide bucket,
        # leaving intermediate buckets empty), plus a sparse bulk
        n_dst, n_src = 24, 40
        dst = np.concatenate([np.zeros(37, np.int64),
                              rng.integers(0, n_dst, 30)])
        src = np.concatenate([np.arange(37) % n_src,
                              rng.integers(0, n_src, 30)])
    elif name == "one_bucket":
        # every row has degree 3 → a single bucket, row count not a
        # multiple of ROW_BLOCK
        n_dst, n_src = ROW_BLOCK * 2 + 3, 7
        dst = np.repeat(np.arange(n_dst), 3)
        src = rng.integers(0, n_src, dst.size)
    elif name == "mixed":
        n_dst, n_src = 61, 53
        deg = rng.integers(1, 70, n_dst)
        dst = np.repeat(np.arange(n_dst), deg)
        src = rng.integers(0, n_src, dst.size)
    else:
        raise ValueError(name)
    pairs = np.unique(np.stack([dst, src], 1), axis=0)
    w = rng.normal(size=pairs.shape[0]).astype(np.float32)
    return pairs[:, 0], pairs[:, 1], w, n_dst, n_src


@pytest.mark.parametrize("dist", ["empty", "evil_row", "one_bucket", "mixed"])
@pytest.mark.parametrize("dim", [64, 256])
def test_drspmm_backend_parity(dist, dim):
    rng = np.random.default_rng(hash(dist) % 2 ** 31)
    dst, src, w, n_dst, n_src = _coo(dist, rng)
    adj, adj_t = pack_ell_pair(dst, src, w, n_dst, n_src)
    k = 8
    x = rng.normal(size=(n_src, dim)).astype(np.float32)
    c = cbsr_from_dense(drelu(jnp.asarray(x), k), k)

    y_ref = np.asarray(ops.drspmm(adj, adj_t, c.values, c.idx, dim,
                                  backend="dense"))
    g_ref = np.asarray(jax.grad(lambda v: jnp.sum(ops.drspmm(
        adj, adj_t, v, c.idx, dim, backend="dense") ** 2))(c.values))
    for be in BACKENDS:
        y = np.asarray(ops.drspmm(adj, adj_t, c.values, c.idx, dim,
                                  backend=be))
        _assert_close(y, y_ref, f"fwd {be}/{dist}/d{dim}")
        g = np.asarray(jax.grad(lambda v: jnp.sum(ops.drspmm(
            adj, adj_t, v, c.idx, dim, backend=be) ** 2))(c.values))
        _assert_close(g, g_ref, f"grad {be}/{dist}/d{dim}")


@pytest.mark.parametrize("dist", ["empty", "evil_row", "one_bucket", "mixed"])
def test_spmm_backend_parity(dist):
    rng = np.random.default_rng(hash(dist) % 2 ** 31)
    dst, src, w, n_dst, n_src = _coo(dist, rng)
    adj, adj_t = pack_ell_pair(dst, src, w, n_dst, n_src)
    x = jnp.asarray(rng.normal(size=(n_src, 64)).astype(np.float32))
    y_ref = np.asarray(ops.spmm(adj, adj_t, x, backend="dense"))
    g_ref = np.asarray(jax.grad(lambda v: jnp.sum(ops.spmm(
        adj, adj_t, v, backend="dense") ** 2))(x))
    for be in BACKENDS:
        y = np.asarray(ops.spmm(adj, adj_t, x, backend=be))
        _assert_close(y, y_ref, f"{be}/{dist}")
        g = np.asarray(jax.grad(lambda v: jnp.sum(ops.spmm(
            adj, adj_t, v, backend=be) ** 2))(x))
        _assert_close(g, g_ref, f"grad {be}/{dist}")


def test_fused_is_one_dispatch_per_direction():
    """The fused forward traces to exactly ONE pallas_call; per-bucket
    traces to one per bucket."""
    rng = np.random.default_rng(3)
    dst, src, w, n_dst, n_src = _coo("mixed", rng)
    adj, adj_t = pack_ell_pair(dst, src, w, n_dst, n_src)
    k, dim = 8, 64
    x = rng.normal(size=(n_src, dim)).astype(np.float32)
    c = cbsr_from_dense(drelu(jnp.asarray(x), k), k)

    from benchmarks.bench_drspmm import dispatch_count

    def n_calls(backend):
        return dispatch_count(lambda v: ops.drspmm(
            adj, adj_t, v, c.idx, dim, backend=backend), c.values)

    assert n_calls("pallas_fused") == 1
    assert n_calls("pallas") == len(adj.buckets) >= 2


# ------------------------ packing round-trip ---------------------------

rt_graphs = st.integers(0, 2 ** 31 - 1).flatmap(lambda seed: st.tuples(
    st.just(seed), st.integers(1, 50), st.integers(1, 50),
    st.integers(0, 250)))


@given(rt_graphs)
def test_pack_fused_roundtrip(args):
    """pack_fused reconstructs exactly the matrix pack_ell reconstructs."""
    seed, n_dst, n_src, nnz = args
    rng = np.random.default_rng(seed)
    if nnz:
        dst = rng.integers(0, n_dst, nnz)
        src = rng.integers(0, n_src, nnz)
        pairs = np.unique(np.stack([dst, src], 1), axis=0)
        dst, src = pairs[:, 0], pairs[:, 1]
        w = rng.normal(size=dst.shape[0]).astype(np.float32)
    else:
        dst = src = np.zeros(0, np.int64)
        w = np.zeros(0, np.float32)
    adj = pack_ell(dst, src, w, n_dst, n_src)
    fused = pack_fused(dst, src, w, n_dst, n_src)
    np.testing.assert_allclose(fused.to_dense(), np.asarray(adj.to_dense()),
                               atol=1e-6)
    assert fused.nnz == adj.nnz == int((w != 0).sum())


def test_fused_backend_with_traced_graph_falls_back():
    """A jitted step that takes the graph as an ARGUMENT (traced pytree)
    cannot host-pack the fused arena; the op must fall back to the
    per-bucket path of the same family instead of crashing."""
    rng = np.random.default_rng(0)
    dst, src, w, n_dst, n_src = _coo("mixed", rng)
    adj, adj_t = pack_ell_pair(dst, src, w, n_dst, n_src)
    x = jnp.asarray(rng.normal(size=(n_src, 32)).astype(np.float32))

    @jax.jit
    def step(a, at, v):
        return ops.spmm(a, at, v, backend="xla_fused")

    y = np.asarray(step(adj, adj_t, x))
    y_ref = np.asarray(ops.spmm(adj, adj_t, x, backend="dense"))
    _assert_close(y, y_ref, "traced-graph fallback")


def test_fuse_bucketed_memoized():
    rng = np.random.default_rng(0)
    dst, src, w, n_dst, n_src = _coo("mixed", rng)
    adj = pack_ell(dst, src, w, n_dst, n_src)
    assert fuse_bucketed(adj) is fuse_bucketed(adj)


def test_nnz_is_static_and_cheap():
    rng = np.random.default_rng(0)
    dst, src, w, n_dst, n_src = _coo("mixed", rng)
    adj = pack_ell(dst, src, w, n_dst, n_src)
    assert isinstance(adj.nnz, int)
    assert adj.nnz == int((w != 0).sum())
    # static field ⇒ part of the pytree aux data, not a device array
    leaves, treedef = jax.tree_util.tree_flatten(adj)
    assert all(not isinstance(l, int) for l in leaves)
