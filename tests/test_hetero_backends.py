"""Hetero message-passing backend parity: topk vs pallas D-ReLU; pallas vs
xla SpMM inside the full layer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hetero_mp import HeteroMPConfig, hetero_conv, init_hetero_layer
from repro.graphs.generator import generate_design


@pytest.fixture(scope="module")
def setup():
    g = generate_design(7, "small", scale=0.03)[0]
    params = init_hetero_layer(jax.random.PRNGKey(0), 16)
    rng = np.random.default_rng(0)
    xc = jnp.asarray(rng.normal(size=(g.n_cell, 16)).astype(np.float32))
    xn = jnp.asarray(rng.normal(size=(g.n_net, 16)).astype(np.float32))
    return g, params, xc, xn


def test_pallas_drelu_backend_matches_topk(setup):
    g, params, xc, xn = setup
    base = HeteroMPConfig(hidden=16, k_cell=4, k_net=4)
    pall = HeteroMPConfig(hidden=16, k_cell=4, k_net=4,
                          drelu_backend="pallas")
    yc0, yn0 = hetero_conv(params, g, xc, xn, base)
    yc1, yn1 = hetero_conv(params, g, xc, xn, pall)
    np.testing.assert_allclose(np.asarray(yc0), np.asarray(yc1),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(yn0), np.asarray(yn1),
                               rtol=1e-5, atol=1e-5)


def test_pallas_spmm_backend_in_layer(setup):
    g, params, xc, xn = setup
    a = HeteroMPConfig(hidden=16, k_cell=4, k_net=4, backend="xla")
    b = HeteroMPConfig(hidden=16, k_cell=4, k_net=4, backend="pallas")
    yca, _ = hetero_conv(params, g, xc, xn, a)
    ycb, _ = hetero_conv(params, g, xc, xn, b)
    np.testing.assert_allclose(np.asarray(yca), np.asarray(ycb),
                               rtol=1e-5, atol=1e-5)


def test_pallas_drelu_gradients_flow(setup):
    g, params, xc, xn = setup
    cfg = HeteroMPConfig(hidden=16, k_cell=4, k_net=4,
                         drelu_backend="pallas")

    def f(x):
        yc, yn = hetero_conv(params, g, x, xn, cfg)
        return jnp.sum(yc ** 2)

    gx = jax.grad(f)(xc)
    assert np.isfinite(np.asarray(gx)).all()
    assert float(jnp.abs(gx).sum()) > 0