"""DR-CircuitGNN model + homogeneous baselines + metrics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hetero_mp import HeteroMPConfig
from repro.graphs.generator import generate_design, generate_partition, TABLE1
from repro.models.hgnn import (drcircuitgnn_forward, homo_forward, homogenize,
                               init_drcircuitgnn, init_homo)
from repro.train import metrics as M


@pytest.fixture(scope="module")
def graph():
    return generate_design(3, "small", scale=0.03)[0]


def test_forward_shapes_and_range(graph):
    params = init_drcircuitgnn(jax.random.PRNGKey(0), 16, 16, 32)
    cfg = HeteroMPConfig(hidden=32, k_cell=8, k_net=8)
    pred = drcircuitgnn_forward(params, graph, cfg)
    assert pred.shape == (graph.n_cell,)
    p = np.asarray(pred)
    assert np.all((p >= 0) & (p <= 1)) and not np.isnan(p).any()


@pytest.mark.parametrize("kind", ["gcn", "sage", "gat", "gat_edge"])
def test_homogeneous_baselines_run(graph, kind):
    adj, adj_t, x, y, n_cell = homogenize(graph)
    params = init_homo(jax.random.PRNGKey(0), x.shape[1], 32, kind=kind,
                       nnz=adj.nnz)
    pred = homo_forward(params, adj, adj_t, x @ jnp.eye(x.shape[1]), n_cell,
                        kind=kind)
    assert pred.shape == (n_cell,)
    assert not np.isnan(np.asarray(pred)).any()


def _naive_gat_f64(params, adj, x, n_cell):
    """Unstabilized exp-space GAT in float64 — the numerics oracle for the
    stabilized f32 implementation (finite in f64 wherever logits < ~700)."""
    from repro.graphs.ell import ell_to_coo
    dst, src, wv = ell_to_coo(adj)
    dst, src = dst.astype(np.int64), src.astype(np.int64)
    wv = wv.astype(np.float64)
    h = np.asarray(x, np.float64) @ np.asarray(params.w_in, np.float64)
    lmax = 0.0
    for (w, a) in params.w_layers:
        hw = h @ np.asarray(w, np.float64)
        a = np.asarray(a, np.float64)
        hd = hw.shape[1]
        lrelu = lambda z: np.where(z >= 0, z, 0.01 * z)
        lr_src = lrelu(hw @ a[:hd])
        lr_self = lrelu(hw @ a[:hd] + hw @ a[hd:])
        lmax = max(lmax, float(np.abs(lr_src).max()),
                   float(np.abs(lr_self).max()))
        num = np.exp(lr_self)[:, None] * hw
        den = np.exp(lr_self).copy()
        np.add.at(num, dst, (wv * np.exp(lr_src[src]))[:, None] * hw[src])
        np.add.at(den, dst, wv * np.exp(lr_src[src]))
        h = np.maximum(num / np.maximum(den, 1e-6)[:, None], 0.0)
    z = h @ np.asarray(params.head_w, np.float64) \
        + np.asarray(params.head_b, np.float64)
    return (1.0 / (1.0 + np.exp(-z)))[:n_cell, 0], lmax


def test_gat_large_scale_inputs_match_f64_oracle(graph):
    """Regression: exp-space GAT attention exponentiated unbounded
    leaky-relu logits — large-magnitude features overflowed jnp.exp to inf
    and num/den went NaN.  The per-destination max-subtracted form must
    stay finite AND keep every node's softmax faithful (a global shift
    would underflow nodes far below the hottest one to 0/0), so compare
    against the unstabilized float64 oracle in the f32-overflow regime."""
    adj, adj_t, x, y, n_cell = homogenize(graph)
    params = init_homo(jax.random.PRNGKey(1), x.shape[1], 32, kind="gat",
                       n_layers=1)
    # moderate scale: semantics unchanged by the stabilization
    ref, _ = _naive_gat_f64(params, adj, x, n_cell)
    pred = homo_forward(params, adj, adj_t, x, n_cell, kind="gat")
    np.testing.assert_allclose(np.asarray(pred), ref, rtol=1e-4, atol=1e-4)
    # scale into the f32-overflow regime (exp arg > 89 ⇒ old code -> inf)
    _, lmax1 = _naive_gat_f64(params, adj, x, n_cell)
    scale = 150.0 / lmax1
    ref_big, lmax = _naive_gat_f64(params, adj, x * scale, n_cell)
    assert lmax > 100, "test did not reach the overflow regime"
    pred_big = homo_forward(params, adj, adj_t, x * scale, n_cell,
                            kind="gat")
    p = np.asarray(pred_big)
    assert np.isfinite(p).all()
    np.testing.assert_allclose(p, ref_big, rtol=1e-3, atol=1e-3)


def test_gat_edge_uniform_attention_matches_gcn(graph):
    """Zero-initialized per-edge logits are uniform attention over each
    destination's in-edges (self-loop included) — exactly the mean
    aggregation the GCN baseline uses, so the two forwards coincide."""
    adj, adj_t, x, y, n_cell = homogenize(graph)
    pe = init_homo(jax.random.PRNGKey(0), x.shape[1], 32, kind="gat_edge",
                   nnz=adj.nnz)
    pg = init_homo(jax.random.PRNGKey(0), x.shape[1], 32, kind="gcn")
    pg = pg._replace(w_layers=tuple(w for (w, s) in pe.w_layers))
    pred_e = homo_forward(pe, adj, adj_t, x, n_cell, kind="gat_edge")
    pred_g = homo_forward(pg, adj, adj_t, x, n_cell, kind="gcn")
    np.testing.assert_allclose(np.asarray(pred_e), np.asarray(pred_g),
                               rtol=1e-5, atol=1e-5)


def test_gat_edge_scores_learn(graph):
    """dL/ds flows through the fused learnable op and a GD step on the
    per-edge scores reduces the loss."""
    adj, adj_t, x, y, n_cell = homogenize(graph)
    params = init_homo(jax.random.PRNGKey(2), x.shape[1], 32,
                       kind="gat_edge", nnz=adj.nnz)

    def loss(p):
        pred = homo_forward(p, adj, adj_t, x, n_cell, kind="gat_edge")
        return jnp.mean((pred - y) ** 2)

    g = jax.grad(loss)(params)
    gs = np.asarray(g.w_layers[0][1])
    assert np.abs(gs).max() > 0, "no gradient reached the edge scores"
    l0 = float(loss(params))
    stepped = jax.tree.map(lambda p, gg: p - 1.0 * gg, params, g)
    assert float(loss(stepped)) < l0


def test_generator_matches_table1_statistics():
    """Structural stats the paper depends on (Fig. 4 / Table 1)."""
    rng = np.random.default_rng(0)
    coo, xc, xn, y = generate_partition(rng, 2000, 1000)
    near_dst, near_src = coo["near"]
    deg = np.bincount(near_dst, minlength=2000)
    assert deg.max() > 4 * max(deg.mean(), 1)      # evil rows exist
    pin_cell, pin_net = coo["pinned"][0], coo["pinned"][1]
    pdeg = np.bincount(coo["pin"][0], minlength=1000)
    assert 2 <= pdeg[pdeg > 0].mean() <= 8         # pins concentrate low
    # pinned is pin transposed
    a = set(zip(coo["pin"][0].tolist(), coo["pin"][1].tolist()))
    b = set(zip(coo["pinned"][1].tolist(), coo["pinned"][0].tolist()))
    assert a == b
    # labels correlate with density (learnable)
    dens = np.bincount(near_dst, minlength=2000).astype(np.float64)
    assert M.pearson(dens, y) > 0.5


def test_design_sizes():
    gs = generate_design(1, "medium", scale=0.02)
    assert len(gs) == TABLE1["medium"]["graphs"]


def test_metrics_against_known_values():
    a = np.array([1.0, 2.0, 3.0, 4.0])
    assert abs(M.pearson(a, a) - 1.0) < 1e-9
    assert abs(M.spearman(a, -a) + 1.0) < 1e-9
    assert abs(M.kendall(a, a) - 1.0) < 1e-9
    b = np.array([1.0, 3.0, 2.0, 4.0])
    assert abs(M.kendall(a, b) - (4.0 / 6.0)) < 1e-9   # 5 conc, 1 disc
    assert M.mae(a, b) == 0.5
    assert abs(M.rmse(a, b) - np.sqrt(0.5)) < 1e-9


def test_metrics_with_ties():
    a = np.array([1.0, 1.0, 2.0, 3.0])
    b = np.array([1.0, 2.0, 2.0, 3.0])
    s = M.spearman(a, b)
    assert 0.5 < s <= 1.0
