"""DR-CircuitGNN model + homogeneous baselines + metrics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hetero_mp import HeteroMPConfig
from repro.graphs.generator import generate_design, generate_partition, TABLE1
from repro.models.hgnn import (drcircuitgnn_forward, homo_forward, homogenize,
                               init_drcircuitgnn, init_homo)
from repro.train import metrics as M


@pytest.fixture(scope="module")
def graph():
    return generate_design(3, "small", scale=0.03)[0]


def test_forward_shapes_and_range(graph):
    params = init_drcircuitgnn(jax.random.PRNGKey(0), 16, 16, 32)
    cfg = HeteroMPConfig(hidden=32, k_cell=8, k_net=8)
    pred = drcircuitgnn_forward(params, graph, cfg)
    assert pred.shape == (graph.n_cell,)
    p = np.asarray(pred)
    assert np.all((p >= 0) & (p <= 1)) and not np.isnan(p).any()


@pytest.mark.parametrize("kind", ["gcn", "sage", "gat"])
def test_homogeneous_baselines_run(graph, kind):
    adj, adj_t, x, y, n_cell = homogenize(graph)
    params = init_homo(jax.random.PRNGKey(0), x.shape[1], 32, kind=kind)
    pred = homo_forward(params, adj, adj_t, x @ jnp.eye(x.shape[1]), n_cell,
                        kind=kind)
    assert pred.shape == (n_cell,)
    assert not np.isnan(np.asarray(pred)).any()


def test_generator_matches_table1_statistics():
    """Structural stats the paper depends on (Fig. 4 / Table 1)."""
    rng = np.random.default_rng(0)
    coo, xc, xn, y = generate_partition(rng, 2000, 1000)
    near_dst, near_src = coo["near"]
    deg = np.bincount(near_dst, minlength=2000)
    assert deg.max() > 4 * max(deg.mean(), 1)      # evil rows exist
    pin_cell, pin_net = coo["pinned"][0], coo["pinned"][1]
    pdeg = np.bincount(coo["pin"][0], minlength=1000)
    assert 2 <= pdeg[pdeg > 0].mean() <= 8         # pins concentrate low
    # pinned is pin transposed
    a = set(zip(coo["pin"][0].tolist(), coo["pin"][1].tolist()))
    b = set(zip(coo["pinned"][1].tolist(), coo["pinned"][0].tolist()))
    assert a == b
    # labels correlate with density (learnable)
    dens = np.bincount(near_dst, minlength=2000).astype(np.float64)
    assert M.pearson(dens, y) > 0.5


def test_design_sizes():
    gs = generate_design(1, "medium", scale=0.02)
    assert len(gs) == TABLE1["medium"]["graphs"]


def test_metrics_against_known_values():
    a = np.array([1.0, 2.0, 3.0, 4.0])
    assert abs(M.pearson(a, a) - 1.0) < 1e-9
    assert abs(M.spearman(a, -a) + 1.0) < 1e-9
    assert abs(M.kendall(a, a) - 1.0) < 1e-9
    b = np.array([1.0, 3.0, 2.0, 4.0])
    assert abs(M.kendall(a, b) - (4.0 / 6.0)) < 1e-9   # 5 conc, 1 disc
    assert M.mae(a, b) == 0.5
    assert abs(M.rmse(a, b) - np.sqrt(0.5)) < 1e-9


def test_metrics_with_ties():
    a = np.array([1.0, 1.0, 2.0, 3.0])
    b = np.array([1.0, 2.0, 2.0, 3.0])
    s = M.spearman(a, b)
    assert 0.5 < s <= 1.0
