"""Unit tests for the trip-count-aware HLO analyzer."""

from repro.launch import hlo_analysis as H

SYNTH = """\
HloModule test, is_scheduled=true

%inner.1 (p0: f32[8,16]) -> f32[8,16] {
  %p0 = f32[8,16]{1,0} parameter(0)
  %w = f32[16,16]{1,0} parameter(1)
  %dot.1 = f32[8,16]{1,0} dot(%p0, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[8,16]{1,0} all-gather(%dot.1), channel_id=1, dimensions={0}
  ROOT %out = f32[8,16]{1,0} add(%ag, %p0)
}

%cond.1 (c: s32[]) -> pred[] {
  %c = s32[] parameter(0)
  ROOT %lt = pred[] compare(%c, %c), direction=LT
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16]{1,0} parameter(0)
  %t = (s32[], f32[8,16]) tuple(%a)
  %wh = (s32[], f32[8,16]{1,0}) while(%t), condition=%cond.1, body=%inner.1, backend_config={"known_trip_count":{"n":"7"}}
  %ar = f32[8,16]{1,0} all-reduce(%a), channel_id=2, to_apply=%cond.1
  ROOT %r = f32[8,16]{1,0} add(%ar, %a)
}
"""


def test_parse_computations():
    comps = H.parse_module(SYNTH)
    assert {"inner.1", "cond.1", "main"} <= set(comps)
    assert any(i.opcode == "dot" for i in comps["inner.1"].instrs)


def test_trip_count_multiplication():
    r = H.analyze(SYNTH)
    # dot: 2*8*16*16 = 4096 flops, ×7 trips
    assert r["flops"] == 4096 * 7


def test_collective_bytes_with_trips():
    r = H.analyze(SYNTH)
    # all-gather inside loop: 8*16*4 bytes ×7; all-reduce outside: ×1
    expected = 8 * 16 * 4 * 7 + 8 * 16 * 4
    assert r["collective_bytes"] == expected
    assert r["collectives"]["all-gather"] == 8 * 16 * 4 * 7


def test_aliased_bytes_leq_bytes():
    r = H.analyze(SYNTH)
    assert 0 < r["bytes_aliased"] <= r["bytes"]


def test_dtype_sizes():
    assert H._nbytes("bf16", (4, 4)) == 32
    assert H._nbytes("f32", ()) == 4
    assert H._nbytes("pred", (8,)) == 8


def test_real_dryrun_record_consistency():
    """The analyzer ran on every sweep cell; spot-check invariants on the
    stored records."""
    import glob
    import json
    recs = [json.load(open(f))
            for f in glob.glob("experiments/dryrun/*_sp.json")]
    oks = [r for r in recs if r.get("status") == "ok"]
    if not oks:   # sweep not run in this checkout
        return
    for r in oks:
        assert r["flops"] > 0
        assert r["bytes_accessed"] > r["collectives"]["total"] * 0.5 or \
            r["collectives"]["total"] < 1e9
        # trip-corrected flops must exceed XLA's naive count for TRAIN
        # cells (L-layer scans); decode cells have ~1 trip and XLA's count
        # includes elementwise flops our dot-only model excludes.
        if r.get("kind") == "train":
            assert r["flops"] >= r.get("xla_flops_naive", 0) * 0.9
