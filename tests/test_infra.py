"""Infra: optimizer, schedules, compression, checkpoint, fault policy,
data pipeline, sharding specs."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # bare container: seeded fallback
    from _hyp_fallback import given, settings, strategies as st

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.fault import ElasticController, StepMonitor
from repro.optim import adamw_init, adamw_update, constant, cosine, wsd
from repro.optim.compression import quantize_int8

settings.register_profile("fast", max_examples=25, deadline=None)
settings.load_profile("fast")


# --------------------------- optimizer --------------------------------

def test_adamw_first_step_is_lr_sized():
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.full((4,), 0.5)}
    st_ = adamw_init(params)
    new, st2 = adamw_update(params, grads, st_, jnp.asarray(0.1))
    # bias-corrected first step ≈ lr * sign(g)
    np.testing.assert_allclose(np.asarray(new["w"]), 1.0 - 0.1, rtol=1e-3)
    assert int(st2.step) == 1


def test_adamw_weight_decay_shrinks():
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.zeros((4,))}
    st_ = adamw_init(params)
    new, _ = adamw_update(params, grads, st_, jnp.asarray(0.1),
                          weight_decay=0.5)
    assert float(new["w"][0]) < 1.0


def test_grad_clip():
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.full((4,), 100.0)}
    st_ = adamw_init(params)
    _, st2 = adamw_update(params, grads, st_, jnp.asarray(0.1),
                          grad_clip=1.0)
    # clipped grads: m = (1-b1)*g_clipped, |g_clipped| = 1/2 per element
    assert float(jnp.abs(st2.m["w"]).max()) < 0.06


def test_wsd_schedule_shape():
    f = wsd(1.0, 1000)
    assert float(f(jnp.asarray(0))) < 0.2            # warmup
    assert float(f(jnp.asarray(500))) == 1.0         # stable
    assert float(f(jnp.asarray(999))) < 0.2          # decay
    c = cosine(1.0, 1000, warmup=10)
    assert float(c(jnp.asarray(1000))) <= 0.11


# --------------------------- compression ------------------------------

@given(st.integers(0, 10_000))
def test_int8_quantization_error_bound(seed):
    x = np.random.default_rng(seed).normal(size=(64,)).astype(np.float32)
    q, scale = quantize_int8(jnp.asarray(x))
    deq = np.asarray(q, np.float32) * float(scale)
    assert np.abs(deq - x).max() <= float(scale) * 0.5 + 1e-7


def test_int8_sum_exactness():
    """int32 accumulation of quantized values is exact."""
    x = np.array([1.0, -2.0, 3.0], np.float32)
    q, s = quantize_int8(jnp.asarray(x))
    total = np.asarray(q, np.int32) * 4                 # 4 participants
    deq = total.astype(np.float32) * float(s)
    np.testing.assert_allclose(deq / 4, np.asarray(q, np.float32) * float(s))


# --------------------------- checkpoint -------------------------------

def test_checkpoint_roundtrip(tmp_path):
    state = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
             "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
             "t": (jnp.zeros(()), jnp.ones((2,)))}
    save_checkpoint(str(tmp_path), 7, state)
    assert latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state)
    restored = restore_checkpoint(str(tmp_path), 7, like)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_manager_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=1, keep=2)
    state = {"w": jnp.ones((2,))}
    for step in range(5):
        mgr.maybe_save(step, state)
    mgr.finalize()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"w": jnp.ones((2,))})
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


# --------------------------- fault policy -----------------------------

def test_straggler_detection_escalates():
    mon = StepMonitor(n_hosts=1, patience=2)
    for s in range(20):
        mon.record(s, 0, 1.0)
    ev1 = mon.record(20, 0, 3.0)
    assert ev1 and ev1.action == "slack"
    ev2 = mon.record(21, 0, 3.0)
    assert ev2 and ev2.action == "rebalance"
    ev3 = mon.record(22, 0, 100.0)
    assert ev3 and ev3.action == "restart"


def test_healthy_steps_no_events():
    mon = StepMonitor()
    for s in range(50):
        assert mon.record(s, 0, 1.0 + 0.01 * (s % 3)) is None


def test_elastic_shrink():
    ec = ElasticController(data=16, model=16, pods=2)
    assert ec.shrink(0) == (2, 16, 16)
    pods, data, model = ec.shrink(16)     # lose a pod's worth
    assert model == 16 and pods * data * model <= 2 * 16 * 16 - 0
    pods, data, model = ec.shrink(3)      # partial loss -> shrink data
    assert data in (8, 16) and model == 16


def test_shard_remap_covers_dead():
    ec = ElasticController(data=8, model=1)
    remap = ec.shard_remap(8, dead=[2, 5])
    assert set(remap) == {2, 5}
    assert all(t not in (2, 5) for t in remap.values())


# --------------------------- data pipeline ----------------------------

def test_pipeline_determinism_and_shard_disjointness():
    cfg = DataConfig(vocab=1000, seq_len=32, global_batch=8, n_shards=4)
    p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
    b1 = p1.shard_batch(5, 2)
    b2 = p2.shard_batch(5, 2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # different shards/steps differ
    assert not np.array_equal(b1["tokens"], p1.shard_batch(5, 3)["tokens"])
    assert not np.array_equal(b1["tokens"], p1.shard_batch(6, 2)["tokens"])


def test_pipeline_targets_shifted():
    cfg = DataConfig(vocab=50, seq_len=16, global_batch=2)
    b = TokenPipeline(cfg).global_batch(0)
    assert b["tokens"].shape == (2, 16)
    # targets are next-token: overlap check
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_pipeline_tokens_in_range():
    cfg = DataConfig(vocab=128, seq_len=64, global_batch=4)
    b = TokenPipeline(cfg).global_batch(3)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 128


# --------------------------- sharding specs ---------------------------

def test_make_pspec_divisibility_fallback():
    from jax.sharding import PartitionSpec as P
    from repro.sharding.specs import make_pspec
    mesh = jax.make_mesh((1,), ("model",))
    # size-1 axis: everything shards trivially
    assert make_pspec((16, 7), ("mlp", "vocab"), mesh) == P("model", "model")
    mesh1 = jax.make_mesh((1,), ("data",))
    spec = make_pspec((16, 7), ("mlp", None), mesh1)
    assert spec == P(None, None)     # 'model' absent from mesh => replicated
