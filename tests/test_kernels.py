"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle, swept
over shapes and dtypes (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cbsr import cbsr_from_dense
from repro.graphs.ell import pack_ell_pair
from repro.kernels import ops, ref
from repro.kernels import drspmm as K


def make_graph(rng, n_dst, n_src, nnz):
    dst = rng.integers(0, n_dst, nnz)
    src = rng.integers(0, n_src, nnz)
    pairs = np.unique(np.stack([dst, src], 1), axis=0)
    w = rng.normal(size=pairs.shape[0]).astype(np.float32)
    return pack_ell_pair(pairs[:, 0], pairs[:, 1], w, n_dst, n_src)


SHAPES = [
    # (n_dst, n_src, nnz, D, k)
    (8, 8, 20, 8, 4),
    (37, 53, 400, 32, 8),
    (64, 64, 1000, 64, 16),
    (100, 40, 600, 128, 32),
    (16, 128, 256, 16, 16),       # k == D (no sparsity)
]


@pytest.mark.parametrize("n_dst,n_src,nnz,d,k", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_drspmm_fwd_vs_oracle(n_dst, n_src, nnz, d, k, dtype):
    rng = np.random.default_rng(n_dst + d)
    adj, adj_t = make_graph(rng, n_dst, n_src, nnz)
    x = rng.normal(size=(n_src, d)).astype(np.float32)
    c = cbsr_from_dense(jnp.asarray(x, dtype), k)
    y_ref = ref.drspmm_fwd_ref(adj, c.values.astype(jnp.float32),
                               c.idx, d)
    y = ops.drspmm(adj, adj_t, c.values, c.idx, d, backend="pallas")
    tol = 1e-5 if dtype == jnp.float32 else 8e-2
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(y_ref),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("n_dst,n_src,nnz,d,k", SHAPES[:4])
def test_drspmm_bwd_vs_oracle(n_dst, n_src, nnz, d, k):
    rng = np.random.default_rng(7 * n_dst + d)
    adj, adj_t = make_graph(rng, n_dst, n_src, nnz)
    x = rng.normal(size=(n_src, d)).astype(np.float32)
    c = cbsr_from_dense(jnp.asarray(x), k)

    def loss(xv, backend):
        y = ops.drspmm(adj, adj_t, xv, c.idx, d, backend=backend)
        return jnp.sum(jnp.sin(y))

    g_ref = jax.grad(lambda xv: jnp.sum(jnp.sin(
        ref.drspmm_fwd_ref(adj, xv, c.idx, d))))(c.values)
    g = jax.grad(loss)(c.values, "pallas")
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n_dst,n_src,nnz,d,k", SHAPES[:3])
def test_xla_backend_matches_pallas(n_dst, n_src, nnz, d, k):
    rng = np.random.default_rng(n_dst * 13)
    adj, adj_t = make_graph(rng, n_dst, n_src, nnz)
    x = rng.normal(size=(n_src, d)).astype(np.float32)
    c = cbsr_from_dense(jnp.asarray(x), k)
    y_p = ops.drspmm(adj, adj_t, c.values, c.idx, d, backend="pallas")
    y_x = ops.drspmm(adj, adj_t, c.values, c.idx, d, backend="xla")
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_x),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,d", [(32, 16), (64, 64), (40, 128)])
def test_dense_spmm_kernel(n, d):
    rng = np.random.default_rng(n + d)
    adj, adj_t = make_graph(rng, n, n, n * 6)
    x = rng.normal(size=(n, d)).astype(np.float32)
    y_ref = ref.spmm_dense_ref(adj, jnp.asarray(x))
    for b in adj.buckets:
        _ = K.spmm_dense_bucket(b, jnp.asarray(x))      # kernel runs
    y = ops.spmm(adj, adj_t, jnp.asarray(x), backend="pallas")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_empty_rows_are_zero():
    """Rows with no in-edges must produce exactly zero output rows."""
    dst = np.array([0, 0, 2])
    src = np.array([1, 2, 0])
    adj, adj_t = pack_ell_pair(dst, src, None, 5, 3)
    x = np.ones((3, 8), np.float32)
    c = cbsr_from_dense(jnp.asarray(x), 4)
    y = ops.drspmm(adj, adj_t, c.values, c.idx, 8, backend="pallas")
    assert np.allclose(np.asarray(y)[[1, 3, 4]], 0.0)
    assert not np.allclose(np.asarray(y)[0], 0.0)


def test_gradient_zero_outside_cbsr_support():
    """SSpMM: gradients must vanish at positions D-ReLU zeroed (Alg. 2)."""
    rng = np.random.default_rng(3)
    adj, adj_t = make_graph(rng, 20, 20, 100)
    x = jnp.asarray(rng.normal(size=(20, 16)).astype(np.float32))
    from repro.core.drelu import drelu

    def loss(xd):
        xs = drelu(xd, 4)
        c = cbsr_from_dense(xs, 4)
        y = ops.drspmm(adj, adj_t, c.values, c.idx, 16, backend="xla")
        return jnp.sum(y ** 2)

    g = jax.grad(loss)(x)
    xs = drelu(x, 4)
    mask = np.asarray(xs != 0)
    assert np.all(np.asarray(g)[~mask] == 0.0)
