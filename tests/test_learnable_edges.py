"""Learnable edge weights through DR-SpMM vs dense oracle (fwd + both grads).

Covers the fused single-dispatch path (DESIGN.md §8): 5-backend parity,
padded eid-slot (−1) inertness, fused eid packing round-trip, executor
cache hits, and collated (member-offset) eid arenas.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # bare container: seeded fallback
    from _hyp_fallback import given, settings, strategies as st

from repro.core.cbsr import cbsr_from_dense
from repro.graphs.ell import (fuse_bucketed, pack_eid_slabs,
                              pack_fused_eid_pair)
from repro.kernels import ops
from repro.kernels.learnable import drspmm_learnable

settings.register_profile("fast_learnable", max_examples=25, deadline=None)
settings.load_profile("fast_learnable")

BACKENDS = ("pallas_fused", "xla_fused", "pallas", "xla", "dense")


def setup(seed=0, n_dst=23, n_src=31, nnz_target=200, d=16, k=4):
    rng = np.random.default_rng(seed)
    dst = rng.integers(0, n_dst, nnz_target)
    src = rng.integers(0, n_src, nnz_target)
    pairs = np.unique(np.stack([dst, src], 1), axis=0)
    dst, src = pairs[:, 0], pairs[:, 1]
    fwd, bwd, order, nnz = pack_eid_slabs(dst, src, n_dst, n_src)
    w = jnp.asarray(rng.normal(size=nnz).astype(np.float32))
    x = rng.normal(size=(n_src, d)).astype(np.float32)
    c = cbsr_from_dense(jnp.asarray(x), k)
    # dense oracle: A(w) with w in CANONICAL (dst-stable-sorted) order
    canon = np.argsort(dst, kind="stable")
    a_rows, a_cols = dst[canon], src[canon]

    def dense_y(wv, xv):
        a = jnp.zeros((n_dst, n_src)).at[a_rows, a_cols].add(wv)
        xd = jnp.zeros((n_src, d)).at[
            jnp.arange(n_src)[:, None], c.idx].add(xv)
        return a @ xd

    return fwd, bwd, nnz, w, c, d, dense_y


def test_forward_matches_dense():
    fwd, bwd, nnz, w, c, d, dense_y = setup()
    y = drspmm_learnable(fwd, bwd, nnz, w, c.values, c.idx, d)
    np.testing.assert_allclose(np.asarray(y), np.asarray(dense_y(w, c.values)),
                               rtol=1e-5, atol=1e-5)


def test_gradients_match_dense():
    fwd, bwd, nnz, w, c, d, dense_y = setup(seed=3)

    def loss(wv, xv):
        return jnp.sum(jnp.sin(
            drspmm_learnable(fwd, bwd, nnz, wv, xv, c.idx, d)))

    def loss_ref(wv, xv):
        return jnp.sum(jnp.sin(dense_y(wv, xv)))

    gw, gx = jax.grad(loss, argnums=(0, 1))(w, c.values)
    gw_r, gx_r = jax.grad(loss_ref, argnums=(0, 1))(w, c.values)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gw_r),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_r),
                               rtol=1e-4, atol=1e-5)


def test_weights_actually_learn():
    """One GD step on w reduces a target-matching loss."""
    fwd, bwd, nnz, w, c, d, dense_y = setup(seed=5)
    target = dense_y(w * 0.3, c.values)

    def loss(wv):
        y = drspmm_learnable(fwd, bwd, nnz, wv, c.values, c.idx, d)
        return jnp.mean((y - target) ** 2)

    l0 = float(loss(w))
    g = jax.grad(loss)(w)
    l1 = float(loss(w - 0.5 * g))
    assert l1 < l0


# ------------------- fused path: 5-backend parity ----------------------

def setup_mixed(seed=7, n_dst=41, n_src=37, d=16, k=4):
    """Heavy-tailed degrees (evil row + sparse bulk) so the packing spans
    several buckets and the arenas carry real −1 padding."""
    rng = np.random.default_rng(seed)
    deg = rng.integers(1, 30, n_dst)
    deg[0] = n_src - 1                    # evil row
    dst = np.repeat(np.arange(n_dst), deg)
    src = rng.integers(0, n_src, dst.size)
    pairs = np.unique(np.stack([dst, src], 1), axis=0)
    dst, src = pairs[:, 0], pairs[:, 1]
    fwd, bwd, order, nnz = pack_eid_slabs(dst, src, n_dst, n_src)
    w = jnp.asarray(rng.normal(size=nnz).astype(np.float32))
    x = rng.normal(size=(n_src, d)).astype(np.float32)
    c = cbsr_from_dense(jnp.asarray(x), k)
    canon = np.argsort(dst, kind="stable")
    a_rows, a_cols = dst[canon], src[canon]

    def dense_y(wv, xv):
        a = jnp.zeros((n_dst, n_src)).at[a_rows, a_cols].add(wv)
        xd = jnp.zeros((n_src, d)).at[
            jnp.arange(n_src)[:, None], c.idx].add(xv)
        return a @ xd

    return fwd, bwd, nnz, w, c, d, dense_y


@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_parity_fwd_and_grads(backend):
    """Every backend matches the dense oracle: forward, dw, and dx."""
    fwd, bwd, nnz, w, c, d, dense_y = setup_mixed()

    def loss(wv, xv):
        return jnp.sum(jnp.sin(ops.drspmm_learnable(
            fwd, bwd, nnz, wv, xv, c.idx, d, backend=backend)))

    def loss_ref(wv, xv):
        return jnp.sum(jnp.sin(dense_y(wv, xv)))

    y = ops.drspmm_learnable(fwd, bwd, nnz, w, c.values, c.idx, d,
                             backend=backend)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(dense_y(w, c.values)),
                               rtol=1e-4, atol=1e-4,
                               err_msg=f"fwd {backend}")
    gw, gx = jax.grad(loss, argnums=(0, 1))(w, c.values)
    gw_r, gx_r = jax.grad(loss_ref, argnums=(0, 1))(w, c.values)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gw_r),
                               rtol=1e-4, atol=1e-4,
                               err_msg=f"dw {backend}")
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_r),
                               rtol=1e-4, atol=1e-4,
                               err_msg=f"dx {backend}")


def test_prefused_arenas_upgrade_bucket_backends():
    """Pre-fused eid arenas (the collated-batch form) run under every
    backend name via the family-upgrade rules."""
    fwd, bwd, nnz, w, c, d, dense_y = setup_mixed(seed=11)
    # rebuild the fused pair straight from the slabs
    ff, fb = fuse_bucketed(fwd, eids=True), fuse_bucketed(bwd, eids=True)
    y_ref = np.asarray(dense_y(w, c.values))
    for be in ("xla", "pallas", "xla_fused", "pallas_fused", "dense"):
        y = ops.drspmm_learnable(ff, fb, nnz, w, c.values, c.idx, d,
                                 backend=be)
        np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4,
                                   atol=1e-4, err_msg=f"prefused {be}")


# ------------------- padded eid-slot inertness -------------------------

def test_fused_eid_padding_slots_are_inert():
    """Padding slots decode to −1 exactly where the mask is 0, every real
    edge id appears exactly once, and scribbling on the weights of padded
    slots' gather target (the appended zero slot) cannot change the output
    — i.e. padding gathers weight 0 by construction."""
    fwd, bwd, nnz, w, c, d, dense_y = setup_mixed(seed=13)
    f = fuse_bucketed(fwd, eids=True)
    eid = np.asarray(f.eid)
    mask = np.asarray(f.w)
    assert ((eid < 0) == (mask == 0)).all()
    real = eid[eid >= 0]
    assert sorted(real.tolist()) == list(range(nnz))   # bijective coverage
    # numerics: fused output with half the weights zeroed matches dense —
    # zero CANONICAL weights are real edges (not padding) and must still
    # land; padding must contribute nothing.
    w_half = w.at[::2].set(0.0)
    y = ops.drspmm_learnable(fwd, bwd, nnz, w_half, c.values, c.idx, d,
                             backend="xla_fused")
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(dense_y(w_half, c.values)),
                               rtol=1e-4, atol=1e-4)


# ------------------- fused eid packing round-trip ----------------------

rt_graphs = st.integers(0, 2 ** 31 - 1).flatmap(lambda seed: st.tuples(
    st.just(seed), st.integers(1, 40), st.integers(1, 40),
    st.integers(0, 200)))


@given(rt_graphs)
def test_fused_eid_packing_roundtrip(args):
    """Scattering w[eid] over the fused arena reconstructs exactly the
    dense A(w) the canonical COO builds."""
    seed, n_dst, n_src, nnz_t = args
    rng = np.random.default_rng(seed)
    if nnz_t:
        dst = rng.integers(0, n_dst, nnz_t)
        src = rng.integers(0, n_src, nnz_t)
        pairs = np.unique(np.stack([dst, src], 1), axis=0)
        dst, src = pairs[:, 0], pairs[:, 1]
    else:
        dst = src = np.zeros(0, np.int64)
    ff, fb, order, nnz = pack_fused_eid_pair(dst, src, n_dst, n_src)
    w = rng.normal(size=nnz).astype(np.float32)
    canon = np.argsort(dst, kind="stable")
    a_ref = np.zeros((n_dst, n_src), np.float32)
    np.add.at(a_ref, (dst[canon], src[canon]), w)
    for f, shape in ((ff, (n_dst, n_src)), (fb, (n_src, n_dst))):
        a = np.zeros(shape, np.float32)
        eid = np.asarray(f.eid)
        rows = np.asarray(f.rows)
        blk = np.asarray(f.block_of)
        br = f.row_block
        for ci in range(f.n_chunks):
            for b in range(br):
                rid = rows[blk[ci] * br + b]
                sl = eid[ci, b]
                m = sl >= 0
                np.add.at(a[rid], np.asarray(f.nbr)[ci, b][m], w[sl[m]])
        ref = a_ref if shape == (n_dst, n_src) else a_ref.T
        np.testing.assert_allclose(a, ref, atol=1e-6)
    assert ff.nnz == fb.nnz == nnz == dst.shape[0]


# ------------------- executor cache regression -------------------------

def test_no_retrace_on_second_call():
    """The custom-vjp executor must be built (and traced) once per
    (packing, nnz, dim, backend) — the seed rebuilt it per call, defeating
    jit caching (same class of bug tests/test_parallel_cache.py guards in
    core/parallel.py)."""
    fwd, bwd, nnz, w, c, d, dense_y = setup_mixed(seed=17)
    for be in ("xla", "xla_fused"):
        ops.drspmm_learnable(fwd, bwd, nnz, w, c.values, c.idx, d,
                             backend=be)                   # warm (trace 1)
        n0 = len(ops._LEARNABLE_TRACES)
        a = ops.drspmm_learnable(fwd, bwd, nnz, w, c.values, c.idx, d,
                                 backend=be)
        b = ops.drspmm_learnable(fwd, bwd, nnz, 2 * w, c.values, c.idx, d,
                                 backend=be)
        assert len(ops._LEARNABLE_TRACES) == n0, \
            f"repeated {be} call retraced the learnable executor"
        assert jnp.allclose(2 * a, b, atol=1e-5)


def test_executable_identity_is_cached():
    fwd, bwd, nnz, w, c, d, _ = setup_mixed(seed=19)
    e1 = ops._learnable_executable(fwd, bwd, nnz, d, "xla")
    e2 = ops._learnable_executable(fwd, bwd, nnz, d, "xla")
    assert e1 is e2
    assert ops._learnable_executable(fwd, bwd, nnz, d, "xla_fused") is not e1


# ------------------- collated (member-offset) eid arenas ---------------

def test_collated_eids_match_per_member():
    """Block-diagonal collation with_eids: the batched learnable op over
    the merged arena equals each member's own learnable op, forward and
    w-gradient (member weights concatenated at the recorded offsets)."""
    from repro.graphs.collate import collate_graphs
    from repro.graphs.ell import ell_to_coo
    from repro.graphs.generator import generate_design

    gs = generate_design(4, "small", scale=0.03)[:2]
    batch = collate_graphs(gs, with_eids=True)
    et = "near"
    es = batch.graph.edges[et]
    nnz = batch.edge_nnz[et]
    offs = batch.edge_eid_offsets[et]
    assert es.adj.eid is not None and es.adj_t.eid is not None

    rng = np.random.default_rng(0)
    d, k = 16, 4
    packs, ws, xvs, xis = [], [], [], []
    for g in gs:
        dst, src, _w = ell_to_coo(g.edges[et].adj)
        order = np.argsort(dst, kind="stable")
        packs.append(pack_eid_slabs(dst[order], src[order],
                                    g.n_cell, g.n_cell))
        ws.append(rng.normal(size=packs[-1][3]).astype(np.float32))
        xvs.append(rng.normal(size=(g.n_cell, k)).astype(np.float32))
        xis.append(rng.integers(0, d, size=(g.n_cell, k)).astype(np.int32))

    xv_b = np.zeros((batch.graph.n_cell, k), np.float32)
    xi_b = np.zeros((batch.graph.n_cell, k), np.int32)
    for m, xv, xi in zip(batch.members, xvs, xis):
        xv_b[m.cell_off:m.cell_off + m.n_cell] = xv
        xi_b[m.cell_off:m.cell_off + m.n_cell] = xi
    w_b = batch.concat_edge_weights(et, ws)

    def batched(wv):
        return ops.drspmm_learnable(es.adj, es.adj_t, nnz, wv,
                                    jnp.asarray(xv_b), jnp.asarray(xi_b),
                                    d, backend="xla_fused")

    y_b = batched(w_b)
    gw_b = jax.grad(lambda wv: jnp.sum(jnp.sin(batched(wv))))(w_b)
    for (fwd, bwd, _o, m_nnz), wv, xv, xi, m, off in zip(
            packs, ws, xvs, xis, batch.members, offs):
        def member(w0):
            return ops.drspmm_learnable(fwd, bwd, m_nnz, w0,
                                        jnp.asarray(xv), jnp.asarray(xi),
                                        d, backend="xla")
        y_m = member(jnp.asarray(wv))
        np.testing.assert_allclose(
            np.asarray(y_b[m.cell_off:m.cell_off + m.n_cell]),
            np.asarray(y_m), rtol=1e-4, atol=1e-5)
        gw_m = jax.grad(lambda w0: jnp.sum(jnp.sin(member(w0))))(
            jnp.asarray(wv))
        np.testing.assert_allclose(np.asarray(gw_b[off:off + m_nnz]),
                                   np.asarray(gw_m), rtol=1e-4, atol=1e-5)
