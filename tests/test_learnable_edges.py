"""Learnable edge weights through DR-SpMM vs dense oracle (fwd + both grads)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cbsr import cbsr_from_dense
from repro.graphs.ell import pack_eid_slabs
from repro.kernels.learnable import drspmm_learnable


def setup(seed=0, n_dst=23, n_src=31, nnz_target=200, d=16, k=4):
    rng = np.random.default_rng(seed)
    dst = rng.integers(0, n_dst, nnz_target)
    src = rng.integers(0, n_src, nnz_target)
    pairs = np.unique(np.stack([dst, src], 1), axis=0)
    dst, src = pairs[:, 0], pairs[:, 1]
    fwd, bwd, order, nnz = pack_eid_slabs(dst, src, n_dst, n_src)
    w = jnp.asarray(rng.normal(size=nnz).astype(np.float32))
    x = rng.normal(size=(n_src, d)).astype(np.float32)
    c = cbsr_from_dense(jnp.asarray(x), k)
    # dense oracle: A(w) with w in CANONICAL (dst-stable-sorted) order
    canon = np.argsort(dst, kind="stable")
    a_rows, a_cols = dst[canon], src[canon]

    def dense_y(wv, xv):
        a = jnp.zeros((n_dst, n_src)).at[a_rows, a_cols].add(wv)
        xd = jnp.zeros((n_src, d)).at[
            jnp.arange(n_src)[:, None], c.idx].add(xv)
        return a @ xd

    return fwd, bwd, nnz, w, c, d, dense_y


def test_forward_matches_dense():
    fwd, bwd, nnz, w, c, d, dense_y = setup()
    y = drspmm_learnable(fwd, bwd, nnz, w, c.values, c.idx, d)
    np.testing.assert_allclose(np.asarray(y), np.asarray(dense_y(w, c.values)),
                               rtol=1e-5, atol=1e-5)


def test_gradients_match_dense():
    fwd, bwd, nnz, w, c, d, dense_y = setup(seed=3)

    def loss(wv, xv):
        return jnp.sum(jnp.sin(
            drspmm_learnable(fwd, bwd, nnz, wv, xv, c.idx, d)))

    def loss_ref(wv, xv):
        return jnp.sum(jnp.sin(dense_y(wv, xv)))

    gw, gx = jax.grad(loss, argnums=(0, 1))(w, c.values)
    gw_r, gx_r = jax.grad(loss_ref, argnums=(0, 1))(w, c.values)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gw_r),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_r),
                               rtol=1e-4, atol=1e-5)


def test_weights_actually_learn():
    """One GD step on w reduces a target-matching loss."""
    fwd, bwd, nnz, w, c, d, dense_y = setup(seed=5)
    target = dense_y(w * 0.3, c.values)

    def loss(wv):
        y = drspmm_learnable(fwd, bwd, nnz, wv, c.values, c.idx, d)
        return jnp.mean((y - target) ** 2)

    l0 = float(loss(w))
    g = jax.grad(loss)(w)
    l1 = float(loss(w - 0.5 * g))
    assert l1 < l0
