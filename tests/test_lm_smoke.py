"""Per-arch smoke tests (deliverable f): reduced same-family config, one
forward/train step on CPU, asserting output shapes + no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config, reduced
from repro.models.lm.model import build_lm
from repro.train import lm_step


def _batch(cfg, lm, b=2, s=16):
    batch = {"tokens": jnp.zeros((b, s), jnp.int32),
             "targets": jnp.ones((b, s), jnp.int32)}
    if cfg.family == "vlm":
        batch["image_emb"] = jnp.full((b, cfg.n_img_tokens, cfg.d_model),
                                      0.01, lm.dtype)
    if cfg.family == "audio":
        batch["frames"] = jnp.full((b, cfg.enc_frames, cfg.d_model),
                                   0.01, lm.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    lm = build_lm(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, lm)

    hidden, aux = lm.forward(params, batch["tokens"],
                             {k: v for k, v in batch.items()
                              if k not in ("tokens", "targets")} or None)
    assert hidden.shape == (2, 16, cfg.d_model)
    assert not np.isnan(np.asarray(hidden, np.float32)).any()

    state = lm_step.init_train_state(lm, jax.random.PRNGKey(1))
    step = jax.jit(lm_step.make_train_step(lm, total_steps=10))
    state2, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually changed
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(state2.params)))
    assert changed


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_template_consistency(arch):
    """FULL configs: template shapes exist, param counts are sane, and the
    abstract params build without allocation."""
    cfg = get_config(arch)
    lm = build_lm(cfg, tp=16)
    ab = lm.abstract_params()
    n_tensor = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(ab))
    n_analytic = cfg.param_count()
    # template includes padding (heads/vocab); allow ±20%
    assert 0.65 < n_tensor / n_analytic < 1.35, (n_tensor, n_analytic)


def test_param_counts_match_names():
    """Headline parameter counts should be in the ballpark of the arch
    names (e.g. qwen3-1.7b ≈ 1.4–2.4 B)."""
    expect = {"qwen3-1.7b": (1.2e9, 2.4e9), "qwen3-0.6b": (0.4e9, 0.9e9),
              "minitron-4b": (3.5e9, 5.5e9), "minicpm-2b": (2.0e9, 3.3e9),
              "mamba2-1.3b": (1.0e9, 1.6e9),
              "llama-3.2-vision-90b": (70e9, 100e9),
              "moonshot-v1-16b-a3b": (13e9, 30e9),   # spec config: 48L×64e
                                                     # ×1408 → 28B total
              "granite-moe-1b-a400m": (0.8e9, 1.8e9),
              "whisper-large-v3": (1.2e9, 2.2e9),
              "zamba2-1.2b": (0.9e9, 1.9e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)


def test_moe_active_params_less_than_total():
    cfg = get_config("moonshot-v1-16b-a3b")
    assert cfg.active_param_count() < 0.45 * cfg.param_count()
