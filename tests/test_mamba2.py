"""Mamba2 SSD: chunked dual form vs naive sequential recurrence oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.lm.mamba2 import (causal_conv1d, causal_conv1d_step,
                                    ssd_chunked, ssd_decode_step, CONV_K)


def naive_ssd(x, b_mat, c_mat, dt, a_log, d_skip):
    """Sequential recurrence in f64: the ground truth SSD computes."""
    x, b_mat, c_mat, dt = (np.asarray(t, np.float64)
                           for t in (x, b_mat, c_mat, dt))
    a = -np.exp(np.asarray(a_log, np.float64))
    dtp = np.log1p(np.exp(dt))                           # softplus
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    state = np.zeros((bsz, h, p, n))
    ys = []
    for t in range(s):
        decay = np.exp(dtp[:, t] * a[None, :])           # (B,H)
        upd = np.einsum("bhp,bn->bhpn",
                        x[:, t] * dtp[:, t][..., None], b_mat[:, t])
        state = state * decay[:, :, None, None] + upd
        y = np.einsum("bhpn,bn->bhp", state, c_mat[:, t])
        ys.append(y + x[:, t] * np.asarray(d_skip)[None, :, None])
    return np.stack(ys, 1), state


@pytest.mark.parametrize("s,chunk", [(16, 4), (16, 16), (32, 8), (12, 5)])
def test_chunked_matches_recurrence(s, chunk):
    rng = np.random.default_rng(s * chunk)
    bsz, h, p, n = 2, 3, 4, 5
    x = rng.normal(size=(bsz, s, h, p)).astype(np.float32)
    bm = rng.normal(size=(bsz, s, n)).astype(np.float32)
    cm = rng.normal(size=(bsz, s, n)).astype(np.float32)
    dt = rng.normal(size=(bsz, s, h)).astype(np.float32)
    a_log = rng.normal(size=(h,)).astype(np.float32) * 0.3
    d_skip = rng.normal(size=(h,)).astype(np.float32)
    # chunk must divide s for the kernel; pick compatible
    if s % chunk:
        chunk = s
    y, st = ssd_chunked(jnp.asarray(x), jnp.asarray(bm), jnp.asarray(cm),
                        jnp.asarray(dt), jnp.asarray(a_log),
                        jnp.asarray(d_skip), chunk=chunk)
    y_ref, st_ref = naive_ssd(x, bm, cm, dt, a_log, d_skip)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), st_ref, rtol=2e-4, atol=2e-4)


def test_decode_continues_prefill():
    """Running S steps of decode == chunked over S tokens."""
    rng = np.random.default_rng(9)
    bsz, s, h, p, n = 1, 8, 2, 3, 4
    x = rng.normal(size=(bsz, s, h, p)).astype(np.float32)
    bm = rng.normal(size=(bsz, s, n)).astype(np.float32)
    cm = rng.normal(size=(bsz, s, n)).astype(np.float32)
    dt = rng.normal(size=(bsz, s, h)).astype(np.float32)
    a_log = rng.normal(size=(h,)).astype(np.float32) * 0.3
    d_skip = rng.normal(size=(h,)).astype(np.float32)
    y_all, st_all = ssd_chunked(jnp.asarray(x), jnp.asarray(bm),
                                jnp.asarray(cm), jnp.asarray(dt),
                                jnp.asarray(a_log), jnp.asarray(d_skip),
                                chunk=4)
    state = jnp.zeros((bsz, h, p, n))
    ys = []
    for t in range(s):
        y, state = ssd_decode_step(
            jnp.asarray(x[:, t: t + 1]), jnp.asarray(bm[:, t: t + 1]),
            jnp.asarray(cm[:, t: t + 1]), jnp.asarray(dt[:, t: t + 1]),
            jnp.asarray(a_log), jnp.asarray(d_skip), state)
        ys.append(np.asarray(y)[:, 0])
    np.testing.assert_allclose(np.stack(ys, 1), np.asarray(y_all),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(st_all),
                               rtol=2e-4, atol=2e-4)


def test_conv_step_matches_full():
    rng = np.random.default_rng(3)
    b, s, c = 2, 10, 6
    x = rng.normal(size=(b, s, c)).astype(np.float32)
    w = rng.normal(size=(CONV_K, c)).astype(np.float32)
    bias = rng.normal(size=(c,)).astype(np.float32)
    full = np.asarray(causal_conv1d(jnp.asarray(x), jnp.asarray(w),
                                    jnp.asarray(bias)))
    state = jnp.zeros((b, CONV_K - 1, c))
    outs = []
    for t in range(s):
        o, state = causal_conv1d_step(jnp.asarray(x[:, t: t + 1]), state,
                                      jnp.asarray(w), jnp.asarray(bias))
        outs.append(np.asarray(o)[:, 0])
    np.testing.assert_allclose(np.stack(outs, 1), full, rtol=1e-5, atol=1e-5)
