"""MoE routing + expert dispatch tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.lm.ffn import (_expert_ffn, _moe_local, _route,
                                 moe_capacity, moe_ffn)


def dense_moe_oracle(x2d, router_w, w_gate, w_up, w_down, top_k):
    """Every expert computed densely for every token, combined by the same
    normalized top-k weights — no capacity drops (oracle)."""
    logits = x2d @ router_w
    full = np.exp(logits - logits.max(-1, keepdims=True))
    full /= full.sum(-1, keepdims=True)
    top_idx = np.argsort(-full, axis=-1)[:, :top_k]
    t, e = full.shape
    y = np.zeros_like(x2d)
    for i in range(t):
        ps = full[i, top_idx[i]]
        ps = ps / ps.sum()
        for j, ei in enumerate(top_idx[i]):
            g = x2d[i] @ w_gate[ei]
            u = x2d[i] @ w_up[ei]
            h = (g / (1 + np.exp(-g))) * u
            y[i] += ps[j] * (h @ w_down[ei])
    return y


def test_local_moe_matches_dense_oracle():
    rng = np.random.default_rng(0)
    t, d, f, e, k = 16, 8, 12, 4, 2
    x = rng.normal(size=(1, t, d)).astype(np.float32) * 0.5
    rw = rng.normal(size=(d, e)).astype(np.float32)
    wg = rng.normal(size=(e, d, f)).astype(np.float32) * 0.3
    wu = rng.normal(size=(e, d, f)).astype(np.float32) * 0.3
    wd = rng.normal(size=(e, f, d)).astype(np.float32) * 0.3
    # capacity_factor huge => no drops => must equal the oracle
    y = _moe_local(jnp.asarray(x), jnp.asarray(rw), jnp.asarray(wg),
                   jnp.asarray(wu), jnp.asarray(wd), k, 100.0, 0, e)
    y_ref = dense_moe_oracle(x[0], rw, wg, wu, wd, k)
    np.testing.assert_allclose(np.asarray(y)[0], y_ref, rtol=2e-4, atol=2e-4)


def test_expert_sharding_partition_sums():
    """Sum of per-expert-shard outputs == single-shard output (the psum
    identity behind EP)."""
    rng = np.random.default_rng(1)
    t, d, f, e, k = 12, 6, 10, 4, 2
    x = jnp.asarray(rng.normal(size=(1, t, d)).astype(np.float32))
    rw = jnp.asarray(rng.normal(size=(d, e)).astype(np.float32))
    wg = jnp.asarray(rng.normal(size=(e, d, f)).astype(np.float32) * 0.3)
    wu = jnp.asarray(rng.normal(size=(e, d, f)).astype(np.float32) * 0.3)
    wd = jnp.asarray(rng.normal(size=(e, f, d)).astype(np.float32) * 0.3)
    full = _moe_local(x, rw, wg, wu, wd, k, 100.0, 0, e)
    half1 = _moe_local(x, rw, wg[:2], wu[:2], wd[:2], k, 100.0, 0, e)
    half2 = _moe_local(x, rw, wg[2:], wu[2:], wd[2:], k, 100.0, 2, e)
    np.testing.assert_allclose(np.asarray(half1 + half2), np.asarray(full),
                               rtol=1e-4, atol=1e-4)


def test_capacity_drops_tokens():
    """With capacity 8 slots and 16 assignments to one expert, later tokens
    are dropped (zero contribution), not corrupted."""
    t, d, f = 16, 4, 6
    x = jnp.ones((1, t, d))
    rw = jnp.zeros((d, 2)).at[:, 0].set(10.0)   # everyone routes to expert 0
    wg = jnp.ones((2, d, f)) * 0.1
    wu = jnp.ones((2, d, f)) * 0.1
    wd = jnp.ones((2, f, d)) * 0.1
    y = _moe_local(x, rw, wg, wu, wd, 1, 0.5, 0, 2)
    out = np.asarray(y)[0]
    kept = (np.abs(out).sum(-1) > 0)
    assert kept.sum() == moe_capacity(t, 2, 1, 0.5)
    # kept rows all equal (identical tokens)
    np.testing.assert_allclose(out[kept],
                               np.broadcast_to(out[kept][0], out[kept].shape),
                               rtol=1e-5)


def test_route_topk_normalized():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(10, 8)).astype(np.float32))
    rw = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
    probs, ids, full = _route(x, rw, 4)
    np.testing.assert_allclose(np.asarray(probs).sum(-1), 1.0, rtol=1e-5)
    assert np.asarray(ids).max() < 16
    # ids unique per row
    for row in np.asarray(ids):
        assert len(set(row.tolist())) == 4


def test_moe_ffn_aux_loss_balanced_vs_skewed():
    rng = np.random.default_rng(3)
    d, e = 8, 8
    x = jnp.asarray(rng.normal(size=(2, 8, d)).astype(np.float32))
    wg = jnp.asarray(rng.normal(size=(e, d, 4)).astype(np.float32) * 0.1)
    wu, wd = wg, jnp.asarray(rng.normal(size=(e, 4, d)).astype(np.float32) * 0.1)
    rw_uniform = jnp.zeros((d, e))
    _, aux_u = moe_ffn(x, rw_uniform, wg, wu, wd, n_experts=e, top_k=2)
    rw_skew = jnp.zeros((d, e)).at[:, 0].set(5.0)
    rw_skew = rw_skew + jnp.asarray(rng.normal(size=(d, e)) * 0.01)
    _, aux_s = moe_ffn(x, rw_skew, wg, wu, wd, n_experts=e, top_k=2)
    assert float(aux_s) > float(aux_u)   # skew must be penalized
