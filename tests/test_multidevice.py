"""Multi-device integration tests — run in a subprocess with 8 virtual
devices via the shared tests/_multidev.py runner (XLA device count locks at
first jax import, so these cannot share the main pytest process)."""

import pytest

from _multidev import run_multidev

SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
assert jax.device_count() == 8
from repro.configs.base import get_config, reduced
from repro.models.lm.model import build_lm
from repro.sharding.specs import mesh_context
from repro.train import lm_step

mesh_mp = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = reduced(get_config("qwen3-0.6b"))
lm = build_lm(cfg, tp=2)
batch = {"tokens": jnp.zeros((8, 32), jnp.int32),
         "targets": jnp.ones((8, 32), jnp.int32)}

with mesh_context(mesh_mp), mesh_mp:
    state = lm_step.init_train_state(lm, jax.random.PRNGKey(0))
    plain = jax.jit(lm_step.make_train_step(lm, total_steps=10))
    s1, m1 = plain(state, batch)
    comp = jax.jit(lm_step.make_train_step(lm, total_steps=10,
                                           compress_pod_grads=True))
    s2, m2 = comp(state, batch)

l1, l2 = float(m1["loss"]), float(m2["loss"])
assert abs(l1 - l2) < 1e-3, (l1, l2)
# int8-compressed grads: params close but not identical to exact path
d = max(float(jnp.abs(a - b).max())
        for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)))
assert d < 5e-3, d
print("COMPRESSED_OK", l1, l2, d)

# sequence-sharded decode (shard_map flash-decode) vs single-device oracle
from repro.models.lm import serve
params = lm.init(jax.random.PRNGKey(0))
tokens = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab, (2, 16)).astype(np.int32))
with mesh_context(None):
    cache0, logits0 = serve.prefill(lm, params, tokens, None)
    nc0, d0 = serve.decode_step(lm, params, cache0, tokens[:, -1:],
                                jnp.asarray(15, jnp.int32))
with mesh_context(mesh_mp), mesh_mp:
    cache1, logits1 = serve.prefill(lm, params, tokens, None)
    nc1, d1 = serve.decode_step(lm, params, cache1, tokens[:, -1:],
                                jnp.asarray(15, jnp.int32))
err = float(jnp.abs(d0 - d1).max())
assert err < 2e-3, err
print("SHARDED_DECODE_OK", err)

# elastic restore across mesh shapes
import tempfile
from repro.checkpoint import save_checkpoint, restore_checkpoint
tmp = tempfile.mkdtemp()
with mesh_context(mesh_mp), mesh_mp:
    save_checkpoint(tmp, 0, state)
mesh_small = jax.make_mesh((4, 2), ("data", "model"))
lm2 = build_lm(cfg, tp=2)
with mesh_context(mesh_small), mesh_small:
    shardings = lm_step.train_state_shardings(lm2, mesh_small)
    restored = restore_checkpoint(tmp, 0, state, shardings=shardings)
ok = all(np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
         for a, b in zip(jax.tree.leaves(state.params),
                         jax.tree.leaves(restored.params)))
assert ok
print("ELASTIC_OK")
"""


@pytest.mark.slow
def test_multidevice_subprocess():
    run_multidev(SCRIPT, n_devices=8,
                 expect=("COMPRESSED_OK", "SHARDED_DECODE_OK", "ELASTIC_OK"))
