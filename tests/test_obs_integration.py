"""Observability integration: the engine/trainer/collator/ops emitting
through one Recorder + MetricsRegistry (DESIGN.md §11).

Covers the ISSUE-7 acceptance points that live above the unit layer:
``stats()`` back-compat as a registry view, the no-op default's zero-cost
contract, pack-time arena gauges (the `near` slot saving), chaos
injections as trace annotations, and the 2-device online acceptance run
(per-slot dispatch tracks + healing ladder + deadline flush in one valid
trace)."""

import importlib.util
import json
import os
import threading

import numpy as np
import pytest

import jax

from _multidev import run_multidev
from repro.core.hetero_mp import HeteroMPConfig
from repro.fault.inject import FaultInjector, FaultRule
from repro.graphs.collate import collate_graphs
from repro.graphs.generator import generate_partition, pack_graph_parallel
from repro.models.hgnn import init_drcircuitgnn
from repro.obs import TraceRecorder
from repro.obs.metrics import DEFAULT_REGISTRY
from repro.serve import CircuitServeEngine

_spec = importlib.util.spec_from_file_location(
    "check_trace",
    os.path.join(os.path.dirname(__file__), "..", "tools", "check_trace.py"))
check_trace_mod = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_trace_mod)
check_trace = check_trace_mod.check_trace


def _graph(n_cell, n_net, seed):
    coo, xc, xn, y = generate_partition(np.random.default_rng(seed),
                                        n_cell, n_net)
    return pack_graph_parallel(coo, n_cell, n_net, xc, xn, y)


def _engine(**kw):
    cfg = HeteroMPConfig(hidden=32, k_cell=8, k_net=8)
    params = init_drcircuitgnn(jax.random.PRNGKey(0), 16, 16, 32)
    return CircuitServeEngine(params, cfg, max_batch=2, **kw)


# --------------------------------------------------- stats() back-compat

GOLDEN_STATS_KEYS = {
    # every pre-PR-7 key (tests and benchmarks index these)...
    "requests", "batches", "compiles", "graphs_per_s", "p50_ms", "p95_ms",
    "wall_s", "cell_padding_ratio", "deadline_flushes", "failures",
    "retries", "bisects", "watchdog_timeouts", "nonfinite_outputs",
    "rejected_inputs", "admission_blocked", "admission_rejected",
    "admission_shed", "queued", "device_health", "quarantines", "probes",
    "readmissions", "devices", "dispatches_per_device", "live_buckets",
    "evictions", "live_compiles", "params_version", "jit_cache_size",
    # ...plus the one additive PR-7 key
    "p99_ms",
}


def test_stats_is_registry_view_with_backcompat_keys():
    eng = _engine()
    for s in range(4):
        eng.submit(_graph(50 + (s % 2), 25, s))
    eng.run()
    st = eng.stats()
    assert set(st) == GOLDEN_STATS_KEYS
    assert st["requests"] == 4
    assert isinstance(st["requests"], int)
    # the dict is a VIEW over the registry: same numbers both ways
    assert eng.metrics.value("serve.requests") == st["requests"]
    assert eng.metrics.value("serve.batches") == st["batches"]
    assert sum(int(c) for c in st["dispatches_per_device"]) == st["batches"]
    assert st["p99_ms"] >= st["p50_ms"] > 0.0


def test_stats_keys_identical_with_and_without_recorder():
    """Tracing on/off must not change the public surface."""
    eng_off = _engine()
    eng_on = _engine(recorder=TraceRecorder())
    for eng in (eng_off, eng_on):
        eng.submit(_graph(50, 25, 0))
        eng.run()
    assert set(eng_off.stats()) == set(eng_on.stats())


def test_noop_recorder_default_emits_nothing(tmp_path):
    eng = _engine()
    eng.submit(_graph(50, 25, 0))
    eng.run()
    assert eng.recorder.enabled is False
    assert eng.recorder.export() == {"traceEvents": []}
    p = tmp_path / "empty.json"
    eng.dump_trace(str(p))
    assert json.loads(p.read_text()) == {"traceEvents": []}


def test_metrics_exports():
    eng = _engine()
    eng.submit(_graph(50, 25, 0))
    eng.run()
    snap = eng.metrics_snapshot()
    assert snap["serve.requests"] == 1
    assert snap["serve.latency_ms"]["count"] == 1
    text = eng.metrics_text()
    assert "serve_requests 1" in text
    assert "# TYPE serve_latency_ms summary" in text
    json.loads(eng.metrics.snapshot_json())   # JSON-able end to end


# ------------------------------------------------- pack-time arena gauges

def test_collate_emits_arena_gauges_with_near_slot_saving():
    """The fused arena's double-bucketing pays off most on `near` (the
    high-variance cell–cell relation): packing 4 medium partitions must
    report the ~1.9x slot saving vs a single-slab layout in the pack-time
    gauge (ISSUE-7 satellite: the claim is visible in metrics, not just in
    a benchmark table)."""
    gs = [_graph(220, 110, s) for s in range(4)]
    collate_graphs(gs)
    saving = DEFAULT_REGISTRY.value("arena.slot_saving",
                                    etype="near", dir="fwd")
    assert saving >= 1.5, saving
    fill = DEFAULT_REGISTRY.value("arena.fill_ratio",
                                  etype="near", dir="fwd")
    assert 0.0 < fill <= 1.0
    slots = DEFAULT_REGISTRY.value("arena.slots", etype="near", dir="fwd")
    padded = DEFAULT_REGISTRY.value("arena.padded_slots",
                                    etype="near", dir="fwd")
    assert slots > 0 and 0 <= padded < slots
    assert fill == pytest.approx(1.0 - padded / slots)
    # the batch plan reports its own arena occupancy under etype __plan__
    assert DEFAULT_REGISTRY.value("arena.slots", etype="__plan__",
                                  dir="fwd") > 0


def test_pack_emits_tier_gauges():
    """§14 tier routing is visible in metrics at pack time: every edge-type
    direction reports which tier it landed in, the nnz that decided it, and
    the crossover threshold in force — so a mis-tiered relation shows up in
    a dashboard, not just in a kernel trace.  On this batch the crossover
    must split the relations: `near` (high-nnz cell–cell) stays on the
    arena tier while `pin` drops to the dense tier."""
    from repro.graphs.ell import DENSE_TIER_NNZ

    gs = [_graph(220, 110, s) for s in range(4)]
    collate_graphs(gs)
    for et in ("near", "pin", "pinned"):
        for d in ("fwd", "bwd"):
            tier = DEFAULT_REGISTRY.value("arena.tier", etype=et, dir=d)
            assert tier in (0.0, 1.0), (et, d, tier)
            nnz = DEFAULT_REGISTRY.value("arena.tier_nnz", etype=et, dir=d)
            assert nnz > 0, (et, d, nnz)
            thr = DEFAULT_REGISTRY.value("arena.tier_threshold",
                                         etype=et, dir=d)
            assert thr == DENSE_TIER_NNZ, (et, d, thr)
            # the gauge agrees with the rule it reports (modulo the area
            # guard and bucket pinning, which only force the arena tier)
            if nnz > thr:
                assert tier == 0.0, (et, d, nnz, tier)
    assert DEFAULT_REGISTRY.value("arena.tier", etype="near",
                                  dir="fwd") == 0.0     # arena
    assert DEFAULT_REGISTRY.value("arena.tier", etype="pin",
                                  dir="fwd") == 1.0     # dense


def test_ops_dispatch_counters_accumulate():
    def total():
        return sum(m.value for m in
                   DEFAULT_REGISTRY.series("ops.dispatch").values())

    before = total()
    eng = _engine()
    eng.submit(_graph(50, 25, 0))
    eng.run()
    assert total() > before
    # labeled by backend family and dispatch kind, mirroring the tags the
    # FUSED_DISPATCH_LOG deque records ("xla:fwd" -> {family,kind})
    labels = set(DEFAULT_REGISTRY.series("ops.dispatch"))
    assert all(dict(lab).keys() == {"family", "kind"} for lab in labels)


# ------------------------------------------------------- trainer metrics

def test_trainer_stats_and_step_histogram():
    from repro.train.circuit_trainer import (CircuitTrainConfig,
                                             CircuitTrainer)
    gs = [_graph(40, 20, 100 + s) for s in range(3)]
    f_cell, f_net = gs[0].x_cell.shape[1], gs[0].x_net.shape[1]
    tr = CircuitTrainer(CircuitTrainConfig(hidden=32, epochs=1),
                        f_cell, f_net)
    tr.train_epoch(gs)
    st = tr.stats()
    assert st["steps"] == 3
    assert st["nonfinite_grad_steps"] == 0
    assert st["step_p50_ms"] > 0.0
    assert tr.nonfinite_grad_steps == 0     # property over the counter
    assert tr.metrics.value("train.steps") == 3


# --------------------------------------- chaos as trace annotations (e2e)

def test_chaos_and_healing_ladder_annotated_in_trace(tmp_path):
    """Seeded dispatch faults on occurrences 0..2 exhaust the retry budget
    (max_retries=2) and force a bisect; every rung must appear in the
    trace — inject instants on the chaos track, retry/bisect instants on
    the healing track, and the final per-slot batch X events."""
    rec = TraceRecorder()
    chaos = FaultInjector([FaultRule("dispatch", at=(0, 1, 2))])
    eng = _engine(recorder=rec, chaos=chaos)
    for s in range(2):                      # one bucket, one batch of 2
        eng.submit(_graph(50, 25, s))
    out = eng.run()
    assert len(out) == 2
    st = eng.stats()
    assert st["retries"] >= 2 and st["bisects"] == 1 and st["failures"] == 0

    doc = rec.export()
    assert check_trace(doc, expect_device_tracks=1,
                       expect_events=("inject:dispatch", "retry", "bisect",
                                      "batch", "submit")) == []
    names = [e["name"] for e in doc["traceEvents"] if e["ph"] != "M"]
    # one chaos annotation per scheduled fault, and the healing-track
    # instants agree exactly with the registry counters
    assert names.count("inject:dispatch") == 3
    assert names.count("retry") == st["retries"]
    assert names.count("bisect") == st["bisects"]
    p = tmp_path / "chaos_trace.json"
    eng.dump_trace(str(p))
    assert check_trace(json.loads(p.read_text())) == []


def test_online_deadline_flush_annotated_in_trace():
    rec = TraceRecorder()
    eng = _engine(recorder=rec, max_wait_ms=15.0)
    server = threading.Thread(target=eng.serve_forever)
    server.start()
    rid = eng.submit(_graph(50, 25, 0))     # lone request: must flush by
    eng.result(rid, timeout=600.0)          # deadline, not by size
    eng.stop()
    server.join()
    assert eng.stats()["deadline_flushes"] >= 1
    doc = rec.export()
    assert check_trace(doc, expect_events=("deadline_flush",)) == []


# ------------------------------------------- 2-device acceptance (slow)

ACCEPTANCE_SCRIPT = r"""
import sys, time, threading
import jax, numpy as np
from repro.core.hetero_mp import HeteroMPConfig
from repro.fault.inject import FaultInjector, FaultRule
from repro.graphs.generator import generate_partition, pack_graph_parallel
from repro.models.hgnn import init_drcircuitgnn
from repro.obs import TraceRecorder
from repro.serve import CircuitServeEngine

assert jax.device_count() == 2

def graph(n_cell, n_net, seed):
    coo, xc, xn, y = generate_partition(np.random.default_rng(seed),
                                        n_cell, n_net)
    return pack_graph_parallel(coo, n_cell, n_net, xc, xn, y)

cfg = HeteroMPConfig(hidden=32, k_cell=8, k_net=8)
params = init_drcircuitgnn(jax.random.PRNGKey(0), 16, 16, 32)
rec = TraceRecorder()
chaos = FaultInjector([FaultRule("dispatch", at=(0, 1, 2))], seed=11)
eng = CircuitServeEngine(params, cfg, max_batch=2, max_wait_ms=20.0,
                         recorder=rec, chaos=chaos)
t = threading.Thread(target=eng.serve_forever)
t.start()
# phase 1: ONE batch in flight — it eats all 3 scheduled dispatch faults
# (retry x2 exhausts the budget, then bisect); a wider burst would let a
# second concurrent batch share the fault schedule and dodge the bisect
rids = [eng.submit(graph(50, 25, s)) for s in range(2)]
for rid in rids:
    eng.result(rid, timeout=600.0)
# phase 2: paced singles — each waits out max_wait_ms => deadline flushes
for s in range(4, 10):
    rid = eng.submit(graph(50, 25, s))
    eng.result(rid, timeout=600.0)
eng.stop(); t.join()
st = eng.stats()
assert st["failures"] == 0, st
assert st["retries"] >= 2 and st["bisects"] >= 1, st
assert st["deadline_flushes"] >= 1, st
assert all(c > 0 for c in st["dispatches_per_device"]), st
eng.dump_trace(sys.argv[1])
print("ACCEPT_OK", st["retries"], st["bisects"], st["deadline_flushes"],
      st["dispatches_per_device"])
"""


@pytest.mark.slow
def test_two_device_chaos_trace_acceptance_subprocess(tmp_path):
    trace_path = str(tmp_path / "accept_trace.json")
    run_multidev(ACCEPTANCE_SCRIPT, n_devices=2, argv=[trace_path],
                 expect=("ACCEPT_OK",))
    with open(trace_path) as f:
        doc = json.load(f)
    assert check_trace(
        doc, expect_device_tracks=2,
        expect_events=("inject:dispatch", "retry", "bisect",
                       "deadline_flush", "batch", "collate",
                       "device_put")) == []
