"""Metrics registry unit tests: counters/gauges/bounded-reservoir
histograms, labeled series, snapshot/Prometheus exposition, and the
thread-safety contract every engine/trainer emitter relies on."""

import json
import threading

import pytest

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               default_registry)
from repro.train.metrics import percentile


def test_counter_inc_and_value():
    c = Counter()
    assert c.value == 0
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5


def test_gauge_set_add():
    g = Gauge()
    g.set(4.0)
    assert g.value == 4.0
    g.add(-1.5)
    assert g.value == 2.5


def test_histogram_percentiles_match_single_definition():
    """Histogram percentiles ARE train/metrics.py nearest-rank — one
    percentile definition repo-wide (the dedup this PR enforces)."""
    h = Histogram()
    vals = [float(v) for v in range(1, 101)]
    for v in vals:
        h.observe(v)
    for p in (0.50, 0.95, 0.99):
        assert h.percentile(p) == percentile(sorted(vals), p)
    p50, p95, p99 = h.percentiles((0.50, 0.95, 0.99))
    assert (p50, p95, p99) == tuple(
        percentile(sorted(vals), p) for p in (0.50, 0.95, 0.99))
    s = h.summary()
    assert s["count"] == 100 and s["min"] == 1.0 and s["max"] == 100.0
    assert s["mean"] == pytest.approx(50.5)
    assert set(s) == {"count", "sum", "min", "max", "mean",
                      "p50", "p95", "p99"}


def test_histogram_reservoir_bounds_window_not_count():
    h = Histogram(reservoir=8)
    for v in range(100):
        h.observe(float(v))
    assert h.count == 100          # exact lifetime count
    assert len(h.window()) == 8    # bounded sliding window
    assert h.window() == [float(v) for v in range(92, 100)]
    assert h.percentile(0.0) == 92.0  # percentiles over the window only


def test_empty_histogram_is_zero_not_nan():
    h = Histogram()
    assert h.percentile(0.5) == 0.0
    assert h.mean == 0.0


def test_registry_get_or_create_identity_and_labels():
    r = MetricsRegistry()
    a = r.counter("serve.requests")
    b = r.counter("serve.requests")
    assert a is b
    d0 = r.counter("serve.dispatches", device=0)
    d1 = r.counter("serve.dispatches", device=1)
    assert d0 is not d1
    assert r.counter("serve.dispatches", device=0) is d0
    d0.inc(3)
    assert r.value("serve.dispatches", device=0) == 3
    assert r.value("serve.dispatches", device=2, default=-1) == -1
    assert set(r.series("serve.dispatches")) == {(("device", "0"),),
                                                 (("device", "1"),)}


def test_registry_kind_conflict_raises():
    r = MetricsRegistry()
    r.counter("x")
    with pytest.raises(ValueError):
        r.gauge("x")
    with pytest.raises(ValueError):
        r.histogram("x")


def test_registry_conveniences():
    r = MetricsRegistry()
    r.inc("c", 2)
    r.inc("c")
    r.set("g", 7.0)
    for v in (1.0, 2.0, 3.0):
        r.observe("h", v)
    assert r.value("c") == 3
    assert r.value("g") == 7.0
    assert r.histogram("h").count == 3


def test_snapshot_and_json_round_trip():
    r = MetricsRegistry()
    r.inc("serve.requests", 5)
    r.set("arena.fill_ratio", 0.75, etype="near", dir="fwd")
    r.observe("serve.latency_ms", 12.0)
    snap = r.snapshot()
    assert snap["serve.requests"] == 5
    assert snap['arena.fill_ratio{dir="fwd",etype="near"}'] == 0.75
    assert snap["serve.latency_ms"]["count"] == 1
    loaded = json.loads(r.snapshot_json())
    assert loaded == json.loads(json.dumps(snap))


def test_prometheus_exposition():
    r = MetricsRegistry()
    r.inc("serve.requests", 5)
    r.set("arena.fill_ratio", 0.75, etype="near", dir="fwd")
    for v in (1.0, 2.0, 3.0, 4.0):
        r.observe("serve.latency_ms", v)
    text = r.to_prometheus()
    assert "# TYPE serve_requests counter" in text
    assert "serve_requests 5" in text
    assert "# TYPE arena_fill_ratio gauge" in text
    assert 'arena_fill_ratio{dir="fwd",etype="near"} 0.75' in text
    assert "# TYPE serve_latency_ms summary" in text
    assert 'serve_latency_ms{quantile="0.5"}' in text
    assert "serve_latency_ms_count 4" in text
    assert "serve_latency_ms_sum 10" in text


def test_default_registry_is_shared():
    assert default_registry() is default_registry()


def test_thread_safety_exact_counts():
    """N threads hammering one counter + one histogram lose nothing."""
    r = MetricsRegistry()
    c = r.counter("hits")
    h = r.histogram("lat")
    n_threads, per = 8, 500

    def work():
        for i in range(per):
            c.inc()
            h.observe(float(i))

    ts = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert c.value == n_threads * per
    assert h.count == n_threads * per


def test_concurrent_get_or_create_single_instance():
    r = MetricsRegistry()
    got = []
    barrier = threading.Barrier(8)

    def work():
        barrier.wait()
        got.append(r.counter("shared", lane=1))

    ts = [threading.Thread(target=work) for _ in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert all(g is got[0] for g in got)
