"""Trace recorder unit tests: Chrome trace-event export schema, monotonic
timestamps, matched B/E pairs per track, bounded buffers, and the no-op
recorder's zero-emission contract — validated with the same checker CI's
trace-smoke leg runs (tools/check_trace.py)."""

import importlib.util
import json
import os
import threading

from repro.obs.trace import (NULL_RECORDER, NULL_SPAN, Recorder,
                             TraceRecorder)

_spec = importlib.util.spec_from_file_location(
    "check_trace",
    os.path.join(os.path.dirname(__file__), "..", "tools", "check_trace.py"))
check_trace_mod = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_trace_mod)
check_trace = check_trace_mod.check_trace


# ------------------------------------------------------- no-op recorder

def test_null_recorder_emits_nothing_and_allocates_nothing():
    rec = NULL_RECORDER
    assert rec.enabled is False
    rec.begin("t", "a")
    rec.end("t", "a")
    rec.instant("t", "b", k=1)
    rec.complete("t", "c", 0.0, 1.0)
    with rec.span("t", "d"):
        pass
    assert rec.export() == {"traceEvents": []}
    # the span context is ONE shared instance — the tracing-off path
    # allocates nothing per call (the overhead contract ISSUE-7 pins)
    assert rec.span("t", "x") is rec.span("u", "y") is NULL_SPAN
    assert rec.now() == 0.0


def test_null_recorder_dump_is_valid_empty_trace(tmp_path):
    p = tmp_path / "trace.json"
    NULL_RECORDER.dump(str(p))
    doc = json.loads(p.read_text())
    assert doc == {"traceEvents": []}
    assert check_trace(doc) == []


def test_trace_recorder_is_a_recorder():
    assert isinstance(TraceRecorder(), Recorder)
    assert TraceRecorder().enabled is True


# --------------------------------------------------------- live recorder

def test_export_schema_and_round_trip(tmp_path):
    rec = TraceRecorder()
    rec.instant("intake", "submit", rid=0)
    with rec.span("worker/0", "collate", batch=2):
        with rec.span("worker/0", "device_put"):
            pass
    t0 = rec.now()
    rec.complete("device/0", "batch", t0, rec.now() - t0, requests=2)
    rec.instant("healing", "retry", attempt=1)
    p = tmp_path / "t.json"
    rec.dump(str(p))
    doc = json.loads(p.read_text())
    assert check_trace(doc, expect_device_tracks=1) == []
    evs = doc["traceEvents"]
    # metadata first: process_name + one thread_name per track
    names = {e["args"]["name"] for e in evs if e.get("ph") == "M"
             and e["name"] == "thread_name"}
    assert names == {"intake", "worker/0", "device/0", "healing"}
    assert evs[0]["name"] == "process_name"
    # every non-meta event carries pid/tid/ts; ts monotonic per export
    data = [e for e in evs if e["ph"] != "M"]
    assert all(e["pid"] == 1 and e["tid"] >= 1 for e in data)
    ts = [e["ts"] for e in data]
    assert ts == sorted(ts)
    assert [e["ph"] for e in data].count("X") == 1


def test_span_pairs_match_and_annotate_errors():
    rec = TraceRecorder()
    try:
        with rec.span("worker/0", "collate"):
            raise RuntimeError("boom")
    except RuntimeError:
        pass
    doc = rec.export()
    assert check_trace(doc) == []      # B/E still matched on the error path
    b, e = [ev for ev in doc["traceEvents"] if ev["ph"] in "BE"]
    assert (b["ph"], e["ph"]) == ("B", "E")
    assert e["args"]["error"] == "RuntimeError"


def test_crossed_spans_fail_the_checker():
    rec = TraceRecorder()
    rec.begin("t", "outer")
    rec.begin("t", "inner")
    rec.end("t", "outer")              # crosses `inner`
    rec.end("t", "inner")
    assert check_trace(rec.export()) != []


def test_unclosed_span_fails_the_checker():
    rec = TraceRecorder()
    rec.begin("t", "open")
    assert any("unclosed" in p for p in check_trace(rec.export()))


def test_bounded_buffer_counts_drops():
    rec = TraceRecorder(max_events=4)
    for i in range(10):
        rec.instant("t", f"e{i}")
    assert len(rec) == 4
    assert rec.dropped == 6
    doc = rec.export()
    assert doc["otherData"]["dropped_events"] == 6
    assert check_trace(doc) == []


def test_tracks_get_stable_distinct_tids():
    rec = TraceRecorder()
    for track in ("device/0", "device/1", "intake", "device/0"):
        rec.instant(track, "x")
    doc = rec.export()
    tids = {e["args"]["name"]: e["tid"] for e in doc["traceEvents"]
            if e.get("ph") == "M" and e["name"] == "thread_name"}
    assert len(tids) == 3
    assert len(set(tids.values())) == 3
    evs = [e for e in doc["traceEvents"] if e["ph"] == "i"]
    assert evs[0]["tid"] == evs[3]["tid"] == tids["device/0"]


def test_concurrent_emission_thread_safe():
    rec = TraceRecorder()
    n_threads, per = 8, 200

    def work(k):
        for i in range(per):
            with rec.span(f"worker/{k}", "step", i=i):
                pass

    ts = [threading.Thread(target=work, args=(k,))
          for k in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert len(rec) == n_threads * per * 2
    assert check_trace(rec.export(), expect_device_tracks=0) == []


def test_checker_rejects_garbage():
    assert check_trace([]) != []
    assert check_trace({"traceEvents": [{"ph": "B"}]}) != []
    assert check_trace({"traceEvents": [
        {"ph": "X", "name": "a", "pid": 1, "tid": 1, "ts": 0.0,
         "dur": -5.0}]}) != []
    ok = {"traceEvents": [
        {"ph": "X", "name": "a", "pid": 1, "tid": 1, "ts": 0.0,
         "dur": 5.0}]}
    assert check_trace(ok) == []
    assert check_trace(ok, expect_events=("missing",)) != []
