"""Online serving: continuous intake, deadline batching, bucket eviction,
multi-device routing (ISSUE-3 acceptance).

The in-process tests run on the single default device; the 2-device test
runs in a subprocess (XLA device count locks at first jax import) and
asserts per-device dispatch counts, the (bucket, device) compile bound, and
data-parallel training parity.
"""

import threading
import time

import jax
import numpy as np
import pytest

from _multidev import run_multidev
from repro.core.hetero_mp import HeteroMPConfig
from repro.graphs.collate import LayoutTable
from repro.graphs.generator import generate_partition, pack_graph_parallel
from repro.models.hgnn import drcircuitgnn_forward, init_drcircuitgnn
from repro.serve import CircuitServeEngine
from repro.sharding.specs import DeviceRing, batch_devices


def _graph(n_cell, n_net, seed):
    coo, xc, xn, y = generate_partition(np.random.default_rng(seed),
                                        n_cell, n_net)
    return pack_graph_parallel(coo, n_cell, n_net, xc, xn, y)


@pytest.fixture(scope="module")
def model():
    cfg = HeteroMPConfig(hidden=32, k_cell=8, k_net=8, backend="xla_fused")
    params = init_drcircuitgnn(jax.random.PRNGKey(0), 16, 16, 32)
    return params, cfg


def _serve_on_thread(eng):
    t = threading.Thread(target=eng.serve_forever)
    t.start()
    return t


# ------------------------------------------------------- deadline batching

def test_deadline_closes_partial_bucket(model):
    """A partial bucket flushes after max_wait_ms without further submits,
    and its predictions equal the graph served alone (i.e. the deadline's
    filler-padded batch is inert)."""
    params, cfg = model
    eng = CircuitServeEngine(params, cfg, max_batch=4, max_wait_ms=40.0)
    t = _serve_on_thread(eng)
    try:
        graphs = [_graph(50, 25, s) for s in range(2)]
        rids = [eng.submit(g) for g in graphs]
        # only 2 of 4 slots filled: completion requires the deadline flush
        for rid, g in zip(rids, graphs):
            res = eng.result(rid, timeout=120.0)
            ref = np.asarray(drcircuitgnn_forward(params, g, cfg))
            np.testing.assert_allclose(res.pred, ref, atol=1e-5, rtol=1e-5)
    finally:
        eng.stop()
        t.join(timeout=120.0)
    assert not t.is_alive()
    st = eng.stats()
    assert st["deadline_flushes"] >= 1, st
    assert st["batches"] >= 1 and st["requests"] == 2


def test_full_batch_needs_no_deadline(model):
    """max_batch compatible requests dispatch as a full batch — no deadline
    flush, no filler padding."""
    params, cfg = model
    # deadline far beyond the test budget: completion proves the full-batch
    # path dispatched without it
    eng = CircuitServeEngine(params, cfg, max_batch=3, max_wait_ms=60_000.0)
    t = _serve_on_thread(eng)
    try:
        rids = [eng.submit(_graph(50, 25, 10 + s)) for s in range(3)]
        for rid in rids:
            eng.result(rid, timeout=120.0)
    finally:
        eng.stop()
        t.join(timeout=120.0)
    st = eng.stats()
    assert st["deadline_flushes"] == 0, st
    assert st["batches"] == 1 and st["requests"] == 3
    # full batch: no filler members, padding ratio is node-grid-only
    assert st["cell_padding_ratio"] < 3.0


def test_deadline_result_matches_drain_mode(model):
    """The same partial bucket served via deadline flush and via run()'s
    immediate flush produces identical predictions (both are the same
    filler-padded batch)."""
    params, cfg = model
    graphs = [_graph(60, 30, s) for s in range(2)]

    eng_a = CircuitServeEngine(params, cfg, max_batch=4, max_wait_ms=20.0)
    t = _serve_on_thread(eng_a)
    try:
        rids_a = [eng_a.submit(g) for g in graphs]
        preds_a = [np.asarray(eng_a.result(r, timeout=120.0).pred)
                   for r in rids_a]
    finally:
        eng_a.stop()
        t.join(timeout=120.0)

    eng_b = CircuitServeEngine(params, cfg, max_batch=4)
    rids_b = [eng_b.submit(g) for g in graphs]
    out_b = eng_b.run()
    for pa, rb in zip(preds_a, rids_b):
        np.testing.assert_allclose(pa, np.asarray(out_b[rb].pred),
                                   atol=1e-6, rtol=1e-6)


# --------------------------------------------------- submit-during-run

def test_submit_during_run_ordering(model):
    """Submits landing while serve_forever is mid-stream are all served,
    FIFO within a bucket: same-bucket requests finish in submit order."""
    params, cfg = model
    eng = CircuitServeEngine(params, cfg, max_batch=2, max_wait_ms=15.0)
    t = _serve_on_thread(eng)
    rids, graphs = [], []
    try:
        for wave in range(3):          # trickle the stream in
            for s in range(3):
                g = _graph(48 + s, 24, 10 * wave + s)
                graphs.append(g)
                rids.append(eng.submit(g))
            time.sleep(0.05)
        for rid in rids:
            eng.result(rid, timeout=120.0)
    finally:
        eng.stop()
        t.join(timeout=120.0)
    out = eng.finished
    assert set(rids) <= set(out), "requests lost"
    # parity for every request
    for rid, g in zip(rids, graphs):
        ref = np.asarray(drcircuitgnn_forward(params, g, cfg))
        np.testing.assert_allclose(out[rid].pred, ref, atol=1e-5, rtol=1e-5)
    # FIFO within each bucket: completion times are monotone in submit order
    by_bucket = {}
    for rid, g in zip(rids, graphs):
        by_bucket.setdefault(eng._group_key(g), []).append(rid)
    for bucket_rids in by_bucket.values():
        dones = [out[r].t_done for r in bucket_rids]
        assert dones == sorted(dones), (bucket_rids, dones)


def test_stop_drains_queue(model):
    """stop() called with requests still queued: serve_forever drains them
    (flushing partials immediately) before returning."""
    params, cfg = model
    eng = CircuitServeEngine(params, cfg, max_batch=4, max_wait_ms=5_000.0)
    rids = [eng.submit(_graph(52, 26, s)) for s in range(3)]
    t = _serve_on_thread(eng)
    eng.stop()                       # long deadline: only the drain flushes
    t.join(timeout=120.0)
    assert not t.is_alive()
    assert set(rids) <= set(eng.finished)


# ------------------------------------------------------------- eviction

def test_bucket_eviction_lru(model):
    """max_live_buckets bounds live layout/compile state: the LRU bucket is
    evicted, an evicted bucket recompiles at most once on return, and live
    buckets' layouts (and executables) are untouched."""
    params, cfg = model
    eng = CircuitServeEngine(params, cfg, max_batch=2, max_live_buckets=2)

    def serve_pair(n_cell, n_net, seed):
        rids = [eng.submit(_graph(n_cell, n_net, seed + i)) for i in (0, 1)]
        out = eng.run()
        return [np.asarray(out[r].pred) for r in rids]

    serve_pair(40, 20, 0)            # bucket A
    serve_pair(90, 45, 10)           # bucket B
    assert eng.live_buckets == 2 and eng.evictions == 0
    assert eng.compiles == 2
    serve_pair(160, 80, 20)          # bucket C -> evicts A (LRU)
    assert eng.live_buckets == 2 and eng.evictions == 1
    assert eng.compiles == 3

    # B and C layouts untouched: serving them again costs no compile
    serve_pair(91, 44, 30)
    serve_pair(158, 81, 40)
    assert eng.compiles == 3, eng.stats()

    # A returns: exactly ONE recompile (fresh layout re-pins identically),
    # evicting the new LRU (B)
    serve_pair(40, 20, 50)
    assert eng.compiles == 4 and eng.evictions == 2
    serve_pair(41, 19, 60)           # A again: compiled state is back
    assert eng.compiles == 4, eng.stats()
    assert eng.live_buckets == 2
    # the honest-counter cross-check still holds per live bucket
    st = eng.stats()
    if "jit_cache_size" in st:
        assert st["jit_cache_size"] == st["live_compiles"]


def test_eviction_under_one_off_tail(model):
    """A long tail of one-off shapes cannot grow live state past the bound
    (the ISSUE-3 memory-stability property)."""
    params, cfg = model
    eng = CircuitServeEngine(params, cfg, max_batch=1, max_live_buckets=3)
    sizes = [(40, 20), (70, 35), (120, 60), (200, 100), (300, 150)]
    for i, (c, n) in enumerate(sizes):
        eng.submit(_graph(c, n, i))
        eng.run()
    assert eng.live_buckets <= 3
    assert len(eng._buckets) <= 3            # jit/lock/sig state bounded too
    assert eng.evictions == len(sizes) - 3       # 5 buckets, cap 3 -> 2
    st = eng.stats()
    assert st["requests"] == len(sizes)


def test_layout_table_lru_order():
    """LayoutTable unit semantics: touch refreshes, eviction fires the hook
    with the evicted key, never the touched one."""
    evicted = []
    tab = LayoutTable(max_live=2, on_evict=lambda k, v: evicted.append(k))
    la = tab.get(("a",))
    tab.get(("b",))
    tab.get(("a",))                  # refresh a: LRU is now b
    tab.get(("c",))                  # evicts b
    assert evicted == [("b",)]
    assert ("a",) in tab and ("c",) in tab and ("b",) not in tab
    assert tab.get(("a",)) is la     # surviving layout object is stable
    assert len(tab) == 2 and tab.evictions == 1


def test_batch_failure_is_contained(model):
    """A malformed request fails its own batch (result() re-raises) but the
    loop keeps serving the rest of the stream."""
    import dataclasses as dc
    import jax.numpy as jnp
    params, cfg = model
    eng = CircuitServeEngine(params, cfg, max_batch=2, max_wait_ms=15.0)
    t = _serve_on_thread(eng)
    try:
        good1 = _graph(40, 20, 0)
        bad = _graph(90, 45, 1)          # its own bucket: poisons only itself
        bad = dc.replace(bad, y_cell=jnp.zeros(bad.n_cell + 7))  # collate breaks
        good2 = _graph(41, 20, 2)
        r1, rb = eng.submit(good1), eng.submit(bad)
        with pytest.raises(RuntimeError):
            eng.result(rb, timeout=120.0)
        r2 = eng.submit(good2)           # engine still alive after the failure
        for rid, g in [(r1, good1), (r2, good2)]:
            res = eng.result(rid, timeout=120.0)
            ref = np.asarray(drcircuitgnn_forward(params, g, cfg))
            np.testing.assert_allclose(res.pred, ref, atol=1e-5, rtol=1e-5)
    finally:
        eng.stop()
        t.join(timeout=120.0)
    assert not t.is_alive()
    st = eng.stats()
    assert st["failures"] == 1 and st["requests"] == 2


def test_max_finished_bounds_retained_results(model):
    """max_finished trims oldest retained results; result(pop=True)
    releases them eagerly; latency stats survive the trimming."""
    params, cfg = model
    eng = CircuitServeEngine(params, cfg, max_batch=1, max_finished=2)
    rids = [eng.submit(_graph(40, 20, s)) for s in range(4)]
    eng.run()
    assert len(eng.finished) == 2            # only the 2 newest retained
    assert rids[-1] in eng.finished and rids[0] not in eng.finished
    st = eng.stats()
    assert st["requests"] == 4 and st["p50_ms"] > 0   # stats see all 4
    assert eng.result(rids[-1], pop=True).pred is not None
    assert rids[-1] not in eng.finished


# ------------------------------------------------- device routing helpers

def test_device_ring_round_robin():
    ring = DeviceRing()
    assert len(ring) >= 1
    idx = [ring.next_index() for _ in range(2 * len(ring))]
    assert idx == [i % len(ring) for i in range(2 * len(ring))]
    x = ring.put(np.ones(3, np.float32), 0)
    assert np.asarray(x).sum() == 3.0


def test_batch_devices_no_mesh():
    assert batch_devices() == tuple(jax.local_devices())


# ------------------------------------------------------- percentile move

def test_percentile_moved_and_reexported():
    from repro.train.metrics import percentile as p_metrics
    from repro.serve.circuit_engine import percentile as p_engine
    assert p_metrics is p_engine
    assert p_metrics([], 0.5) == 0.0
    assert p_metrics([1.0, 2.0, 3.0, 4.0], 0.5) == 2.0


# ----------------------------------------------------- 2-device routing

MULTIDEV_SCRIPT = r"""
import threading
import jax, numpy as np
from repro.core.hetero_mp import HeteroMPConfig
from repro.graphs.generator import generate_partition, pack_graph_parallel
from repro.models.hgnn import drcircuitgnn_forward, init_drcircuitgnn
from repro.serve import CircuitServeEngine
from repro.train.circuit_trainer import CircuitTrainConfig, CircuitTrainer

assert jax.device_count() == 2

def graph(n_cell, n_net, seed):
    coo, xc, xn, y = generate_partition(np.random.default_rng(seed),
                                        n_cell, n_net)
    return pack_graph_parallel(coo, n_cell, n_net, xc, xn, y)

cfg = HeteroMPConfig(hidden=32, k_cell=8, k_net=8, backend="xla_fused")
params = init_drcircuitgnn(jax.random.PRNGKey(0), 16, 16, 32)

# online serving over both devices, submit-during-run
eng = CircuitServeEngine(params, cfg, max_batch=2, max_wait_ms=20.0)
assert len(eng.ring) == 2
t = threading.Thread(target=eng.serve_forever)
t.start()
stream = [graph(50 + (s % 3), 25, s) for s in range(12)]
rids = [eng.submit(g) for g in stream]
for rid in rids:
    eng.result(rid, timeout=600.0)
eng.stop(); t.join()
st = eng.stats()
counts = st["dispatches_per_device"]
assert sum(counts) == st["batches"], (counts, st)
assert all(c > 0 for c in counts), counts          # both devices served
# one bucket, two devices: at most one compile per (bucket, device)
assert eng.compiles <= 2, st
for rid, g in zip(rids, stream):
    ref = np.asarray(drcircuitgnn_forward(params, g, cfg))
    np.testing.assert_allclose(eng.finished[rid].pred, ref,
                               atol=1e-5, rtol=1e-5)
print("SERVE_2DEV_OK", counts)

# data-parallel training: 2-device epoch matches single-device batched loss
graphs = [graph(48 + s, 24, 100 + s) for s in range(4)]
f_cell, f_net = graphs[0].x_cell.shape[1], graphs[0].x_net.shape[1]
tcfg = CircuitTrainConfig(hidden=32, seed=3)
a = CircuitTrainer(tcfg, f_cell, f_net)
b = CircuitTrainer(tcfg, f_cell, f_net)
la = a.train_epoch(graphs, batch_size=4)                      # 1 device
lb = b.train_epoch(graphs, batch_size=4, devices=True)        # 2 devices
assert abs(la - lb) < 1e-5, (la, lb)
pd = max(float(np.abs(np.asarray(x) - np.asarray(y)).max())
         for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)))
assert pd < 1e-5, pd
print("TRAIN_DP_OK", la, lb, pd)
"""


@pytest.mark.slow
def test_two_device_serve_and_train_subprocess():
    run_multidev(MULTIDEV_SCRIPT, n_devices=2,
                 expect=("SERVE_2DEV_OK", "TRAIN_DP_OK"))


# ------------------------------------------- single-device data parallel

def test_train_epoch_devices_single_matches_batched():
    """The data-parallel step path with a 1-device ring reproduces the
    plain batched step (same grads, same update)."""
    from repro.train.circuit_trainer import CircuitTrainConfig, CircuitTrainer
    graphs = [_graph(40 + s, 20, 200 + s) for s in range(4)]
    f_cell, f_net = graphs[0].x_cell.shape[1], graphs[0].x_net.shape[1]
    tcfg = CircuitTrainConfig(hidden=32, seed=9)
    a = CircuitTrainer(tcfg, f_cell, f_net)
    b = CircuitTrainer(tcfg, f_cell, f_net)
    la = a.train_epoch(graphs, batch_size=4)
    lb = b.train_epoch(graphs, batch_size=4,
                       devices=jax.local_devices()[:1])
    assert abs(la - lb) < 1e-5, (la, lb)
    pd = max(float(np.abs(np.asarray(x) - np.asarray(y)).max())
             for x, y in zip(jax.tree.leaves(a.params),
                             jax.tree.leaves(b.params)))
    assert pd < 1e-5, pd
