"""The parallel scheduler must not retrace/recompile its module closures on
every invocation (the seed re-wrapped with a fresh ``jax.jit`` per call)."""

import jax.numpy as jnp

from repro.core.parallel import run_fused, run_sequential


def test_run_fused_no_retrace_on_second_call():
    traces = []

    def f(x):
        traces.append(1)          # executes only while tracing
        return x * 2.0

    x = jnp.ones((4,))
    a = run_fused((f,), ((x,),))
    b = run_fused((f,), ((x,),))
    assert len(traces) == 1, "second run_fused call retraced the closure"
    assert jnp.allclose(a[0], b[0])


def test_run_sequential_no_retrace_on_second_call():
    traces = []

    def f(x):
        traces.append(1)
        return x + 1.0

    x = jnp.zeros((3,))
    run_sequential((f,), ((x,),))
    run_sequential((f,), ((x,),))
    assert len(traces) == 1, "second run_sequential call retraced the closure"


def test_run_fused_matches_sequential():
    def f(x):
        return x * 3.0

    def g(x):
        return x - 1.0

    x = jnp.arange(4.0)
    a = run_fused((f, g), ((x,), (x,)))
    b = run_sequential((f, g), ((x,), (x,)))
    for ya, yb in zip(a, b):
        assert jnp.allclose(ya, yb)
