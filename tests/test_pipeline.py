"""Pipeline parallelism (GPipe over a 'stage' axis) — subprocess with 8
virtual devices; forward AND gradient must match the sequential oracle."""

import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.sharding.pipeline import pipeline_apply, sequential_reference

mesh = jax.make_mesh((4,), ("stage",))
S, M, MB, D = 4, 6, 2, 16
rng = np.random.default_rng(0)
params = {"w": jnp.asarray(rng.normal(size=(S, D, D)).astype(np.float32) * 0.3),
          "b": jnp.asarray(rng.normal(size=(S, D)).astype(np.float32) * 0.1)}
x = jnp.asarray(rng.normal(size=(M, MB, D)).astype(np.float32))

def stage_fn(p, h):
    return jnp.tanh(h @ p["w"] + p["b"])

with mesh:
    y = pipeline_apply(mesh, stage_fn, params, x)
y_ref = sequential_reference(stage_fn, params, x)
err = float(jnp.abs(y - y_ref).max())
assert err < 1e-5, err
print("PIPELINE_FWD_OK", err)

def loss_pipe(p):
    with mesh:
        return jnp.sum(pipeline_apply(mesh, stage_fn, p, x) ** 2)

def loss_ref(p):
    return jnp.sum(sequential_reference(stage_fn, p, x) ** 2)

g1 = jax.grad(loss_pipe)(params)
g2 = jax.grad(loss_ref)(params)
gerr = max(float(jnp.abs(a - b).max()) for a, b in
           zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
assert gerr < 1e-4, gerr
print("PIPELINE_BWD_OK", gerr)
"""


@pytest.mark.slow
def test_pipeline_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "PIPELINE_FWD_OK" in r.stdout and "PIPELINE_BWD_OK" in r.stdout
