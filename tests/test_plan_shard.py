"""Mesh-partitioned RelationPlan properties (DESIGN.md §12).

Host-side guarantees of ``shard_relation_plan`` — no multi-device runtime
needed (the executor itself is covered by tests/test_sharded_parity.py):

* shard ↔ unshard ROUND-TRIP: the union of every shard's local fwd arena,
  mapped back through the slab/halo coordinate tables, is exactly the
  original super-arena's edge set;
* halo table BIJECTIVITY: owned source slabs partition ``[0, n_src)``; a
  shard's halo references rows it does not own, each at most once, and
  ``halo_rows[d, s, j] == s·S + send_idx[s, d, j]`` ties the receive view
  to the all-to-all send gather slot by slot;
* PADDING INERTNESS: the numpy reference simulators (exchange + local
  contraction, forward and reversed-exchange backward) reproduce the dense
  ``A @ x`` / ``Aᵀ @ gy`` exactly through all arena/halo/slab padding —
  including collation filler members and a degree-skewed hub row;
* the ``arena.halo_*`` gauges land in the metrics registry and agree with
  ``halo_stats()``.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # bare container: seeded fallback
    from _hyp_fallback import given, settings, strategies as st

from repro.graphs.circuit import EDGE_SCHEMA, relation_plan_of, \
    sharded_plan_of, with_sharded_plan
from repro.graphs.collate import collate_graphs
from repro.graphs.ell import build_relation_plan, fused_to_coo, plan_to_coo
from repro.graphs.generator import generate_partition, pack_graph_parallel
from repro.obs.metrics import MetricsRegistry
from repro.sharding.plan_shard import (ShardedRelationPlan,
                                       reference_backward,
                                       reference_forward,
                                       shard_relation_plan)

settings.register_profile("fast", max_examples=15, deadline=None)
settings.load_profile("fast")


def _plan(seed, n_cell, n_net, etypes=("near", "pin", "pinned")):
    """A mixed-degree multi-relation plan over the circuit schema."""
    rng = np.random.default_rng(seed)

    def mk(n_dst, n_src, nnz):
        d = rng.integers(0, n_dst, nnz)
        s = rng.integers(0, n_src, nnz)
        pairs = np.unique(np.stack([d, s], 1), axis=0)
        w = rng.normal(size=pairs.shape[0]).astype(np.float32)
        w[w == 0] = 1.0
        return pairs[:, 0], pairs[:, 1], w

    sizes = {"cell": n_cell, "net": n_net}
    nnz_of = {"near": 4 * n_cell, "pin": 2 * n_cell, "pinned": 2 * n_cell}
    rels = []
    for et in etypes:
        s_t, d_t = EDGE_SCHEMA[et]
        rels.append((et, s_t, d_t,
                     *mk(sizes[d_t], sizes[s_t], max(nnz_of[et], 1))))
    return build_relation_plan(rels, {"cell": n_cell, "net": n_net})


def _graph(n_cell, n_net, seed):
    coo, xc, xn, y = generate_partition(np.random.default_rng(seed),
                                        n_cell, n_net)
    return pack_graph_parallel(coo, n_cell, n_net, xc, xn, y)


def _global_coo_of_shards(sp: ShardedRelationPlan):
    """Every shard's local arena mapped back to GLOBAL (dst, src, w) via the
    slab offsets and the halo_rows table — the unshard direction."""
    hr = np.asarray(sp.halo_rows)
    dsts, srcs, ws = [], [], []
    for d in range(sp.n_shards):
        ld, ls, lw = fused_to_coo(sp.local_fwd(d))
        own = ls < sp.src_slab
        slot = np.maximum(ls - sp.src_slab, 0)     # own rows: dummy slot 0
        s_of, j_of = slot // sp.halo_pad, slot % sp.halo_pad
        gsrc = np.where(own, ls + d * sp.src_slab, hr[d, s_of, j_of])
        assert (gsrc >= 0).all(), "edge references a padded halo slot"
        dsts.append(ld + d * sp.out_slab)
        srcs.append(gsrc)
        ws.append(lw)
    return (np.concatenate(dsts), np.concatenate(srcs),
            np.concatenate(ws).astype(np.float32))


def _sorted(dst, src, w):
    o = np.lexsort((src, dst))
    return dst[o], src[o], w[o]


cases = st.integers(0, 2 ** 31 - 1).flatmap(lambda seed: st.tuples(
    st.just(seed), st.integers(9, 40), st.integers(5, 24),
    st.sampled_from((1, 2, 3, 4, 7))))


# -------------------------- round-trip property -------------------------

@given(cases)
def test_shard_unshard_roundtrip(args):
    """Union of the shards' local arenas == the super-arena, edge for edge
    (global coordinates AND weights), at every shard count including the
    ragged ones that leave trailing shards empty."""
    seed, n_cell, n_net, n = args
    plan = _plan(seed, n_cell, n_net)
    sp = shard_relation_plan(plan, n, registry=MetricsRegistry())
    got = _sorted(*_global_coo_of_shards(sp))
    want = _sorted(*plan_to_coo(plan))
    np.testing.assert_array_equal(got[0], want[0], err_msg="dst rows")
    np.testing.assert_array_equal(got[1], want[1], err_msg="src rows")
    np.testing.assert_allclose(got[2], want[2], atol=1e-6, err_msg="weights")


# ------------------------- halo table bijectivity -----------------------

@given(cases)
def test_halo_tables_bijective(args):
    """Owned slabs tile the source space; halo slots reference foreign rows
    at most once each; receive and send tables agree slot by slot."""
    seed, n_cell, n_net, n = args
    sp = shard_relation_plan(_plan(seed, n_cell, n_net), n,
                             registry=MetricsRegistry())
    hr, send = np.asarray(sp.halo_rows), np.asarray(sp.send_idx)

    # every owned source row lives in exactly one owner slab
    assert sum(sp.owned_src_rows(d) for d in range(n)) == sp.n_src_total
    assert sp.src_slab * n >= sp.n_src_total

    for d in range(n):
        rows = hr[d][hr[d] >= 0]
        # reference, never duplicate: one halo slot per needed foreign row
        assert rows.size == np.unique(rows).size, f"shard {d} dup halo"
        assert (rows < sp.n_src_total).all(), f"shard {d} phantom halo row"
        lo = d * sp.src_slab
        owned = (rows >= lo) & (rows < lo + sp.owned_src_rows(d))
        assert not owned.any(), f"shard {d} halos a row it owns"
        assert (hr[d, d] == -1).all(), f"shard {d} self-halo"
        for s in range(n):
            m = hr[d, s] >= 0
            # the receive table IS the send gather, owner-side coords
            np.testing.assert_array_equal(
                hr[d, s][m], s * sp.src_slab + send[s, d][m],
                err_msg=f"send/recv mismatch d={d} s={s}")
            # request lists are sorted-unique (searchsorted precondition)
            assert (np.diff(hr[d, s][m]) > 0).all()
            # padded send slots point at owner row 0: in-range, inert
            assert (send[s, d][~m] == 0).all()


# ----------------- padding inertness (reference exchange) ---------------

@given(cases)
def test_reference_exchange_matches_dense(args):
    """Simulated all-to-all + local contraction == dense A @ x (forward)
    and Aᵀ @ gy (reversed-exchange scatter-add backward): every slab, halo
    and arena padding path is exactly inert."""
    seed, n_cell, n_net, n = args
    plan = _plan(seed, n_cell, n_net)
    sp = shard_relation_plan(plan, n, registry=MetricsRegistry())
    rng = np.random.default_rng(seed ^ 0x5EED)
    x = rng.normal(size=(sp.n_src_total, 5)).astype(np.float32)
    gy = rng.normal(size=(sp.n_out_total, 5)).astype(np.float32)
    A = np.asarray(plan.to_dense(), np.float32)

    y = reference_forward(sp, x)
    dx = reference_backward(sp, gy)
    tol = dict(atol=1e-4, rtol=1e-5)
    np.testing.assert_allclose(y, A @ x, err_msg="sharded fwd", **tol)
    np.testing.assert_allclose(dx, A.T @ gy, err_msg="sharded bwd", **tol)


# --------------------------- edge-case shapes ---------------------------

def test_single_shard_degenerate():
    """n_shards=1: no halo at all (pad stays at its floor of 1, every slot
    −1) and the single local arena is the plan itself, edge for edge."""
    plan = _plan(3, 31, 17)
    sp = shard_relation_plan(plan, 1, registry=MetricsRegistry())
    assert sp.halo_pad == 1
    assert (np.asarray(sp.halo_rows) == -1).all()
    got = _sorted(*_global_coo_of_shards(sp))
    want = _sorted(*plan_to_coo(plan))
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, atol=1e-6)


def test_single_relation_plan_shards():
    """A one-relation plan (near only) survives the partition — no other
    segment's slab to hide layout bugs behind."""
    plan = _plan(11, 26, 13, etypes=("near",))
    sp = shard_relation_plan(plan, 3, registry=MetricsRegistry())
    rng = np.random.default_rng(0)
    x = rng.normal(size=(sp.n_src_total, 4)).astype(np.float32)
    A = np.asarray(plan.to_dense(), np.float32)
    np.testing.assert_allclose(reference_forward(sp, x), A @ x,
                               atol=1e-4, rtol=1e-5)


def test_skewed_hub_row_halos_everywhere():
    """Degree skew: a hub source row read by every output slab must appear
    in every non-owner shard's halo EXACTLY once, and the exchange still
    reproduces the dense product."""
    n_cell, n_net, n = 24, 12, 4
    rng = np.random.default_rng(2)
    dst = np.arange(n_cell, dtype=np.int64)          # hub: cell 0 -> all
    src = np.zeros(n_cell, np.int64)
    extra_d = rng.integers(0, n_cell, 30)
    extra_s = rng.integers(0, n_cell, 30)
    pairs = np.unique(np.stack([np.concatenate([dst, extra_d]),
                                np.concatenate([src, extra_s])], 1), axis=0)
    w = rng.normal(size=pairs.shape[0]).astype(np.float32)
    w[w == 0] = 1.0
    plan = build_relation_plan(
        [("near", "cell", "cell", pairs[:, 0], pairs[:, 1], w)],
        {"cell": n_cell, "net": n_net})
    sp = shard_relation_plan(plan, n, registry=MetricsRegistry())
    hr = np.asarray(sp.halo_rows)
    for d in range(1, n):                            # shard 0 owns the hub
        assert int((hr[d] == 0).sum()) == 1, f"shard {d} hub halo count"
    x = rng.normal(size=(sp.n_src_total, 3)).astype(np.float32)
    A = np.asarray(plan.to_dense(), np.float32)
    np.testing.assert_allclose(reference_forward(sp, x), A @ x,
                               atol=1e-4, rtol=1e-5)


def test_collated_filler_members_shard_cleanly():
    """A collated batch plan (quantized padding + a filler replica) shards
    without disturbing the math — collation padding stays inert through the
    partition, not just through the unsharded plan path."""
    members = [_graph(60, 30, 0), _graph(37, 20, 2)]
    batch = collate_graphs(members + [members[-1]], n_real=len(members))
    plan = batch.graph.plan
    assert plan is not None
    sp = shard_relation_plan(plan, 3, registry=MetricsRegistry())
    rng = np.random.default_rng(1)
    x = rng.normal(size=(sp.n_src_total, 4)).astype(np.float32)
    gy = rng.normal(size=(sp.n_out_total, 4)).astype(np.float32)
    A = np.asarray(plan.to_dense(), np.float32)
    np.testing.assert_allclose(reference_forward(sp, x), A @ x,
                               atol=1e-4, rtol=1e-5)
    np.testing.assert_allclose(reference_backward(sp, gy), A.T @ gy,
                               atol=1e-4, rtol=1e-5)


# ------------------------ graph-level memoization -----------------------

def test_sharded_plan_memoized_and_attachable():
    g = _graph(48, 24, 7)
    sp = sharded_plan_of(g, 2)
    assert sharded_plan_of(g, 2) is sp               # memoized per (g, n)
    assert sharded_plan_of(g, 3) is not sp           # keyed by shard count
    pg = with_sharded_plan(g, 2)
    assert pg.plan is sp
    assert with_sharded_plan(pg, 2) is pg            # already attached
    # the unsharded accessor must NOT return the sharded plan
    assert relation_plan_of(g) is relation_plan_of(g)
    assert not isinstance(relation_plan_of(pg), ShardedRelationPlan)


# ------------------------------ gauges ----------------------------------

def test_halo_gauges_emitted_and_sane():
    """Pack-time observability: per-shard and per-relation ``arena.halo_*``
    gauges land in the registry and agree with ``halo_stats()``; per-shard
    footprint beats full replication on a graph of real size."""
    reg = MetricsRegistry()
    plan = _plan(7, 120, 60)
    sp = shard_relation_plan(plan, 4, registry=reg)
    stats = sp.halo_stats()
    for s in stats["shards"]:
        d = str(s["shard"])
        assert reg.value("arena.halo_rows", -1.0, shard=d) == s["halo_rows"]
        assert reg.value("arena.shard_bytes", -1.0,
                         shard=d) == s["arena_bytes"]
        ratio = reg.value("arena.halo_owned_byte_ratio", -1.0, shard=d)
        assert ratio == pytest.approx(s["halo_owned_ratio"]) and ratio >= 0
    for seg in plan.segments:
        v = reg.value("arena.halo_rows", -1.0, etype=seg.etype)
        assert v >= 0, f"missing per-relation gauge for {seg.etype}"
        r = reg.value("arena.halo_owned_byte_ratio", -1.0, etype=seg.etype)
        assert r >= 0
    assert reg.value("arena.halo_pad", -1.0, shards="4") == sp.halo_pad
    # the reason sharding exists: every device's tables are strictly
    # smaller than holding the whole super-arena
    assert stats["max_shard_bytes"] < stats["full_arena_bytes"]
    assert stats["total_halo_rows"] == int(
        (np.asarray(sp.halo_rows) >= 0).sum())
