"""Relation-fused mega-dispatch (RelationPlan, DESIGN.md §9).

The plan path — one super-arena dispatch per direction-group covering every
edge-type direction of a hetero layer — must be numerically interchangeable
with the serial per-direction reference loop across all five backends,
forward and gradient; its relation segments must round-trip exactly onto
the member relations' matrices; collation padding and fillers must stay
inert through the plan; and the cached custom-vjp executor must never
retrace on repeat calls.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # bare container: seeded fallback
    from _hyp_fallback import given, settings, strategies as st

from repro.core.cbsr import cbsr_from_dense
from repro.core.drelu import drelu
from repro.core.hetero_mp import HeteroMPConfig, hetero_conv, \
    init_hetero_layer
from repro.graphs.circuit import EDGE_SCHEMA, relation_plan_of, with_plan
from repro.graphs.collate import BucketLayout, collate_graphs
from repro.graphs.ell import build_relation_plan, pack_ell_pair
from repro.graphs.generator import generate_partition, pack_graph_parallel
from repro.kernels import ops
from repro.models.hgnn import drcircuitgnn_forward, init_drcircuitgnn

settings.register_profile("fast", max_examples=15, deadline=None)
settings.load_profile("fast")

BACKENDS = ("pallas_fused", "xla_fused", "pallas", "xla", "dense")


def _assert_close(actual, ref, msg):
    atol = 1e-5 * max(1.0, float(np.abs(ref).max()) if ref.size else 1.0)
    np.testing.assert_allclose(actual, ref, atol=atol, rtol=1e-5,
                               err_msg=msg)


def _graph(n_cell, n_net, seed):
    coo, xc, xn, y = generate_partition(np.random.default_rng(seed),
                                        n_cell, n_net)
    return pack_graph_parallel(coo, n_cell, n_net, xc, xn, y)


def _mixed_relations(rng, n_cell, n_net):
    """Three mixed-degree relations over the circuit schema."""

    def mk(n_dst, n_src, nnz):
        d = rng.integers(0, n_dst, nnz)
        s = rng.integers(0, n_src, nnz)
        pairs = np.unique(np.stack([d, s], 1), axis=0)
        w = rng.normal(size=pairs.shape[0]).astype(np.float32)
        w[w == 0] = 1.0
        return pairs[:, 0], pairs[:, 1], w

    sizes = {"cell": n_cell, "net": n_net}
    out = []
    for et, nnz in (("near", 4 * n_cell), ("pin", 2 * n_cell),
                    ("pinned", 2 * n_cell)):
        s_t, d_t = EDGE_SCHEMA[et]
        out.append((et, s_t, d_t, *mk(sizes[d_t], sizes[s_t], max(nnz, 1))))
    return out


# ------------------------- op-level parity -----------------------------

@pytest.fixture(scope="module")
def op_setup():
    rng = np.random.default_rng(3)
    n_cell, n_net, dim = 57, 29, 64
    rels = _mixed_relations(rng, n_cell, n_net)
    plan = build_relation_plan(rels, {"cell": n_cell, "net": n_net})
    k_cell, k_net = 8, 6
    cc = cbsr_from_dense(drelu(jnp.asarray(
        rng.normal(size=(n_cell, dim)).astype(np.float32)), k_cell), k_cell)
    cn = cbsr_from_dense(drelu(jnp.asarray(
        rng.normal(size=(n_net, dim)).astype(np.float32)), k_net), k_net)
    packs = {r[0]: pack_ell_pair(r[3], r[4], r[5],
                                 {"cell": n_cell, "net": n_net}[r[2]],
                                 {"cell": n_cell, "net": n_net}[r[1]])
             for r in rels}
    src_of = {r[0]: r[1] for r in rels}
    return plan, rels, packs, src_of, cc, cn, dim


def _serial_ref(packs, src_of, cc, cn, dim, vc, vn):
    out = {}
    for et, (adj, adj_t) in packs.items():
        c = cc if src_of[et] == "cell" else cn
        v = vc if src_of[et] == "cell" else vn
        out[et] = ops.drspmm(adj, adj_t, v, c.idx, dim, backend="dense")
    return out


@pytest.mark.parametrize("backend", BACKENDS)
def test_multi_matches_serial_per_relation(op_setup, backend):
    """drspmm_multi == one serial drspmm per relation, fwd + grads in both
    source types, under every backend name (per-bucket names upgrade to the
    fused family — plans are always pre-fused)."""
    plan, rels, packs, src_of, cc, cn, dim = op_setup
    refs = _serial_ref(packs, src_of, cc, cn, dim, cc.values, cn.values)
    ys = ops.drspmm_multi(plan, {"cell": (cc.values, cc.idx),
                                 "net": (cn.values, cn.idx)}, dim,
                          backend=backend)
    for et in packs:
        _assert_close(np.asarray(ys[et]), np.asarray(refs[et]),
                      f"fwd {backend}/{et}")

    def loss_multi(vc, vn):
        ys = ops.drspmm_multi(plan, {"cell": (vc, cc.idx),
                                     "net": (vn, cn.idx)}, dim,
                              backend=backend)
        return sum(jnp.sum(y ** 2) for y in ys.values())

    def loss_serial(vc, vn):
        refs = _serial_ref(packs, src_of, cc, cn, dim, vc, vn)
        return sum(jnp.sum(y ** 2) for y in refs.values())

    g = jax.grad(loss_multi, argnums=(0, 1))(cc.values, cn.values)
    g_ref = jax.grad(loss_serial, argnums=(0, 1))(cc.values, cn.values)
    for a, r, nm in zip(g, g_ref, ("cell", "net")):
        _assert_close(np.asarray(a), np.asarray(r), f"grad {backend}/{nm}")


def test_no_retrace_on_second_multi_call(op_setup):
    """The plan executor is built (and traced) once per (plan, dim,
    backend) — mirrors test_no_retrace_on_second_call for the learnable
    op."""
    plan, rels, packs, src_of, cc, cn, dim = op_setup
    cbsr = {"cell": (cc.values, cc.idx), "net": (cn.values, cn.idx)}
    for be in ("xla_fused", "pallas_fused"):
        ops.drspmm_multi(plan, cbsr, dim, backend=be)   # warm (trace 1)
        n0 = len(ops._MULTI_TRACES)
        a = ops.drspmm_multi(plan, cbsr, dim, backend=be)["near"]
        b = ops.drspmm_multi(plan, {"cell": (2 * cc.values, cc.idx),
                                    "net": (cn.values, cn.idx)},
                             dim, backend=be)["near"]
        assert len(ops._MULTI_TRACES) == n0, \
            f"repeated {be} drspmm_multi call retraced the executor"
        _assert_close(np.asarray(b), 2 * np.asarray(a), f"linearity {be}")


# ------------------------ layer-level parity ---------------------------

@pytest.fixture(scope="module")
def layer_setup():
    g = _graph(72, 36, 11)
    lp = init_hetero_layer(jax.random.PRNGKey(0), 32)
    rng = np.random.default_rng(5)
    x_cell = jnp.asarray(rng.normal(size=(72, 32)).astype(np.float32))
    x_net = jnp.asarray(rng.normal(size=(36, 32)).astype(np.float32))
    return g, lp, x_cell, x_net


@pytest.mark.parametrize("backend", ["pallas_fused", "xla_fused"])
def test_hetero_conv_plan_matches_serial(layer_setup, backend):
    """Plan-fused hetero_conv == the serial per-direction loop, forward
    (both node types) and gradients (inputs + layer params)."""
    g, lp, x_cell, x_net = layer_setup
    cfg_p = HeteroMPConfig(hidden=32, k_cell=8, k_net=8, backend=backend,
                           use_plan=True)
    cfg_s = dataclasses.replace(cfg_p, use_plan=False)

    y_p = hetero_conv(lp, g, x_cell, x_net, cfg_p)
    y_s = hetero_conv(lp, g, x_cell, x_net, cfg_s)
    for a, r, nm in zip(y_p, y_s, ("cell", "net")):
        _assert_close(np.asarray(a), np.asarray(r), f"fwd {backend}/{nm}")

    def loss(cfg):
        def f(p, xc, xn):
            yc, yn = hetero_conv(p, g, xc, xn, cfg)
            return jnp.sum(yc ** 2) + jnp.sum(jnp.sin(yn))
        return f

    g_p = jax.grad(loss(cfg_p), argnums=(0, 1, 2))(lp, x_cell, x_net)
    g_s = jax.grad(loss(cfg_s), argnums=(0, 1, 2))(lp, x_cell, x_net)
    for (pa, a), (_, r) in zip(jax.tree_util.tree_leaves_with_path(g_p),
                               jax.tree_util.tree_leaves_with_path(g_s)):
        _assert_close(np.asarray(a), np.asarray(r),
                      f"grad {jax.tree_util.keystr(pa)} {backend}")


def test_one_dispatch_per_direction_group():
    """The acceptance property: a hetero layer's message passing is ONE
    pallas_call forward and ONE backward on the plan path — vs one per edge
    type (×2 for grad) on the serial path.  The xla family asserts the same
    via the trace-time dispatch log.  Uses its own graph (→ fresh plan →
    fresh executor) so every trace actually runs and gets recorded."""
    g = _graph(48, 24, 23)
    lp = init_hetero_layer(jax.random.PRNGKey(1), 32)
    rng = np.random.default_rng(9)
    x_cell = jnp.asarray(rng.normal(size=(48, 32)).astype(np.float32))
    x_net = jnp.asarray(rng.normal(size=(24, 32)).astype(np.float32))
    cfg_p = HeteroMPConfig(hidden=32, k_cell=8, k_net=8,
                           backend="pallas_fused", use_plan=True)
    cfg_s = dataclasses.replace(cfg_p, use_plan=False)

    from benchmarks.bench_drspmm import dispatch_count

    def fwd(cfg):
        return lambda xc: hetero_conv(lp, g, xc, x_net, cfg)[0]

    def grad_both(cfg):
        # sum over BOTH outputs, differentiate wrt BOTH inputs, so no
        # direction's forward or backward is dead-code-eliminated
        return lambda xc, xn: jax.grad(lambda qc, qn: sum(
            jnp.sum(y ** 2) for y in hetero_conv(lp, g, qc, qn, cfg)),
            argnums=(0, 1))(xc, xn)

    assert dispatch_count(fwd(cfg_p), x_cell) == 1
    assert dispatch_count(fwd(cfg_s), x_cell) == 3
    assert dispatch_count(grad_both(cfg_p), x_cell, x_net) == 2
    assert dispatch_count(grad_both(cfg_s), x_cell, x_net) == 6

    # xla family: executor issues recorded while tracing.  Only the
    # direction-group executors may appear — a serial per-relation tag
    # ("xla:fwd"/"xla:bwd") would mean the plan path leaked back to the
    # loop.  This tiny graph's relations all sit below the dense-tier
    # crossover, so the group runs as the batched dense dispatch
    # (DESIGN.md §14).  (custom_vjp traces the forward body twice under
    # grad — primal + f_fwd — so the fwd tag may legitimately repeat.)
    plan = relation_plan_of(g)
    assert not plan.has_arena and plan.has_dense
    cfg_px = dataclasses.replace(cfg_p, backend="xla_fused")
    n0 = len(ops.FUSED_DISPATCH_LOG)
    jax.make_jaxpr(grad_both(cfg_px))(x_cell, x_net)
    tags = list(ops.FUSED_DISPATCH_LOG)[n0:]
    assert set(tags) == {"xla:multi_dense_fwd", "xla:multi_dense_bwd"}, tags
    assert tags.count("xla:multi_dense_bwd") == 1, tags


def test_relation_plan_memoized(layer_setup):
    g, lp, x_cell, x_net = layer_setup
    assert relation_plan_of(g) is relation_plan_of(g)
    pg = with_plan(g)
    assert pg.plan is relation_plan_of(g)
    assert with_plan(pg) is pg


# --------------------- segment round-trip property ---------------------

rt_plans = st.integers(0, 2 ** 31 - 1).flatmap(lambda seed: st.tuples(
    st.just(seed), st.integers(9, 40), st.integers(5, 24)))


def _check_plan_roundtrip(plan, rels):
    """Tier-aware block property: every relation's matrix reappears exactly
    at its segment's block of the full-coordinate plan matrix, nothing
    lands outside the blocks, arena segments tile the rel chunk table /
    transposed super-arena, and dense segments tile the stacked
    ``dense_fwd``/``dense_bwd`` tables."""
    A = plan.to_dense()                   # (n_out_total, n_src_total)
    off = dict(zip(plan.src_types, plan.src_off))
    cov_a = np.zeros_like(A, bool)
    arena_pos = {id(s): i for i, s in enumerate(plan.arena_segments)}
    B = plan.bwd.to_dense() if plan.has_arena else None
    DF = np.asarray(plan.dense_fwd)
    rel_tab = np.asarray(plan.fwd.rel) if plan.has_arena else None
    for seg, r in zip(plan.segments, rels):
        et, s_t, d_t, dst, src, w = r
        dense = np.zeros((seg.n_dst, seg.n_src), np.float32)
        np.add.at(dense, (dst, src), w)
        so = off[seg.src_type]
        np.testing.assert_allclose(
            A[seg.out_off:seg.out_off + seg.n_dst, so:so + seg.n_src],
            dense, atol=1e-6, err_msg=f"fwd {et}")
        cov_a[seg.out_off:seg.out_off + seg.n_dst, so:so + seg.n_src] = True
        if seg.tier == "arena":
            # transposed super-arena addresses the FULL output concat
            np.testing.assert_allclose(
                B[seg.src_out_off:seg.src_out_off + seg.n_src,
                  seg.out_off:seg.out_off + seg.n_dst],
                dense.T, atol=1e-6, err_msg=f"bwd {et}")
            lo, hi = seg.fwd_chunks
            assert (rel_tab[lo:hi] == arena_pos[id(seg)]).all()
            assert seg.dense_off == -1
        else:
            np.testing.assert_allclose(
                DF[seg.dense_off:seg.dense_off + seg.n_dst,
                   so:so + seg.n_src],
                dense, atol=1e-6, err_msg=f"dense fwd {et}")
            assert seg.fwd_chunks == (0, 0) and seg.arena_out_off == -1
    assert A[~cov_a].sum() == 0
    np.testing.assert_allclose(np.asarray(plan.dense_bwd), DF.T, atol=0,
                               err_msg="dense_bwd is dense_fwd transposed")
    if plan.has_arena:
        assert rel_tab.shape[0] == plan.fwd.n_chunks
    assert plan.bwd_src_rows.shape[0] == plan.bwd.n_arena_rows


@given(rt_plans)
def test_relation_segment_roundtrip(args):
    """The block property holds for every tiering of the same relations:
    the default classification (these tiny graphs go all-dense), a
    threshold of −1 (all-arena, the pre-tiering layout), and a forced
    mixed-tier split."""
    seed, n_cell, n_net = args
    rng = np.random.default_rng(seed)
    rels = _mixed_relations(rng, n_cell, n_net)
    sizes = {"cell": n_cell, "net": n_net}
    for plan in (
            build_relation_plan(rels, sizes),
            build_relation_plan(rels, sizes, dense_threshold=-1),
            build_relation_plan(rels, sizes,
                                tiers={"near": "arena", "pin": "dense",
                                       "pinned": "arena"})):
        _check_plan_roundtrip(plan, rels)


# --------------------- collation rides the plan ------------------------

@pytest.fixture(scope="module")
def members():
    return [_graph(60, 30, 0), _graph(101, 55, 1), _graph(37, 20, 2)]


@pytest.fixture(scope="module")
def model_params():
    return init_drcircuitgnn(jax.random.PRNGKey(0), 16, 16, 32)


@pytest.mark.parametrize("backend", ["pallas_fused", "xla_fused"])
def test_collated_plan_padding_is_inert(members, model_params, backend):
    """Quantized collation with an attached plan reproduces the exact
    (serial, unquantized) collation on every member slice — through a jit
    whose graph (plan included) is a TRACED argument, forward and grad."""
    from repro.models.hgnn import batched_loss_fn

    params = model_params
    cfg = HeteroMPConfig(hidden=32, k_cell=8, k_net=8, backend=backend,
                         use_plan=True)
    cfg_ref = dataclasses.replace(cfg, use_plan=False)
    exact = collate_graphs(members, fused=False, quantize=False)
    quant = collate_graphs(members, fused=True, quantize=True)
    assert quant.graph.plan is not None
    assert exact.graph.plan is None      # unfused collation stays plan-free

    fwd = jax.jit(lambda p, g: drcircuitgnn_forward(p, g, cfg))
    p_ref = exact.split_cell(
        drcircuitgnn_forward(params, exact.graph, cfg_ref))
    p_plan = quant.split_cell(fwd(params, quant.graph))
    for i, (a, r) in enumerate(zip(p_plan, p_ref)):
        _assert_close(np.asarray(a), np.asarray(r),
                      f"member {i} {backend} padding")

    g_q = jax.grad(batched_loss_fn)(params, quant.graph, quant.cell_weight,
                                    cfg)
    g_e = jax.grad(batched_loss_fn)(params, exact.graph, exact.cell_weight,
                                    cfg_ref)
    for (pa, a), (_, r) in zip(jax.tree_util.tree_leaves_with_path(g_q),
                               jax.tree_util.tree_leaves_with_path(g_e)):
        _assert_close(np.asarray(a), np.asarray(r),
                      f"grad {jax.tree_util.keystr(pa)} {backend}")


def test_collated_plan_filler_members_inert(members, model_params):
    """Filler replicas change nothing for the real members on the plan
    path (the deadline-batcher property)."""
    cfg = HeteroMPConfig(hidden=32, k_cell=8, k_net=8, backend="xla_fused",
                         use_plan=True)
    plain = collate_graphs(members)
    padded = collate_graphs(members + [members[-1]], n_real=len(members))
    a = plain.split_cell(
        drcircuitgnn_forward(model_params, plain.graph, cfg))
    b = padded.split_cell(
        drcircuitgnn_forward(model_params, padded.graph, cfg))
    assert len(a) == len(b) == len(members)
    for i, (x, y) in enumerate(zip(a, b)):
        _assert_close(np.asarray(y), np.asarray(x), f"member {i} filler")


def test_collated_plan_signature_stable_in_bucket():
    """Jittered same-class batches share one padded signature with a shared
    BucketLayout — now including the plan's super-arena dims (plan_chunk
    pinning + plan_min_chunks floors)."""
    layout = BucketLayout()
    b1 = collate_graphs([_graph(60, 30, 0), _graph(58, 29, 1)],
                        node_bits=1, layout=layout)
    b2 = collate_graphs([_graph(63, 31, 2), _graph(59, 28, 3)],
                        node_bits=1, layout=layout)
    assert b1.graph.plan is not None and b2.graph.plan is not None
    assert b1.signature == b2.signature
    assert layout.plan_chunk.keys() == {"fwd", "bwd"}


# --------------------- shape-bucketed learnable nnz --------------------

def test_edge_nnz_quantized_and_padding_inert():
    """collate_graphs(with_eids=True) rounds the traced-weight nnz up the
    arena grid (layout-floored), and the zero-padded tail is inert: the
    learnable op over the padded vector equals the exact-nnz result, with
    zero gradient on the pad slots."""
    layout = BucketLayout()
    b1 = collate_graphs([_graph(60, 30, 0), _graph(58, 29, 1)],
                        node_bits=1, with_eids=True, layout=layout)
    b2 = collate_graphs([_graph(63, 31, 2), _graph(59, 28, 3)],
                        node_bits=1, with_eids=True, layout=layout)
    et = "near"
    assert b1.edge_nnz[et] >= b1.edge_nnz_exact[et]
    # same bucket -> same padded nnz even though exact counts differ
    assert b1.edge_nnz[et] == b2.edge_nnz[et]
    assert b1.edge_nnz_exact[et] != b2.edge_nnz_exact[et]

    rng = np.random.default_rng(0)
    batch = b1
    es = batch.graph.edges[et]
    exact, padded = batch.edge_nnz_exact[et], batch.edge_nnz[et]
    member_ws = [rng.normal(
        size=batch.edge_eid_offsets[et][1] if i == 0
        else exact - batch.edge_eid_offsets[et][1]).astype(np.float32)
        for i in range(2)]
    w_pad = batch.concat_edge_weights(et, member_ws)
    assert w_pad.shape[0] == padded
    d, k = 16, 4
    n = batch.graph.n_cell
    xv = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
    xi = jnp.asarray(rng.integers(0, d, size=(n, k)).astype(np.int32))

    def f(wv, nnz):
        return ops.drspmm_learnable(es.adj, es.adj_t, nnz, wv, xv, xi, d,
                                    backend="xla_fused")

    y_pad = f(w_pad, padded)
    y_exact = f(w_pad[:exact], exact)
    _assert_close(np.asarray(y_pad), np.asarray(y_exact), "padded nnz fwd")
    gw = jax.grad(lambda wv: jnp.sum(jnp.sin(f(wv, padded))))(w_pad)
    assert np.all(np.asarray(gw[exact:]) == 0.0), "pad slots got gradient"


# ------------------------- params hot-swap -----------------------------

def test_engine_params_hot_swap(members):
    """update_params() swaps replicas between batches: post-swap requests
    are served by the new weights and stamped with the new version; no
    recompile is paid for the swap."""
    from repro.serve import CircuitServeEngine

    cfg = HeteroMPConfig(hidden=32, k_cell=8, k_net=8, backend="xla_fused")
    p0 = init_drcircuitgnn(jax.random.PRNGKey(0), 16, 16, 32)
    p1 = init_drcircuitgnn(jax.random.PRNGKey(1), 16, 16, 32)
    eng = CircuitServeEngine(p0, cfg, max_batch=len(members))
    g = members[0]

    r0 = eng.submit(g)
    eng.run()
    assert eng.result(r0).params_version == 0
    compiles_before = eng.compiles

    assert eng.update_params(p1) == 1
    assert eng.params_version == 1
    r1 = eng.submit(g)
    eng.run()
    req1 = eng.result(r1)
    assert req1.params_version == 1
    assert eng.compiles == compiles_before, "hot swap must not recompile"
    assert eng.stats()["params_version"] == 1

    ref0 = np.asarray(drcircuitgnn_forward(p0, g, cfg))
    ref1 = np.asarray(drcircuitgnn_forward(p1, g, cfg))
    _assert_close(eng.result(r0).pred, ref0, "pre-swap prediction")
    _assert_close(req1.pred, ref1, "post-swap prediction")
    assert not np.allclose(ref0, ref1), "swap should change predictions"
