"""Self-healing serving + training under deterministic chaos (DESIGN.md
§10, ISSUE-6 acceptance).

The containment ladder's promise is exact: healthy members of a failed
batch are re-served with predictions **bit-identical** to a fault-free run
(block-diagonal collation + bucket-pinned shapes make member outputs
independent of batch companions), so every parity check below is
``np.array_equal``, not allclose.

Fault sources used here:

* chaos harness (fault/inject.py) for transient dispatch/output faults,
  stragglers, and device loss;
* a *malformed* graph (feature rows disagree with ``n_cell``) as the
  persistent poison member — it passes the finiteness gate at submit but
  fails collation deterministically, so only bisection can isolate it.
"""

import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hetero_mp import HeteroMPConfig
from repro.fault import FaultInjector, FaultRule, StepMonitor
from repro.graphs.generator import generate_partition, pack_graph_parallel
from repro.models.hgnn import init_drcircuitgnn
from repro.serve import (CircuitServeEngine, LoadShedError,
                         NonFiniteInputError, NonFiniteOutputError,
                         QueueFullError, WatchdogTimeoutError)
from repro.train.circuit_trainer import CircuitTrainConfig, CircuitTrainer


def _graph(n_cell, n_net, seed):
    coo, xc, xn, y = generate_partition(np.random.default_rng(seed),
                                        n_cell, n_net)
    return pack_graph_parallel(coo, n_cell, n_net, xc, xn, y)


def _malformed(g):
    """Persistent poison: one feature row short of ``n_cell``.  Finite (so
    it passes submit validation), same shape bucket, but collation raises
    every time it is a batch member."""
    return dataclasses.replace(g, x_cell=g.x_cell[:-1])


def _nan_features(g):
    return dataclasses.replace(g, x_cell=jnp.full_like(g.x_cell, jnp.nan))


@pytest.fixture(scope="module")
def model():
    cfg = HeteroMPConfig(hidden=32, k_cell=8, k_net=8, backend="xla_fused")
    params = init_drcircuitgnn(jax.random.PRNGKey(0), 16, 16, 32)
    return params, cfg


def _engine(model, **kw):
    params, cfg = model
    kw.setdefault("max_wait_ms", 30.0)
    return CircuitServeEngine(params, cfg, **kw)


def _serve_on_thread(eng):
    t = threading.Thread(target=eng.serve_forever)
    t.start()
    return t


def _reference(model, graphs, **kw):
    """Fault-free predictions for ``graphs`` (drain mode; member
    independence makes batch composition irrelevant)."""
    eng = _engine(model, **kw)
    rids = [eng.submit(g) for g in graphs]
    eng.run()
    return [eng.result(r).pred for r in rids]


# --------------------------------------------------- containment ladder

def test_retry_recovers_transient_dispatch_fault(model):
    chaos = FaultInjector([FaultRule("dispatch", at=(0,))])
    eng = _engine(model, max_batch=2, max_retries=2,
                  retry_backoff_s=0.01, chaos=chaos)
    t = _serve_on_thread(eng)
    try:
        graphs = [_graph(80, 40, s) for s in range(2)]
        rids = [eng.submit(g) for g in graphs]
        preds = [eng.result(r, timeout=240.0).pred for r in rids]
    finally:
        eng.stop()
        t.join(timeout=240.0)
    assert not t.is_alive()
    for p, ref in zip(preds, _reference(model, graphs)):
        assert np.array_equal(p, ref)       # bit-identical to fault-free
    st = eng.stats()
    assert st["retries"] >= 1 and st["failures"] == 0, st
    assert chaos.counts()["dispatch"] == 1


def test_bisect_isolates_poison_member(model):
    """A persistently-failing batch bisects down until ONLY the poison
    member errors; every healthy member is re-served bit-identically."""
    graphs = [_graph(80, 40, s) for s in range(4)]
    poison = _malformed(_graph(80, 40, 99))
    eng = _engine(model, max_batch=4, max_retries=1, retry_backoff_s=0.005)
    t = _serve_on_thread(eng)
    try:
        # the poison lands inside a full batch of 4 (3 healthy + 1 poison)
        rids = [eng.submit(g) for g in (graphs[0], graphs[1], poison,
                                        graphs[2])]
        healthy = {rids[0]: graphs[0], rids[1]: graphs[1],
                   rids[3]: graphs[2]}
        refs = dict(zip(healthy, _reference(model, list(healthy.values()))))
        for rid in healthy:
            assert np.array_equal(eng.result(rid, timeout=240.0).pred,
                                  refs[rid])
        with pytest.raises(RuntimeError) as ei:
            eng.result(rids[2], timeout=240.0)
        assert isinstance(ei.value.__cause__, ValueError)   # collate error
        # the rest of the stream keeps serving after the poison is contained
        r_after = eng.submit(graphs[3])
        assert eng.result(r_after, timeout=240.0).pred is not None
    finally:
        eng.stop()
        t.join(timeout=240.0)
    assert not t.is_alive()
    st = eng.stats()
    assert st["bisects"] >= 1, st
    assert st["failures"] == 1, st          # ONLY the poison request


def test_transient_nan_output_heals_on_retry(model):
    chaos = FaultInjector([FaultRule("nan_output", at=(0,))])
    eng = _engine(model, max_batch=2, max_retries=2,
                  retry_backoff_s=0.01, chaos=chaos)
    t = _serve_on_thread(eng)
    try:
        graphs = [_graph(80, 40, s) for s in range(2)]
        rids = [eng.submit(g) for g in graphs]
        preds = [eng.result(r, timeout=240.0).pred for r in rids]
    finally:
        eng.stop()
        t.join(timeout=240.0)
    for p, ref in zip(preds, _reference(model, graphs)):
        assert np.array_equal(p, ref)
    st = eng.stats()
    assert st["nonfinite_outputs"] == 1 and st["failures"] == 0, st
    assert st["retries"] >= 1, st


def test_persistent_nan_output_diagnosed(model):
    """An output poisoned on every attempt ends as a diagnosed
    NonFiniteOutputError, not a served NaN."""
    chaos = FaultInjector([FaultRule("nan_output", rate=1.0)])
    eng = _engine(model, max_batch=1, max_retries=1,
                  retry_backoff_s=0.005, chaos=chaos)
    t = _serve_on_thread(eng)
    try:
        rid = eng.submit(_graph(80, 40, 0))
        with pytest.raises(RuntimeError) as ei:
            eng.result(rid, timeout=240.0)
    finally:
        eng.stop()
        t.join(timeout=240.0)
    cause = ei.value.__cause__
    assert isinstance(cause, NonFiniteOutputError)
    assert "non-finite predictions" in str(cause)
    assert eng.stats()["nonfinite_outputs"] >= 2   # initial + retry


def test_watchdog_bounds_wedged_batch(model):
    """A wedged prepare (chaos straggler far past the watchdog) becomes a
    prompt WatchdogTimeoutError — result() never hangs on it — and the
    engine keeps serving afterwards."""
    chaos = FaultInjector([FaultRule("straggler", at=(1,), delay_s=5.0)])
    eng = _engine(model, max_batch=1, max_retries=0, chaos=chaos)
    t = _serve_on_thread(eng)
    try:
        g = _graph(80, 40, 0)
        # warm the bucket (compile) before arming the watchdog, so the
        # bound measures the wedge, not the first-dispatch compile
        assert eng.result(eng.submit(g), timeout=240.0).pred is not None
        eng.watchdog_s = 0.3
        t0 = time.perf_counter()
        rid = eng.submit(g)                 # straggler occurrence 1 wedges
        with pytest.raises(RuntimeError) as ei:
            eng.result(rid, timeout=240.0)
        bounded = time.perf_counter() - t0
        assert isinstance(ei.value.__cause__, WatchdogTimeoutError)
        assert bounded < 4.0                # far less than the 5s wedge
        # next request (straggler quiet) is served normally
        rid2 = eng.submit(g)
        assert eng.result(rid2, timeout=240.0).pred is not None
    finally:
        eng.stop()
        t.join(timeout=240.0)
    assert eng.stats()["watchdog_timeouts"] >= 1


def test_device_loss_quarantine_probe_readmission(model):
    """A lost ring slot accumulates consecutive failures -> quarantined
    (routing continues on the survivor) -> periodically probed -> probe
    succeeds once the loss window passes -> re-admitted.  Two logical slots
    on the one local device stand in for two devices."""
    d0 = jax.devices()[0]
    chaos = FaultInjector([FaultRule("device_loss", at=(0,), device=1,
                                     down_for=4)])
    eng = _engine(model, max_batch=1, devices=[d0, d0],
                  quarantine_after=2, probe_interval_s=0.15,
                  max_retries=3, retry_backoff_s=0.01, chaos=chaos)
    t = _serve_on_thread(eng)
    g = _graph(80, 40, 0)
    try:
        saw_quarantine = False
        deadline = time.time() + 240.0
        while time.time() < deadline:
            rid = eng.submit(g)
            assert eng.result(rid, timeout=240.0).pred is not None
            h = eng.ring.health()
            saw_quarantine = saw_quarantine or "quarantined" in h["states"]
            if h["readmissions"] >= 1:
                break
            time.sleep(0.03)
    finally:
        eng.stop()
        t.join(timeout=240.0)
    st = eng.stats()
    assert saw_quarantine, st
    assert st["quarantines"] >= 1 and st["probes"] >= 1, st
    assert st["readmissions"] >= 1, st
    assert st["failures"] == 0, st          # retries absorbed every loss
    assert st["device_health"] == ["up", "up"], st


def test_ring_probe_release_never_sticks():
    """A probe handout whose attempt dies before touching the device is
    released back to quarantined WITHOUT resetting the probe clock — the
    slot is re-probed immediately instead of rotting in probing limbo."""
    from repro.sharding.specs import DeviceRing
    t = [0.0]
    ring = DeviceRing([object(), object()], quarantine_after=1,
                      probe_interval_s=1.0, clock=lambda: t[0])
    ring.record_failure(1)
    assert ring.health()["states"][1] == "quarantined"
    t[0] = 1.5
    assert ring.next_index() == 1           # probe handout
    assert ring.health()["states"][1] == "probing"
    ring.release(1)                         # attempt never reached the slot
    assert ring.health()["states"][1] == "quarantined"
    assert ring.next_index() == 1           # re-probed at once
    ring.record_success(1)
    h = ring.health()
    assert h["states"][1] == "up" and h["readmissions"] == 1
    assert h["probes"] == 2
    ring.release(0)                         # no-op on a healthy slot
    assert ring.health()["states"][0] == "up"


# ------------------------------------------------------ admission control

def test_admission_reject(model):
    eng = _engine(model, max_queue=2, admission="reject")
    g = _graph(60, 30, 0)
    eng.submit(g)
    eng.submit(g)
    with pytest.raises(QueueFullError):
        eng.submit(g)
    st = eng.stats()
    assert st["admission_rejected"] == 1 and st["queued"] == 2, st


def test_admission_shed_oldest(model):
    eng = _engine(model, max_queue=2, admission="shed_oldest")
    g = _graph(60, 30, 0)
    r1, r2 = eng.submit(g), eng.submit(g)
    r3 = eng.submit(g)                      # sheds r1, admits r3
    with pytest.raises(RuntimeError) as ei:
        eng.result(r1, timeout=1.0)         # already finalized: no serving
    assert isinstance(ei.value.__cause__, LoadShedError)
    st = eng.stats()
    assert st["admission_shed"] == 1 and st["failures"] == 1, st
    eng.run()                               # survivors still serve fine
    assert eng.result(r2).pred is not None
    assert eng.result(r3).pred is not None


def test_admission_block_backpressures_producer(model):
    eng = _engine(model, max_queue=1, admission="block", max_batch=1)
    t = _serve_on_thread(eng)
    try:
        g = _graph(60, 30, 0)
        rids = [eng.submit(g) for _ in range(6)]   # blocks while compiling
        for r in rids:
            assert eng.result(r, timeout=240.0).pred is not None
    finally:
        eng.stop()
        t.join(timeout=240.0)
    st = eng.stats()
    assert st["admission_blocked"] >= 1, st
    assert st["failures"] == 0 and st["requests"] == 6, st


def test_admission_block_timeout(model):
    eng = _engine(model, max_queue=1, admission="block")
    g = _graph(60, 30, 0)
    eng.submit(g)
    with pytest.raises(TimeoutError, match="blocked on full queue"):
        eng.submit(g, timeout=0.05)         # nothing draining the queue
    assert eng.stats()["admission_blocked"] == 1


def test_nonfinite_input_rejected_at_submit(model):
    eng = _engine(model)
    with pytest.raises(NonFiniteInputError, match="x_cell"):
        eng.submit(_nan_features(_graph(60, 30, 0)))
    st = eng.stats()
    assert st["rejected_inputs"] == 1 and st["queued"] == 0, st
    # validation off lets the same graph through (the output guard and the
    # ladder own containment then)
    eng2 = _engine(model, validate_inputs=False)
    eng2.submit(_nan_features(_graph(60, 30, 0)))
    assert eng2.stats()["queued"] == 1


# ----------------------------------------- the seeded end-to-end schedule

def test_seeded_chaos_schedule_end_to_end(model):
    """ISSUE-6 acceptance: one stream under a seeded schedule mixing a
    transient dispatch failure, a straggler, a simulated device loss, and
    one persistent poison graph.  Every healthy prediction is bit-identical
    to a fault-free run, ONLY the poison request errors, the lost slot is
    quarantined then probed back, and no result() call hangs."""
    d0 = jax.devices()[0]
    chaos = FaultInjector([
        FaultRule("dispatch", at=(1,)),
        FaultRule("straggler", at=(2,), delay_s=0.05),
        FaultRule("device_loss", at=(0,), device=1, down_for=3),
    ], seed=42)
    eng = _engine(model, max_batch=2, devices=[d0, d0], max_wait_ms=20.0,
                  validate_inputs=False, watchdog_s=60.0,
                  max_retries=3, retry_backoff_s=0.01,
                  quarantine_after=2, probe_interval_s=0.1, chaos=chaos)
    bucket_a = [_graph(80, 40, s) for s in range(6)]
    bucket_b = [_graph(150, 75, 10 + s) for s in range(4)]
    poison = _malformed(_graph(150, 75, 99))
    t = _serve_on_thread(eng)
    try:
        rids = {}
        for g in bucket_a[:2] + bucket_b[:2] + bucket_a[2:4]:
            rids[eng.submit(g)] = g
            time.sleep(0.01)
        poison_rid = eng.submit(poison)     # pairs with the next submit:
        rids[eng.submit(bucket_b[2])] = bucket_b[2]     # a full B-batch
        for g in bucket_a[4:] + bucket_b[3:]:
            rids[eng.submit(g)] = g
            time.sleep(0.01)
        # every result() returns (bounded by its timeout, i.e. no hang)
        for rid in rids:
            assert eng.result(rid, timeout=240.0).pred is not None
        with pytest.raises(RuntimeError) as ei:
            eng.result(poison_rid, timeout=240.0)
        assert isinstance(ei.value.__cause__, ValueError)
        # keep a trickle flowing until the lost slot is probed back in
        g = bucket_a[0]
        deadline = time.time() + 240.0
        while eng.ring.health()["readmissions"] < 1 \
                and time.time() < deadline:
            assert eng.result(eng.submit(g),
                              timeout=240.0).pred is not None
            time.sleep(0.03)
    finally:
        eng.stop()
        t.join(timeout=240.0)
    assert not t.is_alive()
    st = eng.stats()
    # bit-identical healthy parity against a fault-free engine
    order = list(rids.values())
    refs = _reference(model, order)
    for (rid, _), ref in zip(rids.items(), refs):
        assert np.array_equal(eng.result(rid).pred, ref), rid
    assert st["failures"] == 1, st          # ONLY the poison request
    assert st["retries"] >= 1 and st["bisects"] >= 1, st
    assert st["quarantines"] >= 1 and st["probes"] >= 1, st
    assert st["readmissions"] >= 1, st
    assert st["device_health"] == ["up", "up"], st
    counts = chaos.counts()
    assert counts.get("dispatch") == 1 and counts.get("straggler") == 1
    assert counts.get("device_loss", 0) >= 1


# ------------------------------------------------------- trainer guards

def _tcfg():
    return CircuitTrainConfig(hidden=16, n_layers=1, k_cell=4, k_net=4,
                              epochs=1, backend="xla_fused")


def _trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


def test_trainer_skips_nonfinite_grad_steps():
    """A poisoned graph's step is a true no-op: params AND optimizer state
    end bit-identical to a run that never saw the bad graph."""
    g1, g2 = _graph(40, 20, 0), _graph(40, 20, 1)
    bad = _nan_features(_graph(40, 20, 2))
    tr_a = CircuitTrainer(_tcfg(), 16, 16)
    loss_a = tr_a.train_epoch([g1, bad, g2])
    tr_b = CircuitTrainer(_tcfg(), 16, 16)
    loss_b = tr_b.train_epoch([g1, g2])
    assert tr_a.nonfinite_grad_steps == 1
    assert tr_b.nonfinite_grad_steps == 0
    assert np.isfinite(loss_a) and np.isclose(loss_a, loss_b)
    assert _trees_equal(tr_a.params, tr_b.params)
    assert _trees_equal(tr_a.opt_state, tr_b.opt_state)


def test_trainer_batched_step_skips_poisoned_batch():
    g1 = _graph(40, 20, 0)
    bad = _nan_features(_graph(40, 20, 2))
    tr = CircuitTrainer(_tcfg(), 16, 16)
    before = jax.tree.map(lambda x: np.asarray(x).copy(), tr.params)
    loss = tr.train_epoch([g1, bad], batch_size=2)   # one collated step
    assert tr.nonfinite_grad_steps == 1
    assert np.isnan(loss)                   # every step skipped
    assert _trees_equal(tr.params, before)  # the no-op really is one


def test_trainer_straggler_feeds_step_monitor():
    chaos = FaultInjector([FaultRule("straggler", at=(0,), delay_s=0.01)])
    mon = StepMonitor(n_hosts=1)
    tr = CircuitTrainer(_tcfg(), 16, 16, chaos=chaos, monitor=mon)
    g1, g2 = _graph(40, 20, 0), _graph(40, 20, 1)
    tr.train_epoch([g1, g2])
    assert chaos.counts() == {"straggler": 1}
    assert len(mon.history[0]) == 2         # every step ticked the monitor
    assert tr._global_step == 2
