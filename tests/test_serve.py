"""Serving consistency: prefill-then-decode == teacher forcing, per family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config, reduced
from repro.models.lm import serve
from repro.models.lm.model import build_lm


def setup(arch, b=2, s=16):
    cfg = reduced(get_config(arch))
    lm = build_lm(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)).astype(np.int32))
    extra = None
    if cfg.family == "vlm":
        extra = {"image_emb": jnp.full((b, cfg.n_img_tokens, cfg.d_model),
                                       0.01, lm.dtype)}
    if cfg.family == "audio":
        extra = {"frames": jnp.full((b, cfg.enc_frames, cfg.d_model),
                                    0.01, lm.dtype)}
    return cfg, lm, params, tokens, extra


# MoE capacity competition differs between prefill (all tokens) and decode
# (one token) — exact logit match is not expected there.  SSM/hybrid state
# updates are not idempotent (re-decoding the last token advances the state
# twice), so those families are covered by the decode-from-scratch test
# below instead.
EXACT = [a for a in ARCH_IDS
         if get_config(a).family not in ("moe", "ssm", "hybrid")]


@pytest.mark.parametrize("arch", EXACT)
def test_decode_reproduces_prefill_last_logits(arch):
    cfg, lm, params, tokens, extra = setup(arch)
    b, s = tokens.shape
    cache, logits_p = serve.prefill(lm, params, tokens, extra)
    _, logits_d = serve.decode_step(lm, params, cache, tokens[:, -1:],
                                    jnp.asarray(s - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(logits_d),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-1.3b",
                                  "zamba2-1.2b", "whisper-large-v3"])
def test_decode_from_scratch_matches_teacher_forcing(arch):
    """Decode every token one-by-one from a zero cache; logits at each pos
    must match a prefill over the corresponding prefix — the strongest
    serving-consistency property, valid for every cache family."""
    cfg, lm, params, tokens, extra = setup(arch, b=1, s=8)
    b, s = tokens.shape
    cache = serve.cache_zeros(lm, b, s)
    if cfg.family in ("vlm", "audio"):
        # cross-attention caches come from prefill only; seed them
        pre, _ = serve.prefill(lm, params, tokens, extra)
        cache["xk"], cache["xv"] = pre["xk"], pre["xv"]
    dec = jax.jit(lambda p, c, t, q: serve.decode_step(lm, p, c, t, q))
    for pos in range(s):
        cache, logits = dec(params, cache, tokens[:, pos: pos + 1],
                            jnp.asarray(pos, jnp.int32))
        if pos >= 2:
            _, ref_logits = serve.prefill(lm, params,
                                          tokens[:, : pos + 1], extra)
            np.testing.assert_allclose(np.asarray(logits),
                                       np.asarray(ref_logits),
                                       rtol=3e-3, atol=3e-3)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_cache_template_matches_prefill_output(arch):
    cfg, lm, params, tokens, extra = setup(arch)
    b, s = tokens.shape
    cache, _ = serve.prefill(lm, params, tokens, extra)
    tmpl = serve.cache_structs(lm, b, s)
    assert set(cache) == set(tmpl)
    for k in cache:
        assert tuple(cache[k].shape) == tuple(tmpl[k].shape), \
            (k, cache[k].shape, tmpl[k].shape)


def test_drelu_sparse_decode_close_to_dense():
    """The CBSR-gather decode FFN == masked dense FFN (same math)."""
    from repro.models.lm.ffn import swiglu_ffn, swiglu_ffn_decode_sparse
    rng = np.random.default_rng(0)
    d, f, k = 16, 64, 16
    x = jnp.asarray(rng.normal(size=(4, 1, d)).astype(np.float32))
    wg = jnp.asarray(rng.normal(size=(d, f)).astype(np.float32) * 0.3)
    wu = jnp.asarray(rng.normal(size=(d, f)).astype(np.float32) * 0.3)
    wd = jnp.asarray(rng.normal(size=(f, d)).astype(np.float32) * 0.3)
    dense = swiglu_ffn(x, wg, wu, wd, drelu_k=k)
    sparse = swiglu_ffn_decode_sparse(x, wg, wu, wd, k)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(sparse),
                               rtol=1e-4, atol=1e-4)
