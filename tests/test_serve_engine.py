"""Continuous-batching engine: mixed-length requests must generate exactly
what each request generates alone (batch isolation + ragged positions)."""

import jax
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.models.lm.model import build_lm
from repro.serve import ServeEngine


@pytest.fixture(scope="module")
def lm_params():
    cfg = reduced(get_config("qwen3-0.6b"))
    lm = build_lm(cfg)
    return cfg, lm, lm.init(jax.random.PRNGKey(0))


def gen_alone(cfg, lm, params, prompt, n_new, s_max=32):
    eng = ServeEngine(lm, params, max_batch=1, s_max=s_max)
    rid = eng.submit(prompt, n_new)
    return eng.run()[rid].generated


def test_mixed_batch_matches_isolated(lm_params):
    cfg, lm, params = lm_params
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab, n).tolist() for n in (3, 7, 5)]
    solo = [gen_alone(cfg, lm, params, p, 6) for p in prompts]

    eng = ServeEngine(lm, params, max_batch=2, s_max=32)  # 3 reqs, 2 slots
    rids = [eng.submit(p, 6) for p in prompts]
    out = eng.run()
    assert set(out) == set(rids)
    for rid, want in zip(rids, solo):
        assert out[rid].generated == want, (rid, out[rid].generated, want)


def test_queueing_and_slot_reuse(lm_params):
    cfg, lm, params = lm_params
    eng = ServeEngine(lm, params, max_batch=2, s_max=16)
    rids = [eng.submit([1, 2, 3], 4) for _ in range(5)]
    out = eng.run()
    assert len(out) == 5
    # identical prompts => identical generations across slot generations
    gens = [out[r].generated for r in rids]
    assert all(g == gens[0] for g in gens)


def test_cache_bound_respected(lm_params):
    cfg, lm, params = lm_params
    eng = ServeEngine(lm, params, max_batch=1, s_max=8)
    rid = eng.submit([1, 2, 3, 4], 100)      # wants more than cache allows
    out = eng.run()
    assert rid in out
    assert len(out[rid].generated) <= 8      # truncated at s_max
