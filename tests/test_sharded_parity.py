"""Multi-device parity harness for giant-graph sharded execution
(DESIGN.md §12) — the acceptance test of the mesh-partitioned RelationPlan.

Runs in a subprocess per shard count (XLA's device count locks at first jax
import; tests/_multidev.py) with 2/4/8 virtual CPU devices and asserts the
sharded executor — real ``shard_map`` + ``jax.lax.all_to_all`` halo
exchange — matches the single-device plan path to f32 allclose:

* ``ops.drspmm_multi_sharded`` vs ``ops.drspmm_multi``: forward outputs of
  ALL edge-type directions of the medium synthetic graph, plus gradients
  wrt both source types' CBSR values;
* ``hetero_conv`` with ``HeteroMPConfig(n_shards=n)`` vs the unsharded plan
  path: forward (both node types) and gradients (inputs + layer params);
* the skewed-degree (hub source row read by every shard) and
  single-relation plans — the layouts most likely to break halo exchange;
* ``CircuitTrainer(n_shards=2)`` vs the single-device trainer: identical
  per-epoch losses and final parameters (n=2 leg only, runtime bound).

The host-side layout properties behind the same partitioner are covered
(fast, in-process) by tests/test_plan_shard.py.
"""

import pytest

from _multidev import run_multidev

SCRIPT = r"""
import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np

n = int(sys.argv[1])
assert jax.device_count() == n, (jax.device_count(), n)

from repro.core.cbsr import cbsr_from_dense
from repro.core.drelu import drelu
from repro.core.hetero_mp import HeteroMPConfig, hetero_conv, \
    init_hetero_layer
from repro.graphs.circuit import relation_plan_of, sharded_plan_of
from repro.graphs.ell import build_relation_plan
from repro.graphs.generator import generate_partition, pack_graph_parallel
from repro.kernels import ops
from repro.sharding.plan_shard import shard_relation_plan


def close(a, r, msg, tol=2e-5):
    a, r = np.asarray(a), np.asarray(r)
    atol = tol * max(1.0, float(np.abs(r).max()) if r.size else 1.0)
    np.testing.assert_allclose(a, r, atol=atol, rtol=tol, err_msg=msg)


def graph(n_cell, n_net, seed):
    coo, xc, xn, y = generate_partition(np.random.default_rng(seed),
                                        n_cell, n_net)
    return pack_graph_parallel(coo, n_cell, n_net, xc, xn, y)


def sparsify(rng, rows, dim, k):
    x = jnp.asarray(rng.normal(size=(rows, dim)).astype(np.float32))
    return cbsr_from_dense(drelu(x, k), k)


def op_parity(plan, splan, cbsr, idxs, dim, tag):
    # drspmm_multi_sharded == drspmm_multi, fwd + grads in every source
    # type's CBSR values
    y_ref = ops.drspmm_multi(plan, cbsr, dim, backend="xla_fused")
    y_sh = ops.drspmm_multi_sharded(splan, cbsr, dim, backend="xla_fused")
    assert y_ref.keys() == y_sh.keys()
    for et in y_ref:
        close(y_sh[et], y_ref[et], f"{tag} fwd {et}")

    types = list(cbsr)

    def loss(op, p):
        def f(*vals):
            ys = op(p, {t: (v, idxs[t]) for t, v in zip(types, vals)},
                    dim, backend="xla_fused")
            return sum(jnp.sum(jnp.sin(y)) for y in ys.values())
        return f

    vals = tuple(cbsr[t][0] for t in types)
    arg = tuple(range(len(types)))
    g_ref = jax.grad(loss(ops.drspmm_multi, plan), argnums=arg)(*vals)
    g_sh = jax.grad(loss(ops.drspmm_multi_sharded, splan),
                    argnums=arg)(*vals)
    for a, r, t in zip(g_sh, g_ref, types):
        close(a, r, f"{tag} grad {t}")


# ---- all edge-type directions of the medium synthetic graph -----------
rng = np.random.default_rng(1)
dim, k = 32, 8
g = graph(120, 60, 0)
plan = relation_plan_of(g)
splan = sharded_plan_of(g, n)
cc, cn = sparsify(rng, 120, dim, k), sparsify(rng, 60, dim, k)
cbsr = {"cell": (cc.values, cc.idx), "net": (cn.values, cn.idx)}
idxs = {"cell": cc.idx, "net": cn.idx}
op_parity(plan, splan, cbsr, idxs, dim, "medium")
print("OP_PARITY_OK")

# ---- layer-level parity through HeteroMPConfig(n_shards=n) ------------
lp = init_hetero_layer(jax.random.PRNGKey(0), dim)
x_cell = jnp.asarray(rng.normal(size=(120, dim)).astype(np.float32))
x_net = jnp.asarray(rng.normal(size=(60, dim)).astype(np.float32))
cfg1 = HeteroMPConfig(hidden=dim, k_cell=k, k_net=k, backend="xla_fused")
cfgn = dataclasses.replace(cfg1, n_shards=n)
y1 = hetero_conv(lp, g, x_cell, x_net, cfg1)
yn = hetero_conv(lp, g, x_cell, x_net, cfgn)
for a, r, nm in zip(yn, y1, ("cell", "net")):
    close(a, r, f"layer fwd {nm}")


def layer_loss(cfg):
    def f(p, xc, xn):
        yc, yn = hetero_conv(p, g, xc, xn, cfg)
        return jnp.sum(yc ** 2) + jnp.sum(jnp.sin(yn))
    return f


g1 = jax.grad(layer_loss(cfg1), argnums=(0, 1, 2))(lp, x_cell, x_net)
gn = jax.grad(layer_loss(cfgn), argnums=(0, 1, 2))(lp, x_cell, x_net)
for (pa, a), (_, r) in zip(jax.tree_util.tree_leaves_with_path(gn),
                           jax.tree_util.tree_leaves_with_path(g1)):
    close(a, r, f"layer grad {jax.tree_util.keystr(pa)}")
print("LAYER_PARITY_OK")

# ---- edge cases: skewed degree (hub) and single-relation plans --------
n_cell = 96
erng = np.random.default_rng(2)
hub_d = np.arange(n_cell, dtype=np.int64)       # hub: cell 0 feeds all
hub_s = np.zeros(n_cell, np.int64)
ex_d = erng.integers(0, n_cell, 64)
ex_s = erng.integers(0, n_cell, 64)
pairs = np.unique(np.stack([np.concatenate([hub_d, ex_d]),
                            np.concatenate([hub_s, ex_s])], 1), axis=0)
w = erng.normal(size=pairs.shape[0]).astype(np.float32)
w[w == 0] = 1.0
skew = build_relation_plan(
    [("near", "cell", "cell", pairs[:, 0], pairs[:, 1], w)],
    {"cell": n_cell})
ck = sparsify(erng, n_cell, dim, k)
op_parity(skew, shard_relation_plan(skew, n),
          {"cell": (ck.values, ck.idx)}, {"cell": ck.idx}, dim, "skew")

thin_d = erng.integers(0, 40, 120)
thin_s = erng.integers(0, 64, 120)
tp = np.unique(np.stack([thin_d, thin_s], 1), axis=0)
tw = erng.normal(size=tp.shape[0]).astype(np.float32)
tw[tw == 0] = 1.0
single = build_relation_plan(
    [("pinned", "net", "cell", tp[:, 0], tp[:, 1], tw)],
    {"cell": 40, "net": 64})
cs = sparsify(erng, 64, dim, k)
cz = sparsify(erng, 40, dim, k)     # unread source type: zero grads both paths
op_parity(single, shard_relation_plan(single, n),
          {"cell": (cz.values, cz.idx), "net": (cs.values, cs.idx)},
          {"cell": cz.idx, "net": cs.idx}, dim, "single-rel")
print("EDGE_CASES_OK")

# ---- trainer-step parity (2-device leg only: runtime bound) -----------
if n == 2:
    from repro.train.circuit_trainer import CircuitTrainConfig, \
        CircuitTrainer

    graphs = [graph(80, 40, s) for s in (3, 4)]
    fc, fn = graphs[0].x_cell.shape[1], graphs[0].x_net.shape[1]
    runs = {}
    for shards in (0, 2):
        tr = CircuitTrainer(CircuitTrainConfig(
            hidden=32, k_cell=8, k_net=8, backend="xla_fused",
            n_shards=shards), fc, fn)
        losses = [tr.train_epoch(graphs) for _ in range(2)]
        runs[shards] = (losses, tr.params)
    np.testing.assert_allclose(runs[2][0], runs[0][0],
                               rtol=1e-5, atol=1e-6, err_msg="epoch losses")
    d = max(float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(runs[0][1]),
                            jax.tree.leaves(runs[2][1])))
    assert d < 5e-6, f"param divergence {d}"
    print("TRAINER_PARITY_OK")

print("SHARDED_PARITY_OK")
"""


@pytest.mark.slow
@pytest.mark.parametrize("n", [2, 4, 8])
def test_sharded_parity_subprocess(n):
    expect = ["OP_PARITY_OK", "LAYER_PARITY_OK", "EDGE_CASES_OK",
              "SHARDED_PARITY_OK"]
    if n == 2:
        expect.append("TRAINER_PARITY_OK")
    run_multidev(SCRIPT, n_devices=n, argv=[n], expect=tuple(expect))
