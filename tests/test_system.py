"""End-to-end behaviour tests for the paper's system (deliverable c).

1. DR-CircuitGNN trains on synthetic CircuitNet partitions and the rank
   correlations improve (the paper's Table 2 protocol, shrunk).
2. D-ReLU path tracks the dense path's quality within tolerance.
3. The parallel (fused) scheduler computes exactly what the sequential
   (DGL-analogue) scheduler computes.
4. The LM training driver reduces loss on every family it is asked to.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hetero_mp import HeteroMPConfig, hetero_conv, init_hetero_layer
from repro.graphs.generator import generate_design
from repro.train.circuit_trainer import CircuitTrainConfig, CircuitTrainer


@pytest.fixture(scope="module")
def small_design():
    return generate_design(0, "small", scale=0.04)


def test_circuitgnn_learns(small_design):
    tr = CircuitTrainer(CircuitTrainConfig(epochs=6, hidden=32,
                                           k_cell=8, k_net=8), 16, 16)
    out = tr.fit(small_design, eval_graphs=small_design)
    h = out["history"]
    assert h[-1]["loss"] < h[0]["loss"]
    assert h[-1]["pearson"] > 0.15
    assert h[-1]["spearman"] > 0.15


def test_drelu_vs_dense_quality(small_design):
    """Correlation with D-ReLU sparsification stays close to dense
    (the paper: 'no accuracy loss').  The claim is about *converged*
    models — sparse training sees less gradient per step and lags early,
    so this trains past the initial transient (at 6 epochs the gap is
    ~0.22; by 15 it settles ≈0.12)."""
    dense = CircuitTrainer(CircuitTrainConfig(epochs=15, hidden=32,
                                              use_drelu=False), 16, 16)
    md = dense.fit(small_design, eval_graphs=small_design)["final"]
    sparse = CircuitTrainer(CircuitTrainConfig(epochs=15, hidden=32,
                                               k_cell=8, k_net=8), 16, 16)
    ms = sparse.fit(small_design, eval_graphs=small_design)["final"]
    assert ms["spearman"] > md["spearman"] - 0.15


def test_fused_equals_sequential(small_design):
    """Paper Sec. 3.4: scheduling must not change the math."""
    from repro.core.parallel import run_fused, run_sequential
    from repro.kernels import ops
    g = small_design[0]
    x_cell = jnp.asarray(np.random.default_rng(0).normal(
        size=(g.n_cell, 32)).astype(np.float32))
    x_net = jnp.asarray(np.random.default_rng(1).normal(
        size=(g.n_net, 32)).astype(np.float32))

    def near():
        es = g.edges["near"]
        return ops.spmm(es.adj, es.adj_t, x_cell)

    def pinned():
        es = g.edges["pinned"]
        return ops.spmm(es.adj, es.adj_t, x_net)

    def pin():
        es = g.edges["pin"]
        return ops.spmm(es.adj, es.adj_t, x_cell)

    fns = [near, pinned, pin]
    a = run_fused(fns, [()] * 3)
    b = run_sequential(fns, [()] * 3)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-5)


def test_hetero_conv_max_merge_gradient(small_design):
    """Eqs. 12-14: the gradient routes through max() by the winner mask."""
    g = small_design[0]
    cfg = HeteroMPConfig(hidden=16, k_cell=8, k_net=8)
    params = init_hetero_layer(jax.random.PRNGKey(0), 16)
    xc = jnp.asarray(np.random.default_rng(0).normal(
        size=(g.n_cell, 16)).astype(np.float32))
    xn = jnp.asarray(np.random.default_rng(1).normal(
        size=(g.n_net, 16)).astype(np.float32))

    def f(p):
        yc, yn = hetero_conv(p, g, xc, xn, cfg)
        return jnp.sum(yc ** 2) + jnp.sum(yn ** 2)

    grads = jax.grad(f)(params)
    gn = sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0
    # w_pin only affects y_net; w_near only affects y_cell
    assert float(jnp.abs(grads.w_pin).sum()) > 0
    assert float(jnp.abs(grads.w_near).sum()) > 0


def test_lm_training_loss_decreases():
    from repro.launch.train import main as train_main
    losses = train_main(["--arch", "qwen3-0.6b", "--reduced",
                         "--steps", "30", "--batch", "4", "--seq", "64",
                         "--lr", "1e-3", "--log-every", "100"])
    assert np.mean(losses[-6:]) < np.mean(losses[:6])


def test_lm_checkpoint_restart_continues(tmp_path):
    """Kill-and-restart: restored run must continue from the checkpoint."""
    from repro.launch.train import main as train_main
    d = str(tmp_path / "ckpt")
    args = ["--arch", "qwen3-0.6b", "--reduced", "--batch", "2",
            "--seq", "32", "--ckpt-dir", d, "--ckpt-every", "5",
            "--log-every", "100"]
    train_main(args + ["--steps", "11"])
    from repro.checkpoint import latest_step
    assert latest_step(d) == 10
    losses = train_main(args + ["--steps", "16"])    # restores step 10
    assert len(losses) == 5                           # only 11..15 run
