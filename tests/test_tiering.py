"""Size-adaptive dense-tier routing (DESIGN.md §14).

Sub-crossover relations leave the super-arena chunk walk for a batched
masked dense matmul; these tests pin the routing rule and the hybrid
executor around the crossover itself:

* 5-backend fwd+grad parity of the hybrid ``drspmm_multi`` against the
  serial per-relation reference on plans that STRADDLE the threshold
  (mixed arena + dense tiers in one direction-group), including the
  default-constant crossover on a relation genuinely above it;
* tier routing is a function of (nnz, table area) alone — invariant under
  degree-preserving edge/node permutations (hypothesis property);
* exact threshold boundary: nnz == cutoff lands dense, cutoff + 1 lands
  arena, and both plans stay numerically identical;
* collation filler members stay inert when the batch plan routes through
  the dense tier;
* mesh-sharded parity on a mixed-tier plan (sharding flattens every
  relation back into per-shard local arenas — the documented §14 rule).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # bare container: seeded fallback
    from _hyp_fallback import given, settings, strategies as st

from _multidev import run_multidev
from repro.core.cbsr import cbsr_from_dense
from repro.core.drelu import drelu
from repro.core.hetero_mp import HeteroMPConfig
from repro.graphs.circuit import EDGE_SCHEMA
from repro.graphs.collate import collate_graphs
from repro.graphs.ell import DENSE_TIER_NNZ, build_relation_plan, \
    pack_ell_pair
from repro.graphs.generator import generate_partition, pack_graph_parallel
from repro.kernels import ops
from repro.models.hgnn import drcircuitgnn_forward, init_drcircuitgnn

settings.register_profile("fast", max_examples=15, deadline=None)
settings.load_profile("fast")

BACKENDS = ("pallas_fused", "xla_fused", "pallas", "xla", "dense")


def _assert_close(actual, ref, msg):
    atol = 1e-5 * max(1.0, float(np.abs(ref).max()) if ref.size else 1.0)
    np.testing.assert_allclose(actual, ref, atol=atol, rtol=1e-5,
                               err_msg=msg)


def _graph(n_cell, n_net, seed):
    coo, xc, xn, y = generate_partition(np.random.default_rng(seed),
                                        n_cell, n_net)
    return pack_graph_parallel(coo, n_cell, n_net, xc, xn, y)


def _mk(rng, n_dst, n_src, nnz):
    d = rng.integers(0, n_dst, nnz)
    s = rng.integers(0, n_src, nnz)
    pairs = np.unique(np.stack([d, s], 1), axis=0)
    w = rng.normal(size=pairs.shape[0]).astype(np.float32)
    w[w == 0] = 1.0
    return pairs[:, 0], pairs[:, 1], w


def _mixed_relations(rng, n_cell, n_net, near_nnz=None):
    sizes = {"cell": n_cell, "net": n_net}
    out = []
    for et, nnz in (("near", near_nnz or 4 * n_cell), ("pin", 2 * n_cell),
                    ("pinned", 2 * n_cell)):
        s_t, d_t = EDGE_SCHEMA[et]
        out.append((et, s_t, d_t,
                    *_mk(rng, sizes[d_t], sizes[s_t], max(nnz, 1))))
    return out


def _cbsr_pair(rng, n_cell, n_net, dim, k_cell=8, k_net=6):
    cc = cbsr_from_dense(drelu(jnp.asarray(
        rng.normal(size=(n_cell, dim)).astype(np.float32)), k_cell), k_cell)
    cn = cbsr_from_dense(drelu(jnp.asarray(
        rng.normal(size=(n_net, dim)).astype(np.float32)), k_net), k_net)
    return cc, cn


def _serial_refs(rels, sizes, cc, cn, dim, vc, vn):
    out = {}
    for et, s_t, d_t, dst, src, w in rels:
        adj, adj_t = pack_ell_pair(dst, src, w, sizes[d_t], sizes[s_t])
        c = cc if s_t == "cell" else cn
        v = vc if s_t == "cell" else vn
        out[et] = ops.drspmm(adj, adj_t, v, c.idx, dim, backend="dense")
    return out


def _check_parity(plan, rels, sizes, cc, cn, dim, backend, tag):
    refs = _serial_refs(rels, sizes, cc, cn, dim, cc.values, cn.values)
    ys = ops.drspmm_multi(plan, {"cell": (cc.values, cc.idx),
                                 "net": (cn.values, cn.idx)}, dim,
                          backend=backend)
    for et in refs:
        _assert_close(np.asarray(ys[et]), np.asarray(refs[et]),
                      f"{tag} fwd {backend}/{et}")

    def loss_multi(vc, vn):
        ys = ops.drspmm_multi(plan, {"cell": (vc, cc.idx),
                                     "net": (vn, cn.idx)}, dim,
                              backend=backend)
        return sum(jnp.sum(y ** 2) for y in ys.values())

    def loss_serial(vc, vn):
        refs = _serial_refs(rels, sizes, cc, cn, dim, vc, vn)
        return sum(jnp.sum(y ** 2) for y in refs.values())

    g = jax.grad(loss_multi, argnums=(0, 1))(cc.values, cn.values)
    g_ref = jax.grad(loss_serial, argnums=(0, 1))(cc.values, cn.values)
    for a, r, nm in zip(g, g_ref, ("cell", "net")):
        _assert_close(np.asarray(a), np.asarray(r),
                      f"{tag} grad {backend}/{nm}")


# ------------------- hybrid parity across the crossover -----------------

@pytest.fixture(scope="module")
def straddle_setup():
    """A plan whose relations straddle an overridden crossover: `near`
    lands on the arena tier, `pin`/`pinned` on the dense tier."""
    rng = np.random.default_rng(3)
    n_cell, n_net, dim = 57, 29, 64
    rels = _mixed_relations(rng, n_cell, n_net)
    sizes = {"cell": n_cell, "net": n_net}
    plan = build_relation_plan(rels, sizes, dense_threshold=150)
    assert plan.segment("near").tier == "arena"
    assert plan.segment("pin").tier == "dense"
    assert plan.has_arena and plan.has_dense
    cc, cn = _cbsr_pair(rng, n_cell, n_net, dim)
    return plan, rels, sizes, cc, cn, dim


@pytest.mark.parametrize("backend", BACKENDS)
def test_hybrid_multi_matches_serial(straddle_setup, backend):
    """Mixed-tier drspmm_multi == one serial drspmm per relation, fwd +
    grads in both source types, under every backend name."""
    plan, rels, sizes, cc, cn, dim = straddle_setup
    _check_parity(plan, rels, sizes, cc, cn, dim, backend, "straddle")


def test_default_crossover_straddle_parity():
    """Same property at the DEFAULT measured crossover, with a `near`
    genuinely above ``DENSE_TIER_NNZ`` and the cell–net relations below it
    — the real mixed-tier shape medium designs produce."""
    rng = np.random.default_rng(7)
    n_cell, n_net, dim = 300, 150, 32
    rels = _mixed_relations(rng, n_cell, n_net,
                            near_nnz=2 * DENSE_TIER_NNZ)
    sizes = {"cell": n_cell, "net": n_net}
    plan = build_relation_plan(rels, sizes)
    assert plan.segment("near").tier == "arena"
    assert plan.segment("pin").tier == "dense"
    cc, cn = _cbsr_pair(rng, n_cell, n_net, dim)
    _check_parity(plan, rels, sizes, cc, cn, dim, "xla_fused", "default-thr")


# --------------------------- threshold boundary -------------------------

def test_threshold_boundary_exact_nnz():
    """nnz == cutoff routes dense, nnz == cutoff + 1 routes arena (the rule
    is ``nnz <= thr``), and both plans compute identical numbers."""
    rng = np.random.default_rng(11)
    n_cell, n_net, dim = 80, 40, 32
    lin = rng.choice(n_cell * n_cell, size=96, replace=False)
    dst, src = np.divmod(np.sort(lin), n_cell)
    w = rng.normal(size=96).astype(np.float32)
    w[w == 0] = 1.0
    rels = [("near", "cell", "cell", dst, src, w)]
    sizes = {"cell": n_cell, "net": n_net}
    nnz = 96
    cc, cn = _cbsr_pair(rng, n_cell, n_net, dim)
    ys = {}
    for thr, want in ((nnz, "dense"), (nnz - 1, "arena")):
        plan = build_relation_plan(rels, sizes, dense_threshold=thr)
        assert plan.segments[0].tier == want, (thr, want)
        _check_parity(plan, rels, sizes, cc, cn, dim, "xla_fused",
                      f"thr={thr}")
        ys[want] = np.asarray(ops.drspmm_multi(
            plan, {"cell": (cc.values, cc.idx), "net": (cn.values, cn.idx)},
            dim, backend="xla_fused")["near"])
    np.testing.assert_allclose(ys["dense"], ys["arena"], atol=1e-5,
                               rtol=1e-5, err_msg="tier flip changed math")


# ----------------- routing invariance (hypothesis property) -------------

@given(st.integers(0, 2 ** 31 - 1))
def test_tier_routing_invariant_under_permutation(seed):
    """Tier routing is decided by (nnz, table area) alone: shuffling edge
    order and relabeling nodes (a degree-multiset-preserving permutation)
    must route every relation to the same tier."""
    rng = np.random.default_rng(seed)
    n_cell, n_net = 31, 17
    rels = _mixed_relations(rng, n_cell, n_net)
    sizes = {"cell": n_cell, "net": n_net}
    perm = {"cell": rng.permutation(n_cell), "net": rng.permutation(n_net)}
    prels = []
    for et, s_t, d_t, dst, src, w in rels:
        o = rng.permutation(dst.shape[0])
        prels.append((et, s_t, d_t, perm[d_t][dst][o], perm[s_t][src][o],
                      w[o]))
    thr = int(rng.integers(0, 5 * n_cell))
    base = build_relation_plan(rels, sizes, dense_threshold=thr)
    perm_plan = build_relation_plan(prels, sizes, dense_threshold=thr)
    assert [s.tier for s in base.segments] == \
        [s.tier for s in perm_plan.segments]


# ------------------- collated fillers through the dense tier ------------

def test_collated_filler_inert_through_dense_tier():
    """Filler replicas change nothing for the real members when the batch
    plan routes relations through the dense tier (tiny members: the whole
    direction-group is sub-crossover)."""
    members = [_graph(60, 30, 0), _graph(37, 20, 2)]
    params = init_drcircuitgnn(jax.random.PRNGKey(0), 16, 16, 32)
    cfg = HeteroMPConfig(hidden=32, k_cell=8, k_net=8, backend="xla_fused",
                         use_plan=True)
    plain = collate_graphs(members)
    padded = collate_graphs(members + [members[-1]], n_real=len(members))
    assert padded.graph.plan.has_dense, \
        {s.etype: s.tier for s in padded.graph.plan.segments}
    a = plain.split_cell(drcircuitgnn_forward(params, plain.graph, cfg))
    b = padded.split_cell(drcircuitgnn_forward(params, padded.graph, cfg))
    assert len(a) == len(b) == len(members)
    for i, (x, y) in enumerate(zip(a, b)):
        _assert_close(np.asarray(y), np.asarray(x), f"member {i} filler")


# ----------------------- sharded mixed-tier parity ----------------------

SHARDED_SCRIPT = r"""
import sys

import jax
import jax.numpy as jnp
import numpy as np

n = int(sys.argv[1])
assert jax.device_count() == n, (jax.device_count(), n)

from repro.core.cbsr import cbsr_from_dense
from repro.core.drelu import drelu
from repro.graphs.ell import build_relation_plan
from repro.kernels import ops
from repro.sharding.plan_shard import shard_relation_plan

rng = np.random.default_rng(3)
n_cell, n_net, dim, k = 57, 29, 32, 8


def mk(n_dst, n_src, nnz):
    d = rng.integers(0, n_dst, nnz)
    s = rng.integers(0, n_src, nnz)
    pairs = np.unique(np.stack([d, s], 1), axis=0)
    w = rng.normal(size=pairs.shape[0]).astype(np.float32)
    w[w == 0] = 1.0
    return pairs[:, 0], pairs[:, 1], w


rels = [("near", "cell", "cell", *mk(n_cell, n_cell, 4 * n_cell)),
        ("pin", "cell", "net", *mk(n_net, n_cell, 2 * n_cell)),
        ("pinned", "net", "cell", *mk(n_cell, n_net, 2 * n_cell))]
sizes = {"cell": n_cell, "net": n_net}
plan = build_relation_plan(rels, sizes, dense_threshold=150)
assert plan.has_arena and plan.has_dense, \
    {s.etype: s.tier for s in plan.segments}
splan = shard_relation_plan(plan, n)

cc = cbsr_from_dense(drelu(jnp.asarray(
    rng.normal(size=(n_cell, dim)).astype(np.float32)), k), k)
cn = cbsr_from_dense(drelu(jnp.asarray(
    rng.normal(size=(n_net, dim)).astype(np.float32)), k), k)
cbsr = {"cell": (cc.values, cc.idx), "net": (cn.values, cn.idx)}

y_ref = ops.drspmm_multi(plan, cbsr, dim, backend="xla_fused")
y_sh = ops.drspmm_multi_sharded(splan, cbsr, dim, backend="xla_fused")
for et in y_ref:
    r = np.asarray(y_ref[et])
    atol = 2e-5 * max(1.0, float(np.abs(r).max()))
    np.testing.assert_allclose(np.asarray(y_sh[et]), r, atol=atol,
                               rtol=2e-5, err_msg=f"fwd {et}")


def loss(op, p):
    def f(vc, vn):
        ys = op(p, {"cell": (vc, cc.idx), "net": (vn, cn.idx)}, dim,
                backend="xla_fused")
        return sum(jnp.sum(jnp.sin(y)) for y in ys.values())
    return f


g_ref = jax.grad(loss(ops.drspmm_multi, plan),
                 argnums=(0, 1))(cc.values, cn.values)
g_sh = jax.grad(loss(ops.drspmm_multi_sharded, splan),
                argnums=(0, 1))(cc.values, cn.values)
for a, r, t in zip(g_sh, g_ref, ("cell", "net")):
    r = np.asarray(r)
    atol = 2e-5 * max(1.0, float(np.abs(r).max()))
    np.testing.assert_allclose(np.asarray(a), r, atol=atol, rtol=2e-5,
                               err_msg=f"grad {t}")
print("MIXED_TIER_SHARDED_OK")
"""


@pytest.mark.slow
@pytest.mark.parametrize("n", [2, 3])
def test_sharded_mixed_tier_parity(n):
    """The sharded executor reproduces the hybrid single-device path on a
    plan that mixes tiers — sharding flattens every relation (dense tier
    included) back into per-shard local arenas (DESIGN.md §14)."""
    run_multidev(SHARDED_SCRIPT, n_devices=n, argv=[n],
                 expect=("MIXED_TIER_SHARDED_OK",))
