#!/usr/bin/env python
"""Check that intra-repo markdown links resolve (the CI docs leg).

Scans the given markdown files (default: every ``*.md`` at the repo root)
for inline links ``[text](target)``:

* ``http(s)://`` / ``mailto:`` targets are skipped (no network in CI);
* relative targets must exist on disk (resolved against the linking file);
* pure-anchor targets (``#section``) must match a heading slug in the same
  file, using GitHub slugification (lowercase, punctuation stripped,
  spaces to dashes).

Exit code 0 when every link resolves; 1 with one line per broken link.

    python tools/check_docs.py [FILE.md ...]
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.M)
CODE_FENCE_RE = re.compile(r"```.*?```", re.S)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, strip punctuation, spaces→dashes."""
    h = re.sub(r"[`*_]", "", heading.strip()).lower()
    h = re.sub(r"[^\w\- §]", "", h, flags=re.UNICODE)
    h = h.replace("§", "")          # github drops non-alnum like § too
    return re.sub(r"\s+", "-", h.strip())


def check_file(path: Path, repo_root: Path) -> list:
    text = path.read_text(encoding="utf-8")
    prose = CODE_FENCE_RE.sub("", text)     # links inside fences aren't links
    slugs = {github_slug(h) for h in HEADING_RE.findall(prose)}
    errors = []
    for m in LINK_RE.finditer(prose):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if target.startswith("#"):
            if target[1:] not in slugs:
                errors.append(f"{path}: broken anchor '{target}'")
            continue
        rel, _, anchor = target.partition("#")
        dest = (path.parent / rel).resolve()
        if not dest.exists():
            errors.append(f"{path}: broken link '{target}' "
                          f"(no such file: {dest.relative_to(repo_root) if dest.is_relative_to(repo_root) else dest})")
    return errors


def main(argv) -> int:
    repo_root = Path(__file__).resolve().parent.parent
    files = [Path(a) for a in argv] if argv else \
        sorted(repo_root.glob("*.md"))
    errors = []
    for f in files:
        if not f.exists():
            errors.append(f"{f}: file not found")
            continue
        errors.extend(check_file(f.resolve(), repo_root))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"[check_docs] {len(files)} files, "
          f"{'OK' if not errors else f'{len(errors)} broken links'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
