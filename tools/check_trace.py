#!/usr/bin/env python
"""Validate a Chrome trace-event JSON dump (the CI trace-smoke leg).

Checks, in order:

* the file round-trips as JSON and has a ``traceEvents`` list;
* every event carries ``ph``/``pid``/``tid`` (and ``name`` except bare
  ``E`` ends), with a numeric non-negative ``ts`` on non-metadata events;
* per ``(pid, tid)`` track, ``ts`` is monotonically non-decreasing once
  sorted order is asserted (the exporter sorts; a raw concatenation that
  interleaves out of order fails here);
* per track, ``B``/``E`` duration events are strictly nested: every ``E``
  matches the most recent open ``B`` of the same name, and no ``B`` is
  left open at end-of-track;
* ``X`` events carry a non-negative ``dur``;
* with ``--expect-device-tracks N``, the metadata names at least N
  distinct ``device/<i>`` tracks (per-ring-slot dispatch lanes);
* with ``--expect-event NAME`` (repeatable), at least one event with that
  name exists (e.g. ``inject:dispatch`` for chaos annotations,
  ``deadline_flush`` for the deadline regime).

Exit code 0 when the trace is well-formed; 1 with one line per problem.

    python tools/check_trace.py TRACE.json [--expect-device-tracks N]
                                           [--expect-event NAME ...]
"""

from __future__ import annotations

import json
import sys
from collections import defaultdict


def check_trace(doc: object, expect_device_tracks: int = 0,
                expect_events: tuple = ()) -> list:
    """Return a list of problem strings (empty == valid)."""
    problems = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["top level: expected an object with a 'traceEvents' list"]
    events = doc["traceEvents"]
    if not isinstance(events, list):
        return ["traceEvents: expected a list"]

    track_names = {}
    last_ts = {}
    open_spans = defaultdict(list)
    seen_names = set()
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph is None or "pid" not in ev or "tid" not in ev:
            problems.append(f"event {i}: missing ph/pid/tid: {ev}")
            continue
        if ph == "M":
            if ev.get("name") == "thread_name":
                track_names[(ev["pid"], ev["tid"])] = ev["args"]["name"]
            continue
        name = ev.get("name")
        if name is None and ph != "E":
            problems.append(f"event {i}: ph={ph!r} missing name")
            continue
        seen_names.add(name)
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"event {i} ({name!r}): bad ts {ts!r}")
            continue
        key = (ev["pid"], ev["tid"])
        if ts < last_ts.get(key, 0.0):
            problems.append(
                f"event {i} ({name!r}): ts {ts} < previous "
                f"{last_ts[key]} on track {track_names.get(key, key)}")
        last_ts[key] = ts
        if ph == "B":
            open_spans[key].append(name)
        elif ph == "E":
            stack = open_spans[key]
            if not stack:
                problems.append(
                    f"event {i}: E {name!r} with no open B on track "
                    f"{track_names.get(key, key)}")
            elif name is not None and stack[-1] != name:
                problems.append(
                    f"event {i}: E {name!r} crosses open B "
                    f"{stack[-1]!r} on track {track_names.get(key, key)}")
                stack.pop()
            else:
                stack.pop()
        elif ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i} ({name!r}): X bad dur {dur!r}")
    for key, stack in open_spans.items():
        if stack:
            problems.append(
                f"track {track_names.get(key, key)}: unclosed B span(s) "
                f"{stack}")
    n_dev = sum(1 for n in track_names.values()
                if n.startswith("device/"))
    if n_dev < expect_device_tracks:
        problems.append(
            f"expected >= {expect_device_tracks} device/<i> tracks, "
            f"found {n_dev} ({sorted(track_names.values())})")
    for want in expect_events:
        if want not in seen_names:
            problems.append(f"expected at least one {want!r} event; "
                            f"names seen: {sorted(map(str, seen_names))}")
    return problems


def main(argv) -> int:
    if not argv or argv[0].startswith("-"):
        print(__doc__)
        return 2
    path, args = argv[0], argv[1:]
    expect_dev = 0
    expect_events = []
    i = 0
    while i < len(args):
        if args[i] == "--expect-device-tracks":
            expect_dev = int(args[i + 1])
            i += 2
        elif args[i] == "--expect-event":
            expect_events.append(args[i + 1])
            i += 2
        else:
            print(f"unknown arg {args[i]!r}")
            return 2
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"{path}: {e}")
        return 1
    problems = check_trace(doc, expect_dev, tuple(expect_events))
    for p in problems:
        print(f"{path}: {p}")
    if not problems:
        n = sum(1 for e in doc["traceEvents"] if e.get("ph") != "M")
        print(f"{path}: OK ({n} events)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
